"""Paper Figs 3 & 4: NCCL all_reduce bandwidth vs message size and vs GPU
count for TCP / RoCE / GDR — reproduced from the calibrated α–β network model
(core/netmodel.py).  Validation targets from the paper's text:
  * 8 MB @ 1024 GPUs: GDR ≈ 10× TCP
  * >= 500 MB: GDR 20–30 GB/s busbw vs TCP ~6 GB/s (3–5×)
"""
import time

from repro.core import netmodel as nm

SIZES = [1e6, 8e6, 64e6, 256e6, 500e6, 1e9, 2e9]
COUNTS = [32, 64, 128, 256, 512, 1024, 1752]


def run():
    rows = []
    t0 = time.perf_counter()
    # Fig 3: bandwidth vs message size @ 1024 GPUs
    for proto in (nm.TCP, nm.ROCE, nm.GDR):
        for m in SIZES:
            bw = nm.bus_bandwidth(m, 1024, proto)
            rows.append((f"fig3/allreduce_busbw/{proto.name}/{int(m/1e6)}MB",
                         nm.allreduce_time(m, 1024, proto) * 1e6,
                         f"{bw/1e9:.2f}GBps"))
    # Fig 4: scaling vs GPU count @ 512 MB
    for proto in (nm.GDR, nm.ROCE):
        for n in COUNTS:
            bw = nm.bus_bandwidth(512e6, n, proto)
            rows.append((f"fig4/allreduce_scaling/{proto.name}/{n}gpu",
                         nm.allreduce_time(512e6, n, proto) * 1e6,
                         f"{bw/1e9:.2f}GBps"))
    # headline validations
    r_small = (nm.alg_bandwidth(8e6, 1024, nm.GDR)
               / nm.alg_bandwidth(8e6, 1024, nm.TCP))
    r_big = (nm.alg_bandwidth(500e6, 1024, nm.GDR)
             / nm.alg_bandwidth(500e6, 1024, nm.TCP))
    assert 6 <= r_small <= 14 and 3 <= r_big <= 6, (r_small, r_big)
    rows.append(("fig3/validate/gdr_vs_tcp@8MB",
                 (time.perf_counter() - t0) * 1e6, f"{r_small:.1f}x"))
    rows.append(("fig3/validate/gdr_vs_tcp@500MB", 0.0, f"{r_big:.1f}x"))
    return rows
