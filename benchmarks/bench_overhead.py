"""Paper Figs 5/6/8 analogue: the platform layers must cost <= 5%.

No VM/OpenShift layer exists here; the measured equivalent is the framework's
own instrumentation: train step with full telemetry + health checks + alert
evaluation vs the bare jitted step, across batch sizes (small batches stress
per-step overhead like small batches stressed network overhead in Fig 8)."""
import dataclasses
import time

import jax

from repro.configs import CONFIGS, TrainConfig
from repro.core import (AlertManager, Autopilot, MetricsRegistry, SimCluster,
                        SlackSink, StragglerDetector)
from repro.models import LM, ForwardOpts, make_batch
from repro.train import init_train_state, make_train_step

STEPS = 12


def _timed_loop(step, state, batch, instrumented: bool):
    reg = MetricsRegistry()
    cluster = SimCluster(4, registry=reg)
    autopilot = Autopilot(cluster, reg)
    detector = StragglerDetector(reg)
    alerts = AlertManager(reg, sinks=[SlackSink()])
    # warmup/compile
    state, _ = step(state, batch)
    jax.block_until_ready(state["params"])
    t0 = time.perf_counter()
    for i in range(STEPS):
        ts = time.perf_counter()
        state, m = step(state, batch)
        jax.block_until_ready(m["loss"])
        if instrumented:
            dt = time.perf_counter() - ts
            reg.histogram("train_step_seconds").observe(dt)
            reg.gauge("train_loss").set(float(m["loss"]))
            detector.observe_step(dt)
            if i % 4 == 0:
                autopilot.run_checks()
                detector.check(cluster, [0, 1, 2, 3])
                alerts.evaluate()
    return (time.perf_counter() - t0) / STEPS


def run():
    rows = []
    cfg = dataclasses.replace(CONFIGS["granite-13b"].reduced(), num_layers=4,
                              d_model=256, d_ff=1024)
    lm = LM(cfg)
    tcfg = TrainConfig(total_steps=100)
    opts = ForwardOpts(attn_impl="dense", remat="none")
    step = jax.jit(make_train_step(lm, tcfg, opts))
    worst = 0.0
    for bs in (2, 4, 8):
        state = init_train_state(lm, jax.random.key(0), tcfg)
        batch = make_batch(cfg, bs, 128)
        bare = _timed_loop(step, state, batch, instrumented=False)
        inst = _timed_loop(step, state, batch, instrumented=True)
        ovh = inst / bare - 1.0
        worst = max(worst, ovh)
        rows.append((f"fig8/step_time/bare/bs{bs}", bare * 1e6,
                     f"{bare*1e3:.1f}ms"))
        rows.append((f"fig8/step_time/instrumented/bs{bs}", inst * 1e6,
                     f"overhead={ovh*100:+.1f}%"))
    rows.append(("fig8/validate/max_overhead", 0.0, f"{worst*100:.1f}%"))
    assert worst < 0.05, f"instrumentation overhead {worst*100:.1f}% > 5%"
    return rows
