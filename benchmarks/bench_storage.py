"""Paper Fig 7: training iteration time with NFS vs Scale input storage.

The iteration model: step_time = compute + input_read (reads contend across
DP clients; Scale hits cache after warm-up).  Paper validation targets:
  * NFS steady-state variance ≈ 50%, Scale < 10%
  * Scale reaches steady state almost instantly, NFS takes many iterations
  * average step >= 10% faster on Scale
Plus a REAL measurement: local checkpoint serialize throughput (the blocking
part of a checkpoint on the fast tier).
"""
import time

import numpy as np

from repro.core import StorageStack, VirtualClock

COMPUTE_S = 4.5                  # Granite-13B-class step compute (paper ~5s)
READ_BYTES = int(2.5e9)          # per-step global input slice (768-GPU job)
ITERS = 120


def _simulate(tier: str, seed: int):
    clock = VirtualClock()
    stack = StorageStack(clock, seed=seed)
    times = []
    for step in range(ITERS):
        key = f"shard_{step % 8}"          # working set cycles over 8 shards
        if not stack.cos.exists(key):
            stack.cos.blobs[key] = READ_BYTES
        t0 = clock.now()
        stack.dataset_read(key, tier)
        clock.advance(COMPUTE_S)
        times.append(clock.now() - t0)
    return np.asarray(times)


def run():
    rows = []
    nfs = _simulate("nfs", 0)
    scale = _simulate("scale", 0)
    # steady state = last half
    nfs_ss, scale_ss = nfs[ITERS // 2:], scale[ITERS // 2:]
    var_nfs = (nfs_ss.max() - nfs_ss.min()) / nfs_ss.mean()
    var_scale = (scale_ss.max() - scale_ss.min()) / scale_ss.mean()
    speedup = nfs_ss.mean() / scale_ss.mean()
    for i in (0, 10, 30, 60, 119):
        rows.append((f"fig7/iter_time/nfs/step{i}", nfs[i] * 1e6,
                     f"{nfs[i]:.2f}s"))
        rows.append((f"fig7/iter_time/scale/step{i}", scale[i] * 1e6,
                     f"{scale[i]:.2f}s"))
    rows.append(("fig7/steady_variance/nfs", 0.0, f"{var_nfs*100:.0f}%"))
    rows.append(("fig7/steady_variance/scale", 0.0, f"{var_scale*100:.0f}%"))
    rows.append(("fig7/step_speedup_scale_vs_nfs", 0.0, f"{speedup:.2f}x"))
    assert var_scale < 0.15 and var_nfs > 0.3, (var_scale, var_nfs)
    assert speedup >= 1.10, speedup    # paper: >10% faster steps

    # REAL: blocking checkpoint serialize throughput on local fast tier
    arr = np.random.default_rng(0).normal(size=(8 << 20,)).astype(np.float32)
    import tempfile, os
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        np.savez(os.path.join(d, "ckpt.npz"), a=arr)
        dt = time.perf_counter() - t0
    rows.append(("real/ckpt_serialize_bw", dt * 1e6,
                 f"{arr.nbytes/dt/1e9:.2f}GBps"))
    return rows
