"""Paper §2.3.3: Young's-formula checkpointing and <10% lost time.

Table rows: the Young interval for the paper's three Vela jobs (Table 2
scale: 768–1024 GPUs = 96–128 nodes), and full goodput simulations of a
Granite-20B-class run (46 days, 768 GPUs) under the paper's failure rates
(avg 2%/host/month crashes) and the worst-case month (5%)."""
import time

from repro.core import simulate_job, young_interval
from repro.core.cluster import DEFAULT_RATES, FailureKind, MONTH
from repro.core.runtime import job_mtbf_seconds

CKPT_DELTA = 90.0        # seconds to write a sharded checkpoint to Scale
STEP_TIME = 5.0


def run():
    rows = []
    for name, gpus in (("granite-20b", 768), ("granite-13b", 768),
                       ("granite-8b", 1024)):
        nodes = gpus // 8
        mtbf = job_mtbf_seconds(nodes)
        tau = young_interval(CKPT_DELTA, mtbf)
        rows.append((f"s2.3.3/young_interval/{name}", tau * 1e6,
                     f"{tau/3600:.2f}h_every_{round(tau/STEP_TIME)}steps"))

    # Granite-20B: 46 days on 768 GPUs (96 nodes + 10% buffer pool)
    t0 = time.perf_counter()
    rep = simulate_job(n_cluster_nodes=106, job_nodes=96,
                       total_steps=120_000, base_step_time=STEP_TIME,
                       ckpt_write_seconds=CKPT_DELTA, seed=11)
    rows.append(("s2.3.3/goodput/avg_failure_rates",
                 (time.perf_counter() - t0) * 1e6,
                 f"lost={rep.lost_fraction*100:.1f}%_restarts={rep.restarts}"
                 f"_swaps={rep.node_swaps}"))
    assert rep.lost_fraction < 0.10, rep.summary()

    # worst-case month: 5% of hosts crash (paper's observed worst case)
    rates = dict(DEFAULT_RATES)
    rates[FailureKind.HOST_CRASH] = 0.05 / MONTH
    rep2 = simulate_job(n_cluster_nodes=106, job_nodes=96,
                        total_steps=120_000, base_step_time=STEP_TIME,
                        ckpt_write_seconds=CKPT_DELTA, seed=13, rates=rates)
    rows.append(("s2.3.3/goodput/worst_case_5pct_month", 0.0,
                 f"lost={rep2.lost_fraction*100:.1f}%_restarts={rep2.restarts}"))
    assert rep2.lost_fraction < 0.10, rep2.summary()
    return rows
