"""§Perf hillclimb summary (EXPERIMENTS.md): baseline vs optimized roofline
terms for the three selected cells + the decode cache-pinning fix, read from
the tagged dry-run records."""
import json
from pathlib import Path

from repro.roofline.analysis import from_record

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun" / \
    "pod16x16"

CHAINS = {
    "llama3-405b__train_4k": ["baseline", "it1_flatheads", "it4_fh_revertmask",
                              "it5_tpsm", "it6_tpsm_save", "it7_bigchunk"],
    "arctic-480b__train_4k": ["baseline", "it1_seqsp", "it3_epmoe_split"],
    "zamba2-1.2b__train_4k": ["baseline", "it1_sepconv", "it3_tponly"],
}

FINAL = {
    "llama3-405b__train_4k": "baseline",      # bound-metric optimum (see §Perf)
    "arctic-480b__train_4k": "it3_epmoe_split",
    "zamba2-1.2b__train_4k": "it1_sepconv",
}


def _load(cell: str, tag: str):
    suffix = "" if tag == "baseline" else f"__{tag}"
    p = DRYRUN / f"{cell}{suffix}.json"
    if not p.exists():
        return None
    rec = json.loads(p.read_text())
    return from_record(rec) if rec.get("ok") else None


def run():
    rows = []
    for cell, tags in CHAINS.items():
        base = _load(cell, "baseline")
        if base is None:
            rows.append((f"perf/{cell}", 0.0, "records_missing"))
            continue
        for tag in tags:
            r = _load(cell, tag)
            if r is None:
                continue
            rows.append((f"perf/{cell}/{tag}", r.bound_s * 1e6,
                         f"cmp{r.compute_s:.1f}s_mem{r.memory_s:.1f}s_"
                         f"coll{r.collective_s:.1f}s_mfu{r.mfu_bound*100:.1f}%"))
        best = _load(cell, FINAL[cell])
        gain = base.bound_s / best.bound_s
        rows.append((f"perf/{cell}/GAIN", 0.0,
                     f"{gain:.2f}x_bound_{base.mfu_bound*100:.1f}%->"
                     f"{best.mfu_bound*100:.1f}%MFU"))
        # arctic must show a real improvement; llama/zamba asserted >= 1.0
        assert gain >= 1.0 - 1e-9
    return rows
