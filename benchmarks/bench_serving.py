"""Serving benchmark: fused ragged decode vs the seed grouped-by-position
engine, and the paged KV cache vs dense rows (tokens/s, TTFT, decode
dispatches per engine iteration, and concurrent admissions at a fixed HBM
budget — the perf and memory wins are measured, not asserted).

The decode workload is deliberately ragged: mixed prompt lengths put every
slot at a distinct position, which degrades the seed engine to one decode
dispatch per *slot* per iteration while the fused engine stays at exactly
one.  The memory workload is deliberately short and same-prefixed: dense
rows pin ``max_seq`` positions per slot regardless, while the paged backend
pins ``ceil(len/page)`` pages and shares the common prefix page — that gap
is the concurrency multiplier under a fixed byte budget.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import time
from pathlib import Path
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CONFIGS
from repro.models import LM
from repro.serve import (Request, ServeEngine, contiguous_kv_bytes,
                         decode_transient_bytes, make_cache, page_kv_bytes)
from repro.serve.engine import sample_token

OUT_JSON = Path(__file__).resolve().parent / "out" / "decode_transient.json"
SHARDED_JSON = Path(__file__).resolve().parent / "out" / "sharded_serving.json"
CHUNKED_JSON = Path(__file__).resolve().parent / "out" / "chunked_prefill.json"


class GroupedReferenceEngine:
    """The seed engine's algorithm, kept as the benchmark baseline:
    token-by-token prefill through the full-batch decode step, slots grouped
    by position (one scalar-cache-index dispatch per distinct position per
    iteration), host-side numpy sampling.  Counts its device dispatches."""

    def __init__(self, lm: LM, params, max_batch: int, max_seq: int):
        self.lm, self.params = lm, params
        self.B, self.S = max_batch, max_seq
        dt = jnp.float32 if lm.cfg.dtype == "float32" else jnp.bfloat16
        self.cache = lm.init_cache(max_batch, max_seq, dtype=dt)
        self.slot_req: List = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.dispatches = 0
        self.iterations = 0
        self.ttft: List[float] = []
        self._decode = jax.jit(
            lambda p, t, c, i: lm.decode_step(p, t, c, jnp.asarray(i)))

    def submit(self, req: Request):
        req.submitted_at = time.perf_counter()
        self.queue.append(req)

    def _step_one(self, slot: int, token: int, pos: int):
        tokens = np.zeros((self.B, 1), np.int32)
        tokens[slot, 0] = token
        logits, self.cache = self._decode(self.params, jnp.asarray(tokens),
                                          self.cache, jnp.int32(pos))
        self.dispatches += 1
        return np.asarray(logits[slot, -1])

    def step(self) -> bool:
        for slot in [i for i, r in enumerate(self.slot_req) if r is None]:
            if not self.queue:
                break
            req = self.queue.pop(0)
            logits = None
            for pos, tok in enumerate(req.prompt):
                logits = self._step_one(slot, int(tok), pos)
            self.slot_req[slot] = req
            self.slot_pos[slot] = len(req.prompt)
            req._last_logits = logits
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        self.iterations += 1
        by_pos: Dict[int, List[int]] = {}
        for i in active:
            by_pos.setdefault(int(self.slot_pos[i]), []).append(i)
        vocab = self.lm.cfg.vocab_size
        for pos, slots in sorted(by_pos.items()):
            tokens = np.zeros((self.B, 1), np.int32)
            for i in slots:
                req = self.slot_req[i]
                tokens[i, 0] = sample_token(
                    np.asarray(req._last_logits[:vocab]), req.sampling,
                    len(req.out_tokens))
            logits, self.cache = self._decode(
                self.params, jnp.asarray(tokens), self.cache, jnp.int32(pos))
            self.dispatches += 1
            logits = np.asarray(logits[:, -1])
            now = time.perf_counter()
            for i in slots:
                req = self.slot_req[i]
                req.out_tokens.append(int(tokens[i, 0]))
                if req.first_token_at is None:
                    req.first_token_at = now
                    self.ttft.append(now - req.submitted_at)
                req._last_logits = logits[i]
                self.slot_pos[i] += 1
                if (len(req.out_tokens) >= req.max_new_tokens
                        or self.slot_pos[i] >= self.S):
                    self.finished.append(req)
                    self.slot_req[i] = None
        return True

    def run_until_drained(self, max_iters: int = 10_000):
        for _ in range(max_iters):
            if not self.step() and not self.queue:
                break
        return self.finished


def _workload(cfg, n_requests: int, new_tokens: int) -> List[Request]:
    rng = np.random.default_rng(3)
    reqs = []
    for i in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              int(rng.integers(3, 18))).astype(np.int32)
        reqs.append(Request(i, prompt, max_new_tokens=new_tokens))
    return reqs


def _drain_measured(eng, cfg, n_requests: int, new_tokens: int):
    """Warm up (pays jit compilation of the decode step and every prefill
    bucket), then time a fresh identical workload on the same engine so the
    reported numbers are steady-state serving cost."""
    for r in _workload(cfg, n_requests, new_tokens):
        eng.submit(r)
    eng.run_until_drained()
    n_warm = len(eng.finished)
    for r in _workload(cfg, n_requests, new_tokens):
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run_until_drained()
    wall = time.perf_counter() - t0
    done = eng.finished[n_warm:]
    assert len(done) == n_requests
    toks = sum(len(r.out_tokens) for r in done)
    ttft = float(np.median([r.first_token_at - r.submitted_at
                            for r in done]))
    return wall, toks, ttft


def _admission_at_budget(lm, cfg):
    """Concurrent short requests admitted under one fixed HBM budget.

    The budget is what a 4-slot dense cache pins at max_seq=64.  Everything
    is sized from that number: the dense engine gets 4 slots; the paged
    engines get ``budget / page_bytes`` physical pages (same HBM) and a
    generous slot count so *memory*, not slots, is the binding constraint.
    The workload is N identical short prompts (a shared system prompt) —
    the serving pattern the paper's train<->inference flips make common.

    The budget governs *pinned* cache bytes.  The XLA gather decode still
    materializes a dense-equivalent gathered KV view per step as a
    transient, which grows with the enlarged concurrent batch (see
    ``attention.gather_pages``); ``_decode_transient_sweep`` measures that
    transient against the page-table-walking flash kernel that removes it
    (``decode_impl="pallas"``).

    Admission is counted through backend ``alloc`` bookkeeping directly —
    the same host-side path ``ServeEngine._admit`` reserves through (whose
    end-to-end behaviour tests/test_kvcache.py covers), with zero device
    dispatches, so this comparison adds no jit compiles to ``make smoke``.
    """
    dense_slots, max_seq, page = 4, 64, 8
    budget = contiguous_kv_bytes(cfg, dense_slots, max_seq, jnp.float32)
    n_pages = budget // page_kv_bytes(cfg, page, jnp.float32)
    n_req, plen, new_tokens = 40, 12, 4
    prompt = (np.arange(plen) % cfg.vocab_size).astype(np.int32)
    footprint = min(plen + new_tokens, max_seq)

    def admitted(slots, backend, **kw):
        kv = make_cache(lm, slots, max_seq, dtype=jnp.float32,
                        backend=backend, **kw)
        n = 0
        while n < slots and kv.alloc(n, footprint, prefix=prompt) is not None:
            n += 1
        return n, kv.memory_stats()

    n_dense, dense_stats = admitted(dense_slots, "contiguous")
    n_paged, paged_stats = admitted(n_req, "paged", page_size=page,
                                    num_pages=n_pages)
    n_noshare, noshare_stats = admitted(n_req, "paged", page_size=page,
                                        num_pages=n_pages,
                                        prefix_sharing=False)
    assert dense_stats.bytes_total == budget
    assert paged_stats.bytes_total <= budget
    return [
        ("serving/concurrent_at_budget_dense", 0.0,
         f"{n_dense} admitted ({budget/1e3:.0f} kB budget)"),
        ("serving/concurrent_at_budget_paged", 0.0,
         f"{n_paged} admitted (x{n_paged/max(n_dense,1):.1f} vs dense; "
         f"{paged_stats.pages_in_use}/{paged_stats.pages_total} pages, "
         f"{paged_stats.pages_shared} shared)"),
        ("serving/concurrent_at_budget_paged_nosharing", 0.0,
         f"{n_noshare} admitted (x{n_noshare/max(n_dense,1):.1f} vs dense)"),
    ]


def _decode_transient_sweep(lm, cfg, params):
    """Gather-vs-kernel paged decode at several (batch, pages-per-slot)
    points: per-step transient bytes of the KV read path plus fused decode
    step latency.  Numbers land in ``benchmarks/out/decode_transient.json``.

    Transient accounting is split by what each path actually allocates:

    * **gather** — the dense-equivalent (B, M*page, KV, D) views are XLA
      temporaries, so we report the *measured* ``temp_size_in_bytes`` of the
      compiled single-layer attention op (and assert it grows with B·M).
    * **pallas** — the kernel's transient is its VMEM working set (one K and
      one V page block + fp32 online-softmax state), which XLA's temp
      accounting never sees; we report the analytic
      ``decode_transient_bytes`` (and assert it is independent of B and M).
      The measured temp of the *interpret-mode* simulation (a lax.scan over
      grid points — a CPU correctness vehicle, not a memory model) is
      recorded in the JSON for transparency.
    """
    from repro.models import attention as attn

    page = 8
    points = [(4, 4), (8, 4), (8, 8), (16, 8)]
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    rng = np.random.default_rng(11)
    records = []
    for b, m in points:
        pool_pages = b * m + 1
        q = jnp.asarray(
            rng.normal(size=(b, 1, kvh, cfg.num_heads // kvh, hd)),
            jnp.float32)
        kp = jnp.asarray(rng.normal(size=(pool_pages, page, kvh, hd)),
                         jnp.float32)
        vp = jnp.asarray(rng.normal(size=(pool_pages, page, kvh, hd)),
                         jnp.float32)
        pt = jnp.asarray(rng.integers(1, pool_pages, (b, m)), jnp.int32)
        pos = jnp.asarray(rng.integers(0, m * page, (b,)), jnp.int32)
        # paged decode_step inputs for the latency measurement
        kv = lm.init_cache(b, m * page, dtype=jnp.float32, backend="paged",
                           page_size=page, num_pages=pool_pages)
        for s in range(b):
            kv.alloc(s, min(int(pos[s]) + 2, m * page))
        view = kv.decode_view()
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 1)), jnp.int32)
        for impl in ("gather", "pallas"):
            op = jax.jit(functools.partial(attn.decode_attention, impl=impl))
            measured = op.lower(q, kp, vp, pos, pt).compile() \
                .memory_analysis().temp_size_in_bytes
            analytic = decode_transient_bytes(cfg, b, m, page, jnp.float32,
                                              impl)
            step = jax.jit(functools.partial(lm.decode_step,
                                             decode_impl=impl))
            _, c0 = step(params, toks, view, pos)            # compile+warm
            jax.block_until_ready(c0)
            t0 = time.perf_counter()
            reps = 5
            for _ in range(reps):
                _, c = step(params, toks, view, pos)
                jax.block_until_ready(c)
            step_us = (time.perf_counter() - t0) / reps * 1e6
            from repro.kernels.ops import _interpret
            records.append({
                "batch": b, "pages_per_slot": m, "page_size": page,
                "impl": impl,
                # interpret=True means the pallas latency is the CPU
                # interpreter simulating the grid, not a Mosaic kernel —
                # only the transient-bytes contrast carries to TPU
                "interpret": bool(impl == "pallas" and _interpret()),
                "attn_temp_bytes_measured": int(measured),
                "transient_bytes": int(analytic if impl == "pallas"
                                       else measured),
                "transient_bytes_analytic": int(analytic),
                "decode_step_us": round(step_us, 1),
            })

    by = {(r["batch"], r["pages_per_slot"], r["impl"]): r for r in records}
    # gather's transient grows with the paged-enlarged batch width B*M ...
    g_small = by[(4, 4, "gather")]["transient_bytes"]
    g_big = by[(16, 8, "gather")]["transient_bytes"]
    assert g_big >= 4 * g_small, (g_small, g_big)
    # ... while the kernel's is O(block): identical at every point and far
    # below the gather transient at the widest one
    k_vals = {by[(b, m, "pallas")]["transient_bytes"] for b, m in points}
    assert len(k_vals) == 1, k_vals
    assert by[(16, 8, "pallas")]["transient_bytes"] * 8 \
        < by[(16, 8, "gather")]["transient_bytes"]
    OUT_JSON.parent.mkdir(parents=True, exist_ok=True)
    OUT_JSON.write_text(json.dumps(records, indent=1))

    rows = []
    for b, m in points:
        g, k = by[(b, m, "gather")], by[(b, m, "pallas")]
        rows.append((
            f"serving/decode_transient_b{b}_m{m}", g["decode_step_us"],
            f"gather={g['transient_bytes']}B kernel={k['transient_bytes']}B "
            f"(x{g['transient_bytes'] / k['transient_bytes']:.0f}); "
            f"kernel_step={k['decode_step_us']:.0f}us"))
    return rows


def run_decode():
    """The gather-vs-kernel transient sweep alone (``make bench-decode``)."""
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    return _decode_transient_sweep(lm, cfg, lm.init(jax.random.key(0)))


def run_sharded():
    """Sharded paged serving sweep (``make bench-sharded``, 8 fake host
    devices): the same ragged workload served over 1/2/4/8-chip inference
    meshes with the kv_pages-partitioned pool.

    Reported per mesh width n: **pinned KV bytes per chip** — both the
    analytic P/n page split from ``memory_stats`` and the *measured* max
    per-device bytes of the live pool arrays (they must agree: the pool
    shards down with the mesh instead of replicating) — plus steady-state
    fused decode-step latency vs the 1-chip baseline and the end-to-end
    token-stream parity assert.  JSON lands in
    ``benchmarks/out/sharded_serving.json``.

    On CPU the shard_map runs over fake host devices, so the latency column
    is a dispatch-overhead trend (n interpreter shards + the psum merge),
    not an ICI model; the per-chip byte accounting is exact everywhere."""
    n_dev = len(jax.devices())
    widths = [n for n in (1, 2, 4, 8) if n <= n_dev]
    if widths != [1, 2, 4, 8]:
        print(f"# bench-sharded: only {n_dev} devices visible; sweeping "
              f"{widths} (run with XLA_FLAGS="
              "--xla_force_host_platform_device_count=8 for the full sweep)")
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    max_batch, max_seq, page, pool = 8, 64, 8, 64   # 64 pages: all n divide
    n_requests, new_tokens = 12, 8

    from repro.parallel.mesh import make_mesh

    records, rows, base = [], [], None
    base_streams = None
    for n in widths:
        mesh = make_mesh((n,), ("model",)) if n > 1 else None
        eng = ServeEngine(lm, params, max_batch, max_seq,
                          cache_backend="paged", page_size=page,
                          num_pages=pool, mesh=mesh)
        wall, toks, ttft = _drain_measured(eng, cfg, n_requests, new_tokens)
        streams = sorted((r.id, tuple(r.out_tokens)) for r in eng.finished)
        if base_streams is None:
            base_streams = streams
        else:
            assert streams == base_streams, \
                f"sharded stream divergence at n={n}"
        st = eng.kv.memory_stats()
        assert st.mesh_chips == (n if mesh is not None else 1)
        assert st.bytes_per_chip == st.bytes_total // st.mesh_chips
        # measured per-device bytes of the live pool (post-decode, so the
        # steady-state sharding — not a prefill transient — is what's on
        # each chip)
        per_dev: Dict = {}
        for arr in jax.tree.leaves(eng.kv.state["layers"]):
            for s in arr.addressable_shards:
                key = repr(s.device)
                per_dev[key] = per_dev.get(key, 0) + s.data.nbytes
        measured_per_chip = max(per_dev.values())
        assert measured_per_chip == st.bytes_per_chip, (
            measured_per_chip, st.bytes_per_chip)
        # steady-state fused step latency: all slots mid-decode
        view = eng.kv.decode_view()
        args = (jnp.asarray(np.zeros((max_batch, 1), np.int32)),
                view["layers"], view.get("page_table"),
                jnp.asarray(np.full(max_batch, 9, np.int32)),
                jnp.asarray(np.ones(max_batch, bool)),
                jnp.asarray(np.zeros(max_batch, np.float32)),
                jnp.asarray(np.zeros(max_batch, np.int32)),
                jnp.asarray(np.ones(max_batch, np.float32)),
                jnp.asarray(np.zeros(max_batch, np.int32)),
                jnp.asarray(np.ones(max_batch, np.int32)), True)
        tok, layers = eng._fused(params, *args)      # warm (donates view)
        jax.block_until_ready(layers)
        reps, t0 = 10, time.perf_counter()
        for _ in range(reps):
            tok, layers = eng._fused(params, args[0], layers, *args[2:])
            jax.block_until_ready(layers)
        step_us = (time.perf_counter() - t0) / reps * 1e6
        if base is None:
            base = step_us
        records.append({
            "mesh": n, "pool_pages": st.pages_total + 1, "page_size": page,
            "pinned_bytes_total": st.bytes_total,
            "pinned_bytes_per_chip": st.bytes_per_chip,
            "pinned_bytes_per_chip_measured": int(measured_per_chip),
            "fused_step_us": round(step_us, 1),
            "tok_s": round(toks / wall, 1),
            "ttft_p50_ms": round(ttft * 1e3, 2),
            "stream_parity": True,
        })
        rows.append((
            f"serving/sharded_step_n{n}", step_us,
            f"{st.bytes_per_chip/1e3:.0f}kB/chip pinned "
            f"(P/{st.mesh_chips}={((st.pages_total + 1) // st.mesh_chips)} "
            f"pages), x{step_us/base:.2f} vs 1-chip, parity ok"))
    # pool bytes must scale down P/n with the mesh
    per_chip = {r["mesh"]: r["pinned_bytes_per_chip"] for r in records}
    for n in widths[1:]:
        assert per_chip[n] * n == per_chip[widths[0]] * widths[0], per_chip
    SHARDED_JSON.parent.mkdir(parents=True, exist_ok=True)
    SHARDED_JSON.write_text(json.dumps(records, indent=1))
    return rows


def run_chunked():
    """Long-prompt-vs-streams workload (``make bench-chunked``): short
    requests decode steadily; a long prompt is admitted mid-flight.  With
    whole-prompt prefill the admission stalls every stream for the prompt's
    full forward; with chunked prefill (``prefill_chunk``) the prompt lands
    one chunk per iteration interleaved with the fused decode steps, so no
    stream's inter-token gap ever covers more than one chunk of prefill
    compute.

    Measured per mode: **max inter-token gap** across the streams (wall
    time between consecutive emitted tokens, excluding TTFT), the long
    request's TTFT, steady-state fused-step wall time, and the stall
    telemetry (``serve_decode_stall_iters`` — zero by construction when
    chunking).  Token streams must match bitwise between the two modes.
    JSON lands in ``benchmarks/out/chunked_prefill.json``."""
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    max_batch, max_seq, page, chunk = 4, 512, 8, 16
    long_len, stream_new = 480, 44
    rng = np.random.default_rng(23)
    stream_prompts = [rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
                      for _ in range(3)]
    long_prompt = rng.integers(0, cfg.vocab_size, long_len).astype(np.int32)

    def run_one(chunked: bool):
        kw = dict(prefill_chunk=chunk) if chunked else {}
        eng = ServeEngine(lm, params, max_batch, max_seq,
                          cache_backend="paged", page_size=page, **kw)

        def drive(offset):
            """Submit streams, let them reach steady decode, admit the long
            prompt, run to drain.  Returns (per-stream max/median inter-token
            gap, long-request TTFT, offset-normalized token streams)."""
            for i, p in enumerate(stream_prompts):
                eng.submit(Request(offset + i, p.copy(),
                                   max_new_tokens=stream_new))
            for _ in range(3):
                eng.step()
            eng.submit(Request(offset + 9, long_prompt.copy(),
                               max_new_tokens=8))
            # baseline the in-flight streams NOW so the very next
            # iteration — the one that admits the long prompt — shows up
            # as a gap (this is exactly the stall being measured)
            stamps: Dict[int, List[float]] = {}
            counts: Dict[int, int] = {}
            t_base = time.perf_counter()
            for r in eng.slot_req:
                if r is not None:
                    counts[r.id] = len(r.out_tokens)
                    stamps[r.id] = [t_base]
            n_done = len(eng.finished)      # prior repeats: skip their tail
            while eng.step() or eng.queue:
                now = time.perf_counter()
                for r in eng.finished[n_done:] + [r for r in eng.slot_req
                                                  if r is not None]:
                    n = len(r.out_tokens)
                    if n > counts.get(r.id, 0):
                        stamps.setdefault(r.id, []).extend(
                            [now] * (n - counts.get(r.id, 0)))
                        counts[r.id] = n
            gaps = [b - a for rid, ts in stamps.items()
                    if offset <= rid < offset + 9
                    for a, b in zip(ts, ts[1:])]
            done = {r.id - offset: r for r in eng.finished
                    if r.id >= offset}
            return (max(gaps), float(np.median(gaps)),
                    done[9].first_token_at - done[9].submitted_at,
                    sorted((i, tuple(r.out_tokens))
                           for i, r in done.items()))

        drive(0)                                    # warm: pays every jit
        stall0 = eng.reg.counter("serve_decode_stall_iters").get()
        chunk0 = eng.reg.counter("serve_prefill_chunks_total").get()
        # three measured repeats; the reported worst gap is the MIN over
        # repeats of the per-repeat max — scheduler noise inflates a max,
        # it never deflates one below the true stall cost, so min-of-max
        # is the noise-robust estimate of the structural worst gap
        t0 = time.perf_counter()
        reps = [drive(100 * (r + 1)) for r in range(3)]
        wall = time.perf_counter() - t0
        stalls = eng.reg.counter("serve_decode_stall_iters").get() - stall0
        streams = reps[0][3]
        assert all(r[3] == streams for r in reps), "repeat divergence"
        return {
            "mode": "chunked" if chunked else "whole_prompt",
            "prefill_chunk": chunk if chunked else 0,
            "max_stream_gap_ms": round(min(r[0] for r in reps) * 1e3, 3),
            "max_stream_gap_ms_per_rep": [round(r[0] * 1e3, 3)
                                          for r in reps],
            "median_stream_gap_ms": round(
                float(np.median([r[1] for r in reps])) * 1e3, 3),
            "ttft_long_ms": round(
                float(np.median([r[2] for r in reps])) * 1e3, 2),
            "decode_stall_iters": int(stalls),
            "prefill_chunks": int(eng.reg.counter(
                "serve_prefill_chunks_total").get() - chunk0),
            "repeats": len(reps),
            "wall_s": round(wall, 3),
        }, streams

    whole, whole_streams = run_one(False)
    chunked, chunked_streams = run_one(True)
    # bitwise token-stream parity between the two prefill modes, and the
    # structural stall contrast: chunking bounds every decode iteration's
    # prefill work at one budget, the whole-prompt engine provably stalled
    assert chunked_streams == whole_streams, "chunked/whole stream divergence"
    assert chunked["decode_stall_iters"] == 0, chunked
    assert whole["decode_stall_iters"] > 0, whole
    # the worst stream gap must shrink: whole-prompt pays the full 480-token
    # prefill inside one gap, chunked pays at most one 16-token chunk
    assert chunked["max_stream_gap_ms"] < whole["max_stream_gap_ms"], (
        chunked["max_stream_gap_ms"], whole["max_stream_gap_ms"])
    records = [whole, chunked]
    CHUNKED_JSON.parent.mkdir(parents=True, exist_ok=True)
    CHUNKED_JSON.write_text(json.dumps(records, indent=1))
    return [
        ("serving/chunked_max_stream_gap", chunked["max_stream_gap_ms"] * 1e3,
         f"{chunked['max_stream_gap_ms']:.1f}ms max inter-token gap "
         f"(median {chunked['median_stream_gap_ms']:.1f}ms), "
         f"{chunked['prefill_chunks']} chunks, 0 stall iters, parity ok"),
        ("serving/whole_max_stream_gap", whole["max_stream_gap_ms"] * 1e3,
         f"{whole['max_stream_gap_ms']:.1f}ms max inter-token gap "
         f"(x{whole['max_stream_gap_ms']/chunked['max_stream_gap_ms']:.1f} "
         f"vs chunked; {whole['decode_stall_iters']} stall iters)"),
        ("serving/chunked_ttft_long", chunked["ttft_long_ms"] * 1e3,
         f"long-prompt TTFT {chunked['ttft_long_ms']:.0f}ms chunked vs "
         f"{whole['ttft_long_ms']:.0f}ms whole"),
    ]


def run():
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    max_batch, max_seq, new_tokens, n_requests = 8, 64, 8, 12

    fused = ServeEngine(lm, params, max_batch, max_seq)   # paged default
    fused_wall, fused_toks, fused_ttft = _drain_measured(
        fused, cfg, n_requests, new_tokens)
    # counters cover warmup+measured identically for both engines, so the
    # dispatch ratio is unaffected by including the warmup pass
    fused_iters = fused.reg.counter("serve_iterations_total").get()
    fused_decode = fused.reg.counter("serve_decode_dispatches_total").get()
    fused_prefill = fused.reg.counter("serve_prefill_dispatches_total").get()
    pf_batch = fused.reg.histogram("serve_prefill_batch_size")

    contig = ServeEngine(lm, params, max_batch, max_seq,
                         cache_backend="contiguous")
    contig_wall, contig_toks, _ = _drain_measured(
        contig, cfg, n_requests, new_tokens)

    # paged and contiguous backends must emit identical greedy streams —
    # warmup and measured passes reuse request ids, so compare the full
    # multiset of (id, stream) pairs, not a last-write-wins dict
    fused_out = sorted((r.id, tuple(r.out_tokens)) for r in fused.finished)
    contig_out = sorted((r.id, tuple(r.out_tokens)) for r in contig.finished)
    assert fused_out == contig_out, "paged/contiguous token divergence"

    ref = GroupedReferenceEngine(lm, params, max_batch, max_seq)
    ref_wall, ref_toks, ref_ttft = _drain_measured(
        ref, cfg, n_requests, new_tokens)

    assert fused_toks == ref_toks, (fused_toks, ref_toks)
    reduction = ref.dispatches / max(fused_decode + fused_prefill, 1)
    return [
        ("serving/fused_us_per_tok", fused_wall / max(fused_toks, 1) * 1e6,
         f"tok_s={fused_toks / fused_wall:.1f} (paged kv)"),
        ("serving/fused_ttft_p50", fused_ttft * 1e6,
         f"decode_calls_per_iter="
         f"{fused_decode / max(fused_iters, 1):.2f}"),
        ("serving/contiguous_us_per_tok",
         contig_wall / max(contig_toks, 1) * 1e6,
         f"tok_s={contig_toks / contig_wall:.1f} (dense kv, parity ok)"),
        ("serving/grouped_us_per_tok", ref_wall / max(ref_toks, 1) * 1e6,
         f"tok_s={ref_toks / ref_wall:.1f}"),
        ("serving/grouped_ttft_p50", ref_ttft * 1e6,
         f"decode_calls_per_iter="
         f"{ref.dispatches / max(ref.iterations, 1):.2f}"),
        ("serving/dispatch_reduction", 0.0,
         f"{reduction:.1f}x ({ref.dispatches} grouped vs "
         f"{fused_decode + fused_prefill:.0f} fused device calls; "
         f"prefill batch p50={pf_batch.quantile(0.5):.0f})"),
    ] + _admission_at_budget(lm, cfg) \
      + _decode_transient_sweep(lm, cfg, params)
