"""Serving benchmark: fused ragged decode vs the seed grouped-by-position
engine (tokens/s, TTFT, and decode dispatches per engine iteration on a
ragged workload — the perf win is measured, not asserted).

The workload is deliberately ragged: mixed prompt lengths put every slot at
a distinct position, which degrades the seed engine to one decode dispatch
per *slot* per iteration while the fused engine stays at exactly one.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CONFIGS
from repro.models import LM
from repro.serve import Request, ServeEngine
from repro.serve.engine import sample_token


class GroupedReferenceEngine:
    """The seed engine's algorithm, kept as the benchmark baseline:
    token-by-token prefill through the full-batch decode step, slots grouped
    by position (one scalar-cache-index dispatch per distinct position per
    iteration), host-side numpy sampling.  Counts its device dispatches."""

    def __init__(self, lm: LM, params, max_batch: int, max_seq: int):
        self.lm, self.params = lm, params
        self.B, self.S = max_batch, max_seq
        dt = jnp.float32 if lm.cfg.dtype == "float32" else jnp.bfloat16
        self.cache = lm.init_cache(max_batch, max_seq, dtype=dt)
        self.slot_req: List = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.dispatches = 0
        self.iterations = 0
        self.ttft: List[float] = []
        self._decode = jax.jit(
            lambda p, t, c, i: lm.decode_step(p, t, c, jnp.asarray(i)))

    def submit(self, req: Request):
        req.submitted_at = time.perf_counter()
        self.queue.append(req)

    def _step_one(self, slot: int, token: int, pos: int):
        tokens = np.zeros((self.B, 1), np.int32)
        tokens[slot, 0] = token
        logits, self.cache = self._decode(self.params, jnp.asarray(tokens),
                                          self.cache, jnp.int32(pos))
        self.dispatches += 1
        return np.asarray(logits[slot, -1])

    def step(self) -> bool:
        for slot in [i for i, r in enumerate(self.slot_req) if r is None]:
            if not self.queue:
                break
            req = self.queue.pop(0)
            logits = None
            for pos, tok in enumerate(req.prompt):
                logits = self._step_one(slot, int(tok), pos)
            self.slot_req[slot] = req
            self.slot_pos[slot] = len(req.prompt)
            req._last_logits = logits
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        self.iterations += 1
        by_pos: Dict[int, List[int]] = {}
        for i in active:
            by_pos.setdefault(int(self.slot_pos[i]), []).append(i)
        vocab = self.lm.cfg.vocab_size
        for pos, slots in sorted(by_pos.items()):
            tokens = np.zeros((self.B, 1), np.int32)
            for i in slots:
                req = self.slot_req[i]
                tokens[i, 0] = sample_token(
                    np.asarray(req._last_logits[:vocab]), req.sampling,
                    len(req.out_tokens))
            logits, self.cache = self._decode(
                self.params, jnp.asarray(tokens), self.cache, jnp.int32(pos))
            self.dispatches += 1
            logits = np.asarray(logits[:, -1])
            now = time.perf_counter()
            for i in slots:
                req = self.slot_req[i]
                req.out_tokens.append(int(tokens[i, 0]))
                if req.first_token_at is None:
                    req.first_token_at = now
                    self.ttft.append(now - req.submitted_at)
                req._last_logits = logits[i]
                self.slot_pos[i] += 1
                if (len(req.out_tokens) >= req.max_new_tokens
                        or self.slot_pos[i] >= self.S):
                    self.finished.append(req)
                    self.slot_req[i] = None
        return True

    def run_until_drained(self, max_iters: int = 10_000):
        for _ in range(max_iters):
            if not self.step() and not self.queue:
                break
        return self.finished


def _workload(cfg, n_requests: int, new_tokens: int) -> List[Request]:
    rng = np.random.default_rng(3)
    reqs = []
    for i in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              int(rng.integers(3, 18))).astype(np.int32)
        reqs.append(Request(i, prompt, max_new_tokens=new_tokens))
    return reqs


def _drain_measured(eng, cfg, n_requests: int, new_tokens: int):
    """Warm up (pays jit compilation of the decode step and every prefill
    bucket), then time a fresh identical workload on the same engine so the
    reported numbers are steady-state serving cost."""
    for r in _workload(cfg, n_requests, new_tokens):
        eng.submit(r)
    eng.run_until_drained()
    n_warm = len(eng.finished)
    for r in _workload(cfg, n_requests, new_tokens):
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run_until_drained()
    wall = time.perf_counter() - t0
    done = eng.finished[n_warm:]
    assert len(done) == n_requests
    toks = sum(len(r.out_tokens) for r in done)
    ttft = float(np.median([r.first_token_at - r.submitted_at
                            for r in done]))
    return wall, toks, ttft


def run():
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    max_batch, max_seq, new_tokens, n_requests = 8, 64, 8, 12

    fused = ServeEngine(lm, params, max_batch, max_seq)
    fused_wall, fused_toks, fused_ttft = _drain_measured(
        fused, cfg, n_requests, new_tokens)
    # counters cover warmup+measured identically for both engines, so the
    # dispatch ratio is unaffected by including the warmup pass
    fused_iters = fused.reg.counter("serve_iterations_total").get()
    fused_decode = fused.reg.counter("serve_decode_dispatches_total").get()
    fused_prefill = fused.reg.counter("serve_prefill_dispatches_total").get()

    ref = GroupedReferenceEngine(lm, params, max_batch, max_seq)
    ref_wall, ref_toks, ref_ttft = _drain_measured(
        ref, cfg, n_requests, new_tokens)

    assert fused_toks == ref_toks, (fused_toks, ref_toks)
    reduction = ref.dispatches / max(fused_decode + fused_prefill, 1)
    return [
        ("serving/fused_us_per_tok", fused_wall / max(fused_toks, 1) * 1e6,
         f"tok_s={fused_toks / fused_wall:.1f}"),
        ("serving/fused_ttft_p50", fused_ttft * 1e6,
         f"decode_calls_per_iter="
         f"{fused_decode / max(fused_iters, 1):.2f}"),
        ("serving/grouped_us_per_tok", ref_wall / max(ref_toks, 1) * 1e6,
         f"tok_s={ref_toks / ref_wall:.1f}"),
        ("serving/grouped_ttft_p50", ref_ttft * 1e6,
         f"decode_calls_per_iter="
         f"{ref.dispatches / max(ref.iterations, 1):.2f}"),
        ("serving/dispatch_reduction", 0.0,
         f"{reduction:.1f}x ({ref.dispatches} grouped vs "
         f"{fused_decode + fused_prefill:.0f} fused device calls)"),
    ]
