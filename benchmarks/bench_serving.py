"""Serving benchmark: fused ragged decode vs the seed grouped-by-position
engine, and the paged KV cache vs dense rows (tokens/s, TTFT, decode
dispatches per engine iteration, and concurrent admissions at a fixed HBM
budget — the perf and memory wins are measured, not asserted).

The decode workload is deliberately ragged: mixed prompt lengths put every
slot at a distinct position, which degrades the seed engine to one decode
dispatch per *slot* per iteration while the fused engine stays at exactly
one.  The memory workload is deliberately short and same-prefixed: dense
rows pin ``max_seq`` positions per slot regardless, while the paged backend
pins ``ceil(len/page)`` pages and shares the common prefix page — that gap
is the concurrency multiplier under a fixed byte budget.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import time
from pathlib import Path
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CONFIGS
from repro.models import LM
from repro.serve import (FaultEvent, FaultPlan, PrefixStore, PriorityClass,
                         Request, SamplingParams, ServeEngine, TenancyConfig,
                         TenantSpec, contiguous_kv_bytes,
                         decode_transient_bytes, make_cache, page_kv_bytes,
                         prefill_transient_bytes)
from repro.serve.engine import sample_token

OUT_JSON = Path(__file__).resolve().parent / "out" / "decode_transient.json"
OFFLOAD_JSON = Path(__file__).resolve().parent / "out" / "host_offload.json"
SHARDED_JSON = Path(__file__).resolve().parent / "out" / "sharded_serving.json"
CHUNKED_JSON = Path(__file__).resolve().parent / "out" / "chunked_prefill.json"
QUANT_JSON = Path(__file__).resolve().parent / "out" / "quant_kv.json"
TENANT_JSON = Path(__file__).resolve().parent / "out" / "tenant_slo.json"
FAULTS_JSON = Path(__file__).resolve().parent / "out" / "fault_recovery.json"
# committed perf trajectory: one entry appended per `make bench-quant` run,
# so regressions in the headline serving numbers show up in review diffs
TRAJECTORY_JSON = Path(__file__).resolve().parents[1] / "BENCH_serving.json"

# documented int8 KV quality bound (see docs/serving.md "Quantized KV
# pages"): max |quantized - fp32-oracle| over every decoded logit of the
# bench workload.  Per-element dequant error is <= absmax/254 per row
# (tests/test_quant.py); this is the calibrated end-to-end bound the bench
# asserts after that error propagates through attention + MLP + unembed.
QUANT_LOGIT_TOL = 0.05


def _append_trajectory(entry: dict) -> None:
    hist = (json.loads(TRAJECTORY_JSON.read_text())
            if TRAJECTORY_JSON.exists() else [])
    hist.append(entry)
    TRAJECTORY_JSON.write_text(json.dumps(hist, indent=1) + "\n")


class GroupedReferenceEngine:
    """The seed engine's algorithm, kept as the benchmark baseline:
    token-by-token prefill through the full-batch decode step, slots grouped
    by position (one scalar-cache-index dispatch per distinct position per
    iteration), host-side numpy sampling.  Counts its device dispatches."""

    def __init__(self, lm: LM, params, max_batch: int, max_seq: int):
        self.lm, self.params = lm, params
        self.B, self.S = max_batch, max_seq
        dt = jnp.float32 if lm.cfg.dtype == "float32" else jnp.bfloat16
        self.cache = lm.init_cache(max_batch, max_seq, dtype=dt)
        self.slot_req: List = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.dispatches = 0
        self.iterations = 0
        self.ttft: List[float] = []
        self._decode = jax.jit(
            lambda p, t, c, i: lm.decode_step(p, t, c, jnp.asarray(i)))

    def submit(self, req: Request):
        req.submitted_at = time.perf_counter()
        self.queue.append(req)

    def _step_one(self, slot: int, token: int, pos: int):
        tokens = np.zeros((self.B, 1), np.int32)
        tokens[slot, 0] = token
        logits, self.cache = self._decode(self.params, jnp.asarray(tokens),
                                          self.cache, jnp.int32(pos))
        self.dispatches += 1
        return np.asarray(logits[slot, -1])

    def step(self) -> bool:
        for slot in [i for i, r in enumerate(self.slot_req) if r is None]:
            if not self.queue:
                break
            req = self.queue.pop(0)
            logits = None
            for pos, tok in enumerate(req.prompt):
                logits = self._step_one(slot, int(tok), pos)
            self.slot_req[slot] = req
            self.slot_pos[slot] = len(req.prompt)
            req._last_logits = logits
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        self.iterations += 1
        by_pos: Dict[int, List[int]] = {}
        for i in active:
            by_pos.setdefault(int(self.slot_pos[i]), []).append(i)
        vocab = self.lm.cfg.vocab_size
        for pos, slots in sorted(by_pos.items()):
            tokens = np.zeros((self.B, 1), np.int32)
            for i in slots:
                req = self.slot_req[i]
                tokens[i, 0] = sample_token(
                    np.asarray(req._last_logits[:vocab]), req.sampling,
                    len(req.out_tokens))
            logits, self.cache = self._decode(
                self.params, jnp.asarray(tokens), self.cache, jnp.int32(pos))
            self.dispatches += 1
            logits = np.asarray(logits[:, -1])
            now = time.perf_counter()
            for i in slots:
                req = self.slot_req[i]
                req.out_tokens.append(int(tokens[i, 0]))
                if req.first_token_at is None:
                    req.first_token_at = now
                    self.ttft.append(now - req.submitted_at)
                req._last_logits = logits[i]
                self.slot_pos[i] += 1
                if (len(req.out_tokens) >= req.max_new_tokens
                        or self.slot_pos[i] >= self.S):
                    self.finished.append(req)
                    self.slot_req[i] = None
        return True

    def run_until_drained(self, max_iters: int = 10_000):
        for _ in range(max_iters):
            if not self.step() and not self.queue:
                break
        return self.finished


def _workload(cfg, n_requests: int, new_tokens: int) -> List[Request]:
    rng = np.random.default_rng(3)
    reqs = []
    for i in range(n_requests):
        prompt = rng.integers(0, cfg.vocab_size,
                              int(rng.integers(3, 18))).astype(np.int32)
        reqs.append(Request(i, prompt, max_new_tokens=new_tokens))
    return reqs


def _drain_measured(eng, cfg, n_requests: int, new_tokens: int):
    """Warm up (pays jit compilation of the decode step and every prefill
    bucket), then time a fresh identical workload on the same engine so the
    reported numbers are steady-state serving cost."""
    for r in _workload(cfg, n_requests, new_tokens):
        eng.submit(r)
    eng.run_until_drained()
    n_warm = len(eng.finished)
    for r in _workload(cfg, n_requests, new_tokens):
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run_until_drained()
    wall = time.perf_counter() - t0
    done = eng.finished[n_warm:]
    assert len(done) == n_requests
    toks = sum(len(r.out_tokens) for r in done)
    ttft = float(np.median([r.first_token_at - r.submitted_at
                            for r in done]))
    return wall, toks, ttft


def _admission_at_budget(lm, cfg):
    """Concurrent short requests admitted under one fixed HBM budget.

    The budget is what a 4-slot dense cache pins at max_seq=64.  Everything
    is sized from that number: the dense engine gets 4 slots; the paged
    engines get ``budget / page_bytes`` physical pages (same HBM) and a
    generous slot count so *memory*, not slots, is the binding constraint.
    The workload is N identical short prompts (a shared system prompt) —
    the serving pattern the paper's train<->inference flips make common.

    The budget governs *pinned* cache bytes.  The XLA gather decode still
    materializes a dense-equivalent gathered KV view per step as a
    transient, which grows with the enlarged concurrent batch (see
    ``attention.gather_pages``); ``_decode_transient_sweep`` measures that
    transient against the page-table-walking flash kernel that removes it
    (``decode_impl="pallas"``).

    Admission is counted through backend ``alloc`` bookkeeping directly —
    the same host-side path ``ServeEngine._admit`` reserves through (whose
    end-to-end behaviour tests/test_kvcache.py covers), with zero device
    dispatches, so this comparison adds no jit compiles to ``make smoke``.
    """
    dense_slots, max_seq, page = 4, 64, 8
    budget = contiguous_kv_bytes(cfg, dense_slots, max_seq, jnp.float32)
    n_pages = budget // page_kv_bytes(cfg, page, jnp.float32)
    n_req, plen, new_tokens = 40, 12, 4
    prompt = (np.arange(plen) % cfg.vocab_size).astype(np.int32)
    footprint = min(plen + new_tokens, max_seq)

    def admitted(slots, backend, **kw):
        kv = make_cache(lm, slots, max_seq, dtype=jnp.float32,
                        backend=backend, **kw)
        n = 0
        while n < slots and kv.alloc(n, footprint, prefix=prompt) is not None:
            n += 1
        return n, kv.memory_stats()

    n_dense, dense_stats = admitted(dense_slots, "contiguous")
    n_paged, paged_stats = admitted(n_req, "paged", page_size=page,
                                    num_pages=n_pages)
    n_noshare, noshare_stats = admitted(n_req, "paged", page_size=page,
                                        num_pages=n_pages,
                                        prefix_sharing=False)
    assert dense_stats.bytes_total == budget
    assert paged_stats.bytes_total <= budget
    return [
        ("serving/concurrent_at_budget_dense", 0.0,
         f"{n_dense} admitted ({budget/1e3:.0f} kB budget)"),
        ("serving/concurrent_at_budget_paged", 0.0,
         f"{n_paged} admitted (x{n_paged/max(n_dense,1):.1f} vs dense; "
         f"{paged_stats.pages_in_use}/{paged_stats.pages_total} pages, "
         f"{paged_stats.pages_shared} shared)"),
        ("serving/concurrent_at_budget_paged_nosharing", 0.0,
         f"{n_noshare} admitted (x{n_noshare/max(n_dense,1):.1f} vs dense)"),
    ]


def _decode_transient_sweep(lm, cfg, params):
    """Gather-vs-kernel paged decode at several (batch, pages-per-slot)
    points: per-step transient bytes of the KV read path plus fused decode
    step latency.  Numbers land in ``benchmarks/out/decode_transient.json``.

    Transient accounting is split by what each path actually allocates:

    * **gather** — the dense-equivalent (B, M*page, KV, D) views are XLA
      temporaries, so we report the *measured* ``temp_size_in_bytes`` of the
      compiled single-layer attention op (and assert it grows with B·M).
    * **pallas** — the kernel's transient is its VMEM working set (one K and
      one V page block + fp32 online-softmax state), which XLA's temp
      accounting never sees; we report the analytic
      ``decode_transient_bytes`` (and assert it is independent of B and M).
      The measured temp of the *interpret-mode* simulation (a lax.scan over
      grid points — a CPU correctness vehicle, not a memory model) is
      recorded in the JSON for transparency.
    """
    from repro.models import attention as attn

    page = 8
    points = [(4, 4), (8, 4), (8, 8), (16, 8)]
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    rng = np.random.default_rng(11)
    records = []
    for b, m in points:
        pool_pages = b * m + 1
        q = jnp.asarray(
            rng.normal(size=(b, 1, kvh, cfg.num_heads // kvh, hd)),
            jnp.float32)
        kp = jnp.asarray(rng.normal(size=(pool_pages, page, kvh, hd)),
                         jnp.float32)
        vp = jnp.asarray(rng.normal(size=(pool_pages, page, kvh, hd)),
                         jnp.float32)
        pt = jnp.asarray(rng.integers(1, pool_pages, (b, m)), jnp.int32)
        pos = jnp.asarray(rng.integers(0, m * page, (b,)), jnp.int32)
        # paged decode_step inputs for the latency measurement
        kv = lm.init_cache(b, m * page, dtype=jnp.float32, backend="paged",
                           page_size=page, num_pages=pool_pages)
        for s in range(b):
            kv.alloc(s, min(int(pos[s]) + 2, m * page))
        view = kv.decode_view()
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, 1)), jnp.int32)
        for impl in ("gather", "pallas"):
            op = jax.jit(functools.partial(attn.decode_attention, impl=impl))
            measured = op.lower(q, kp, vp, pos, pt).compile() \
                .memory_analysis().temp_size_in_bytes
            analytic = decode_transient_bytes(cfg, b, m, page, jnp.float32,
                                              impl)
            step = jax.jit(functools.partial(lm.decode_step,
                                             decode_impl=impl))
            _, c0 = step(params, toks, view, pos)            # compile+warm
            jax.block_until_ready(c0)
            t0 = time.perf_counter()
            reps = 5
            for _ in range(reps):
                _, c = step(params, toks, view, pos)
                jax.block_until_ready(c)
            step_us = (time.perf_counter() - t0) / reps * 1e6
            from repro.kernels.ops import _interpret
            records.append({
                "batch": b, "pages_per_slot": m, "page_size": page,
                "impl": impl,
                # interpret=True means the pallas latency is the CPU
                # interpreter simulating the grid, not a Mosaic kernel —
                # only the transient-bytes contrast carries to TPU
                "interpret": bool(impl == "pallas" and _interpret()),
                "attn_temp_bytes_measured": int(measured),
                "transient_bytes": int(analytic if impl == "pallas"
                                       else measured),
                "transient_bytes_analytic": int(analytic),
                "decode_step_us": round(step_us, 1),
            })

    by = {(r["batch"], r["pages_per_slot"], r["impl"]): r for r in records}
    # gather's transient grows with the paged-enlarged batch width B*M ...
    g_small = by[(4, 4, "gather")]["transient_bytes"]
    g_big = by[(16, 8, "gather")]["transient_bytes"]
    assert g_big >= 4 * g_small, (g_small, g_big)
    # ... while the kernel's is O(block): identical at every point and far
    # below the gather transient at the widest one
    k_vals = {by[(b, m, "pallas")]["transient_bytes"] for b, m in points}
    assert len(k_vals) == 1, k_vals
    assert by[(16, 8, "pallas")]["transient_bytes"] * 8 \
        < by[(16, 8, "gather")]["transient_bytes"]
    OUT_JSON.parent.mkdir(parents=True, exist_ok=True)
    OUT_JSON.write_text(json.dumps(records, indent=1))

    rows = []
    for b, m in points:
        g, k = by[(b, m, "gather")], by[(b, m, "pallas")]
        rows.append((
            f"serving/decode_transient_b{b}_m{m}", g["decode_step_us"],
            f"gather={g['transient_bytes']}B kernel={k['transient_bytes']}B "
            f"(x{g['transient_bytes'] / k['transient_bytes']:.0f}); "
            f"kernel_step={k['decode_step_us']:.0f}us"))
    return rows


def run_decode():
    """The gather-vs-kernel transient sweep alone (``make bench-decode``)."""
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    return _decode_transient_sweep(lm, cfg, lm.init(jax.random.key(0)))


def run_sharded():
    """Sharded paged serving sweep (``make bench-sharded``, 8 fake host
    devices): the same ragged workload served over 1/2/4/8-chip inference
    meshes with the kv_pages-partitioned pool.

    Reported per mesh width n: **pinned KV bytes per chip** — both the
    analytic P/n page split from ``memory_stats`` and the *measured* max
    per-device bytes of the live pool arrays (they must agree: the pool
    shards down with the mesh instead of replicating) — plus steady-state
    fused decode-step latency vs the 1-chip baseline and the end-to-end
    token-stream parity assert.  JSON lands in
    ``benchmarks/out/sharded_serving.json``.

    Since the unified write/attend primitive, two more columns per width:
    the **prefill write transient** — compiled ``temp_size_in_bytes`` of the
    shard_map ``staged_write_prefill`` vs the retained GSPMD baseline
    (``gspmd_write_prefill``) on a (group=4, block=64) staged K/V block,
    asserted O(group x block) (P-independent), never an O(P) replicated
    pool — and **chunked stream parity**: the same workload re-served with
    ``prefill_chunk=8`` through the sharded chunk scatter + C-row merge
    must emit identical streams.  A dated summary row also appends to
    ``BENCH_serving.json``.

    On CPU the shard_map runs over fake host devices, so the latency column
    is a dispatch-overhead trend (n interpreter shards + the psum merge),
    not an ICI model; the per-chip byte accounting is exact everywhere."""
    n_dev = len(jax.devices())
    widths = [n for n in (1, 2, 4, 8) if n <= n_dev]
    if widths != [1, 2, 4, 8]:
        print(f"# bench-sharded: only {n_dev} devices visible; sweeping "
              f"{widths} (run with XLA_FLAGS="
              "--xla_force_host_platform_device_count=8 for the full sweep)")
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    max_batch, max_seq, page, pool = 8, 64, 8, 64   # 64 pages: all n divide
    n_requests, new_tokens = 12, 8

    from repro.parallel.mesh import make_mesh

    records, rows, base = [], [], None
    base_streams = None
    for n in widths:
        mesh = make_mesh((n,), ("model",)) if n > 1 else None
        eng = ServeEngine(lm, params, max_batch, max_seq,
                          cache_backend="paged", page_size=page,
                          num_pages=pool, mesh=mesh)
        wall, toks, ttft = _drain_measured(eng, cfg, n_requests, new_tokens)
        streams = sorted((r.id, tuple(r.out_tokens)) for r in eng.finished)
        if base_streams is None:
            base_streams = streams
        else:
            assert streams == base_streams, \
                f"sharded stream divergence at n={n}"
        # chunked prefill through the unified primitive: same streams
        ceng = ServeEngine(lm, params, max_batch, max_seq,
                           cache_backend="paged", page_size=page,
                           num_pages=pool, mesh=mesh, prefill_chunk=8)
        _drain_measured(ceng, cfg, n_requests, new_tokens)
        cstreams = sorted((r.id, tuple(r.out_tokens))
                          for r in ceng.finished)
        assert cstreams == base_streams, \
            f"chunked sharded stream divergence at n={n}"
        # prefill write transient: the shard_map local scatter stages only
        # the O(group x block) K/V block per chip, pool-size-independent
        wgroup, wblock = 4, 64
        staged_t = gspmd_t = None
        if mesh is not None:
            layers = eng.kv.state["layers"]
            kv_block = {k: jax.ShapeDtypeStruct(
                (cfg.num_layers, wgroup, wblock) + v.shape[3:],
                jnp.float32) for k, v in layers.items()}
            dest = jax.ShapeDtypeStruct((wgroup, wblock), jnp.int32)

            def _temp(fn):
                c = jax.jit(fn).lower(layers, kv_block, dest).compile()
                return int(c.memory_analysis().temp_size_in_bytes)

            staged_t = _temp(eng.kv.staged_write_prefill)
            gspmd_t = _temp(eng.kv.gspmd_write_prefill)
            analytic = prefill_transient_bytes(cfg, wgroup, wblock,
                                               jnp.float32)
            assert staged_t <= analytic, (staged_t, analytic)
            assert staged_t < eng.kv.memory_stats().bytes_total
        st = eng.kv.memory_stats()
        assert st.mesh_chips == (n if mesh is not None else 1)
        assert st.bytes_per_chip == st.bytes_total // st.mesh_chips
        # measured per-device bytes of the live pool (post-decode, so the
        # steady-state sharding — not a prefill transient — is what's on
        # each chip)
        per_dev: Dict = {}
        for arr in jax.tree.leaves(eng.kv.state["layers"]):
            for s in arr.addressable_shards:
                key = repr(s.device)
                per_dev[key] = per_dev.get(key, 0) + s.data.nbytes
        measured_per_chip = max(per_dev.values())
        assert measured_per_chip == st.bytes_per_chip, (
            measured_per_chip, st.bytes_per_chip)
        # steady-state fused step latency: all slots mid-decode
        view = eng.kv.decode_view()
        args = (jnp.asarray(np.zeros((max_batch, 1), np.int32)),
                view["layers"], view.get("page_table"),
                jnp.asarray(np.full(max_batch, 9, np.int32)),
                jnp.asarray(np.ones(max_batch, bool)),
                jnp.asarray(np.zeros(max_batch, np.float32)),
                jnp.asarray(np.zeros(max_batch, np.int32)),
                jnp.asarray(np.ones(max_batch, np.float32)),
                jnp.asarray(np.zeros(max_batch, np.int32)),
                jnp.asarray(np.ones(max_batch, np.int32)),
                jnp.asarray(np.zeros(max_batch, bool)), True)
        tok, layers = eng._fused(params, *args)      # warm (donates view)
        jax.block_until_ready(layers)
        reps, t0 = 10, time.perf_counter()
        for _ in range(reps):
            tok, layers = eng._fused(params, args[0], layers, *args[2:])
            jax.block_until_ready(layers)
        step_us = (time.perf_counter() - t0) / reps * 1e6
        if base is None:
            base = step_us
        records.append({
            "mesh": n, "pool_pages": st.pages_total + 1, "page_size": page,
            "pinned_bytes_total": st.bytes_total,
            "pinned_bytes_per_chip": st.bytes_per_chip,
            "pinned_bytes_per_chip_measured": int(measured_per_chip),
            "fused_step_us": round(step_us, 1),
            "tok_s": round(toks / wall, 1),
            "ttft_p50_ms": round(ttft * 1e3, 2),
            "stream_parity": True,
            "chunked_stream_parity": True,
            "prefill_write_transient_bytes": staged_t,
            "prefill_write_transient_bytes_gspmd": gspmd_t,
        })
        rows.append((
            f"serving/sharded_step_n{n}", step_us,
            f"{st.bytes_per_chip/1e3:.0f}kB/chip pinned "
            f"(P/{st.mesh_chips}={((st.pages_total + 1) // st.mesh_chips)} "
            f"pages), x{step_us/base:.2f} vs 1-chip, parity ok"))
    # pool bytes must scale down P/n with the mesh
    per_chip = {r["mesh"]: r["pinned_bytes_per_chip"] for r in records}
    for n in widths[1:]:
        assert per_chip[n] * n == per_chip[widths[0]] * widths[0], per_chip
    # the write transient must NOT scale with the pool (it is the staged
    # block, identical at every width that shards the same pool)
    transients = [r["prefill_write_transient_bytes"] for r in records
                  if r["prefill_write_transient_bytes"] is not None]
    assert len(set(transients)) <= 1, transients
    SHARDED_JSON.parent.mkdir(parents=True, exist_ok=True)
    SHARDED_JSON.write_text(json.dumps(records, indent=1))
    if transients:
        widest = records[-1]
        _append_trajectory({
            "date": time.strftime("%Y-%m-%d"),
            "bench": "sharded",
            "mesh_widths": widths,
            "pinned_bytes_per_chip_at_widest": widest[
                "pinned_bytes_per_chip"],
            "prefill_write_transient_bytes": transients[0],
            "prefill_write_transient_bytes_gspmd": widest[
                "prefill_write_transient_bytes_gspmd"],
            "pool_bytes_total": widest["pinned_bytes_total"],
            "stream_parity": True, "chunked_stream_parity": True,
        })
    return rows


def run_chunked():
    """Long-prompt-vs-streams workload (``make bench-chunked``): short
    requests decode steadily; a long prompt is admitted mid-flight.  With
    whole-prompt prefill the admission stalls every stream for the prompt's
    full forward; with chunked prefill (``prefill_chunk``) the prompt lands
    one chunk per iteration interleaved with the fused decode steps, so no
    stream's inter-token gap ever covers more than one chunk of prefill
    compute.

    Measured per mode: **max inter-token gap** across the streams (wall
    time between consecutive emitted tokens, excluding TTFT), the long
    request's TTFT, steady-state fused-step wall time, and the stall
    telemetry (``serve_decode_stall_iters`` — zero by construction when
    chunking).  Token streams must match bitwise between the two modes.
    JSON lands in ``benchmarks/out/chunked_prefill.json``."""
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    max_batch, max_seq, page, chunk = 4, 512, 8, 16
    long_len, stream_new = 480, 44
    rng = np.random.default_rng(23)
    stream_prompts = [rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
                      for _ in range(3)]
    long_prompt = rng.integers(0, cfg.vocab_size, long_len).astype(np.int32)

    def run_one(chunked: bool):
        kw = dict(prefill_chunk=chunk) if chunked else {}
        eng = ServeEngine(lm, params, max_batch, max_seq,
                          cache_backend="paged", page_size=page, **kw)

        def drive(offset):
            """Submit streams, let them reach steady decode, admit the long
            prompt, run to drain.  Returns (per-stream max/median inter-token
            gap, long-request TTFT, offset-normalized token streams)."""
            for i, p in enumerate(stream_prompts):
                eng.submit(Request(offset + i, p.copy(),
                                   max_new_tokens=stream_new))
            for _ in range(3):
                eng.step()
            eng.submit(Request(offset + 9, long_prompt.copy(),
                               max_new_tokens=8))
            # baseline the in-flight streams NOW so the very next
            # iteration — the one that admits the long prompt — shows up
            # as a gap (this is exactly the stall being measured)
            stamps: Dict[int, List[float]] = {}
            counts: Dict[int, int] = {}
            t_base = time.perf_counter()
            for r in eng.slot_req:
                if r is not None:
                    counts[r.id] = len(r.out_tokens)
                    stamps[r.id] = [t_base]
            n_done = len(eng.finished)      # prior repeats: skip their tail
            while eng.step() or eng.queue:
                now = time.perf_counter()
                for r in eng.finished[n_done:] + [r for r in eng.slot_req
                                                  if r is not None]:
                    n = len(r.out_tokens)
                    if n > counts.get(r.id, 0):
                        stamps.setdefault(r.id, []).extend(
                            [now] * (n - counts.get(r.id, 0)))
                        counts[r.id] = n
            gaps = [b - a for rid, ts in stamps.items()
                    if offset <= rid < offset + 9
                    for a, b in zip(ts, ts[1:])]
            done = {r.id - offset: r for r in eng.finished
                    if r.id >= offset}
            return (max(gaps), float(np.median(gaps)),
                    done[9].first_token_at - done[9].submitted_at,
                    sorted((i, tuple(r.out_tokens))
                           for i, r in done.items()))

        drive(0)                                    # warm: pays every jit
        stall0 = eng.reg.counter("serve_decode_stall_iters").get()
        chunk0 = eng.reg.counter("serve_prefill_chunks_total").get()
        # three measured repeats; the reported worst gap is the MIN over
        # repeats of the per-repeat max — scheduler noise inflates a max,
        # it never deflates one below the true stall cost, so min-of-max
        # is the noise-robust estimate of the structural worst gap
        t0 = time.perf_counter()
        reps = [drive(100 * (r + 1)) for r in range(3)]
        wall = time.perf_counter() - t0
        stalls = eng.reg.counter("serve_decode_stall_iters").get() - stall0
        streams = reps[0][3]
        assert all(r[3] == streams for r in reps), "repeat divergence"
        return {
            "mode": "chunked" if chunked else "whole_prompt",
            "prefill_chunk": chunk if chunked else 0,
            "max_stream_gap_ms": round(min(r[0] for r in reps) * 1e3, 3),
            "max_stream_gap_ms_per_rep": [round(r[0] * 1e3, 3)
                                          for r in reps],
            "median_stream_gap_ms": round(
                float(np.median([r[1] for r in reps])) * 1e3, 3),
            "ttft_long_ms": round(
                float(np.median([r[2] for r in reps])) * 1e3, 2),
            "decode_stall_iters": int(stalls),
            "prefill_chunks": int(eng.reg.counter(
                "serve_prefill_chunks_total").get() - chunk0),
            "repeats": len(reps),
            "wall_s": round(wall, 3),
        }, streams

    whole, whole_streams = run_one(False)
    chunked, chunked_streams = run_one(True)
    # bitwise token-stream parity between the two prefill modes, and the
    # structural stall contrast: chunking bounds every decode iteration's
    # prefill work at one budget, the whole-prompt engine provably stalled
    assert chunked_streams == whole_streams, "chunked/whole stream divergence"
    assert chunked["decode_stall_iters"] == 0, chunked
    assert whole["decode_stall_iters"] > 0, whole
    # the worst stream gap must shrink: whole-prompt pays the full 480-token
    # prefill inside one gap, chunked pays at most one 16-token chunk
    assert chunked["max_stream_gap_ms"] < whole["max_stream_gap_ms"], (
        chunked["max_stream_gap_ms"], whole["max_stream_gap_ms"])
    records = [whole, chunked]
    CHUNKED_JSON.parent.mkdir(parents=True, exist_ok=True)
    CHUNKED_JSON.write_text(json.dumps(records, indent=1))
    return [
        ("serving/chunked_max_stream_gap", chunked["max_stream_gap_ms"] * 1e3,
         f"{chunked['max_stream_gap_ms']:.1f}ms max inter-token gap "
         f"(median {chunked['median_stream_gap_ms']:.1f}ms), "
         f"{chunked['prefill_chunks']} chunks, 0 stall iters, parity ok"),
        ("serving/whole_max_stream_gap", whole["max_stream_gap_ms"] * 1e3,
         f"{whole['max_stream_gap_ms']:.1f}ms max inter-token gap "
         f"(x{whole['max_stream_gap_ms']/chunked['max_stream_gap_ms']:.1f} "
         f"vs chunked; {whole['decode_stall_iters']} stall iters)"),
        ("serving/chunked_ttft_long", chunked["ttft_long_ms"] * 1e3,
         f"long-prompt TTFT {chunked['ttft_long_ms']:.0f}ms chunked vs "
         f"{whole['ttft_long_ms']:.0f}ms whole"),
    ]


def _quant_admission(lm, cfg, baseline_dtype, dense_slots: int = 8):
    """Concurrent short streams admitted at one fixed HBM budget:
    ``baseline_dtype`` pages vs int8 pages with per-row fp32 scales.

    Same host-side ``alloc`` bookkeeping as ``_admission_at_budget`` (zero
    device dispatches); prefix sharing is off so the ratio measures the
    page *format* alone, not sharing.  Returns
    (n_baseline, n_int8, pool stats for each)."""
    max_seq, page = 64, 8
    budget = contiguous_kv_bytes(cfg, dense_slots, max_seq, baseline_dtype)
    n_req, plen, new_tokens = 64, 12, 4
    prompt = (np.arange(plen) % cfg.vocab_size).astype(np.int32)
    footprint = min(plen + new_tokens, max_seq)

    def admitted(kv_dtype, dtype):
        n_pages = budget // page_kv_bytes(cfg, page, dtype,
                                          kv_dtype=kv_dtype)
        kv = make_cache(lm, n_req, max_seq, dtype=dtype, backend="paged",
                        page_size=page, num_pages=n_pages,
                        prefix_sharing=False, kv_dtype=kv_dtype)
        n = 0
        while n < n_req and kv.alloc(n, footprint, prefix=prompt) is not None:
            n += 1
        st = kv.memory_stats()
        assert st.bytes_total <= budget, (st.bytes_total, budget)
        return n, st

    n_base, base_stats = admitted("native", baseline_dtype)
    n_int8, int8_stats = admitted("int8", baseline_dtype)
    return n_base, n_int8, base_stats, int8_stats


def _quant_logit_trace(lm, cfg, params, impl: str, kv_dtype: str,
                       prompts: np.ndarray, steps: int, page: int,
                       max_seq: int):
    """Greedy decode with the decoded logits visible: whole-prompt prefill
    through the cache's real staged write (quantize-on-write for int8),
    then ``steps`` fused decode steps (dequant-on-read), collecting the
    full-vocab logits of every decoded position.  Returns (tokens (B, steps)
    int64, logits (B, steps, V) fp32) — the fp32 ``kv_dtype="native"`` run
    of the same workload is the oracle the int8 runs are scored against."""
    b, plen = prompts.shape
    vocab = cfg.vocab_size
    kv = make_cache(lm, b, max_seq, dtype=jnp.float32, backend="paged",
                    page_size=page, decode_impl=impl, kv_dtype=kv_dtype)
    for s in range(b):
        assert kv.alloc(s, plen + steps) is not None
    logits, _, pcache = lm.forward(params, {"tokens": jnp.asarray(prompts)},
                                   collect_cache=True)
    dest = np.stack([kv.prefill_dest(s, plen, plen) for s in range(b)])
    kv.update({**kv.state, "layers": kv.staged_write_prefill(
        kv.state["layers"], pcache["layers"], jnp.asarray(dest, jnp.int32))})
    step = jax.jit(functools.partial(lm.decode_step, decode_impl=impl))
    tok = np.asarray(jnp.argmax(logits[:, plen - 1, :vocab], axis=-1))
    pos = np.full(b, plen, np.int32)
    out_toks, out_logits = [], []
    for _ in range(steps):
        lg, new_cache = step(params, jnp.asarray(tok[:, None], jnp.int32),
                             kv.decode_view(), jnp.asarray(pos))
        kv.update(new_cache)
        rows = np.asarray(lg[:, -1, :vocab], np.float32)
        out_toks.append(tok)
        out_logits.append(rows)
        tok = rows.argmax(axis=-1)
        pos += 1
    for s in range(b):
        kv.free(s)
    return np.stack(out_toks, 1), np.stack(out_logits, 1)


def run_quant():
    """Int8 KV page benchmark (``make bench-quant``): concurrent streams at
    a fixed HBM budget, end-to-end quality gate, and the decode transient.

    * **Admission** — the same short-prompt workload admitted into an fp32
      page pool vs an int8 pool holding the *same pinned bytes* (and the
      bf16-vs-int8 contrast at head_dim=64, the deployment-shaped geometry
      — at head_dim 32 the per-row fp32 scale overhead caps bf16→int8 at
      1.78x).  Asserts >= 1.8x concurrent streams in both contrasts.
    * **Quality** — the ragged serving workload on int8 engines (gather and
      pallas decode) must emit bitwise-identical greedy streams to the fp32
      engine, and a logit-visible greedy trace scores every decoded logit
      against the fp32 oracle: max |error| must stay under the documented
      ``QUANT_LOGIT_TOL`` bound.  The full error distribution lands in the
      JSON.
    * **Trajectory** — appends one entry (tok/s, streams-at-budget, decode
      transient bytes, admission ratios, logit error) to the committed
      ``BENCH_serving.json`` so the headline numbers are diffable in review.
    """
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    max_batch, max_seq, page = 8, 64, 8
    n_requests, new_tokens = 12, 8

    # --- admission at fixed budget: fp32 vs int8 at the bench geometry,
    # bf16 vs int8 at head_dim=64 (pure host-side bookkeeping) ---
    n_f32, n_i8, f32_st, i8_st = _quant_admission(lm, cfg, jnp.float32)
    cfg64 = dataclasses.replace(cfg, head_dim=64)
    n_b16, n_i8_64, b16_st, i8_64_st = _quant_admission(
        LM(cfg64), cfg64, jnp.bfloat16)
    ratio_f32 = n_i8 / max(n_f32, 1)
    ratio_b16 = n_i8_64 / max(n_b16, 1)
    assert ratio_f32 >= 1.8, (n_f32, n_i8)
    assert ratio_b16 >= 1.8, (n_b16, n_i8_64)

    # --- end-to-end stream parity + tok/s: fp32 engine vs int8 engines ---
    engines = {}
    for name, kw in (("native", {}),
                     ("int8_gather", dict(kv_dtype="int8")),
                     ("int8_pallas", dict(kv_dtype="int8",
                                          decode_impl="pallas"))):
        eng = ServeEngine(lm, params, max_batch, max_seq,
                          cache_backend="paged", page_size=page, **kw)
        wall, toks, _ = _drain_measured(eng, cfg, n_requests, new_tokens)
        streams = sorted((r.id, tuple(r.out_tokens)) for r in eng.finished)
        engines[name] = dict(tok_s=toks / wall, streams=streams,
                             stats=eng.kv.memory_stats())
    for name in ("int8_gather", "int8_pallas"):
        assert engines[name]["streams"] == engines["native"]["streams"], \
            f"int8 stream divergence ({name})"

    # --- logit-visible greedy trace vs the fp32 oracle ---
    rng = np.random.default_rng(7)
    prompts = rng.integers(0, cfg.vocab_size, (4, 9)).astype(np.int32)
    steps = 8
    oracle_toks, oracle_logits = _quant_logit_trace(
        lm, cfg, params, "gather", "native", prompts, steps, page, max_seq)
    errs = {}
    for impl in ("gather", "pallas"):
        toks, logits = _quant_logit_trace(
            lm, cfg, params, impl, "int8", prompts, steps, page, max_seq)
        assert np.array_equal(toks, oracle_toks), f"greedy divergence {impl}"
        e = np.abs(logits - oracle_logits)
        errs[impl] = {
            "max": float(e.max()),
            "p50": float(np.median(e)),
            "p99": float(np.quantile(e, 0.99)),
            "mean": float(e.mean()),
        }
        assert e.max() <= QUANT_LOGIT_TOL, (impl, float(e.max()))

    # --- decode transient bytes under the int8 format ---
    transient = {
        impl: decode_transient_bytes(cfg, max_batch, max_seq // page, page,
                                     jnp.float32, impl, kv_dtype="int8")
        for impl in ("gather", "pallas")}

    records = {
        "admission": {
            "budget_dtype_fp32": {
                "baseline": n_f32, "int8": n_i8,
                "ratio": round(ratio_f32, 3),
                "baseline_pages": f32_st.pages_total,
                "int8_pages": i8_st.pages_total,
                "int8_scale_bytes": i8_st.bytes_scales,
            },
            "budget_dtype_bf16_hd64": {
                "baseline": n_b16, "int8": n_i8_64,
                "ratio": round(ratio_b16, 3),
                "baseline_pages": b16_st.pages_total,
                "int8_pages": i8_64_st.pages_total,
                "int8_scale_bytes": i8_64_st.bytes_scales,
            },
        },
        "tok_s": {k: round(v["tok_s"], 1) for k, v in engines.items()},
        "logit_err": errs, "logit_tol": QUANT_LOGIT_TOL,
        "decode_transient_bytes_int8": transient,
        "stream_parity": True, "greedy_trace_parity": True,
    }
    QUANT_JSON.parent.mkdir(parents=True, exist_ok=True)
    QUANT_JSON.write_text(json.dumps(records, indent=1))
    _append_trajectory({
        "date": time.strftime("%Y-%m-%d"),
        "bench": "quant",
        "tok_s_int8_gather": round(engines["int8_gather"]["tok_s"], 1),
        "tok_s_int8_pallas": round(engines["int8_pallas"]["tok_s"], 1),
        "tok_s_fp32": round(engines["native"]["tok_s"], 1),
        "concurrent_at_budget_fp32": n_f32,
        "concurrent_at_budget_int8": n_i8,
        "quant_admission_ratio_fp32": round(ratio_f32, 3),
        "quant_admission_ratio_bf16_hd64": round(ratio_b16, 3),
        "decode_transient_bytes_int8_pallas": transient["pallas"],
        "max_logit_err": max(e["max"] for e in errs.values()),
        "stream_parity": True,
    })
    return [
        ("serving/quant_admission_fp32", 0.0,
         f"{n_i8} int8 vs {n_f32} fp32 streams at the same budget "
         f"(x{ratio_f32:.2f}; {i8_st.pages_total} vs {f32_st.pages_total} "
         f"pages)"),
        ("serving/quant_admission_bf16_hd64", 0.0,
         f"{n_i8_64} int8 vs {n_b16} bf16 streams (x{ratio_b16:.2f} at "
         f"head_dim=64)"),
        ("serving/quant_tok_s", engines["int8_gather"]["tok_s"],
         f"int8 gather={engines['int8_gather']['tok_s']:.1f} "
         f"pallas={engines['int8_pallas']['tok_s']:.1f} vs "
         f"fp32={engines['native']['tok_s']:.1f} tok/s, streams bitwise ok"),
        ("serving/quant_logit_err", max(e["max"] for e in errs.values()),
         f"max |logit err| gather={errs['gather']['max']:.2e} "
         f"pallas={errs['pallas']['max']:.2e} (tol {QUANT_LOGIT_TOL}), "
         f"greedy trace identical"),
    ]


def run():
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    max_batch, max_seq, new_tokens, n_requests = 8, 64, 8, 12

    fused = ServeEngine(lm, params, max_batch, max_seq)   # paged default
    fused_wall, fused_toks, fused_ttft = _drain_measured(
        fused, cfg, n_requests, new_tokens)
    # counters cover warmup+measured identically for both engines, so the
    # dispatch ratio is unaffected by including the warmup pass
    fused_iters = fused.reg.counter("serve_iterations_total").get()
    fused_decode = fused.reg.counter("serve_decode_dispatches_total").get()
    fused_prefill = fused.reg.counter("serve_prefill_dispatches_total").get()
    pf_batch = fused.reg.histogram("serve_prefill_batch_size")

    contig = ServeEngine(lm, params, max_batch, max_seq,
                         cache_backend="contiguous")
    contig_wall, contig_toks, _ = _drain_measured(
        contig, cfg, n_requests, new_tokens)

    # paged and contiguous backends must emit identical greedy streams —
    # warmup and measured passes reuse request ids, so compare the full
    # multiset of (id, stream) pairs, not a last-write-wins dict
    fused_out = sorted((r.id, tuple(r.out_tokens)) for r in fused.finished)
    contig_out = sorted((r.id, tuple(r.out_tokens)) for r in contig.finished)
    assert fused_out == contig_out, "paged/contiguous token divergence"

    ref = GroupedReferenceEngine(lm, params, max_batch, max_seq)
    ref_wall, ref_toks, ref_ttft = _drain_measured(
        ref, cfg, n_requests, new_tokens)

    assert fused_toks == ref_toks, (fused_toks, ref_toks)
    reduction = ref.dispatches / max(fused_decode + fused_prefill, 1)
    return [
        ("serving/fused_us_per_tok", fused_wall / max(fused_toks, 1) * 1e6,
         f"tok_s={fused_toks / fused_wall:.1f} (paged kv)"),
        ("serving/fused_ttft_p50", fused_ttft * 1e6,
         f"decode_calls_per_iter="
         f"{fused_decode / max(fused_iters, 1):.2f}"),
        ("serving/contiguous_us_per_tok",
         contig_wall / max(contig_toks, 1) * 1e6,
         f"tok_s={contig_toks / contig_wall:.1f} (dense kv, parity ok)"),
        ("serving/grouped_us_per_tok", ref_wall / max(ref_toks, 1) * 1e6,
         f"tok_s={ref_toks / ref_wall:.1f}"),
        ("serving/grouped_ttft_p50", ref_ttft * 1e6,
         f"decode_calls_per_iter="
         f"{ref.dispatches / max(ref.iterations, 1):.2f}"),
        ("serving/dispatch_reduction", 0.0,
         f"{reduction:.1f}x ({ref.dispatches} grouped vs "
         f"{fused_decode + fused_prefill:.0f} fused device calls; "
         f"prefill batch p50={pf_batch.quantile(0.5):.0f})"),
    ] + _admission_at_budget(lm, cfg) \
      + _decode_transient_sweep(lm, cfg, params)


def run_tenant():
    """Multi-tenant SLO soak (``make bench-tenant``): a bursty two-class
    adversarial trace — eight large ``batch``-class requests that want every
    slot and page, with short ``interactive`` chat requests trickling in
    mid-flight — driven through three engines that differ only in tenancy:

    * **sched** — priority classes + per-tenant page quota + preemption:
      the bulk tenant is quota-capped, chat admissions preempt the
      lowest-priority active decode when slots/pages run out, and the
      per-class chunked-prefill budget keeps bulk (re)prefills from
      monopolising iterations.
    * **fifo**  — the same engine geometry and trace with tenancy disabled:
      chat requests queue behind the bulk backlog in submission order.
    * **solo**  — chat trace alone at the same iteration marks: the
      no-contention TTFT baseline.

    Asserted SLO contrast (acceptance criteria of the scheduler PR):
    interactive p99 TTFT under mixed load stays within **2x** of solo while
    the fifo engine degrades **>= 5x**; zero per-tenant quota violations
    polled after every engine iteration; preemptions and quota denials both
    actually fire; and every stream not preempted in the sched run is
    bitwise identical to its fifo twin (greedy decode — preemption resume
    must not perturb untouched streams).  JSON lands in
    ``benchmarks/out/tenant_slo.json`` plus one trajectory entry in the
    committed ``BENCH_serving.json``."""
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    max_batch, max_seq, page, chunk = 4, 96, 8, 16
    bulk_new, chat_new = 24, 4
    # 3 concurrent bulk (6 pages each): one slot's worth BELOW the slot
    # limit, so the 4th queued bulk is denied by the page quota while a
    # slot is still free — exercising the quota-deny path (and leaving the
    # slot open for interactive traffic), while chat overlaps beyond one
    # concurrent request still force preemption of an active bulk decode
    bulk_quota = 18
    rng = np.random.default_rng(41)
    bulk_prompts = [rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
                    for _ in range(8)]
    chat_prompts = [rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
                    for _ in range(6)]
    # chat arrival marks (engine iteration index): the first burst waits out
    # the initial bulk prefill wave so preemption — not prefill contention —
    # is what the scheduler must solve; then one chat every 3 iterations
    chat_marks = {10 + 3 * k: k for k in range(6)}

    def tenancy():
        # batch-class prefill budget of one chunk/iteration: bulk resumes
        # after preemption never starve the interactive class of the global
        # chunk budget (2 chunks/iteration at budget 32)
        classes = {"interactive": PriorityClass("interactive", 100,
                                                preemptible=False),
                   "batch": PriorityClass("batch", 0, preemptible=True,
                                          prefill_budget=chunk)}
        return TenancyConfig(
            tenants=[TenantSpec("chat", "interactive"),
                     TenantSpec("bulk", "batch", page_quota=bulk_quota)],
            classes=classes)

    def make_engine(mode):
        return ServeEngine(
            lm, params, max_batch, max_seq, cache_backend="paged",
            page_size=page, prefill_chunk=chunk, prefill_budget=2 * chunk,
            tenancy=tenancy() if mode == "sched" else None)

    def drive(eng, offset, bulk=True, chat=True):
        """One full trace pass.  Returns (interactive TTFTs, quota
        violations polled per iteration, offset-normalized streams,
        per-request preemption counts)."""
        expected = 8 * bulk + len(chat_prompts) * chat
        n_done = len(eng.finished)
        if bulk:
            for j, p in enumerate(bulk_prompts):
                eng.submit(Request(offset + j, p.copy(),
                                   max_new_tokens=bulk_new, tenant="bulk"))
        it, violations = 0, 0
        while len(eng.finished) - n_done < expected:
            eng.step()
            it += 1
            assert it < 3000, "soak did not drain"
            if chat and it in chat_marks:
                k = chat_marks[it]
                eng.submit(Request(offset + 100 + k, chat_prompts[k].copy(),
                                   max_new_tokens=chat_new, tenant="chat"))
            tp = eng.kv.memory_stats().tenant_pages
            if tp.get("bulk", 0) > bulk_quota:
                violations += 1
        done = [r for r in eng.finished[n_done:]]
        ttfts = [r.first_token_at - r.submitted_at
                 for r in done if r.tenant == "chat"]
        streams = sorted((r.id - offset, tuple(r.out_tokens)) for r in done)
        preempted = {r.id - offset for r in done if r.preemptions > 0}
        return ttfts, violations, streams, preempted

    def run_mode(mode):
        eng = make_engine(mode)
        bulk = mode != "solo"
        drive(eng, 0, bulk=bulk)                 # warm: pays every jit trace
        reps = [drive(eng, 1000 * (r + 1), bulk=bulk) for r in range(3)]
        streams = reps[0][2]
        assert all(r[2] == streams for r in reps), "repeat divergence"
        # min over repeats of the per-repeat p99 (= worst chat TTFT):
        # scheduler noise only ever inflates a max, so min-of-p99 is the
        # noise-robust structural estimate (same idiom as run_chunked)
        p99s = [float(np.quantile(r[0], 0.99)) for r in reps]
        rec = {
            "mode": mode,
            "ttft_interactive_p99_ms": round(min(p99s) * 1e3, 3),
            "ttft_interactive_p99_ms_per_rep": [round(p * 1e3, 3)
                                                for p in p99s],
            "ttft_interactive_p50_ms": round(float(np.median(
                [t for r in reps for t in r[0]])) * 1e3, 3),
            "quota_violations": sum(r[1] for r in reps),
            "repeats": len(reps),
        }
        if mode == "sched":
            rec["preemptions"] = int(
                eng.reg.counter("serve_preemptions_total").get())
            rec["quota_denied"] = int(
                eng.reg.counter("serve_quota_denied_total").get())
            rec["deferred_pool"] = int(eng.reg.counter(
                "serve_admission_deferred_total").get(
                    {"reason": "pool_exhausted"}))
        return rec, streams, set().union(*(r[3] for r in reps))

    sched, sched_streams, sched_preempted = run_mode("sched")
    fifo, fifo_streams, fifo_preempted = run_mode("fifo")
    solo, _, _ = run_mode("solo")

    # --- SLO contrast (the acceptance criteria, asserted) ---
    solo_p99 = solo["ttft_interactive_p99_ms"]
    sched_ratio = sched["ttft_interactive_p99_ms"] / solo_p99
    fifo_ratio = fifo["ttft_interactive_p99_ms"] / solo_p99
    assert sched_ratio <= 2.0, (sched, solo)
    assert fifo_ratio >= 5.0, (fifo, solo)
    # the adversarial trace must actually exercise the mechanisms
    assert sched["preemptions"] > 0, sched
    assert sched["quota_denied"] > 0, sched
    assert sched["quota_violations"] == 0, sched
    assert fifo_preempted == set(), fifo
    # bitwise parity for every stream the scheduler did NOT preempt
    sched_ok = {i: s for i, s in sched_streams if i not in sched_preempted}
    fifo_by_id = dict(fifo_streams)
    assert sched_ok and all(fifo_by_id[i] == s for i, s in sched_ok.items()), \
        "non-preempted stream divergence"

    records = {"sched": sched, "fifo": fifo, "solo": solo,
               "sched_vs_solo_ttft_ratio": round(sched_ratio, 3),
               "fifo_vs_solo_ttft_ratio": round(fifo_ratio, 3),
               "preempted_requests": sorted(sched_preempted),
               "nonpreempted_stream_parity": True,
               "geometry": {"max_batch": max_batch, "max_seq": max_seq,
                            "page_size": page, "prefill_chunk": chunk,
                            "bulk_quota_pages": bulk_quota}}
    TENANT_JSON.parent.mkdir(parents=True, exist_ok=True)
    TENANT_JSON.write_text(json.dumps(records, indent=1))
    _append_trajectory({
        "date": time.strftime("%Y-%m-%d"),
        "bench": "tenant",
        "ttft_interactive_p99_ms_sched": sched["ttft_interactive_p99_ms"],
        "ttft_interactive_p99_ms_fifo": fifo["ttft_interactive_p99_ms"],
        "ttft_interactive_p99_ms_solo": solo_p99,
        "sched_vs_solo_ttft_ratio": round(sched_ratio, 3),
        "fifo_vs_solo_ttft_ratio": round(fifo_ratio, 3),
        "preemptions": sched["preemptions"],
        "quota_denied": sched["quota_denied"],
        "quota_violations": 0,
        "stream_parity": True,
    })
    return [
        ("serving/tenant_ttft_p99_sched",
         sched["ttft_interactive_p99_ms"] * 1e3,
         f"interactive p99 TTFT {sched['ttft_interactive_p99_ms']:.1f}ms "
         f"under mixed load (x{sched_ratio:.2f} vs solo "
         f"{solo_p99:.1f}ms; {sched['preemptions']} preemptions, "
         f"{sched['quota_denied']} quota denies, 0 violations)"),
        ("serving/tenant_ttft_p99_fifo",
         fifo["ttft_interactive_p99_ms"] * 1e3,
         f"same trace without scheduler: {fifo['ttft_interactive_p99_ms']:.0f}"
         f"ms (x{fifo_ratio:.1f} vs solo — the SLO gap tenancy closes)"),
        ("serving/tenant_ttft_p99_solo", solo_p99 * 1e3,
         f"no-contention baseline {solo_p99:.1f}ms; non-preempted streams "
         f"bitwise identical sched vs fifo"),
    ]

def run_offload():
    """Host-offload page tier + persistent prefix store benchmark
    (``make bench-offload``), in two phases:

    * **Prefix-hit TTFT vs recompute** — a 480-token shared prefix served
      through chunked prefill (15 chunks of 32).  Cold: a fresh prefix
      recomputes every chunk.  Warm: a *second engine* sharing the same
      :class:`PrefixStore` (persistence across engine lifetimes is the
      point) hash-hits the prefix at admission, prefetches all 30 pages
      from host RAM, and skips every fully-landed chunk's forward — only
      the final (sampling) chunk dispatches.  Asserts warm TTFT >= 3x
      faster than cold and that the warm stream is bitwise the cold one.
    * **Sustained concurrency at 10x working set** — 20 distinct 3-page
      prefixes (60 warm pages) revisited through a random schedule
      against a 6-usable-page HBM pool with a 64-page host tier: the
      engine must drain with zero OOMs (every admission banker-safe,
      ``serve_kv_pages_in_use`` bounded by the pool at every step) and
      emit byte-identical streams vs a no-offload contiguous oracle.

    JSON lands in ``benchmarks/out/host_offload.json`` plus one entry in
    the committed ``BENCH_serving.json``."""
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))

    # ---- phase 1: prefix-hit TTFT vs recompute ----
    max_batch, max_seq, page, chunk = 4, 512, 16, 32
    plen, tail_len, new_tokens = 480, 4, 6
    rng = np.random.default_rng(17)
    store = PrefixStore(128)     # 30 pages/prefix: warmup + 3 cold + slack

    def engine():
        return ServeEngine(lm, params, max_batch, max_seq,
                           cache_backend="paged", page_size=page,
                           prefill_chunk=chunk, prefix_store=store)

    def prompt(prefix):
        return np.concatenate(
            [prefix, rng.integers(0, cfg.vocab_size,
                                  tail_len).astype(np.int32)])

    def serve_one(eng, rid, p):
        eng.submit(Request(rid, p.copy(), max_new_tokens=new_tokens))
        n_done = len(eng.finished)
        while len(eng.finished) == n_done:
            eng.step()
        eng.kv.drain_offloads()       # prefix lands in the store NOW
        r = eng.finished[-1]
        return r.first_token_at - r.submitted_at, tuple(r.out_tokens)

    prefixes = [rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
                for _ in range(4)]
    cold_eng = engine()
    serve_one(cold_eng, 0, prompt(prefixes[0]))      # warm: pays every jit
    cold, cold_streams = [], []
    measured = [prompt(pre) for pre in prefixes[1:]]
    for k, p in enumerate(measured):                 # fresh prefixes: recompute
        t, s = serve_one(cold_eng, 1 + k, p)
        cold.append(t)
        cold_streams.append(s)
    chunks_cold = cold_eng.reg.counter("serve_prefill_chunks_total").get()
    assert chunks_cold >= 4 * (plen // chunk), chunks_cold

    warm_eng = engine()                   # NEW engine, same persistent store
    serve_one(warm_eng, 0, prompt(prefixes[0]))      # warm jit via store hit
    warm = []
    for k, p in enumerate(measured):                 # same prompts: hash hits
        t, s = serve_one(warm_eng, 1 + k, p)
        warm.append(t)
        assert s == cold_streams[k], "warm stream diverged from recompute"
    ttft_cold = float(np.median(cold))
    ttft_warm = float(np.median(warm))
    speedup = ttft_cold / ttft_warm
    assert speedup >= 3.0, (ttft_cold, ttft_warm)
    skipped = warm_eng.reg.counter("serve_prefill_chunks_skipped_total").get()
    assert skipped >= 4 * (plen // chunk - 1), skipped
    wstats = warm_eng.kv.store.stats()
    assert wstats["hits"] >= 4 * (plen // page), wstats
    page_mb = store.tier.page_bytes / 2**20

    # ---- phase 2: sustained concurrency at a 10x-pool working set ----
    n_prefix, per_prefix, soak_page, soak_pages, host = 20, 2, 4, 7, 64
    srng = np.random.default_rng(53)
    soak_prefixes = [srng.integers(0, cfg.vocab_size, 12).astype(np.int32)
                     for _ in range(n_prefix)]
    reqs = []
    for i in range(n_prefix * per_prefix):
        t = srng.integers(0, cfg.vocab_size,
                          int(srng.integers(1, 3))).astype(np.int32)
        reqs.append((i, np.concatenate([soak_prefixes[i % n_prefix], t]),
                     int(srng.integers(2, 5))))
    arrivals: Dict[int, list] = {}
    for j in srng.permutation(len(reqs)):
        arrivals.setdefault(int(srng.integers(0, 120)), []).append(reqs[j])

    def soak(**kw):
        eng = ServeEngine(lm, params, max_batch=4, max_seq=32, **kw)
        paged = kw.get("cache_backend") == "paged"
        gauge = eng.reg.gauge("serve_kv_pages_in_use")
        pool = eng.kv.memory_stats().pages_total if paged else 0
        peak, t0 = 0, time.perf_counter()
        step = 0
        while (step < 400 or eng.queue
               or any(r is not None for r in eng.slot_req)):
            for i, p, n in arrivals.get(step, []):
                eng.submit(Request(i, p.copy(), max_new_tokens=n))
            eng.step()
            step += 1
            assert step < 3000, "offload soak did not drain"
            if paged:
                g = gauge.get()
                assert 0 <= g <= pool, "page gauge exceeded the HBM pool"
                peak = max(peak, g)
        wall = time.perf_counter() - t0
        toks = sum(len(r.out_tokens) for r in eng.finished)
        return ({r.id: tuple(r.out_tokens) for r in eng.finished},
                eng, peak, toks / wall)

    out, seng, peak, tok_s = soak(cache_backend="paged", page_size=soak_page,
                                  num_pages=soak_pages, host_pages=host)
    ref, _, _, _ = soak(cache_backend="contiguous")
    assert out == ref and len(out) == len(reqs), \
        "10x-working-set streams diverged from the no-offload oracle"
    st = seng.kv.memory_stats()
    sstats = seng.kv.store.stats()
    working_set = n_prefix * 3          # 12-token prefixes, 4-token pages
    ws_ratio = working_set / st.pages_total
    assert ws_ratio >= 10.0, ws_ratio
    assert sstats["hits"] > 0 and sstats["offloads"] > 0
    assert st.pages_in_use == 0        # drained to zero, zero OOMs

    records = {
        "prefix_hit_ttft": {
            "prefix_tokens": plen, "page_size": page,
            "prefill_chunk": chunk, "repeats": len(measured),
            "ttft_cold_ms": round(ttft_cold * 1e3, 2),
            "ttft_warm_ms": round(ttft_warm * 1e3, 2),
            "ttft_cold_ms_per_rep": [round(t * 1e3, 2) for t in cold],
            "ttft_warm_ms_per_rep": [round(t * 1e3, 2) for t in warm],
            "speedup": round(speedup, 2),
            "chunks_skipped": int(skipped),
            "store_hits": int(wstats["hits"]),
            "page_bytes": store.tier.page_bytes,
            "prefetch_mb": round(wstats["prefetch_bytes"] / 2**20, 3),
            "stream_parity": True,
        },
        "working_set_10x": {
            "requests": len(reqs), "distinct_prefixes": n_prefix,
            "pool_pages": st.pages_total, "host_pages": host,
            "working_set_pages": working_set,
            "working_set_ratio": round(ws_ratio, 2),
            "peak_pages_in_use": int(peak),
            "host_pages_resident": st.host_pages_in_use,
            "tok_s": round(tok_s, 1),
            "store": {k: int(v) for k, v in sstats.items()},
            "oom_events": 0, "stream_parity": True,
        },
    }
    OFFLOAD_JSON.parent.mkdir(parents=True, exist_ok=True)
    OFFLOAD_JSON.write_text(json.dumps(records, indent=1))
    _append_trajectory({
        "date": time.strftime("%Y-%m-%d"),
        "bench": "host_offload",
        "ttft_cold_ms": round(ttft_cold * 1e3, 2),
        "ttft_warm_ms": round(ttft_warm * 1e3, 2),
        "prefix_hit_ttft_speedup": round(speedup, 2),
        "prefill_chunks_skipped": int(skipped),
        "working_set_ratio": round(ws_ratio, 2),
        "working_set_tok_s": round(tok_s, 1),
        "host_prefetch_mb": round(
            (wstats["prefetch_bytes"] + sstats["prefetch_bytes"]) / 2**20, 3),
        "oom_events": 0,
        "stream_parity": True,
    })
    return [
        ("serving/offload_ttft_warm", ttft_warm * 1e6,
         f"prefix-hit TTFT {ttft_warm * 1e3:.0f}ms vs "
         f"{ttft_cold * 1e3:.0f}ms recompute (x{speedup:.1f}; "
         f"{int(skipped)} chunk forwards skipped, "
         f"{wstats['prefetch_bytes'] / 2**20:.1f}MB prefetched at "
         f"{page_mb * 1024:.0f}kB/page)"),
        ("serving/offload_working_set_10x", 0.0,
         f"{len(reqs)} requests over {working_set} warm pages vs "
         f"{st.pages_total}-page pool (x{ws_ratio:.1f} working set): "
         f"0 OOMs, peak {int(peak)} pages, {tok_s:.1f} tok/s, "
         f"streams bitwise identical to no-offload oracle"),
        ("serving/offload_store_traffic", 0.0,
         f"store: {int(sstats['offloads'])} offloads / "
         f"{int(sstats['hits'])} hits / {int(sstats['evictions'])} LRU "
         f"evictions in soak; {st.host_pages_in_use}/{host} host pages "
         f"resident at drain"),
    ]


def run_faults():
    """Fault-injection recovery soak (``make bench-faults``): the same mixed
    greedy/seeded chunked-prefill workload driven through a clean engine and
    through one with a deterministic :class:`FaultPlan` firing every
    transient seam — a chunked-prefill stall, non-finite logits, a poisoned
    KV page, and a transient dispatch error — plus a separate engine pair
    where a whole KV chip fails mid-flight (capacity P -> P*(n-1)/n).

    Built-in acceptance asserts (the recovery contract, not a perf taste
    test):

    * every stream of the faulted run — recovered victims included — is
      **bitwise identical** to the fault-free run (recompute-on-resume
      re-draws the discarded sample at the same stream step, so greedy and
      seeded sampling both resume exactly);
    * after the chip failure, victims actually recover
      (``serve_stream_retries_total{reason="chip_failure"} > 0``), every
      completed stream matches its clean twin bitwise, and the usable pool
      shrinks to the surviving chips' pages;
    * nothing dead-letters, every fault kind fires, and
      ``serve_recovery_iters`` records the fault-to-resumption latency.

    Reported numbers: goodput (tokens/iteration) dip under faults and the
    recovery latency distribution in engine iterations.  JSON lands in
    ``benchmarks/out/fault_recovery.json`` plus one trajectory entry in the
    committed ``BENCH_serving.json``."""
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    max_batch, max_seq, page, chunk, n_req, max_new = 4, 64, 4, 8, 8, 8
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, 6 + (i % 5)).astype(np.int32)
               for i in range(n_req)]

    def submit(eng, offset):
        for i, p in enumerate(prompts):
            eng.submit(Request(
                offset + i, p.copy(), max_new_tokens=max_new,
                sampling=SamplingParams(
                    temperature=0.0 if i % 2 == 0 else 0.8, seed=i)))

    def drive(eng, offset):
        """One trace pass: streams (offset-normalized), iterations taken,
        tokens emitted, wall seconds."""
        n_done, it0 = len(eng.finished), eng._iter
        submit(eng, offset)
        t0 = time.perf_counter()
        it = 0
        while len(eng.finished) - n_done < n_req:
            eng.step()
            it += 1
            assert it < 3000, "soak did not drain"
        wall = time.perf_counter() - t0
        done = eng.finished[n_done:]
        assert all(r.status == "completed" for r in done), \
            [(r.id, r.status, r.error) for r in done]
        streams = sorted((r.id - offset, tuple(r.out_tokens)) for r in done)
        toks = sum(len(r.out_tokens) for r in done)
        return streams, eng._iter - it0, toks, wall

    def transient_plan(at):
        """Every transient seam, anchored at absolute iteration ``at``:
        the stall lands while prefill chunks are in flight, the rest while
        decodes are live (unfirable events carry, so exact phase does not
        matter for correctness — only for which seam each one exercises)."""
        return FaultPlan([
            FaultEvent(at + 1, "stall_chunk", duration=2),
            FaultEvent(at + 3, "nan_logits"),
            FaultEvent(at + 5, "poison_page"),
            FaultEvent(at + 7, "dispatch_error", duration=2),
        ])

    def engine(**kw):
        return ServeEngine(lm, params, max_batch, max_seq,
                           cache_backend="paged", page_size=page,
                           prefill_chunk=chunk, **kw)

    # --- scenario A: transient faults, bitwise parity + goodput dip ---
    base = engine(num_pages=33)
    drive(base, 0)                                   # warm: pays jit traces
    b_streams, b_iters, b_toks, b_wall = drive(base, 100)

    eng = engine(num_pages=33, watchdog_iters=12, max_retries=4,
                 verify_cache=True)
    drive(eng, 0)                                    # warm, fault-free
    eng.fault_plan = transient_plan(eng._iter)       # arm for measured pass
    f_streams, f_iters, f_toks, f_wall = drive(eng, 100)
    assert f_streams == b_streams, "faulted run diverged bitwise"
    eng.kv.verify()
    injected = {dict(ls)["kind"]: v for ls, v in eng.reg.counter(
        "serve_faults_injected_total").labels_values() if ls}
    retries = {dict(ls)["reason"]: v for ls, v in eng.reg.counter(
        "serve_stream_retries_total").labels_values() if ls}
    assert set(injected) == {"stall_chunk", "nan_logits", "poison_page",
                             "dispatch_error"}, injected
    recov = eng.reg.histogram("serve_recovery_iters").recent(100)
    assert recov and sum(retries.values()) >= 3, (recov, retries)
    assert eng.reg.counter("serve_dead_letter_total").get() == 0

    base_goodput = b_toks / b_iters
    fault_goodput = f_toks / f_iters
    dip_pct = 100.0 * (1 - fault_goodput / base_goodput)

    # --- scenario B: chip failure drains a per-chip free list ---
    cbase = engine(num_pages=24, locality_chips=2)
    drive(cbase, 0)
    cb_streams, cb_iters, _, _ = drive(cbase, 100)

    ceng = engine(num_pages=24, locality_chips=2, watchdog_iters=16,
                  verify_cache=True)
    drive(ceng, 0)
    usable_before = ceng.kv.usable_pages()
    ceng.fault_plan = FaultPlan(
        [FaultEvent(ceng._iter + 3, "chip_failure", chip=1)])
    n_done = len(ceng.finished)
    submit(ceng, 100)
    it0 = ceng._iter
    it = 0
    while len(ceng.finished) - n_done < n_req:
        ceng.step()
        it += 1
        assert it < 3000, "chip-failure soak did not drain"
    cdone = ceng.finished[n_done:]
    chip_retries = ceng.reg.counter("serve_stream_retries_total").get(
        {"reason": "chip_failure"})
    assert chip_retries >= 1, "chip failure drained no victims"
    cb_by_id = dict(cb_streams)
    completed = [r for r in cdone if r.status == "completed"]
    assert completed, [(r.id, r.status) for r in cdone]
    for r in completed:
        assert tuple(r.out_tokens) == cb_by_id[r.id - 100], r.id
    usable_after = ceng.kv.usable_pages()
    assert usable_after == ceng.kv.pages_per_chip - 1, \
        (usable_after, ceng.kv.pages_per_chip)
    ceng.kv.verify()

    records = {
        "workload": {"requests": n_req, "max_new_tokens": max_new,
                     "max_batch": max_batch, "page_size": page,
                     "prefill_chunk": chunk,
                     "sampling": "alternating greedy / seeded top-p"},
        "baseline": {"iterations": b_iters,
                     "goodput_tok_per_iter": round(base_goodput, 3),
                     "wall_ms": round(b_wall * 1e3, 2)},
        "faulted": {"iterations": f_iters,
                    "goodput_tok_per_iter": round(fault_goodput, 3),
                    "wall_ms": round(f_wall * 1e3, 2),
                    "injected": {k: int(v) for k, v in injected.items()},
                    "retries": {k: int(v) for k, v in retries.items()}},
        "goodput_dip_pct": round(dip_pct, 2),
        "recovery_iters": {"count": len(recov),
                           "mean": round(float(np.mean(recov)), 2),
                           "max": int(max(recov))},
        "stream_parity_bitwise": True,
        "chip_failure": {
            "chips": ceng.kv.chips, "usable_pages_before": usable_before,
            "usable_pages_after": usable_after,
            "victim_recoveries": int(chip_retries),
            "iterations": ceng._iter - it0,
            "baseline_iterations": cb_iters,
            "completed": len(completed),
            "dead_lettered": len(cdone) - len(completed),
            "completed_stream_parity_bitwise": True},
    }
    FAULTS_JSON.parent.mkdir(parents=True, exist_ok=True)
    FAULTS_JSON.write_text(json.dumps(records, indent=1))
    _append_trajectory({
        "date": time.strftime("%Y-%m-%d"),
        "bench": "fault_recovery",
        "goodput_dip_pct": round(dip_pct, 2),
        "recovery_iters_mean": records["recovery_iters"]["mean"],
        "faults_injected": sum(int(v) for v in injected.values()),
        "stream_retries": sum(int(v) for v in retries.values()),
        "chip_victim_recoveries": int(chip_retries),
        "dead_letters": 0,
        "stream_parity": True,
    })
    return [
        ("serving/fault_goodput_dip", f_wall * 1e6,
         f"goodput {fault_goodput:.2f} tok/iter under "
         f"{sum(int(v) for v in injected.values())} injected faults vs "
         f"{base_goodput:.2f} clean ({dip_pct:.1f}% dip, "
         f"{b_iters}->{f_iters} iters); all streams bitwise identical"),
        ("serving/fault_recovery_latency",
         float(np.mean(recov)),
         f"fault-to-resumption latency: mean {float(np.mean(recov)):.1f} "
         f"iters, max {int(max(recov))} over {len(recov)} recoveries "
         f"({sum(int(v) for v in retries.values())} retries, 0 dead-letters)"),
        ("serving/fault_chip_drain", float(ceng._iter - it0),
         f"chip failure: pool {usable_before}->{usable_after} usable pages, "
         f"{int(chip_retries)} victim(s) recovered, {len(completed)}/{n_req} "
         f"completed bitwise identical to the 2-chip clean run"),
    ]
