"""Paper Table 1 + §2.3.1 incidents: failure taxonomy handling.

Rows: per-failure-kind mitigation outcomes (detected? job survives? recovery
path), and the two narrated incidents replayed:
  * Granite-20B on 768 GPUs drops to ~3x step time from one power-braked
    node -> detected via autopilot, node swapped from the buffer, throughput
    restored;
  * single NIC port failure -> slowdown, not crash (ECMP), job continues.
"""
import time

from repro.core import (Autopilot, FailureKind, GangScheduler, Job,
                        MetricsRegistry, SimCluster, StragglerDetector)


def run():
    rows = []
    t0 = time.perf_counter()
    reg = MetricsRegistry()
    cluster = SimCluster(106, registry=reg)
    sched = GangScheduler(cluster, buffer_fraction=0.10, registry=reg)
    autopilot = Autopilot(cluster, reg)
    det = StragglerDetector(reg)
    job = Job("granite-20b", 96)
    sched.submit(job)

    # --- incident 1: power brake = ~2.7x job slowdown ------------------------
    for _ in range(20):
        det.observe_step(5.0)
    victim = job.nodes[42]
    cluster.inject(victim, FailureKind.POWER_BRAKE)
    slow = 5.0 / cluster.job_perf_factor(job.nodes)
    for _ in range(4):
        det.observe_step(slow)
    rep = det.check(cluster, job.nodes)
    assert rep.detected and rep.suspect_nodes == [victim]
    ok = sched.replace_degraded(job.id, [victim])
    assert ok and cluster.job_perf_factor(job.nodes) == 1.0
    rows.append(("table1/power_brake_incident", (time.perf_counter()-t0)*1e6,
                 f"slowdown={slow/5.0:.1f}x_detected_swapped_restored"))

    # --- incident 2: port failure slows but does not crash -------------------
    victim2 = job.nodes[7]
    cluster.inject(victim2, FailureKind.PORT_FAILURE)
    pf = cluster.job_perf_factor(job.nodes)
    assert 0 < pf < 1.0
    assert not cluster.crashed_in(job.nodes)
    rows.append(("table1/port_failure_no_crash", 0.0,
                 f"perf_factor={pf:.2f}_job_running"))
    sched.replace_degraded(job.id, [victim2])

    # --- full taxonomy: inject every kind, verify mitigation path ------------
    for kind in FailureKind:
        c2 = SimCluster(24, registry=MetricsRegistry())
        s2 = GangScheduler(c2, 0.15)
        j2 = Job("j", 18)
        s2.submit(j2)
        n = j2.nodes[0]
        c2.inject(n, kind)
        crashed = bool(c2.crashed_in(j2.nodes))
        if crashed:
            s2.on_node_failure(n)
            outcome = f"requeue+restart(restarts={j2.restarts})"
            assert j2.state.value == "running"
        elif c2.nodes[n].perf_factor < 1.0:
            s2.replace_degraded("j", [n])
            outcome = "buffer_swap"
        else:
            outcome = "warn_only(reset_recommended)"
        rows.append((f"table1/{kind.value}", 0.0, outcome))
    return rows
