"""Paper §2.4: the Granite-20B layout (4TP × 4PP × 48DP on 768 GPUs) and its
communication budget per step under the calibrated network model — TP on the
fast fabric, PP point-to-point, DP all-reduce once per step — plus the same
budget with int8 gradient compression (beyond-paper optimization)."""
import time

from repro.configs import get_config
from repro.core import netmodel as nm
from repro.parallel.compression import (wire_bytes_f32_allreduce,
                                        wire_bytes_int8_sync)


def run():
    rows = []
    cfg = get_config("granite-20b-code")
    n_params = cfg.param_count()
    tp, pp, dp = 4, 4, 48

    # DP gradient all-reduce (f32 grads over GDR)
    grad_bytes = 4 * n_params / (tp * pp)     # per DP replica shard
    t_dp = nm.allreduce_time(grad_bytes, dp, nm.GDR)
    rows.append(("s2.4/granite20b/dp_allreduce", t_dp * 1e6,
                 f"{grad_bytes/1e9:.1f}GB_over_{dp}way_GDR"))

    # PP activation hop per microbatch boundary (bf16, seq=4096 slice)
    act_bytes = 2 * cfg.d_model * 4096 * 2    # fwd + bwd
    t_pp = act_bytes / nm.GDR.bus_bw + nm.GDR.alpha
    rows.append(("s2.4/granite20b/pp_hop", t_pp * 1e6,
                 f"{act_bytes/1e6:.0f}MB_p2p"))

    # TP all-reduce stays on NVLink (intra-node; modeled at 10x GDR bw)
    tp_bytes = 2 * cfg.d_model * 4096 * 2 * 2
    t_tp = tp_bytes / (10 * nm.GDR.bus_bw)
    rows.append(("s2.4/granite20b/tp_allreduce_nvlink", t_tp * 1e6,
                 f"{tp_bytes/1e6:.0f}MB_intranode"))

    # beyond-paper: int8 error-feedback DP sync
    f32 = wire_bytes_f32_allreduce(int(n_params / (tp * pp)))
    i8 = wire_bytes_int8_sync(int(n_params / (tp * pp)), dp)
    rows.append(("beyond/int8_grad_sync_wire_reduction", 0.0,
                 f"{f32/i8:.1f}x_fewer_bytes"))
    assert f32 / i8 > 6
    return rows
