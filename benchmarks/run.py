"""Benchmark harness: one module per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (scaffold contract)."""
import sys
import time
import traceback

from benchmarks import (bench_allreduce, bench_checkpoint, bench_failures,
                        bench_overhead, bench_parallel_plan,
                        bench_perf_iterations, bench_serving, bench_storage,
                        bench_throughput)

MODULES = [
    ("fig3_fig4_allreduce", bench_allreduce),
    ("fig7_storage", bench_storage),
    ("s2_3_3_checkpoint", bench_checkpoint),
    ("fig5_6_8_overhead", bench_overhead),
    ("table1_failures", bench_failures),
    ("s2_4_parallel_plan", bench_parallel_plan),
    ("table2_table4_throughput", bench_throughput),
    ("s2_serving", bench_serving),
    ("perf_hillclimb", bench_perf_iterations),
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for label, mod in MODULES:
        t0 = time.perf_counter()
        try:
            rows = mod.run()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{label}/ERROR,0.0,{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
            continue
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived}")
        print(f"{label}/total,{(time.perf_counter()-t0)*1e6:.1f},ok")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
