"""Paper Tables 2 & 4: training throughput.  Wall time cannot be measured on
CPU, so throughput is the roofline bound from the compiled dry-run artifacts
(max of compute/memory/collective terms per step on the v5e production mesh),
reported as tokens/day and TFLOP/s/chip with the paper's A100/H100 reference
MFUs alongside.

Paper reference points:
  * Megatron paper: 135–142 TFLOP/s/GPU on A100 (43–46% MFU) for 8–20B
  * Vela Granite-13B: 140 TFLOP/s/GPU on 256 GPUs (45% MFU)
  * BloombergGPT replica on Vela: 160 TFLOP/s (51%) vs their 101 (32%)
"""
import json
from pathlib import Path

from repro.roofline.analysis import PEAK_FLOPS, from_record

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def _load(mesh: str, arch: str, shape: str, tag: str = ""):
    suffix = f"__{tag}" if tag else ""
    p = DRYRUN / mesh / f"{arch}__{shape}{suffix}.json"
    if not p.exists():
        return None
    rec = json.loads(p.read_text())
    return rec if rec.get("ok") else None


def run():
    rows = []
    paper_points = {  # model -> (paper TFLOP/s/GPU, peak, label)
        "granite-13b": (140.0, 312.0, "vela_a100"),
        "granite-8b": (140.0, 312.0, "vela_a100"),
        "granite-20b-code": (138.0, 312.0, "megatron_ref_a100"),
    }
    for arch in ("granite-8b", "granite-13b", "granite-20b-code"):
        rec = _load("pod16x16", arch, "train_4k")
        if rec is None:
            rows.append((f"table2/{arch}", 0.0, "dryrun_missing"))
            continue
        r = from_record(rec)
        step_s = r.bound_s
        tokens_day = r.tokens_per_step / step_s * 86400
        tflops_chip = (r.model_flops_global / r.chips) / step_s / 1e12
        mfu = tflops_chip * 1e12 / PEAK_FLOPS
        rows.append((f"table2/{arch}/roofline_step", step_s * 1e6,
                     f"{r.dominant}-bound"))
        rows.append((f"table2/{arch}/tokens_per_day", 0.0,
                     f"{tokens_day/1e9:.0f}B"))
        rows.append((f"table2/{arch}/TFLOPs_per_chip", 0.0,
                     f"{tflops_chip:.0f}({mfu*100:.0f}%MFU_v5e)"))
        if arch in paper_points:
            ref, peak, label = paper_points[arch]
            rows.append((f"table2/{arch}/paper_ref", 0.0,
                         f"{ref:.0f}TFLOPs({ref/peak*100:.0f}%MFU_{label})"))

    # Table 4 analogue: assigned-arch throughputs at the roofline bound,
    # baseline (paper-faithful uniform sharding) AND the §Perf-optimized
    # variants reported separately
    optimized_tags = {
        "llama3-405b": "it4_fh_revertmask",
        "arctic-480b": "it3_epmoe_split",
        "zamba2-1.2b": "it1_sepconv",
    }
    for arch, shape in (("llama3-405b", "train_4k"),
                        ("arctic-480b", "train_4k"),
                        ("zamba2-1.2b", "train_4k"),
                        ("moonshot-v1-16b-a3b", "train_4k"),
                        ("qwen3-4b", "train_4k")):
        for tag in ("baseline", optimized_tags.get(arch)):
            if tag is None:
                continue
            rec = _load("pod16x16", arch, shape,
                        tag="" if tag == "baseline" else tag)
            if rec is None:
                continue
            r = from_record(rec)
            tokens_day = r.tokens_per_step / r.bound_s * 86400
            label = "baseline" if tag == "baseline" else "optimized"
            rows.append((f"table4/{arch}/{label}/tokens_per_day",
                         r.bound_s * 1e6,
                         f"{tokens_day/1e9:.1f}B_{r.dominant}-bound_"
                         f"mfu{r.mfu_bound*100:.1f}%"))
    return rows
