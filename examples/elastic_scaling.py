"""Elastic scaling demo: a job checkpointed on one mesh restarts on a smaller
mesh (node shortage after failures) with identical weights, then scales back
up — the checkpoint reshard makes gang-size changes transparent.

    PYTHONPATH=src python examples/elastic_scaling.py
(uses 8 virtual host devices; run standalone, not under the test process)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import dataclasses
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import CONFIGS, TrainConfig
from repro.core import GangScheduler, Job, SimCluster, load_checkpoint, \
    save_checkpoint
from repro.models import LM, ForwardOpts, make_batch
from repro.parallel.mesh import make_mesh
from repro.parallel.sharding import (default_rules, logical_to_sharding,
                                     sharding_context)
from repro.train import (abstract_train_state, init_train_state,
                         make_train_step, train_state_logical_axes)


def run_steps(lm, tcfg, opts, state, mesh_shape, n_steps, cfg, start):
    mesh = make_mesh(mesh_shape, ("data", "model"))
    rules = default_rules(mesh.axis_names)
    sh = logical_to_sharding(train_state_logical_axes(lm),
                             abstract_train_state(lm), mesh, rules)
    step = make_train_step(lm, tcfg, opts)

    def wrapped(s, b):
        with sharding_context(mesh, rules):
            return step(s, b)

    fn = jax.jit(wrapped, in_shardings=(sh, None), out_shardings=(sh, None))
    with mesh:
        state = jax.device_put(state, sh)
        for i in range(start, start + n_steps):
            state, m = fn(state, make_batch(cfg, 8, 64, rng=i))
        print(f"  mesh {mesh_shape}: steps {start}..{start+n_steps-1}, "
              f"loss {float(m['loss']):.4f}")
    return jax.tree.map(np.asarray, state)


def main():
    cfg = dataclasses.replace(CONFIGS["qwen3-4b"].reduced(), dtype="float32")
    lm = LM(cfg)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=4, total_steps=40)
    opts = ForwardOpts(attn_impl="dense", remat="none")
    state = init_train_state(lm, jax.random.key(0), tcfg)
    ckpt = tempfile.mkdtemp()

    # the scheduler decides the resize when capacity drops
    cluster = SimCluster(8, seed=0)
    sched = GangScheduler(cluster, buffer_fraction=0.0)
    job = Job("train", 8)
    sched.submit(job)

    print("phase 1: full mesh (4x2)")
    state = run_steps(lm, tcfg, opts, state, (4, 2), 6, cfg, 0)
    save_checkpoint(ckpt, state, 6)

    print("phase 2: two nodes lost -> elastic downsize to (2x2)")
    from repro.core import FailureKind
    cluster.inject(6, FailureKind.HOST_CRASH)
    cluster.inject(7, FailureKind.HOST_CRASH)
    sched.elastic_resize("train", 4)
    restored, s = load_checkpoint(ckpt, template=state)
    state = run_steps(lm, tcfg, opts, restored, (2, 2), 6, cfg, s)
    save_checkpoint(ckpt, state, 12)

    print("phase 3: nodes repaired -> scale back up to (4x2)")
    restored, s = load_checkpoint(ckpt, template=state)
    state = run_steps(lm, tcfg, opts, restored, (4, 2), 6, cfg, s)
    print("OK: one job, three gang sizes, continuous loss trajectory")


if __name__ == "__main__":
    main()
