"""Fault-tolerant training demo (the paper's §2.3 end to end):

1. REAL run: a CPU training job crashes twice mid-run and resumes from
   Young-interval checkpoints with an identical loss trajectory.
2. SIMULATED fleet: a Granite-20B-class job (96 nodes + 10% buffer) over the
   paper's failure rates — host crashes, power-brake stragglers, PCIe
   degradation — with autopilot detection, Slack-style alerts, node swaps,
   and <10% lost time.

    PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.configs import CONFIGS, TrainConfig
from repro.core import (AlertManager, FTTrainLoop, MetricsRegistry, SlackSink,
                        simulate_job)
from repro.models import LM, ForwardOpts, make_batch
from repro.train import init_train_state, make_train_step


def real_run(tmp="/tmp/repro_ft_demo"):
    print("=== 1. real run with injected crashes ===")
    cfg = dataclasses.replace(CONFIGS["granite-8b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=4, total_steps=30)
    opts = ForwardOpts(attn_impl="dense", remat="none")
    state = init_train_state(lm, jax.random.key(0), tcfg)
    step = jax.jit(make_train_step(lm, tcfg, opts))
    batches = lambda s: make_batch(cfg, 4, 48, rng=s)

    import shutil
    for d in ("clean", "faulty"):
        shutil.rmtree(f"{tmp}/{d}", ignore_errors=True)
    clean = FTTrainLoop(step, state, f"{tmp}/clean", ckpt_every=8)
    clean.run(batches, 30)
    faulty = FTTrainLoop(step, state, f"{tmp}/faulty", ckpt_every=8)
    faulty.run(batches, 30, fail_at=lambda s: s in (11, 21))
    print(f"  crashes survived: {faulty.restarts}")
    a = {m['step']: m['loss'] for m in clean.metrics_log}
    b = {m['step']: m['loss'] for m in faulty.metrics_log}
    drift = max(abs(a[s] - b[s]) for s in a)
    print(f"  max loss drift vs failure-free run: {drift:.2e}")
    assert drift < 1e-4
    print("  OK: trajectory identical after checkpoint restarts\n")


def simulated_fleet():
    print("=== 2. simulated 96-node Granite-class job (46 days scale) ===")
    reg = MetricsRegistry()
    rep = simulate_job(n_cluster_nodes=106, job_nodes=96,
                       total_steps=150_000, base_step_time=5.0,
                       ckpt_write_seconds=90.0, seed=5, registry=reg)
    print(" ", rep.summary())
    print(f"  checkpoint interval (Young): {rep.checkpoint_interval_steps} "
          f"steps")
    print(f"  failures injected: "
          f"{ {k: v for k, v in rep.failures.items() if v} }")
    assert rep.lost_fraction < 0.10
    print("  OK: <10% of wall time lost (paper claim)")


if __name__ == "__main__":
    real_run()
    simulated_fleet()
