"""Observability demo (§2.3.2 / §3.4 / §3.6): a fleet under load with
failures, autopilot checks, Slack-style alerts, AIOps anomaly detection, and
the text 'Grafana' dashboard.

    PYTHONPATH=src python examples/observability_dashboard.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import numpy as np

from repro.core import (AlertManager, AnomalyDetector, Autopilot, FailureKind,
                        GangScheduler, Job, MetricsRegistry, SimCluster,
                        SlackSink, StragglerDetector, TenantScheduler,
                        render_dashboard)


def main():
    reg = MetricsRegistry()
    cluster = SimCluster(24, seed=3, registry=reg)
    sched = GangScheduler(cluster, buffer_fraction=0.10, registry=reg)
    tenants = TenantScheduler(sched, reg)
    tenants.create_namespace("granite-training", 16, priority=1)
    tenants.create_namespace("watsonx-inference", 4)
    tenants.submit("granite-training", Job("granite-20b", 16))
    tenants.submit("watsonx-inference", Job("serving", 3))

    autopilot = Autopilot(cluster, reg)
    alerts = AlertManager(reg, sinks=[SlackSink()])
    detector = StragglerDetector(reg)
    aiops = AnomalyDetector(threshold=4.0, persistence=3)

    rng = np.random.default_rng(0)
    job = sched.jobs["granite-20b"]
    print("running 60 simulated steps with a power-brake incident at t=30…\n")
    for t in range(60):
        if t == 30:
            cluster.inject(job.nodes[5], FailureKind.POWER_BRAKE)
        perf = cluster.job_perf_factor(job.nodes)
        step_s = 5.0 / max(perf, 1e-9) + rng.normal(0, 0.05)
        detector.observe_step(step_s)
        reg.histogram("train_step_seconds").observe(step_s)
        a = aiops.observe("step_seconds", {"job": "granite-20b"}, step_s)
        if a:
            print(f"[AIOps t={t}] {a.message}")
            rep = detector.check(cluster, job.nodes)
            if rep.suspect_nodes and sched.replace_degraded(
                    "granite-20b", rep.suspect_nodes):
                print(f"[mitigation t={t}] swapped nodes "
                      f"{rep.suspect_nodes} from the buffer pool\n")
        if t % 10 == 0:
            autopilot.run_checks(node_ids=job.nodes, busy=job.nodes)
            alerts.evaluate()

    slack = alerts.sinks[0]
    print("slack alerts:")
    for m in slack.messages[:5]:
        print("  ", m)
    print()
    print(render_dashboard(reg, "vela"))


if __name__ == "__main__":
    main()
