"""Batched serving example: ragged continuous batching through the fused
ServeEngine — one decode+sample device call per iteration however mixed the
slot positions are — with prometheus-style metrics (watsonx.ai
inference-cluster role).

    PYTHONPATH=src python examples/serve_batched.py
"""
import dataclasses
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import CONFIGS
from repro.models import LM
from repro.serve import Request, SamplingParams, ServeEngine


def main():
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    eng = ServeEngine(lm, params, max_batch=4, max_seq=96)

    rng = np.random.default_rng(7)
    for i in range(10):
        prompt = rng.integers(0, cfg.vocab_size, int(rng.integers(3, 10)))
        # mix greedy and sampled requests in the same ragged batch — the
        # on-device sampler is vectorized over per-slot params
        sampling = (SamplingParams() if i % 2 == 0 else
                    SamplingParams(temperature=0.8, top_k=16, top_p=0.95,
                                   seed=i))
        eng.submit(Request(i, prompt.astype(np.int32), max_new_tokens=12,
                           sampling=sampling))
    done = eng.run_until_drained()

    iters = eng.reg.counter("serve_iterations_total").get()
    decode = eng.reg.counter("serve_decode_dispatches_total").get()
    print(f"served {len(done)} requests "
          f"({sum(len(r.out_tokens) for r in done)} tokens) "
          f"through {eng.B} continuous-batching slots")
    print(f"fused decode dispatches: {decode:.0f} over {iters:.0f} "
          f"iterations ({decode/max(iters,1):.2f} per iteration — "
          "ragged positions, still one device call)")
    for r in done[:3]:
        print(f"  req {r.id}: prompt {len(r.prompt)} toks -> "
              f"{r.out_tokens[:6]}...")
    print("\nmetrics exposition (prometheus format):")
    for line in eng.reg.render().splitlines():
        if "serve_" in line and not line.startswith("#"):
            print(" ", line)


if __name__ == "__main__":
    main()
