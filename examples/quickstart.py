"""Quickstart: train a small LM end-to-end on CPU through the full stack
(data pipeline -> jit'd train step -> Young-interval checkpoints -> metrics).

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.configs import CONFIGS, TrainConfig
from repro.core import FTTrainLoop, MetricsRegistry
from repro.data import (DeterministicLoader, LoaderConfig, TokenDataset,
                        synthetic_corpus, write_token_shards)
from repro.models import LM, ForwardOpts
from repro.train import init_train_state, make_train_step


def main():
    cfg = dataclasses.replace(CONFIGS["qwen3-4b"].reduced(), num_layers=4,
                              d_model=256, d_ff=512)
    lm = LM(cfg)
    print(f"model: {cfg.name} ({cfg.param_count()/1e6:.1f}M params)")

    data_dir = "/tmp/repro_quickstart_data"
    if not (Path(data_dir) / "index.txt").exists():
        write_token_shards(data_dir, synthetic_corpus(500_000,
                                                      cfg.vocab_size))
    loader = DeterministicLoader(TokenDataset(data_dir),
                                 LoaderConfig(batch_size=8, seq_len=128))

    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=60)
    opts = ForwardOpts(attn_impl="blockwise", q_chunk=128, kv_chunk=128,
                       remat="none")
    state = init_train_state(lm, jax.random.key(0), tcfg)
    step = jax.jit(make_train_step(lm, tcfg, opts))

    reg = MetricsRegistry()
    loop = FTTrainLoop(step, state, "/tmp/repro_quickstart_ckpt",
                       ckpt_every=20, registry=reg)
    t0 = time.perf_counter()
    loop.run(loader.batch_at, 60)
    for m in loop.metrics_log[::10] + loop.metrics_log[-1:]:
        print(f"  step {m['step']:3d}  loss {m['loss']:.4f}")
    print(f"60 steps in {time.perf_counter()-t0:.1f}s, "
          f"{reg.counter('checkpoints_written').get():.0f} checkpoints "
          f"written to /tmp/repro_quickstart_ckpt")
    assert loop.metrics_log[-1]["loss"] < loop.metrics_log[0]["loss"]
    print("OK: loss decreased")


if __name__ == "__main__":
    main()
