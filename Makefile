# Developer entry points.  `make smoke` is the CI gate: the tier-1 test
# suite, an import-check of the benchmark harness (so dependency drift —
# e.g. an unguarded optional import — can't silently break collection
# again), and the serving benchmark on its tiny config (fused-dispatch
# invariant + paged-vs-contiguous KV parity and memory comparison).

PY ?= python

.PHONY: test smoke bench bench-serve bench-decode dev-deps

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

smoke: test bench-serve
	PYTHONPATH=src:. $(PY) -c "import benchmarks.run; print('benchmarks: import ok')"

bench:
	PYTHONPATH=src:. $(PY) benchmarks/run.py

# serving-only slice of the harness: ragged fused decode vs the grouped
# seed engine, plus the paged-memory admission comparison at a fixed HBM
# budget — asserts paged/contiguous token parity as a side effect
bench-serve:
	PYTHONPATH=src:. $(PY) -c "from benchmarks import bench_serving; \
	[print(f'{n},{u:.1f},{d}') for n, u, d in bench_serving.run()]"

# paged-decode microbenchmark: gather-vs-kernel per-step transient bytes and
# fused decode latency at several (batch, pages-per-slot) points; JSON lands
# in benchmarks/out/decode_transient.json (kernel runs interpret-mode on CPU)
bench-decode:
	PYTHONPATH=src:. $(PY) -c "from benchmarks import bench_serving; \
	[print(f'{n},{u:.1f},{d}') for n, u, d in bench_serving.run_decode()]"

dev-deps:
	$(PY) -m pip install -r requirements-dev.txt
