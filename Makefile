# Developer entry points.  `make smoke` is the CI gate: the tier-1 test
# suite plus an import-check of the benchmark harness, so dependency drift
# (e.g. an unguarded optional import) can't silently break collection again.

PY ?= python

.PHONY: test smoke bench dev-deps

test:
	PYTHONPATH=src $(PY) -m pytest -x -q

smoke: test
	PYTHONPATH=src:. $(PY) -c "import benchmarks.run; print('benchmarks: import ok')"

bench:
	PYTHONPATH=src:. $(PY) benchmarks/run.py

dev-deps:
	$(PY) -m pip install -r requirements-dev.txt
