# Developer entry points.  `make smoke` is the CI gate: the tier-1 test
# suite, an import-check of the benchmark harness (so dependency drift —
# e.g. an unguarded optional import — can't silently break collection
# again), and the serving benchmark on its tiny config (fused-dispatch
# invariant + paged-vs-contiguous KV parity and memory comparison).

PY ?= python

.PHONY: test test-fast test-multidevice test-all smoke bench bench-serve \
	bench-decode bench-sharded bench-chunked bench-quant bench-tenant \
	bench-faults bench-offload docs-check dev-deps

# tier-1: the fast single-process suite.  The multi-device subprocess
# files are split into `test-multidevice` (their own CI job) so this —
# and the `smoke` target that depends on it — stays fast; `test-all`
# runs everything (what a bare `pytest -x -q` collects)
test:
	PYTHONPATH=src $(PY) -m pytest -x -q \
		--ignore=tests/test_parallel_multidevice.py \
		--ignore=tests/test_serve_sharded.py

# local fast loop: tier-1 minus the `slow`-marked nightly-style tests
# (the cross-backend conformance matrix and the 10x working-set soak) —
# CI and `make test` still run them
test-fast:
	PYTHONPATH=src $(PY) -m pytest -x -q -m "not slow" \
		--ignore=tests/test_parallel_multidevice.py \
		--ignore=tests/test_serve_sharded.py

# the subprocess-per-test multi-device suites (8 fake host devices each):
# sharded train/pipeline semantics + sharded paged serving parity
test-multidevice:
	PYTHONPATH=src $(PY) -m pytest -x -q tests/test_parallel_multidevice.py \
		tests/test_serve_sharded.py

test-all: test test-multidevice

smoke: test bench-serve
	PYTHONPATH=src:. $(PY) -c "import benchmarks.run; print('benchmarks: import ok')"

bench:
	PYTHONPATH=src:. $(PY) benchmarks/run.py

# serving-only slice of the harness: ragged fused decode vs the grouped
# seed engine, plus the paged-memory admission comparison at a fixed HBM
# budget — asserts paged/contiguous token parity as a side effect
bench-serve:
	PYTHONPATH=src:. $(PY) -c "from benchmarks import bench_serving; \
	[print(f'{n},{u:.1f},{d}') for n, u, d in bench_serving.run()]"

# paged-decode microbenchmark: gather-vs-kernel per-step transient bytes and
# fused decode latency at several (batch, pages-per-slot) points; JSON lands
# in benchmarks/out/decode_transient.json (kernel runs interpret-mode on CPU)
bench-decode:
	PYTHONPATH=src:. $(PY) -c "from benchmarks import bench_serving; \
	[print(f'{n},{u:.1f},{d}') for n, u, d in bench_serving.run_decode()]"

# sharded paged serving sweep on 8 fake host devices: the kv_pages-
# partitioned pool at mesh 1/2/4/8 — per-chip pinned KV bytes (P/n pages,
# analytic == measured), fused-step latency vs the 1-chip baseline,
# token-stream parity asserts (whole-prompt AND chunked through the
# unified write/attend primitive), and the compiled prefill write
# transient (shard_map local scatter vs the GSPMD baseline — asserted
# block-sized, not O(P) replicated); JSON lands in
# benchmarks/out/sharded_serving.json plus a dated BENCH_serving.json row
bench-sharded:
	XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src:. \
	$(PY) -c "from benchmarks import bench_serving; \
	[print(f'{n},{u:.1f},{d}') for n, u, d in bench_serving.run_sharded()]"

# chunked-prefill benchmark: a long prompt admitted into live decode
# streams, chunked vs whole-prompt — max inter-token stream gap (min-of-max
# over repeats), long-request TTFT, decode-stall telemetry, and a bitwise
# stream-parity assert; JSON lands in benchmarks/out/chunked_prefill.json
bench-chunked:
	PYTHONPATH=src:. $(PY) -c "from benchmarks import bench_serving; \
	[print(f'{n},{u:.1f},{d}') for n, u, d in bench_serving.run_chunked()]"

# int8 KV page benchmark: concurrent streams admitted at a fixed HBM
# budget (int8 vs fp32, and bf16-vs-int8 at head_dim=64 — both asserted
# >= 1.8x), bitwise greedy stream parity on both decode impls, and the
# max-logit-error quality gate vs the fp32 oracle; JSON lands in
# benchmarks/out/quant_kv.json and one trajectory entry is appended to
# the committed BENCH_serving.json
bench-quant:
	PYTHONPATH=src:. $(PY) -c "from benchmarks import bench_serving; \
	[print(f'{n},{u:.1f},{d}') for n, u, d in bench_serving.run_quant()]"

# multi-tenant SLO soak: bursty interactive chat over a saturating batch
# backlog, scheduled (priority + quota + preemption) vs fifo vs solo —
# asserts interactive p99 TTFT within 2x of solo under the scheduler while
# fifo degrades >= 5x, zero quota violations, and bitwise parity of every
# non-preempted stream; JSON lands in benchmarks/out/tenant_slo.json and
# one trajectory entry is appended to the committed BENCH_serving.json
bench-tenant:
	PYTHONPATH=src:. $(PY) -c "from benchmarks import bench_serving; \
	[print(f'{n},{u:.1f},{d}') for n, u, d in bench_serving.run_tenant()]"

# fault-injection recovery soak: a deterministic FaultPlan fires every
# transient seam (chunked-prefill stall, non-finite logits, poisoned KV
# page, transient dispatch error) plus a whole-chip KV failure — asserts
# every stream (recovered victims included) is bitwise identical to the
# fault-free run, chip victims actually recover, and nothing dead-letters;
# reports the goodput dip and recovery latency; JSON lands in
# benchmarks/out/fault_recovery.json and one trajectory entry is appended
# to the committed BENCH_serving.json
bench-faults:
	PYTHONPATH=src:. $(PY) -c "from benchmarks import bench_serving; \
	[print(f'{n},{u:.1f},{d}') for n, u, d in bench_serving.run_faults()]"

# host-offload tier benchmark: prefix-hit TTFT vs recompute (a 480-token
# shared prefix prefetched from the persistent PrefixStore and its
# fully-landed chunks skipped — asserted >= 3x faster), plus the
# sustained-concurrency soak at a working set 10x the HBM page pool
# (zero OOMs, bounded page gauge, streams bitwise identical to the
# no-offload oracle); JSON lands in benchmarks/out/host_offload.json and
# one trajectory entry is appended to the committed BENCH_serving.json
bench-offload:
	PYTHONPATH=src:. $(PY) -c "from benchmarks import bench_serving; \
	[print(f'{n},{u:.1f},{d}') for n, u, d in bench_serving.run_offload()]"

# documentation gate: every relative link in tracked *.md files must
# resolve, and docs/telemetry.md must list exactly the metrics the engine
# registers (tests/test_docs.py re-checks the same contract under pytest)
docs-check:
	$(PY) tools/check_docs.py
	PYTHONPATH=src $(PY) -m pytest -q tests/test_docs.py

dev-deps:
	$(PY) -m pip install -r requirements-dev.txt
