#!/usr/bin/env python
"""Documentation link gate (``make docs-check``).

Walks every tracked markdown file and verifies that each relative link
target — ``[text](path)`` and bare reference-style ``[text]: path`` —
resolves to a file or directory in the repo (anchors are stripped; http(s)
and mailto links are skipped: CI must not depend on the network).  Exits
nonzero listing every dangling link, so a doc can't merge pointing at a
file a refactor moved.
"""
from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
REF = re.compile(r"^\[[^\]]+\]:\s+(\S+)", re.M)
SKIP_SCHEMES = ("http://", "https://", "mailto:", "#")


def tracked_markdown():
    out = subprocess.run(["git", "ls-files", "*.md", "**/*.md"],
                         cwd=ROOT, capture_output=True, text=True,
                         check=True).stdout
    return sorted({ROOT / line for line in out.splitlines() if line})


def check_file(md: Path):
    text = md.read_text()
    bad = []
    for target in LINK.findall(text) + REF.findall(text):
        if target.startswith(SKIP_SCHEMES):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        # leading-slash links are repo-root-relative; lstrip so pathlib
        # doesn't discard ROOT on an absolute join
        resolved = (ROOT / path.lstrip("/") if path.startswith("/")
                    else md.parent / path)
        if not resolved.exists():
            bad.append((target, str(resolved)))
    return bad


def main() -> int:
    files = tracked_markdown()
    failures = 0
    for md in files:
        for target, resolved in check_file(md):
            print(f"{md.relative_to(ROOT)}: dangling link "
                  f"'{target}' (-> {resolved})")
            failures += 1
    print(f"docs-check: {len(files)} markdown files, "
          f"{failures} dangling links")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
