"""Docs gate: the documentation suite must exist, its relative links must
resolve, and docs/telemetry.md must list *exactly* the metrics a
constructed engine registers — name and type — so the reference can never
drift from the code.  `make docs-check` runs this file plus the standalone
link checker; the tier-1 suite collects it too."""
import dataclasses
import re
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np

from repro.configs import CONFIGS
from repro.models import LM
from repro.serve import Request, ServeEngine

ROOT = Path(__file__).resolve().parent.parent
DOC = ROOT / "docs" / "telemetry.md"
ROW = re.compile(r"^\|\s*`(serve_\w+)`\s*\|\s*(counter|gauge|histogram)\s*\|",
                 re.M)


def test_docs_suite_exists():
    for rel in ("README.md", "docs/serving.md", "docs/telemetry.md"):
        assert (ROOT / rel).is_file(), f"missing {rel}"


def test_markdown_relative_links_resolve():
    res = subprocess.run(
        [sys.executable, str(ROOT / "tools" / "check_docs.py")],
        capture_output=True, text=True, cwd=ROOT)
    assert res.returncode == 0, res.stdout + res.stderr


def _documented():
    return dict(ROW.findall(DOC.read_text()))


def test_telemetry_doc_matches_engine_registry():
    """Two-way check: every documented serve_* metric is registered with
    the documented type, and every metric the engine registers — at
    construction *and* after serving a chunked workload — is documented.
    The engine declares its surface eagerly, so a metric emitted anywhere
    in the serve path but missing from ``_declare_metrics`` shows up here
    as an undocumented stray."""
    doc = _documented()
    assert doc, "no metric rows parsed from docs/telemetry.md"
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=1)
    lm = LM(cfg)
    eng = ServeEngine(lm, lm.init(jax.random.key(0)), max_batch=2,
                      max_seq=16, cache_backend="paged", page_size=4,
                      prefill_chunk=2)
    registered = {n: m.kind for n, m in eng.reg._metrics.items()
                  if n.startswith("serve_")}
    assert registered == doc, (
        "docs/telemetry.md out of sync with the engine registry:\n"
        f"  undocumented: {sorted(set(registered) - set(doc))}\n"
        f"  stale doc rows: {sorted(set(doc) - set(registered))}\n"
        f"  type mismatches: "
        f"{[n for n in set(doc) & set(registered) if doc[n] != registered[n]]}")
    # drive a chunked workload end-to-end: anything registered lazily on a
    # code path _declare_metrics missed would appear now
    eng.submit(Request(0, np.arange(5, dtype=np.int32) % cfg.vocab_size,
                       max_new_tokens=2))
    eng.run_until_drained()
    after = {n: m.kind for n, m in eng.reg._metrics.items()
             if n.startswith("serve_")}
    assert after == registered, (
        f"metrics registered only at runtime: "
        f"{sorted(set(after) - set(registered))}")
