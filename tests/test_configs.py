"""Config registry: every assigned arch present with the exact published
dims; derived quantities consistent."""
import pytest

from repro.configs import (ASSIGNED_ARCHS, CONFIGS, SHAPES, applicable,
                           get_config, get_shape)

EXPECTED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
    "moonshot-v1-16b-a3b": (48, 2048, 16, 16, 1408, 163840),
    "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
    "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
    "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
    "llama3-405b": (126, 16384, 128, 8, 53248, 128256),
    "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
    "rwkv6-1.6b": (24, 2048, 0, 0, 7168, 65536),
    "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
}

PARAM_BILLIONS = {
    "arctic-480b": (430, 520), "moonshot-v1-16b-a3b": (20, 32),
    "zamba2-1.2b": (1.0, 1.5), "llama3.2-3b": (2.8, 3.6),
    "starcoder2-3b": (2.7, 3.6), "llama3-405b": (390, 420),
    "qwen3-4b": (3.6, 4.4), "rwkv6-1.6b": (1.3, 1.8),
    "seamless-m4t-large-v2": (1.2, 2.4), "internvl2-2b": (1.6, 2.3),
}


def test_all_assigned_archs_present():
    assert set(EXPECTED) == set(ASSIGNED_ARCHS)


@pytest.mark.parametrize("name", sorted(EXPECTED))
def test_exact_dims(name):
    c = get_config(name)
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == EXPECTED[name]


@pytest.mark.parametrize("name", sorted(PARAM_BILLIONS))
def test_param_counts_in_range(name):
    c = get_config(name)
    lo, hi = PARAM_BILLIONS[name]
    n = c.param_count() / 1e9
    assert lo <= n <= hi, f"{name}: {n:.2f}B not in [{lo},{hi}]"


def test_moe_active_params_less_than_total():
    for name in ("arctic-480b", "moonshot-v1-16b-a3b"):
        c = get_config(name)
        assert c.active_param_count() < 0.2 * c.param_count()


def test_param_count_matches_spec_tree():
    """Analytic count == actual initializer tree (exactness contract)."""
    from repro.models import LM
    import numpy as np
    import jax
    for name in ("qwen3-4b", "rwkv6-1.6b", "zamba2-1.2b",
                 "seamless-m4t-large-v2", "moonshot-v1-16b-a3b"):
        c = get_config(name).reduced()
        spec = LM(c).spec()
        actual = sum(int(np.prod(p.shape))
                     for p in jax.tree.leaves(
                         spec, is_leaf=lambda x: hasattr(x, "shape")))
        analytic = c.param_count()
        # vocab padding and lora dims make the analytic formula approximate
        assert abs(actual - analytic) / actual < 0.25, \
            (name, actual, analytic)


def test_padded_vocab_multiple_of_128():
    for c in CONFIGS.values():
        assert c.padded_vocab % 128 == 0
        assert c.padded_vocab >= c.vocab_size


def test_dff_divides_model_axis():
    for c in CONFIGS.values():
        assert c.d_ff % 16 == 0


def test_shape_applicability():
    long = get_shape("long_500k")
    runs = [n for n, c in ASSIGNED_ARCHS.items() if applicable(c, long)]
    assert sorted(runs) == ["rwkv6-1.6b", "zamba2-1.2b"]
    for s in ("train_4k", "prefill_32k", "decode_32k"):
        for c in ASSIGNED_ARCHS.values():
            assert applicable(c, get_shape(s))


def test_shapes_exact():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].seq_len == 32768
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_flops_per_token_orders():
    c = get_config("llama3-405b")
    t = c.flops_per_token(4096, "train")
    assert 2.4e12 < t < 3.5e12          # ~6N + attention
    d = c.flops_per_token(32768, "decode")
    assert d < t
