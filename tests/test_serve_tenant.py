"""Engine-level multi-tenant SLO scheduling: per-tenant page quotas inside
the paged banker, priority-ordered admission, preemptive eviction with
recompute-on-resume, and the per-class telemetry.

The correctness bar: tenancy is a *scheduling* layer, so a tenanted engine
must emit bitwise-identical token streams to the untenanted engine for
every request it does not reorder — and a preempted stream, greedy or
seeded, must resume exactly where it left off (the resume prefill replays
prompt + generated tokens and re-samples the discarded pending token at
the same stream step).

Policy units (``next_victim``, ``TenancyConfig``) live in
tests/test_tenancy.py; the adversarial SLO soak with measured TTFT
contrast is ``make bench-tenant``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.models import LM
from repro.serve import (PriorityClass, Request, SamplingParams, ServeEngine,
                         TenancyConfig, TenantSpec)


def small_lm(name="llama3.2-3b", layers=2):
    cfg = dataclasses.replace(CONFIGS[name].reduced(), dtype="float32",
                              num_layers=layers)
    lm = LM(cfg)
    return cfg, lm, lm.init(jax.random.key(0))


def cache_only_lm(name="llama3.2-3b", layers=2):
    cfg = dataclasses.replace(CONFIGS[name].reduced(), dtype="float32",
                              num_layers=layers)
    return cfg, LM(cfg)


def _streams(eng):
    return sorted((r.id, tuple(r.out_tokens)) for r in eng.finished)


def two_class(bulk_quota=None, preemption=True, classes=None):
    return TenancyConfig([TenantSpec("chat", "interactive"),
                          TenantSpec("bulk", "batch",
                                     page_quota=bulk_quota)],
                         classes=classes, preemption=preemption)


# -------------------------------------------------------- cache quotas ----

def test_paged_quota_accounting_and_eviction():
    """PagedCache-level: quota denies are distinguishable from pool denies,
    per-tenant charges cover the full footprint (shared pages included) and
    drain on free, and evict() reports exclusively-owned pages only."""
    _, lm = cache_only_lm()
    kv = lm.init_cache(4, 32, dtype=jnp.float32, backend="paged",
                       page_size=4, num_pages=16)
    kv.set_quota("bulk", 6)
    prompt = np.arange(8, dtype=np.int32)        # 2 full shareable pages

    assert kv.alloc(0, 12, prefix=prompt, tenant="bulk") is not None
    assert kv.tenant_pages("bulk") == 3 and kv.last_deny is None
    # prefix sharing halves the *pool* cost of slot 1 but its quota charge
    # is still the full footprint — quotas meter entitlement, not luck
    assert kv.alloc(1, 12, prefix=prompt, tenant="bulk") is not None
    st = kv.memory_stats()
    assert st.tenant_pages == {"bulk": 6}
    assert st.pages_shared == 2

    # at cap: one more page is a quota deny (pool has plenty free)
    assert kv.alloc(2, 4, tenant="bulk") is None
    assert kv.last_deny == "quota"
    # other tenants are untouched by bulk's cap
    assert kv.alloc(2, 4, tenant="chat") is not None
    assert kv.last_deny is None

    # slot 1 owns 1 exclusive page (2 are shared with slot 0): evicting it
    # frees exactly that page but refunds the full 3-page quota charge
    assert kv.slot_freeable(1) == 1
    assert kv.evict(1) == 1
    assert kv.tenant_pages("bulk") == 3
    kv.free(0)
    kv.free(2)
    assert kv.memory_stats().tenant_pages == {}


def test_quota_unset_and_quotaless_tenant():
    _, lm = cache_only_lm()
    kv = lm.init_cache(2, 32, dtype=jnp.float32, backend="paged",
                       page_size=4, num_pages=8)
    kv.set_quota("bulk", 2)
    assert kv.alloc(0, 12, tenant="bulk") is None      # 3 pages > quota 2
    assert kv.last_deny == "quota"
    kv.set_quota("bulk", None)                         # lift the cap
    assert kv.alloc(0, 12, tenant="bulk") is not None
    # untracked tenants and tenant=None never hit quota checks
    assert kv.alloc(1, 12, tenant=None) is not None


# ------------------------------------------------- engine construction ----

def test_tenancy_validation_against_backend():
    cfg, lm, params = small_lm()
    with pytest.raises(ValueError, match="quota"):
        ServeEngine(lm, params, 2, 32, cache_backend="contiguous",
                    tenancy=two_class(bulk_quota=4))
    with pytest.raises(ValueError, match="preemption"):
        ServeEngine(lm, params, 2, 32, cache_backend="contiguous",
                    tenancy=two_class())
    # quota-less, preemption-less tenancy still works on dense rows
    # (priority-ordered admission only)
    eng = ServeEngine(lm, params, 2, 32, cache_backend="contiguous",
                      tenancy=two_class(preemption=False))
    eng.submit(Request(0, np.arange(4, dtype=np.int32), max_new_tokens=2,
                       tenant="chat"))
    eng.run_until_drained()
    assert len(eng.finished) == 1

    with pytest.raises(ValueError, match="unknown tenant"):
        eng.submit(Request(1, np.arange(4, dtype=np.int32),
                           max_new_tokens=2, tenant="nobody"))

    # a class prefill budget below the chunk size could never dispatch
    starved = {"batch": PriorityClass("batch", 0, prefill_budget=4)}
    with pytest.raises(ValueError, match="prefill_budget"):
        ServeEngine(lm, params, 2, 64, cache_backend="paged", page_size=8,
                    prefill_chunk=8, tenancy=two_class(classes=starved))


# ---------------------------------------------------- admission policy ----

def test_priority_admission_and_quota_skip():
    """One pass over a mixed queue: interactive admits first even when
    submitted last; a quota-capped bulk request is *skipped* (not a
    head-of-line block) so the next bulk request behind it still admits."""
    cfg, lm, params = small_lm()
    rng = np.random.default_rng(0)
    eng = ServeEngine(lm, params, max_batch=4, max_seq=64,
                      cache_backend="paged", page_size=8,
                      # a slot stays free when bulk #2 is tried, so the
                      # quota — not the slot limit — is what denies it
                      num_pages=16, tenancy=two_class(bulk_quota=4))
    p = lambda n: rng.integers(0, cfg.vocab_size, n).astype(np.int32)
    eng.submit(Request(0, p(8), max_new_tokens=4, tenant="bulk"))   # 2 pages
    eng.submit(Request(1, p(8), max_new_tokens=4, tenant="bulk"))   # 2 pages
    eng.submit(Request(2, p(8), max_new_tokens=4, tenant="bulk"))   # denied
    eng.submit(Request(3, p(4), max_new_tokens=4, tenant="chat"))
    eng.step()
    admitted = {r.id for r in eng.slot_req if r is not None}
    assert admitted == {0, 1, 3}          # chat in-slot ahead of bulk #2
    assert [r.id for r in eng.queue] == [2]
    assert eng.reg.counter("serve_quota_denied_total").get() == 1
    assert eng.reg.counter("serve_admission_deferred_total").get(
        {"reason": "quota_denied"}) == 1
    assert eng.kv.tenant_pages("bulk") == 4
    # gauges exported for both the charge and the configured cap
    assert eng.reg.gauge("serve_tenant_pages_in_use").get(
        {"tenant": "bulk"}) == 4
    assert eng.reg.gauge("serve_tenant_quota_pages").get(
        {"tenant": "bulk"}) == 4
    eng.run_until_drained()
    assert len(eng.finished) == 4
    assert eng.kv.memory_stats().tenant_pages == {}


def test_deferred_total_reason_split_sums_to_unlabeled():
    """The satellite contract: the unlabeled serve_admission_deferred_total
    series (what pre-tenancy dashboards read) must equal the sum of its
    reason-labeled series."""
    cfg, lm, params = small_lm()
    rng = np.random.default_rng(1)
    eng = ServeEngine(lm, params, max_batch=2, max_seq=64,
                      cache_backend="paged", page_size=8, num_pages=8,
                      tenancy=two_class(bulk_quota=2, preemption=False))
    p = lambda n: rng.integers(0, cfg.vocab_size, n).astype(np.int32)
    for i in range(3):
        eng.submit(Request(i, p(12), max_new_tokens=4, tenant="bulk"))
    eng.submit(Request(3, p(12), max_new_tokens=4, tenant="chat"))
    eng.run_until_drained()
    c = eng.reg.counter("serve_admission_deferred_total")
    pool = c.get({"reason": "pool_exhausted"})
    quota = c.get({"reason": "quota_denied"})
    assert quota > 0
    assert c.get() == pool + quota
    assert eng.reg.counter("serve_quota_denied_total").get() == quota


# ----------------------------------------------- preemption and resume ----

def _mixed_run(sampling=None, prefill_chunk=0, preemption=True):
    """Fill every slot with bulk decodes, then submit chat mid-flight so
    admission *must* preempt.  Returns the drained engine."""
    cfg, lm, params = small_lm()
    rng = np.random.default_rng(7)
    kw = dict(prefill_chunk=prefill_chunk) if prefill_chunk else {}
    eng = ServeEngine(lm, params, max_batch=2, max_seq=64,
                      cache_backend="paged", page_size=8, num_pages=12,
                      tenancy=two_class(preemption=preemption), **kw)
    p = lambda n: rng.integers(0, cfg.vocab_size, n).astype(np.int32)
    sp = sampling or SamplingParams()
    for i in range(2):
        eng.submit(Request(i, p(10), max_new_tokens=12, tenant="bulk",
                           sampling=sp))
    for _ in range(4):
        eng.step()                        # bulk decoding in both slots
    eng.submit(Request(2, p(6), max_new_tokens=4, tenant="chat",
                       sampling=sp))
    eng.run_until_drained()
    return eng


@pytest.mark.parametrize("chunk", [0, 8])
def test_preemption_resume_streams_bitwise(chunk):
    """Preemption must be invisible in the token streams: the preempted
    bulk stream resumes bit-identically (greedy), and the chat stream
    matches a run where it had the pool to itself."""
    eng = _mixed_run(prefill_chunk=chunk)
    assert eng.reg.counter("serve_preemptions_total").get() >= 1
    preempted = [r for r in eng.finished if r.preemptions > 0]
    assert preempted and all(r.tenant == "bulk" for r in preempted)
    assert all(len(r.out_tokens) == 12 for r in eng.finished
               if r.tenant == "bulk")

    # oracle: same trace, no tenancy (chat waits instead of preempting)
    cfg, lm, params = small_lm()
    rng = np.random.default_rng(7)
    oracle = ServeEngine(lm, params, max_batch=2, max_seq=64,
                         cache_backend="paged", page_size=8, num_pages=12,
                         **(dict(prefill_chunk=chunk) if chunk else {}))
    p = lambda n: rng.integers(0, cfg.vocab_size, n).astype(np.int32)
    for i in range(2):
        oracle.submit(Request(i, p(10), max_new_tokens=12))
    for _ in range(4):
        oracle.step()
    oracle.submit(Request(2, p(6), max_new_tokens=4))
    oracle.run_until_drained()
    assert _streams(eng) == _streams(oracle)
    assert oracle.reg.counter("serve_preemptions_total").get() == 0


def test_preemption_resume_seeded_sampling_bitwise():
    """The resume-aware sampling steps: a seeded non-greedy stream must
    also continue bit-identically across preemption — the discarded
    pending token is re-drawn at the same (seed, id, step) triple."""
    sp = SamplingParams(temperature=0.8, top_k=20, top_p=0.95, seed=9)
    eng = _mixed_run(sampling=sp)
    assert eng.reg.counter("serve_preemptions_total").get() >= 1

    solo = _mixed_run(sampling=sp, preemption=False)
    assert solo.reg.counter("serve_preemptions_total").get() == 0
    assert _streams(eng) == _streams(solo)


def test_no_preemption_mode_waits_instead():
    eng = _mixed_run(preemption=False)
    assert eng.reg.counter("serve_preemptions_total").get() == 0
    assert all(r.preemptions == 0 for r in eng.finished)
    assert len(eng.finished) == 3


def test_equal_priority_never_preempts():
    """Two bulk tenants contending for one slot must take turns via
    completion, never evict each other (anti-livelock)."""
    cfg, lm, params = small_lm()
    rng = np.random.default_rng(3)
    ten = TenancyConfig([TenantSpec("bulk_a", "batch"),
                         TenantSpec("bulk_b", "batch")])
    eng = ServeEngine(lm, params, max_batch=1, max_seq=64,
                      cache_backend="paged", page_size=8, num_pages=4,
                      tenancy=ten)
    p = lambda n: rng.integers(0, cfg.vocab_size, n).astype(np.int32)
    eng.submit(Request(0, p(6), max_new_tokens=4, tenant="bulk_a"))
    eng.submit(Request(1, p(6), max_new_tokens=4, tenant="bulk_b"))
    eng.run_until_drained()
    assert eng.reg.counter("serve_preemptions_total").get() == 0
    assert len(eng.finished) == 2


# ----------------------------------------------- per-class chunk budget ----

def test_class_prefill_budget_caps_chunks_per_iteration():
    """With a batch-class budget of one chunk, two queued bulk prompts
    land one chunk per iteration even though the global budget would
    allow two; without the cap both dispatch in the same iteration."""
    cfg, lm, params = small_lm()
    rng = np.random.default_rng(4)
    prompts = [rng.integers(0, cfg.vocab_size, 16).astype(np.int32)
               for _ in range(2)]

    def first_step_chunks(classes):
        eng = ServeEngine(lm, params, max_batch=2, max_seq=64,
                          cache_backend="paged", page_size=8,
                          prefill_chunk=8, prefill_budget=16,
                          tenancy=two_class(classes=classes))
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p.copy(), max_new_tokens=2,
                               tenant="bulk"))
        eng.step()
        n = eng.reg.counter("serve_prefill_chunks_total").get()
        eng.run_until_drained()
        assert len(eng.finished) == 2
        return n, _streams(eng)

    capped = {"batch": PriorityClass("batch", 0, prefill_budget=8)}
    n_capped, streams_capped = first_step_chunks(capped)
    n_free, streams_free = first_step_chunks(None)
    assert n_capped == 1 and n_free == 2
    assert streams_capped == streams_free      # pacing, not content


# ------------------------------------------------------ class telemetry ----

def test_per_class_latency_histograms():
    eng = _mixed_run()
    ttft = eng.reg.histogram("serve_class_ttft_seconds")
    itl = eng.reg.histogram("serve_class_itl_seconds")
    assert ttft.count({"class": "interactive"}) == 1
    assert ttft.count({"class": "batch"}) == 2
    # every emitted token past the first records an inter-token gap
    assert itl.count({"class": "batch"}) == 2 * (12 - 1)
    assert itl.count({"class": "interactive"}) == 4 - 1
