"""Trainer/optimizer correctness, data-pipeline determinism, serving engine."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS, TrainConfig
from repro.data import (DeterministicLoader, LoaderConfig, PrefetchLoader,
                        TokenDataset, synthetic_corpus, write_token_shards)
from repro.models import LM, ForwardOpts, make_batch
from repro.serve import Request, ServeEngine
from repro.train import init_train_state, make_train_step
from repro.train.optimizer import lr_schedule

OPTS = ForwardOpts(attn_impl="dense", remat="none")


def test_loss_decreases_on_fixed_batch():
    cfg = CONFIGS["llama3.2-3b"].reduced()
    lm = LM(cfg)
    tcfg = TrainConfig(learning_rate=1e-2, warmup_steps=5, total_steps=60)
    state = init_train_state(lm, jax.random.key(0), tcfg)
    step = jax.jit(make_train_step(lm, tcfg, OPTS))
    batch = make_batch(cfg, 4, 64)
    first = None
    for _ in range(25):
        state, m = step(state, batch)
        first = first if first is not None else float(m["loss"])
    assert float(m["loss"]) < first - 2.0


def test_microbatch_accumulation_matches_full_batch():
    cfg = dataclasses.replace(CONFIGS["qwen3-4b"].reduced(), dtype="float32")
    lm = LM(cfg)
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10)
    state0 = init_train_state(lm, jax.random.key(0), tcfg)
    batch = make_batch(cfg, 4, 32)
    s1, m1 = jax.jit(make_train_step(lm, tcfg, OPTS, microbatches=1))(
        jax.tree.map(lambda x: x, state0), batch)
    s4, m4 = jax.jit(make_train_step(lm, tcfg, OPTS, microbatches=4))(
        jax.tree.map(lambda x: x, state0), batch)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                                   atol=2e-5)


def test_lr_schedule_shape():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100,
                       min_lr_ratio=0.1)
    lrs = [float(lr_schedule(tcfg, jnp.asarray(s))) for s in range(0, 101, 10)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(1e-3, rel=1e-5)       # end of warmup
    assert lrs[-1] == pytest.approx(1e-4, rel=1e-3)      # min lr
    assert all(a >= b - 1e-12 for a, b in zip(lrs[1:], lrs[2:]))


def test_grad_clipping_bounds_update():
    cfg = CONFIGS["qwen3-4b"].reduced()
    lm = LM(cfg)
    tcfg = TrainConfig(learning_rate=1.0, grad_clip=1e-4, warmup_steps=0,
                       total_steps=10)
    state = init_train_state(lm, jax.random.key(0), tcfg)
    step = jax.jit(make_train_step(lm, tcfg, OPTS))
    batch = make_batch(cfg, 2, 32)
    new_state, m = step(state, batch)
    assert float(m["grad_norm"]) > 1e-4   # raw norm bigger than the clip
    for a, b in zip(jax.tree.leaves(state["params"]),
                    jax.tree.leaves(new_state["params"])):
        assert bool(jnp.isfinite(b).all())


# ------------------------------------------------------------------- data ----

def test_data_determinism_and_dp_disjointness(tmp_path):
    toks = synthetic_corpus(200_000, vocab=500, seed=1)
    write_token_shards(str(tmp_path), toks, shard_tokens=64_000)
    ds = TokenDataset(str(tmp_path))
    assert ds.total == 200_000
    l0 = DeterministicLoader(ds, LoaderConfig(8, 128, dp_rank=0, dp_size=2))
    l1 = DeterministicLoader(ds, LoaderConfig(8, 128, dp_rank=1, dp_size=2))
    b0a, b0b = l0.batch_at(5), l0.batch_at(5)
    np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])  # determinism
    b1 = l1.batch_at(5)
    assert not np.array_equal(b0a["tokens"], b1["tokens"])       # disjoint
    # labels are next-token shifted
    np.testing.assert_array_equal(b0a["tokens"][:, 1:], b0a["labels"][:, :-1])


def test_prefetch_loader_ordering(tmp_path):
    toks = synthetic_corpus(50_000, vocab=100, seed=0)
    write_token_shards(str(tmp_path), toks)
    ds = TokenDataset(str(tmp_path))
    loader = DeterministicLoader(ds, LoaderConfig(4, 64))
    pf = PrefetchLoader(loader, depth=2, start_step=3)
    steps = [next(pf)[0] for _ in range(4)]
    pf.close()
    assert steps == [3, 4, 5, 6]


def test_dataset_read_crosses_shard_boundary(tmp_path):
    toks = np.arange(1000, dtype=np.uint32)
    write_token_shards(str(tmp_path), toks, shard_tokens=256)
    ds = TokenDataset(str(tmp_path))
    out = ds.slice(250, 20)   # crosses the 256 boundary
    np.testing.assert_array_equal(out, np.arange(250, 271))


# ------------------------------------------------------------------ serve ----

def test_serve_engine_continuous_batching_and_metrics():
    cfg = dataclasses.replace(CONFIGS["qwen3-4b"].reduced(), dtype="float32",
                              num_layers=2)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    eng = ServeEngine(lm, params, max_batch=2, max_seq=48)
    rng = np.random.default_rng(0)
    for i in range(5):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 4)
                           .astype(np.int32), max_new_tokens=6))
    done = eng.run_until_drained()
    assert len(done) == 5
    assert all(len(r.out_tokens) == 6 for r in done)
    assert eng.reg.histogram("serve_ttft_seconds").count() == 5
    assert eng.reg.counter("serve_tokens_total").get() == 30


def test_serve_greedy_matches_manual_argmax_decode():
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    prompt = np.array([5, 17, 301], np.int32)
    eng = ServeEngine(lm, params, max_batch=1, max_seq=32)
    eng.submit(Request(0, prompt, max_new_tokens=4))
    out = eng.run_until_drained()[0].out_tokens

    # manual: forward the prompt, then greedy decode with the cache
    batch = {"tokens": jnp.asarray(prompt[None])}
    last, cache = lm.prefill(params, batch, OPTS)

    def pad_kv(x, name):
        if name in ("k", "v"):
            pw = [(0, 0)] * x.ndim
            pw[2] = (0, 32 - x.shape[2])
            return jnp.pad(x, pw)
        return x
    cache = {"layers": {k: pad_kv(v, k) for k, v in cache["layers"].items()}}
    toks = []
    cur = int(jnp.argmax(last[0, -1, :cfg.vocab_size]))
    toks.append(cur)
    pos = len(prompt)
    for _ in range(3):
        logits, cache = lm.decode_step(params, jnp.asarray([[cur]]), cache,
                                       jnp.int32(pos))
        cur = int(jnp.argmax(logits[0, -1, :cfg.vocab_size]))
        toks.append(cur)
        pos += 1
    assert out == toks
