"""Multi-device semantics on 8 host devices (subprocess — the main pytest
process keeps 1 device): sharded train step == single-device step, pipeline
parallelism == sequential, compressed grad sync == mean, elastic checkpoint
reshard."""
import pytest


def test_sharded_train_step_matches_single_device(subproc):
    subproc("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import CONFIGS, TrainConfig
from repro.models import LM, ForwardOpts, make_batch
from repro.train import init_train_state, make_train_step, train_state_logical_axes, abstract_train_state
from repro.parallel.mesh import make_mesh
from repro.parallel.sharding import default_rules, logical_to_sharding, sharding_context

cfg = dataclasses.replace(CONFIGS['qwen3-4b'].reduced(), dtype='float32')
lm = LM(cfg)
tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=10)
opts = ForwardOpts(attn_impl='dense', remat='none')
state = init_train_state(lm, jax.random.key(0), tcfg)
batch = make_batch(cfg, 4, 64)
step = make_train_step(lm, tcfg, opts)

ref_state, ref_m = jax.jit(step)(jax.tree.map(lambda x: x, state), batch)

mesh = make_mesh((4, 2), ('data', 'model'))
rules = default_rules(mesh.axis_names)
st_sh = logical_to_sharding(train_state_logical_axes(lm), abstract_train_state(lm), mesh, rules)
def wrapped(s, b):
    with sharding_context(mesh, rules):
        return step(s, b)
with mesh:
    out_state, out_m = jax.jit(wrapped, in_shardings=(st_sh, None), out_shardings=(st_sh, None))(state, batch)
assert abs(float(out_m['loss']) - float(ref_m['loss'])) < 1e-4, (float(out_m['loss']), float(ref_m['loss']))
for a, b in zip(jax.tree.leaves(ref_state['params']), jax.tree.leaves(out_state['params'])):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=3e-4, atol=3e-5)
print('OK sharded == single-device')
""")


def test_pipeline_forward_matches_sequential(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.parallel.mesh import make_mesh
from repro.parallel.pipeline import make_pipelined_apply

mesh = make_mesh((4,), ('pipe',))
L, D = 8, 16   # 8 layers over 4 stages
rng = np.random.default_rng(0)
w = jnp.asarray(rng.normal(0, 0.5, (L, D, D)), jnp.float32)
params = {'w': w}
x = jnp.asarray(rng.normal(0, 1, (8, D)), jnp.float32)

def layer_fn(lp, h):
    return jnp.tanh(h @ lp['w'])

apply = make_pipelined_apply(layer_fn, mesh, 'pipe', n_microbatches=4)
with mesh:
    y = apply(params, x)

h = x
for i in range(L):
    h = jnp.tanh(h @ w[i])
np.testing.assert_allclose(np.asarray(y), np.asarray(h), rtol=1e-5, atol=1e-5)
print('OK pipeline == sequential')

# gradient flows through the pipeline
def loss(p, x):
    return jnp.sum(apply({'w': p}, x) ** 2)
def loss_seq(p, x):
    h = x
    for i in range(L):
        h = jnp.tanh(h @ p[i])
    return jnp.sum(h ** 2)
with mesh:
    g_pipe = jax.grad(loss)(w, x)
g_seq = jax.grad(loss_seq)(w, x)
np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq), rtol=1e-4, atol=1e-4)
print('OK pipeline grads == sequential grads')
""")


def test_compressed_grad_sync_approximates_mean(subproc):
    subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.mesh import make_mesh
from repro.parallel.compression import make_compressed_grad_sync, init_error_state

mesh = make_mesh((8,), ('data',))
rng = np.random.default_rng(0)
g = jnp.asarray(rng.normal(0, 1, (8, 128)), jnp.float32)  # per-device grads
sync = make_compressed_grad_sync(mesh, 'data')
err = init_error_state({'g': g})
with mesh:
    mean, err = sync({'g': g}, err)
true = jnp.mean(g, axis=0)
scale = float(jnp.max(jnp.abs(g))) / 127.0
assert float(jnp.max(jnp.abs(mean['g'] - true))) <= scale + 1e-6
print('OK compressed sync ~ mean within one quantization bucket')
""")


def test_elastic_checkpoint_reshard(subproc):
    subproc("""
import dataclasses, tempfile, jax, jax.numpy as jnp, numpy as np
from repro.configs import CONFIGS, TrainConfig
from repro.models import LM, ForwardOpts, make_batch
from repro.train import init_train_state, make_train_step, train_state_logical_axes, abstract_train_state
from repro.parallel.mesh import make_mesh
from repro.parallel.sharding import default_rules, logical_to_sharding, sharding_context
from repro.core import save_checkpoint, load_checkpoint

cfg = dataclasses.replace(CONFIGS['llama3.2-3b'].reduced(), dtype='float32')
lm = LM(cfg)
tcfg = TrainConfig(total_steps=10)
state = init_train_state(lm, jax.random.key(0), tcfg)
d = tempfile.mkdtemp()
save_checkpoint(d, state, 4)

# restart on a DIFFERENT mesh shape (8 -> elastic downsize to 2x2)
mesh = make_mesh((2, 2), ('data', 'model'))
rules = default_rules(mesh.axis_names)
sh = logical_to_sharding(train_state_logical_axes(lm), abstract_train_state(lm), mesh, rules)
restored, step = load_checkpoint(d, template=state, shardings=sh)
assert step == 4
for a, b in zip(jax.tree.leaves(state['params']), jax.tree.leaves(restored['params'])):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
# and the restored sharded state trains
opts = ForwardOpts(attn_impl='dense', remat='none')
stepf = make_train_step(lm, tcfg, opts)
def wrapped(s, b):
    with sharding_context(mesh, rules):
        return stepf(s, b)
with mesh:
    out, m = jax.jit(wrapped, in_shardings=(sh, None), out_shardings=(sh, None))(restored, make_batch(cfg, 4, 32))
assert np.isfinite(float(m['loss']))
print('OK elastic reshard restore + train')
""")


def test_pp_forward_matches_standard_forward(subproc):
    """Full-model pipeline-parallel forward (layers over 'pod', DP inside)
    equals the standard forward."""
    subproc("""
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.models import LM, ForwardOpts
from repro.launch.pp_dryrun import build_pp_forward
from repro.parallel.mesh import make_mesh
from repro.parallel.sharding import default_rules

cfg = dataclasses.replace(get_config('granite-20b-code').reduced(),
                          dtype='float32', num_layers=4)
lm = LM(cfg)
params = lm.init(jax.random.key(0))
mesh = make_mesh((2, 4), ('pod', 'data'))
rules = default_rules(mesh.axis_names)
rules['batch'] = ('data',)
opts = ForwardOpts(attn_impl='dense', remat='none')
fwd = build_pp_forward(lm, cfg, mesh, rules, opts, n_microbatches=2)
toks = jnp.asarray(np.random.default_rng(0).integers(
    0, cfg.vocab_size, (8, 32)), jnp.int32)
with mesh:
    logits_pp = jax.jit(fwd)(params, toks)
logits_ref, _, _ = lm.forward(params, {'tokens': toks}, opts)
np.testing.assert_allclose(np.asarray(logits_pp), np.asarray(logits_ref),
                           rtol=2e-4, atol=2e-4)
print('OK PP forward == standard forward')
""")


def test_multidevice_collectives_present_in_hlo(subproc):
    """Dry-run style check on a small mesh: FSDP+TP sharding produces
    all-gather/all-reduce/reduce-scatter in the optimized HLO."""
    subproc("""
import dataclasses, jax
from repro.configs import CONFIGS, TrainConfig
from repro.models import LM, ForwardOpts, make_batch
from repro.train import init_train_state, make_train_step, train_state_logical_axes, abstract_train_state
from repro.parallel.mesh import make_mesh
from repro.parallel.sharding import default_rules, logical_to_sharding, sharding_context
from repro.roofline.hlo import parse_collectives

cfg = dataclasses.replace(CONFIGS['qwen3-4b'].reduced(), dtype='float32')
lm = LM(cfg)
tcfg = TrainConfig()
opts = ForwardOpts(attn_impl='dense', remat='none')
state = init_train_state(lm, jax.random.key(0), tcfg)
batch = make_batch(cfg, 4, 64)
step = make_train_step(lm, tcfg, opts)
mesh = make_mesh((4, 2), ('data', 'model'))
rules = default_rules(mesh.axis_names)
sh = logical_to_sharding(train_state_logical_axes(lm), abstract_train_state(lm), mesh, rules)
def wrapped(s, b):
    with sharding_context(mesh, rules):
        return step(s, b)
with mesh:
    compiled = jax.jit(wrapped, in_shardings=(sh, None), out_shardings=(sh, None)).lower(state, batch).compile()
stats = parse_collectives(compiled.as_text())
kinds = set(stats['per_kind'])
assert 'all-reduce' in kinds or 'reduce-scatter' in kinds, kinds
assert stats['total_bytes'] > 0
print('OK collectives:', sorted(kinds))
""")
