"""Hypothesis property-based tests on system invariants."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

# optional dev dependency (requirements-dev.txt): skip the module instead of
# erroring the whole suite's collection when hypothesis isn't installed
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.youngs import (lost_fraction, optimal_lost_fraction,
                               young_interval)
from repro.parallel.compression import dequantize, quantize_int8


# ----------------------------------------------------------------- Young ----

@given(delta=st.floats(1.0, 1e4), mtbf=st.floats(60.0, 1e8))
@settings(max_examples=200, deadline=None)
def test_young_interval_is_optimal(delta, mtbf):
    """The Young interval minimizes first-order lost fraction."""
    tau = young_interval(delta, mtbf)
    best = lost_fraction(delta, mtbf, tau)
    for mult in (0.5, 0.8, 1.25, 2.0):
        assert best <= lost_fraction(delta, mtbf, tau * mult) + 1e-12


@given(delta=st.floats(1.0, 1e3), mtbf=st.floats(1e4, 1e8))
@settings(max_examples=100, deadline=None)
def test_young_closed_form(delta, mtbf):
    assert math.isclose(optimal_lost_fraction(delta, mtbf),
                        math.sqrt(2 * delta / mtbf), rel_tol=1e-9)
    assert math.isclose(young_interval(delta, mtbf),
                        math.sqrt(2 * delta * mtbf), rel_tol=1e-12)


# -------------------------------------------------------------- sharding ----

mesh_axes_st = st.sampled_from([("data", "model"), ("pod", "data", "model")])


@given(
    mesh_axes=mesh_axes_st,
    dims=st.lists(st.sampled_from([1, 2, 3, 5, 8, 16, 24, 56, 128, 4096]),
                  min_size=1, max_size=4),
    names=st.lists(st.sampled_from(["batch", "embed", "heads", "kv_heads",
                                    "mlp", "vocab", "expert", None]),
                   min_size=1, max_size=4),
)
@settings(max_examples=300, deadline=None)
def test_spec_for_invariants(mesh_axes, dims, names):
    """Resolved PartitionSpecs never repeat a mesh axis and always divide the
    dimension they shard."""
    import jax
    from repro.parallel.sharding import default_rules, spec_for
    n = min(len(dims), len(names))
    dims, names = dims[:n], names[:n]
    devices = np.array(jax.devices() * 512)[:512]
    shape = ((2, 16, 16) if len(mesh_axes) == 3 else (16, 16))
    mesh = jax.sharding.Mesh(devices[:np.prod(shape)].reshape(shape),
                             mesh_axes)
    rules = default_rules(mesh_axes)
    spec = spec_for(names, dims, rules, mesh)
    used = []
    for dim, entry in zip(dims, tuple(spec) + (None,) * (n - len(spec))):
        axes = (entry,) if isinstance(entry, str) else (entry or ())
        extent = 1
        for a in axes:
            assert a not in used
            used.append(a)
            extent *= mesh.shape[a]
        assert dim % extent == 0


# ------------------------------------------------------------ compression ----

@given(st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_int8_quantization_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, rng.uniform(0.1, 10), 256), jnp.float32)
    q, scale, err = quantize_int8(x)
    rec = dequantize(q, scale)
    # max error bounded by half a quantization bucket
    assert float(jnp.max(jnp.abs(x - rec))) <= float(scale) * 0.5 + 1e-6
    # error feedback exactness: x == rec + err
    np.testing.assert_allclose(np.asarray(rec + err), np.asarray(x),
                               rtol=1e-5, atol=1e-6)


@given(st.integers(0, 200))
@settings(max_examples=30, deadline=None)
def test_error_feedback_reduces_bias(seed):
    """Accumulated error feedback makes the time-average unbiased."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(0, 1, 64), jnp.float32)
    err = jnp.zeros(64, jnp.float32)
    total = jnp.zeros(64, jnp.float32)
    steps = 32
    for _ in range(steps):
        q, scale, err = quantize_int8(g, err)
        total = total + dequantize(q, scale)
    np.testing.assert_allclose(np.asarray(total / steps), np.asarray(g),
                               atol=float(scale) / steps + 1e-4)


# ---------------------------------------------------------------- storage ----

@given(cap=st.integers(2, 20), n=st.integers(1, 40))
@settings(max_examples=50, deadline=None)
def test_lru_never_exceeds_capacity_with_clean_entries(cap, n):
    from repro.core import COS, BlobStore, ScaleCache, VirtualClock
    clock = VirtualClock()
    cos = BlobStore(COS, clock)
    cache = ScaleCache(cos, clock, capacity_bytes=float(cap))
    for i in range(n):
        cos.blobs[f"b{i}"] = 1
        cache.read(f"b{i}")
    assert cache.used <= cap
