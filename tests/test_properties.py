"""Hypothesis property-based tests on system invariants."""
import math

import jax.numpy as jnp
import numpy as np
import pytest

# optional dev dependency (requirements-dev.txt): skip the module instead of
# erroring the whole suite's collection when hypothesis isn't installed
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.youngs import (lost_fraction, optimal_lost_fraction,
                               young_interval)
from repro.parallel.compression import dequantize, quantize_int8


# ----------------------------------------------------------------- Young ----

@given(delta=st.floats(1.0, 1e4), mtbf=st.floats(60.0, 1e8))
@settings(max_examples=200, deadline=None)
def test_young_interval_is_optimal(delta, mtbf):
    """The Young interval minimizes first-order lost fraction."""
    tau = young_interval(delta, mtbf)
    best = lost_fraction(delta, mtbf, tau)
    for mult in (0.5, 0.8, 1.25, 2.0):
        assert best <= lost_fraction(delta, mtbf, tau * mult) + 1e-12


@given(delta=st.floats(1.0, 1e3), mtbf=st.floats(1e4, 1e8))
@settings(max_examples=100, deadline=None)
def test_young_closed_form(delta, mtbf):
    assert math.isclose(optimal_lost_fraction(delta, mtbf),
                        math.sqrt(2 * delta / mtbf), rel_tol=1e-9)
    assert math.isclose(young_interval(delta, mtbf),
                        math.sqrt(2 * delta * mtbf), rel_tol=1e-12)


# -------------------------------------------------------------- sharding ----

mesh_axes_st = st.sampled_from([("data", "model"), ("pod", "data", "model")])


@given(
    mesh_axes=mesh_axes_st,
    dims=st.lists(st.sampled_from([1, 2, 3, 5, 8, 16, 24, 56, 128, 4096]),
                  min_size=1, max_size=4),
    names=st.lists(st.sampled_from(["batch", "embed", "heads", "kv_heads",
                                    "mlp", "vocab", "expert", None]),
                   min_size=1, max_size=4),
)
@settings(max_examples=300, deadline=None)
def test_spec_for_invariants(mesh_axes, dims, names):
    """Resolved PartitionSpecs never repeat a mesh axis and always divide the
    dimension they shard."""
    import jax
    from repro.parallel.sharding import default_rules, spec_for
    n = min(len(dims), len(names))
    dims, names = dims[:n], names[:n]
    devices = np.array(jax.devices() * 512)[:512]
    shape = ((2, 16, 16) if len(mesh_axes) == 3 else (16, 16))
    mesh = jax.sharding.Mesh(devices[:np.prod(shape)].reshape(shape),
                             mesh_axes)
    rules = default_rules(mesh_axes)
    spec = spec_for(names, dims, rules, mesh)
    used = []
    for dim, entry in zip(dims, tuple(spec) + (None,) * (n - len(spec))):
        axes = (entry,) if isinstance(entry, str) else (entry or ())
        extent = 1
        for a in axes:
            assert a not in used
            used.append(a)
            extent *= mesh.shape[a]
        assert dim % extent == 0


# ------------------------------------------------------------ compression ----

@given(st.integers(0, 10_000))
@settings(max_examples=50, deadline=None)
def test_int8_quantization_bounded_error(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(0, rng.uniform(0.1, 10), 256), jnp.float32)
    q, scale, err = quantize_int8(x)
    rec = dequantize(q, scale)
    # max error bounded by half a quantization bucket
    assert float(jnp.max(jnp.abs(x - rec))) <= float(scale) * 0.5 + 1e-6
    # error feedback exactness: x == rec + err
    np.testing.assert_allclose(np.asarray(rec + err), np.asarray(x),
                               rtol=1e-5, atol=1e-6)


@given(st.integers(0, 200))
@settings(max_examples=30, deadline=None)
def test_error_feedback_reduces_bias(seed):
    """Accumulated error feedback makes the time-average unbiased."""
    rng = np.random.default_rng(seed)
    g = jnp.asarray(rng.normal(0, 1, 64), jnp.float32)
    err = jnp.zeros(64, jnp.float32)
    total = jnp.zeros(64, jnp.float32)
    steps = 32
    for _ in range(steps):
        q, scale, err = quantize_int8(g, err)
        total = total + dequantize(q, scale)
    np.testing.assert_allclose(np.asarray(total / steps), np.asarray(g),
                               atol=float(scale) / steps + 1e-4)


# ------------------------------------------------------- paged allocator ----

_ALLOC_LM = None


def _alloc_lm():
    """One tiny LM shared by every hypothesis example (pool construction
    needs real cfg shapes; building the config once keeps examples cheap)."""
    global _ALLOC_LM
    if _ALLOC_LM is None:
        import dataclasses
        from repro.configs import CONFIGS
        from repro.models import LM
        cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                                  dtype="float32", num_layers=1)
        _ALLOC_LM = LM(cfg)
    return _ALLOC_LM


# op stream: (kind, slot, length, prefix_id) — prefix_id picks one of three
# canonical prompts so alloc sequences actually hit the sharing path
alloc_ops_st = st.lists(
    st.tuples(st.sampled_from(["alloc", "free", "write"]),
              st.integers(0, 3),                  # slot
              st.integers(1, 24),                 # length (footprint)
              st.integers(0, 2)),                 # prefix choice
    min_size=1, max_size=25)


def _drive(kv, ops, record=None):
    """Apply an op stream to a PagedCache; returns the admit/defer trace."""
    page = kv.page
    prefixes = [np.arange(9, dtype=np.int32),
                np.arange(9, dtype=np.int32) + 1,
                np.arange(3, dtype=np.int32)]
    trace = []
    for kind, slot, length, pid in ops:
        if kind == "alloc":
            if kv._slot_pages[slot]:
                kv.free(slot)
            length = min(length, kv.S)
            # the engine's contract: the prompt fits inside the footprint
            got = kv.alloc(slot, length, prefix=prefixes[pid][:length])
            trace.append(("alloc", slot, got is not None,
                          got if got is not None else -1))
        elif kind == "free":
            if kv._slot_pages[slot]:
                kv.free(slot)
            trace.append(("free", slot))
        elif kind == "write" and kv._slot_pages[slot]:
            # zeros block shaped like a bucket-4 prompt: exercises
            # prefill_dest's shared/padding scratch-routing
            L = kv.cfg.num_layers
            kvh, hd = kv.cfg.num_kv_heads, kv.cfg.resolved_head_dim
            blk = {"k": jnp.zeros((L, 1, page, kvh, hd), jnp.float32),
                   "v": jnp.zeros((L, 1, page, kvh, hd), jnp.float32)}
            kv.write_prefill(slot, blk)
        if record is not None:
            record(kv)
    return trace


def _check_invariants(kv):
    from repro.serve.kvcache import page_kv_bytes
    owned = [pid for pages in kv._slot_pages for pid in pages]
    free = [pid for chip in kv._free_chip for pid in chip]
    # scratch page 0 is never handed out, listed free, or refcounted
    assert 0 not in owned and 0 not in free
    assert kv._ref[0] == 0
    # refcounts == live references; free pages carry no references
    counts = np.bincount(owned, minlength=kv.P) if owned else \
        np.zeros(kv.P, np.int64)
    np.testing.assert_array_equal(kv._ref, counts)
    assert all(kv._ref[pid] == 0 for pid in free)
    # no page both free and owned; free+owned partition the usable pool
    assert set(free).isdisjoint(owned)
    assert len(free) == len(set(free))
    assert len(set(free) | set(owned)) == len(free) + len(set(owned))
    assert set(free) | set(owned) <= set(range(1, kv.P))
    # every page sits in its owning chip's free list
    for c, chip in enumerate(kv._free_chip):
        assert all(pid // kv.pages_per_chip == c for pid in chip)
    # memory_stats byte math is consistent with the page bookkeeping
    stats = kv.memory_stats()
    pb = page_kv_bytes(kv.cfg, kv.page, kv.dtype)
    assert stats.pages_total == kv.P - 1
    assert stats.pages_in_use == stats.pages_total - len(free)
    assert stats.bytes_reserved == stats.pages_in_use * pb
    assert stats.bytes_total == kv.P * pb
    assert stats.bytes_per_chip * stats.mesh_chips == stats.bytes_total
    # shared accounting never exceeds what's owned
    assert stats.pages_shared <= len(set(owned))
    # the cache's own sanitizer must agree with every check above
    kv.verify()


@given(ops=alloc_ops_st)
@settings(max_examples=25, deadline=None)
def test_paged_alloc_invariants_hold_under_random_op_streams(ops):
    """Random alloc/write/free/prefix-share sequences: no page owned twice
    (refcounts == live references), scratch page 0 never allocated, free and
    owned pages partition the pool, memory_stats byte math consistent —
    checked after *every* op."""
    from repro.serve.kvcache import PagedCache
    kv = PagedCache(_alloc_lm(), 4, 24, dtype=jnp.float32, page_size=4,
                    num_pages=12)
    _drive(kv, ops, record=_check_invariants)


@given(ops=alloc_ops_st, chips=st.sampled_from([2, 3, 4]))
@settings(max_examples=25, deadline=None)
def test_locality_aware_free_list_never_changes_admissions(ops, chips):
    """The locality-aware (per-chip) free list is a placement hint only:
    driving the identical op stream against a chip-partitioned pool and a
    flat pool must produce the identical admit/defer trace *and* identical
    shared-page credits — placement never leaks into admission control."""
    from repro.serve.kvcache import PagedCache
    # num_pages=12 divides 2, 3, and 4, so both pools are the same width
    flat = PagedCache(_alloc_lm(), 4, 24, dtype=jnp.float32, page_size=4,
                      num_pages=12)
    local = PagedCache(_alloc_lm(), 4, 24, dtype=jnp.float32, page_size=4,
                       num_pages=12, locality_chips=chips)
    assert flat.P == local.P
    t_flat = _drive(flat, ops)
    t_local = _drive(local, ops, record=_check_invariants)
    assert t_flat == t_local
    # and the two pools agree on aggregate accounting at the end
    sf, sl = flat.memory_stats(), local.memory_stats()
    assert (sf.pages_in_use, sf.bytes_reserved, sf.slots_in_use) == \
        (sl.pages_in_use, sl.bytes_reserved, sl.slots_in_use)


# ---------------------------------------------------------------- tenancy ----

# op stream over a quota'd pool: tenants "a"/"b" are capped, "c" is not
tenant_ops_st = st.lists(
    st.tuples(st.sampled_from(["alloc", "alloc_chunked", "extend",
                               "free", "evict"]),
              st.integers(0, 3),                  # slot
              st.integers(1, 24),                 # footprint positions
              st.sampled_from(["a", "b", "c"])),  # tenant
    min_size=1, max_size=30)


@given(ops=tenant_ops_st, qa=st.integers(1, 8), qb=st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_tenant_pages_never_exceed_quota(ops, qa, qb):
    """Under random alloc/alloc_chunked/extend/free/evict streams a quota'd
    tenant's charged pages never exceed its cap, charges always equal the
    sum of its live slots' footprints, a quota deny never perturbs the pool
    (refcounts/free lists bitwise unchanged), and a fully-drained pool
    carries no residual charges — checked after every op."""
    from repro.serve.kvcache import PagedCache
    kv = PagedCache(_alloc_lm(), 4, 24, dtype=jnp.float32, page_size=4,
                    num_pages=12)
    quota = {"a": qa, "b": qb}
    for t, q in quota.items():
        kv.set_quota(t, q)
    footprint = {}                      # slot -> positions to cover
    for kind, slot, length, tenant in ops:
        length = min(length, kv.S)
        if kind in ("alloc", "alloc_chunked") and not kv._slot_pages[slot]:
            before = (list(kv._ref), [list(c) for c in kv._free_chip])
            if kind == "alloc":
                got = kv.alloc(slot, length, tenant=tenant)
            else:
                got = kv.alloc_chunked(slot, length, min(4, length),
                                       tenant=tenant)
            if got is None:
                if kv.last_deny == "quota":     # denial leaves no residue
                    assert (list(kv._ref),
                            [list(c) for c in kv._free_chip]) == before
            else:
                footprint[slot] = length
        elif kind == "extend" and kv._slot_need[slot] > 0:
            have = len(kv._slot_pages[slot]) * kv.page
            kv.extend(slot, min(have + kv.page, footprint[slot]))
        elif kind in ("free", "evict") and kv._slot_pages[slot]:
            (kv.free if kind == "free" else kv.evict)(slot)
            footprint.pop(slot, None)
        for t, q in quota.items():
            assert kv.tenant_pages(t) <= q, (t, kind)
        by_tenant = {}
        for s in range(4):
            t = kv._slot_tenant[s]
            if t is not None and kv._slot_pages[s]:
                by_tenant[t] = by_tenant.get(t, 0) + kv._slot_charge[s]
        assert by_tenant == {t: n for t, n in kv._tenant_pages.items() if n}
    for slot in range(4):
        if kv._slot_pages[slot]:
            kv.free(slot)
    assert kv._tenant_pages == {} and kv.memory_stats().tenant_pages == {}


@given(seed=st.integers(0, 10_000))
@settings(max_examples=40, deadline=None)
def test_preemption_frees_enough_to_admit_preemptor(seed):
    """The engine's preemption loop (evict ``next_victim`` until ``alloc``
    succeeds) admits the high-priority preemptor iff the free pool plus the
    eligible victims' exclusively-owned pages can ever cover its footprint
    — and every eviction it takes is of a strictly-lower-priority slot."""
    from repro.serve.kvcache import PagedCache
    from repro.serve.tenancy import Victim, next_victim
    rng = np.random.default_rng(seed)
    kv = PagedCache(_alloc_lm(), 5, 24, dtype=jnp.float32, page_size=4,
                    num_pages=12)
    prompt = np.arange(8, dtype=np.int32)
    prio = {}
    for slot in range(4):
        if rng.random() < 0.8:
            # half the slots share a prompt prefix, so some victim pages
            # are pinned by other references and not actually freeable
            pref = prompt if rng.random() < 0.5 else None
            if kv.alloc(slot, int(rng.integers(1, 20)), prefix=pref) is None:
                continue
            prio[slot] = int(rng.choice([0, 0, 50]))
    need = int(rng.integers(1, 24))
    could_free = sum(kv.slot_freeable(s) for s, p in prio.items() if p < 100)
    free_now = len([p for c in kv._free_chip for p in c])
    evicted = []
    while True:
        if kv.alloc(4, need) is not None:
            admitted = True
            break
        cands = [Victim(s, prio[s], True, kv.slot_freeable(s))
                 for s in prio if s not in evicted]
        v = next_victim(cands, 100)
        if v is None:
            admitted = False
            break
        assert v.priority < 100
        kv.evict(v.slot)
        evicted.append(v.slot)
    assert admitted == (free_now + could_free >= kv.pages_needed(need)), \
        (admitted, free_now, could_free, need, evicted)


@given(seed=st.integers(0, 10_000))
@settings(max_examples=30, deadline=None)
def test_banker_never_deadlocks_under_random_preemption(seed):
    """Random schedule of chunked admissions, chunk extends, completions,
    and preemptive evictions (of fully-prefilled slots only — the engine
    never evicts mid-prefill): the banker's safety invariant must keep the
    system live, i.e. whenever any slot is mid-prefill, either some extend
    makes progress this sweep or a fully-covered slot exists whose
    completion will free pages.  Every request drains within a bounded
    number of sweeps."""
    from repro.serve.kvcache import PagedCache
    rng = np.random.default_rng(seed)
    kv = PagedCache(_alloc_lm(), 4, 24, dtype=jnp.float32, page_size=4,
                    num_pages=12)
    pending = [int(rng.integers(5, 25)) for _ in range(8)]   # footprints
    covered = {}                      # slot -> (covered, footprint)
    for _ in range(400):
        if not pending and not covered:
            break
        # admit into free slots (first chunk only, banker-checked)
        for slot in range(4):
            if pending and not kv._slot_pages[slot]:
                length = min(pending[0], kv.S)
                if kv.alloc_chunked(slot, length, min(4, length)) is not None:
                    covered[slot] = [min(4, length), length]
                    pending.pop(0)
        progressed = False
        # one sweep: try to advance every mid-prefill slot a chunk
        for slot in sorted(covered, key=lambda s: rng.random()):
            cov, length = covered[slot]
            if cov < length and kv.extend(slot, min(cov + 4, length)):
                covered[slot][0] = min(cov + 4, length)
                progressed = True
        full = [s for s, (cov, length) in covered.items() if cov >= length]
        stalled = [s for s, (cov, length) in covered.items() if cov < length]
        # THE liveness claim: a stalled prefill always has a completion
        # coming (banker-safe grants can never mutually deadlock)
        if stalled and not progressed:
            assert full, (stalled, covered)
        if full:
            victim = full[int(rng.integers(len(full)))]
            if rng.random() < 0.3:    # preemption: evict + resubmit
                kv.evict(victim)
                pending.append(covered.pop(victim)[1])
            else:                     # decode finished
                kv.free(victim)
                covered.pop(victim)
    assert not pending and not covered, (pending, covered)


# -------------------------------------------------------------- host tier ----

# op stream over a store-backed pool: evict-to-host happens implicitly when
# a free/evict drops a registered page's last reference; prefetch happens
# implicitly when a later alloc hash-hits a host-resident prefix; "drain"
# forces the async offload queue to materialize at an arbitrary point
host_ops_st = st.lists(
    st.tuples(st.sampled_from(["alloc", "alloc_chunked", "extend", "free",
                               "evict", "drain"]),
              st.integers(0, 3),                  # slot
              st.integers(1, 24),                 # footprint positions
              st.integers(0, 3),                  # prefix choice
              st.sampled_from(["a", "b", "c"])),  # tenant ("a" is quota'd)
    min_size=1, max_size=30)


@given(ops=host_ops_st, cap=st.integers(1, 6), qa=st.integers(2, 8))
@settings(max_examples=30, deadline=None)
def test_host_tier_invariants_hold_under_random_op_streams(ops, cap, qa):
    """Random alloc/alloc_chunked/extend/free/evict/drain streams against a
    store-backed pool with a deliberately tiny host tier (LRU churn on
    nearly every offload): refcounts, quota charges and banker safety never
    break, ``PagedCache.verify()`` — which cross-checks the host tier's
    slab/entry bookkeeping and the device<->host byte math — passes after
    every single op, host residency never exceeds tier capacity, and the
    store's counters stay monotonic (the engine exports them as Prometheus
    counters by delta, so one decrement corrupts telemetry forever)."""
    from repro.serve.kvcache import PagedCache
    from repro.serve.offload import PrefixStore
    store = PrefixStore(cap)
    kv = PagedCache(_alloc_lm(), 4, 24, dtype=jnp.float32, page_size=4,
                    num_pages=12, prefix_store=store)
    kv.set_quota("a", qa)
    # overlapping prefixes: runs share leading pages, so offloaded pages
    # from one prompt are prefetch hits for another
    prefixes = [np.arange(12, dtype=np.int32),
                np.arange(12, dtype=np.int32) + 1,
                np.concatenate([np.arange(8, dtype=np.int32),
                                np.arange(70, 74, dtype=np.int32)]),
                np.arange(4, dtype=np.int32)]
    footprint = {}
    prev_stats = store.stats()
    for kind, slot, length, pid, tenant in ops:
        length = min(length, kv.S)
        if kind in ("alloc", "alloc_chunked"):
            if kv._slot_pages[slot]:
                kv.free(slot)
                footprint.pop(slot, None)
            pref = prefixes[pid][:length]
            if kind == "alloc":
                got = kv.alloc(slot, length, prefix=pref, tenant=tenant)
            else:
                got = kv.alloc_chunked(slot, length, min(4, length),
                                       prefix=pref, tenant=tenant)
            if got is not None:
                footprint[slot] = length
        elif kind == "extend" and kv._slot_need[slot] > 0:
            have = len(kv._slot_pages[slot]) * kv.page
            kv.extend(slot, min(have + kv.page, footprint[slot]))
        elif kind in ("free", "evict") and kv._slot_pages[slot]:
            (kv.free if kind == "free" else kv.evict)(slot)
            footprint.pop(slot, None)
        elif kind == "drain":
            kv.drain_offloads()
        # --- invariants after EVERY op ---
        kv.verify()    # refcounts, banker safety, host slab/entry/byte math
        assert kv.tenant_pages("a") <= qa
        assert store.pages_in_use() <= cap
        stats = store.stats()
        assert all(stats[k] >= prev_stats[k] for k in stats), \
            (prev_stats, stats)
        prev_stats = stats
        st_ = kv.memory_stats()          # memory_stats drains, then reports
        assert st_.host_pages_in_use == store.pages_in_use()
        assert st_.host_bytes == store.bytes_in_use()
    for slot in range(4):
        if kv._slot_pages[slot]:
            kv.free(slot)
    kv.drain_offloads()
    kv.verify()
    # drained pool: no device pages held, no residual charges; host pages
    # legitimately stay warm (that is the tier's purpose) but bounded
    assert kv.memory_stats().pages_in_use == 0
    assert kv._tenant_pages == {}
    assert store.pages_in_use() <= cap


# ---------------------------------------------------------------- storage ----

@given(cap=st.integers(2, 20), n=st.integers(1, 40))
@settings(max_examples=50, deadline=None)
def test_lru_never_exceeds_capacity_with_clean_entries(cap, n):
    from repro.core import COS, BlobStore, ScaleCache, VirtualClock
    clock = VirtualClock()
    cos = BlobStore(COS, clock)
    cache = ScaleCache(cos, clock, capacity_bytes=float(cap))
    for i in range(n):
        cos.blobs[f"b{i}"] = 1
        cache.read(f"b{i}")
    assert cache.used <= cap
