"""Fault-tolerant serving: injection at every seam, watchdog/guard
detection, and bitwise recompute-on-resume recovery.

The correctness bar is the one the recovery design is built around: a
recovered stream re-draws its discarded sample *at the same stream step*
(the per-request sampling fold keys on ``len(out_tokens)``), so after any
recoverable fault — non-finite logits out of the fused dispatch, a
poisoned KV page, a stalled prefill chunk, a transient dispatch error, a
whole failed chip — every stream that completes must be **bitwise
identical** to the same workload on a fault-free engine, for greedy and
seeded sampling alike, whole-prompt and chunked prefill alike.  Faults
that cannot be recovered degrade predictably: bounded retries dead-letter
the victim without perturbing its neighbours, and a wedged engine names
its wedged slots instead of returning silently."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.models import LM
from repro.serve import (CacheInvariantError, EngineStuckError, FaultEvent,
                         FaultPlan, PriorityClass, Request, SamplingParams,
                         ServeEngine, TenancyConfig, TenantSpec,
                         TransientDispatchError)


@pytest.fixture(scope="module")
def model():
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    return cfg, lm, lm.init(jax.random.key(0))


def _requests(cfg, n=6, max_new=6, seed=0, tenant=None):
    """Mixed sampling workload: even ids greedy, odd ids seeded top-p —
    both must survive recovery bitwise."""
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size,
                                    6 + (i % 5)).astype(np.int32),
                    max_new_tokens=max_new, tenant=tenant,
                    sampling=SamplingParams(
                        temperature=0.0 if i % 2 == 0 else 0.8, seed=i))
            for i in range(n)]


def _drain(eng, reqs, max_iters=2000):
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained(max_iters=max_iters)
    return {r.id: tuple(r.out_tokens) for r in done}


def _engine(lm, params, *, chunked=False, **kw):
    kw.setdefault("num_pages", 33)
    if chunked:
        kw.setdefault("prefill_chunk", 8)
    return ServeEngine(lm, params, max_batch=4, max_seq=64,
                       cache_backend="paged", page_size=4, **kw)


# --------------------------------------------- bitwise resume parity ----

@pytest.mark.parametrize("chunked", [False, True],
                         ids=["whole_prompt", "chunked"])
@pytest.mark.parametrize("kind", ["nan_logits", "poison_page"])
def test_recovered_streams_bitwise_identical(model, kind, chunked):
    """The tentpole assertion: inject a corruption fault mid-decode, let
    detection (the in-dispatch non-finite guard) and recovery (evict +
    re-queue + recompute-on-resume) run, and require every stream —
    including the recovered victim — bitwise equal to a fault-free run,
    across greedy and seeded sampling and both prefill modes."""
    cfg, lm, params = model
    base = _drain(_engine(lm, params, chunked=chunked), _requests(cfg))
    plan = FaultPlan([FaultEvent(2, kind), FaultEvent(6, kind)])
    eng = _engine(lm, params, chunked=chunked, fault_plan=plan,
                  watchdog_iters=16, verify_cache=True)
    out = _drain(eng, _requests(cfg))
    assert out == base
    assert eng.reg.counter("serve_faults_injected_total").get(
        {"kind": kind}) == 2
    assert eng.reg.counter("serve_stream_retries_total").get(
        {"reason": "nonfinite_logits"}) >= 1
    assert eng.reg.histogram("serve_recovery_iters").recent(10)
    assert eng.reg.gauge("serve_streams_quarantined").get() == 0
    eng.kv.verify()


def test_recovery_bitwise_under_tenancy(model):
    """Recovery composes with the multi-tenant scheduler: the re-queued
    victim keeps its tenant, re-admits under quota/priority, and still
    resumes bitwise."""
    cfg, lm, params = model

    def tenancy():
        return TenancyConfig(
            tenants=[TenantSpec("chat", "interactive"),
                     TenantSpec("bulk", "batch", page_quota=20)],
            classes={"interactive": PriorityClass("interactive", 100,
                                                  preemptible=False),
                     "batch": PriorityClass("batch", 0, preemptible=True)})

    def reqs():
        out = _requests(cfg)
        for r in out:
            r.tenant = "chat" if r.id % 2 else "bulk"
        return out

    base = _drain(_engine(lm, params, tenancy=tenancy()), reqs())
    plan = FaultPlan([FaultEvent(2, "nan_logits"),
                      FaultEvent(5, "poison_page")])
    eng = _engine(lm, params, tenancy=tenancy(), fault_plan=plan,
                  verify_cache=True)
    assert _drain(eng, reqs()) == base
    eng.kv.verify()


def test_stalled_chunk_recovered_by_watchdog_bitwise(model):
    """A prefill chunk stalled past the watchdog window (a stuck allocator
    grant) is detected by the per-stream progress watchdog and recovered;
    the resumed stream — and its untouched neighbours — stay bitwise."""
    cfg, lm, params = model
    base = _drain(_engine(lm, params, chunked=True), _requests(cfg))
    plan = FaultPlan([FaultEvent(1, "stall_chunk", duration=50)])
    eng = _engine(lm, params, chunked=True, fault_plan=plan,
                  watchdog_iters=6, verify_cache=True)
    assert _drain(eng, _requests(cfg)) == base
    assert eng.reg.counter("serve_stream_retries_total").get(
        {"reason": "watchdog"}) >= 1
    assert eng.reg.counter("serve_prefill_chunk_stalls_total").get() >= 1


def test_transient_dispatch_error_retried_bitwise(model):
    """A transient dispatch failure raises *before* the fused call touches
    its donated buffers, so the in-place retry is idempotent: the run
    completes bitwise with only the retry counter showing the hiccup."""
    cfg, lm, params = model
    base = _drain(_engine(lm, params), _requests(cfg))
    plan = FaultPlan([FaultEvent(3, "dispatch_error", duration=2)])
    eng = _engine(lm, params, fault_plan=plan)
    assert _drain(eng, _requests(cfg)) == base
    assert eng.reg.counter("serve_stream_retries_total").get(
        {"reason": "dispatch_error"}) == 2


def test_persistent_dispatch_error_is_engine_fatal(model):
    """``max_retries`` consecutive dispatch failures re-raise: a dead
    dispatch path is an engine outage, not a per-stream fault."""
    cfg, lm, params = model
    plan = FaultPlan([FaultEvent(2, "dispatch_error", duration=10)])
    eng = _engine(lm, params, fault_plan=plan, max_retries=2)
    for r in _requests(cfg, n=2):
        eng.submit(r)
    with pytest.raises(TransientDispatchError):
        eng.run_until_drained(max_iters=100)


# ----------------------------------------------- bounded retries ----

def test_retry_exhaustion_dead_letters_without_poisoning_neighbors(model):
    """A persistent per-stream fault (nan_logits re-firing on the same
    slot every time its victim resumes) exhausts the retry budget and
    dead-letters that one request — with the error surfaced on it — while
    its neighbour completes bitwise and the engine drains clean."""
    cfg, lm, params = model

    def reqs():
        return _requests(cfg, n=2)

    base = _drain(ServeEngine(lm, params, max_batch=2, max_seq=64,
                              cache_backend="paged", page_size=4,
                              num_pages=33), reqs())
    plan = FaultPlan([FaultEvent(1, "nan_logits", slot=0),
                      FaultEvent(3, "nan_logits", slot=0)])
    eng = ServeEngine(lm, params, max_batch=2, max_seq=64,
                      cache_backend="paged", page_size=4, num_pages=33,
                      fault_plan=plan, max_retries=1, verify_cache=True)
    for r in reqs():
        eng.submit(r)
    done = {r.id: r for r in eng.run_until_drained(max_iters=2000)}
    assert done[0].status == "dead_letter"
    assert done[0].retries == 2
    assert "dead-lettered" in done[0].error
    assert "nonfinite_logits" in done[0].error
    assert done[1].status == "completed"
    assert tuple(done[1].out_tokens) == base[1]
    assert eng.reg.counter("serve_dead_letter_total").get(
        {"reason": "nonfinite_logits"}) == 1
    assert eng.reg.gauge("serve_streams_quarantined").get() == 0
    eng.kv.verify()


# ------------------------------------------------- chip failure ----

def test_chip_failure_drains_victims_and_resumes_bitwise(model):
    """One chip of a 2-chip page pool fails mid-flight: capacity degrades
    to the surviving chip's pages, only streams holding pages there are
    recovered, and every completed stream matches the 2-chip clean run
    bitwise."""
    cfg, lm, params = model

    def engine(**kw):
        return ServeEngine(lm, params, max_batch=4, max_seq=64,
                           cache_backend="paged", page_size=4,
                           num_pages=24, locality_chips=2, **kw)

    base = _drain(engine(), _requests(cfg, n=8))
    plan = FaultPlan([FaultEvent(3, "chip_failure", chip=1)])
    eng = engine(fault_plan=plan, watchdog_iters=16, verify_cache=True)
    for r in _requests(cfg, n=8):
        eng.submit(r)
    done = eng.run_until_drained(max_iters=2000)
    victims = eng.reg.counter("serve_stream_retries_total").get(
        {"reason": "chip_failure"})
    assert victims >= 1
    assert eng.reg.counter("serve_faults_injected_total").get(
        {"kind": "chip_failure"}) == 1
    for r in done:
        if r.status == "completed":
            assert tuple(r.out_tokens) == base[r.id]
    # the surviving pool: chip 0's pages minus the scratch page
    assert eng.kv.usable_pages() == eng.kv.pages_per_chip - 1
    assert eng.kv.memory_stats().chips_failed == 1
    eng.kv.verify()


def test_chip_failure_dead_letters_unservable_footprints(model):
    """After the failure, a queued request whose footprint can never fit
    the degraded pool dead-letters immediately (reason ``capacity_lost``)
    instead of deferring forever."""
    cfg, lm, params = model
    eng = ServeEngine(lm, params, max_batch=2, max_seq=64,
                      cache_backend="paged", page_size=4, num_pages=12,
                      locality_chips=2,
                      fault_plan=FaultPlan([FaultEvent(2, "chip_failure",
                                                       chip=1)]))
    rng = np.random.default_rng(0)
    # footprint ceil((14+10)/4) = 6 pages > the 5 that survive chip 0
    big = Request(0, rng.integers(0, cfg.vocab_size, 14).astype(np.int32),
                  max_new_tokens=10)
    small = Request(1, rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                    max_new_tokens=4)
    third = Request(2, rng.integers(0, cfg.vocab_size, 14).astype(np.int32),
                    max_new_tokens=10)
    for r in (small, big, third):
        eng.submit(r)
    done = {r.id: r for r in eng.run_until_drained(max_iters=2000)}
    assert done[1].status == "completed"
    dead = [r for r in done.values() if r.status == "dead_letter"]
    assert dead and all("capacity_lost" in r.error for r in dead)
    assert eng.reg.counter("serve_dead_letter_total").get(
        {"reason": "capacity_lost"}) == len(dead)


# ---------------------------------------------------- random soak ----

def test_random_fault_soak_always_drains(model):
    """~200-step soak under a seeded random plan firing every recoverable
    kind, with requests trickling in mid-flight: the engine must drain,
    every request must reach a terminal status, and the pool sanitizer
    must come back clean."""
    cfg, lm, params = model
    rng = np.random.default_rng(11)
    arrivals = {}
    for i in range(16):
        arrivals.setdefault(int(rng.integers(0, 60)), []).append(
            Request(i, rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(2, 12))).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, 7)),
                    sampling=SamplingParams(
                        temperature=0.0 if i % 2 == 0 else 0.8, seed=i)))
    eng = _engine(lm, params, chunked=True,
                  fault_plan=FaultPlan.random(10, 150, seed=3),
                  watchdog_iters=16, max_retries=8, verify_cache=True)
    it = 0
    while it < 200 or eng.queue or any(r is not None for r in eng.slot_req):
        for r in arrivals.get(it, []):
            eng.submit(r)
        eng.step()
        it += 1
        assert it < 1000, "fault soak did not drain"
    assert len(eng.finished) == 16
    assert all(r.status in ("completed", "dead_letter")
               for r in eng.finished)
    injected = sum(v for _, v in eng.reg.counter(
        "serve_faults_injected_total").labels_values())
    assert injected >= 1
    assert eng.reg.gauge("serve_streams_quarantined").get() == 0
    eng.kv.verify()


# --------------------------------------------- stuck-stream surfacing ----

def test_run_until_drained_raises_naming_wedged_slots(model):
    """Exhausting ``max_iters`` with work in flight is an error, not a
    silent return: the raise carries the wedged requests, each flagged
    ``stuck`` with its slot and last-progress iteration."""
    cfg, lm, params = model
    eng = _engine(lm, params)
    for r in _requests(cfg, n=2):
        eng.submit(r)
    with pytest.raises(EngineStuckError) as ei:
        eng.run_until_drained(max_iters=2)
    assert ei.value.stuck and all(r.status == "stuck"
                                  for r in ei.value.stuck)
    assert "slot" in ei.value.stuck[0].error
    assert "iteration" in ei.value.stuck[0].error


def test_run_until_drained_status_mode_returns_stuck_streams(model):
    """``on_stuck="status"`` reports instead of raising: the return value
    includes the wedged requests with their partial output intact."""
    cfg, lm, params = model
    eng = _engine(lm, params)
    for r in _requests(cfg, n=2):
        eng.submit(r)
    done = eng.run_until_drained(max_iters=3, on_stuck="status")
    stuck = [r for r in done if r.status == "stuck"]
    assert stuck and all(r.error for r in stuck)


# ----------------------------------------------- sanitizer + plan API ----

def test_verify_detects_corrupted_bookkeeping(model):
    """The sanitizer actually bites: hand-corrupt the allocator state and
    ``verify()`` must raise ``CacheInvariantError`` naming the drift."""
    cfg, lm, params = model
    kv = lm.init_cache(2, 32, dtype=jnp.float32, backend="paged",
                       page_size=4, num_pages=9)
    prompt = np.arange(5, dtype=np.int32)
    assert kv.alloc(0, 8, prefix=prompt) == 0
    kv.verify()
    kv._ref[kv._slot_pages[0][0]] += 1
    with pytest.raises(CacheInvariantError, match="refcounts"):
        kv.verify()


def test_fault_plan_parse_round_trip():
    plan = FaultPlan.parse("nan_logits@5,poison_page@9:slot=2,"
                           "chip_failure@12:chip=1,"
                           "stall_chunk@3:slot=0:dur=8,"
                           "dispatch_error@7:dur=2")
    assert [(e.kind, e.iteration) for e in plan.events] == [
        ("stall_chunk", 3), ("nan_logits", 5), ("dispatch_error", 7),
        ("poison_page", 9), ("chip_failure", 12)]
    assert plan.events_at(3)[0].duration == 8
    assert plan.events_at(9)[0].slot == 2
    assert plan.events_at(12)[0].chip == 1
    for bad in ("typo_kind@3", "nan_logits", "nan_logits@2:bogus=1"):
        with pytest.raises(ValueError):
            FaultPlan.parse(bad)
    with pytest.raises(ValueError):
        FaultEvent(-1, "nan_logits")
    with pytest.raises(ValueError):
        FaultEvent(1, "nan_logits", duration=0)


def test_paged_only_fault_kinds_rejected_on_contiguous(model):
    """Plans with page/chip-level kinds cannot target the contiguous
    backend — rejected at construction, not at fire time."""
    cfg, lm, params = model
    plan = FaultPlan([FaultEvent(1, "poison_page")])
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(lm, params, max_batch=2, max_seq=64,
                    cache_backend="contiguous", fault_plan=plan)


def test_nan_guard_recovers_on_contiguous_backend(model):
    """The dispatch guard and recompute-on-resume don't depend on paging:
    nan_logits recovery holds bitwise on the contiguous backend too."""
    cfg, lm, params = model

    def engine(**kw):
        return ServeEngine(lm, params, max_batch=4, max_seq=64,
                           cache_backend="contiguous", **kw)

    base = _drain(engine(), _requests(cfg))
    eng = engine(fault_plan=FaultPlan([FaultEvent(2, "nan_logits")]))
    assert _drain(eng, _requests(cfg)) == base
    assert eng.reg.counter("serve_stream_retries_total").get(
        {"reason": "nonfinite_logits"}) >= 1
