"""Fault-tolerant serving: injection at every seam, watchdog/guard
detection, and bitwise recompute-on-resume recovery.

The correctness bar is the one the recovery design is built around: a
recovered stream re-draws its discarded sample *at the same stream step*
(the per-request sampling fold keys on ``len(out_tokens)``), so after any
recoverable fault — non-finite logits out of the fused dispatch, a
poisoned KV page, a stalled prefill chunk, a transient dispatch error, a
whole failed chip — every stream that completes must be **bitwise
identical** to the same workload on a fault-free engine, for greedy and
seeded sampling alike, whole-prompt and chunked prefill alike.  Faults
that cannot be recovered degrade predictably: bounded retries dead-letter
the victim without perturbing its neighbours, and a wedged engine names
its wedged slots instead of returning silently."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.models import LM
from repro.serve import (CacheInvariantError, EngineStuckError, FaultEvent,
                         FaultPlan, PrefixStore, PriorityClass, Request,
                         SamplingParams, ServeEngine, TenancyConfig,
                         TenantSpec, TransientDispatchError)


@pytest.fixture(scope="module")
def model():
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    return cfg, lm, lm.init(jax.random.key(0))


def _requests(cfg, n=6, max_new=6, seed=0, tenant=None):
    """Mixed sampling workload: even ids greedy, odd ids seeded top-p —
    both must survive recovery bitwise."""
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size,
                                    6 + (i % 5)).astype(np.int32),
                    max_new_tokens=max_new, tenant=tenant,
                    sampling=SamplingParams(
                        temperature=0.0 if i % 2 == 0 else 0.8, seed=i))
            for i in range(n)]


def _drain(eng, reqs, max_iters=2000):
    for r in reqs:
        eng.submit(r)
    done = eng.run_until_drained(max_iters=max_iters)
    return {r.id: tuple(r.out_tokens) for r in done}


def _engine(lm, params, *, chunked=False, **kw):
    kw.setdefault("num_pages", 33)
    if chunked:
        kw.setdefault("prefill_chunk", 8)
    return ServeEngine(lm, params, max_batch=4, max_seq=64,
                       cache_backend="paged", page_size=4, **kw)


# --------------------------------------------- bitwise resume parity ----

@pytest.mark.parametrize("chunked", [False, True],
                         ids=["whole_prompt", "chunked"])
@pytest.mark.parametrize("kind", ["nan_logits", "poison_page"])
def test_recovered_streams_bitwise_identical(model, kind, chunked):
    """The tentpole assertion: inject a corruption fault mid-decode, let
    detection (the in-dispatch non-finite guard) and recovery (evict +
    re-queue + recompute-on-resume) run, and require every stream —
    including the recovered victim — bitwise equal to a fault-free run,
    across greedy and seeded sampling and both prefill modes."""
    cfg, lm, params = model
    base = _drain(_engine(lm, params, chunked=chunked), _requests(cfg))
    plan = FaultPlan([FaultEvent(2, kind), FaultEvent(6, kind)])
    eng = _engine(lm, params, chunked=chunked, fault_plan=plan,
                  watchdog_iters=16, verify_cache=True)
    out = _drain(eng, _requests(cfg))
    assert out == base
    assert eng.reg.counter("serve_faults_injected_total").get(
        {"kind": kind}) == 2
    assert eng.reg.counter("serve_stream_retries_total").get(
        {"reason": "nonfinite_logits"}) >= 1
    assert eng.reg.histogram("serve_recovery_iters").recent(10)
    assert eng.reg.gauge("serve_streams_quarantined").get() == 0
    eng.kv.verify()


def test_recovery_bitwise_under_tenancy(model):
    """Recovery composes with the multi-tenant scheduler: the re-queued
    victim keeps its tenant, re-admits under quota/priority, and still
    resumes bitwise."""
    cfg, lm, params = model

    def tenancy():
        return TenancyConfig(
            tenants=[TenantSpec("chat", "interactive"),
                     TenantSpec("bulk", "batch", page_quota=20)],
            classes={"interactive": PriorityClass("interactive", 100,
                                                  preemptible=False),
                     "batch": PriorityClass("batch", 0, preemptible=True)})

    def reqs():
        out = _requests(cfg)
        for r in out:
            r.tenant = "chat" if r.id % 2 else "bulk"
        return out

    base = _drain(_engine(lm, params, tenancy=tenancy()), reqs())
    plan = FaultPlan([FaultEvent(2, "nan_logits"),
                      FaultEvent(5, "poison_page")])
    eng = _engine(lm, params, tenancy=tenancy(), fault_plan=plan,
                  verify_cache=True)
    assert _drain(eng, reqs()) == base
    eng.kv.verify()


def test_stalled_chunk_recovered_by_watchdog_bitwise(model):
    """A prefill chunk stalled past the watchdog window (a stuck allocator
    grant) is detected by the per-stream progress watchdog and recovered;
    the resumed stream — and its untouched neighbours — stay bitwise."""
    cfg, lm, params = model
    base = _drain(_engine(lm, params, chunked=True), _requests(cfg))
    plan = FaultPlan([FaultEvent(1, "stall_chunk", duration=50)])
    eng = _engine(lm, params, chunked=True, fault_plan=plan,
                  watchdog_iters=6, verify_cache=True)
    assert _drain(eng, _requests(cfg)) == base
    assert eng.reg.counter("serve_stream_retries_total").get(
        {"reason": "watchdog"}) >= 1
    assert eng.reg.counter("serve_prefill_chunk_stalls_total").get() >= 1


def test_transient_dispatch_error_retried_bitwise(model):
    """A transient dispatch failure raises *before* the fused call touches
    its donated buffers, so the in-place retry is idempotent: the run
    completes bitwise with only the retry counter showing the hiccup."""
    cfg, lm, params = model
    base = _drain(_engine(lm, params), _requests(cfg))
    plan = FaultPlan([FaultEvent(3, "dispatch_error", duration=2)])
    eng = _engine(lm, params, fault_plan=plan)
    assert _drain(eng, _requests(cfg)) == base
    assert eng.reg.counter("serve_stream_retries_total").get(
        {"reason": "dispatch_error"}) == 2


def test_persistent_dispatch_error_is_engine_fatal(model):
    """``max_retries`` consecutive dispatch failures re-raise: a dead
    dispatch path is an engine outage, not a per-stream fault."""
    cfg, lm, params = model
    plan = FaultPlan([FaultEvent(2, "dispatch_error", duration=10)])
    eng = _engine(lm, params, fault_plan=plan, max_retries=2)
    for r in _requests(cfg, n=2):
        eng.submit(r)
    with pytest.raises(TransientDispatchError):
        eng.run_until_drained(max_iters=100)


# ----------------------------------------------- bounded retries ----

def test_retry_exhaustion_dead_letters_without_poisoning_neighbors(model):
    """A persistent per-stream fault (nan_logits re-firing on the same
    slot every time its victim resumes) exhausts the retry budget and
    dead-letters that one request — with the error surfaced on it — while
    its neighbour completes bitwise and the engine drains clean."""
    cfg, lm, params = model

    def reqs():
        return _requests(cfg, n=2)

    base = _drain(ServeEngine(lm, params, max_batch=2, max_seq=64,
                              cache_backend="paged", page_size=4,
                              num_pages=33), reqs())
    plan = FaultPlan([FaultEvent(1, "nan_logits", slot=0),
                      FaultEvent(3, "nan_logits", slot=0)])
    eng = ServeEngine(lm, params, max_batch=2, max_seq=64,
                      cache_backend="paged", page_size=4, num_pages=33,
                      fault_plan=plan, max_retries=1, verify_cache=True)
    for r in reqs():
        eng.submit(r)
    done = {r.id: r for r in eng.run_until_drained(max_iters=2000)}
    assert done[0].status == "dead_letter"
    assert done[0].retries == 2
    assert "dead-lettered" in done[0].error
    assert "nonfinite_logits" in done[0].error
    assert done[1].status == "completed"
    assert tuple(done[1].out_tokens) == base[1]
    assert eng.reg.counter("serve_dead_letter_total").get(
        {"reason": "nonfinite_logits"}) == 1
    assert eng.reg.gauge("serve_streams_quarantined").get() == 0
    eng.kv.verify()


# ------------------------------------------------- chip failure ----

def test_chip_failure_drains_victims_and_resumes_bitwise(model):
    """One chip of a 2-chip page pool fails mid-flight: capacity degrades
    to the surviving chip's pages, only streams holding pages there are
    recovered, and every completed stream matches the 2-chip clean run
    bitwise."""
    cfg, lm, params = model

    def engine(**kw):
        return ServeEngine(lm, params, max_batch=4, max_seq=64,
                           cache_backend="paged", page_size=4,
                           num_pages=24, locality_chips=2, **kw)

    base = _drain(engine(), _requests(cfg, n=8))
    plan = FaultPlan([FaultEvent(3, "chip_failure", chip=1)])
    eng = engine(fault_plan=plan, watchdog_iters=16, verify_cache=True)
    for r in _requests(cfg, n=8):
        eng.submit(r)
    done = eng.run_until_drained(max_iters=2000)
    victims = eng.reg.counter("serve_stream_retries_total").get(
        {"reason": "chip_failure"})
    assert victims >= 1
    assert eng.reg.counter("serve_faults_injected_total").get(
        {"kind": "chip_failure"}) == 1
    for r in done:
        if r.status == "completed":
            assert tuple(r.out_tokens) == base[r.id]
    # the surviving pool: chip 0's pages minus the scratch page
    assert eng.kv.usable_pages() == eng.kv.pages_per_chip - 1
    assert eng.kv.memory_stats().chips_failed == 1
    eng.kv.verify()


def test_chip_failure_dead_letters_unservable_footprints(model):
    """After the failure, a queued request whose footprint can never fit
    the degraded pool dead-letters immediately (reason ``capacity_lost``)
    instead of deferring forever."""
    cfg, lm, params = model
    eng = ServeEngine(lm, params, max_batch=2, max_seq=64,
                      cache_backend="paged", page_size=4, num_pages=12,
                      locality_chips=2,
                      fault_plan=FaultPlan([FaultEvent(2, "chip_failure",
                                                       chip=1)]))
    rng = np.random.default_rng(0)
    # footprint ceil((14+10)/4) = 6 pages > the 5 that survive chip 0
    big = Request(0, rng.integers(0, cfg.vocab_size, 14).astype(np.int32),
                  max_new_tokens=10)
    small = Request(1, rng.integers(0, cfg.vocab_size, 4).astype(np.int32),
                    max_new_tokens=4)
    third = Request(2, rng.integers(0, cfg.vocab_size, 14).astype(np.int32),
                    max_new_tokens=10)
    for r in (small, big, third):
        eng.submit(r)
    done = {r.id: r for r in eng.run_until_drained(max_iters=2000)}
    assert done[1].status == "completed"
    dead = [r for r in done.values() if r.status == "dead_letter"]
    assert dead and all("capacity_lost" in r.error for r in dead)
    assert eng.reg.counter("serve_dead_letter_total").get(
        {"reason": "capacity_lost"}) == len(dead)


# ---------------------------------------------------- random soak ----

def test_random_fault_soak_always_drains(model):
    """~200-step soak under a seeded random plan firing every recoverable
    kind, with requests trickling in mid-flight: the engine must drain,
    every request must reach a terminal status, and the pool sanitizer
    must come back clean."""
    cfg, lm, params = model
    rng = np.random.default_rng(11)
    arrivals = {}
    for i in range(16):
        arrivals.setdefault(int(rng.integers(0, 60)), []).append(
            Request(i, rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(2, 12))).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, 7)),
                    sampling=SamplingParams(
                        temperature=0.0 if i % 2 == 0 else 0.8, seed=i)))
    eng = _engine(lm, params, chunked=True,
                  fault_plan=FaultPlan.random(10, 150, seed=3),
                  watchdog_iters=16, max_retries=8, verify_cache=True)
    it = 0
    while it < 200 or eng.queue or any(r is not None for r in eng.slot_req):
        for r in arrivals.get(it, []):
            eng.submit(r)
        eng.step()
        it += 1
        assert it < 1000, "fault soak did not drain"
    assert len(eng.finished) == 16
    assert all(r.status in ("completed", "dead_letter")
               for r in eng.finished)
    injected = sum(v for _, v in eng.reg.counter(
        "serve_faults_injected_total").labels_values())
    assert injected >= 1
    assert eng.reg.gauge("serve_streams_quarantined").get() == 0
    eng.kv.verify()


# ------------------------------------------------- host-tier faults ----

def test_host_poisoned_page_quarantined_at_prefetch(model):
    """Corruption in the warm tier surfaces as recompute, never as a
    poisoned stream: poison a host-resident prefix page, and the next
    hash-hitting admission's prefetch finite-check catches it *before*
    the page is registered as landed — the entry is quarantined, the
    prefix recomputes, and the stream stays bitwise identical to a
    tier-less engine."""
    cfg, lm, params = model
    prefix = np.arange(100, 108, dtype=np.int32)    # 2 full pages @ page=4

    def reqs(ids):
        return [Request(i, np.concatenate(
                    [prefix, np.asarray([(i * 7 + 3) % cfg.vocab_size],
                                        np.int32)]),
                        max_new_tokens=5) for i in ids]

    base = _drain(_engine(lm, params), reqs(range(4)))
    eng = _engine(lm, params, host_pages=16, verify_cache=True)
    # wave 1: sharers complete and free -> prefix pages offload to host
    out1 = _drain(eng, reqs([0, 1]))
    assert out1[0] == base[0] and out1[1] == base[1]
    eng.kv.drain_offloads()
    keys = [eng.kv._key(prefix, i) for i in range(2)]
    assert all(eng.kv.store.has(k) for k in keys)
    for k in keys:
        assert eng.kv.store.poison(k)
    # wave 2: the hash hit prefetches, trips the finite check, quarantines
    out2 = _drain(eng, reqs([2, 3]))
    assert out2[2] == base[2] and out2[3] == base[3]
    stats = eng.kv.store.stats()
    assert stats["poisoned"] >= 1
    # the poisoned entry was dropped; the wave-2 recompute re-offloaded
    # the prefix, so the key is resident again — with clean bytes
    got = eng.kv.store.lookup(keys[0])
    assert got is not None
    assert all(np.isfinite(np.asarray(v, np.float32)).all()
               for v in got.values()
               if np.issubdtype(v.dtype, np.floating))
    assert eng.reg.gauge("serve_streams_quarantined").get() == 0
    eng.kv.verify()


def test_prefix_store_digest_collision_is_miss_never_crosstalk(model):
    """The store indexes by a short digest but verifies the full prefix
    key on every lookup: force *every* digest to collide and the store
    must degrade to misses/replacements — another prefix's KV bytes are
    never served — while engine streams stay bitwise clean."""
    cfg, lm, params = model

    class CollidingStore(PrefixStore):
        def _digest(self, key):
            return b"\x00"                    # all keys collide

    # unit pin: collision on lookup is a miss, on put a replacement
    store = CollidingStore(8)
    store.bind({"x": ((2,), np.float32)})
    a, b = b"prefix-a", b"prefix-b"
    store.put(a, {"x": np.ones(2, np.float32)})
    assert store.lookup(b) is None            # full-key mismatch: miss
    assert store.stats()["collisions"] == 1
    store.put(b, {"x": np.full(2, 2.0, np.float32)})   # replaces a
    assert store.lookup(a) is None
    got = store.lookup(b)
    np.testing.assert_array_equal(got["x"], np.full(2, 2.0, np.float32))
    store.verify()

    # engine pin: a fully-colliding store never changes any stream
    def reqs():
        out = _requests(cfg)
        for r in out:       # two recurring prefixes so offloads collide
            r.prompt = np.concatenate(
                [np.arange(8, dtype=np.int32) + (r.id % 2) * 50,
                 r.prompt[:2]])
        return out

    base = _drain(_engine(lm, params), reqs())
    eng = _engine(lm, params, prefix_store=CollidingStore(16),
                  verify_cache=True)
    assert _drain(eng, reqs()) == base
    assert eng.kv.store.stats()["collisions"] >= 1
    assert eng.kv.store.pages_in_use() <= 1   # one digest -> one entry
    eng.kv.verify()


def test_evict_while_shared_never_offloads_live_pages(model):
    """The evict-while-shared race: preempting one sharer of a prefix
    while another still decodes from it must NOT offload the pages (their
    refcount is still positive — offload of a live page would let the
    host copy go stale).  Offload happens only when the *last* sharer
    frees; a later admission then prefetches the pages back."""
    cfg, lm, params = model
    kv = lm.init_cache(4, 32, dtype=jnp.float32, backend="paged",
                       page_size=4, num_pages=16, host_pages=8)
    prompt = np.arange(8, dtype=np.int32)
    _, _, pc = lm.forward(params, {"tokens": jnp.asarray(prompt[None])},
                          collect_cache=True)
    assert kv.alloc(0, 12, prefix=prompt) == 0
    kv.write_prefill(0, pc["layers"])
    assert kv.alloc(1, 12, prefix=prompt) == 8       # shares both pages
    shared = list(kv._slot_pages[1][:2])
    kv.evict(0)                     # preemption while slot 1 still shares
    kv.drain_offloads()
    assert kv.store.pages_in_use() == 0, \
        "evicting one sharer offloaded pages another slot still reads"
    assert all(kv._ref[p] == 1 for p in shared)
    assert all(p in kv._page_to_hash for p in shared)   # still registered
    kv.verify()
    kv.free(1)                      # last reference: NOW they offload
    kv.drain_offloads()
    assert kv.store.pages_in_use() == 2
    assert kv.store.stats()["offloads"] == 2
    assert kv.alloc(2, 12, prefix=prompt) == 8       # host prefetch hit
    assert kv.store.stats()["hits"] == 2
    kv.verify()


# --------------------------------------------- stuck-stream surfacing ----

def test_run_until_drained_raises_naming_wedged_slots(model):
    """Exhausting ``max_iters`` with work in flight is an error, not a
    silent return: the raise carries the wedged requests, each flagged
    ``stuck`` with its slot and last-progress iteration."""
    cfg, lm, params = model
    eng = _engine(lm, params)
    for r in _requests(cfg, n=2):
        eng.submit(r)
    with pytest.raises(EngineStuckError) as ei:
        eng.run_until_drained(max_iters=2)
    assert ei.value.stuck and all(r.status == "stuck"
                                  for r in ei.value.stuck)
    assert "slot" in ei.value.stuck[0].error
    assert "iteration" in ei.value.stuck[0].error


def test_run_until_drained_status_mode_returns_stuck_streams(model):
    """``on_stuck="status"`` reports instead of raising: the return value
    includes the wedged requests with their partial output intact."""
    cfg, lm, params = model
    eng = _engine(lm, params)
    for r in _requests(cfg, n=2):
        eng.submit(r)
    done = eng.run_until_drained(max_iters=3, on_stuck="status")
    stuck = [r for r in done if r.status == "stuck"]
    assert stuck and all(r.error for r in stuck)


# ----------------------------------------------- sanitizer + plan API ----

def test_verify_detects_corrupted_bookkeeping(model):
    """The sanitizer actually bites: hand-corrupt the allocator state and
    ``verify()`` must raise ``CacheInvariantError`` naming the drift."""
    cfg, lm, params = model
    kv = lm.init_cache(2, 32, dtype=jnp.float32, backend="paged",
                       page_size=4, num_pages=9)
    prompt = np.arange(5, dtype=np.int32)
    assert kv.alloc(0, 8, prefix=prompt) == 0
    kv.verify()
    kv._ref[kv._slot_pages[0][0]] += 1
    with pytest.raises(CacheInvariantError, match="refcounts"):
        kv.verify()


def test_fault_plan_parse_round_trip():
    plan = FaultPlan.parse("nan_logits@5,poison_page@9:slot=2,"
                           "chip_failure@12:chip=1,"
                           "stall_chunk@3:slot=0:dur=8,"
                           "dispatch_error@7:dur=2")
    assert [(e.kind, e.iteration) for e in plan.events] == [
        ("stall_chunk", 3), ("nan_logits", 5), ("dispatch_error", 7),
        ("poison_page", 9), ("chip_failure", 12)]
    assert plan.events_at(3)[0].duration == 8
    assert plan.events_at(9)[0].slot == 2
    assert plan.events_at(12)[0].chip == 1
    for bad in ("typo_kind@3", "nan_logits", "nan_logits@2:bogus=1"):
        with pytest.raises(ValueError):
            FaultPlan.parse(bad)
    with pytest.raises(ValueError):
        FaultEvent(-1, "nan_logits")
    with pytest.raises(ValueError):
        FaultEvent(1, "nan_logits", duration=0)


def test_paged_only_fault_kinds_rejected_on_contiguous(model):
    """Plans with page/chip-level kinds cannot target the contiguous
    backend — rejected at construction, not at fire time."""
    cfg, lm, params = model
    plan = FaultPlan([FaultEvent(1, "poison_page")])
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(lm, params, max_batch=2, max_seq=64,
                    cache_backend="contiguous", fault_plan=plan)


def test_nan_guard_recovers_on_contiguous_backend(model):
    """The dispatch guard and recompute-on-resume don't depend on paging:
    nan_logits recovery holds bitwise on the contiguous backend too."""
    cfg, lm, params = model

    def engine(**kw):
        return ServeEngine(lm, params, max_batch=4, max_seq=64,
                           cache_backend="contiguous", **kw)

    base = _drain(engine(), _requests(cfg))
    eng = engine(fault_plan=FaultPlan([FaultEvent(2, "nan_logits")]))
    assert _drain(eng, _requests(cfg)) == base
    assert eng.reg.counter("serve_stream_retries_total").get(
        {"reason": "nonfinite_logits"}) >= 1
