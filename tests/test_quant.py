"""Shared int8 quantization module (``repro.kernels.quant``): the per-tensor
error-feedback path the compressed gradient sync uses, and the per-row KV-page
path the int8 paged cache uses.

The documented contract under test: symmetric absmax quantization with
``scale = max(absmax, 1e-12) / 127`` keeps every element's round-trip error
within ``scale / 2 = max(absmax, 1e-12) / 254``, and all-zero (or denormal)
rows reproduce exactly.  ``tests/test_kvcache.py`` / ``test_paged_decode.py``
check the same bound end-to-end through the cache and kernels; this file
checks it at the source."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.quant import (dequantize, dequantize_kv, quantize_int8,
                                 quantize_kv)

try:                    # optional dev dependency (requirements-dev.txt): the
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True   # numpy sweeps below keep coverage without it
except ImportError:
    HAVE_HYPOTHESIS = False


def _row_bound(x):
    """Per-row error bound: scale/2 with the absmax floor, plus fp32 slack."""
    absmax = np.max(np.abs(x), axis=-1, keepdims=True)
    return np.maximum(absmax, 1e-12) / 254 * (1 + 1e-5) + 1e-30


# ---------------------------------------------------------- per-row (KV) ----

def test_quantize_kv_shapes_and_dtypes():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(3, 5, 2, 8)),
                    jnp.float32)
    q, s = quantize_kv(x)
    assert q.shape == x.shape and q.dtype == jnp.int8
    assert s.shape == x.shape[:-1] and s.dtype == jnp.float32
    y = dequantize_kv(q, s)
    assert y.shape == x.shape and y.dtype == jnp.float32
    assert dequantize_kv(q, s, jnp.bfloat16).dtype == jnp.bfloat16


def test_quantize_kv_roundtrip_error_bound_random_sweep():
    """Seeded sweep over magnitudes spanning 1e-30..1e4 (mixed per row):
    every element round-trips within the documented absmax/254 row bound."""
    rng = np.random.default_rng(1)
    for trial in range(20):
        shape = tuple(rng.integers(1, 6, size=int(rng.integers(2, 5)))) + \
            (int(rng.integers(1, 33)),)
        mag = 10.0 ** rng.uniform(-30, 4, size=shape[:-1] + (1,))
        x = (rng.normal(size=shape) * mag).astype(np.float32)
        q, s = quantize_kv(jnp.asarray(x))
        err = np.abs(np.asarray(dequantize_kv(q, s)) - x)
        assert (err <= _row_bound(x)).all(), (trial, shape, err.max())


def test_quantize_kv_zero_and_denormal_rows_exact():
    """All-zero rows and denormal rows (absmax under the 1e-12 floor) decode
    to values within scale/2 of the input — for zeros, exactly zero; the
    floor keeps the scale finite so nothing NaNs or explodes."""
    x = np.zeros((4, 3, 8), np.float32)
    x[1] = 1e-40                                    # denormal row
    x[2] = np.float32(1e-13)                        # under the floor
    x[3, :, 0] = 5.0                                # one normal row for scale
    q, s = quantize_kv(jnp.asarray(x))
    y = np.asarray(dequantize_kv(q, s))
    assert np.isfinite(y).all() and np.isfinite(np.asarray(s)).all()
    np.testing.assert_array_equal(y[0], 0.0)        # zeros exact
    err = np.abs(y - x)
    assert (err <= _row_bound(x)).all()
    # denormal rows: the floored scale bounds error at ~4e-15 absolute
    assert err[1].max() <= 1e-12 / 254 * 1.01 + 1e-40


def test_quantize_kv_single_row_write_is_self_contained():
    """The decode-step property the per-ROW scale layout exists for: one new
    token's (KV, D) slice quantizes alone to exactly what it quantizes to
    inside a full page — no neighboring row can perturb its scale."""
    rng = np.random.default_rng(2)
    page = rng.normal(size=(8, 2, 16)).astype(np.float32) * 3
    q_full, s_full = quantize_kv(jnp.asarray(page))
    q_row, s_row = quantize_kv(jnp.asarray(page[5]))
    np.testing.assert_array_equal(np.asarray(q_full)[5], np.asarray(q_row))
    np.testing.assert_array_equal(np.asarray(s_full)[5], np.asarray(s_row))


# ------------------------------------------- per-tensor (gradient sync) ----

def test_quantize_int8_roundtrip_and_error_feedback():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(64,)).astype(np.float32)
    q, scale, err = quantize_int8(jnp.asarray(x))
    assert q.dtype == jnp.int8 and np.ndim(scale) == 0
    y = np.asarray(dequantize(q, scale))
    # error feedback is exactly the round-trip residual: y + err == x
    np.testing.assert_allclose(y + np.asarray(err), x, rtol=1e-6, atol=1e-7)
    assert np.abs(y - x).max() <= np.abs(x).max() / 254 * (1 + 1e-5)
    # carrying the residual into the next step cancels systematic bias
    q2, scale2, _ = quantize_int8(jnp.asarray(x), seed_err=err)
    y2 = np.asarray(dequantize(q2, scale2))
    assert np.abs((y + y2) - 2 * x).max() <= np.abs(x).max() / 254 * 1.01


def test_compression_module_reexports_shared_quant():
    """The gradient-compression path must be the *same* functions — factoring
    them into repro.kernels.quant must not fork the math."""
    from repro.parallel import compression
    assert compression.quantize_int8 is quantize_int8
    assert compression.dequantize is dequantize


# ------------------------------------------------------------ hypothesis ----

if HAVE_HYPOTHESIS:
    finite = st.floats(min_value=-1e6, max_value=1e6, width=32,
                       allow_nan=False, allow_infinity=False)
    tiny = st.floats(min_value=-1e-12, max_value=1e-12, width=32,
                     allow_nan=False, allow_infinity=False)

    @given(rows=st.lists(
        st.lists(st.one_of(finite, tiny, st.just(0.0)),
                 min_size=1, max_size=16),
        min_size=1, max_size=8).filter(
            lambda r: len({len(row) for row in r}) == 1))
    @settings(max_examples=200, deadline=None)
    def test_quantize_kv_error_bound_property(rows):
        """For any finite fp32 page — including all-zero, denormal, and
        mixed-magnitude rows — |dequant(quant(x)) - x| <= absmax(row)/254
        (with the 1e-12 absmax floor), elementwise."""
        x = np.asarray(rows, np.float32)
        q, s = quantize_kv(jnp.asarray(x))
        y = np.asarray(dequantize_kv(q, s))
        assert np.isfinite(y).all()
        assert (np.abs(y - x) <= _row_bound(x)).all()
        zero_rows = (x == 0).all(axis=-1)
        assert (y[zero_rows] == 0).all()
else:                                       # pragma: no cover
    def test_quantize_kv_error_bound_property():
        # importorskip (not a hard @skip): the test self-resurrects the
        # moment hypothesis lands, instead of staying skipped forever
        pytest.importorskip("hypothesis")
