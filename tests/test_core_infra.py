"""Core infrastructure behaviours: telemetry, alerts, health checks, cluster
failure model, scheduler gang semantics + buffer pool, straggler detection,
storage tiers, network model calibration."""
import numpy as np
import pytest

from repro.core import (COS, NFS, SCALE, AlertManager, Autopilot, BlobStore,
                        FailureKind, GangScheduler, Job, JobState,
                        MetricsRegistry, NodeState, ScaleCache, SimCluster,
                        SlackSink, StorageStack, StragglerDetector,
                        VirtualClock)
from repro.core import netmodel


# ------------------------------------------------------------- telemetry ----

def test_metrics_registry_counter_gauge_histogram():
    reg = MetricsRegistry()
    reg.counter("c").inc(2, {"a": "x"})
    reg.counter("c").inc(3, {"a": "x"})
    assert reg.counter("c").get({"a": "x"}) == 5
    reg.gauge("g").set(1.5)
    assert reg.gauge("g").get() == 1.5
    h = reg.histogram("h")
    for v in (0.1, 0.2, 0.3, 10.0):
        h.observe(v)
    assert h.count() == 4
    assert h.quantile(0.5) in (0.2, 0.3)
    text = reg.render()
    assert "# TYPE c counter" in text and 'a="x"' in text


# -------------------------------------------------------- cluster + health ----

def test_failure_injection_and_job_perf():
    cluster = SimCluster(8, seed=0)
    cluster.inject(3, FailureKind.POWER_BRAKE)
    assert cluster.nodes[3].state == NodeState.DEGRADED
    # power brake derates to 150/400 => whole job runs ~2.7x slower
    assert cluster.job_perf_factor(list(range(8))) == pytest.approx(0.375)
    cluster.inject(5, FailureKind.HOST_CRASH)
    assert cluster.crashed_in(list(range(8))) == [5]
    assert cluster.job_perf_factor(list(range(8))) == 0.0


def test_repair_cycle_heals_node():
    cluster = SimCluster(4, seed=0)
    cluster.inject(1, FailureKind.PCIE_DEGRADE)
    cluster.start_repair(1)
    assert cluster.nodes[1].state == NodeState.REPAIRING
    cluster.advance(1000.0)   # pcie repair = 900s VM reboot
    assert cluster.nodes[1].state == NodeState.HEALTHY
    assert cluster.nodes[1].perf_factor == 1.0


def test_autopilot_flags_degraded_nodes_and_alerts_fire():
    reg = MetricsRegistry()
    cluster = SimCluster(4, seed=0, registry=reg)
    ap = Autopilot(cluster, reg)
    cluster.inject(2, FailureKind.POWER_BRAKE)
    results = ap.run_checks()
    assert 2 in ap.err_nodes(results)
    sink = SlackSink()
    am = AlertManager(reg, sinks=[sink])
    fired = am.evaluate()
    assert any("node 2" in a.message for a in fired)
    assert sink.messages


def test_intrusive_checks_skip_busy_nodes():
    reg = MetricsRegistry()
    cluster = SimCluster(2, seed=0, registry=reg)
    ap = Autopilot(cluster, reg)
    res = ap.run_checks(busy=[0])
    names0 = {r.name for r in res if r.node_id == 0}
    names1 = {r.name for r in res if r.node_id == 1}
    assert "dcgm_level3_ok" not in names0
    assert "dcgm_level3_ok" in names1


# ------------------------------------------------------------- scheduler ----

def test_gang_scheduling_and_buffer_pool():
    cluster = SimCluster(20, seed=0)
    sched = GangScheduler(cluster, buffer_fraction=0.10)
    job = Job("j1", 16)
    sched.submit(job)
    assert job.state == JobState.RUNNING
    assert len(job.nodes) == 16
    # 20 - 16 = 4 free; buffer target = 2; a new 3-node job must queue
    j2 = Job("j2", 3)
    sched.submit(j2)
    assert j2.state == JobState.PENDING
    j3 = Job("j3", 2)
    sched.submit(j3)
    assert j3.state == JobState.RUNNING


def test_failure_requeues_and_restarts_from_buffer():
    cluster = SimCluster(20, seed=0)
    sched = GangScheduler(cluster, buffer_fraction=0.10)
    job = Job("j1", 18, rerunnable=True)
    sched.submit(job)
    victim = job.nodes[0]
    cluster.inject(victim, FailureKind.HOST_CRASH)
    sched.on_node_failure(victim)
    # restart allowed to dip into buffer: 19 healthy free >= 18
    assert job.state == JobState.RUNNING
    assert victim not in job.nodes
    assert job.restarts == 1


def test_non_rerunnable_job_fails():
    cluster = SimCluster(6, seed=0)
    sched = GangScheduler(cluster, buffer_fraction=0.0)
    job = Job("j1", 4, rerunnable=False)
    sched.submit(job)
    cluster.inject(job.nodes[0], FailureKind.CUDA_ERROR)
    sched.on_node_failure(job.nodes[0])
    assert job.state == JobState.FAILED


def test_straggler_swap_preserves_job_size():
    cluster = SimCluster(12, seed=0)
    sched = GangScheduler(cluster, buffer_fraction=0.15)
    job = Job("j1", 8)
    sched.submit(job)
    bad = job.nodes[3]
    cluster.inject(bad, FailureKind.POWER_BRAKE)
    assert sched.replace_degraded("j1", [bad])
    assert len(job.nodes) == 8
    assert bad not in job.nodes
    assert cluster.job_perf_factor(job.nodes) == 1.0


def test_elastic_resize():
    cluster = SimCluster(12, seed=0)
    sched = GangScheduler(cluster, buffer_fraction=0.0)
    job = Job("j1", 10)
    sched.submit(job)
    sched.elastic_resize("j1", 6)
    assert job.state == JobState.RUNNING
    assert len(job.nodes) == 6


# ------------------------------------------------------------- straggler ----

def test_straggler_detector_localizes_power_brake():
    reg = MetricsRegistry()
    cluster = SimCluster(8, seed=0, registry=reg)
    det = StragglerDetector(reg, factor=1.25)
    for _ in range(10):
        det.observe_step(5.0)
    cluster.inject(4, FailureKind.POWER_BRAKE)
    for _ in range(3):                # persistent ~13.3s: the 2.7x incident
        det.observe_step(5.0 / 0.375)
    rep = det.check(cluster, list(range(8)))
    assert rep.detected and rep.slowdown > 2.5
    assert rep.suspect_nodes == [4]
    assert "power_brake" in rep.reason


# --------------------------------------------------------------- storage ----

def test_scale_cache_hit_faster_than_miss():
    clock = VirtualClock()
    cos = BlobStore(COS, clock)
    cos.blobs["data"] = int(10e9)
    cache = ScaleCache(cos, clock, capacity_bytes=100e9)
    t_miss = cache.read("data")
    t_hit = cache.read("data")
    assert t_hit < t_miss / 3


def test_afm_writeback_does_not_gate_foreground():
    clock = VirtualClock()
    cos = BlobStore(COS, clock)
    cache = ScaleCache(cos, clock, capacity_bytes=1e12)
    t0 = clock.now()
    cache.write("ckpt", int(100e9))       # 100 GB checkpoint
    fg = clock.now() - t0
    assert fg < 100e9 / 10e9              # charged at Scale speed (15 GB/s)
    mover = cache.drain_async()
    assert clock.now() - t0 == pytest.approx(fg)   # foreground unaffected
    assert mover > fg                     # COS upload slower, in background
    assert not cache.dirty


def test_lru_eviction_only_clean_entries():
    clock = VirtualClock()
    cos = BlobStore(COS, clock)
    cache = ScaleCache(cos, clock, capacity_bytes=int(3e9))
    cache.write("dirty1", int(2e9))
    for i in range(3):
        cos.blobs[f"c{i}"] = int(1e9)
        cache.read(f"c{i}")
    assert "dirty1" in cache.lru          # dirty entry never evicted
    assert cache.used <= 2 * 3e9


def test_nfs_variance_exceeds_scale_variance():
    clock = VirtualClock()
    stack = StorageStack(clock)
    stack.cos.blobs["shard"] = int(1e9)
    stack.dataset_read("shard", "scale")   # warm the AFM cache (first miss)
    nfs_times, scale_times = [], []
    for _ in range(60):
        nfs_times.append(stack.dataset_read("shard", "nfs"))
        scale_times.append(stack.dataset_read("shard", "scale"))
    cv_nfs = np.std(nfs_times) / np.mean(nfs_times)
    cv_scale = np.std(scale_times) / np.mean(scale_times)
    assert cv_nfs > 3 * cv_scale          # paper: ~50% vs <10% variation
    assert np.mean(scale_times) < np.mean(nfs_times) / 5


# ---------------------------------------------------------- network model ----

def test_netmodel_reproduces_paper_ratios():
    # 8 MB @ 1024 GPUs: GDR ~10x TCP (paper Fig 3)
    r_small = (netmodel.alg_bandwidth(8e6, 1024, netmodel.GDR)
               / netmodel.alg_bandwidth(8e6, 1024, netmodel.TCP))
    assert 6 <= r_small <= 14
    # >= 500 MB: 3-5x
    r_big = (netmodel.alg_bandwidth(500e6, 1024, netmodel.GDR)
             / netmodel.alg_bandwidth(500e6, 1024, netmodel.TCP))
    assert 3 <= r_big <= 6
    # busbw saturates near protocol peaks at large messages
    assert netmodel.bus_bandwidth(2e9, 1024, netmodel.GDR) > 25e9
    assert netmodel.bus_bandwidth(2e9, 1024, netmodel.TCP) < 7e9


def test_netmodel_scales_with_gpu_count():
    # Fig 4: GDR busbw roughly flat from 32 to 1752 GPUs at large messages
    bws = [netmodel.bus_bandwidth(512e6, n, netmodel.GDR)
           for n in (32, 128, 512, 1752)]
    assert max(bws) / min(bws) < 1.6
    # and latency-bound small messages DO degrade with scale (also Fig 4)
    small = [netmodel.bus_bandwidth(8e6, n, netmodel.GDR)
             for n in (32, 1752)]
    assert small[0] > 2 * small[1]
