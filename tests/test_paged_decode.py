"""Paged flash-decode kernel: page-table-walking attention parity.

Three implementations of paged single-token decode must agree:

* contiguous dense rows (the ground-truth layout),
* the XLA gather fallback (``decode_impl="gather"`` — bitwise vs contiguous,
  including with the position-masked page table of ``gather_pages``),
* the Pallas kernel (``decode_impl="pallas"``, interpret mode on CPU —
  within fp32 online-softmax tolerance of both).

Coverage deliberately includes positions straddling page boundaries (the
first row of a fresh page, the last row of a full one) and freed slots whose
page-table rows point at scratch page 0.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.kernels import ops as kops
from repro.kernels.ref import paged_decode_ref
from repro.models import LM
from repro.serve import Request, ServeEngine

TOL = dict(rtol=2e-5, atol=2e-5)


def small_lm(name="llama3.2-3b", layers=2):
    cfg = dataclasses.replace(CONFIGS[name].reduced(), dtype="float32",
                              num_layers=layers)
    lm = LM(cfg)
    return cfg, lm, lm.init(jax.random.key(0))


# ------------------------------------------------------------ kernel-level ----

def test_kernel_matches_ref_random_pools_boundary_positions():
    """Direct kernel-vs-oracle sweep.  Positions cover page boundaries on
    both sides: 0 (single row), page-1 (full first page), page (first row of
    the second page), and the last valid row."""
    rng = np.random.default_rng(0)
    B, KV, G, D, page, M = 6, 2, 3, 16, 4, 3
    P = B * M + 1
    q = jnp.asarray(rng.normal(size=(B, 1, KV, G, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, page, KV, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, page, KV, D)), jnp.float32)
    pt = jnp.asarray(rng.integers(1, P, (B, M)), jnp.int32)
    pos = jnp.asarray([0, page - 1, page, 2 * page - 1, 2 * page,
                       M * page - 1], jnp.int32)
    o = kops.paged_decode_attention(q, kp, vp, pt, pos)
    o_ref = paged_decode_ref(q[:, 0], kp, vp, pt, pos)
    np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(o_ref), **TOL)


def test_kernel_dead_pages_do_not_contribute():
    """Table entries past a slot's position may be stale (recycled pages of
    another request) — the walk's early exit must never read them into the
    softmax.  Poison the dead entries with huge values and check the output
    is untouched."""
    rng = np.random.default_rng(1)
    B, KV, G, D, page, M = 2, 1, 2, 8, 4, 4
    P = 8
    q = jnp.asarray(rng.normal(size=(B, 1, KV, G, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, page, KV, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, page, KV, D)), jnp.float32)
    kp = kp.at[7].set(1e9)        # poison page: huge K would dominate softmax
    vp = vp.at[7].set(jnp.nan)    # ... and NaN V would propagate instantly
    pos = jnp.asarray([2, 5], jnp.int32)   # slots use pages 0..0 and 0..1
    live = jnp.asarray([[1, 2, 0, 0], [3, 4, 0, 0]], jnp.int32)
    dead = jnp.asarray([[1, 7, 7, 7], [3, 4, 7, 7]], jnp.int32)
    o_live = kops.paged_decode_attention(q, kp, vp, live, pos)
    o_dead = kops.paged_decode_attention(q, kp, vp, dead, pos)
    assert np.isfinite(np.asarray(o_dead)).all()
    np.testing.assert_allclose(np.asarray(o_dead), np.asarray(o_live), **TOL)


def test_kernel_fuzz_random_shapes_three_way():
    """Seeded fuzz over (B, M, page_size, positions): pallas-interpret vs
    the XLA gather path vs the dense ``paged_decode_ref`` oracle must agree
    at every draw.  Positions deliberately include pos=0, both sides of
    every page boundary, and the last valid row; tables include repeated
    physical pages (prefix sharing aliases pages across slots)."""
    from repro.models.attention import decode_attention

    rng = np.random.default_rng(2024)          # reproducible by seed
    for trial in range(6):
        B = int(rng.integers(1, 6))
        KV = int(rng.integers(1, 3))
        G = int(rng.integers(1, 4))
        D = int(rng.choice([4, 8, 16]))
        page = int(rng.choice([2, 4, 8]))
        M = int(rng.integers(1, 5))
        P = int(B * M + rng.integers(1, 4))
        q = jnp.asarray(rng.normal(size=(B, 1, KV, G, D)), jnp.float32)
        kp = jnp.asarray(rng.normal(size=(P, page, KV, D)), jnp.float32)
        vp = jnp.asarray(rng.normal(size=(P, page, KV, D)), jnp.float32)
        # repeated entries alias pages across slots, like prefix sharing
        pt = jnp.asarray(rng.integers(1, P, (B, M)), jnp.int32)
        boundary = np.array([0, page - 1, page, M * page - 1])
        pos = np.where(rng.random(B) < 0.5,
                       rng.choice(boundary, B),
                       rng.integers(0, M * page, B)).astype(np.int32)
        pos = jnp.asarray(np.minimum(pos, M * page - 1))

        o_ref = paged_decode_ref(q[:, 0], kp, vp, pt, pos)
        o_kernel = kops.paged_decode_attention(q, kp, vp, pt, pos)
        o_gather = decode_attention(q, kp, vp, pos, page_table=pt,
                                    impl="gather")
        ctx = dict(trial=trial, B=B, KV=KV, G=G, D=D, page=page, M=M,
                   pos=np.asarray(pos).tolist())
        np.testing.assert_allclose(np.asarray(o_kernel[:, 0]),
                                   np.asarray(o_ref), err_msg=str(ctx),
                                   **TOL)
        np.testing.assert_allclose(np.asarray(o_gather[:, 0]),
                                   np.asarray(o_ref), err_msg=str(ctx),
                                   **TOL)


def test_kernel_quantized_matches_ref_and_float_pool():
    """Int8 pools with per-row scales: the kernel's in-register dequant must
    match the dequantizing gather oracle within kernel tolerance, and both
    must track the original float pool within the quantization error bound.
    Positions cover the same page boundaries as the float sweep."""
    from repro.kernels.quant import quantize_kv

    rng = np.random.default_rng(5)
    B, KV, G, D, page, M = 6, 2, 3, 16, 4, 3
    P = B * M + 1
    q = jnp.asarray(rng.normal(size=(B, 1, KV, G, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, page, KV, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, page, KV, D)), jnp.float32)
    kq, ks = quantize_kv(kp)
    vq, vs = quantize_kv(vp)
    assert ks.shape == (P, page, KV)
    pt = jnp.asarray(rng.integers(1, P, (B, M)), jnp.int32)
    pos = jnp.asarray([0, page - 1, page, 2 * page - 1, 2 * page,
                       M * page - 1], jnp.int32)
    o = kops.paged_decode_attention(q, kq, vq, pt, pos, k_scale=ks,
                                    v_scale=vs)
    o_ref = paged_decode_ref(q[:, 0], kq, vq, pt, pos, k_scale=ks,
                             v_scale=vs)
    np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(o_ref), **TOL)
    # and the quantized result stays near the float-pool softmax: per-element
    # K/V error is <= absmax/254, attention smooths it well under 2%
    o_float = paged_decode_ref(q[:, 0], kp, vp, pt, pos)
    np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(o_float),
                               rtol=0.05, atol=0.02)


def test_kernel_quantized_dead_pages_and_scratch():
    """Poisoned dead pages — including poisoned *scales* — must not leak
    into a quantized walk, and scratch-routed slots stay finite."""
    from repro.kernels.quant import quantize_kv

    rng = np.random.default_rng(6)
    B, KV, G, D, page, M, P = 2, 1, 2, 8, 4, 4, 8
    q = jnp.asarray(rng.normal(size=(B, 1, KV, G, D)), jnp.float32)
    kq, ks = quantize_kv(jnp.asarray(rng.normal(size=(P, page, KV, D)),
                                     jnp.float32))
    vq, vs = quantize_kv(jnp.asarray(rng.normal(size=(P, page, KV, D)),
                                     jnp.float32))
    ks = ks.at[7].set(1e30)                  # poisoned scale on a dead page
    vs = vs.at[7].set(jnp.nan)
    pos = jnp.asarray([2, 5], jnp.int32)
    live = jnp.asarray([[1, 2, 0, 0], [3, 4, 0, 0]], jnp.int32)
    dead = jnp.asarray([[1, 7, 7, 7], [3, 4, 7, 7]], jnp.int32)
    o_live = kops.paged_decode_attention(q, kq, vq, live, pos, k_scale=ks,
                                         v_scale=vs)
    o_dead = kops.paged_decode_attention(q, kq, vq, dead, pos, k_scale=ks,
                                         v_scale=vs)
    assert np.isfinite(np.asarray(o_dead)).all()
    np.testing.assert_allclose(np.asarray(o_dead), np.asarray(o_live), **TOL)


def test_quantized_partials_kernel_vs_gather():
    """Sharded int8 building block: the kernel's partial triple over a local
    pool shard (with local scale shards) matches the gather partials, and
    the two-chip merge reconstructs the full quantized softmax."""
    from repro.kernels.quant import quantize_kv
    from repro.models.attention import (decode_attention,
                                        paged_gather_partials)

    rng = np.random.default_rng(9)
    B, KV, G, D, page, M, P = 3, 2, 2, 8, 4, 3, 12
    q = jnp.asarray(rng.normal(size=(B, 1, KV, G, D)), jnp.float32)
    kq, ks = quantize_kv(jnp.asarray(rng.normal(size=(P, page, KV, D)),
                                     jnp.float32))
    vq, vs = quantize_kv(jnp.asarray(rng.normal(size=(P, page, KV, D)),
                                     jnp.float32))
    pt = jnp.asarray(rng.integers(1, P, (B, M)), jnp.int32)
    pos = jnp.asarray([0, 5, 11], jnp.int32)
    half = P // 2

    def window(c):
        s = slice(c * half, (c + 1) * half)
        return kq[s], vq[s], ks[s], vs[s], jnp.int32(c * half)

    parts = []
    for c in range(2):
        kw, vw, ksw, vsw, off = window(c)
        g = paged_gather_partials(q, kw, vw, pt, pos, off, k_scale=ksw,
                                  v_scale=vsw)
        k = kops.paged_decode_partials(q, kw, vw, pt, pos, off, k_scale=ksw,
                                       v_scale=vsw)
        np.testing.assert_allclose(np.asarray(k[1]), np.asarray(g[1]), **TOL)
        np.testing.assert_allclose(np.asarray(k[2]), np.asarray(g[2]), **TOL)
        np.testing.assert_allclose(np.asarray(k[0]), np.asarray(g[0]),
                                   rtol=2e-4, atol=2e-4)
        parts.append(g)
    ms = jnp.stack([m for _, _, m in parts])
    gm = ms.max(axis=0)
    num = sum(acc * jnp.exp(m - gm)[:, None, :, :, None]
              for acc, _, m in parts)
    den = sum(l * jnp.exp(m - gm) for _, l, m in parts)
    merged = num / jnp.maximum(den, 1e-30)[:, None, :, :, None]
    full = decode_attention(q, kq, vq, pos, page_table=pt, impl="gather",
                            k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full), **TOL)


def test_partials_merge_matches_full_softmax_singlehost():
    """The sharded path's building blocks, checked without a mesh: gather
    partials over two half-pools, merged with the partial-softmax formula,
    equal the full-pool softmax — and the pallas partials triple matches the
    gather partials triple on the same half-pool."""
    from repro.models.attention import (decode_attention,
                                        paged_gather_partials)

    rng = np.random.default_rng(8)
    B, KV, G, D, page, M, P = 3, 2, 2, 8, 4, 3, 12   # halves of 6 pages
    q = jnp.asarray(rng.normal(size=(B, 1, KV, G, D)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(P, page, KV, D)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(P, page, KV, D)), jnp.float32)
    pt = jnp.asarray(rng.integers(1, P, (B, M)), jnp.int32)
    pos = jnp.asarray([0, 5, 11], jnp.int32)

    half = P // 2
    parts = [paged_gather_partials(q, kp[c * half:(c + 1) * half],
                                   vp[c * half:(c + 1) * half], pt, pos,
                                   jnp.int32(c * half)) for c in range(2)]
    # host-side merge (the on-mesh version uses pmax/psum over chips)
    ms = jnp.stack([m for _, _, m in parts])
    gm = ms.max(axis=0)
    num = sum(acc * jnp.exp(m - gm)[:, None, :, :, None]
              for acc, _, m in parts)
    den = sum(l * jnp.exp(m - gm) for _, l, m in parts)
    merged = num / jnp.maximum(den, 1e-30)[:, None, :, :, None]
    full = decode_attention(q, kp, vp, pos, page_table=pt, impl="gather")
    np.testing.assert_allclose(np.asarray(merged), np.asarray(full), **TOL)

    # kernel partials == gather partials on one half-pool window
    acc_g, l_g, m_g = parts[1]
    acc_k, l_k, m_k = kops.paged_decode_partials(
        q, kp[half:], vp[half:], pt, pos, jnp.int32(half))
    np.testing.assert_allclose(np.asarray(l_k), np.asarray(l_g), **TOL)
    # only compare running maxima where a live page exists (both report
    # NEG_INF identity otherwise, but -1e30 equality is exact anyway)
    np.testing.assert_allclose(np.asarray(m_k), np.asarray(m_g), **TOL)
    np.testing.assert_allclose(np.asarray(acc_k), np.asarray(acc_g),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------- decode parity ----

def test_ragged_8slot_kernel_vs_gather_vs_contiguous():
    """The acceptance workload: eight slots at eight depths (several
    straddling the page-size-8 boundary).  Gather stays bitwise vs
    contiguous; the kernel matches within fp32 online-softmax tolerance —
    through two chained decode steps so the kernel also consumes
    scatter-written pages."""
    cfg, lm, params = small_lm()
    B, S, pg = 8, 32, 8
    rng = np.random.default_rng(7)
    lens = [3, 11, 7, 1, 14, 5, 9, 2]     # 7->8 and 11->12 cross page rows
    contig = lm.init_cache(B, S, dtype=jnp.float32, backend="contiguous")
    paged = lm.init_cache(B, S, dtype=jnp.float32, backend="paged",
                          page_size=pg)
    for b, plen in enumerate(lens):
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        assert contig.alloc(b, plen + 4) == 0
        assert paged.alloc(b, plen + 4, prefix=prompt) == 0
        _, _, pc = lm.forward(params, {"tokens": jnp.asarray(prompt[None])},
                              collect_cache=True)
        contig.write_prefill(b, pc["layers"])
        paged.write_prefill(b, pc["layers"])
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    pos = jnp.asarray(np.array(lens, np.int32))

    lc, cc = lm.decode_step(params, toks, contig.decode_view(), pos)
    lg, cg = lm.decode_step(params, toks, paged.decode_view(), pos)
    lk, ck = lm.decode_step(params, toks, paged.decode_view(), pos,
                            decode_impl="pallas")
    np.testing.assert_array_equal(np.asarray(lc), np.asarray(lg))
    np.testing.assert_allclose(np.asarray(lk), np.asarray(lg), **TOL)
    # the kernel path's cache writes land in the same pages/rows; values
    # beyond layer 0 inherit the attention tolerance (layer N's K/V project
    # layer N-1's output), so this is allclose, not bitwise
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), **TOL), cg["layers"], ck["layers"])
    # step 2: positions advance across more page boundaries
    contig.update(cc)
    paged.update(cg)
    lc2, _ = lm.decode_step(params, toks, contig.decode_view(), pos + 1)
    lg2, _ = lm.decode_step(params, toks, paged.decode_view(), pos + 1)
    lk2, _ = lm.decode_step(params, toks, paged.decode_view(), pos + 1,
                            decode_impl="pallas")
    np.testing.assert_array_equal(np.asarray(lc2), np.asarray(lg2))
    np.testing.assert_allclose(np.asarray(lk2), np.asarray(lg2), **TOL)


def test_freed_slot_scratch_page_rows_are_inert():
    """A freed slot's page-table row is all scratch-page zeros and the
    engine decodes it at position 0: the kernel must return finite garbage
    for that slot while active slots' logits are unperturbed."""
    cfg, lm, params = small_lm()
    B, S, pg = 4, 16, 4
    rng = np.random.default_rng(3)
    paged = lm.init_cache(B, S, dtype=jnp.float32, backend="paged",
                          page_size=pg)
    lens = [5, 6, 4]
    for b, plen in enumerate(lens):
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        assert paged.alloc(b, plen + 2, prefix=prompt) == 0
        _, _, pc = lm.forward(params, {"tokens": jnp.asarray(prompt[None])},
                              collect_cache=True)
        paged.write_prefill(b, pc["layers"])
    paged.free(1)                              # slot 1 -> scratch page 0
    assert np.all(paged.page_table[1] == 0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    pos = jnp.asarray([5, 0, 4, 0], jnp.int32)   # freed/empty slots at 0
    lg, _ = lm.decode_step(params, toks, paged.decode_view(), pos)
    lk, _ = lm.decode_step(params, toks, paged.decode_view(), pos,
                           decode_impl="pallas")
    assert np.isfinite(np.asarray(lk)).all()
    for b in (0, 2):                           # live slots: full parity
        np.testing.assert_allclose(np.asarray(lk[b]), np.asarray(lg[b]),
                                   **TOL)


def test_decode_impl_rejected_values():
    cfg, lm, params = small_lm()
    with pytest.raises(AssertionError):
        lm.init_cache(2, 16, dtype=jnp.float32, backend="paged",
                      decode_impl="typo")
    paged = lm.init_cache(2, 16, dtype=jnp.float32, backend="paged",
                          page_size=4)
    toks = jnp.zeros((2, 1), jnp.int32)
    with pytest.raises(AssertionError):
        lm.decode_step(params, toks, paged.decode_view(),
                       jnp.zeros(2, jnp.int32), decode_impl="typo")
