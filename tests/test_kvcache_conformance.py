"""Cross-backend KV-cache conformance suite.

ONE parametrized matrix over every cache configuration the engine accepts
— backend x kv_dtype x decode_impl x host-tier x prefill mode — asserting
the three contracts every configuration must honour:

* **bitwise stream parity**: greedy token streams never depend on page
  placement, table resolution, wire format quirks, chunking, host-tier
  round-trips, or admission order;
* **memory_stats accounting**: the byte/page math holds at every
  iteration (``verify_cache=True`` runs the full ``PagedCache.verify``
  sanitizer, host tier included, after each engine step);
* **free/drain-to-zero**: a drained engine returns every page, slot and
  gauge to zero (host-tier pages legitimately stay warm — that is the
  tier's purpose — but stay bounded by capacity).

This file replaces the near-duplicate engine parity tests that had been
copy-pasted across the suite as each configuration landed:

* ``test_kvcache.py``: ``test_paged_logits_match_contiguous_exactly_
  ragged_8slot``, ``test_paged_engine_single_fused_dispatch_and_token_
  parity``, ``test_tight_pool_slot_reuse_parity``, ``test_engine_soak_
  random_schedule_tight_pool_parity_and_telemetry``, ``test_int8_decode_
  logits_close_to_fp32_oracle``, ``test_int8_engine_greedy_stream_
  parity_and_telemetry``, ``test_int8_prefix_sharing_and_tight_pool_
  parity``
* ``test_chunked_prefill.py``: ``test_chunked_stream_parity_across_
  chunk_sizes``
* ``test_paged_decode.py``: ``test_engine_token_stream_parity_gather_
  vs_kernel``

Mesh-only parity cases stay in ``test_serve_sharded.py`` (they need a
multi-device subprocess, not a matrix axis).

The whole module is ``slow``-marked: it runs in the default (tier-1 /
CI) invocation and is skippable locally with ``-m "not slow"``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.models import LM
from repro.serve import PrefixStore, Request, ServeEngine

pytestmark = pytest.mark.slow


def small_lm(name="qwen3-4b", layers=2):
    cfg = dataclasses.replace(CONFIGS[name].reduced(), dtype="float32",
                              num_layers=layers)
    lm = LM(cfg)
    return cfg, lm, lm.init(jax.random.key(0))


@pytest.fixture(scope="module")
def model():
    return small_lm()


def _shared_prefix_requests(cfg, n=12, seed=29):
    """Ragged workload with two recurring system prompts: two of every
    three requests extend one of the 8-token prefixes (page_size=4 -> two
    shareable full pages each), the third is fully random.  Staggered
    lifetimes mean some admissions device-share a live prefix while
    others arrive after its last sharer freed — the only way a host-tier
    combo exercises offload AND prefetch on the same schedule."""
    rng = np.random.default_rng(seed)
    sys_prompts = [np.arange(8, dtype=np.int32) % cfg.vocab_size,
                   (np.arange(8, dtype=np.int32) + 101) % cfg.vocab_size]
    reqs = []
    for i in range(n):
        if i % 3 == 2:
            prompt = rng.integers(0, cfg.vocab_size,
                                  int(rng.integers(2, 10))).astype(np.int32)
        else:
            tail = rng.integers(0, cfg.vocab_size,
                                int(rng.integers(1, 5))).astype(np.int32)
            prompt = np.concatenate([sys_prompts[i % 2], tail])
        reqs.append((i, prompt, int(rng.integers(3, 7))))
    return reqs


def _run_engine(lm, params, reqs, **kw):
    eng = ServeEngine(lm, params, max_batch=4, max_seq=32, **kw)
    for i, p, n in reqs:
        eng.submit(Request(i, p.copy(), max_new_tokens=n))
    out = {r.id: list(r.out_tokens) for r in eng.run_until_drained()}
    return out, eng


@pytest.fixture(scope="module")
def oracle(model):
    """The contiguous engine's streams on the shared-prefix workload —
    the parity target for every native-format combo."""
    cfg, lm, params = model
    reqs = _shared_prefix_requests(cfg)
    out, eng = _run_engine(lm, params, reqs, cache_backend="contiguous")
    st = eng.kv.memory_stats()
    assert st.slots_in_use == 0 and len(out) == len(reqs)
    return reqs, out


@pytest.fixture(scope="module")
def int8_oracle(model, oracle):
    """The int8 baseline (paged/gather/no tier/no chunking): the parity
    target for the other int8 combos.  On this reduced model the int8
    quantization error does not move any greedy argmax, so the baseline
    itself matches the fp32 oracle bitwise — pinning the quality gate the
    deleted test_int8_engine_greedy_stream_parity test asserted."""
    cfg, lm, params = model
    reqs, ref = oracle
    out, eng = _run_engine(lm, params, reqs, cache_backend="paged",
                           page_size=4, kv_dtype="int8", verify_cache=True)
    assert out == ref, "int8 baseline diverged from the fp32 oracle"
    assert eng.reg.gauge("serve_kv_quant_enabled").get() == 1
    return out


# --------------------------------------------------------- engine matrix ----

ENGINE_COMBOS = [
    pytest.param("paged", kv_dtype, impl, host, chunk,
                 id=f"paged-{kv_dtype}-{impl}-"
                    f"{'host' if host else 'hbm'}-"
                    f"{'chunked' if chunk else 'whole'}")
    for kv_dtype in ("native", "int8")
    for impl in ("gather", "pallas")
    for host in (0, 32)
    for chunk in (0, 4)
]


@pytest.mark.parametrize("backend,kv_dtype,impl,host,chunk", ENGINE_COMBOS)
def test_engine_conformance(model, oracle, int8_oracle, backend, kv_dtype,
                            impl, host, chunk):
    """Every paged configuration the engine accepts emits bitwise the
    reference streams, keeps the one-fused-dispatch-per-iteration
    invariant, passes the full allocator sanitizer after every iteration
    (verify_cache), and drains to zero."""
    cfg, lm, params = model
    reqs, ref = oracle
    out, eng = _run_engine(
        lm, params, reqs, cache_backend=backend, page_size=4,
        kv_dtype=kv_dtype, decode_impl=impl, host_pages=host,
        prefill_chunk=chunk, verify_cache=True)
    target = ref if kv_dtype == "native" else int8_oracle
    assert out == target
    assert len(out) == len(reqs)

    iters = eng.reg.counter("serve_iterations_total").get()
    assert iters > 0
    assert eng.reg.counter("serve_decode_dispatches_total").get() == iters

    st = eng.kv.memory_stats()
    assert st.pages_in_use == 0 and st.slots_in_use == 0
    assert st.bytes_reserved == 0
    assert eng.reg.gauge("serve_kv_pages_in_use").get() == 0
    eng.kv.verify()

    if host:
        # warm tier: offloads happened, later admissions hit, residency
        # stays bounded by capacity and the gauge mirrors the store
        stats = eng.kv.store.stats()
        assert stats["offloads"] > 0
        assert stats["hits"] > 0
        assert 0 < st.host_pages_in_use <= host
        assert st.host_bytes == st.host_pages_in_use \
            * eng.kv.store.tier.page_bytes
        assert eng.reg.gauge("serve_host_pages_in_use").get() == \
            st.host_pages_in_use
        assert eng.reg.counter("serve_prefix_store_hits_total").get() == \
            stats["hits"]
        assert eng.reg.counter("serve_host_offload_bytes_total").get() == \
            stats["offload_bytes"]
    else:
        assert eng.kv.store is None
        assert st.host_pages_in_use == 0
    if chunk:
        assert eng.reg.counter("serve_prefill_chunks_total").get() > 0
        # shared admissions cover whole chunks -> their forwards skip
        assert eng.reg.counter(
            "serve_prefill_chunks_skipped_total").get() > 0


def test_engine_conformance_contiguous(model, oracle):
    """The one contiguous configuration (native/gather/no tier): dense
    accounting pins everything up front, drains to zero slots."""
    cfg, lm, params = model
    reqs, ref = oracle
    out, eng = _run_engine(lm, params, reqs, cache_backend="contiguous")
    assert out == ref
    st = eng.kv.memory_stats()
    assert st.slots_in_use == 0
    assert st.bytes_reserved == st.bytes_total   # dense always pins all
    assert st.host_pages_in_use == 0


TIGHT_COMBOS = [
    pytest.param(kv_dtype, host, chips,
                 id=f"{kv_dtype}-{'host' if host else 'hbm'}"
                    + (f"-chips{chips}" if chips else ""))
    for kv_dtype, host, chips in [("native", 0, None), ("native", 24, None),
                                  ("int8", 0, None), ("int8", 24, None),
                                  ("native", 24, 2)]
]


@pytest.mark.parametrize("kv_dtype,host,chips", TIGHT_COMBOS)
def test_tight_pool_conformance(model, oracle, int8_oracle, kv_dtype, host,
                                chips):
    """A pool admitting only ~2 requests forces deferrals, page recycling
    and (with the tier on) eviction-to-host under pressure — streams must
    still match the unconstrained reference bitwise.  The locality-chips
    variant partitions the free list per chip: eviction returns each page
    to its owning chip's list and prefetch claims through the same
    locality-aware allocator, with zero behavioural surface."""
    cfg, lm, params = model
    reqs, ref = oracle
    # pool of 9 usable pages vs footprints up to 4 pages: 2-ish in flight
    # (padded to 10 usable with locality_chips=2)
    out, eng = _run_engine(
        lm, params, reqs, cache_backend="paged", page_size=4, num_pages=10,
        kv_dtype=kv_dtype, host_pages=host, locality_chips=chips,
        verify_cache=True)
    assert out == (ref if kv_dtype == "native" else int8_oracle)
    assert eng.reg.counter("serve_admission_deferred_total").get() > 0
    st = eng.kv.memory_stats()
    assert st.pages_in_use == 0 and st.slots_in_use == 0
    if host:
        stats = eng.kv.store.stats()
        assert stats["offloads"] > 0 and stats["hits"] > 0
    if chips:
        assert st.mesh_chips == chips


# ------------------------------------------------- cache-level bitwise ----

@pytest.mark.parametrize("host", [0, 16], ids=["hbm", "host"])
@pytest.mark.parametrize("impl", ["gather", "pallas"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["float32", "bfloat16"])
def test_cache_level_logit_parity(dtype, impl, host):
    """Eight slots at eight depths: gather-resolved paged decode logits
    (either storage dtype) are bitwise the dense layout's; the pallas
    kernel's online-softmax reassociates the reduction, so its contract
    is allclose at 2e-5 with identical argmax.  Either way the logits are
    **bitwise stable** across a full offload -> prefetch round-trip
    through the host tier (free every slot, re-admit the same prompts,
    decode off the prefetched pages)."""
    cfg, lm, params = small_lm("llama3.2-3b")
    B, S, pg = 8, 32, 8
    rng = np.random.default_rng(7)
    lens = [3, 11, 7, 1, 14, 5, 9, 2]
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    blocks = []
    contig = lm.init_cache(B, S, dtype=dtype, backend="contiguous")
    paged = lm.init_cache(B, S, dtype=dtype, backend="paged", page_size=pg,
                          decode_impl=impl, host_pages=host)
    for b, prompt in enumerate(prompts):
        assert contig.alloc(b, len(prompt) + 4) == 0
        assert paged.alloc(b, len(prompt) + 4, prefix=prompt) == 0
        _, _, pc = lm.forward(params, {"tokens": jnp.asarray(prompt[None])},
                              collect_cache=True)
        blocks.append(pc["layers"])
        contig.write_prefill(b, pc["layers"])
        paged.write_prefill(b, pc["layers"])
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    positions = jnp.asarray(np.array(lens, np.int32))
    lc, _ = lm.decode_step(params, toks, contig.decode_view(), positions)
    lp, _ = lm.decode_step(params, toks, paged.decode_view(), positions,
                           decode_impl=impl)
    lc, lp = np.asarray(lc), np.asarray(lp)
    if impl == "gather":
        np.testing.assert_array_equal(lc, lp)
    else:
        np.testing.assert_allclose(lc, lp, rtol=2e-5, atol=2e-5)
        np.testing.assert_array_equal(lc[..., :cfg.vocab_size].argmax(-1),
                                      lp[..., :cfg.vocab_size].argmax(-1))
    if not host:
        return
    # round-trip: last frees offload the full prompt pages; re-admission
    # prefetches them back — logits must not move by a single bit
    for b in range(B):
        paged.free(b)
    paged.drain_offloads()
    assert paged.store.pages_in_use() == sum(n // pg for n in lens)
    for b, prompt in enumerate(prompts):
        got = paged.alloc(b, len(prompt) + 4, prefix=prompt)
        assert got == (len(prompt) // pg) * pg
        paged.write_prefill(b, blocks[b])     # shared positions scratch-route
    lp2, _ = lm.decode_step(params, toks, paged.decode_view(), positions,
                            decode_impl=impl)
    np.testing.assert_array_equal(lp, np.asarray(lp2))
    paged.verify()


@pytest.mark.parametrize("host", [0, 16], ids=["hbm", "host"])
@pytest.mark.parametrize("impl", ["gather", "pallas"])
def test_int8_logit_quality_gate(impl, host):
    """The int8 quality gate at the logit level (replacing the deleted
    per-file copy): int8 pages decode within the documented 0.05 logit
    tolerance of the fp32 paged oracle and never move a greedy argmax —
    and a host-tier round-trip of the int8 wire format (int8 payload +
    fp32 scales) reproduces the exact pre-offload logits."""
    cfg, lm, params = small_lm("llama3.2-3b")
    B, S, pg = 8, 32, 8
    rng = np.random.default_rng(7)
    lens = [3, 11, 7, 1, 14, 5, 9, 2]
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    blocks = {}

    def build(kv_dtype):
        kv = lm.init_cache(B, S, dtype=jnp.float32, backend="paged",
                           page_size=pg, decode_impl=impl,
                           kv_dtype=kv_dtype, host_pages=host)
        for b, prompt in enumerate(prompts):
            assert kv.alloc(b, len(prompt) + 4, prefix=prompt) == 0
            if b not in blocks:
                _, _, pc = lm.forward(
                    params, {"tokens": jnp.asarray(prompt[None])},
                    collect_cache=True)
                blocks[b] = pc["layers"]
            kv.write_prefill(b, blocks[b])
        return kv

    oracle, quant = build("native"), build("int8")
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    pos = jnp.asarray(np.array(lens, np.int32))
    lo, _ = lm.decode_step(params, toks, oracle.decode_view(), pos,
                           decode_impl=impl)
    lq, _ = lm.decode_step(params, toks, quant.decode_view(), pos,
                           decode_impl=impl)
    lo, lq = np.asarray(lo), np.asarray(lq)
    assert np.abs(lq - lo).max() <= 0.05
    np.testing.assert_array_equal(lo[..., :cfg.vocab_size].argmax(-1),
                                  lq[..., :cfg.vocab_size].argmax(-1))
    if not host:
        return
    for b in range(B):
        quant.free(b)
    quant.drain_offloads()
    for b, prompt in enumerate(prompts):
        assert quant.alloc(b, len(prompt) + 4, prefix=prompt) == \
            (len(prompt) // pg) * pg
        quant.write_prefill(b, blocks[b])
    lq2, _ = lm.decode_step(params, toks, quant.decode_view(), pos,
                            decode_impl=impl)
    np.testing.assert_array_equal(lq, np.asarray(lq2))
    quant.verify()


# ------------------------------------------------------- 10x working-set ----

def test_soak_working_set_10x_pool_host_tier(model):
    """10x working-set soak (the tentpole's capacity claim as a test):
    20 distinct 12-token prefixes x 3 pages = 60 warm prefix pages vs a
    6-usable-page HBM pool, served through a random prefix-sharing
    schedule with the host tier on.  The engine must always drain, keep
    ``serve_kv_pages_in_use`` bounded by the pool at every step and zero
    at the end, serve every revisit from the tier, and emit byte-identical
    streams vs the no-offload (contiguous) oracle."""
    cfg, lm, params = model
    rng = np.random.default_rng(53)
    n_prefix, per_prefix = 20, 2
    prefixes = [rng.integers(0, cfg.vocab_size, 12).astype(np.int32)
                for _ in range(n_prefix)]
    reqs = []
    for i in range(n_prefix * per_prefix):
        pre = prefixes[i % n_prefix]
        tail = rng.integers(0, cfg.vocab_size,
                            int(rng.integers(1, 3))).astype(np.int32)
        reqs.append((i, np.concatenate([pre, tail]),
                     int(rng.integers(2, 5))))
    order = rng.permutation(len(reqs))
    arrivals: dict = {}
    for j, idx in enumerate(order):
        arrivals.setdefault(int(rng.integers(0, 120)), []).append(reqs[idx])

    def run(**kw):
        eng = ServeEngine(lm, params, max_batch=4, max_seq=32, **kw)
        paged = kw.get("cache_backend") == "paged"
        pages_total = eng.kv.memory_stats().pages_total if paged else 0
        gauge = eng.reg.gauge("serve_kv_pages_in_use")
        for step in range(400):
            for i, p, n in arrivals.get(step, []):
                eng.submit(Request(i, p.copy(), max_new_tokens=n))
            eng.step()
            if paged:
                assert 0 <= gauge.get() <= pages_total, step
        done = eng.run_until_drained(max_iters=2000)
        assert not eng.queue and all(r is None for r in eng.slot_req), \
            "soak must drain (zero-OOM claim)"
        return {r.id: list(r.out_tokens) for r in done}, eng

    # 6 usable pages; every footprint needs <= ceil((14+4)/4)=5 pages
    out, eng = run(cache_backend="paged", page_size=4, num_pages=7,
                   host_pages=64, verify_cache=True)
    ref, _ = run(cache_backend="contiguous")
    assert out == ref and len(out) == len(reqs)
    st = eng.kv.memory_stats()
    assert st.pages_in_use == 0 and st.slots_in_use == 0
    assert eng.reg.gauge("serve_kv_pages_in_use").get() == 0
    # the working set really was ~10x the pool, held by the host tier
    assert n_prefix * 3 >= 10 * st.pages_total
    stats = eng.kv.store.stats()
    assert stats["offloads"] > 0 and stats["hits"] > 0
    assert 0 < st.host_pages_in_use <= 64
    assert eng.reg.counter("serve_admission_deferred_total").get() > 0
    eng.kv.verify()
