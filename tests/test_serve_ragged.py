"""Ragged fused decode: per-slot positions, one-dispatch-per-iteration
engine, and on-device vectorized sampling."""
import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.models import LM
from repro.serve import (Request, SamplingParams, ServeEngine, filtered_probs,
                         sample_batch, sample_token)
from repro.serve.engine import _filtered_probs_np


def small_lm(name="llama3.2-3b", layers=2):
    cfg = dataclasses.replace(CONFIGS[name].reduced(), dtype="float32",
                              num_layers=layers)
    lm = LM(cfg)
    return cfg, lm, lm.init(jax.random.key(0))


# ------------------------------------------------------- per-slot decode ----

def test_per_slot_positions_match_scalar_path_when_uniform():
    """With every slot at the same depth, the (B,) vector path must agree
    with the scalar cache_index path bit-for-bit in structure and closely in
    value (same math, different mask/scatter lowering)."""
    cfg, lm, params = small_lm()
    B, S = 3, 16
    rng = np.random.default_rng(0)
    toks = rng.integers(0, cfg.vocab_size, (B, 4)).astype(np.int32)
    cache_a = lm.init_cache(B, S, dtype=jnp.float32)
    cache_b = lm.init_cache(B, S, dtype=jnp.float32)
    for pos in range(4):
        t = jnp.asarray(toks[:, pos:pos + 1])
        la, cache_a = lm.decode_step(params, t, cache_a, jnp.int32(pos))
        lb, cache_b = lm.decode_step(params, t, cache_b,
                                     jnp.full((B,), pos, jnp.int32))
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=1e-5, atol=1e-5)
    for a, b in zip(jax.tree.leaves(cache_a), jax.tree.leaves(cache_b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_per_slot_ragged_matches_independent_scalar_decodes():
    """Slots at *different* depths decoded in one ragged call must match
    decoding each sequence alone with the scalar path at its own position."""
    cfg, lm, params = small_lm("qwen3-4b")
    B, S = 3, 24
    rng = np.random.default_rng(1)
    lens = [3, 7, 5]
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    # build the ragged batch cache by prefilling each prompt alone (batch 1)
    # with the scalar path, then stacking the rows into a B-slot cache
    cache = lm.init_cache(B, S, dtype=jnp.float32)

    def put_row(big, row, b):
        return big.at[:, b].set(row[:, 0])

    solo_logits = []
    solo_caches = []
    for b, prompt in enumerate(prompts):
        c1 = lm.init_cache(1, S, dtype=jnp.float32)
        logits = None
        for pos, tok in enumerate(prompt):
            logits, c1 = lm.decode_step(params, jnp.asarray([[int(tok)]]),
                                        c1, jnp.int32(pos))
        solo_caches.append(c1)
        solo_logits.append(logits)
        cache = jax.tree.map(lambda big, row, b=b: put_row(big, row, b),
                             cache, c1)
    # one more token per sequence, all in ONE ragged per-slot-position call
    next_toks = np.array([[int(np.argmax(np.asarray(l[0, -1])))]
                          for l in solo_logits], np.int32)
    positions = jnp.asarray(np.array(lens, np.int32))
    ragged_logits, _ = lm.decode_step(params, jnp.asarray(next_toks), cache,
                                      positions)
    # reference: the same token through the scalar path, per sequence
    for b in range(B):
        ref_logits, _ = lm.decode_step(params, jnp.asarray(next_toks[b:b + 1]),
                                       solo_caches[b], jnp.int32(lens[b]))
        np.testing.assert_allclose(np.asarray(ragged_logits[b]),
                                   np.asarray(ref_logits[0]),
                                   rtol=2e-4, atol=2e-4)


# ------------------------------------------------- engine: fused dispatch ----

def _ragged_requests(cfg, n, seed=5):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(2, 10))).astype(np.int32),
                    max_new_tokens=int(rng.integers(4, 9)))
            for i in range(n)]


def test_engine_single_fused_dispatch_per_iteration():
    cfg, lm, params = small_lm("qwen3-4b")
    eng = ServeEngine(lm, params, max_batch=4, max_seq=64)
    calls = {"n": 0}
    orig = eng._fused

    def counting(*a, **k):
        calls["n"] += 1
        return orig(*a, **k)

    eng._fused = counting
    for r in _ragged_requests(cfg, 6):
        eng.submit(r)
    done = eng.run_until_drained()
    assert len(done) == 6
    iters = eng.reg.counter("serve_iterations_total").get()
    assert iters > 0
    # exactly ONE jitted decode dispatch per engine iteration, however
    # ragged the slot positions are
    assert calls["n"] == iters
    assert eng.reg.counter("serve_decode_dispatches_total").get() == iters


def test_engine_ragged_greedy_parity_with_grouped_reference():
    """The fused per-slot-position engine must emit token-for-token the same
    greedy outputs as the seed algorithm (token-by-token prefill + decode
    grouped by position with a scalar cache index) on a mixed-length
    workload that exercises slot reuse."""
    cfg, lm, params = small_lm()
    B, S = 2, 48
    reqs = _ragged_requests(cfg, 5, seed=11)

    eng = ServeEngine(lm, params, max_batch=B, max_seq=S)
    for r in reqs:
        eng.submit(Request(r.id, r.prompt, max_new_tokens=r.max_new_tokens))
    fused_out = {r.id: r.out_tokens
                 for r in eng.run_until_drained()}

    ref_out = _grouped_reference(lm, params, reqs, B, S)
    assert fused_out == ref_out


def _grouped_reference(lm, params, reqs, B, S):
    """Compact re-implementation of the seed engine's per-position-group
    loop (greedy), used as the parity oracle."""
    decode = jax.jit(lambda p, t, c, i: lm.decode_step(p, t, c, i))
    cache = lm.init_cache(B, S, dtype=jnp.float32)
    slot_req: List = [None] * B
    slot_pos = np.zeros(B, np.int32)
    last: Dict[int, np.ndarray] = {}
    queue = [Request(r.id, r.prompt, max_new_tokens=r.max_new_tokens)
             for r in reqs]
    out: Dict[int, List[int]] = {}
    vocab = lm.cfg.vocab_size
    for _ in range(10_000):
        for slot in [i for i, r in enumerate(slot_req) if r is None]:
            if not queue:
                break
            req = queue.pop(0)
            for pos, tok in enumerate(req.prompt):
                tokens = np.zeros((B, 1), np.int32)
                tokens[slot, 0] = int(tok)
                logits, cache = decode(params, jnp.asarray(tokens), cache,
                                       jnp.int32(pos))
                last[slot] = np.asarray(logits[slot, -1])
            slot_req[slot] = req
            slot_pos[slot] = len(req.prompt)
        active = [i for i, r in enumerate(slot_req) if r is not None]
        if not active:
            break
        by_pos: Dict[int, List[int]] = {}
        for i in active:
            by_pos.setdefault(int(slot_pos[i]), []).append(i)
        for pos, slots in sorted(by_pos.items()):
            tokens = np.zeros((B, 1), np.int32)
            for i in slots:
                tokens[i, 0] = int(np.argmax(last[i][:vocab]))
            logits, cache = decode(params, jnp.asarray(tokens), cache,
                                   jnp.int32(pos))
            logits = np.asarray(logits[:, -1])
            for i in slots:
                req = slot_req[i]
                out.setdefault(req.id, []).append(int(tokens[i, 0]))
                last[i] = logits[i]
                slot_pos[i] += 1
                if len(out[req.id]) >= req.max_new_tokens or slot_pos[i] >= S:
                    slot_req[i] = None
    return out


# --------------------------------------------------------------- sampling ----

def test_vectorized_greedy_sampling_matches_sample_token():
    rng = np.random.default_rng(2)
    logits = rng.normal(0, 2, (5, 97)).astype(np.float32)
    params = SamplingParams()           # greedy
    toks = np.asarray(sample_batch(
        jnp.asarray(logits), jnp.zeros(5, jnp.float32),
        jnp.zeros(5, jnp.int32), jnp.ones(5, jnp.float32),
        jnp.zeros(5, jnp.int32), jnp.zeros(5, jnp.int32)))
    for b in range(5):
        assert toks[b] == sample_token(logits[b], params, step=b)


@pytest.mark.parametrize("temp,top_k,top_p", [
    (0.7, 0, 1.0), (1.3, 10, 1.0), (0.9, 0, 0.8), (1.0, 12, 0.9)])
def test_vectorized_filtered_probs_match_host_reference(temp, top_k, top_p):
    """The device sampler must draw from exactly the distribution the host
    ``sample_token`` reference filters to, per row."""
    rng = np.random.default_rng(4)
    logits = rng.normal(0, 1.5, (6, 83)).astype(np.float32)
    params = SamplingParams(temperature=temp, top_k=top_k, top_p=top_p)
    dev = np.asarray(filtered_probs(
        jnp.asarray(logits), jnp.full(6, temp, jnp.float32),
        jnp.full(6, top_k, jnp.int32), jnp.full(6, top_p, jnp.float32)))
    for b in range(6):
        ref = _filtered_probs_np(logits[b], params)
        np.testing.assert_allclose(dev[b], ref, rtol=2e-4, atol=1e-6)


def test_engine_stochastic_sampling_runs_and_is_reproducible():
    cfg, lm, params = small_lm("qwen3-4b")

    def run():
        eng = ServeEngine(lm, params, max_batch=2, max_seq=48)
        sp = SamplingParams(temperature=0.8, top_k=20, top_p=0.95, seed=9)
        rng = np.random.default_rng(6)
        for i in range(4):
            eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 3 + i)
                               .astype(np.int32), max_new_tokens=5,
                               sampling=sp))
        return {r.id: r.out_tokens for r in eng.run_until_drained()}

    a, b = run(), run()
    assert a == b
    assert all(len(t) == 5 for t in a.values())
    assert all(0 <= tok < cfg.vocab_size for t in a.values() for tok in t)


# ------------------------------------------------------------ edge cases ----

def test_empty_prompt_rejected():
    cfg, lm, params = small_lm("qwen3-4b")
    eng = ServeEngine(lm, params, max_batch=2, max_seq=32)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(Request(0, np.zeros(0, np.int32)))


def test_overlong_prompt_rejected():
    cfg, lm, params = small_lm("qwen3-4b")
    eng = ServeEngine(lm, params, max_batch=2, max_seq=16)
    with pytest.raises(ValueError, match="no room to decode"):
        eng.submit(Request(0, np.zeros(16, np.int32)))
