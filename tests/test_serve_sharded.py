"""Sharded paged serving on 8 host devices (subprocess — the main pytest
process keeps 1 device): kv_pages-partitioned pools under shard_map must be
behaviourally invisible.

Parity bar: the sharded engine (2/4/8-way, gather and pallas-interpret)
emits **identical token streams** to the single-device paged engine on the
ragged workload — through chained decode steps, freed/recycled slots, and
prefix-shared prompts whose pages land on different chips — and sharded
decode logits match within fp32 partial-softmax-merge tolerance.  Pool
accounting must show the P/n split: every chip pins pages_total/n pages.
"""
import pytest

HEADER = """
import dataclasses, jax, jax.numpy as jnp, numpy as np
from repro.configs import CONFIGS
from repro.models import LM
from repro.parallel.mesh import make_mesh
from repro.serve import Request, ServeEngine

cfg = dataclasses.replace(CONFIGS['llama3.2-3b'].reduced(), dtype='float32',
                          num_layers=2)
lm = LM(cfg)
params = lm.init(jax.random.key(0))
TOL = dict(rtol=2e-5, atol=2e-5)
"""


def test_sharded_decode_step_logit_parity_2_4_8(subproc):
    """Direct fused-decode parity on the ragged 8-slot workload: sharded
    gather and pallas-interpret at every mesh width vs the single-device
    gather path — first step, a chained second step over scatter-written
    pages, and a freed slot parked on scratch page 0."""
    subproc(HEADER + """
B, S, pg = 8, 32, 8
lens = [3, 11, 7, 1, 14, 5, 9, 2]

def build(mesh=None, impl='gather'):
    kv = lm.init_cache(B, S, dtype=jnp.float32, backend='paged',
                       page_size=pg, mesh=mesh, decode_impl=impl)
    rng = np.random.default_rng(7)
    for b, plen in enumerate(lens):
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        assert kv.alloc(b, plen + 4, prefix=prompt) == 0
        _, _, pc = lm.forward(params, {'tokens': jnp.asarray(prompt[None])},
                              collect_cache=True)
        kv.write_prefill(b, pc['layers'])
    kv.free(3)                    # freed slot: table row -> scratch page 0
    return kv

rng = np.random.default_rng(7)
toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
pos = np.array(lens, np.int32)
pos[3] = 0                        # engine decodes freed slots at position 0
pos = jnp.asarray(pos)
live = np.array([b for b in range(B) if b != 3])

ref = build()
l_ref, c_ref = lm.decode_step(params, toks, ref.decode_view(), pos)
ref.update(c_ref)
l_ref2, _ = lm.decode_step(params, toks, ref.decode_view(), pos + 1)
l_ref, l_ref2 = np.asarray(l_ref), np.asarray(l_ref2)

for n in (2, 4, 8):
    mesh = make_mesh((n,), ('model',))
    for impl in ('gather', 'pallas'):
        kv = build(mesh, impl)
        assert kv.memory_stats().pages_total + 1 == kv.P
        l1, c1 = lm.decode_step(params, toks, kv.decode_view(), pos,
                                decode_impl=impl, mesh=mesh)
        np.testing.assert_allclose(np.asarray(l1)[live], l_ref[live], **TOL)
        assert np.isfinite(np.asarray(l1)).all()   # freed slot: finite junk
        kv.update(c1)              # chained step over scatter-written pages
        l2, _ = lm.decode_step(params, toks, kv.decode_view(), pos + 1,
                               decode_impl=impl, mesh=mesh)
        np.testing.assert_allclose(np.asarray(l2)[live], l_ref2[live],
                                   **TOL)
        print(f'OK logits n={n} impl={impl}')
print('OK sharded decode logit parity')
""")


def test_sharded_engine_stream_parity_gather(subproc):
    """End-to-end ragged continuous batching: the 2/4/8-way sharded paged
    engine emits bitwise-identical token streams to the single-device
    engine, through deferrals and slot recycling on a tight pool."""
    subproc(HEADER + """
rng = np.random.default_rng(23)
reqs = [(i, rng.integers(0, cfg.vocab_size,
                         int(rng.integers(2, 10))).astype(np.int32),
         int(rng.integers(3, 7))) for i in range(10)]

def run(mesh=None):
    # 8 pages (7 usable; divisible by every mesh width, so the pool is
    # byte-identical across runs) vs 4-page footprints: admissions defer
    # and pages recycle continuously
    eng = ServeEngine(lm, params, max_batch=4, max_seq=32,
                      cache_backend='paged', page_size=4, num_pages=8,
                      mesh=mesh)
    for i, p, n in reqs:
        eng.submit(Request(i, p, max_new_tokens=n))
    out = {r.id: r.out_tokens for r in eng.run_until_drained()}
    return out, eng

base, base_eng = run()
assert len(base) == 10
assert base_eng.reg.counter('serve_admission_deferred_total').get() > 0
for n in (2, 4, 8):
    out, eng = run(make_mesh((n,), ('model',)))
    assert out == base, f'stream divergence at n={n}'
    st = eng.kv.memory_stats()
    assert st.mesh_chips == n
    assert st.bytes_per_chip == st.bytes_total // n
    # one fused dispatch per iteration survives the shard_map
    iters = eng.reg.counter('serve_iterations_total').get()
    assert eng.reg.counter('serve_decode_dispatches_total').get() == iters
    print(f'OK streams n={n}')
print('OK sharded engine parity (gather)')
""")


def test_sharded_engine_stream_parity_pallas(subproc):
    """Same stream-parity bar for the page-table-walking kernel in
    interpret mode: sharded pallas == single-device pallas == single-device
    gather (smaller workload — the CPU interpreter pays per grid point)."""
    subproc(HEADER + """
rng = np.random.default_rng(31)
reqs = [(i, rng.integers(0, cfg.vocab_size,
                         int(rng.integers(2, 8))).astype(np.int32),
         int(rng.integers(2, 5))) for i in range(6)]

def run(mesh=None, impl='pallas'):
    eng = ServeEngine(lm, params, max_batch=4, max_seq=16,
                      cache_backend='paged', page_size=4, num_pages=16,
                      decode_impl=impl, mesh=mesh)
    for i, p, n in reqs:
        eng.submit(Request(i, p, max_new_tokens=n))
    return {r.id: r.out_tokens for r in eng.run_until_drained()}

base = run(None, 'gather')
assert run(None, 'pallas') == base
for n in (2, 4, 8):
    assert run(make_mesh((n,), ('model',))) == base, f'divergence at n={n}'
    print(f'OK streams n={n}')
print('OK sharded engine parity (pallas)')
""")


def test_sharded_int8_quantized_parity(subproc):
    """Int8 KV pages under the kv_pages mesh: the scale arrays must shard
    with their pools (P/n pages of scales per chip), quantization happens
    inside the shard_map body, and the 2/4-way int8 engines — gather and
    pallas-interpret — emit bitwise the single-device *fp32* engine's
    greedy streams."""
    subproc(HEADER + """
rng = np.random.default_rng(37)
reqs = [(i, rng.integers(0, cfg.vocab_size,
                         int(rng.integers(2, 9))).astype(np.int32),
         int(rng.integers(2, 6))) for i in range(8)]

def run(mesh=None, impl='gather', kv_dtype='native'):
    eng = ServeEngine(lm, params, max_batch=4, max_seq=32,
                      cache_backend='paged', page_size=4, num_pages=16,
                      decode_impl=impl, mesh=mesh, kv_dtype=kv_dtype)
    for i, p, n in reqs:
        eng.submit(Request(i, p, max_new_tokens=n))
    out = {r.id: r.out_tokens for r in eng.run_until_drained()}
    return out, eng

base, _ = run()
assert len(base) == 8
for n in (2, 4):
    mesh = make_mesh((n,), ('model',))
    for impl in ('gather', 'pallas'):
        out, eng = run(mesh, impl, 'int8')
        assert out == base, f'int8 stream divergence n={n} impl={impl}'
        st = eng.kv.memory_stats()
        assert st.kv_dtype == 'int8' and st.mesh_chips == n
        assert st.bytes_per_chip == st.bytes_total // n
        layers = eng.kv.state['layers']
        assert layers['k'].dtype == jnp.int8
        # scale arrays shard P/n with their pools: each chip holds only
        # its page range's scales
        for name in ('k_scale', 'v_scale'):
            arr = layers[name]
            shards = arr.addressable_shards
            assert len(shards) == n, name
            assert shards[0].data.shape[1] == arr.shape[1] // n, name
        print(f'OK int8 streams n={n} impl={impl}')
print('OK sharded int8 parity')
""")


def test_prefix_shared_pages_span_chips(subproc):
    """Prefix sharing across the chip boundary: with per-chip capacity
    smaller than one request's footprint, a slot's pages (and the shared
    prefix pages a second request maps) land on different chips — streams
    must still match the single-device engine exactly."""
    subproc(HEADER + """
mesh = make_mesh((4,), ('model',))
sys_prompt = (np.arange(9) % cfg.vocab_size).astype(np.int32)

# allocator-level: a 5-page footprint exceeds the 4-pages-per-chip shard,
# so the grab must spill; shared pages stay put, fresh pages go elsewhere
kv = lm.init_cache(4, 32, dtype=jnp.float32, backend='paged', page_size=4,
                   num_pages=16, mesh=mesh)
assert kv.alloc(0, 17, prefix=sys_prompt) == 0          # 5 pages: spills
chips0 = {p // kv.pages_per_chip for p in kv._slot_pages[0]}
assert len(chips0) > 1, (kv._slot_pages[0], kv.pages_per_chip)
assert kv.alloc(1, 17, prefix=sys_prompt) == 8          # 2 shared + 3 fresh
assert kv._slot_pages[1][:2] == kv._slot_pages[0][:2]
chips1 = {p // kv.pages_per_chip for p in kv._slot_pages[1]}
assert len(chips0 | chips1) > 1

# engine-level: same-prefix requests on that mesh match single-device
rng = np.random.default_rng(5)
prompts = [np.concatenate([sys_prompt,
                           rng.integers(0, cfg.vocab_size, 2)
                           .astype(np.int32)]) for _ in range(5)]

def run(mesh):
    eng = ServeEngine(lm, params, max_batch=4, max_seq=32,
                      cache_backend='paged', page_size=4, num_pages=16,
                      mesh=mesh)
    for i, p in enumerate(prompts):
        eng.submit(Request(i, p, max_new_tokens=4))
    out = {r.id: r.out_tokens for r in eng.run_until_drained()}
    assert eng.kv.memory_stats().pages_in_use == 0
    return out

assert run(mesh) == run(None)
print('OK cross-chip prefix sharing parity')
""")

def test_sharded_chip_failure_drain_parity(subproc):
    """Fault tolerance on a real kv_pages mesh: one chip of the 2- and
    4-way sharded pool fails mid-flight (its free list drains, capacity
    degrades P -> P·(n-1)/n), streams holding pages there are recovered
    via recompute-on-resume, and every completed stream is bitwise
    identical to the clean sharded run.  The pool is sized so slots must
    span chips by the fire iteration, guaranteeing real victims."""
    subproc(HEADER + """
from repro.serve import FaultEvent, FaultPlan

rng = np.random.default_rng(29)
reqs = [(i, rng.integers(0, cfg.vocab_size,
                         int(rng.integers(2, 10))).astype(np.int32),
         int(rng.integers(3, 7))) for i in range(8)]

def run(mesh, plan=None):
    # 15 usable pages vs up to 16 pages of live footprint: slots spill
    # across chips within the first decode iterations
    eng = ServeEngine(lm, params, max_batch=4, max_seq=32,
                      cache_backend='paged', page_size=4, num_pages=16,
                      mesh=mesh, fault_plan=plan, watchdog_iters=16,
                      verify_cache=plan is not None)
    for i, p, n in reqs:
        eng.submit(Request(i, p, max_new_tokens=n))
    done = eng.run_until_drained(max_iters=2000)
    return {r.id: (r.status, tuple(r.out_tokens)) for r in done}, eng

# clean streams are mesh-invariant (sharded parity), so one baseline
# serves every width
base, _ = run(make_mesh((2,), ('model',)))
assert all(st == 'completed' for st, _ in base.values())

for n in (2, 4):
    mesh = make_mesh((n,), ('model',))
    out, eng = run(mesh, FaultPlan([FaultEvent(3, 'chip_failure', chip=1)]))
    victims = eng.reg.counter('serve_stream_retries_total').get(
        {'reason': 'chip_failure'})
    assert victims >= 1, f'chip failure drained no victims at n={n}'
    completed = [i for i, (st, _) in out.items() if st == 'completed']
    assert completed
    for i in completed:
        assert out[i][1] == base[i][1], \
            f'stream {i} diverged after chip drain at n={n}'
    st = eng.kv.memory_stats()
    assert st.chips_failed == 1 and st.mesh_chips == n
    assert eng.kv.usable_pages() == (n - 1) * eng.kv.pages_per_chip - 1
    eng.kv.verify()
    print(f'OK n={n}: {victims:.0f} victim(s), '
          f'{len(completed)}/8 completed bitwise')
print('OK sharded chip drain parity (2/4-way)')
""")

# ------------------------------------------- chunked prefill under mesh ----

def test_sharded_chunked_prefill_stream_parity(subproc):
    """Chunked prefill through the unified shard_map primitive: the 2/4-way
    sharded chunked engine (gather, plus pallas-interpret at 2-way) emits
    bitwise the single-device whole-prompt engine's streams — chunk writes
    land as per-chip mode='drop' scatters and chunk attention merges
    partial softmaxes across the pool shards."""
    subproc(HEADER + """
rng = np.random.default_rng(41)
reqs = [(i, rng.integers(0, cfg.vocab_size,
                         int(rng.integers(2, 14))).astype(np.int32),
         int(rng.integers(3, 7))) for i in range(8)]

def run(mesh=None, chunk=0, impl='gather'):
    eng = ServeEngine(lm, params, max_batch=4, max_seq=32,
                      cache_backend='paged', page_size=4, num_pages=16,
                      mesh=mesh, decode_impl=impl, prefill_chunk=chunk)
    for i, p, n in reqs:
        eng.submit(Request(i, p, max_new_tokens=n))
    out = {r.id: r.out_tokens for r in eng.run_until_drained()}
    return out, eng

base, _ = run()
assert len(base) == 8
chunked, _ = run(chunk=4)
assert chunked == base, 'single-device chunked != whole-prompt'
for n in (2, 4):
    out, eng = run(make_mesh((n,), ('model',)), chunk=4)
    assert out == base, f'chunked stream divergence at n={n}'
    assert eng.reg.counter('serve_prefill_chunks_total').get() > 0
    st = eng.kv.memory_stats()
    assert st.mesh_chips == n and st.bytes_per_chip == st.bytes_total // n
    print(f'OK chunked streams n={n}')
out, _ = run(make_mesh((2,), ('model',)), chunk=4, impl='pallas')
assert out == base, 'chunked stream divergence (pallas, n=2)'
print('OK sharded chunked prefill parity')
""")


def test_sharded_chunked_int8_parity(subproc):
    """Int8 KV pages + chunked prefill + kv_pages mesh, gather and
    pallas-interpret: chunk K/V quantize before the sharded scatter, scales
    land through the same mode='drop' routing, and the streams are bitwise
    the single-device fp32 whole-prompt engine's."""
    subproc(HEADER + """
rng = np.random.default_rng(43)
reqs = [(i, rng.integers(0, cfg.vocab_size,
                         int(rng.integers(2, 12))).astype(np.int32),
         int(rng.integers(2, 6))) for i in range(6)]

def run(mesh=None, chunk=0, impl='gather', kv_dtype='native'):
    eng = ServeEngine(lm, params, max_batch=4, max_seq=32,
                      cache_backend='paged', page_size=4, num_pages=16,
                      mesh=mesh, decode_impl=impl, prefill_chunk=chunk,
                      kv_dtype=kv_dtype)
    for i, p, n in reqs:
        eng.submit(Request(i, p, max_new_tokens=n))
    return {r.id: r.out_tokens for r in eng.run_until_drained()}

base = run()
mesh = make_mesh((2,), ('model',))
for impl in ('gather', 'pallas'):
    out = run(mesh, chunk=4, impl=impl, kv_dtype='int8')
    assert out == base, f'int8 chunked divergence impl={impl}'
    print(f'OK int8 chunked streams impl={impl}')
print('OK sharded int8 chunked parity')
""")


def test_2d_mesh_dp_by_pool_stream_parity(subproc):
    """2-D batch x pages mesh (dp=2, model=2): the pool shards P/2 over the
    model axis and replicates across dp, dispatch batch dims shard over dp,
    and the partial-softmax merge psums over the pool axis per DP replica.
    Whole-prompt AND chunked engines must emit bitwise the single-device
    streams, and memory accounting must report the pool-axis split only."""
    subproc(HEADER + """
rng = np.random.default_rng(47)
reqs = [(i, rng.integers(0, cfg.vocab_size,
                         int(rng.integers(2, 14))).astype(np.int32),
         int(rng.integers(3, 7))) for i in range(8)]

def run(mesh=None, chunk=0, dp_axis=None):
    eng = ServeEngine(lm, params, max_batch=4, max_seq=32,
                      cache_backend='paged', page_size=4, num_pages=16,
                      mesh=mesh, dp_axis=dp_axis, prefill_chunk=chunk)
    for i, p, n in reqs:
        eng.submit(Request(i, p, max_new_tokens=n))
    out = {r.id: r.out_tokens for r in eng.run_until_drained()}
    return out, eng

base, _ = run()
mesh = make_mesh((2, 2), ('data', 'model'))
out, eng = run(mesh, dp_axis='data')
assert out == base, '2-D whole-prompt stream divergence'
st = eng.kv.memory_stats()
assert st.mesh_chips == 2                    # pool splits over 'model' only
assert st.bytes_per_chip == st.bytes_total // 2
# pool shards really are replicated across dp: 4 addressable shards, 2
# distinct page ranges
k = eng.kv.state['layers']['k']
assert len(k.addressable_shards) == 4
assert k.addressable_shards[0].data.shape[1] == k.shape[1] // 2
out, _ = run(mesh, chunk=4, dp_axis='data')
assert out == base, '2-D chunked stream divergence'
print('OK 2-D (dp=2, model=2) mesh parity, whole-prompt + chunked')
""")


def test_sharded_prefill_write_transient_is_block_sized(subproc):
    """The tentpole's measurable claim: the unified shard_map prefill write
    stages only the O(group x block) K/V block per chip — its compiled
    transient is INDEPENDENT of pool size P and far below the pool bytes a
    replicated-pool GSPMD transient would cost (the retained
    ``gspmd_write_prefill`` baseline is compiled alongside for the
    record)."""
    subproc(HEADER + """
from repro.serve import prefill_transient_bytes

def temps(num_pages, group=4, block=64, n=4):
    mesh = make_mesh((n,), ('model',))
    kv = lm.init_cache(8, 2048, dtype=jnp.float32, backend='paged',
                       page_size=8, num_pages=num_pages, mesh=mesh)
    layers = kv.state['layers']
    kv_block = {k: jax.ShapeDtypeStruct(
        (cfg.num_layers, group, block) + v.shape[3:], jnp.float32)
        for k, v in layers.items()}
    dest = jax.ShapeDtypeStruct((group, block), jnp.int32)
    def t(fn):
        c = jax.jit(fn).lower(layers, kv_block, dest).compile()
        return c.memory_analysis().temp_size_in_bytes
    return t(kv.staged_write_prefill), t(kv.gspmd_write_prefill), \
        kv.memory_stats()

measured = {P: temps(P) for P in (64, 256, 1024)}
staged0 = measured[64][0]
analytic = prefill_transient_bytes(cfg, 4, 64, jnp.float32)
for P, (staged, gspmd, st) in measured.items():
    print(f'P={P}: staged={staged} gspmd={gspmd} pool={st.bytes_total}')
    assert staged == staged0, 'write transient grew with pool size'
    assert staged <= analytic, (staged, analytic)
# at the largest pool the block transient is far below even one shard
assert staged0 < measured[1024][2].bytes_per_chip
assert staged0 < measured[1024][2].bytes_total
print('OK block-sized prefill write transient (P-independent)')
""")
