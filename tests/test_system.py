"""End-to-end behaviour tests for the paper's system: a full training job
through the fault-tolerant runtime with real data pipeline, checkpointing,
telemetry, alerting, and failure injection — the whole stack in one test."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CONFIGS, TrainConfig
from repro.core import (AlertManager, FTTrainLoop, MetricsRegistry, SlackSink,
                        StragglerDetector)
from repro.data import (DeterministicLoader, LoaderConfig, TokenDataset,
                        synthetic_corpus, write_token_shards)
from repro.models import LM, ForwardOpts
from repro.train import init_train_state, make_train_step


def test_end_to_end_ft_training_job(tmp_path):
    # --- substrate: data pipeline over real files ---------------------------
    toks = synthetic_corpus(100_000, vocab=512, seed=0)
    write_token_shards(str(tmp_path / "data"), toks)
    ds = TokenDataset(str(tmp_path / "data"))
    loader = DeterministicLoader(ds, LoaderConfig(batch_size=4, seq_len=48))

    # --- model + trainer -----------------------------------------------------
    cfg = dataclasses.replace(CONFIGS["granite-8b"].reduced(),
                              dtype="float32", num_layers=2)
    lm = LM(cfg)
    tcfg = TrainConfig(learning_rate=3e-3, warmup_steps=4, total_steps=24)
    opts = ForwardOpts(attn_impl="dense", remat="none")
    state = init_train_state(lm, jax.random.key(0), tcfg)
    step = jax.jit(make_train_step(lm, tcfg, opts))

    # --- FT runtime with telemetry + alerts + failure injection -------------
    reg = MetricsRegistry()
    loop = FTTrainLoop(step, state, str(tmp_path / "ckpt"), ckpt_every=6,
                       registry=reg)
    get_batch = lambda s: loader.batch_at(s)
    final = loop.run(get_batch, 24, fail_at=lambda s: s == 13)

    assert loop.restarts == 1
    assert int(final["step"]) == 24
    losses = [m["loss"] for m in loop.metrics_log]
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]                       # it learns
    assert reg.counter("checkpoints_written").get() >= 3
    assert reg.histogram("train_step_seconds").count() >= 24

    # alerting stack sees the runtime's metrics
    det = StragglerDetector(reg)
    det.observe_step(100.0)                             # synthetic straggler
    am = AlertManager(reg, sinks=[SlackSink()])
    am.evaluate()


def test_dryrun_artifacts_are_coherent():
    """Integration check over generated dry-run records (skipped when the
    sweep has not been run in this checkout)."""
    import json
    from pathlib import Path
    import pytest
    d = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
    recs = list(d.glob("*/*.json")) if d.exists() else []
    if not recs:
        pytest.skip("dry-run sweep artifacts not present")
    n_ok = 0
    for p in recs:
        r = json.loads(p.read_text())
        if r.get("skipped"):
            continue
        assert r.get("ok"), f"{p} failed: {r.get('error')}"
        assert r["cost_analysis"]["flops"] > 0, p
        assert r["chips"] in (256, 512)
        n_ok += 1
    assert n_ok >= 30
