"""Chunked prefill interleaved with decode: token-stream parity with
whole-prompt prefill, the bounded-stall guarantee, and page-aware
incremental allocation (banker-safe admission, chunk-time stall/resume,
decode shielding).

The correctness bar mirrors the paged-cache one: chunking is a *scheduling*
change, so a chunked engine must emit bitwise-identical token streams to a
whole-prompt engine for every chunk size — including chunks that equal the
prefill bucket and chunks that don't divide the prompt length — while never
letting a decode iteration wait on more than one budget's worth of prefill
compute."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.models import LM, ForwardOpts
from repro.serve import Request, ServeEngine


def small_lm(name="llama3.2-3b", layers=2):
    cfg = dataclasses.replace(CONFIGS[name].reduced(), dtype="float32",
                              num_layers=layers)
    lm = LM(cfg)
    return cfg, lm, lm.init(jax.random.key(0))


def cache_only_lm(name="llama3.2-3b", layers=2):
    """LM without params — for host-side allocator tests (no dispatches)."""
    cfg = dataclasses.replace(CONFIGS[name].reduced(), dtype="float32",
                              num_layers=layers)
    return cfg, LM(cfg)


def _streams(eng):
    return sorted((r.id, tuple(r.out_tokens)) for r in eng.finished)


# ------------------------------------------------- token-stream parity ----

def test_chunked_prefill_logits_bitwise_match_whole_prompt():
    """lm-level exactness: landing a prompt through lm.prefill_chunk in
    uneven chunks must leave the paged pools in a state whose decode logits
    — and whose final-chunk sampling row — are bitwise identical to
    whole-prompt prefill (dense attention, the serving default)."""
    cfg, lm, params = small_lm()
    S, page, plen, chunk = 32, 4, 14, 6
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
    opts = ForwardOpts(attn_impl="dense", remat="none")

    whole = lm.init_cache(1, S, dtype=jnp.float32, backend="paged",
                          page_size=page)
    assert whole.alloc(0, plen + 4, prefix=prompt) == 0
    logits_full, _, pc = lm.forward(
        params, {"tokens": jnp.asarray(prompt[None])}, opts,
        collect_cache=True)
    whole.write_prefill(0, pc["layers"])

    chunked = lm.init_cache(1, S, dtype=jnp.float32, backend="paged",
                            page_size=page)
    assert chunked.alloc_chunked(0, plen + 4, first=min(chunk, plen),
                                 prefix=prompt) == 0
    done, last_logits = 0, None
    while done < plen:
        end = min(done + chunk, plen)
        cover = plen + 4 if end == plen else end
        assert chunked.extend(0, cover)
        tokens = np.zeros((1, chunk), np.int32)
        tokens[0, :end - done] = prompt[done:end]
        cache = {"layers": chunked.state["layers"],
                 "page_table": jnp.asarray(chunked.table_row(0)[None])}
        last_logits, cache = lm.prefill_chunk(
            params, jnp.asarray(tokens), cache,
            jnp.asarray([done], jnp.int32),
            jnp.asarray(chunked.chunk_dest(0, done, end, chunk)[None]),
            jnp.asarray([end - 1], jnp.int32))
        chunked.update({**chunked.state, "layers": cache["layers"]})
        done = end
    # the final chunk's sampling row == the whole forward's last prompt row
    np.testing.assert_array_equal(np.asarray(last_logits[:, -1]),
                                  np.asarray(logits_full[:, plen - 1]))
    # and the pools decode identically from here on
    tok = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 1)), jnp.int32)
    pos = jnp.asarray([plen], jnp.int32)
    lw, _ = lm.decode_step(params, tok, whole.decode_view(), pos)
    lc, _ = lm.decode_step(params, tok, chunked.decode_view(), pos)
    np.testing.assert_array_equal(np.asarray(lw), np.asarray(lc))


# ------------------------------------------------------- bounded stall ----

def test_long_admission_never_stalls_decode_streams():
    """While a long prompt chunk-prefills, every live decode stream must
    emit exactly one token per engine iteration — the fused-step cadence
    a whole-prompt admission provably breaks (its serve_decode_stall_iters
    fires)."""
    cfg, lm, params = small_lm()
    rng = np.random.default_rng(11)
    shorts = [rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
              for _ in range(2)]
    long_p = rng.integers(0, cfg.vocab_size, 33).astype(np.int32)

    def seed_engine(**kw):
        eng = ServeEngine(lm, params, max_batch=4, max_seq=64,
                          cache_backend="paged", page_size=8, **kw)
        for i, p in enumerate(shorts):
            eng.submit(Request(i, p.copy(), max_new_tokens=20))
        eng.step()
        eng.step()
        eng.submit(Request(9, long_p.copy(), max_new_tokens=4))
        return eng

    eng = seed_engine(prefill_chunk=8)
    long_active = False
    for _ in range(40):
        before = {i: len(eng.slot_req[i].out_tokens)
                  for i in range(eng.B)
                  if eng.slot_req[i] is not None and eng.active[i]}
        eng.step()
        for i, n in before.items():
            assert len(eng.slot_req[i].out_tokens) == n + 1, (
                f"stream in slot {i} skipped an iteration while the long "
                "prompt prefilled")
        if any(r is not None and r.id == 9 and eng.active[i]
               for i, r in enumerate(eng.slot_req)):
            long_active = True
            break
    assert long_active, "long prompt never finished its chunks"
    # 1 chunk per short prompt + ceil(33/8) = 5 for the long one
    assert eng.reg.counter("serve_prefill_chunks_total").get() == 7
    assert eng.reg.counter("serve_decode_stall_iters").get() == 0
    eng.run_until_drained()
    assert len(eng.finished) == 3

    whole = seed_engine()
    whole.run_until_drained()
    assert whole.reg.counter("serve_decode_stall_iters").get() >= 1
    assert _streams(whole) == _streams(eng)


# --------------------------------------- page-aware incremental alloc ----

def test_tight_pool_admits_long_prompt_that_whole_prefill_defers():
    """The incremental-allocation payoff: shorts hold most of a tight pool;
    a long prompt's full footprint exceeds the free pages, so whole-prompt
    admission defers it — but its *first chunk* fits and the banker check
    proves the shorts' completions will free the rest, so the chunked
    engine admits it immediately and lands it with chunk-time
    stall/resume.  Streams must still match an unconstrained contiguous
    engine bitwise."""
    cfg, lm, params = small_lm()
    rng = np.random.default_rng(3)
    shorts = [rng.integers(0, cfg.vocab_size, 4).astype(np.int32)
              for _ in range(2)]
    long_p = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)

    def drive(eng):
        for i, p in enumerate(shorts):
            eng.submit(Request(i, p.copy(), max_new_tokens=6))
        eng.step()
        eng.step()
        eng.submit(Request(9, long_p.copy(), max_new_tokens=4))
        for _ in range(300):
            if not eng.step() and not eng.queue:
                break
        return eng

    # 7 usable pages of 4: shorts hold 3 pages each (footprint 10), the
    # long needs 7 (footprint 28) — free is 1 when it arrives
    chunked = drive(ServeEngine(lm, params, max_batch=4, max_seq=32,
                                cache_backend="paged", page_size=4,
                                num_pages=8, prefill_chunk=4))
    assert len(chunked.finished) == 3
    assert chunked.reg.counter("serve_admission_deferred_total").get() == 0
    assert chunked.reg.counter("serve_prefill_chunk_stalls_total").get() > 0

    whole = drive(ServeEngine(lm, params, max_batch=4, max_seq=32,
                              cache_backend="paged", page_size=4,
                              num_pages=8))
    assert len(whole.finished) == 3
    assert whole.reg.counter("serve_admission_deferred_total").get() > 0

    contig = drive(ServeEngine(lm, params, max_batch=4, max_seq=32,
                               cache_backend="contiguous"))
    assert _streams(chunked) == _streams(whole) == _streams(contig)


# --------------------------------------------- allocator unit coverage ----

def test_alloc_chunked_banker_denies_mutual_starvation():
    """Two long chunked prefills that would each starve the other: the
    second admission must be deferred — this is exactly the deadlock the
    banker check exists to prevent."""
    cfg, lm = cache_only_lm()
    kv = lm.init_cache(4, 32, dtype=jnp.float32, backend="paged",
                       page_size=4, num_pages=8)        # 7 usable
    assert kv.alloc_chunked(0, 28, first=4) == 0        # 1 page now, 6 later
    refs = kv._ref.copy()
    assert kv.alloc_chunked(1, 28, first=4) is None     # 6+6 > 7: unsafe
    np.testing.assert_array_equal(kv._ref, refs)        # clean rollback
    assert kv._slot_pages[1] == [] and kv._slot_need[1] == 0
    # a short whole-prompt request still fits alongside the long prefill
    assert kv.alloc(1, 8) == 0


def test_extend_stall_resume_and_need_accounting():
    cfg, lm = cache_only_lm()
    kv = lm.init_cache(4, 32, dtype=jnp.float32, backend="paged",
                       page_size=4, num_pages=8)        # 7 usable
    assert kv.alloc_chunked(0, 28, first=4) == 0
    assert kv._slot_need[0] == 6
    assert kv.alloc(1, 12) == 0                         # 3 pages, safe
    assert kv.extend(0, 16)                             # +3 pages, safe
    assert kv._slot_need[0] == 3 and len(kv._slot_pages[0]) == 4
    assert not kv.extend(0, 20)                         # pool dry: stall
    assert kv._slot_need[0] == 3                        # untouched by stall
    kv.free(1)
    assert kv.extend(0, 28)                             # resume to full
    assert kv._slot_need[0] == 0 and len(kv._slot_pages[0]) == 7
    kv.free(0)
    assert kv.memory_stats().pages_in_use == 0


def test_decode_shield_masks_table_row():
    cfg, lm = cache_only_lm()
    kv = lm.init_cache(2, 32, dtype=jnp.float32, backend="paged",
                       page_size=4, num_pages=16)
    assert kv.alloc(0, 8) == 0
    assert kv.alloc_chunked(1, 16, first=8) == 0
    assert kv.table_row(1).max() > 0
    kv.set_decode_shield(1, True)
    tbl = np.asarray(kv.decode_view()["page_table"])
    assert (tbl[1] == 0).all(), "shielded row must read as scratch"
    assert tbl[0].max() > 0, "other slots unaffected"
    assert kv.table_row(1).max() > 0, "real row intact for chunk dispatch"
    kv.set_decode_shield(1, False)
    assert np.asarray(kv.decode_view()["page_table"])[1].max() > 0
    kv.set_decode_shield(1, True)
    kv.free(1)          # free drops the shield with the pages
    assert 1 not in kv._shielded


def test_chunked_prefix_sharing_registers_only_landed_pages():
    """A chunked request's prompt pages become shareable page-by-page as
    their chunks land — never at alloc time, when their content is still
    pending."""
    cfg, lm = cache_only_lm()
    kv = lm.init_cache(4, 32, dtype=jnp.float32, backend="paged",
                       page_size=4, num_pages=16)
    prompt = np.arange(12, dtype=np.int32)
    assert kv.alloc_chunked(0, 16, first=4, prefix=prompt) == 0
    # nothing landed yet: an identical prompt shares nothing
    assert kv.alloc_chunked(1, 16, first=4, prefix=prompt) == 0
    kv.free(1)
    kv.register_landed(0, prompt, 4)        # page 0 landed
    assert kv.alloc_chunked(2, 16, first=4, prefix=prompt) == 4
    kv.free(2)
    assert kv.extend(0, 8)
    kv.register_landed(0, prompt, 8)        # pages 0-1 landed
    assert kv.alloc_chunked(3, 16, first=4, prefix=prompt) == 8


def test_chunked_engine_constructor_validation():
    """One assertion per row of the chunked-prefill capability matrix.
    Since the unified shard_map primitive, ``mesh=`` is NOT a chunking
    offence — the last case proves a chunked engine constructs on a mesh —
    and ``--prefill-chunk --mesh --cache-backend contiguous`` reports the
    contiguous/mesh conflict (cache construction precedes chunk
    validation), not a chunking error."""
    from repro.parallel.mesh import make_mesh
    cfg, lm, params = small_lm()
    with pytest.raises(ValueError, match="page-aware"):
        ServeEngine(lm, params, max_batch=2, max_seq=32,
                    cache_backend="contiguous", prefill_chunk=8)
    with pytest.raises(ValueError, match="budget"):
        ServeEngine(lm, params, max_batch=2, max_seq=32,
                    cache_backend="paged", prefill_chunk=8,
                    prefill_budget=4)
    with pytest.raises(ValueError, match="prefill_chunk"):
        ServeEngine(lm, params, max_batch=2, max_seq=32,
                    cache_backend="paged", prefill_budget=16)
    # MoE: expert-capacity token dropping is computed per forwarded
    # sequence, so per-chunk routing would diverge from whole-prompt —
    # chunking must be rejected (params never touched before validation)
    moe_cfg = dataclasses.replace(CONFIGS["moonshot-v1-16b-a3b"].reduced(),
                                  dtype="float32", num_layers=2)
    with pytest.raises(ValueError, match="capacity"):
        ServeEngine(LM(moe_cfg), None, max_batch=2, max_seq=32,
                    cache_backend="paged", prefill_chunk=8)
    # VLM image-embed prefixes prefill whole-prompt only
    vlm_cfg = dataclasses.replace(CONFIGS["internvl2-2b"].reduced(),
                                  dtype="float32", num_layers=2)
    with pytest.raises(ValueError, match="token prompts"):
        ServeEngine(LM(vlm_cfg), None, max_batch=2, max_seq=64,
                    cache_backend="paged", prefill_chunk=8)
    mesh = make_mesh((1,), ("model",))
    # contiguous + mesh + chunk: first offence is the contiguous layout's
    # missing page dim, raised at cache construction before any chunk check
    with pytest.raises(ValueError, match="page dim"):
        ServeEngine(lm, params, max_batch=2, max_seq=32,
                    cache_backend="contiguous", mesh=mesh, prefill_chunk=8)
    # paged + mesh + chunk constructs: chunking is mesh-clean now
    eng = ServeEngine(lm, params, max_batch=2, max_seq=32,
                      cache_backend="paged", mesh=mesh, prefill_chunk=8)
    assert eng.chunk == 8 and eng.kv.mesh is mesh


def test_chunked_stream_parity_on_one_chip_mesh():
    """Tier-1 (single-device) coverage of the sharded chunk path: a
    mesh=(1,) chunked engine routes every chunk through
    ``sharded_prefill_chunk_attention`` — local scatter, C-row partials,
    (trivial) merge — and must emit bitwise the mesh-free engine's
    streams."""
    from repro.parallel.mesh import make_mesh
    cfg, lm, params = small_lm()
    rng = np.random.default_rng(13)
    reqs = [(i, rng.integers(0, cfg.vocab_size,
                             int(rng.integers(2, 14))).astype(np.int32),
             int(rng.integers(3, 6))) for i in range(6)]

    def run(mesh=None):
        eng = ServeEngine(lm, params, max_batch=4, max_seq=32,
                          cache_backend="paged", page_size=4, num_pages=16,
                          mesh=mesh, prefill_chunk=4)
        for i, p, n in reqs:
            eng.submit(Request(i, p.copy(), max_new_tokens=n))
        eng.run_until_drained()
        return eng

    base = run()
    eng = run(make_mesh((1,), ("model",)))
    assert _streams(eng) == _streams(base)
    assert eng.reg.counter("serve_prefill_chunks_total").get() > 0


def test_stalled_prefill_gets_freed_pages_before_new_admissions():
    """Fairness under sustained traffic: a mid-prefill long prompt whose
    chunk stalled must claim pages freed by completions *before* the next
    iteration's admissions hand them to newer, shorter requests — chunks
    retry ahead of `_admit`, so churning shorts can slow the long prompt
    but never starve it."""
    cfg, lm, params = small_lm()
    rng = np.random.default_rng(7)
    # 7 usable pages of 4.  Shorts: footprint 8 -> 2 pages.  Long: 24+4
    # -> 7 pages, chunked 4 at a time.
    eng = ServeEngine(lm, params, max_batch=4, max_seq=32,
                      cache_backend="paged", page_size=4, num_pages=8,
                      prefill_chunk=4)
    next_id = 0
    for _ in range(2):
        eng.submit(Request(next_id, rng.integers(
            0, cfg.vocab_size, 4).astype(np.int32), max_new_tokens=4))
        next_id += 1
    eng.step()
    long_id = 999
    eng.submit(Request(long_id, rng.integers(
        0, cfg.vocab_size, 24).astype(np.int32), max_new_tokens=4))
    # keep one short queued at all times for 60 iterations
    for _ in range(60):
        if len(eng.queue) < 2:
            eng.submit(Request(next_id, rng.integers(
                0, cfg.vocab_size, 4).astype(np.int32), max_new_tokens=4))
            next_id += 1
        eng.step()
        if any(r.id == long_id for r in eng.finished):
            break
    assert any(r.id == long_id for r in eng.finished), (
        "long prompt starved by short-request traffic")
    assert eng.reg.counter("serve_prefill_chunk_stalls_total").get() > 0
