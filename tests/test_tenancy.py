"""Multi-tenant units on both resource layers.

* The seed cluster layer (``repro.core.tenancy``): namespace quotas over
  the gang scheduler — create/resize/submit/complete bookkeeping, quota
  rejection accounting, and the usage report.  These classes shipped with
  the seed but had no dedicated tests.
* The serving layer (``repro.serve.tenancy``): the shared priority-class
  registry, ``TenancyConfig`` validation + CLI parsing, and the pure
  ``next_victim`` preemption policy that the engine builds on.

Engine-level integration (quota denies, preemption + resume, per-class
budgets) lives in tests/test_serve_tenant.py.
"""
import pytest

from repro.core.cluster import SimCluster
from repro.core.scheduler import GangScheduler, Job, JobState
from repro.core.telemetry import MetricsRegistry
from repro.core.tenancy import (BATCH, DEFAULT_CLASSES, INTERACTIVE,
                                PriorityClass, TenantScheduler)
from repro.serve.tenancy import (TenancyConfig, TenantSpec, Victim,
                                 next_victim)


# ------------------------------------------------------------------ core ----
def _tenant_sched(n_nodes=20, buffer_fraction=0.0, reg=None):
    cluster = SimCluster(n_nodes, seed=0)
    return TenantScheduler(GangScheduler(cluster,
                                         buffer_fraction=buffer_fraction),
                           registry=reg)


def test_namespace_create_and_quota_accounting():
    reg = MetricsRegistry()
    ts = _tenant_sched(reg=reg)
    ns = ts.create_namespace("train", 12, priority=5)
    ts.create_namespace("serve", 8)
    assert ns.available == 12
    assert reg.gauge("tenant_quota_nodes").get({"namespace": "train"}) == 12

    assert ts.submit("train", Job("j1", 10))
    assert ns.used_nodes == 10 and ns.available == 2
    assert reg.gauge("tenant_used_nodes").get({"namespace": "train"}) == 10
    # namespace priority floors the job priority
    assert ts.sched.jobs["j1"].priority == 5
    assert ts.sched.jobs["j1"].state == JobState.RUNNING

    # over-quota submit is rejected and counted, scheduler never sees it
    assert not ts.submit("train", Job("j2", 3))
    assert "j2" not in ts.sched.jobs
    assert reg.counter("tenant_quota_rejections").get(
        {"namespace": "train"}) == 1

    ts.complete("j1")
    assert ns.used_nodes == 0
    assert ts.sched.jobs["j1"].state == JobState.DONE
    assert "j1" not in ts.job_ns


def test_namespace_overcommit_rejected():
    ts = _tenant_sched(n_nodes=10)
    ts.create_namespace("a", 7)
    with pytest.raises(AssertionError):
        ts.create_namespace("b", 4)          # 7 + 4 > 10 nodes


def test_resize_moves_capacity_between_tenants():
    ts = _tenant_sched(n_nodes=10)
    a = ts.create_namespace("train", 6)
    b = ts.create_namespace("serve", 4)
    assert ts.submit("train", Job("j1", 4))
    # can't shrink below live usage, can't grow past the cluster
    with pytest.raises(AssertionError):
        ts.resize_namespace("train", 3)
    with pytest.raises(AssertionError):
        ts.resize_namespace("serve", 5)      # 6 + 5 > 10
    # the paper's training -> inference capacity shift
    ts.resize_namespace("train", 4)
    ts.resize_namespace("serve", 6)
    assert a.quota_nodes == 4 and b.quota_nodes == 6
    assert ts.submit("serve", Job("j2", 6))


def test_usage_report_lists_every_namespace():
    ts = _tenant_sched()
    ts.create_namespace("train", 12, priority=5)
    ts.create_namespace("serve", 8)
    ts.submit("train", Job("j1", 3))
    report = ts.usage_report()
    assert report == ["train: 3/12 nodes (prio 5)",
                      "serve: 0/8 nodes (prio 0)"]


# -------------------------------------------------------- class registry ----
def test_default_classes_shared_registry():
    assert DEFAULT_CLASSES == {"interactive": INTERACTIVE, "batch": BATCH}
    assert INTERACTIVE.priority > BATCH.priority
    assert not INTERACTIVE.preemptible and BATCH.preemptible
    # the serving layer re-exports the same objects — one registry
    from repro.serve.tenancy import DEFAULT_CLASSES as serve_classes
    assert serve_classes is DEFAULT_CLASSES


# --------------------------------------------------------- TenancyConfig ----
def test_tenancy_config_lookup_helpers():
    cfg = TenancyConfig([TenantSpec("chat", "interactive"),
                         TenantSpec("bulk", "batch", page_quota=10)])
    assert cfg.spec("bulk").page_quota == 10
    assert cfg.class_of("chat") is INTERACTIVE
    assert cfg.priority_of("chat") > cfg.priority_of("bulk")
    assert cfg.has_quotas()
    assert not TenancyConfig([TenantSpec("a")]).has_quotas()
    with pytest.raises(ValueError):
        cfg.spec("nobody")


@pytest.mark.parametrize("tenants, classes", [
    ([], None),                                              # no tenants
    ([TenantSpec("a"), TenantSpec("a")], None),              # duplicate
    ([TenantSpec("a", cls="gold")], None),                   # unknown class
    ([TenantSpec("a", page_quota=0)], None),                 # quota < 1
    ([TenantSpec("a")],
     {"oops": PriorityClass("batch", 0)}),                   # key != name
])
def test_tenancy_config_validation(tenants, classes):
    with pytest.raises(ValueError):
        TenancyConfig(tenants, classes=classes)


def test_tenancy_config_parse_cli_strings():
    cfg = TenancyConfig.parse("chat=interactive,bulk=batch",
                              "bulk=12", preemption=False)
    assert sorted(cfg.tenants) == ["bulk", "chat"]
    assert cfg.spec("bulk").page_quota == 12
    assert cfg.spec("chat").page_quota is None
    assert not cfg.preemption
    with pytest.raises(ValueError):
        TenancyConfig.parse("chat=interactive", "bulk=12")   # unknown tenant
    with pytest.raises(ValueError):
        TenancyConfig.parse("chat=gold", "")                 # unknown class
    with pytest.raises(ValueError):
        TenancyConfig.parse("chat=interactive", "chat=zero")  # bad int


# ------------------------------------------------------------ next_victim ----
def test_next_victim_policy():
    lo = Victim(slot=0, priority=0, preemptible=True, freeable=4)
    lo2 = Victim(slot=1, priority=0, preemptible=True, freeable=7)
    mid = Victim(slot=2, priority=50, preemptible=True, freeable=9)
    pinned = Victim(slot=3, priority=0, preemptible=False, freeable=99)

    # lowest priority class first; within it, most freeable pages
    assert next_victim([lo, lo2, mid, pinned], 100) == lo2
    # slot index breaks exact (priority, freeable) ties deterministically
    tie = Victim(slot=9, priority=0, preemptible=True, freeable=7)
    assert next_victim([tie, lo2], 100) == lo2
    # non-preemptible classes are never chosen, whatever they'd free
    assert next_victim([pinned], 100) is None
    # equal priority never preempts (anti-livelock), only strictly lower
    assert next_victim([lo, lo2], 0) is None
    assert next_victim([mid], 50) is None
    assert next_victim([], 100) is None
