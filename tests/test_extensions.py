"""Extended functionality: fused AdamW/softmax-xent kernels, multi-tenant
quotas, AIOps anomaly detection, serving sampling, sequence packing, and
MoE-dispatch property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# optional dev dependency (requirements-dev.txt): skip the module instead of
# erroring the whole suite's collection when hypothesis isn't installed
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (AnomalyDetector, GangScheduler, Job, MetricsRegistry,
                        Namespace, SimCluster, TenantScheduler,
                        render_dashboard)
from repro.data import pack_documents
from repro.kernels import ops, ref
from repro.serve import SamplingParams, sample_token

RNG = np.random.default_rng(7)


# ------------------------------------------------------------ new kernels ----

@pytest.mark.parametrize("n,block", [(1000, 256), (4096, 1024), (37, 16)])
def test_adamw_fused_matches_ref(n, block):
    g = jnp.asarray(RNG.normal(0, 1, n), jnp.bfloat16)
    m = jnp.asarray(RNG.normal(0, 0.1, n), jnp.float32)
    v = jnp.asarray(np.abs(RNG.normal(0, 0.01, n)), jnp.float32)
    p = jnp.asarray(RNG.normal(0, 1, n), jnp.float32)
    kw = dict(lr=1e-3, beta1=0.9, beta2=0.95, eps=1e-8, weight_decay=0.1,
              step=7)
    nm, nv, np_ = ops.adamw_fused(g, m, v, p, block=block, **kw)
    rm, rv, rp = ref.adamw_ref(g, m, v, p, **kw)
    np.testing.assert_allclose(np.asarray(nm), np.asarray(rm), rtol=1e-5,
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(nv), np.asarray(rv), rtol=1e-5,
                               atol=1e-7)
    np.testing.assert_allclose(np.asarray(np_), np.asarray(rp), rtol=1e-5,
                               atol=1e-6)


@pytest.mark.parametrize("n,vp,vocab", [(16, 128, 128), (24, 256, 200),
                                        (8, 1024, 1000)])
def test_softmax_xent_matches_ref(n, vp, vocab):
    logits = jnp.asarray(RNG.normal(0, 2, (n, vp)), jnp.float32)
    labels = jnp.asarray(RNG.integers(0, vocab, n), jnp.int32)
    out = ops.softmax_xent(logits, labels, vocab=vocab, block_rows=4)
    exp = ref.softmax_xent_ref(logits, labels, vocab=vocab)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-5,
                               atol=1e-5)


# ------------------------------------------------------------ multi-tenant ----

def test_tenant_quota_enforced_and_resize():
    cluster = SimCluster(40, seed=0)
    sched = GangScheduler(cluster, buffer_fraction=0.0)
    reg = MetricsRegistry()
    t = TenantScheduler(sched, reg)
    t.create_namespace("training", 30, priority=1)
    t.create_namespace("inference", 8)
    assert t.submit("training", Job("big", 24))
    assert not t.submit("training", Job("too-big", 10))   # over quota
    assert reg.counter("tenant_quota_rejections").get(
        {"namespace": "training"}) == 1
    assert t.submit("inference", Job("serve", 6))
    # business-needs shift: move capacity from training to inference
    t.complete("big")
    t.resize_namespace("training", 20)
    t.resize_namespace("inference", 18)
    assert t.submit("inference", Job("serve2", 10))
    assert "inference: 16/18" in " ".join(t.usage_report())


def test_tenant_cannot_overcommit_cluster():
    cluster = SimCluster(10, seed=0)
    t = TenantScheduler(GangScheduler(cluster, 0.0))
    t.create_namespace("a", 7)
    with pytest.raises(AssertionError):
        t.create_namespace("b", 4)


# ------------------------------------------------------------------ AIOps ----

def test_anomaly_detector_flags_persistent_shift_only():
    det = AnomalyDetector(threshold=4.0, persistence=3, min_history=12)
    labels = {"node": "7"}
    for _ in range(30):
        assert det.observe("gpu_power_w", labels, 400 + RNG.normal(0, 2)) \
            is None
    # one spike: no alarm
    assert det.observe("gpu_power_w", labels, 150.0) is None
    # persistent power-brake level: alarm on the 3rd consecutive sample
    assert det.observe("gpu_power_w", labels, 150.0) is None
    a = det.observe("gpu_power_w", labels, 150.0)
    assert a is not None and a.zscore < -4
    assert "node" in str(a.labels)


def test_dashboard_renders_cluster_state():
    reg = MetricsRegistry()
    cluster = SimCluster(4, seed=0, registry=reg)
    from repro.core import FailureKind
    cluster.inject(2, FailureKind.POWER_BRAKE)
    reg.histogram("train_step_seconds").observe(5.0)
    text = render_dashboard(reg, "vela")
    assert "VELA DASHBOARD" in text
    assert "node performance factor" in text
    assert "0.375" in text


# ------------------------------------------------------------- sampling ----

def test_sampling_modes():
    logits = np.array([1.0, 5.0, 2.0, 4.9], np.float32)
    greedy = sample_token(logits, SamplingParams(temperature=0.0), 0)
    assert greedy == 1
    # top-k=1 == greedy even at high temperature
    assert sample_token(logits, SamplingParams(temperature=2.0, top_k=1,
                                               seed=3), 0) == 1
    # nucleus keeps only the two near-top entries
    picks = {sample_token(logits, SamplingParams(temperature=1.0, top_p=0.9,
                                                 seed=s), s)
             for s in range(50)}
    assert picks <= {1, 3}
    # determinism per (seed, step)
    a = sample_token(logits, SamplingParams(temperature=1.0, seed=11), 4)
    b = sample_token(logits, SamplingParams(temperature=1.0, seed=11), 4)
    assert a == b


# ------------------------------------------------------------ packing -------

def test_pack_documents_masks_and_boundaries():
    docs = [np.arange(1, 6), np.arange(10, 13), np.arange(20, 29)]
    out = pack_documents(docs, seq_len=12, eos_id=0)
    toks, labels, mask = out["tokens"], out["labels"], out["loss_mask"]
    assert toks.shape == labels.shape == mask.shape
    assert toks.shape[1] == 12
    # next-token alignment wherever the mask is on
    for i in range(toks.shape[0]):
        for t in range(11):
            if mask[i, t] == 1.0:
                assert labels[i, t] == toks[i, t + 1]
    # boundary positions (EOS) are masked out
    for i in range(toks.shape[0]):
        for t in range(12):
            if toks[i, t] == 0 and t > 0:
                assert mask[i, t] == 0.0


@given(st.lists(st.integers(1, 20), min_size=1, max_size=12),
       st.integers(8, 64))
@settings(max_examples=50, deadline=None)
def test_pack_documents_properties(doc_lens, seq_len):
    docs = [np.full(n, 7, np.int32) for n in doc_lens]
    out = pack_documents(docs, seq_len=seq_len, eos_id=0)
    assert out["tokens"].shape[1] == seq_len
    # masked fraction sane and all masked positions have aligned labels
    assert (out["loss_mask"] <= 1).all() and (out["loss_mask"] >= 0).all()
    on = out["loss_mask"][:, :-1] == 1.0
    np.testing.assert_array_equal(out["labels"][:, :-1][on],
                                  out["tokens"][:, 1:][on])


# ----------------------------------------------------- MoE dispatch props ----

@given(st.integers(0, 1000), st.sampled_from([4, 8]),
       st.sampled_from([1, 2, 3]))
@settings(max_examples=30, deadline=None)
def test_moe_dispatch_capacity_invariants(seed, e, k):
    """Every kept slot lands in-range; per-expert slot usage never exceeds
    capacity; dropped tokens are exactly those over capacity."""
    import dataclasses
    from repro.configs import CONFIGS
    from repro.models.moe import _capacity, route
    rng = np.random.default_rng(seed)
    cfg = dataclasses.replace(CONFIGS["moonshot-v1-16b-a3b"].reduced(),
                              num_experts=e, experts_per_token=k,
                              capacity_factor=1.0)
    sg = 16
    xg = jnp.asarray(rng.normal(0, 1, (1, sg, cfg.d_model)), jnp.float32)
    p = {"kernel": jnp.asarray(rng.normal(0, 0.1, (cfg.d_model, e)),
                               jnp.float32)}
    gates, ids, aux = route(p, cfg, xg)
    cap = _capacity(sg, cfg)
    ids_sm = np.asarray(ids[0]).T.reshape(-1)
    onehot = np.eye(e, dtype=int)[ids_sm]
    pos = (np.cumsum(onehot, 0) - onehot)[np.arange(k * sg), ids_sm]
    kept = pos < cap
    # per-expert kept count <= capacity
    for ex in range(e):
        assert ((ids_sm == ex) & kept).sum() <= cap
    assert float(aux) >= 0.0
