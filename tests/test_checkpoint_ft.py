"""Checkpointing + fault-tolerant runtime: roundtrip, corruption detection,
crash-consistency, Young policy, loss-trajectory equivalence under injected
failures, and the <10% lost-time simulation (paper §2.3.3)."""
import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS, TrainConfig
from repro.core import (CheckpointManager, FTTrainLoop, MetricsRegistry,
                        latest_step, load_checkpoint, save_checkpoint,
                        simulate_job)
from repro.core.runtime import job_mtbf_seconds
from repro.models import LM, ForwardOpts, make_batch
from repro.train import init_train_state, make_train_step

OPTS = ForwardOpts(attn_impl="dense", remat="none")


def _tiny_setup(tmp_path, name="qwen3-4b"):
    cfg = dataclasses.replace(CONFIGS[name].reduced(), dtype="float32",
                              num_layers=2)
    lm = LM(cfg)
    tcfg = TrainConfig(learning_rate=5e-3, warmup_steps=2, total_steps=40)
    state = init_train_state(lm, jax.random.key(0), tcfg)
    step = jax.jit(make_train_step(lm, tcfg, OPTS))
    return cfg, lm, state, step


def test_checkpoint_roundtrip_and_latest(tmp_path):
    cfg, lm, state, step = _tiny_setup(tmp_path)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, state, 7)
    save_checkpoint(d, state, 14)
    assert latest_step(d) == 14
    restored, s = load_checkpoint(d, template=state)
    assert s == 14
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_corruption_detected(tmp_path):
    cfg, lm, state, step = _tiny_setup(tmp_path)
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, state, 1)
    shard = next(Path(d, "step_00000001").glob("shard_*.npz"))
    data = bytearray(shard.read_bytes())
    data[100] ^= 0xFF
    shard.write_bytes(bytes(data))
    with pytest.raises(IOError, match="corruption"):
        load_checkpoint(d, template=state)


def test_checkpoint_gc_keeps_last_k(tmp_path):
    cfg, lm, state, step = _tiny_setup(tmp_path)
    d = str(tmp_path / "ckpt")
    for s in range(1, 7):
        save_checkpoint(d, state, s, keep_last=3)
    dirs = sorted(p.name for p in Path(d).glob("step_*"))
    assert dirs == ["step_00000004", "step_00000005", "step_00000006"]


def test_ft_loop_failure_equivalence(tmp_path):
    """Loss trajectory with injected crashes must equal the failure-free run
    (same deterministic data order, restart from checkpoint)."""
    cfg, lm, state, step = _tiny_setup(tmp_path)
    batches = {i: make_batch(cfg, 2, 32, rng=i) for i in range(12)}
    get_batch = lambda i: batches[i]

    clean = FTTrainLoop(step, state, str(tmp_path / "a"), ckpt_every=3)
    clean.run(get_batch, 12)
    faulty = FTTrainLoop(step, state, str(tmp_path / "b"), ckpt_every=3)
    faulty.run(get_batch, 12, fail_at=lambda s: s in (5, 10))
    assert faulty.restarts == 2

    clean_by_step = {m["step"]: m["loss"] for m in clean.metrics_log}
    fault_by_step = {m["step"]: m["loss"] for m in faulty.metrics_log}
    for s in range(12):
        assert fault_by_step[s] == pytest.approx(clean_by_step[s], rel=1e-5)


def test_ft_loop_resume_after_process_restart(tmp_path):
    cfg, lm, state, step = _tiny_setup(tmp_path)
    get_batch = lambda i: make_batch(cfg, 2, 32, rng=i)
    d = str(tmp_path / "c")
    loop1 = FTTrainLoop(step, state, d, ckpt_every=4)
    loop1.run(get_batch, 8)
    # simulates a new process resuming the same job
    loop2 = FTTrainLoop(step, state, d, ckpt_every=4)
    final = loop2.run(get_batch, 12)
    assert int(final["step"]) == 12
    assert loop2.metrics_log[0]["step"] == 8


def test_young_interval_used_by_manager(tmp_path):
    mgr = CheckpointManager(str(tmp_path), delta_seconds=90.0,
                            mtbf_seconds=job_mtbf_seconds(96),
                            step_time=5.0)
    # sqrt(2*90*M)/5 steps; M = 1/(0.04/month*96) ~ 8.1 days
    assert 1000 < mgr.every < 15000
    assert not mgr.should_save(mgr.every - 1)
    assert mgr.should_save(mgr.every)


def test_simulation_lost_time_under_10_percent():
    """The paper's headline: <10% of time lost to failures, Young interval."""
    for seed in (0, 1):
        rep = simulate_job(n_cluster_nodes=110, job_nodes=96,
                           total_steps=60_000, base_step_time=5.0, seed=seed)
        assert rep.lost_fraction < 0.10, rep.summary()
        assert rep.useful_s > 0


def test_simulation_with_aggressive_failures_still_bounded():
    from repro.core.cluster import DEFAULT_RATES
    rates = {k: 5 * v for k, v in DEFAULT_RATES.items()}  # 10%/mo crashes
    rep = simulate_job(n_cluster_nodes=120, job_nodes=96,
                       total_steps=40_000, base_step_time=5.0, seed=2,
                       rates=rates)
    # worst-case month in the paper is 5%: we stress 2x beyond and require
    # bounded degradation rather than the clean <10%
    assert rep.lost_fraction < 0.25, rep.summary()
    assert rep.restarts >= 1 or rep.node_swaps >= 1
