"""Per-kernel allclose sweeps vs the pure-jnp oracles (deliverable c):
shapes × dtypes, interpret mode on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,kv,g,d,bq,bk", [
    (1, 128, 1, 1, 64, 64, 64),
    (2, 256, 2, 3, 64, 128, 64),
    (1, 128, 4, 2, 128, 32, 128),
    (2, 64, 1, 8, 32, 64, 32),
])
def test_flash_attention_sweep(b, s, kv, g, d, bq, bk, dtype):
    q = jnp.asarray(RNG.normal(0, 1, (b, s, kv, g, d)), dtype)
    k = jnp.asarray(RNG.normal(0, 1, (b, s, kv, d)), dtype)
    v = jnp.asarray(RNG.normal(0, 1, (b, s, kv, d)), dtype)
    o = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    q2 = q.transpose(0, 2, 3, 1, 4).reshape(b * kv * g, s, d)
    k2 = k.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    v2 = v.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    exp = ref.flash_attention_ref(q2, k2, v2, causal=True)
    exp = exp.reshape(b, kv, g, s, d).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_flash_attention_non_causal():
    b, s, kv, g, d = 1, 128, 2, 2, 64
    q = jnp.asarray(RNG.normal(0, 1, (b, s, kv, g, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(0, 1, (b, s, kv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(0, 1, (b, s, kv, d)), jnp.float32)
    o = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    q2 = q.transpose(0, 2, 3, 1, 4).reshape(b * kv * g, s, d)
    k2 = k.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    v2 = v.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    exp = ref.flash_attention_ref(q2, k2, v2, causal=False)
    exp = exp.reshape(b, kv, g, s, d).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(np.asarray(o), np.asarray(exp), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,d,br", [(128, 64, 64), (384, 256, 128),
                                    (64, 1024, 32)])
def test_rmsnorm_sweep(n, d, br, dtype):
    x = jnp.asarray(RNG.normal(0, 2, (n, d)), dtype)
    sc = jnp.asarray(RNG.normal(1, 0.2, (d,)), jnp.float32)
    o = ops.rmsnorm(x, sc, block_rows=br)
    exp = ref.rmsnorm_ref(x, sc)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (1, 64, 1, 16, 8, 16),
    (2, 128, 3, 32, 16, 32),
    (1, 256, 2, 64, 64, 64),
])
def test_mamba2_ssd_sweep(b, s, h, p, n, chunk, dtype):
    x = jnp.asarray(RNG.normal(0, 0.5, (b, s, h, p)), dtype)
    da = -jnp.asarray(RNG.uniform(0.001, 0.3, (b, s, h)), jnp.float32)
    bm = jnp.asarray(RNG.normal(0, 0.5, (b, s, n)), dtype)
    cm = jnp.asarray(RNG.normal(0, 0.5, (b, s, n)), dtype)
    o = ops.mamba2_ssd(x, da, bm, cm, chunk=chunk)
    x2 = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    da2 = da.transpose(0, 2, 1).reshape(b * h, s)
    exp = ref.ssd_ref(x2, da2, bm, cm).reshape(b, h, s, p).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,k,chunk", [
    (1, 64, 1, 16, 16),
    (2, 128, 3, 32, 32),
    (1, 96, 2, 64, 32),
])
def test_rwkv6_wkv_sweep(b, s, h, k, chunk, dtype):
    r = jnp.asarray(RNG.normal(0, 0.5, (b, s, h, k)), dtype)
    kk = jnp.asarray(RNG.normal(0, 0.5, (b, s, h, k)), dtype)
    vv = jnp.asarray(RNG.normal(0, 0.5, (b, s, h, k)), dtype)
    lw = jnp.maximum(
        -jnp.asarray(RNG.uniform(0.001, 1.5, (b, s, h, k)), jnp.float32),
        -2.5)
    u = jnp.asarray(RNG.normal(0, 0.3, (h, k)), jnp.float32)
    o = ops.rwkv6_wkv(r, kk, vv, lw, u, chunk=chunk)

    def fold(t):
        return t.transpose(0, 2, 1, 3).reshape(b * h, s, -1)
    exp = ref.wkv6_ref(fold(r), fold(kk), fold(vv), fold(lw), u)
    exp = exp.reshape(b, h, s, k).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_chunked_model_forms_match_naive_recurrence():
    """The models' chunked-parallel SSD/WKV (the XLA path, not just the
    kernels) must equal the naive scan oracle."""
    from repro.models.mamba2 import ssd_chunked
    from repro.models.rwkv6 import wkv6_chunked
    b, s, h, p, n = 2, 96, 2, 16, 8
    x = jnp.asarray(RNG.normal(0, 0.5, (b, s, h, p)), jnp.float32)
    da = -jnp.asarray(RNG.uniform(0.001, 0.3, (b, s, h)), jnp.float32)
    bm = jnp.asarray(RNG.normal(0, 0.5, (b, s, n)), jnp.float32)
    cm = jnp.asarray(RNG.normal(0, 0.5, (b, s, n)), jnp.float32)
    y = ssd_chunked(x, da, bm, cm, chunk=32)
    x2 = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    da2 = da.transpose(0, 2, 1).reshape(b * h, s)
    exp = ref.ssd_ref(x2, da2, bm, cm).reshape(b, h, s, p).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(exp), rtol=2e-5,
                               atol=2e-5)

    k = 16
    r = jnp.asarray(RNG.normal(0, 0.5, (b, s, h, k)), jnp.float32)
    kk = jnp.asarray(RNG.normal(0, 0.5, (b, s, h, k)), jnp.float32)
    vv = jnp.asarray(RNG.normal(0, 0.5, (b, s, h, k)), jnp.float32)
    lw = jnp.maximum(
        -jnp.asarray(RNG.uniform(0.001, 1.5, (b, s, h, k)), jnp.float32),
        -2.5)
    u = jnp.asarray(RNG.normal(0, 0.3, (h, k)), jnp.float32)
    y = wkv6_chunked(r, kk, vv, lw, u, chunk=16)
    if isinstance(y, tuple):
        y = y[0]

    def fold(t):
        return t.transpose(0, 2, 1, 3).reshape(b * h, s, -1)
    exp = ref.wkv6_ref(fold(r), fold(kk), fold(vv), fold(lw), u)
    exp = exp.reshape(b, h, s, k).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(exp), rtol=2e-5,
                               atol=2e-5)
