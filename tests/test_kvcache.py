"""Pluggable KV-cache API: paged backend parity, page-table lifecycle,
prefix sharing, and admission control.

The correctness bar for ``PagedCache`` is *exactness*: the gathered page
view preserves logical row order, so decode logits must match the dense
contiguous layout bit-for-bit, and the engines must emit identical greedy
token streams however tight the page pool (admission order must never
change a request's output — that is the whole point of per-request
determinism in continuous batching)."""
import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.models import LM
from repro.serve import (ContiguousCache, PagedCache, Request, ServeEngine,
                         contiguous_kv_bytes, page_kv_bytes)


def small_lm(name="llama3.2-3b", layers=2):
    cfg = dataclasses.replace(CONFIGS[name].reduced(), dtype="float32",
                              num_layers=layers)
    lm = LM(cfg)
    return cfg, lm, lm.init(jax.random.key(0))


def _ragged_requests(cfg, n, seed=5, lo=2, hi=10, new_lo=4, new_hi=9):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(lo, hi))).astype(np.int32),
                    max_new_tokens=int(rng.integers(new_lo, new_hi)))
            for i in range(n)]


# (Engine stream-parity, int8-parity, tight-pool and soak tests moved to
# tests/test_kvcache_conformance.py — the cross-backend conformance matrix.)

# ------------------------------------------------------- prefix sharing ----

def test_prefix_sharing_refcount_and_free_lifecycle():
    cfg, lm, params = small_lm()
    kv = lm.init_cache(4, 32, dtype=jnp.float32, backend="paged",
                       page_size=4, num_pages=16)
    prompt = np.arange(9, dtype=np.int32)       # 2 full pages + 1 partial
    assert kv.alloc(0, 11, prefix=prompt) == 0          # 3 pages, none shared
    pages0 = list(kv._slot_pages[0])
    assert len(pages0) == 3
    # identical prefix: the 2 full prompt pages are shared, 1 fresh page
    assert kv.alloc(1, 11, prefix=prompt) == 8
    pages1 = list(kv._slot_pages[1])
    assert pages1[:2] == pages0[:2] and pages1[2] != pages0[2]
    st = kv.memory_stats()
    assert st.pages_in_use == 4 and st.pages_shared == 2
    # a different prefix shares nothing
    assert kv.alloc(2, 11, prefix=prompt + 1) == 0
    # freeing one sharer keeps the pages alive for the other
    kv.free(0)
    assert (kv._ref[pages0[:2]] == 1).all()
    assert kv.memory_stats().pages_shared == 0
    assert np.all(kv.page_table[0] == 0)        # freed row points at scratch
    # the surviving sharer still owns them; a new request can still share
    assert kv.alloc(3, 11, prefix=prompt) == 8
    kv.free(1), kv.free(2), kv.free(3)
    st = kv.memory_stats()
    assert st.pages_in_use == 0 and st.slots_in_use == 0
    assert not kv._hash_to_page and not kv._page_to_hash
    # hash registry was cleared with the last ref: nothing to share now
    assert kv.alloc(0, 11, prefix=prompt) == 0


def test_prefix_sharing_disabled_flag():
    cfg, lm, params = small_lm()
    kv = lm.init_cache(2, 32, dtype=jnp.float32, backend="paged",
                       page_size=4, num_pages=16, prefix_sharing=False)
    prompt = np.arange(8, dtype=np.int32)
    assert kv.alloc(0, 10, prefix=prompt) == 0
    assert kv.alloc(1, 10, prefix=prompt) == 0   # nothing shared
    assert kv.memory_stats().pages_shared == 0
    assert set(kv._slot_pages[0]).isdisjoint(kv._slot_pages[1])


def test_shared_prefix_engine_outputs_unchanged():
    """N requests with one system prompt: sharing pins the prefix pages once
    and must not perturb any request's greedy stream (the sharer never
    rewrites shared pages — its prefill scatter routes them to scratch)."""
    cfg, lm, params = small_lm("qwen3-4b")
    sys_prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    rng = np.random.default_rng(17)
    prompts = [np.concatenate([sys_prompt,
                               rng.integers(0, cfg.vocab_size, 3)
                               .astype(np.int32)]) for _ in range(6)]

    def run(**kw):
        eng = ServeEngine(lm, params, max_batch=4, max_seq=32,
                          cache_backend="paged", page_size=4, **kw)
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, max_new_tokens=4))
        out = {r.id: r.out_tokens for r in eng.run_until_drained()}
        return out, eng

    shared_out, _ = run(prefix_sharing=True)
    plain_out, _ = run(prefix_sharing=False)
    assert shared_out == plain_out
    assert len(shared_out) == 6
    # and sharing does kick in at admission time on this workload
    probe = ServeEngine(lm, params, max_batch=4, max_seq=32,
                        cache_backend="paged", page_size=4)
    for i, p in enumerate(prompts):
        probe.submit(Request(i, p, max_new_tokens=4))
    probe._admit()
    assert probe.kv.memory_stats().pages_shared > 0
    assert probe.reg.gauge("serve_kv_pages_shared").get() > 0


# ------------------------------------------------------- admission control ----

def test_page_exhaustion_defers_admission_then_drains():
    cfg, lm, params = small_lm()
    eng = ServeEngine(lm, params, max_batch=4, max_seq=32,
                      cache_backend="paged", page_size=4, num_pages=5)
    # 4 pages usable; each request needs ceil((4+8)/4)=3 pages -> one at a time
    for r in _ragged_requests(cfg, 4, seed=2, lo=4, hi=5, new_lo=8, new_hi=9):
        eng.submit(r)
    eng.step()
    assert sum(r is not None for r in eng.slot_req) == 1   # pool-bound, not slot-bound
    assert eng.reg.counter("serve_admission_deferred_total").get() > 0
    done = eng.run_until_drained()
    assert len(done) == 4                                  # all served eventually
    assert eng.kv.memory_stats().pages_in_use == 0


def test_request_that_can_never_fit_rejected_at_submit():
    cfg, lm, params = small_lm()
    eng = ServeEngine(lm, params, max_batch=2, max_seq=64,
                      cache_backend="paged", page_size=4, num_pages=4)
    with pytest.raises(ValueError, match="can never fit"):
        eng.submit(Request(0, np.zeros(20, np.int32), max_new_tokens=8))


def test_failed_alloc_leaks_no_refcounts():
    cfg, lm, params = small_lm()
    kv = lm.init_cache(4, 64, dtype=jnp.float32, backend="paged",
                       page_size=4, num_pages=6)
    prompt = np.arange(8, dtype=np.int32)
    assert kv.alloc(0, 12, prefix=prompt) == 0             # 3 of 5 pages
    refs_before = kv._ref.copy()
    assert kv.alloc(1, 20, prefix=prompt) is None          # needs 5, only 2 left
    np.testing.assert_array_equal(kv._ref, refs_before)
    assert kv._slot_pages[1] == []
    # a smaller request (sharing the prefix) still fits: 2 shared + 1 fresh
    assert kv.alloc(1, 12, prefix=prompt) == 8


def test_contiguous_backend_alloc_is_unconditional():
    cfg, lm, params = small_lm()
    kv = lm.init_cache(2, 32, dtype=jnp.float32, backend="contiguous")
    assert kv.alloc(0, 32) == 0
    st = kv.memory_stats()
    assert st.slots_in_use == 1
    assert st.bytes_reserved == st.bytes_total      # dense always pins all
    assert st.bytes_total == contiguous_kv_bytes(cfg, 2, 32, jnp.float32)
    kv.free(0)
    assert kv.memory_stats().slots_in_use == 0


def test_paged_memory_accounting():
    cfg, lm, params = small_lm()
    kv = lm.init_cache(2, 32, dtype=jnp.float32, backend="paged",
                       page_size=8, num_pages=9)
    pb = page_kv_bytes(cfg, 8, jnp.float32)
    assert kv.memory_stats().bytes_total == 9 * pb
    kv.alloc(0, 9)                                  # 2 pages
    st = kv.memory_stats()
    assert st.pages_in_use == 2 and st.bytes_reserved == 2 * pb
    assert st.pages_total == 8                      # scratch page excluded


# --------------------------------------------------- batched group prefill ----

def test_same_bucket_prompts_prefill_in_one_dispatch():
    cfg, lm, params = small_lm("qwen3-4b")
    eng = ServeEngine(lm, params, max_batch=4, max_seq=64)
    rng = np.random.default_rng(4)
    for i in range(4):      # all bucket-4 prompts (lengths 3..4)
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 3 + (i % 2))
                           .astype(np.int32), max_new_tokens=3))
    eng.step()
    assert eng.reg.counter("serve_prefill_dispatches_total").get() == 1
    h = eng.reg.histogram("serve_prefill_batch_size")
    assert h.count() == 1 and h.sum() == 4


def test_mixed_bucket_prompts_prefill_one_dispatch_per_bucket():
    cfg, lm, params = small_lm("qwen3-4b")
    eng = ServeEngine(lm, params, max_batch=4, max_seq=64)
    rng = np.random.default_rng(5)
    lens = [3, 4, 9, 12]            # buckets 4, 4, 16, 16
    for i, n in enumerate(lens):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, n)
                           .astype(np.int32), max_new_tokens=3))
    eng.step()
    assert eng.reg.counter("serve_prefill_dispatches_total").get() == 2
    h = eng.reg.histogram("serve_prefill_batch_size")
    assert h.count() == 2 and h.sum() == 4


# ------------------------------------------------------------- engine soak ----

def test_encdec_rejects_paged_backend():
    cfg = dataclasses.replace(CONFIGS["seamless-m4t-large-v2"].reduced(),
                              dtype="float32")
    lm = LM(cfg)
    with pytest.raises(NotImplementedError, match="paged"):
        lm.init_cache(2, 32, dtype=jnp.float32, backend="paged")


# ------------------------------------------------------- int8 KV pages ----

def test_int8_pool_format_and_memory_accounting():
    """The int8 page format: int8 pools + per-row fp32 scale arrays in the
    same layers subtree, with the byte math (`page_kv_bytes`,
    `memory_stats`) accounting for both."""
    from repro.serve.kvcache import SCALE_BYTES, kv_position_bytes

    cfg, lm, params = small_lm()
    kv = lm.init_cache(2, 32, dtype=jnp.float32, backend="paged",
                       page_size=8, num_pages=9, kv_dtype="int8")
    assert kv.quantized and kv.kv_dtype == "int8"
    layers = kv.state["layers"]
    assert set(layers) == {"k", "v", "k_scale", "v_scale"}
    assert layers["k"].dtype == jnp.int8
    assert layers["k_scale"].dtype == jnp.float32
    assert layers["k_scale"].shape == layers["k"].shape[:-1]
    L, kvh, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    assert kv_position_bytes(cfg, jnp.float32, "int8") == \
        2 * L * kvh * (hd + SCALE_BYTES)
    pb = page_kv_bytes(cfg, 8, jnp.float32, kv_dtype="int8")
    st = kv.memory_stats()
    assert st.kv_dtype == "int8"
    assert st.bytes_total == 9 * pb
    assert st.bytes_scales == 9 * 8 * 2 * L * kvh * 4
    # the position-per-byte win vs the native pool this cache replaces
    assert page_kv_bytes(cfg, 8, jnp.float32) / pb > 3
    kv.alloc(0, 9)                                  # 2 pages
    assert kv.memory_stats().bytes_reserved == 2 * pb


def test_int8_rejected_off_paged_backend():
    cfg, lm, params = small_lm()
    with pytest.raises(ValueError, match="int8"):
        lm.init_cache(2, 32, dtype=jnp.float32, backend="contiguous",
                      kv_dtype="int8")
    with pytest.raises(AssertionError, match="paged"):
        lm.init_cache(2, 32, dtype=jnp.float32, kv_dtype="int8")


