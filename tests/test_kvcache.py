"""Pluggable KV-cache API: paged backend parity, page-table lifecycle,
prefix sharing, and admission control.

The correctness bar for ``PagedCache`` is *exactness*: the gathered page
view preserves logical row order, so decode logits must match the dense
contiguous layout bit-for-bit, and the engines must emit identical greedy
token streams however tight the page pool (admission order must never
change a request's output — that is the whole point of per-request
determinism in continuous batching)."""
import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CONFIGS
from repro.models import LM
from repro.serve import (ContiguousCache, PagedCache, Request, ServeEngine,
                         contiguous_kv_bytes, page_kv_bytes)


def small_lm(name="llama3.2-3b", layers=2):
    cfg = dataclasses.replace(CONFIGS[name].reduced(), dtype="float32",
                              num_layers=layers)
    lm = LM(cfg)
    return cfg, lm, lm.init(jax.random.key(0))


def _ragged_requests(cfg, n, seed=5, lo=2, hi=10, new_lo=4, new_hi=9):
    rng = np.random.default_rng(seed)
    return [Request(i, rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(lo, hi))).astype(np.int32),
                    max_new_tokens=int(rng.integers(new_lo, new_hi)))
            for i in range(n)]


# ------------------------------------------------------ exact logit parity ----

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16],
                         ids=["float32", "bfloat16"])
def test_paged_logits_match_contiguous_exactly_ragged_8slot(dtype):
    """Eight slots at eight different depths: the paged decode (scatter via
    page table + gather over pages) must produce bitwise-identical logits to
    the dense (B, Smax) layout — in both cache storage dtypes (bf16 rows
    round identically through both layouts, so parity stays bitwise)."""
    cfg, lm, params = small_lm()
    B, S, pg = 8, 32, 8
    rng = np.random.default_rng(7)
    lens = [3, 11, 7, 1, 14, 5, 9, 2]
    contig = lm.init_cache(B, S, dtype=dtype, backend="contiguous")
    paged = lm.init_cache(B, S, dtype=dtype, backend="paged",
                          page_size=pg)
    for b, plen in enumerate(lens):
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        assert contig.alloc(b, plen + 4) == 0
        assert paged.alloc(b, plen + 4, prefix=prompt) == 0
        _, _, pc = lm.forward(params, {"tokens": jnp.asarray(prompt[None])},
                              collect_cache=True)
        contig.write_prefill(b, pc["layers"])
        paged.write_prefill(b, pc["layers"])
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    positions = jnp.asarray(np.array(lens, np.int32))
    lc, cc = lm.decode_step(params, toks, contig.decode_view(), positions)
    lp, pc2 = lm.decode_step(params, toks, paged.decode_view(), positions)
    np.testing.assert_array_equal(np.asarray(lc), np.asarray(lp))
    # and again after the scatter-written token, through decode_view round-trip
    contig.update(cc)
    paged.update(pc2)
    lc2, _ = lm.decode_step(params, toks, contig.decode_view(), positions + 1)
    lp2, _ = lm.decode_step(params, toks, paged.decode_view(), positions + 1)
    np.testing.assert_array_equal(np.asarray(lc2), np.asarray(lp2))


def test_paged_engine_single_fused_dispatch_and_token_parity():
    """Acceptance: ragged 8-slot workload through the paged engine keeps the
    one-fused-dispatch-per-iteration invariant (serve_decode_dispatches_total
    == iterations) and emits exactly the contiguous engine's tokens."""
    cfg, lm, params = small_lm("qwen3-4b")
    reqs = _ragged_requests(cfg, 12, seed=3)

    paged = ServeEngine(lm, params, max_batch=8, max_seq=64,
                        cache_backend="paged", page_size=8)
    for r in reqs:
        paged.submit(Request(r.id, r.prompt, max_new_tokens=r.max_new_tokens))
    paged_out = {r.id: r.out_tokens for r in paged.run_until_drained()}
    iters = paged.reg.counter("serve_iterations_total").get()
    assert iters > 0
    assert paged.reg.counter("serve_decode_dispatches_total").get() == iters

    contig = ServeEngine(lm, params, max_batch=8, max_seq=64,
                         cache_backend="contiguous")
    for r in reqs:
        contig.submit(Request(r.id, r.prompt,
                              max_new_tokens=r.max_new_tokens))
    contig_out = {r.id: r.out_tokens for r in contig.run_until_drained()}
    assert paged_out == contig_out
    assert len(paged_out) == 12


def test_tight_pool_slot_reuse_parity():
    """A pool holding only ~2 requests forces deferrals, page recycling, and
    scratch-routed writes from freed slots.  Greedy outputs must still match
    an unconstrained contiguous engine exactly — admission order and page
    placement must never leak into a request's tokens."""
    cfg, lm, params = small_lm()
    reqs = _ragged_requests(cfg, 8, seed=13, lo=2, hi=8, new_lo=3, new_hi=6)
    # each request needs at most ceil((7+5)/4)=3 pages; 6 usable pages
    # admit at most ~2 requests at a time
    tight = ServeEngine(lm, params, max_batch=4, max_seq=32,
                        cache_backend="paged", page_size=4, num_pages=7)
    for r in reqs:
        tight.submit(Request(r.id, r.prompt, max_new_tokens=r.max_new_tokens))
    tight_out = {r.id: r.out_tokens for r in tight.run_until_drained()}
    assert len(tight_out) == 8
    assert tight.reg.counter("serve_admission_deferred_total").get() > 0

    contig = ServeEngine(lm, params, max_batch=4, max_seq=32,
                         cache_backend="contiguous")
    for r in reqs:
        contig.submit(Request(r.id, r.prompt,
                              max_new_tokens=r.max_new_tokens))
    contig_out = {r.id: r.out_tokens for r in contig.run_until_drained()}
    assert tight_out == contig_out


# --------------------------------------------------- prefix-share lifecycle ----

def test_prefix_sharing_refcount_and_free_lifecycle():
    cfg, lm, params = small_lm()
    kv = lm.init_cache(4, 32, dtype=jnp.float32, backend="paged",
                       page_size=4, num_pages=16)
    prompt = np.arange(9, dtype=np.int32)       # 2 full pages + 1 partial
    assert kv.alloc(0, 11, prefix=prompt) == 0          # 3 pages, none shared
    pages0 = list(kv._slot_pages[0])
    assert len(pages0) == 3
    # identical prefix: the 2 full prompt pages are shared, 1 fresh page
    assert kv.alloc(1, 11, prefix=prompt) == 8
    pages1 = list(kv._slot_pages[1])
    assert pages1[:2] == pages0[:2] and pages1[2] != pages0[2]
    st = kv.memory_stats()
    assert st.pages_in_use == 4 and st.pages_shared == 2
    # a different prefix shares nothing
    assert kv.alloc(2, 11, prefix=prompt + 1) == 0
    # freeing one sharer keeps the pages alive for the other
    kv.free(0)
    assert (kv._ref[pages0[:2]] == 1).all()
    assert kv.memory_stats().pages_shared == 0
    assert np.all(kv.page_table[0] == 0)        # freed row points at scratch
    # the surviving sharer still owns them; a new request can still share
    assert kv.alloc(3, 11, prefix=prompt) == 8
    kv.free(1), kv.free(2), kv.free(3)
    st = kv.memory_stats()
    assert st.pages_in_use == 0 and st.slots_in_use == 0
    assert not kv._hash_to_page and not kv._page_to_hash
    # hash registry was cleared with the last ref: nothing to share now
    assert kv.alloc(0, 11, prefix=prompt) == 0


def test_prefix_sharing_disabled_flag():
    cfg, lm, params = small_lm()
    kv = lm.init_cache(2, 32, dtype=jnp.float32, backend="paged",
                       page_size=4, num_pages=16, prefix_sharing=False)
    prompt = np.arange(8, dtype=np.int32)
    assert kv.alloc(0, 10, prefix=prompt) == 0
    assert kv.alloc(1, 10, prefix=prompt) == 0   # nothing shared
    assert kv.memory_stats().pages_shared == 0
    assert set(kv._slot_pages[0]).isdisjoint(kv._slot_pages[1])


def test_shared_prefix_engine_outputs_unchanged():
    """N requests with one system prompt: sharing pins the prefix pages once
    and must not perturb any request's greedy stream (the sharer never
    rewrites shared pages — its prefill scatter routes them to scratch)."""
    cfg, lm, params = small_lm("qwen3-4b")
    sys_prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    rng = np.random.default_rng(17)
    prompts = [np.concatenate([sys_prompt,
                               rng.integers(0, cfg.vocab_size, 3)
                               .astype(np.int32)]) for _ in range(6)]

    def run(**kw):
        eng = ServeEngine(lm, params, max_batch=4, max_seq=32,
                          cache_backend="paged", page_size=4, **kw)
        for i, p in enumerate(prompts):
            eng.submit(Request(i, p, max_new_tokens=4))
        out = {r.id: r.out_tokens for r in eng.run_until_drained()}
        return out, eng

    shared_out, _ = run(prefix_sharing=True)
    plain_out, _ = run(prefix_sharing=False)
    assert shared_out == plain_out
    assert len(shared_out) == 6
    # and sharing does kick in at admission time on this workload
    probe = ServeEngine(lm, params, max_batch=4, max_seq=32,
                        cache_backend="paged", page_size=4)
    for i, p in enumerate(prompts):
        probe.submit(Request(i, p, max_new_tokens=4))
    probe._admit()
    assert probe.kv.memory_stats().pages_shared > 0
    assert probe.reg.gauge("serve_kv_pages_shared").get() > 0


# ------------------------------------------------------- admission control ----

def test_page_exhaustion_defers_admission_then_drains():
    cfg, lm, params = small_lm()
    eng = ServeEngine(lm, params, max_batch=4, max_seq=32,
                      cache_backend="paged", page_size=4, num_pages=5)
    # 4 pages usable; each request needs ceil((4+8)/4)=3 pages -> one at a time
    for r in _ragged_requests(cfg, 4, seed=2, lo=4, hi=5, new_lo=8, new_hi=9):
        eng.submit(r)
    eng.step()
    assert sum(r is not None for r in eng.slot_req) == 1   # pool-bound, not slot-bound
    assert eng.reg.counter("serve_admission_deferred_total").get() > 0
    done = eng.run_until_drained()
    assert len(done) == 4                                  # all served eventually
    assert eng.kv.memory_stats().pages_in_use == 0


def test_request_that_can_never_fit_rejected_at_submit():
    cfg, lm, params = small_lm()
    eng = ServeEngine(lm, params, max_batch=2, max_seq=64,
                      cache_backend="paged", page_size=4, num_pages=4)
    with pytest.raises(ValueError, match="can never fit"):
        eng.submit(Request(0, np.zeros(20, np.int32), max_new_tokens=8))


def test_failed_alloc_leaks_no_refcounts():
    cfg, lm, params = small_lm()
    kv = lm.init_cache(4, 64, dtype=jnp.float32, backend="paged",
                       page_size=4, num_pages=6)
    prompt = np.arange(8, dtype=np.int32)
    assert kv.alloc(0, 12, prefix=prompt) == 0             # 3 of 5 pages
    refs_before = kv._ref.copy()
    assert kv.alloc(1, 20, prefix=prompt) is None          # needs 5, only 2 left
    np.testing.assert_array_equal(kv._ref, refs_before)
    assert kv._slot_pages[1] == []
    # a smaller request (sharing the prefix) still fits: 2 shared + 1 fresh
    assert kv.alloc(1, 12, prefix=prompt) == 8


def test_contiguous_backend_alloc_is_unconditional():
    cfg, lm, params = small_lm()
    kv = lm.init_cache(2, 32, dtype=jnp.float32, backend="contiguous")
    assert kv.alloc(0, 32) == 0
    st = kv.memory_stats()
    assert st.slots_in_use == 1
    assert st.bytes_reserved == st.bytes_total      # dense always pins all
    assert st.bytes_total == contiguous_kv_bytes(cfg, 2, 32, jnp.float32)
    kv.free(0)
    assert kv.memory_stats().slots_in_use == 0


def test_paged_memory_accounting():
    cfg, lm, params = small_lm()
    kv = lm.init_cache(2, 32, dtype=jnp.float32, backend="paged",
                       page_size=8, num_pages=9)
    pb = page_kv_bytes(cfg, 8, jnp.float32)
    assert kv.memory_stats().bytes_total == 9 * pb
    kv.alloc(0, 9)                                  # 2 pages
    st = kv.memory_stats()
    assert st.pages_in_use == 2 and st.bytes_reserved == 2 * pb
    assert st.pages_total == 8                      # scratch page excluded


# --------------------------------------------------- batched group prefill ----

def test_same_bucket_prompts_prefill_in_one_dispatch():
    cfg, lm, params = small_lm("qwen3-4b")
    eng = ServeEngine(lm, params, max_batch=4, max_seq=64)
    rng = np.random.default_rng(4)
    for i in range(4):      # all bucket-4 prompts (lengths 3..4)
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, 3 + (i % 2))
                           .astype(np.int32), max_new_tokens=3))
    eng.step()
    assert eng.reg.counter("serve_prefill_dispatches_total").get() == 1
    h = eng.reg.histogram("serve_prefill_batch_size")
    assert h.count() == 1 and h.sum() == 4


def test_mixed_bucket_prompts_prefill_one_dispatch_per_bucket():
    cfg, lm, params = small_lm("qwen3-4b")
    eng = ServeEngine(lm, params, max_batch=4, max_seq=64)
    rng = np.random.default_rng(5)
    lens = [3, 4, 9, 12]            # buckets 4, 4, 16, 16
    for i, n in enumerate(lens):
        eng.submit(Request(i, rng.integers(0, cfg.vocab_size, n)
                           .astype(np.int32), max_new_tokens=3))
    eng.step()
    assert eng.reg.counter("serve_prefill_dispatches_total").get() == 2
    h = eng.reg.histogram("serve_prefill_batch_size")
    assert h.count() == 2 and h.sum() == 4


# ------------------------------------------------------------- engine soak ----

def test_engine_soak_random_schedule_tight_pool_parity_and_telemetry():
    """~200-step soak: a randomized submit schedule trickles ragged requests
    into a pool tight enough to defer admissions and recycle pages/slots
    continuously.  The paged engine must (a) emit exactly the streams an
    unconstrained contiguous engine emits, and (b) keep its pool telemetry
    inside invariants at every step: ``serve_kv_pages_in_use`` never exceeds
    the pool and returns to 0 once drained."""
    cfg, lm, params = small_lm()
    rng = np.random.default_rng(41)
    n_req, steps = 24, 200
    # submit step -> requests arriving then (bursty: several per tick)
    arrivals: dict = {}
    for i in range(n_req):
        arrivals.setdefault(int(rng.integers(0, 60)), []).append(
            Request(i, rng.integers(0, cfg.vocab_size,
                                    int(rng.integers(2, 9))).astype(np.int32),
                    max_new_tokens=int(rng.integers(2, 6))))

    def run(**kw):
        eng = ServeEngine(lm, params, max_batch=4, max_seq=32, **kw)
        pages_total = eng.kv.memory_stats().pages_total
        gauge = eng.reg.gauge("serve_kv_pages_in_use")
        for step in range(steps):
            for r in arrivals.get(step, []):
                eng.submit(Request(r.id, r.prompt,
                                   max_new_tokens=r.max_new_tokens))
            eng.step()
            if kw.get("cache_backend") == "paged":
                st = eng.kv.memory_stats()
                assert 0 <= st.pages_in_use <= pages_total, (step, st)
                assert 0 <= gauge.get() <= pages_total, (step, gauge.get())
                assert st.bytes_reserved <= st.bytes_total
        assert not eng.queue and all(r is None for r in eng.slot_req), \
            "soak schedule must drain within the step budget"
        if kw.get("cache_backend") == "paged":
            eng.kv.verify()       # full sanitizer sweep on the drained pool
        return {r.id: r.out_tokens for r in eng.finished}, eng

    # 8 usable pages, footprints up to ceil((8+5)/4)=4 pages: 2-3 in flight
    paged_out, paged_eng = run(cache_backend="paged", page_size=4,
                               num_pages=9)
    contig_out, _ = run(cache_backend="contiguous")
    assert paged_out == contig_out
    assert len(paged_out) == n_req
    assert paged_eng.reg.counter("serve_admission_deferred_total").get() > 0
    st = paged_eng.kv.memory_stats()
    assert st.pages_in_use == 0 and st.slots_in_use == 0     # fully drained
    assert paged_eng.reg.gauge("serve_kv_pages_in_use").get() == 0


def test_encdec_rejects_paged_backend():
    cfg = dataclasses.replace(CONFIGS["seamless-m4t-large-v2"].reduced(),
                              dtype="float32")
    lm = LM(cfg)
    with pytest.raises(NotImplementedError, match="paged"):
        lm.init_cache(2, 32, dtype=jnp.float32, backend="paged")


# ------------------------------------------------------- int8 KV pages ----

def test_int8_pool_format_and_memory_accounting():
    """The int8 page format: int8 pools + per-row fp32 scale arrays in the
    same layers subtree, with the byte math (`page_kv_bytes`,
    `memory_stats`) accounting for both."""
    from repro.serve.kvcache import SCALE_BYTES, kv_position_bytes

    cfg, lm, params = small_lm()
    kv = lm.init_cache(2, 32, dtype=jnp.float32, backend="paged",
                       page_size=8, num_pages=9, kv_dtype="int8")
    assert kv.quantized and kv.kv_dtype == "int8"
    layers = kv.state["layers"]
    assert set(layers) == {"k", "v", "k_scale", "v_scale"}
    assert layers["k"].dtype == jnp.int8
    assert layers["k_scale"].dtype == jnp.float32
    assert layers["k_scale"].shape == layers["k"].shape[:-1]
    L, kvh, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    assert kv_position_bytes(cfg, jnp.float32, "int8") == \
        2 * L * kvh * (hd + SCALE_BYTES)
    pb = page_kv_bytes(cfg, 8, jnp.float32, kv_dtype="int8")
    st = kv.memory_stats()
    assert st.kv_dtype == "int8"
    assert st.bytes_total == 9 * pb
    assert st.bytes_scales == 9 * 8 * 2 * L * kvh * 4
    # the position-per-byte win vs the native pool this cache replaces
    assert page_kv_bytes(cfg, 8, jnp.float32) / pb > 3
    kv.alloc(0, 9)                                  # 2 pages
    assert kv.memory_stats().bytes_reserved == 2 * pb


def test_int8_rejected_off_paged_backend():
    cfg, lm, params = small_lm()
    with pytest.raises(ValueError, match="int8"):
        lm.init_cache(2, 32, dtype=jnp.float32, backend="contiguous",
                      kv_dtype="int8")
    with pytest.raises(AssertionError, match="paged"):
        lm.init_cache(2, 32, dtype=jnp.float32, kv_dtype="int8")


@pytest.mark.parametrize("impl", ["gather", "pallas"])
def test_int8_decode_logits_close_to_fp32_oracle(impl):
    """Quality gate at the logit level: the ragged 8-slot workload decoded
    off int8 pages must match the fp32 paged oracle within the quantization
    tolerance — and pick the same greedy token everywhere — on both decode
    impls, through two chained steps (the second consumes a quantized
    scatter-written decode token)."""
    cfg, lm, params = small_lm()
    B, S, pg = 8, 32, 8
    rng = np.random.default_rng(7)
    lens = [3, 11, 7, 1, 14, 5, 9, 2]

    def build(kv_dtype):
        kv = lm.init_cache(B, S, dtype=jnp.float32, backend="paged",
                           page_size=pg, decode_impl=impl,
                           kv_dtype=kv_dtype)
        rng2 = np.random.default_rng(7)
        for b, plen in enumerate(lens):
            prompt = rng2.integers(0, cfg.vocab_size, plen).astype(np.int32)
            assert kv.alloc(b, plen + 4, prefix=prompt) == 0
            _, _, pc = lm.forward(params,
                                  {"tokens": jnp.asarray(prompt[None])},
                                  collect_cache=True)
            kv.write_prefill(b, pc["layers"])
        return kv

    oracle, quant = build("native"), build("int8")
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
    pos = jnp.asarray(np.array(lens, np.int32))
    for step in range(2):
        lo, co = lm.decode_step(params, toks, oracle.decode_view(), pos,
                                decode_impl=impl)
        lq, cq = lm.decode_step(params, toks, quant.decode_view(), pos,
                                decode_impl=impl)
        lo, lq = np.asarray(lo), np.asarray(lq)
        # the documented end-to-end bound (benchmarks.bench_serving
        # asserts the same constant over its full workload)
        assert np.abs(lq - lo).max() <= 0.05, (step, np.abs(lq - lo).max())
        np.testing.assert_array_equal(
            lo[..., :cfg.vocab_size].argmax(-1),
            lq[..., :cfg.vocab_size].argmax(-1), err_msg=f"step {step}")
        oracle.update(co), quant.update(cq)
        pos = pos + 1


def test_int8_engine_greedy_stream_parity_and_telemetry():
    """End-to-end quality gate: int8 engines (both decode impls, plus
    chunked prefill) emit bitwise the fp32 engine's greedy streams, and the
    quant telemetry gauges report the format."""
    cfg, lm, params = small_lm("qwen3-4b")
    reqs = _ragged_requests(cfg, 10, seed=29)

    def run(**kw):
        eng = ServeEngine(lm, params, max_batch=4, max_seq=32,
                          cache_backend="paged", page_size=4, **kw)
        for r in reqs:
            eng.submit(Request(r.id, r.prompt,
                               max_new_tokens=r.max_new_tokens))
        return {r.id: r.out_tokens for r in eng.run_until_drained()}, eng

    ref, ref_eng = run()
    assert len(ref) == 10
    for kw in (dict(kv_dtype="int8"),
               dict(kv_dtype="int8", decode_impl="pallas"),
               dict(kv_dtype="int8", prefill_chunk=4)):
        out, eng = run(**kw)
        assert out == ref, kw
        st = eng.kv.memory_stats()
        assert st.kv_dtype == "int8" and st.bytes_scales > 0
        assert eng.reg.gauge("serve_kv_quant_enabled").get() == 1
        assert eng.reg.gauge("serve_kv_quant_scale_bytes").get() == \
            st.bytes_scales
        assert eng.reg.gauge("serve_kv_quant_bytes_saved").get() > 0
        # quantized pool pins fewer bytes than the fp32 pool it replaces
        assert st.bytes_total < ref_eng.kv.memory_stats().bytes_total
    assert ref_eng.reg.gauge("serve_kv_quant_enabled").get() == 0


def test_int8_prefix_sharing_and_tight_pool_parity():
    """Admission control and prefix sharing are format-agnostic: a tight
    int8 pool defers/recycles exactly like fp32 and still matches the
    unconstrained contiguous engine's streams."""
    cfg, lm, params = small_lm()
    reqs = _ragged_requests(cfg, 8, seed=13, lo=2, hi=8, new_lo=3, new_hi=6)
    tight = ServeEngine(lm, params, max_batch=4, max_seq=32,
                        cache_backend="paged", page_size=4, num_pages=7,
                        kv_dtype="int8")
    for r in reqs:
        tight.submit(Request(r.id, r.prompt, max_new_tokens=r.max_new_tokens))
    tight_out = {r.id: r.out_tokens for r in tight.run_until_drained()}
    assert len(tight_out) == 8
    assert tight.reg.counter("serve_admission_deferred_total").get() > 0

    contig = ServeEngine(lm, params, max_batch=4, max_seq=32,
                         cache_backend="contiguous")
    for r in reqs:
        contig.submit(Request(r.id, r.prompt,
                              max_new_tokens=r.max_new_tokens))
    contig_out = {r.id: r.out_tokens for r in contig.run_until_drained()}
    assert tight_out == contig_out
