"""Per-architecture smoke tests (deliverable f): REDUCED config of the same
family, one forward/train step on CPU, assert output shapes + no NaNs.  Plus
cross-implementation equivalences (dense vs blockwise attention; decode vs
full forward; scan vs unrolled layers)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, CONFIGS, TrainConfig
from repro.models import LM, ForwardOpts, make_batch
from repro.train import init_train_state, make_train_step

OPTS = ForwardOpts(attn_impl="dense", remat="none")
ALL = sorted(CONFIGS)


@pytest.mark.parametrize("name", ALL)
def test_smoke_forward_and_train_step(name):
    cfg = CONFIGS[name].reduced()
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    batch = make_batch(cfg, 2, 64)
    logits, aux, _ = lm.forward(params, batch, OPTS)
    seq = 64 if cfg.family != "vlm" else 64  # img tokens prepended internally
    expect_s = (64 - cfg.num_image_tokens + cfg.num_image_tokens
                if cfg.family == "vlm" else 64)
    assert logits.shape == (2, expect_s, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    tcfg = TrainConfig(warmup_steps=2, total_steps=10)
    state = init_train_state(lm, jax.random.key(1), tcfg)
    step = make_train_step(lm, tcfg, OPTS)
    state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(state["step"]) == 1
    # every parameter finite after one update
    assert all(bool(jnp.isfinite(p.astype(jnp.float32)).all())
               for p in jax.tree.leaves(state["params"]))


@pytest.mark.parametrize("name", ["qwen3-4b", "starcoder2-3b", "llama3-405b"])
def test_blockwise_matches_dense_attention(name):
    cfg = dataclasses.replace(CONFIGS[name].reduced(), dtype="float32")
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    batch = make_batch(cfg, 2, 96)
    l1, _, _ = lm.forward(params, batch, ForwardOpts(attn_impl="dense",
                                                     remat="none"))
    l2, _, _ = lm.forward(params, batch,
                          ForwardOpts(attn_impl="blockwise", q_chunk=32,
                                      kv_chunk=32, remat="none"))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=2e-4,
                               atol=2e-4)


@pytest.mark.parametrize("name", sorted(ASSIGNED_ARCHS))
def test_decode_consistent_with_forward(name):
    cfg = dataclasses.replace(ASSIGNED_ARCHS[name].reduced(),
                              dtype="float32", capacity_factor=8.0)
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    S = 32
    batch = make_batch(cfg, 2, S)
    logits_full, _, _ = lm.forward(params, batch, OPTS)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :-1]
    pre.pop("labels", None)
    _, cache = lm.prefill(params, pre, OPTS)

    def pad_kv(x, name):
        if name in ("k", "v"):
            pw = [(0, 0)] * x.ndim
            pw[2] = (0, 1)
            return jnp.pad(x, pw)
        return x

    cache = {k: ({k2: pad_kv(v2, k2) for k2, v2 in v.items()})
             for k, v in cache.items()}
    tok = batch["tokens"][:, -1:]
    idx = jnp.int32(logits_full.shape[1] - 1)
    dl, new_cache = lm.decode_step(params, tok, cache, idx)
    a = np.asarray(logits_full[:, -1, :])
    b = np.asarray(dl[:, 0, :])
    err = np.max(np.abs(a - b)) / (np.max(np.abs(a)) + 1e-9)
    assert err < 1e-4, f"{name}: decode/forward mismatch {err:.2e}"
    # cache structurally unchanged
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


def test_scan_matches_unrolled_layers():
    cfg = dataclasses.replace(CONFIGS["qwen3-4b"].reduced(), dtype="float32")
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    batch = make_batch(cfg, 2, 64)
    l1, _, _ = lm.forward(params, batch,
                          ForwardOpts(attn_impl="dense", remat="none",
                                      scan_layers=True))
    l2, _, _ = lm.forward(params, batch,
                          ForwardOpts(attn_impl="dense", remat="none",
                                      scan_layers=False))
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5,
                               atol=1e-5)


def test_remat_policies_do_not_change_loss():
    cfg = dataclasses.replace(CONFIGS["llama3.2-3b"].reduced(),
                              dtype="float32")
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    batch = make_batch(cfg, 2, 64)
    losses = []
    for remat in ("none", "selective", "full"):
        loss, _ = lm.loss(params, batch,
                          ForwardOpts(attn_impl="dense", remat=remat))
        losses.append(float(loss))
    assert max(losses) - min(losses) < 1e-5


def test_vlm_image_tokens_change_text_logits():
    cfg = dataclasses.replace(CONFIGS["internvl2-2b"].reduced(),
                              dtype="float32")
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    batch = make_batch(cfg, 2, 64)
    l1, _, _ = lm.forward(params, batch, OPTS)
    batch2 = dict(batch)
    batch2["img_embeds"] = batch["img_embeds"] + 1.0
    l2, _, _ = lm.forward(params, batch2, OPTS)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-3


def test_zamba_shared_block_fires():
    """Removing the shared attention block must change the output."""
    cfg = dataclasses.replace(CONFIGS["zamba2-1.2b"].reduced(),
                              dtype="float32")
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    batch = make_batch(cfg, 2, 32)
    l1, _, _ = lm.forward(params, batch, OPTS)
    params2 = jax.tree.map(lambda x: x, params)
    params2["shared"]["attn"]["wo"]["kernel"] = \
        params["shared"]["attn"]["wo"]["kernel"] * 0 + 1.0
    l2, _, _ = lm.forward(params2, batch, OPTS)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-3
