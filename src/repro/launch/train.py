"""End-to-end training driver: data pipeline -> FT runtime -> checkpoints ->
telemetry, runnable on CPU with a reduced config or on a real mesh with the
full config.

    python -m repro.launch.train --arch qwen3-4b --reduced --steps 200
    python -m repro.launch.train --preset quickstart-100m --steps 300
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import CONFIGS, TrainConfig, get_config
from repro.configs.base import ModelConfig
from repro.core import (CheckpointManager, FTTrainLoop, MetricsRegistry,
                        job_mtbf_seconds)
from repro.data import (DeterministicLoader, LoaderConfig, TokenDataset,
                        synthetic_corpus, write_token_shards)
from repro.models import LM, ForwardOpts
from repro.train import init_train_state, make_train_step

QUICKSTART_100M = ModelConfig(
    name="quickstart-100m", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=4, head_dim=64, d_ff=3072, vocab_size=32000)


def build_config(args) -> ModelConfig:
    if args.preset == "quickstart-100m":
        return QUICKSTART_100M
    cfg = get_config(args.arch)
    return cfg.reduced() if args.reduced else cfg


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b",
                    choices=sorted(CONFIGS) + ["quickstart-100m"])
    ap.add_argument("--preset", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--data-dir", default="/tmp/repro_data")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="0 = Young's formula from measured step time")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = build_config(args)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"family={cfg.family}")

    # --- data ---------------------------------------------------------------
    data_dir = Path(args.data_dir) / cfg.name
    if not (data_dir / "index.txt").exists():
        toks = synthetic_corpus(max(2_000_000, args.batch * args.seq * 20),
                                cfg.vocab_size, seed=0)
        write_token_shards(str(data_dir), toks)
    ds = TokenDataset(str(data_dir))
    loader = DeterministicLoader(ds, LoaderConfig(args.batch, args.seq))

    # --- model / trainer -----------------------------------------------------
    lm = LM(cfg)
    tcfg = TrainConfig(learning_rate=args.lr, warmup_steps=20,
                       total_steps=args.steps)
    opts = ForwardOpts(attn_impl="blockwise", q_chunk=min(args.seq, 512),
                       kv_chunk=min(args.seq, 512), remat="none")
    state = init_train_state(lm, jax.random.key(0), tcfg)
    step = jax.jit(make_train_step(lm, tcfg, opts,
                                   microbatches=args.microbatches))

    # --- warmup to measure step time for Young's interval --------------------
    b0 = loader.batch_at(0)
    t0 = time.perf_counter()
    state, _ = step(state, b0)
    jax.block_until_ready(state["step"])
    t_compile = time.perf_counter() - t0
    t0 = time.perf_counter()
    state, _ = step(state, b0)
    jax.block_until_ready(state["step"])
    step_time = time.perf_counter() - t0
    ckpt_every = args.ckpt_every or CheckpointManager(
        args.ckpt_dir, delta_seconds=max(step_time, 1.0),
        mtbf_seconds=job_mtbf_seconds(96), step_time=step_time).every
    ckpt_every = min(ckpt_every, max(args.steps // 3, 1))
    print(f"compile={t_compile:.1f}s step={step_time*1e3:.0f}ms "
          f"ckpt_every={ckpt_every}")

    # --- FT loop --------------------------------------------------------------
    reg = MetricsRegistry()
    loop = FTTrainLoop(step, state, args.ckpt_dir, ckpt_every, registry=reg)
    t0 = time.perf_counter()
    final = loop.run(loader.batch_at, args.steps)
    wall = time.perf_counter() - t0
    for m in loop.metrics_log:
        if m["step"] % args.log_every == 0 or m["step"] == args.steps - 1:
            print(f"step {m['step']:5d} loss {m['loss']:.4f} "
                  f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e}")
    toks = args.steps * args.batch * args.seq
    print(f"done: {args.steps} steps, {toks/wall:.0f} tok/s, "
          f"{reg.counter('checkpoints_written').get():.0f} checkpoints, "
          f"final loss {loop.metrics_log[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
