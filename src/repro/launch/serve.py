"""Batched serving driver (watsonx.ai-style inference cluster role).

Drives the fused ragged continuous-batching engine: one jitted
decode+sample dispatch per iteration regardless of slot positions, batched
group prefill, on-device sampling, and a pluggable KV cache — paged
(page-table indirection + prefix sharing + admission control) by default,
contiguous dense rows via ``--cache-backend contiguous``.

    python -m repro.launch.serve --arch qwen3-4b --reduced --requests 16
    python -m repro.launch.serve --cache-backend paged --page-size 8 \
        --num-pages 48   # tight pool: watch admissions defer, not OOM
    python -m repro.launch.serve --decode-impl pallas   # page-table-walking
        # flash-decode kernel: no gathered dense KV transient per step
    python -m repro.launch.serve --prefill-chunk 16     # chunked prefill:
        # long prompts interleave with decode, no stream ever stalls on
        # more than one chunk of prefill compute
    python -m repro.launch.serve --kv-dtype int8        # int8 KV pages:
        # quantize-on-write, dequant-on-read — same pool HBM holds ~2x
        # the concurrent streams (vs bf16; ~3.8x vs fp32)
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.launch.serve --mesh 4   # sharded paged serving:
        # pools pinned P/4 pages per chip, partial-softmax merged reads
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m repro.launch.serve --mesh dp=2,model=4   # 2-D mesh:
        # pool shards P/4 over the model axis, dispatch batch dims shard
        # over 2 DP replicas, merge runs per replica
    python -m repro.launch.serve \
        --tenants chat=interactive,bulk=batch --quota bulk=24 \
        # multi-tenant SLO serving: priority-ordered admission, per-tenant
        # page quotas in the banker check, preemptive page eviction —
        # interactive traffic admits ahead of (and can preempt) batch
    python -m repro.launch.serve --fault-plan nan_logits@5,poison_page@9 \
        --watchdog-iters 8 --verify-cache   # fault-tolerant serving:
        # injected faults are detected by the fused step's non-finite
        # guard, quarantined streams resume *bitwise* via recompute-on-
        # resume prefill, and the summary reports per-stream outcomes
    python -m repro.launch.serve --host-pages 64 --prefix-store \
        --prefill-chunk 16   # hierarchical KV: cold shared prefixes
        # spill to a 64-page host-RAM tier on their last free and
        # prefetch back on a hash-hit instead of recomputing prefill;
        # --prefix-store runs a warmup pass through a second engine
        # sharing one persistent store, so the reported pass serves warm
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import CONFIGS, get_config
from repro.models import LM
from repro.serve import Request, SamplingParams, ServeEngine


def _parse_mesh(spec: str):
    """Parse --mesh: 'N' -> (0, N) 1-D pool mesh; 'DxM' / 'D,M' /
    'dp=D,model=M' -> (D, M) 2-D batch x pages mesh.  '0' -> (0, 0)."""
    spec = spec.strip().lower()
    if "=" in spec:
        kv = dict(part.split("=", 1) for part in spec.split(","))
        unknown = set(kv) - {"dp", "model"}
        if unknown:
            raise SystemExit(f"--mesh: unknown axes {sorted(unknown)} "
                             "(expected dp=D,model=M)")
        return int(kv.get("dp", 1)), int(kv["model"])
    for sep in ("x", ","):
        if sep in spec:
            d, m = spec.split(sep, 1)
            return int(d), int(m)
    return 0, int(spec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b", choices=sorted(CONFIGS))
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 => greedy; sampling runs on device either way")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--cache-backend", default="paged",
                    choices=["paged", "contiguous"])
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--num-pages", type=int, default=None,
                    help="physical page pool size (default: dense-equivalent"
                         " capacity); smaller pools defer admissions")
    ap.add_argument("--no-prefix-sharing", action="store_true")
    ap.add_argument("--decode-impl", default="gather",
                    choices=["gather", "pallas"],
                    help="paged page-table resolution per decode step: "
                         "'gather' (XLA fallback — materializes a "
                         "dense-equivalent KV view, transient grows with "
                         "batch x pages) or 'pallas' (page-table-walking "
                         "flash-decode kernel, O(page) transient; interpret "
                         "mode on CPU, Mosaic on TPU).  Ignored by "
                         "--cache-backend contiguous")
    ap.add_argument("--kv-dtype", default="native",
                    choices=["native", "int8"],
                    help="page-pool storage format: 'native' (the model "
                         "dtype) or 'int8' — pages stored int8 with "
                         "per-row fp32 scales, quantized on write and "
                         "dequantized on read (in-register inside the "
                         "pallas kernel; in the gathered view under "
                         "'gather').  Requires --cache-backend paged")
    ap.add_argument("--prefill-chunk", type=int, default=0, metavar="C",
                    help="chunked prefill: split admitted prompts into "
                         "C-token chunks interleaved with fused decode "
                         "steps (chunk k attends the pages chunks 0..k-1 "
                         "wrote), claiming pages chunk-by-chunk so a long "
                         "prompt admits into a pool whose free pages cover "
                         "only its first chunk.  0 = whole-prompt prefill.  "
                         "Requires --cache-backend paged; composes with "
                         "--mesh (chunks route through the unified sharded "
                         "write/attend primitive)")
    ap.add_argument("--prefill-budget", type=int, default=0, metavar="T",
                    help="max prefill tokens per engine iteration "
                         "(>= one chunk; default: exactly one chunk) — the "
                         "bound on how long any decode iteration can wait "
                         "on prefill compute")
    ap.add_argument("--mesh", default="0", metavar="N|DxM",
                    help="sharded paged serving over an inference mesh.  "
                         "'N': the page pool's kv_pages dim shards P/N "
                         "pages per chip (pool HBM scales down with N) and "
                         "every dispatch — fused decode, whole-prompt "
                         "prefill writes, chunked prefill — runs under the "
                         "unified shard_map primitive: per-chip "
                         "mode='drop' local pool writes, local-window "
                         "attention partials, one psum-style partial-"
                         "softmax merge.  'DxM' / 'D,M' / 'dp=D,model=M': "
                         "a 2-D batch x pages mesh — the pool shards P/M "
                         "over the model axis (replicated across DP), "
                         "dispatch batch dims shard over D replicas, and "
                         "the merge runs per DP replica.  Requires D*M "
                         "visible devices (on CPU: XLA_FLAGS=--xla_force_"
                         "host_platform_device_count=K) and "
                         "--cache-backend paged.  0 = single-device")
    ap.add_argument("--mesh-axis", default="model",
                    help="mesh axis name the kv_pages dim maps onto "
                         "(default: model, matching the kv_pages sharding "
                         "rule in repro.parallel.sharding)")
    ap.add_argument("--tenants", default="", metavar="N=CLS,...",
                    help="multi-tenant SLO serving: comma-separated "
                         "name=class tenant table (classes: interactive — "
                         "admitted first, never preempted — and batch; "
                         "class defaults to batch when omitted).  Requests "
                         "round-robin over the tenants.  Empty = "
                         "single-tenant FIFO engine")
    ap.add_argument("--quota", default="", metavar="N=PAGES,...",
                    help="per-tenant KV page quotas (name=pages,...): a "
                         "tenant at cap has its admissions quota-denied — "
                         "skipped, not queue-blocking — until its slots "
                         "free pages.  Requires --tenants and the paged "
                         "backend")
    ap.add_argument("--priority", dest="priority", action="store_true",
                    default=True,
                    help="preempt lowest-priority running decodes when a "
                         "higher class cannot admit (pages evicted, request "
                         "re-queued for recompute-on-resume prefill; "
                         "default on)")
    ap.add_argument("--no-priority", dest="priority", action="store_false",
                    help="disable preemption: quotas and priority-ordered "
                         "admission only")
    ap.add_argument("--fault-plan", default="", metavar="PLAN",
                    help="deterministic fault injection: comma-separated "
                         "kind@iteration[:slot=N][:chip=N][:page=N][:dur=N] "
                         "events (kinds: nan_logits, poison_page, "
                         "chip_failure, stall_chunk, dispatch_error), e.g. "
                         "'nan_logits@5,chip_failure@12:chip=1'.  Faulted "
                         "streams are quarantined and resume bitwise via "
                         "recompute-on-resume prefill")
    ap.add_argument("--watchdog-iters", type=int, default=0, metavar="N",
                    help="per-stream progress watchdog: recover any live "
                         "slot that emits no token / lands no chunk for N "
                         "engine iterations (0 = off)")
    ap.add_argument("--max-retries", type=int, default=3,
                    help="recoveries a stream may consume before it "
                         "dead-letters (error surfaced on the request; "
                         "neighbour streams unaffected)")
    ap.add_argument("--verify-cache", action="store_true",
                    help="debug mode: run the PagedCache.verify() "
                         "invariant sanitizer (refcounts, free lists, page "
                         "tables, quotas) after every engine iteration")
    ap.add_argument("--host-pages", type=int, default=0, metavar="N",
                    help="host-RAM page tier: cold shared prefix pages "
                         "spill to N pinned host page buffers when their "
                         "last device reference drops, and admissions that "
                         "hash-hit the stored prefix prefetch the pages "
                         "back instead of recomputing prefill (with "
                         "--prefill-chunk, fully-covered chunks skip their "
                         "forward entirely).  Requires --cache-backend "
                         "paged and prefix sharing.  0 = off")
    ap.add_argument("--prefix-store", action="store_true",
                    help="persistent prefix store demo: serve the workload "
                         "through a warmup engine first, then rebuild the "
                         "engine REUSING the same store — the reported "
                         "pass admits against a warm host tier, showing "
                         "cross-engine prefix persistence.  Requires "
                         "--host-pages")
    args = ap.parse_args()
    if args.prefix_store and not args.host_pages:
        raise SystemExit("--prefix-store persists the host tier across "
                         "engines; size it with --host-pages N")

    import dataclasses
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), dtype="float32")
    lm = LM(cfg)
    params = lm.init(jax.random.key(0))
    mesh, dp_axis = None, None
    dp, nkv = _parse_mesh(args.mesh)
    if nkv:
        from repro.parallel.mesh import make_mesh
        if dp:
            # 2-D batch x pages mesh: dp axis named 'data' (matching the
            # batch sharding rule in repro.parallel.sharding)
            mesh = make_mesh((dp, nkv), ("data", args.mesh_axis))
            dp_axis = "data"
        else:
            mesh = make_mesh((nkv,), (args.mesh_axis,))
    tenancy = None
    if args.tenants:
        from repro.serve import TenancyConfig
        tenancy = TenancyConfig.parse(args.tenants, args.quota,
                                      preemption=args.priority)
    elif args.quota:
        raise SystemExit("--quota requires --tenants")
    fault_plan = None
    if args.fault_plan:
        from repro.serve import FaultPlan
        fault_plan = FaultPlan.parse(args.fault_plan)
    from repro.core.alerts import (AlertManager, DEFAULT_RULES, LogSink,
                                   SERVE_RULES, SlackSink)
    from repro.core.telemetry import MetricsRegistry
    reg = MetricsRegistry()
    slack = SlackSink()
    alerts = AlertManager(reg, sinks=[slack, LogSink()],
                          rules=DEFAULT_RULES + SERVE_RULES)
    store = None
    if args.host_pages:
        from repro.serve import PrefixStore
        store = PrefixStore(args.host_pages)

    def build_engine(registry, with_alerts=True):
        return ServeEngine(lm, params, args.max_batch, args.max_seq,
                           registry=registry,
                           cache_backend=args.cache_backend,
                           page_size=args.page_size,
                           num_pages=args.num_pages,
                           prefix_sharing=not args.no_prefix_sharing,
                           decode_impl=args.decode_impl, mesh=mesh,
                           kv_axis=args.mesh_axis, dp_axis=dp_axis,
                           prefill_chunk=args.prefill_chunk,
                           prefill_budget=args.prefill_budget,
                           kv_dtype=args.kv_dtype, tenancy=tenancy,
                           fault_plan=fault_plan,
                           watchdog_iters=args.watchdog_iters,
                           max_retries=args.max_retries,
                           verify_cache=args.verify_cache,
                           alerts=alerts if with_alerts else None,
                           prefix_store=store)

    tenant_names = sorted(tenancy.tenants) if tenancy else []

    def submit_all(engine):
        rng = np.random.default_rng(0)
        for i in range(args.requests):
            prompt = rng.integers(0, cfg.vocab_size,
                                  rng.integers(4, 12)).astype(np.int32)
            engine.submit(Request(
                i, prompt, max_new_tokens=args.new_tokens,
                tenant=(tenant_names[i % len(tenant_names)]
                        if tenant_names else "default"),
                sampling=SamplingParams(
                    temperature=args.temperature,
                    top_k=args.top_k, top_p=args.top_p, seed=i)))

    if args.prefix_store:
        # warmup engine: same workload, own registry, SAME store — its
        # freed prefixes offload to host and survive the engine teardown
        warm = build_engine(MetricsRegistry(), with_alerts=False)
        t0 = time.perf_counter()
        submit_all(warm)
        warm.run_until_drained(on_stuck="status")
        cold_ttft = warm.reg.histogram(
            "serve_ttft_seconds").quantile(0.5) * 1e3
        print(f"warmup pass: {time.perf_counter()-t0:.1f}s, TTFT p50 "
              f"{cold_ttft:.0f}ms, {store.pages_in_use()} prefix pages "
              f"now host-resident; rebuilding engine on the warm store")
        del warm

    eng = build_engine(reg)
    t0 = time.perf_counter()
    submit_all(eng)
    done = eng.run_until_drained(on_stuck="status")
    wall = time.perf_counter() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    iters = eng.reg.counter("serve_iterations_total").get()
    decode = eng.reg.counter("serve_decode_dispatches_total").get()
    prefill = eng.reg.counter("serve_prefill_dispatches_total").get()
    by_status = {}
    for r in done:
        by_status.setdefault(r.status, []).append(r)
    outcome = ", ".join(f"{len(rs)} {s}" for s, rs in sorted(by_status.items()))
    print(f"served {len(done)} requests ({outcome}), {total_tokens} tokens "
          f"in {wall:.1f}s ({total_tokens/wall:.1f} tok/s)")
    print(f"device calls: {decode:.0f} fused decode+sample "
          f"({decode/max(iters,1):.2f}/iteration) + {prefill:.0f} prefill")
    print(f"TTFT p50 {eng.reg.histogram('serve_ttft_seconds').quantile(0.5)*1e3:.0f}ms "
          f"p95 {eng.reg.histogram('serve_ttft_seconds').quantile(0.95)*1e3:.0f}ms")
    print(f"latency p50 "
          f"{eng.reg.histogram('serve_latency_seconds').quantile(0.5):.2f}s")
    st = eng.kv.memory_stats()
    deferred = eng.reg.counter("serve_admission_deferred_total").get()
    pf_h = eng.reg.histogram("serve_prefill_batch_size")
    print(f"kv cache [{st.backend}]: {st.bytes_total/1e6:.2f} MB pinned"
          + (f", {st.pages_total} pages of {st.page_size}"
             if st.backend == "paged" else "")
          + (f", sharded over {st.mesh_chips} chips "
             f"({st.bytes_per_chip/1e6:.2f} MB/chip)"
             if st.mesh_chips > 1 else "")
          + f"; admissions deferred={deferred:.0f}; "
          f"prefill batch p50={pf_h.quantile(0.5):.0f}")
    if st.backend == "paged":
        transient = eng.reg.gauge("serve_decode_transient_bytes").get()
        print(f"decode impl [{eng.kv.decode_impl}]: per-step KV read "
              f"transient {transient/1e3:.1f} kB/layer")
    if st.backend == "paged" and st.kv_dtype == "int8":
        saved = eng.reg.gauge("serve_kv_quant_bytes_saved").get()
        per_chip = (f" ({st.bytes_scales_per_chip/1e3:.1f} kB/chip)"
                    if st.mesh_chips > 1 else "")
        print(f"kv quant [int8]: {st.bytes_scales/1e3:.1f} kB scales"
              f"{per_chip}, "
              f"{saved/1e6:.2f} MB saved vs {np.dtype(eng.kv.dtype).name} "
              f"pages "
              f"({(st.bytes_total + saved)/max(st.bytes_total, 1):.2f}x "
              f"positions per byte)")
    if args.host_pages:
        hits = eng.reg.counter("serve_prefix_store_hits_total").get()
        misses = eng.reg.counter("serve_prefix_store_misses_total").get()
        off_b = eng.reg.counter("serve_host_offload_bytes_total").get()
        pre_b = eng.reg.counter("serve_host_prefetch_bytes_total").get()
        print(f"host tier [{args.host_pages} pages"
              + (", persistent store" if args.prefix_store else "")
              + f"]: {st.host_pages_in_use} resident "
              f"({st.host_bytes/1e6:.2f} MB host RAM), "
              f"{hits:.0f} page hits / {misses:.0f} misses, "
              f"{off_b/1e6:.2f} MB offloaded, {pre_b/1e6:.2f} MB "
              f"prefetched")
        if args.prefill_chunk:
            skipped = eng.reg.counter(
                "serve_prefill_chunks_skipped_total").get()
            print(f"  {skipped:.0f} fully-shared chunks skipped their "
                  f"forward")
    if args.prefill_chunk:
        chunks = eng.reg.counter("serve_prefill_chunks_total").get()
        stalls = eng.reg.counter("serve_prefill_chunk_stalls_total").get()
        stall_it = eng.reg.counter("serve_decode_stall_iters").get()
        print(f"chunked prefill [{args.prefill_chunk} tok/chunk, budget "
              f"{eng.budget}]: {chunks:.0f} chunks, {stalls:.0f} page-grant "
              f"stalls, decode stall iters={stall_it:.0f}")
    if tenancy is not None:
        preempt = eng.reg.counter("serve_preemptions_total").get()
        qdeny = eng.reg.counter("serve_quota_denied_total").get()
        print(f"tenancy [{len(tenancy.tenants)} tenants, preemption "
              f"{'on' if tenancy.preemption else 'off'}]: "
              f"{preempt:.0f} preemptions, {qdeny:.0f} quota denies")
        for name in tenant_names:
            spec = tenancy.spec(name)
            peak = eng.reg.gauge("serve_tenant_pages_in_use").get(
                {"tenant": name})
            quota = (f"/{spec.page_quota}" if spec.page_quota is not None
                     else "")
            print(f"  tenant {name} [{spec.cls}]: pages {peak:.0f}{quota}")
        for cls in sorted({t.cls for t in tenancy.tenants.values()}):
            h = eng.reg.histogram("serve_class_ttft_seconds")
            if h.count({"class": cls}):
                print(f"  class {cls}: TTFT p50 "
                      f"{h.quantile(0.5, {'class': cls})*1e3:.0f}ms p99 "
                      f"{h.quantile(0.99, {'class': cls})*1e3:.0f}ms")
    injected = sum(v for _, v in eng.reg.counter(
        "serve_faults_injected_total").labels_values())
    recovered = sum(v for _, v in eng.reg.counter(
        "serve_stream_retries_total").labels_values())
    dead_total = sum(v for _, v in eng.reg.counter(
        "serve_dead_letter_total").labels_values())
    if args.fault_plan or args.watchdog_iters or injected or recovered:
        rec_h = eng.reg.histogram("serve_recovery_iters")
        rec_p50 = (f", recovery p50 {rec_h.quantile(0.5):.0f} iters"
                   if rec_h.count() else "")
        print(f"faults: {injected:.0f} injected, {recovered:.0f} stream "
              f"retries, {dead_total:.0f} dead-lettered{rec_p50}")
    # per-stream terminal outcomes: operators see recovery results without
    # scraping metrics — dead-letter/stuck always shown, retried streams too
    for r in sorted(done, key=lambda r: r.id):
        if r.status != "completed" or r.retries or r.preemptions:
            detail = f"  request {r.id} [{r.status}]: " \
                     f"{len(r.out_tokens)} tokens, {r.retries} retries, " \
                     f"{r.preemptions} preemptions"
            if r.error:
                detail += f" — {r.error}"
            print(detail)
    if slack.messages:
        print("alerts fired:")
        for m in slack.messages:
            print(f"  {m}")


if __name__ == "__main__":
    main()
