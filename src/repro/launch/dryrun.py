import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import (jax locks device count at first init).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell with
ShapeDtypeStruct inputs on the production mesh, record memory/cost analysis and
the post-SPMD collective schedule.  No arrays are ever allocated.

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k --multi-pod
  python -m repro.launch.dryrun --all              # every applicable cell
  python -m repro.launch.dryrun --all --mesh both  # single- and multi-pod
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import (ASSIGNED_ARCHS, TrainConfig, applicable,
                           get_config, get_shape, SHAPES)
from repro.models import LM, ForwardOpts, input_logical_axes, input_specs
from repro.parallel.mesh import make_production_mesh
from repro.parallel.sharding import (default_rules, logical_to_sharding,
                                     sharding_context, spec_for)
from repro.roofline.hlo import count_op_flavors, parse_collectives
from repro.train import (abstract_train_state, make_train_step,
                         train_state_logical_axes)

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def _mesh_name(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


def _forward_opts(cfg, shape, overrides=None) -> ForwardOpts:
    qc = kv = 1024 if shape.seq_len >= 4096 else min(shape.seq_len, 512)
    base = dict(attn_impl="blockwise", q_chunk=qc, kv_chunk=kv,
                remat="selective", scan_layers=True)
    base.update(overrides or {})
    return ForwardOpts(**base)


def _jit_for_cell(lm: LM, cfg, shape, mesh, rules, opts,
                  microbatches: int = 1, shard_grads: bool = False):
    """Build (jitted_fn, example_args) for the cell's step kind."""
    batch_abs = input_specs(cfg, shape)
    batch_axes = input_logical_axes(cfg, shape)
    batch_sh = jax.tree.map(
        lambda ax, ab: jax.sharding.NamedSharding(
            mesh, spec_for(ax, ab.shape, rules, mesh)),
        batch_axes, batch_abs,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))

    if shape.kind == "train":
        state_abs = abstract_train_state(lm)
        state_axes = train_state_logical_axes(lm)
        state_sh = logical_to_sharding(state_axes, state_abs, mesh, rules)
        tcfg = TrainConfig()
        step = make_train_step(lm, tcfg, opts, microbatches=microbatches,
                               shard_grads=shard_grads)

        def wrapped(state, batch):
            with sharding_context(mesh, rules):
                return step(state, batch)

        jitted = jax.jit(wrapped, in_shardings=(state_sh, batch_sh),
                         out_shardings=(state_sh, None),
                         donate_argnums=(0,))
        return jitted, (state_abs, batch_abs)

    params_abs = lm.abstract_params()
    params_sh = logical_to_sharding(lm.param_logical_axes(), params_abs,
                                    mesh, rules)
    if shape.kind == "prefill":
        def wrapped(params, batch):
            with sharding_context(mesh, rules):
                return lm.prefill(params, batch, opts)

        jitted = jax.jit(wrapped, in_shardings=(params_sh, batch_sh))
        return jitted, (params_abs, batch_abs)

    if shape.kind == "decode":
        cache_sh = batch_sh["cache"]

        def wrapped(params, tokens, cache, cache_index):
            with sharding_context(mesh, rules):
                return lm.decode_step(params, tokens, cache, cache_index,
                                      scan_layers=opts.scan_layers)

        jitted = jax.jit(
            wrapped,
            in_shardings=(params_sh, batch_sh["tokens"], cache_sh,
                          batch_sh["cache_index"]),
            out_shardings=(None, cache_sh),
            donate_argnums=(2,))
        return jitted, (params_abs, batch_abs["tokens"], batch_abs["cache"],
                        batch_abs["cache_index"])

    raise ValueError(shape.kind)


def _compile_once(cfg, shape, mesh, rules, opts, microbatches: int = 1,
                  want_hlo_text: bool = False, shard_grads: bool = False):
    """One lower+compile; returns a dict of analysis numbers."""
    lm = LM(cfg)
    t0 = time.time()
    jitted, args = _jit_for_cell(lm, cfg, shape, mesh, rules, opts,
                                 microbatches, shard_grads)
    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
    out = {"lower_s": round(t_lower, 2),
           "compile_s": round(time.time() - t0 - t_lower, 2)}
    mem = compiled.memory_analysis()
    if mem is not None:
        out["memory_analysis"] = {
            k: int(getattr(mem, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(mem, k)}
    ca = compiled.cost_analysis()
    if ca:
        out["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))
                                and k in ("flops", "bytes accessed",
                                          "transcendentals",
                                          "optimal_seconds")}
    hlo = compiled.as_text()
    out["collectives"] = parse_collectives(hlo)
    flavors = count_op_flavors(hlo)
    out["op_counts"] = {k: v for k, v in sorted(
        flavors.items(), key=lambda kv: -kv[1])[:20]}
    out["hlo_lines"] = hlo.count("\n")
    if want_hlo_text:
        out["hlo_text"] = hlo
    del hlo, compiled, lowered
    return out


def _unroll_depths(cfg) -> tuple:
    """(L1, L2) unroll depths for the linear cost extrapolation, honouring the
    arch's layer-pattern period (hybrid shared-block cadence)."""
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        k = cfg.hybrid_attn_every
        return k, 2 * k
    return 1, 2


def _with_layers(cfg, n: int):
    kw = {"num_layers": n}
    if cfg.family == "encdec":
        kw["encoder_layers"] = n
    return dataclasses.replace(cfg, **kw)


def _layer_units(cfg) -> float:
    """Total 'layer units' of the full config in extrapolation space."""
    if cfg.family == "encdec":
        return float(cfg.num_layers)     # enc+dec scale together in _with_layers
    return float(cfg.num_layers)


_EXTRAP_KEYS = ("flops", "bytes accessed", "transcendentals")


def _extrapolate(rec1, rec2, l1: int, l2: int, full_l: float):
    """Linear in layer count: f(L) = f(l1) + (L-l1) * (f(l2)-f(l1))/(l2-l1).

    XLA's HloCostAnalysis counts while-loop (scan) bodies once, so the scanned
    production compile under-reports; two small unrolled compiles calibrate the
    exact per-layer cost instead (see EXPERIMENTS.md §Dry-run methodology).
    """
    out = {"cost_analysis": {}, "collectives": {"per_kind": {}}}
    c1, c2 = rec1.get("cost_analysis", {}), rec2.get("cost_analysis", {})
    for k in _EXTRAP_KEYS:
        if k in c1 and k in c2:
            slope = (c2[k] - c1[k]) / (l2 - l1)
            # fusion nondeterminism can make f(l2) < f(l1); clamp to a
            # proportional scale-up rather than extrapolating negative
            if slope < 0:
                out["cost_analysis"][k] = c2[k] * full_l / l2
            else:
                out["cost_analysis"][k] = c1[k] + (full_l - l1) * slope
    b1 = rec1["collectives"]["total_bytes"]
    b2 = rec2["collectives"]["total_bytes"]
    slope = (b2 - b1) / (l2 - l1)
    out["collectives"]["total_bytes"] = b1 + (full_l - l1) * slope
    for kind in set(rec1["collectives"]["per_kind"]) | set(
            rec2["collectives"]["per_kind"]):
        k1 = rec1["collectives"]["per_kind"].get(kind, {"bytes": 0})["bytes"]
        k2 = rec2["collectives"]["per_kind"].get(kind, {"bytes": 0})["bytes"]
        s = (k2 - k1) / (l2 - l1)
        out["collectives"]["per_kind"][kind] = {
            "bytes": k1 + (full_l - l1) * s}
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opt_overrides=None, rule_overrides=None, tag: str = "baseline",
             save: bool = True, microbatches: int = 1,
             extrapolate: bool = True, shard_grads: bool = False):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = _mesh_name(multi_pod)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
           "chips": 512 if multi_pod else 256,
           "tokens_per_step": shape.tokens_per_step}
    if not applicable(cfg, shape):
        rec["skipped"] = ("long_500k needs sub-quadratic attention state; "
                          f"{cfg.family} arch is full-attention (DESIGN.md §4)")
        return _save(rec, save)

    cfg = dataclasses.replace(cfg, param_dtype="bfloat16")
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(mesh.axis_names,
                          seq_sharded_cache=(shape.name == "long_500k"))
    rules.update(rule_overrides or {})
    opts = _forward_opts(cfg, shape, opt_overrides)
    rec["opts"] = dataclasses.asdict(opts)

    try:
        # 1) the production artifact: scanned layers, full depth — proves the
        #    cell compiles on this mesh and yields the true memory analysis
        rec.update(_compile_once(cfg, shape, mesh, rules, opts, microbatches,
                                 shard_grads=shard_grads))
        rec["scan_counted"] = {"cost_analysis": rec.pop("cost_analysis", {}),
                               "collectives": rec.pop("collectives", {})}

        # 2) cost calibration: two small unrolled compiles, linear in depth
        if extrapolate:
            l1, l2 = _unroll_depths(cfg)
            opts_u = dataclasses.replace(opts, scan_layers=False)
            r1 = _compile_once(_with_layers(cfg, l1), shape, mesh, rules,
                               opts_u, microbatches, shard_grads=shard_grads)
            r2 = _compile_once(_with_layers(cfg, l2), shape, mesh, rules,
                               opts_u, microbatches, shard_grads=shard_grads)
            ext = _extrapolate(r1, r2, l1, l2, _layer_units(cfg))
            rec["cost_analysis"] = ext["cost_analysis"]
            rec["collectives"] = ext["collectives"]
            rec["calib"] = {"l1": l1, "l2": l2,
                            "r1_flops": r1["cost_analysis"].get("flops"),
                            "r2_flops": r2["cost_analysis"].get("flops"),
                            "r1_coll": r1["collectives"]["total_bytes"],
                            "r2_coll": r2["collectives"]["total_bytes"],
                            "compile_s": r1["compile_s"] + r2["compile_s"]}
        else:
            rec["cost_analysis"] = rec["scan_counted"]["cost_analysis"]
            rec["collectives"] = rec["scan_counted"]["collectives"]

        rec["model_flops_global"] = (cfg.flops_per_token(shape.seq_len,
                                                         shape.kind)
                                     * shape.tokens_per_step)
        rec["n_params"] = cfg.param_count()
        rec["n_active_params"] = cfg.active_param_count()
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    return _save(rec, save)


def _save(rec, save: bool):
    if save:
        d = OUT_DIR / rec["mesh"]
        d.mkdir(parents=True, exist_ok=True)
        suffix = "" if rec.get("tag", "baseline") == "baseline" else \
            f"__{rec['tag']}"
        path = d / f"{rec['arch']}__{rec['shape']}{suffix}.json"
        path.write_text(json.dumps(rec, indent=1, default=str))
    status = ("SKIP" if rec.get("skipped")
              else "OK" if rec.get("ok") else "FAIL")
    flops = rec.get("cost_analysis", {}).get("flops", 0)
    coll = rec.get("collectives", {}).get("total_bytes", 0)
    print(f"[{status}] {rec['mesh']} {rec['arch']} {rec['shape']} "
          f"({rec.get('tag','baseline')}) "
          f"compile={rec.get('compile_s','-')}s flops/dev={flops:.3g} "
          f"coll B/dev={coll:.3g}"
          + (f" err={rec.get('error','')}" if not rec.get("ok") and
             not rec.get("skipped") else ""), flush=True)
    if not rec.get("ok") and not rec.get("skipped") and rec.get("traceback"):
        print(rec["traceback"][-1500:], flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    meshes = ([True] if (args.multi_pod or args.mesh == "multi") else
              [False] if args.mesh == "single" else [False, True])
    archs = [args.arch] if args.arch else sorted(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    if not (args.all or args.arch or args.shape):
        ap.error("pass --all or --arch/--shape")

    n_fail = 0
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                out = (OUT_DIR / _mesh_name(mp) / f"{arch}__{shape}.json")
                if args.skip_existing and out.exists() and \
                        json.loads(out.read_text()).get("ok"):
                    print(f"[CACHED] {arch} {shape} {_mesh_name(mp)}",
                          flush=True)
                    continue
                # multi-pod pass proves the pod axis shards; the roofline
                # table is single-pod only -> calibration compiles skipped
                rec = run_cell(arch, shape, mp, tag=args.tag,
                               extrapolate=not mp)
                if not rec.get("ok") and not rec.get("skipped"):
                    n_fail += 1
    print(f"done; failures={n_fail}", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
