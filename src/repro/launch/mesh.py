"""Production mesh entry point (deliverable e).  Functions, not constants —
importing never touches jax device state."""
from repro.parallel.mesh import make_mesh, make_production_mesh  # noqa: F401
