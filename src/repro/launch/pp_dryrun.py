import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Pipeline-parallel multi-pod dry-run (the paper's PP dimension, §2.4):
layers pipelined over the ``pod`` axis (point-to-point collective-permute
hops between pods — the slow-fabric-friendly traffic pattern) with data
parallelism inside each pod.

The mesh here is (pod=2, data=256), fully shard_map-manual: the
partial-manual composition (Manual pod + GSPMD-auto TP inside) trips an XLA
CPU backend crash ("Invalid binary instruction opcode copy") — recorded as a
backend limitation in DESIGN.md; on TPU the `auto=` composition is the
intended deployment.

    python -m repro.launch.pp_dryrun --arch granite-20b-code
"""
import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models import LM, ForwardOpts
from repro.models import transformer as tfm
from repro.models.common import apply_norm
from repro.parallel.mesh import make_mesh, make_production_mesh
from repro.parallel.pipeline import pipeline_forward
from repro.parallel.sharding import default_rules, logical_to_sharding, \
    shard_map, sharding_context
from repro.roofline.hlo import parse_collectives

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def build_pp_forward(lm: LM, cfg, mesh, rules, opts, n_microbatches: int):
    """Forward pass with layers pipelined over 'pod'; embed/unembed replicated
    across pods; data/model axes stay GSPMD-automatic inside the stages."""
    n_stages = mesh.shape["pod"]
    assert cfg.num_layers % n_stages == 0

    def layer_fn(lp, h):
        h, _, _ = tfm._attn_layer(lp, cfg, h, opts, collect=False)
        return h

    def stage_fn(stage_params, x):
        def one(h, lp):
            return layer_fn(lp, h), None
        body = jax.checkpoint(one, prevent_cse=False)
        h, _ = jax.lax.scan(body, x, stage_params)
        return h

    pipe = pipeline_forward(stage_fn, n_stages, "pod")

    def fwd(params, tokens):
        h = tfm.embed_inputs(params, cfg, {"tokens": tokens})
        b = h.shape[0]
        mb = b // n_microbatches
        x_mb = h.reshape(n_microbatches, mb, *h.shape[1:])

        def inner(stage_params, x_loc):
            out = pipe(stage_params, x_loc)
            s = jax.lax.axis_index("pod")
            out = jnp.where(s == n_stages - 1, out, jnp.zeros_like(out))
            return jax.lax.psum(out, "pod")

        spec_params = jax.tree.map(lambda _: P("pod"), params["layers"])
        # fully manual: pipeline over pod, batch over data (microbatch dim
        # replicated; the per-microbatch batch dim is data-sharded)
        fn = shard_map(
            inner, mesh=mesh,
            in_specs=(spec_params, P(None, "data", None, None)),
            out_specs=P(None, "data", None, None),
            check_vma=False)
        h = fn(params["layers"], x_mb)
        h = h.reshape(b, *h.shape[2:])
        logits = tfm.unembed(params, cfg, h)
        return logits

    return fwd


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-20b-code")
    ap.add_argument("--microbatches", type=int, default=4)
    args = ap.parse_args()

    cfg = dataclasses.replace(get_config(args.arch), param_dtype="bfloat16")
    lm = LM(cfg)
    mesh = make_mesh((2, 256), ("pod", "data"))   # PP across pods, DP inside
    rules = default_rules(mesh.axis_names)
    rules["batch"] = ("data",)       # pod axis is the pipeline, not DP
    opts = ForwardOpts(attn_impl="blockwise", q_chunk=1024, kv_chunk=1024,
                       remat="none", scan_layers=True)

    params_abs = lm.abstract_params()
    params_sh = logical_to_sharding(lm.param_logical_axes(), params_abs,
                                    mesh, rules)
    # layer stack: leading dim over pods (stage-contiguous slices)
    params_sh["layers"] = jax.tree.map(
        lambda s: NamedSharding(mesh, P(*(("pod",) + tuple(s.spec)[1:]))),
        params_sh["layers"])
    b, s = 1024, 1024   # mb=256 divides data=256
    tokens = jax.ShapeDtypeStruct((b, s), jnp.int32)
    tok_sh = NamedSharding(mesh, P("data", None))

    fwd = build_pp_forward(lm, cfg, mesh, rules, opts, args.microbatches)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fwd, in_shardings=(params_sh, tok_sh)).lower(
            params_abs, tokens)
        compiled = lowered.compile()
    dt = time.time() - t0
    hlo = compiled.as_text()
    coll = parse_collectives(hlo)
    mem = compiled.memory_analysis()
    # The paper's pods flip between training and inferencing: project how
    # much serve-side KV capacity the leftover HBM buys on this arch, dense
    # rows vs paged (the serve engine's default backend).  Pure byte math —
    # nothing here is compiled or allocated.  Attention-cache families
    # only: recurrent state has no (L, B, S, KV, D) cache to page.
    kv_proj = None
    if cfg.family in ("dense", "vlm", "moe"):
        from repro.serve.kvcache import (contiguous_kv_bytes,
                                         decode_transient_bytes,
                                         page_kv_bytes,
                                         prefill_transient_bytes)
        kv_b, kv_s, kv_page = 64, 8192, 16
        kv_m = kv_s // kv_page
        kv_proj = {
            "batch": kv_b, "max_seq": kv_s, "page_size": kv_page,
            "contiguous_bytes": contiguous_kv_bytes(cfg, kv_b, kv_s,
                                                    jnp.bfloat16),
            "bytes_per_page": page_kv_bytes(cfg, kv_page, jnp.bfloat16),
            "pages_in_dense_equiv": kv_b * kv_m,
            # per-decode-step transient of the paged KV *read* path (one
            # layer): the XLA gather materializes dense-equivalent views
            # (scales with batch x pages), the page-table-walking kernel
            # streams one page block per (slot, kv-head) program
            "decode_transient_gather_bytes": decode_transient_bytes(
                cfg, kv_b, kv_m, kv_page, jnp.bfloat16, "gather"),
            "decode_transient_kernel_bytes": decode_transient_bytes(
                cfg, kv_b, kv_m, kv_page, jnp.bfloat16, "pallas"),
            # per-chip transient of the sharded prefill *write* path (a
            # group of 4 chunk-length-512 staged blocks): the shard_map
            # local scatter stages only the O(group x block) K/V block —
            # vs the O(P) pool a replicated GSPMD transient would cost
            "prefill_transient_sharded_bytes": prefill_transient_bytes(
                cfg, 4, 512, jnp.bfloat16),
            "prefill_transient_replicated_pool_bytes":
                kv_b * kv_m * page_kv_bytes(cfg, kv_page, jnp.bfloat16),
        }
    rec = {
        "arch": args.arch, "shape": f"pp_fwd_b{b}_s{s}",
        "mesh": "pod2x16x16_PP", "tag": "pp", "chips": 512, "ok": True,
        "compile_s": round(dt, 1),
        "collectives": coll,
        "cost_analysis": {k: float(v) for k, v in
                          (compiled.cost_analysis() or {}).items()
                          if isinstance(v, (int, float))},
        "memory_analysis": {k: int(getattr(mem, k)) for k in
                            ("argument_size_in_bytes", "temp_size_in_bytes")
                            if hasattr(mem, k)},
        "serve_kv_projection": kv_proj,
    }
    out = OUT_DIR / "pod2x16x16" / f"{args.arch}__pp_fwd.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1, default=str))
    cp = coll["per_kind"].get("collective-permute", {"bytes": 0, "count": 0})
    print(f"[OK] PP dry-run {args.arch}: compile={dt:.1f}s "
          f"collective-permute hops={cp['count']} "
          f"({cp['bytes']/1e9:.2f} GB/dev) "
          f"total coll={coll['total_bytes']/1e9:.2f} GB/dev")
    if kv_proj is not None:
        print(f"     serve KV projection (b{kv_proj['batch']} "
              f"s{kv_proj['max_seq']}): dense "
              f"{kv_proj['contiguous_bytes']/1e9:.2f} GB = "
              f"{kv_proj['pages_in_dense_equiv']} pages of "
              f"{kv_proj['page_size']} "
              f"({kv_proj['bytes_per_page']/1e6:.2f} MB/page)")
        print(f"     paged decode transient/step/layer: gather "
              f"{kv_proj['decode_transient_gather_bytes']/1e6:.1f} MB vs "
              f"kernel {kv_proj['decode_transient_kernel_bytes']/1e3:.1f} kB "
              f"(x{kv_proj['decode_transient_gather_bytes'] / max(kv_proj['decode_transient_kernel_bytes'], 1):.0f})")


if __name__ == "__main__":
    main()
