"""Ragged continuous batching: one fused decode+sample dispatch per iteration.

watsonx.ai-style inference — the paper's clusters are "constantly moved
between training and inferencing", so the same model stack must serve, and
per-step overheads must stay in the <5% regime of Figs 5/6/8.  Design:

* **B fixed cache slots**, each holding one in-flight request at its own
  depth.  ``decode_step`` takes a per-slot position vector ``(B,)`` (per-slot
  RoPE, scatter-writes, causal masks), so an arbitrarily ragged batch costs
  exactly **one jitted device call per engine iteration**.  (The seed engine
  grouped slots by position and paid one dispatch per *distinct position* —
  worst case batch-1 decode.)
* **Batched prefill**: an admitted prompt is written into its slot's cache by
  a single ``lm.forward(collect_cache=True)`` call whose K/V block is
  scatter-copied into the engine cache on device; prompt lengths are bucketed
  to powers of two to bound retracing.  (The seed prefilled token-by-token
  through the full-batch decode step.)
* **On-device sampling**: greedy / temperature / top-k / top-p run as a
  vectorized kernel (``repro.serve.sampling``) fused into the decode
  dispatch.  The only host transfer per iteration is the (B,) vector of
  sampled token ids; free slots are masked inert via ``active_mask``.

Finished slots (EOS or max_len) are freed and refilled from the queue — the
'continuous batching' part.  Dispatch accounting is exported through the
metrics registry (``serve_decode_dispatches_total`` /
``serve_iterations_total`` / ``serve_prefill_dispatches_total``) so the
one-call-per-iteration invariant is observable, not asserted.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ForwardOpts, LM
from repro.core.telemetry import MetricsRegistry
from repro.serve.sampling import sample_batch


@dataclass
class SamplingParams:
    temperature: float = 0.0         # 0 => greedy
    top_k: int = 0                   # 0 => no top-k filter
    top_p: float = 1.0               # nucleus
    seed: int = 0


@dataclass
class Request:
    id: int
    prompt: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                 # -1: never stops early
    sampling: SamplingParams = field(default_factory=SamplingParams)
    img_embeds: Optional[np.ndarray] = None   # (num_image_tokens, d) for vlm
    out_tokens: List[int] = field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None


def _filtered_probs_np(logits: np.ndarray, params: SamplingParams) -> np.ndarray:
    """The (float64 numpy) filtered distribution ``sample_token`` draws from —
    the per-row reference the vectorized device sampler is tested against."""
    x = logits.astype(np.float64) / params.temperature
    if params.top_k > 0:
        kth = np.partition(x, -params.top_k)[-params.top_k]
        x = np.where(x < kth, -np.inf, x)
    p = np.exp(x - np.max(x))
    p /= p.sum()
    if params.top_p < 1.0:
        order = np.argsort(-p)
        cum = np.cumsum(p[order])
        cut = np.searchsorted(cum, params.top_p) + 1
        mask = np.zeros_like(p)
        mask[order[:cut]] = 1.0
        p = p * mask
        p /= p.sum()
    return p


def sample_token(logits: np.ndarray, params: SamplingParams,
                 step: int) -> int:
    """Greedy / temperature / top-k / top-p sampling over a 1-D logit row
    (host-side reference implementation; the engine samples on device)."""
    if params.temperature <= 0.0:
        return int(np.argmax(logits))
    p = _filtered_probs_np(logits, params)
    rng = np.random.default_rng((params.seed, step))
    return int(rng.choice(len(p), p=p))


class ServeEngine:
    def __init__(self, lm: LM, params, max_batch: int, max_seq: int,
                 opts: ForwardOpts = ForwardOpts(attn_impl="dense",
                                                 remat="none"),
                 registry: Optional[MetricsRegistry] = None,
                 greedy: bool = True):
        # per-slot positions rely on masked-then-overwritten cache writes,
        # which holds for attention KV caches but not recurrent state
        assert lm.cfg.family in ("dense", "moe", "vlm"), (
            "ServeEngine supports attention-cache families; recurrent archs "
            "serve via a synchronized full-batch decode loop")
        self.lm = lm
        self.params = params
        self.B = max_batch
        self.S = max_seq
        self.finished: List[Request] = []
        self.opts = opts
        self.reg = registry or MetricsRegistry()
        self.greedy = greedy
        self.img_len = (lm.cfg.num_image_tokens
                        if lm.cfg.family == "vlm" else 0)
        dt = jnp.float32 if lm.cfg.dtype == "float32" else jnp.bfloat16
        self.cache = lm.init_cache(max_batch, max_seq, dtype=dt)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)   # next write index
        self.queue: List[Request] = []
        # per-slot device-call state: the pending (sampled, not yet emitted)
        # token plus the sampling params, mirrored as flat arrays so the
        # fused dispatch takes plain (B,) tensors
        self.next_token = np.zeros(max_batch, np.int32)
        self.active = np.zeros(max_batch, bool)
        self.temps = np.zeros(max_batch, np.float32)
        self.top_ks = np.zeros(max_batch, np.int32)
        self.top_ps = np.ones(max_batch, np.float32)
        self.seeds = np.zeros(max_batch, np.int32)
        self._fused = jax.jit(self._make_fused(), static_argnums=(10,))
        self._prefill = jax.jit(self._make_prefill())

    # ---------------------------------------------------------- jit builds ----
    def _make_fused(self):
        """One device call: decode all B slots at their own positions, then
        sample the next token for every slot, vectorized.  Returns the (B,)
        sampled ids (zeros on inactive slots) and the new cache.

        ``all_greedy`` is static: the common all-greedy batch compiles to a
        bare argmax, skipping the top-k/top-p sort machinery entirely (at
        most two jit cache entries)."""
        lm, vocab = self.lm, self.lm.cfg.vocab_size

        def fused(params, tokens, cache, positions, active,
                  temps, top_ks, top_ps, seeds, steps, all_greedy):
            logits, cache = lm.decode_step(params, tokens, cache, positions)
            rows = logits[:, -1, :vocab].astype(jnp.float32)
            if all_greedy:
                tok = jnp.argmax(rows, axis=-1).astype(jnp.int32)
            else:
                tok = sample_batch(rows, temps, top_ks, top_ps, seeds, steps)
            return jnp.where(active, tok, 0), cache

        return fused

    def _make_prefill(self):
        """Whole-prompt prefill: forward with cache collection, scatter the
        K/V block into this slot's rows of the engine cache, and sample the
        first token on device.  jit caches one trace per prompt bucket."""
        lm, opts, vocab = self.lm, self.opts, self.lm.cfg.vocab_size
        has_img = self.img_len > 0

        def run(params, tokens, img_embeds, cache, slot, last_idx,
                temp, top_k, top_p, seed):
            batch = {"tokens": tokens}
            if has_img:
                batch["img_embeds"] = img_embeds
            logits, _, pcache = lm.forward(params, batch, opts,
                                           collect_cache=True)

            def write(big, small):
                # big: (L, B, S, ...) engine cache; small: (L, 1, P, ...)
                start = (0, slot, 0) + (0,) * (big.ndim - 3)
                return jax.lax.dynamic_update_slice(
                    big, small.astype(big.dtype), start)

            cache = jax.tree.map(write, cache, pcache)
            row = logits[0, last_idx, :vocab].astype(jnp.float32)
            tok = sample_batch(row[None], temp[None], top_k[None],
                               top_p[None], seed[None],
                               jnp.zeros((1,), jnp.int32))
            return tok[0], cache

        return run

    # ------------------------------------------------------------- intake ----
    def submit(self, req: Request):
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.id}: empty prompt "
                             "(nothing to prefill or sample from)")
        if len(req.prompt) + self.img_len >= self.S:
            raise ValueError(
                f"request {req.id}: prompt length {len(req.prompt)} "
                f"(+{self.img_len} image tokens) leaves no room to decode "
                f"in a max_seq={self.S} cache")
        req.submitted_at = time.perf_counter()
        self.queue.append(req)
        self.reg.counter("serve_requests_total").inc()

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    # ------------------------------------------------------------ prefill ----
    def _admit(self):
        """Prefill queued requests into free slots — one forward pass per
        prompt (bucketed to powers of two), whose K/V block lands in the
        slot's cache rows in the same device call."""
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            plen = len(req.prompt)
            bucket = 1 << (plen - 1).bit_length()          # next power of two
            bucket = min(bucket, self.S - self.img_len)
            tokens = np.zeros((1, bucket), np.int32)
            tokens[0, :plen] = req.prompt
            if self.img_len:
                img = (req.img_embeds if req.img_embeds is not None
                       else np.zeros((self.img_len, self.lm.cfg.d_model)))
                img = jnp.asarray(img, self.cache["layers"]["k"].dtype)[None]
            else:
                img = None
            sp = req.sampling
            tok, self.cache = self._prefill(
                self.params, jnp.asarray(tokens), img, self.cache,
                jnp.int32(slot), jnp.int32(self.img_len + plen - 1),
                jnp.float32(sp.temperature), jnp.int32(sp.top_k),
                jnp.float32(sp.top_p), jnp.int32(sp.seed))
            self.slot_req[slot] = req
            self.slot_pos[slot] = self.img_len + plen
            self.next_token[slot] = int(tok)
            self.active[slot] = True
            self.temps[slot] = sp.temperature
            self.top_ks[slot] = sp.top_k
            self.top_ps[slot] = sp.top_p
            self.seeds[slot] = sp.seed
            self.reg.counter("serve_prefill_dispatches_total").inc()
            self.reg.counter("serve_prefill_tokens_total").inc(plen)

    # ------------------------------------------------------------- decode ----
    def step(self):
        """One engine iteration: admit, then **one** fused decode+sample
        dispatch for all active slots at their own positions."""
        self._admit()
        active_idx = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active_idx:
            return False
        # per-slot sample-step index: the token being sampled now is
        # out_tokens[len]+1 deep in the request's stream (the pending token,
        # sampled earlier, is #len and gets emitted this iteration)
        steps = np.zeros(self.B, np.int32)
        for i in active_idx:
            steps[i] = len(self.slot_req[i].out_tokens) + 1
        positions = np.minimum(self.slot_pos, self.S - 1)
        all_greedy = bool(np.all(self.temps[self.active] <= 0.0))
        sampled, self.cache = self._fused(
            self.params, jnp.asarray(self.next_token[:, None]), self.cache,
            jnp.asarray(positions), jnp.asarray(self.active),
            jnp.asarray(self.temps), jnp.asarray(self.top_ks),
            jnp.asarray(self.top_ps), jnp.asarray(self.seeds),
            jnp.asarray(steps), all_greedy)
        self.reg.counter("serve_decode_dispatches_total").inc()
        self.reg.counter("serve_iterations_total").inc()
        sampled = np.asarray(sampled)     # the one (B,) host transfer
        now = time.perf_counter()
        for i in active_idx:
            req = self.slot_req[i]
            tok = int(self.next_token[i])
            req.out_tokens.append(tok)
            if req.first_token_at is None:
                req.first_token_at = now
                self.reg.histogram("serve_ttft_seconds").observe(
                    now - req.submitted_at)
            self.slot_pos[i] += 1
            done = (len(req.out_tokens) >= req.max_new_tokens
                    or tok == req.eos_id
                    or self.slot_pos[i] >= self.S)
            if done:
                req.done_at = now
                self.reg.counter("serve_tokens_total").inc(
                    len(req.out_tokens))
                self.reg.histogram("serve_latency_seconds").observe(
                    now - req.submitted_at)
                self.finished.append(req)
                self.slot_req[i] = None
                self.active[i] = False
            else:
                self.next_token[i] = sampled[i]
        return True

    def run_until_drained(self, max_iters: int = 10_000) -> List[Request]:
        for _ in range(max_iters):
            if not self.step() and not self.queue:
                break
        return self.finished
