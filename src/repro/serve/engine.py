"""Batched serving engine: continuous batching over a fixed-slot KV cache
(watsonx.ai-style inference — the paper's clusters are "constantly moved
between training and inferencing" so the same model stack must serve).

Design: B cache slots; each incoming request is prefilled individually
(right-aligned into its slot is unnecessary — slots are per-sequence) and
then joins the synchronized decode batch.  Finished slots (EOS or max_len)
are freed and refilled from the queue — the 'continuous batching' part.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ForwardOpts, LM
from repro.core.telemetry import MetricsRegistry


@dataclass
class SamplingParams:
    temperature: float = 0.0         # 0 => greedy
    top_k: int = 0                   # 0 => no top-k filter
    top_p: float = 1.0               # nucleus
    seed: int = 0


@dataclass
class Request:
    id: int
    prompt: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                 # -1: never stops early
    sampling: SamplingParams = field(default_factory=SamplingParams)
    out_tokens: List[int] = field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None


def sample_token(logits: np.ndarray, params: SamplingParams,
                 step: int) -> int:
    """Greedy / temperature / top-k / top-p sampling over a 1-D logit row."""
    if params.temperature <= 0.0:
        return int(np.argmax(logits))
    x = logits.astype(np.float64) / params.temperature
    if params.top_k > 0:
        kth = np.partition(x, -params.top_k)[-params.top_k]
        x = np.where(x < kth, -np.inf, x)
    p = np.exp(x - np.max(x))
    p /= p.sum()
    if params.top_p < 1.0:
        order = np.argsort(-p)
        cum = np.cumsum(p[order])
        cut = np.searchsorted(cum, params.top_p) + 1
        mask = np.zeros_like(p)
        mask[order[:cut]] = 1.0
        p = p * mask
        p /= p.sum()
    rng = np.random.default_rng((params.seed, step))
    return int(rng.choice(len(p), p=p))


class ServeEngine:
    def __init__(self, lm: LM, params, max_batch: int, max_seq: int,
                 opts: ForwardOpts = ForwardOpts(attn_impl="dense",
                                                 remat="none"),
                 registry: Optional[MetricsRegistry] = None,
                 greedy: bool = True):
        # per-slot positions rely on masked-then-overwritten cache writes,
        # which holds for attention KV caches but not recurrent state
        assert lm.cfg.family in ("dense", "moe", "vlm"), (
            "ServeEngine supports attention-cache families; recurrent archs "
            "serve via launch/serve.py's synchronized batch path")
        self.lm = lm
        self.params = params
        self.B = max_batch
        self.S = max_seq
        self.finished: List[Request] = []
        self.opts = opts
        self.reg = registry or MetricsRegistry()
        self.greedy = greedy
        dt = jnp.float32 if lm.cfg.dtype == "float32" else jnp.bfloat16
        self.cache = lm.init_cache(max_batch, max_seq, dtype=dt)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)   # next write index
        self.queue: List[Request] = []
        self._decode = jax.jit(
            lambda p, t, c, i: lm.decode_step(p, t, c, i))

    # ------------------------------------------------------------- intake ----
    def submit(self, req: Request):
        req.submitted_at = time.perf_counter()
        self.queue.append(req)
        self.reg.counter("serve_requests_total").inc()

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    # ------------------------------------------------------------ prefill ----
    def _admit(self):
        """Prefill queued requests into free slots one at a time (per-slot
        cache writes via token-by-token decode keeps the engine simple and
        exactly consistent with the decode path)."""
        for slot in self._free_slots():
            if not self.queue:
                break
            req = self.queue.pop(0)
            pos = 0
            for tok in req.prompt:
                logits, self.cache = self._step_one(slot, int(tok), pos)
                pos += 1
            self.slot_req[slot] = req
            self.slot_pos[slot] = pos
            req._last_logits = logits   # type: ignore[attr-defined]

    def _step_one(self, slot: int, token: int, pos: int):
        """Single-slot, single-token cache update: run the batched decode step
        with only this slot's token (other slots get a dummy token written to
        a scratch position = their current pos; harmless since it is
        overwritten when they actually decode).  For simplicity and batch-1
        exactness the engine serializes prefill; production prefill is the
        dedicated ``lm.prefill`` path (see launch/serve.py)."""
        tokens = np.zeros((self.B, 1), np.int32)
        tokens[slot, 0] = token
        # decode_step uses one shared cache_index; emulate per-slot positions
        # by running with this slot's position (other slots' writes at that
        # index are overwritten later by their own decodes).
        logits, cache = self._decode(self.params, jnp.asarray(tokens),
                                     self.cache, jnp.int32(pos))
        return np.asarray(logits[slot, -1]), cache

    # ------------------------------------------------------------- decode ----
    def step(self):
        """One engine iteration: admit, then one synchronized decode step for
        all active slots at their own positions (slots must share a cache
        index per decode_step call; the engine groups slots by position)."""
        self._admit()
        active = [i for i, r in enumerate(self.slot_req) if r is not None]
        if not active:
            return False
        # group slots by position so each group shares a cache_index
        by_pos: Dict[int, List[int]] = {}
        for i in active:
            by_pos.setdefault(int(self.slot_pos[i]), []).append(i)
        for pos, slots in sorted(by_pos.items()):
            tokens = np.zeros((self.B, 1), np.int32)
            for i in slots:
                req = self.slot_req[i]
                last = req._last_logits  # type: ignore[attr-defined]
                vocab = self.lm.cfg.vocab_size
                tokens[i, 0] = sample_token(
                    np.asarray(last[:vocab]), req.sampling,
                    len(req.out_tokens))
            logits, self.cache = self._decode(
                self.params, jnp.asarray(tokens), self.cache, jnp.int32(pos))
            logits = np.asarray(logits[:, -1])
            now = time.perf_counter()
            for i in slots:
                req = self.slot_req[i]
                tok = int(tokens[i, 0])
                req.out_tokens.append(tok)
                if req.first_token_at is None:
                    req.first_token_at = now
                    self.reg.histogram("serve_ttft_seconds").observe(
                        now - req.submitted_at)
                req._last_logits = logits[i]  # type: ignore[attr-defined]
                self.slot_pos[i] += 1
                done = (len(req.out_tokens) >= req.max_new_tokens
                        or tok == req.eos_id
                        or self.slot_pos[i] >= self.S)
                if done:
                    req.done_at = now
                    self.reg.counter("serve_tokens_total").inc(
                        len(req.out_tokens))
                    self.reg.histogram("serve_latency_seconds").observe(
                        now - req.submitted_at)
                    self.finished.append(req)
                    self.slot_req[i] = None
        return True

    def run_until_drained(self, max_iters: int = 10_000) -> List[Request]:
        for _ in range(max_iters):
            if not self.step() and not self.queue:
                break
        return self.finished
