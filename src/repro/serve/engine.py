"""Ragged continuous batching over a pluggable KV cache: one fused
decode+sample dispatch per iteration.

watsonx.ai-style inference — the paper's clusters are "constantly moved
between training and inferencing", so the same model stack must serve inside
whatever HBM training leaves, and per-step overheads must stay in the <5%
regime of Figs 5/6/8.  Design:

* **B fixed cache slots**, each holding one in-flight request at its own
  depth.  ``decode_step`` takes a per-slot position vector ``(B,)`` (per-slot
  RoPE, scatter-writes, causal masks), so an arbitrarily ragged batch costs
  exactly **one jitted device call per engine iteration**.
* **Pluggable KV cache** (``repro.serve.kvcache``): the engine talks to a
  backend through ``alloc`` / ``decode_view`` / ``free`` / ``memory_stats``.
  ``PagedCache`` (the default) reserves only the pages a request actually
  needs — ``ceil((prompt + max_new_tokens) / page)`` — behind a (B, M) page
  table that rides into the fused dispatch as one more int32 input, with
  hash-based prefix sharing so identical prompt prefixes pin physical pages
  once.  When the page pool is exhausted, **admission is deferred** (the
  request stays queued) instead of the engine OOMing.  ``ContiguousCache``
  is the seed dense layout behind the same API.  ``decode_impl`` picks how
  the paged table is resolved per step: ``"gather"`` (XLA fallback,
  O(B·M·page) transient) or ``"pallas"`` (the page-table-walking
  flash-decode kernel, O(page) transient — ``repro.kernels.paged_decode``).
* **Sharded paged serving** (``mesh=``): the page pools carry the
  ``kv_pages`` logical axis and shard P/n over the ``kv_axis`` mesh axis,
  so pinned pool HBM scales *down* with the inference mesh.  The fused
  dispatch stays one device call: inside it, each layer's scatter-write +
  paged attention runs under shard_map — every chip owns the page-id range
  ``[chip*P/n, (chip+1)*P/n)``, treats non-local pages exactly like dead
  pages, and the per-chip online-softmax partials merge with one
  pmax + two psums (``repro.parallel.pagedkv``).  ``PagedCache.alloc``'s
  free list is locality-aware (prefers one chip per request) without ever
  changing admission decisions.
* **Batched bucketed prefill**: admitted prompts are grouped by power-of-two
  length bucket and each group runs as a *single* ``lm.forward`` call whose
  K/V block is scatter-written into every admitted slot's cache rows/pages
  in the same device call (one dispatch per group, not per request).
* **Chunked prefill** (``prefill_chunk=C``): instead of prefilling a whole
  prompt in one bucketed call — which stalls every in-flight decode stream
  for the prompt's full forward — admitted prompts split into fixed-size
  C-token chunks that interleave with the fused decode steps: each engine
  iteration spends at most ``prefill_budget`` tokens (default: one chunk)
  on prefill before running decode, so no decode iteration ever waits on
  more than one chunk of prefill compute.  Chunk *k* attends causally over
  the pages written by chunks ``0..k-1`` at a position offset
  (``lm.prefill_chunk``), pages are claimed chunk-by-chunk
  (``PagedCache.alloc_chunked``/``extend`` — banker-safe, so a long prompt
  admits into a pool whose free pages cover only its first chunk, and a
  mid-prefill stall defers the chunk rather than deadlocking), and
  mid-prefill slots are excluded from the fused dispatch's ``active`` mask
  with their page-table rows shielded to scratch until the last chunk
  lands.  Page-aware by construction: paged backend only, single-device
  (the sharded pool's per-chip chunk scatter is a ROADMAP follow-on), and
  dense-FFN families only — MoE capacity routing depends on the forwarded
  group shape, so chunk-at-a-time routing would break stream parity.
* **Multi-tenant SLO scheduling** (``tenancy=``, ``repro.serve.tenancy``):
  requests carry a tenant id; each tenant has a priority class
  (``interactive`` / ``batch``, extensible) and an optional KV **page
  quota** the paged banker enforces (a quota deny skips just that request
  — other tenants keep admitting — while a pool deny still stops
  admission in order).  Admission is priority-ordered (stable FIFO within
  a class), chunked prefill schedules TTFT-sensitive classes first with
  optional per-class token budgets, and under slot/page pressure the
  engine **preempts** the lowest-priority preemptible running decode:
  its pages are evicted and the request re-queued for
  recompute-on-resume prefill (prompt + generated-so-far tokens re-enter
  as one prefill, re-sharing still-registered prefix pages, with the
  sampling step index resumed so non-greedy streams stay reproducible).
* **On-device sampling**: greedy / temperature / top-k / top-p run as a
  vectorized kernel (``repro.serve.sampling``) fused into the decode
  dispatch.  The only host transfer per iteration is the (B,) vector of
  sampled token ids.
* **Scratch-routed inactive writes**: masked (free) slots still participate
  in the fused scatter, but their write position is routed to a scratch
  location — row 0 of their own slot (contiguous: always rewritten by the
  next prefill before it can be attended) or the scratch page (paged: a
  freed slot's page-table row points at physical page 0), so a freed slot
  can never deposit stale-position K/V into pages that have since been
  reallocated to another request.
* **Fault tolerance** (``repro.serve.faults``): a seedable ``FaultPlan``
  injects faults at named seams — non-finite logits inside the fused
  dispatch, poisoned KV pages, a failed chip of the sharded pool, stalled
  prefill chunks, transient dispatch exceptions.  Detection is in-band
  and cheap: the fused step's non-finite guard maps a bad logit row to a
  ``-1`` token sentinel riding the existing single (B,) host transfer, a
  per-stream progress watchdog (``watchdog_iters``) catches wedged slots,
  and ``PagedCache.verify()`` re-checks the allocator invariants every
  iteration in debug mode (``verify_cache=True``).  Recovery reuses the
  preemption machinery so it stays **bitwise**: the suspect slot's pages
  are dropped from the prefix registry (corrupt content must never
  re-share) and evicted, and the request re-queues for
  recompute-on-resume prefill with its sampling step indices intact —
  greedy AND seeded streams continue bit-identically — under bounded
  retries with exponential backoff.  A stream out of retries (or whose
  footprint can never fit again after a chip loss) dead-letters:
  ``status="dead_letter"`` with the error on the ``Request``, neighbours
  untouched.  Chip failure (``PagedCache.fail_chip``) drains the lost
  chip's free pages so capacity degrades from P to P·(n-1)/n and only
  the streams actually holding pages there are recovered.

Finished slots (EOS or max_len) free their cache reservation and are
refilled from the queue — the 'continuous batching' part.  Dispatch and
memory accounting are exported through the metrics registry
(``serve_decode_dispatches_total`` / ``serve_iterations_total`` /
``serve_prefill_dispatches_total`` / ``serve_prefill_batch_size`` /
``serve_kv_pages_in_use`` / ``serve_kv_bytes_reserved`` /
``serve_decode_transient_bytes``) so the one-call-per-iteration and
paged-memory invariants are observable, not asserted.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import ForwardOpts, LM
from repro.core.telemetry import MetricsRegistry
from repro.serve.faults import FaultEvent, FaultPlan, TransientDispatchError
from repro.serve.sampling import sample_batch
from repro.serve.tenancy import TenancyConfig, Victim, next_victim


@dataclass
class SamplingParams:
    temperature: float = 0.0         # 0 => greedy
    top_k: int = 0                   # 0 => no top-k filter
    top_p: float = 1.0               # nucleus
    seed: int = 0


@dataclass
class _PrefillState:
    """A slot mid-chunked-prefill: resumable across engine iterations."""
    req: Request
    done: int = 0            # prefill positions landed so far
    shared: int = 0          # leading positions backed by shared pages
    # the token array being prefilled: the prompt, or — after a preemption
    # — prompt + generated-so-far (recompute-on-resume)
    tokens: Optional[np.ndarray] = None


@dataclass
class Request:
    id: int
    prompt: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int = 32
    eos_id: int = -1                 # -1: never stops early
    sampling: SamplingParams = field(default_factory=SamplingParams)
    img_embeds: Optional[np.ndarray] = None   # (num_image_tokens, d) for vlm
    out_tokens: List[int] = field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: Optional[float] = None
    done_at: Optional[float] = None
    tenant: str = "default"          # tenancy key (ignored without tenancy=)
    preemptions: int = 0             # times this request lost its slot
    last_token_at: Optional[float] = None     # for inter-token latency
    retries: int = 0                 # fault/watchdog recoveries consumed
    error: Optional[str] = None      # dead-letter / stuck diagnostic
    status: str = "pending"          # terminal: completed|dead_letter|stuck
    _seq: int = 0                    # submit order — the FIFO tiebreak
    _resume_after: int = 0           # recovery backoff: earliest readmit iter


class EngineStuckError(RuntimeError):
    """``run_until_drained`` exhausted ``max_iters`` with work still in
    flight.  ``.stuck`` holds the wedged requests, each flagged
    ``status="stuck"`` with the diagnostic on ``Request.error``."""

    def __init__(self, message: str, stuck: List["Request"]):
        super().__init__(message)
        self.stuck = stuck


def _filtered_probs_np(logits: np.ndarray, params: SamplingParams) -> np.ndarray:
    """The (float64 numpy) filtered distribution ``sample_token`` draws from —
    the per-row reference the vectorized device sampler is tested against."""
    x = logits.astype(np.float64) / params.temperature
    if params.top_k > 0:
        kth = np.partition(x, -params.top_k)[-params.top_k]
        x = np.where(x < kth, -np.inf, x)
    p = np.exp(x - np.max(x))
    p /= p.sum()
    if params.top_p < 1.0:
        order = np.argsort(-p)
        cum = np.cumsum(p[order])
        cut = np.searchsorted(cum, params.top_p) + 1
        mask = np.zeros_like(p)
        mask[order[:cut]] = 1.0
        p = p * mask
        p /= p.sum()
    return p


def sample_token(logits: np.ndarray, params: SamplingParams,
                 step: int) -> int:
    """Greedy / temperature / top-k / top-p sampling over a 1-D logit row
    (host-side reference implementation; the engine samples on device)."""
    if params.temperature <= 0.0:
        return int(np.argmax(logits))
    p = _filtered_probs_np(logits, params)
    rng = np.random.default_rng((params.seed, step))
    return int(rng.choice(len(p), p=p))


class ServeEngine:
    def __init__(self, lm: LM, params, max_batch: int, max_seq: int,
                 opts: ForwardOpts = ForwardOpts(attn_impl="dense",
                                                 remat="none"),
                 registry: Optional[MetricsRegistry] = None,
                 greedy: bool = True,
                 cache_backend: str = "paged", page_size: int = 16,
                 num_pages: Optional[int] = None,
                 prefix_sharing: bool = True,
                 decode_impl: str = "gather",
                 mesh=None, kv_axis: str = "model", dp_axis=None,
                 prefill_chunk: int = 0, prefill_budget: int = 0,
                 kv_dtype: str = "native",
                 tenancy: Optional[TenancyConfig] = None,
                 fault_plan: Optional[FaultPlan] = None,
                 watchdog_iters: int = 0, max_retries: int = 3,
                 verify_cache: bool = False, alerts=None,
                 health_every: int = 16,
                 locality_chips: Optional[int] = None,
                 host_pages: int = 0, prefix_store=None):
        # per-slot positions rely on masked-then-overwritten cache writes,
        # which holds for attention KV caches but not recurrent state
        assert lm.cfg.family in ("dense", "moe", "vlm"), (
            "ServeEngine supports attention-cache families; recurrent archs "
            "serve via a synchronized full-batch decode loop")
        self.lm = lm
        self.params = params
        self.B = max_batch
        self.S = max_seq
        self.finished: List[Request] = []
        self.opts = opts
        self.reg = registry or MetricsRegistry()
        self.greedy = greedy
        self.img_len = (lm.cfg.num_image_tokens
                        if lm.cfg.family == "vlm" else 0)
        dt = jnp.float32 if lm.cfg.dtype == "float32" else jnp.bfloat16
        self.kv = lm.init_cache(max_batch, max_seq, dtype=dt,
                                backend=cache_backend, page_size=page_size,
                                num_pages=num_pages,
                                prefix_sharing=prefix_sharing,
                                decode_impl=decode_impl, mesh=mesh,
                                kv_axis=kv_axis, dp_axis=dp_axis,
                                kv_dtype=kv_dtype,
                                locality_chips=locality_chips,
                                host_pages=host_pages,
                                prefix_store=prefix_store)
        # host-tier counter sync: the PrefixStore keeps monotonic totals;
        # _export_memory publishes them as counter increments by delta
        self._host_synced: Dict[str, int] = {}
        # fault injection + detection + recovery (repro.serve.faults): the
        # plan is polled once per step; all detection state is host-side
        self.fault_plan = fault_plan
        self.watchdog_iters = int(watchdog_iters)
        self.max_retries = int(max_retries)
        self.verify_cache = bool(verify_cache)
        self.alerts = alerts
        self.health_every = int(health_every)
        if fault_plan is not None and type(self.kv).backend != "paged":
            bad = ({e.kind for e in fault_plan.events}
                   & {"poison_page", "chip_failure", "stall_chunk"})
            if bad:
                raise ValueError(
                    f"fault kinds {sorted(bad)} target the paged allocator "
                    "(physical pages / chips / chunked grants); use "
                    "cache_backend='paged'")
        self._iter = 0                      # step() clock (faults, watchdog)
        self._pending_faults: List[FaultEvent] = []  # carried until firable
        self._poison_slots: set = set()     # nan_logits victims this step
        self._stall_until: Dict[int, int] = {}   # slot -> stall expiry iter
        self._dispatch_fail_left = 0        # queued transient dispatch raises
        self._last_progress: Dict[int, int] = {}  # slot -> last progress it.
        self._quarantined: Dict[int, int] = {}    # req.id -> recovery start
        # chunked prefill: C-token chunks interleaved with decode, at most
        # `budget` prefill tokens per engine iteration (0 = whole-prompt)
        self.chunk = int(prefill_chunk)
        self.budget = int(prefill_budget) or self.chunk
        if prefill_budget and not self.chunk:
            raise ValueError(
                "prefill_budget bounds *chunked* prefill per iteration; "
                "without prefill_chunk the whole prompt lands in one "
                "dispatch and no budget applies (set prefill_chunk)")
        if self.chunk:
            if cache_backend != "paged":
                raise ValueError(
                    "chunked prefill is page-aware: chunks claim pages "
                    "incrementally and mid-prefill slots shield their table "
                    "rows from decode (use cache_backend='paged')")
            if self.img_len:
                raise ValueError(
                    "chunked prefill covers token prompts; VLM image-embed "
                    "prefixes prefill whole-prompt")
            if lm.cfg.family == "moe":
                raise ValueError(
                    "chunked prefill would change MoE expert-capacity "
                    "routing: moe_ffn computes capacity and token dropping "
                    "per forwarded sequence, so a (1, C) chunk routes "
                    "differently than the whole bucketed prompt and the "
                    "bitwise stream-parity contract breaks; MoE prompts "
                    "prefill whole-prompt")
            if self.chunk < 1 or self.budget < self.chunk:
                raise ValueError(
                    f"prefill budget {self.budget} below one chunk "
                    f"({self.chunk}): no chunk could ever dispatch")
        # multi-tenant SLO scheduling: priority-ordered admission, per-tenant
        # page quotas (enforced by the paged banker), preemptive eviction
        self.tenancy = tenancy
        self._submit_seq = 0
        if tenancy is not None:
            if tenancy.has_quotas():
                if type(self.kv).backend != "paged":
                    raise ValueError(
                        "per-tenant page quotas are enforced inside the "
                        "paged backend's banker-style safety check; the "
                        "contiguous layout has no pages to meter (use "
                        "cache_backend='paged' or drop the quotas)")
                for spec in tenancy.tenants.values():
                    if spec.page_quota is not None:
                        self.kv.set_quota(spec.name, spec.page_quota)
            if tenancy.preemption and type(self.kv).backend != "paged":
                raise ValueError(
                    "preemption evicts a victim's KV pages back to the "
                    "pool; the contiguous layout pre-reserves every slot so "
                    "there is nothing to reclaim (use cache_backend='paged' "
                    "or TenancyConfig(..., preemption=False))")
            if self.chunk:
                for spec in tenancy.tenants.values():
                    cap = tenancy.classes[spec.cls].prefill_budget
                    if cap is not None and cap < self.chunk:
                        raise ValueError(
                            f"class {spec.cls!r} prefill_budget {cap} below "
                            f"one chunk ({self.chunk}): its tenants could "
                            "never finish a prefill")
        self.prefilling: dict = {}           # slot -> _PrefillState (FIFO)
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)   # next write index
        self.queue: List[Request] = []
        # per-slot device-call state: the pending (sampled, not yet emitted)
        # token plus the sampling params, mirrored as flat arrays so the
        # fused dispatch takes plain (B,) tensors
        self.next_token = np.zeros(max_batch, np.int32)
        self.active = np.zeros(max_batch, bool)
        self.temps = np.zeros(max_batch, np.float32)
        self.top_ks = np.zeros(max_batch, np.int32)
        self.top_ps = np.ones(max_batch, np.float32)
        self.seeds = np.zeros(max_batch, np.int32)
        # the pool/rows argument is donated so XLA can update the cache in
        # place instead of double-buffering it per dispatch (live HBM stays
        # ~bytes_total, not 2x).  The page table is a separate, NON-donated
        # input: its device copy is cached across steps by PagedCache.
        self._fused = jax.jit(self._make_fused(), static_argnums=(12,),
                              donate_argnums=(2,))
        self._prefill = jax.jit(self._make_prefill(), donate_argnums=(3,))
        if self.chunk:
            self._chunk_step = jax.jit(self._make_chunk(),
                                       donate_argnums=(2,))
        self._declare_metrics()
        if tenancy is not None:
            for spec in tenancy.tenants.values():
                if spec.page_quota is not None:
                    self.reg.gauge("serve_tenant_quota_pages").set(
                        spec.page_quota, {"tenant": spec.name})

    def _declare_metrics(self):
        """Eagerly register every metric the engine can emit, with help
        text, so the observability surface is complete from iteration zero
        (dashboards see zero-valued series instead of gaps) and
        ``docs/telemetry.md`` can be verified against the registry by a test
        (tests/test_docs.py) rather than by hand."""
        c, g, h = self.reg.counter, self.reg.gauge, self.reg.histogram
        c("serve_requests_total", "requests accepted by submit()")
        c("serve_admission_deferred_total",
          "admissions deferred by page-pool admission control; the "
          "'reason' label splits pool_exhausted vs quota_denied (the "
          "unlabeled series counts both)")
        c("serve_quota_denied_total",
          "admissions denied by a per-tenant page quota (the tenant's "
          "request is skipped; lower-priority tenants still admit)")
        c("serve_preemptions_total",
          "running decodes preempted under pressure: pages evicted, "
          "request re-queued for recompute-on-resume prefill")
        c("serve_prefill_dispatches_total",
          "prefill device dispatches (bucketed groups + chunks)")
        c("serve_prefill_tokens_total", "prompt tokens prefilled")
        c("serve_prefill_chunks_total", "chunked-prefill chunk dispatches")
        c("serve_prefill_chunk_stalls_total",
          "prefill chunks deferred because a page grant was not banker-safe")
        c("serve_decode_stall_iters",
          "iterations where live decode streams waited on prefill work "
          "exceeding the per-iteration budget")
        c("serve_decode_dispatches_total", "fused decode+sample dispatches")
        c("serve_iterations_total", "engine iterations")
        c("serve_tokens_total", "tokens emitted by finished requests")
        h("serve_ttft_seconds", "submit-to-first-token latency")
        h("serve_latency_seconds", "submit-to-completion latency")
        h("serve_class_ttft_seconds",
          "submit-to-first-token latency by priority class ('class' label; "
          "populated when tenancy is configured)")
        h("serve_class_itl_seconds",
          "inter-token latency by priority class ('class' label; a "
          "preempted stream's requeue gap counts — that is the SLO cost "
          "of preemption)")
        h("serve_prefill_batch_size",
          "requests covered by one bucketed prefill dispatch",
          buckets=(1, 2, 4, 8, 16, 32, 64, float("inf")))
        g("serve_kv_pages_in_use", "physical KV pages reserved by live slots")
        g("serve_kv_bytes_reserved", "cache bytes reserved by live slots")
        g("serve_kv_pages_shared", "pages with refcount > 1 (prefix sharing)")
        g("serve_kv_bytes_per_chip", "pinned cache bytes per mesh chip")
        g("serve_decode_transient_bytes",
          "per-step transient of the paged KV read path, one layer")
        g("serve_kv_quant_enabled",
          "1 when the cache stores int8 quantized KV pages")
        g("serve_kv_quant_scale_bytes",
          "HBM pinned by the int8 page format's fp32 scale arrays")
        g("serve_kv_quant_bytes_saved",
          "pool bytes saved by int8 pages vs the compute-dtype pool")
        g("serve_tenant_pages_in_use",
          "footprint pages charged to each tenant ('tenant' label)")
        g("serve_tenant_quota_pages",
          "configured per-tenant page quota ('tenant' label)")
        c("serve_faults_injected_total",
          "injected faults that fired, by seam ('kind' label)")
        c("serve_stream_retries_total",
          "stream recoveries (quarantine + evict + re-queue) and transient "
          "dispatch retries, by detection ('reason' label)")
        c("serve_dead_letter_total",
          "requests terminally failed: recovery retries exhausted or "
          "footprint unfittable after a chip loss ('reason' label)")
        h("serve_recovery_iters",
          "engine iterations from a stream's quarantine to its next "
          "emitted token",
          buckets=(1, 2, 4, 8, 16, 32, 64, float("inf")))
        g("serve_streams_quarantined",
          "streams currently re-queued by fault recovery (awaiting resume)")
        c("serve_prefill_chunks_skipped_total",
          "prefill chunks whose forward was skipped because every position "
          "was already backed by landed shared pages (device-shared or "
          "prefetched from the host tier)")
        c("serve_prefix_store_hits_total",
          "prefix-store page lookups served from the host tier")
        c("serve_prefix_store_misses_total",
          "prefix-store page lookups that missed (cold, evicted, digest "
          "collision, or quarantined-poisoned) and recomputed prefill")
        c("serve_host_evictions_total",
          "host-tier pages LRU-evicted to make room for newer prefixes")
        c("serve_host_offload_bytes_total",
          "wire bytes copied device->host by cold-prefix offload")
        c("serve_host_prefetch_bytes_total",
          "wire bytes copied host->device by prefix-hit prefetch")
        g("serve_host_pages_in_use",
          "prefix pages resident in the host-RAM tier's pinned buffers")

    # ---------------------------------------------------------- jit builds ----
    def _make_fused(self):
        """One device call: decode all B slots at their own positions, then
        sample the next token for every slot, vectorized.  Returns the (B,)
        sampled ids (zeros on inactive slots) and the new cache view.

        ``all_greedy`` is static: the common all-greedy batch compiles to a
        bare argmax, skipping the top-k/top-p sort machinery entirely (at
        most two jit cache entries)."""
        lm, vocab = self.lm, self.lm.cfg.vocab_size
        decode_impl = self.kv.decode_impl   # fixed per engine (kvcache config)
        mesh, kv_axis = self.kv.mesh, self.kv.kv_axis
        dp_axis = self.kv.dp_axis

        def fused(params, tokens, layers, page_table, positions, active,
                  temps, top_ks, top_ps, seeds, steps, poison, all_greedy):
            cache = {"layers": layers}
            if page_table is not None:
                cache["page_table"] = page_table
            logits, cache = lm.decode_step(params, tokens, cache, positions,
                                           decode_impl=decode_impl,
                                           mesh=mesh, kv_axis=kv_axis,
                                           dp_axis=dp_axis)
            rows = logits[:, -1, :vocab].astype(jnp.float32)
            # nan_logits fault seam: a traced (B,) mask NaNs the victim's
            # row *inside* the dispatch, so detection exercises the real
            # guard (all-False on healthy iterations — same trace)
            rows = jnp.where(poison[:, None], jnp.nan, rows)
            if all_greedy:
                tok = jnp.argmax(rows, axis=-1).astype(jnp.int32)
            else:
                tok = sample_batch(rows, temps, top_ks, top_ps, seeds, steps)
            # non-finite guard: a row with any NaN/Inf yields the -1
            # sentinel in place of a token id — detection rides the
            # existing single (B,) host transfer instead of adding one
            tok = jnp.where(jnp.isfinite(rows).all(axis=-1), tok, -1)
            return jnp.where(active, tok, 0), cache["layers"]

        return fused

    def _make_prefill(self):
        """Batched whole-prompt prefill: one forward with cache collection
        for ``n`` same-bucket prompts, scatter the K/V blocks into every
        admitted slot's storage (rows for contiguous, page-table-resolved
        flat indices for paged), and sample each request's first token on
        device — all in one dispatch.  jit caches one trace per
        (group size, prompt bucket) pair.

        ``steps`` is each request's per-stream sampling index: 0 for a
        fresh prompt, ``len(out_tokens)`` for a preempted request being
        recompute-resumed — the token it re-samples is that deep in its
        stream, so a seeded non-greedy stream draws the same value it
        would have drawn without the preemption."""
        lm, opts, vocab = self.lm, self.opts, self.lm.cfg.vocab_size
        has_img = self.img_len > 0
        writer = self.kv.staged_write_prefill

        def run(params, tokens, img_embeds, layers, write_spec, last_idx,
                temps, top_ks, top_ps, seeds, steps):
            batch = {"tokens": tokens}
            if has_img:
                batch["img_embeds"] = img_embeds
            logits, _, pcache = lm.forward(params, batch, opts,
                                           collect_cache=True)
            layers = writer(layers, pcache["layers"], write_spec)
            n = tokens.shape[0]
            rows = logits[jnp.arange(n), last_idx, :vocab].astype(jnp.float32)
            toks = sample_batch(rows, temps, top_ks, top_ps, seeds, steps)
            # same non-finite sentinel as the fused dispatch
            toks = jnp.where(jnp.isfinite(rows).all(axis=-1), toks, -1)
            return toks, layers

        return run

    def _make_chunk(self):
        """One chunked-prefill device call: forward a stacked (n, C) round
        of chunks — at most one chunk per slot — against their pages
        (``lm.prefill_chunk`` — scatter + prior-cache attention), and
        sample a would-be first token from each chunk's last valid row.
        A sampled token is consumed only when that chunk was its prompt's
        final one; computing it unconditionally keeps traces cheap.  jit
        caches one trace per round group size (same shape discipline as
        ``_prefill_group``); every chunk is padded to the fixed length.

        Under ``mesh=`` the chunk forward routes through the sharded
        write/attend primitive (per-chip ``mode="drop"`` scatters + the
        partial-softmax merge) — the captured mesh/axes mirror
        ``_make_fused``."""
        lm, vocab = self.lm, self.lm.cfg.vocab_size
        mesh, kv_axis = self.kv.mesh, self.kv.kv_axis
        dp_axis = self.kv.dp_axis

        def run(params, tokens, layers, page_row, dest, start_pos, last_pos,
                temps, top_ks, top_ps, seeds, steps):
            cache = {"layers": layers, "page_table": page_row}
            logits, cache = lm.prefill_chunk(params, tokens, cache,
                                             start_pos, dest, last_pos,
                                             mesh=mesh, kv_axis=kv_axis,
                                             dp_axis=dp_axis)
            rows = logits[:, -1, :vocab].astype(jnp.float32)
            toks = sample_batch(rows, temps, top_ks, top_ps, seeds, steps)
            # the chunk attends prior pages: a poisoned page surfaces here
            toks = jnp.where(jnp.isfinite(rows).all(axis=-1), toks, -1)
            return toks, cache["layers"]

        return run

    # ------------------------------------------------------------- intake ----
    def submit(self, req: Request):
        if len(req.prompt) == 0:
            raise ValueError(f"request {req.id}: empty prompt "
                             "(nothing to prefill or sample from)")
        if len(req.prompt) + self.img_len >= self.S:
            raise ValueError(
                f"request {req.id}: prompt length {len(req.prompt)} "
                f"(+{self.img_len} image tokens) leaves no room to decode "
                f"in a max_seq={self.S} cache")
        if not self.kv.can_ever_fit(self._footprint(req)):
            raise ValueError(
                f"request {req.id}: footprint of {self._footprint(req)} "
                f"positions can never fit the {type(self.kv).backend} cache "
                "pool (shrink the prompt/max_new_tokens or grow num_pages)")
        if self.tenancy is not None:
            self.tenancy.spec(req.tenant)    # raises on unknown tenant
        req.submitted_at = time.perf_counter()
        req._seq = self._submit_seq
        self._submit_seq += 1
        self.queue.append(req)
        self.reg.counter("serve_requests_total").inc()

    def _free_slots(self) -> List[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    # ----------------------------------------------------------- tenancy ----
    def _prio(self, req: Request) -> int:
        return self.tenancy.priority_of(req.tenant) if self.tenancy else 0

    def _class_name(self, req: Request) -> str:
        return self.tenancy.spec(req.tenant).cls if self.tenancy else "none"

    def _tenant(self, req: Request) -> Optional[str]:
        return req.tenant if self.tenancy is not None else None

    def _admission_order(self) -> List[Request]:
        """Queue snapshot in admission order: priority class first, then
        submit order.  Without tenancy the sort is a no-op (all priority 0,
        stable by ``_seq``) — plain FIFO, bit-identical to the untenanted
        engine.  A request preempted *during* the current admission pass
        re-enters ``self.queue`` but not this snapshot, so one pass can
        never preempt-and-readmit the same request.  A recovering request
        stays invisible until its ``_resume_after`` backoff horizon."""
        ready = [r for r in self.queue if r._resume_after <= self._iter]
        return sorted(ready, key=lambda r: (-self._prio(r), r._seq))

    def _prefill_tokens(self, req: Request) -> np.ndarray:
        """What prefill must land for this request: the prompt — plus, for
        a preempted request, every token it had already generated
        (recompute-on-resume: the whole history re-enters as one prefill,
        re-sharing any of its pages still in the prefix registry)."""
        if req.out_tokens:
            return np.concatenate(
                [np.asarray(req.prompt, np.int32),
                 np.asarray(req.out_tokens, np.int32)])
        return np.asarray(req.prompt, np.int32)

    def _count_deferral(self, reason: str) -> None:
        c = self.reg.counter("serve_admission_deferred_total")
        c.inc()                       # unlabeled total (both causes)
        c.inc(1, {"reason": reason})
        if reason == "quota_denied":
            self.reg.counter("serve_quota_denied_total").inc()

    def _preempt_for(self, req: Request) -> Optional[int]:
        """Evict the best victim so ``req`` can take its slot/pages.

        Victims are running decode slots only — strictly lower priority,
        preemptible class; mid-chunked-prefill slots are excluded (their
        banker need is in flight).  Returns the freed slot, or ``None``
        when nothing is eligible (equal priority never preempts: two batch
        tenants cannot livelock evicting each other)."""
        if (self.tenancy is None or not self.tenancy.preemption
                or type(self.kv).backend != "paged"):
            return None
        cands = [Victim(i, self._prio(r),
                        self.tenancy.class_of(r.tenant).preemptible,
                        self.kv.slot_freeable(i))
                 for i, r in enumerate(self.slot_req)
                 if r is not None and i not in self.prefilling]
        victim = next_victim(cands, self._prio(req))
        if victim is None:
            return None
        self._preempt(victim.slot)
        return victim.slot

    def _preempt(self, slot: int) -> None:
        """Evict ``slot``'s pages and re-queue its request.  The pending
        (sampled, not yet emitted) token is discarded — the resume prefill
        re-samples it at the same stream step, so a greedy or seeded
        stream continues bit-identically."""
        req = self.slot_req[slot]
        self.kv.evict(slot)
        self.slot_req[slot] = None
        self.active[slot] = False
        self.slot_pos[slot] = 0
        self.next_token[slot] = 0
        req.preemptions += 1
        self.queue.append(req)   # keeps _seq: resumes ahead of later peers
        self.reg.counter("serve_preemptions_total").inc()

    def _footprint(self, req: Request) -> int:
        """Cache positions a request can ever occupy — the number ``submit``
        validates against ``can_ever_fit`` and ``_admit`` reserves via
        ``kv.alloc``; keeping both on this one formula is what guarantees an
        admitted request can never stall waiting for pages that cannot
        exist."""
        return min(self.img_len + len(req.prompt) + req.max_new_tokens,
                   self.S)

    # ----------------------------------------------------- fault recovery ----
    def _apply_faults(self) -> None:
        """Fire every pending fault whose preconditions hold; the rest
        carry to the next iteration (a plan never silently drops a fault
        it could eventually fire)."""
        self._pending_faults = [
            e for e in self._pending_faults if not self._fire(e)]

    def _victim_slot(self, want: Optional[int]) -> Optional[int]:
        """Deterministic victim resolution: the requested slot if it is
        live and decodable, else the lowest active decoding slot."""
        slots = [i for i in range(self.B)
                 if self.active[i] and i not in self.prefilling]
        if want is not None:
            return want if want in slots else None
        return slots[0] if slots else None

    def _fire(self, e: FaultEvent) -> bool:
        """Apply one fault event; returns ``False`` to carry it forward."""
        if e.kind == "dispatch_error":
            self._dispatch_fail_left += e.duration
        elif e.kind == "nan_logits":
            slot = self._victim_slot(e.slot)
            if slot is None:
                return False
            self._poison_slots.add(slot)
        elif e.kind == "poison_page":
            pid = e.page
            if pid is None:
                slot = self._victim_slot(e.slot)
                if slot is None or self.slot_pos[slot] <= 0:
                    return False
                # the page backing the victim's most recent position: read
                # by its very next decode step, so detection is immediate
                pid = int(self.kv.page_table[
                    slot, (int(self.slot_pos[slot]) - 1) // self.kv.page])
            if pid <= 0:
                return False
            self.kv.poison_page(pid)
        elif e.kind == "stall_chunk":
            if not self.chunk:
                return True         # impossible by config: never fires
            slots = sorted(self.prefilling)
            if e.slot is not None:
                slots = [s for s in slots if s == e.slot]
            if not slots:
                return False
            self._stall_until[slots[0]] = self._iter + e.duration
        else:
            assert e.kind == "chip_failure", e.kind
            chip = e.chip if e.chip is not None \
                else getattr(self.kv, "chips", 1) - 1
            for s in self.kv.fail_chip(chip):
                self._recover(s, "chip_failure")
            # queued requests whose footprint can no longer ever fit the
            # degraded pool would defer forever: dead-letter them now
            for r in [r for r in self.queue
                      if not self.kv.can_ever_fit(self._footprint(r))]:
                self.queue.remove(r)
                self._dead_letter(r, "capacity_lost")
        self.reg.counter("serve_faults_injected_total").inc(
            1, {"kind": e.kind})
        return True

    def _recover(self, slot: int, reason: str) -> None:
        """Quarantine ``slot``'s stream and re-queue it for bitwise
        recompute-on-resume — the preemption path under a retry budget.
        The slot's pages are dropped from the prefix registry first
        (suspect content must never re-share into a resume prefill), then
        evicted; the request re-enters the queue keeping its ``_seq``,
        gated by an exponential-backoff resume horizon.  Out of retries —
        or with a footprint the post-chip-failure pool can never hold
        again — the request dead-letters instead of looping."""
        req = self.slot_req[slot]
        self.prefilling.pop(slot, None)
        if type(self.kv).backend == "paged":
            self.kv.unregister_pages(list(self.kv._slot_pages[slot]))
            self.kv.evict(slot)
        else:
            self.kv.free(slot)
        self.slot_req[slot] = None
        self.active[slot] = False
        self.slot_pos[slot] = 0
        self.next_token[slot] = 0
        self._stall_until.pop(slot, None)
        self._last_progress.pop(slot, None)
        req.retries += 1
        self.reg.counter("serve_stream_retries_total").inc(
            1, {"reason": reason})
        if req.retries > self.max_retries:
            self._dead_letter(req, reason)
        elif not self.kv.can_ever_fit(self._footprint(req)):
            self._dead_letter(req, "capacity_lost")
        else:
            # keep the original quarantine iteration across re-faults so
            # serve_recovery_iters measures fault-to-resumption end to end
            self._quarantined.setdefault(req.id, self._iter)
            req._resume_after = self._iter + (1 << (req.retries - 1))
            self.queue.append(req)   # keeps _seq: resumes ahead of peers
            self.reg.gauge("serve_streams_quarantined").set(
                len(self._quarantined))
        self._export_memory()

    def _dead_letter(self, req: Request, reason: str) -> None:
        """Terminal failure: surface the error on the request and finish
        it un-served (``status="dead_letter"``).  Neighbour streams are
        untouched — the slot and pages were already released."""
        req.status = "dead_letter"
        req.error = (f"dead-lettered after {req.retries} recoveries "
                     f"(reason: {reason})")
        req.done_at = time.perf_counter()
        self._quarantined.pop(req.id, None)
        self.reg.gauge("serve_streams_quarantined").set(
            len(self._quarantined))
        self.reg.counter("serve_dead_letter_total").inc(1, {"reason": reason})
        self.finished.append(req)

    def _watchdog(self) -> None:
        """Recover every live slot that made no progress — no token
        emitted, no chunk landed, no admission — for ``watchdog_iters``
        engine iterations (a stalled allocator grant, a need stranded by a
        chip failure, or any future wedge)."""
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            last = self._last_progress.get(slot, self._iter)
            if self._iter - last >= self.watchdog_iters:
                self._recover(slot, "watchdog")

    def _dispatch_fused(self, *args):
        """The fused dispatch behind the transient-fault retry loop.  An
        injected :class:`TransientDispatchError` raises *before* the real
        call, so the donated buffers are untouched and the retry is
        idempotent; ``max_retries`` consecutive failures re-raise — a
        permanently dead dispatch path is engine-fatal, not per-stream."""
        attempt = 0
        while True:
            try:
                if self._dispatch_fail_left > 0:
                    self._dispatch_fail_left -= 1
                    raise TransientDispatchError(
                        f"injected dispatch failure (iteration {self._iter})")
                return self._fused(*args)
            except TransientDispatchError:
                attempt += 1
                self.reg.counter("serve_stream_retries_total").inc(
                    1, {"reason": "dispatch_error"})
                if attempt > self.max_retries:
                    raise
                time.sleep(min(0.001 * (1 << (attempt - 1)), 0.05))

    # ------------------------------------------------------------ prefill ----
    def _admit(self):
        """Admit queued requests into free slots under admission control,
        then prefill them — one forward dispatch per power-of-two prompt
        bucket, each covering every same-bucket request admitted this
        iteration.

        Admission is FIFO within a priority class (priority-ordered across
        classes under tenancy): a request reserves its full cache footprint
        (prompt + max_new_tokens) via ``kv.alloc`` before its slot is
        committed.  A **pool** deny stops admission in order (no
        head-of-line skipping inside a class) — after preemption, if
        enabled, has run out of lower-priority victims to evict.  A
        **quota** deny skips just that request: its tenant is at cap, but
        other tenants' requests behind it must still admit."""
        if self.chunk:
            self._admit_chunked()
            return
        free = self._free_slots()
        admitted = []                 # (slot, req, bucket, shared_len, toks)
        stop = False
        for req in self._admission_order():
            if stop:
                break
            toks = self._prefill_tokens(req)
            # image positions are embeddings, not tokens — no hash identity,
            # so VLM requests skip prefix sharing
            prefix = toks if self.img_len == 0 else None
            while True:
                if not free:
                    slot = self._preempt_for(req)
                    if slot is None:
                        self._count_deferral("pool_exhausted")
                        stop = True
                        break
                    free.append(slot)
                shared = self.kv.alloc(free[0], self._footprint(req),
                                       prefix=prefix,
                                       tenant=self._tenant(req))
                if shared is not None:
                    slot = free.pop(0)
                    self.queue.remove(req)
                    plen = len(toks)
                    bucket = 1 << (plen - 1).bit_length()  # next power of two
                    bucket = min(bucket, self.S - self.img_len)
                    admitted.append((slot, req, bucket, shared, toks))
                    break
                if getattr(self.kv, "last_deny", None) == "quota":
                    self._count_deferral("quota_denied")
                    break             # skip this request, keep admitting
                slot = self._preempt_for(req)
                if slot is None:
                    self._count_deferral("pool_exhausted")
                    stop = True
                    break
                free.append(slot)
        # group same-bucket admissions into single forward dispatches
        for bucket in sorted({b for _, _, b, _, _ in admitted}):
            self._prefill_group(
                bucket, [a for a in admitted if a[2] == bucket])
        if admitted:
            self._export_memory()

    def _admit_chunked(self):
        """Chunked admission (priority-ordered under tenancy, FIFO within a
        class): an admitted request claims only its first chunk's pages
        (``kv.alloc_chunked`` — banker-safe incremental allocation, full
        footprint charged against its tenant's quota up front so later
        ``extend``s never quota-stall), takes a slot with the decode shield
        up, and joins ``self.prefilling``; its chunks dispatch from
        ``_run_prefill_chunks`` starting this same iteration.  Deny
        handling mirrors whole-prompt ``_admit``: pool denies preempt then
        stop, quota denies skip just the capped tenant's request."""
        free = self._free_slots()
        admitted = False
        stop = False
        for req in self._admission_order():
            if stop:
                break
            toks = self._prefill_tokens(req)
            first = min(self.chunk, len(toks))
            while True:
                if not free:
                    slot = self._preempt_for(req)
                    if slot is None:
                        self._count_deferral("pool_exhausted")
                        stop = True
                        break
                    free.append(slot)
                shared = self.kv.alloc_chunked(free[0], self._footprint(req),
                                               first, prefix=toks,
                                               tenant=self._tenant(req))
                if shared is not None:
                    slot = free.pop(0)
                    self.queue.remove(req)
                    self.slot_req[slot] = req
                    self.active[slot] = False    # not decodable yet
                    self.kv.set_decode_shield(slot, True)
                    self.prefilling[slot] = _PrefillState(
                        req=req, shared=shared, tokens=toks)
                    self._last_progress[slot] = self._iter
                    admitted = True
                    break
                if getattr(self.kv, "last_deny", None) == "quota":
                    self._count_deferral("quota_denied")
                    break
                slot = self._preempt_for(req)
                if slot is None:
                    self._count_deferral("pool_exhausted")
                    stop = True
                    break
                free.append(slot)
        if admitted:
            self._export_memory()

    def _run_prefill_chunks(self, budget: int, skip=(), cls_spent=None):
        """Dispatch up to ``budget`` tokens of prefill chunks in stacked
        rounds: each round collects at most one chunk per mid-prefill slot
        (same-slot chunks are sequentially dependent — chunk k attends the
        pages chunk k-1 wrote) and forwards them as ONE (n, C)
        ``_chunk_step`` dispatch, the chunk-time mirror of
        ``_prefill_group``'s stacked whole-prompt dispatch.  Rounds repeat
        while budget remains and slots still have chunks, so a lone long
        prompt drains its budget exactly as the per-slot loop did.

        Collection order is admission order without tenancy (dict order);
        with tenancy, TTFT-sensitive classes collect first (priority
        order, ``_seq`` tiebreak) and a class's per-iteration token cap
        (``PriorityClass.prefill_budget``, tracked across both
        same-iteration passes via ``cls_spent``) stops batch-class prompts
        from monopolizing the global budget.  Each collected chunk first
        ``extend``s the slot's pages to cover its end — the *final* chunk
        extends to the full footprint, claiming the decode tail — and a
        chunk whose grant is not banker-safe stalls (the slot resumes in a
        later iteration once completions free pages; the round dispatches
        without it).  When a slot's last chunk lands it is unshielded,
        marked active with the sampled first token pending, and decodes in
        this same iteration's fused dispatch.  Returns (budget tokens
        consumed, slots that stalled) — ``skip`` lets the second
        same-iteration pass avoid re-stalling slots the first already
        counted."""
        landed = spent = 0
        stalled: set = set()
        cls_spent: Dict[str, int] = \
            cls_spent if cls_spent is not None else {}
        if not self.prefilling:
            return spent, stalled
        done_slots: set = set(skip)     # no further chunks this call
        while budget >= self.chunk and self.prefilling:
            order = [s for s in self.prefilling if s not in done_slots]
            if self.tenancy is not None:
                order.sort(
                    key=lambda s: (-self._prio(self.prefilling[s].req),
                                   self.prefilling[s].req._seq))
            group = []      # (slot, st, req, ptoks, end, final, dest)
            for slot in order:
                if (len(group) + 1) * self.chunk > budget:
                    break
                st = self.prefilling[slot]
                req = st.req
                ptoks = st.tokens if st.tokens is not None else req.prompt
                plen = len(ptoks)
                cname = self._class_name(req)
                cap = (self.tenancy.classes[cname].prefill_budget
                       if self.tenancy is not None else None)
                if (cap is not None
                        and cls_spent.get(cname, 0) + self.chunk > cap):
                    done_slots.add(slot)
                    continue
                if self._iter < self._stall_until.get(slot, 0):
                    # injected stall_chunk fault: behaves exactly like a
                    # banker-unsafe grant until the stall expires
                    self.reg.counter(
                        "serve_prefill_chunk_stalls_total").inc()
                    stalled.add(slot)
                    done_slots.add(slot)
                    continue
                # fully-landed shared chunks skip their forward entirely:
                # every position below st.shared is backed by pages whose
                # content already landed (device prefix sharing, or a
                # host-tier prefetch at admission), the chunk's writes
                # would all scratch-route, and its logits are consumed
                # only on the FINAL chunk — so a covered non-final chunk
                # costs zero dispatches, zero budget.  This is where the
                # prefix-hit TTFT win comes from: a fully warm prompt
                # fast-forwards to its last chunk in one pass.
                skipped = 0
                while (st.done + self.chunk < plen
                       and st.done + self.chunk <= st.shared):
                    st.done += self.chunk
                    skipped += 1
                if skipped:
                    self.reg.counter(
                        "serve_prefill_chunks_skipped_total").inc(skipped)
                    self._last_progress[slot] = self._iter
                end = min(st.done + self.chunk, plen)
                final = end == plen
                cover = self._footprint(req) if final else end
                if not self.kv.extend(slot, cover):
                    self.reg.counter(
                        "serve_prefill_chunk_stalls_total").inc()
                    stalled.add(slot)
                    done_slots.add(slot)
                    continue             # defer-and-resume, not deadlock
                dest = self.kv.chunk_dest(slot, st.done, end, self.chunk,
                                          st.shared)
                cls_spent[cname] = cls_spent.get(cname, 0) + self.chunk
                group.append((slot, st, req, ptoks, end, final, dest))
            if not group:
                break
            n = len(group)
            tokens = np.zeros((n, self.chunk), np.int32)
            dests = np.zeros((n, self.chunk), np.int32)
            rows = np.zeros((n,) + self.kv.table_row(group[0][0]).shape,
                            np.int32)
            starts = np.zeros(n, np.int32)
            lasts = np.zeros(n, np.int32)
            temps = np.zeros(n, np.float32)
            top_ks = np.zeros(n, np.int32)
            top_ps = np.ones(n, np.float32)
            seeds = np.zeros(n, np.int32)
            steps = np.zeros(n, np.int32)
            for j, (slot, st, req, ptoks, end, final, dest) in \
                    enumerate(group):
                tokens[j, :end - st.done] = ptoks[st.done:end]
                dests[j] = dest
                rows[j] = self.kv.table_row(slot)
                starts[j] = st.done
                lasts[j] = end - 1
                sp = req.sampling
                temps[j] = sp.temperature
                top_ks[j] = sp.top_k
                top_ps[j] = sp.top_p
                seeds[j] = sp.seed
                steps[j] = len(req.out_tokens)
            toks, new_layers = self._chunk_step(
                self.params, jnp.asarray(tokens),
                self.kv.state["layers"], jnp.asarray(rows),
                jnp.asarray(dests), jnp.asarray(starts),
                jnp.asarray(lasts), jnp.asarray(temps),
                jnp.asarray(top_ks), jnp.asarray(top_ps),
                jnp.asarray(seeds), jnp.asarray(steps))
            self.kv.update({**self.kv.state, "layers": new_layers})
            self.reg.counter("serve_prefill_chunks_total").inc(n)
            self.reg.counter("serve_prefill_dispatches_total").inc()
            toks = np.asarray(toks)
            for j, (slot, st, req, ptoks, end, final, dest) in \
                    enumerate(group):
                self.reg.counter("serve_prefill_tokens_total").inc(
                    end - st.done)
                budget -= self.chunk
                spent += self.chunk
                tok0 = int(toks[j])
                if tok0 == -1:
                    # the chunk attended non-finite content (a poisoned
                    # page): quarantine before the landed pages can enter
                    # the prefix registry and re-share the corruption
                    self._recover(slot, "nonfinite_logits")
                    done_slots.add(slot)
                    continue
                self.kv.register_landed(slot, ptoks, end)
                landed += end - st.done
                st.done = end
                self._last_progress[slot] = self._iter
                if final:
                    del self.prefilling[slot]
                    self.kv.set_decode_shield(slot, False)
                    sp = req.sampling
                    self.slot_pos[slot] = self.img_len + len(ptoks)
                    self.next_token[slot] = tok0
                    self.active[slot] = True
                    self.temps[slot] = sp.temperature
                    self.top_ks[slot] = sp.top_k
                    self.top_ps[slot] = sp.top_p
                    self.seeds[slot] = sp.seed
        if landed:
            self._export_memory()
        return spent, stalled

    def _prefill_group(self, bucket: int, group):
        """One ``lm.forward`` dispatch for every admitted request in this
        prefill bucket: stacked (n, bucket) tokens in, per-request first
        tokens and the updated K/V storage out.  A recompute-resumed
        request's token array is prompt + generated-so-far; its first
        token re-samples at stream step ``len(out_tokens)``."""
        n = len(group)
        paged = type(self.kv).backend == "paged"
        tokens = np.zeros((n, bucket), np.int32)
        last_idx = np.zeros(n, np.int32)
        temps = np.zeros(n, np.float32)
        top_ks = np.zeros(n, np.int32)
        top_ps = np.ones(n, np.float32)
        seeds = np.zeros(n, np.int32)
        steps = np.zeros(n, np.int32)
        imgs = np.zeros((n, self.img_len, self.lm.cfg.d_model), np.float32) \
            if self.img_len else None
        block_len = self.img_len + bucket
        write_spec = (np.zeros((n, block_len), np.int32) if paged
                      else np.zeros(n, np.int32))
        for j, (slot, req, _, shared, ptoks) in enumerate(group):
            plen = len(ptoks)
            tokens[j, :plen] = ptoks
            last_idx[j] = self.img_len + plen - 1
            sp = req.sampling
            temps[j], top_ks[j] = sp.temperature, sp.top_k
            top_ps[j], seeds[j] = sp.top_p, sp.seed
            steps[j] = len(req.out_tokens)
            if self.img_len and req.img_embeds is not None:
                imgs[j] = req.img_embeds
            if paged:
                write_spec[j] = self.kv.prefill_dest(
                    slot, block_len, self.img_len + plen, shared)
            else:
                write_spec[j] = slot
        img = (jnp.asarray(imgs, jax.tree.leaves(self.kv.state)[0].dtype)
               if self.img_len else None)
        toks, new_layers = self._prefill(
            self.params, jnp.asarray(tokens), img, self.kv.state["layers"],
            jnp.asarray(write_spec), jnp.asarray(last_idx),
            jnp.asarray(temps), jnp.asarray(top_ks), jnp.asarray(top_ps),
            jnp.asarray(seeds), jnp.asarray(steps))
        self.kv.update({**self.kv.state, "layers": new_layers})
        toks = np.asarray(toks)
        for j, (slot, req, _, _, ptoks) in enumerate(group):
            sp = req.sampling
            self.slot_req[slot] = req
            self.slot_pos[slot] = self.img_len + len(ptoks)
            self.next_token[slot] = int(toks[j])
            self.active[slot] = True
            self.temps[slot] = sp.temperature
            self.top_ks[slot] = sp.top_k
            self.top_ps[slot] = sp.top_p
            self.seeds[slot] = sp.seed
            self._last_progress[slot] = self._iter
            self.reg.counter("serve_prefill_tokens_total").inc(len(ptoks))
        self.reg.counter("serve_prefill_dispatches_total").inc()
        # buckets fixed by the eager _declare_metrics registration
        self.reg.histogram("serve_prefill_batch_size").observe(n)
        for j, (slot, _, _, _, _) in enumerate(group):
            if int(toks[j]) == -1:
                # non-finite logits out of the prefill forward itself:
                # quarantine this slot before it can decode
                self._recover(slot, "nonfinite_logits")

    # ------------------------------------------------------------- decode ----
    def step(self) -> bool:
        """One engine iteration (``_step``), wrapped with the fault clock:
        scheduled faults fire first (events whose preconditions are not
        met yet carry forward), the watchdog then recovers any stream that
        made no progress for ``watchdog_iters`` iterations, debug mode
        re-verifies the allocator invariants, and — when an
        ``AlertManager`` is wired in — the serve-path light health checks
        and alert rules run every ``health_every`` iterations."""
        if self.fault_plan is not None:
            self._pending_faults.extend(self.fault_plan.events_at(self._iter))
            if self._pending_faults:
                self._apply_faults()
        live = self._step()
        if self.watchdog_iters:
            self._watchdog()
        if self.verify_cache and hasattr(self.kv, "verify"):
            self.kv.verify()
        if self.alerts is not None and self._iter % self.health_every == 0:
            from repro.core.health import serve_light_checks
            serve_light_checks(self)
            self.alerts.evaluate()
        self._iter += 1
        return live

    def _step(self):
        """One engine iteration: admit (+ up to one budget's worth of
        prefill chunks), then **one** fused decode+sample dispatch for all
        active slots at their own positions.

        ``serve_decode_stall_iters`` counts iterations where live decode
        streams waited on more prefill tokens than the per-iteration budget
        allows — zero by construction with chunking on; in whole-prompt mode
        there is no budget, so every prefill dispatched alongside live
        decode streams counts as a stall."""
        streams_waiting = bool(np.any(self.active))
        pf0 = self.reg.counter("serve_prefill_tokens_total").get()
        if self.chunk:
            # resume in-flight chunked prefills BEFORE admitting new work:
            # a stalled slot gets first claim on pages freed since last
            # iteration, so sustained short-request traffic can slow a
            # mid-prefill long prompt but never starve it
            cls_spent: Dict[str, int] = {}
            spent, stalled = self._run_prefill_chunks(self.budget,
                                                      cls_spent=cls_spent)
            self._admit()
            if spent < self.budget:
                # leftover budget covers a fresh admission's first chunk in
                # the same iteration (skip already-stalled slots: the pages
                # they need did not appear mid-iteration; per-class caps
                # carry over via cls_spent)
                self._run_prefill_chunks(self.budget - spent, skip=stalled,
                                         cls_spent=cls_spent)
        else:
            self._admit()
        pf_tokens = self.reg.counter("serve_prefill_tokens_total").get() - pf0
        if streams_waiting and pf_tokens > (self.budget if self.chunk else 0):
            self.reg.counter("serve_decode_stall_iters").inc()
        active_idx = [i for i, r in enumerate(self.slot_req)
                      if r is not None and i not in self.prefilling]
        if not active_idx:
            # mid-prefill slots are still work in flight
            return bool(self.prefilling)
        # per-slot sample-step index: the token being sampled now is
        # out_tokens[len]+1 deep in the request's stream (the pending token,
        # sampled earlier, is #len and gets emitted this iteration)
        steps = np.zeros(self.B, np.int32)
        for i in active_idx:
            steps[i] = len(self.slot_req[i].out_tokens) + 1
        # inactive slots decode at scratch position 0: their masked scatter
        # lands in storage the next prefill rewrites (contiguous row 0) or
        # in the scratch page (paged), never in live data
        positions = np.where(self.active,
                             np.minimum(self.slot_pos, self.S - 1), 0)
        all_greedy = bool(np.all(self.temps[self.active] <= 0.0))
        view = self.kv.decode_view()
        poison = np.zeros(self.B, bool)
        if self._poison_slots:
            poison[sorted(self._poison_slots)] = True
            self._poison_slots.clear()
        sampled, new_layers = self._dispatch_fused(
            self.params, jnp.asarray(self.next_token[:, None]),
            view["layers"], view.get("page_table"),
            jnp.asarray(positions), jnp.asarray(self.active),
            jnp.asarray(self.temps), jnp.asarray(self.top_ks),
            jnp.asarray(self.top_ps), jnp.asarray(self.seeds),
            jnp.asarray(steps), jnp.asarray(poison), all_greedy)
        self.kv.update({**view, "layers": new_layers})
        self.reg.counter("serve_decode_dispatches_total").inc()
        self.reg.counter("serve_iterations_total").inc()
        sampled = np.asarray(sampled)     # the one (B,) host transfer
        now = time.perf_counter()
        freed = False
        for i in active_idx:
            req = self.slot_req[i]
            tok = int(self.next_token[i])
            req.out_tokens.append(tok)
            if req.first_token_at is None:
                req.first_token_at = now
                self.reg.histogram("serve_ttft_seconds").observe(
                    now - req.submitted_at)
                if self.tenancy is not None:
                    self.reg.histogram("serve_class_ttft_seconds").observe(
                        now - req.submitted_at,
                        {"class": self._class_name(req)})
            elif self.tenancy is not None and req.last_token_at is not None:
                self.reg.histogram("serve_class_itl_seconds").observe(
                    now - req.last_token_at, {"class": self._class_name(req)})
            req.last_token_at = now
            self.slot_pos[i] += 1
            self._last_progress[i] = self._iter
            if req.id in self._quarantined:
                # the recovered stream resumed emitting: recovery complete
                self.reg.histogram("serve_recovery_iters").observe(
                    self._iter - self._quarantined.pop(req.id))
                self.reg.gauge("serve_streams_quarantined").set(
                    len(self._quarantined))
            done = (len(req.out_tokens) >= req.max_new_tokens
                    or tok == req.eos_id
                    or self.slot_pos[i] >= self.S)
            if done:
                req.done_at = now
                req.status = "completed"
                self.reg.counter("serve_tokens_total").inc(
                    len(req.out_tokens))
                self.reg.histogram("serve_latency_seconds").observe(
                    now - req.submitted_at)
                self.finished.append(req)
                self.slot_req[i] = None
                self.active[i] = False
                self.kv.free(i)
                freed = True
            elif int(sampled[i]) == -1:
                # the non-finite guard tripped on THIS step's logits
                # (injected NaN or a poisoned page read).  The pending
                # token just emitted came from last step's clean logits;
                # the corrupt sample is discarded and re-drawn at the same
                # stream step by the resume prefill — bitwise either way.
                self._recover(i, "nonfinite_logits")
            else:
                self.next_token[i] = sampled[i]
        if freed:
            self._export_memory()
        return True

    def _export_memory(self):
        st = self.kv.memory_stats()
        if self.tenancy is not None:
            for name in self.tenancy.tenants:
                self.reg.gauge("serve_tenant_pages_in_use").set(
                    st.tenant_pages.get(name, 0), {"tenant": name})
        self.reg.gauge("serve_kv_pages_in_use").set(st.pages_in_use)
        self.reg.gauge("serve_kv_bytes_reserved").set(st.bytes_reserved)
        self.reg.gauge("serve_kv_pages_shared").set(st.pages_shared)
        self.reg.gauge("serve_kv_bytes_per_chip").set(st.bytes_per_chip)
        # per-step transient of the paged KV read path (byte math, one
        # layer): the gather fallback scales with B·M·page, the pallas
        # kernel with the page block only — dense rows gather nothing
        transient = 0
        if st.backend == "paged":
            from repro.serve.kvcache import decode_transient_bytes
            transient = decode_transient_bytes(
                self.lm.cfg, self.B, self.kv.max_pages, st.page_size,
                self.kv.dtype, self.kv.decode_impl, kv_dtype=st.kv_dtype)
        self.reg.gauge("serve_decode_transient_bytes").set(transient)
        quant = st.kv_dtype == "int8"
        self.reg.gauge("serve_kv_quant_enabled").set(int(quant))
        self.reg.gauge("serve_kv_quant_scale_bytes").set(st.bytes_scales)
        saved = 0
        if quant:
            from repro.serve.kvcache import page_kv_bytes
            dense_total = page_kv_bytes(
                self.lm.cfg, st.page_size, self.kv.dtype) \
                * (st.pages_total + 1)
            saved = dense_total - st.bytes_total
        self.reg.gauge("serve_kv_quant_bytes_saved").set(saved)
        # host-RAM page tier: publish the store's monotonic totals as
        # counter deltas (counters are engine-owned; the store may be
        # shared across engines, so each engine syncs from its own mark)
        self.reg.gauge("serve_host_pages_in_use").set(st.host_pages_in_use)
        store = getattr(self.kv, "store", None)
        if store is not None:
            totals = store.stats()
            for metric, key in (
                    ("serve_prefix_store_hits_total", "hits"),
                    ("serve_prefix_store_misses_total", "misses"),
                    ("serve_host_evictions_total", "evictions"),
                    ("serve_host_offload_bytes_total", "offload_bytes"),
                    ("serve_host_prefetch_bytes_total", "prefetch_bytes")):
                delta = totals[key] - self._host_synced.get(key, 0)
                if delta:
                    self.reg.counter(metric).inc(delta)
                self._host_synced[key] = totals[key]

    def run_until_drained(self, max_iters: int = 10_000,
                          on_stuck: str = "raise") -> List[Request]:
        """Step until every submitted request reaches a terminal state
        (completed or dead-lettered).

        Exhausting ``max_iters`` with work still in flight no longer
        returns silently: every surviving request is flagged
        ``status="stuck"`` with its diagnostic on ``Request.error``, and
        ``on_stuck="raise"`` (default) raises :class:`EngineStuckError`
        naming the wedged slots and their last-progress iteration, while
        ``on_stuck="status"`` returns the survivors appended to
        ``finished`` so drivers can report per-stream outcomes."""
        assert on_stuck in ("raise", "status"), on_stuck
        for _ in range(max_iters):
            if not self.step() and not self.queue:
                return self.finished
        if not self.queue and all(r is None for r in self.slot_req):
            return self.finished
        stuck: List[Request] = []
        what: List[str] = []
        for slot, req in enumerate(self.slot_req):
            if req is None:
                continue
            last = self._last_progress.get(slot)
            lp = f"iteration {last}" if last is not None else "never"
            stuck.append(req)
            what.append(f"request {req.id} wedged in slot {slot} "
                        f"(last progress: {lp})")
        for req in self.queue:
            stuck.append(req)
            what.append(f"request {req.id} still queued")
        for req, w in zip(stuck, what):
            req.status = "stuck"
            req.error = f"undrained after {max_iters} iterations ({w})"
        if on_stuck == "status":
            return self.finished + stuck
        raise EngineStuckError(
            f"engine not drained after {max_iters} iterations: "
            + "; ".join(what), stuck)
