from repro.serve.engine import Request, SamplingParams, ServeEngine, \
    sample_token

__all__ = ["Request", "SamplingParams", "ServeEngine", "sample_token"]
