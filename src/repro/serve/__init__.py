from repro.serve.engine import (EngineStuckError, Request, SamplingParams,
                                ServeEngine, sample_token)
from repro.serve.faults import (FaultEvent, FaultPlan,
                                TransientDispatchError)
from repro.serve.kvcache import (CacheInvariantError, ContiguousCache,
                                 KVCache, MemoryStats, PagedCache,
                                 contiguous_kv_bytes,
                                 decode_transient_bytes, make_cache,
                                 page_kv_bytes, prefill_transient_bytes)
from repro.serve.offload import HostPageTier, HostTierError, PrefixStore
from repro.serve.sampling import filtered_probs, sample_batch
from repro.serve.tenancy import (BATCH, INTERACTIVE, PriorityClass,
                                 TenancyConfig, TenantSpec, Victim,
                                 next_victim)

__all__ = ["Request", "SamplingParams", "ServeEngine", "sample_token",
           "EngineStuckError", "FaultEvent", "FaultPlan",
           "TransientDispatchError", "CacheInvariantError",
           "filtered_probs", "sample_batch", "KVCache", "ContiguousCache",
           "PagedCache", "MemoryStats", "make_cache", "contiguous_kv_bytes",
           "decode_transient_bytes", "page_kv_bytes",
           "prefill_transient_bytes", "HostPageTier", "HostTierError",
           "PrefixStore", "PriorityClass",
           "INTERACTIVE", "BATCH", "TenantSpec", "TenancyConfig", "Victim",
           "next_victim"]
