from repro.serve.engine import Request, SamplingParams, ServeEngine, \
    sample_token
from repro.serve.sampling import filtered_probs, sample_batch

__all__ = ["Request", "SamplingParams", "ServeEngine", "sample_token",
           "filtered_probs", "sample_batch"]
