"""On-device batched sampling for the serve engine.

Greedy / temperature / top-k / top-p over a (B, V) logit matrix as one
vectorized, jittable computation.  The engine fuses this into the decode
dispatch, so the only host transfer per engine iteration is the (B,) vector
of sampled token ids (the seed engine pulled full per-slot logit rows to the
host and sampled with numpy — exactly the per-step overhead the paper's
Figs 5/6/8 warn about).

Per-row randomness is derived as ``fold_in(key(seed), step)``: a request's
sample stream depends only on its own (seed, step), never on batch
composition — continuous batching stays reproducible per request.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def filtered_probs(logits, temperature, top_k, top_p):
    """Per-row filtered sampling distribution.

    logits: (B, V) float; temperature / top_k / top_p: (B,) per-slot params.
    Rows with ``top_k == 0`` skip the top-k filter; rows with
    ``top_p >= 1`` skip the nucleus filter.  Rows with ``temperature <= 0``
    are greedy — the caller overrides them with argmax; here their
    temperature is clamped to 1 merely to keep the softmax finite.
    Returns (B, V) probabilities summing to 1 per row.
    """
    v = logits.shape[-1]
    t = jnp.where(temperature > 0, temperature, 1.0)[:, None]
    x = logits.astype(jnp.float32) / t
    # top-k: mask everything strictly below the k-th largest value
    k = jnp.clip(top_k, 0, v)
    sorted_desc = jnp.sort(x, axis=-1)[:, ::-1]
    kth = jnp.take_along_axis(sorted_desc, jnp.maximum(k - 1, 0)[:, None],
                              axis=-1)
    x = jnp.where((k[:, None] > 0) & (x < kth), -jnp.inf, x)
    p = jax.nn.softmax(x, axis=-1)
    # nucleus: keep a token iff the cumulative mass *before* it (descending
    # order) is < top_p — i.e. the smallest prefix whose mass reaches top_p
    order = jnp.argsort(-p, axis=-1)
    p_sorted = jnp.take_along_axis(p, order, axis=-1)
    cum = jnp.cumsum(p_sorted, axis=-1)
    keep_sorted = (cum - p_sorted) < top_p[:, None]
    inv = jnp.argsort(order, axis=-1)
    keep = jnp.take_along_axis(keep_sorted, inv, axis=-1)
    keep = keep | (top_p[:, None] >= 1.0)
    p = jnp.where(keep, p, 0.0)
    return p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)


def sample_batch(logits, temperature, top_k, top_p, seeds, steps):
    """Vectorized sampling: (B, V) logits -> (B,) int32 token ids.

    Greedy rows (temperature <= 0) take argmax; the rest draw from the
    filtered distribution with a per-row key folded from (seed, step).
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    p = filtered_probs(logits, temperature, top_k, top_p)

    def draw(p_row, seed, step):
        key = jax.random.fold_in(jax.random.key(seed), step)
        return jax.random.categorical(key, jnp.log(jnp.maximum(p_row, 1e-30)))

    sampled = jax.vmap(draw)(p, seeds, steps).astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy)
