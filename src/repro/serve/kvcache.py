"""Pluggable KV-cache backends for the serve engine.

The paper's clusters are "constantly moved between training and inferencing",
so the serving path has to live inside whatever HBM training left behind.
The #1 waste under ragged continuous batching is the cache reservation: a
dense cache pins ``max_seq`` rows per slot no matter how short the request.
This module makes the cache a first-class API with two backends behind one
small protocol (``alloc`` / ``write_prefill`` / ``decode_view`` / ``free`` /
``memory_stats``):

* ``ContiguousCache`` — today's dense (L, B, Smax, KV, D) layout.  The
  train/dry-run layout; every slot's full capacity is reserved up front, so
  ``alloc`` never fails and admission is bounded only by the slot count.
* ``PagedCache`` — fixed-size pages.  Physical storage is a per-layer
  (P, page, KV, D) pool; each slot owns a row of a (B, M) int32 **page
  table** mapping logical page index -> physical page.  ``alloc`` reserves
  ``ceil(total_len / page)`` pages (admission control: it returns ``None``
  when the pool is exhausted, instead of the engine OOMing), and **prefix
  sharing** lets identical prompt prefixes share physical pages: full prompt
  pages are keyed by a hash of the token prefix they cover and refcounted,
  so N requests with the same system prompt pin its pages once.

Physical page 0 is the **scratch page**: never allocated, it is where freed
slots' page-table rows point, so the fused decode's masked scatter-writes
for inactive slots land in garbage space rather than in pages that may since
have been reallocated to another request.

**Chunked-prefill allocation** (``alloc_chunked`` / ``extend``): a long
prompt admitted for chunked prefill takes only the pages its *first* chunk
writes; every later chunk claims its pages just before it dispatches, and
the final chunk claims the decode pages.  Admission and every grant run a
banker-style single-resource safety check — the live slots (each with its
remaining page need and the pages it would return on completion) must still
be completable in *some* order — so a partially-prefilled slot can stall
(``extend`` returns ``False``; the engine defers the chunk and resumes when
pages free) but can never deadlock the pool.  Mid-prefill slots are
**shielded** (``set_decode_shield``): ``decode_view`` hands the fused decode
dispatch a table whose shielded rows point at scratch, so the masked decode
write for a slot that is still prefilling can never land in its own live
pages.

Device-side state stays a plain pytree (``decode_view()``) so the engine's
one-fused-dispatch-per-iteration invariant from PR 1 is untouched: the page
table rides into ``lm.decode_step`` as just another (B, M) int32 argument.
Page-table *management* (alloc / free / refcounts / hashes) is host-side
numpy — it is O(pages) bookkeeping, never a device sync.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------- byte math ----

#: fp32 bytes of the per-row-per-KV-head scale the int8 page format stores
#: next to each quantized K/V row (``repro.kernels.quant.quantize_kv``).
SCALE_BYTES = 4


def kv_position_bytes(cfg, dtype, kv_dtype: str = "native") -> int:
    """Bytes of K+V cache per token position (all layers).

    ``kv_dtype="int8"``: each of the 2·L·KV rows stores head_dim int8
    elements plus one fp32 absmax scale — ``2·L·KV·(D + 4)`` bytes per
    position instead of ``2·L·KV·D·itemsize``."""
    l, kvh, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    if kv_dtype == "int8":
        return 2 * l * kvh * (hd + SCALE_BYTES)
    assert kv_dtype == "native", kv_dtype
    return 2 * l * kvh * hd * jnp.dtype(dtype).itemsize


def contiguous_kv_bytes(cfg, batch: int, max_seq: int, dtype) -> int:
    """HBM pinned by a dense cache: every slot reserves max_seq positions."""
    return batch * max_seq * kv_position_bytes(cfg, dtype)


def page_kv_bytes(cfg, page_size: int, dtype,
                  kv_dtype: str = "native") -> int:
    """HBM of one physical page (all layers, K+V, incl. int8 scales)."""
    return page_size * kv_position_bytes(cfg, dtype, kv_dtype)


def decode_transient_bytes(cfg, batch: int, max_pages: int, page_size: int,
                           dtype, decode_impl: str = "gather",
                           kv_dtype: str = "native") -> int:
    """Per-decode-step transient bytes of the paged KV *read* path, one
    layer's worth (the layer scan reuses the buffer).

    ``"gather"``: XLA materializes two dense-equivalent gathered views,
    (B, M*page, KV, D) each — the transient grows with the paged-enlarged
    concurrent batch.  ``"pallas"``: each (slot, kv-head) program of the
    page-table-walking kernel streams one (page, D) K and V tile into VMEM
    plus fp32 online-softmax state — O(page), independent of B and M.

    ``kv_dtype="int8"``: the gather twin additionally materializes the
    gathered scale views and the dequantized compute-dtype K/V (the int8
    gather shrinks but the dequant expands to ``dtype``); the kernel
    streams the int8 tile + its (page,) scale rows and dequantizes
    in-register, so its transient *shrinks* with the narrow wire format."""
    itemsize = jnp.dtype(dtype).itemsize
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    if decode_impl == "gather":
        rows = 2 * batch * max_pages * page_size * kvh
        if kv_dtype == "int8":
            return rows * (hd + SCALE_BYTES) + rows * hd * itemsize
        return rows * hd * itemsize
    assert decode_impl == "pallas", decode_impl
    g = cfg.num_heads // kvh
    if kv_dtype == "int8":
        return 2 * page_size * (hd + SCALE_BYTES) + 4 * g * (hd + 2)
    return 2 * page_size * hd * itemsize + 4 * g * (hd + 2)


def prefill_transient_bytes(cfg, group: int, block_len: int, dtype,
                            kv_dtype: str = "native") -> int:
    """Per-chip transient of the *sharded* paged prefill write path: the
    replicated (group, block_len) staged K/V block each chip scatters from
    under the shard_map primitive — O(group·block), independent of the
    pool width P.  The pre-unification GSPMD scatter could instead stage a
    replicated O(P)-pool temporary (= ``P · page_kv_bytes``); benches and
    the sharded tests compare the two measured ``temp_size_in_bytes``
    against these analytic poles."""
    return group * block_len * kv_position_bytes(cfg, dtype, kv_dtype)


class CacheInvariantError(AssertionError):
    """Raised by ``PagedCache.verify`` when the allocator's host-side
    bookkeeping violates an invariant — the detection signal for silent
    state corruption (vs the fused dispatch's non-finite guard, which
    detects *content* corruption)."""


@dataclass
class MemoryStats:
    backend: str
    bytes_total: int          # HBM pinned by the backend's physical storage
    bytes_reserved: int       # portion reserved by live requests
    slots_total: int
    slots_in_use: int
    page_size: int = 0        # paged only
    pages_total: int = 0      # usable pages (excludes scratch + failed chips)
    pages_in_use: int = 0
    pages_shared: int = 0     # pages with refcount > 1 (prefix sharing)
    mesh_chips: int = 1       # chips the pool is partitioned over (device
    #                           mesh OR the mesh-free locality_chips harness)
    bytes_per_chip: int = 0   # pinned bytes each chip holds (= total / chips,
    #                           int8 scale shards included via page_kv_bytes)
    kv_dtype: str = "native"  # page element format ("native" / "int8")
    bytes_scales: int = 0     # portion of bytes_total pinned by int8 scales
    bytes_scales_per_chip: int = 0   # each chip's sharded scale-array bytes
    chips_failed: int = 0     # chips drained by fail_chip (degraded pool)
    # footprint pages charged per tenant (multi-tenant serving; empty when
    # requests carry no tenant tag)
    tenant_pages: Dict[str, int] = field(default_factory=dict)
    # host-RAM page tier (PR 10): warm prefix pages resident in the
    # PrefixStore's pinned host slabs — zero when the tier is off
    host_pages_total: int = 0     # tier capacity (pages)
    host_pages_in_use: int = 0    # prefix pages currently stored
    host_bytes: int = 0           # wire bytes those pages pin in host RAM


class KVCache(Protocol):
    """The engine-facing cache protocol.

    ``alloc(slot, length, prefix=None, tenant=None)`` reserves capacity for
    ``length`` token positions in ``slot``; returns the number of leading
    positions already covered by shared physical storage (0 without
    sharing), or ``None`` if the backend cannot admit the request now
    (admission control) — ``last_deny`` then names the cause ("pool" vs
    "quota") so the engine can defer pool pressure but *skip past* a
    quota-capped tenant.  ``write_prefill(slot, kv_block)`` lands a prompt's K/V block
    in the slot's storage.  ``decode_view()`` is the device pytree handed to
    ``lm.decode_step``; ``update()`` stores the pytree a fused dispatch
    returned.  ``free(slot)`` releases the slot's storage.

    Engine-fusion surface (beyond the five core methods): ``backend`` names
    the layout, ``state`` is the backend's persistent device pytree (with a
    ``"layers"`` per-layer K/V entry — the engine donates exactly that
    subtree into its jitted dispatches), ``can_ever_fit`` backs submit-time
    rejection of requests no amount of freeing could admit, and
    ``staged_write_prefill`` is the *pure* (jit-stageable) form of
    ``write_prefill`` the engine traces into its one-dispatch-per-bucket
    batched prefill — its ``write_spec`` is backend-defined ((n,) slot ids
    for contiguous; (n, Sblk) flat pool indices from ``prefill_dest`` for
    paged).  ``mesh`` / ``kv_axis`` describe how the backend's storage is
    device-sharded (None / 1-extent for single-chip backends) — the engine
    forwards them into ``lm.decode_step`` so the fused dispatch runs the
    matching shard_map.
    """

    backend: str
    state: dict
    mesh: object
    kv_axis: str
    last_deny: Optional[str]

    def alloc(self, slot: int, length: int,
              prefix: Optional[np.ndarray] = None,
              tenant: Optional[str] = None) -> Optional[int]: ...
    def write_prefill(self, slot: int, kv_block) -> None: ...
    def decode_view(self): ...
    def update(self, new_state) -> None: ...
    def free(self, slot: int) -> None: ...
    def memory_stats(self) -> MemoryStats: ...
    def can_ever_fit(self, length: int) -> bool: ...
    def staged_write_prefill(self, layers, kv_block, write_spec): ...


# ---------------------------------------------------------- contiguous ----

class ContiguousCache:
    """Dense (B, Smax) rows per slot — the seed layout behind the new API.

    ``alloc`` always succeeds (capacity is pre-reserved, which is exactly
    the memory waste ``PagedCache`` exists to remove) and nothing is ever
    shared, so ``memory_stats().bytes_reserved == bytes_total`` at all
    times.
    """

    backend = "contiguous"
    decode_impl = "gather"      # dense rows have no page table to resolve
    mesh = None                 # dense rows have no kv_pages dim to shard
    kv_axis = "model"
    dp_axis = None
    kv_dtype = "native"         # int8 pages are a paged-format feature
    quantized = False
    last_deny = None            # alloc never fails -> never a deny reason

    def __init__(self, lm, batch: int, max_seq: int, dtype=jnp.bfloat16):
        self.cfg = lm.cfg
        self.B, self.S = batch, max_seq
        self.dtype = dtype
        self.state = lm.init_cache(batch, max_seq, dtype=dtype)
        self._in_use = np.zeros(batch, bool)
        self._bytes = sum(a.size * a.dtype.itemsize
                          for a in jax.tree.leaves(self.state))

    def can_ever_fit(self, length: int) -> bool:
        return length <= self.S

    def alloc(self, slot: int, length: int,
              prefix: Optional[np.ndarray] = None,
              tenant: Optional[str] = None) -> Optional[int]:
        assert not self._in_use[slot], f"slot {slot} already allocated"
        assert 0 < length <= self.S, (length, self.S)
        self._in_use[slot] = True
        return 0

    @staticmethod
    def staged_write_prefill(layers, kv_block, slots):
        """Jit-stageable multi-slot prefill write over the per-layer K/V
        subtree (``state["layers"]``).

        kv_block: per-layer (L, n, Sblk, ...) K/V for ``n`` admitted
        requests; slots: (n,) int32 target slots.  Rows [0, Sblk) of each
        slot are overwritten — including any prompt padding, which stays
        invisible behind the decode causal mask until decode rewrites it.
        """
        def write(big, small):
            # big: (L, B, S, ...); small: (L, n, Sblk, ...)
            rows = jnp.arange(small.shape[2])
            return big.at[:, slots[:, None], rows[None, :]].set(
                small.astype(big.dtype))

        return jax.tree.map(write, layers, kv_block)

    def write_prefill(self, slot: int, kv_block) -> None:
        self.state = {**self.state, "layers": self.staged_write_prefill(
            self.state["layers"], kv_block, jnp.asarray([slot], jnp.int32))}

    def decode_view(self):
        return self.state

    def update(self, new_state) -> None:
        self.state = new_state

    def free(self, slot: int) -> None:
        self._in_use[slot] = False

    def memory_stats(self) -> MemoryStats:
        return MemoryStats(backend=self.backend, bytes_total=self._bytes,
                           bytes_reserved=self._bytes, slots_total=self.B,
                           slots_in_use=int(self._in_use.sum()),
                           bytes_per_chip=self._bytes)


# --------------------------------------------------------------- paged ----

class PagedCache:
    """Fixed-size pages + (B, M) page-table indirection + prefix sharing.

    Pool: per-layer (L, P, page, KV, D) for K and V; page 0 is scratch.
    ``alloc`` reserves the request's full footprint up front
    (prompt + max_new_tokens), so a decode can never run out of pages
    mid-flight — exhaustion surfaces only as admission control.

    Prefix sharing: full prompt pages (positions [i*page, (i+1)*page) wholly
    inside the prompt) are keyed by the token prefix they causally depend on
    — K/V at position p is a function of tokens[:p+1] only — and refcounted.
    A later request whose prompt starts with the same tokens maps its page
    table at those logical pages to the same physical pages and skips
    writing them (its prefill scatter routes those positions to scratch).
    The first page *not* fully covered by the prompt is always privately
    owned, so decode scatter-writes never touch shared storage.

    **Sharded pools** (``mesh``): the pool's leading (P) dim carries the
    ``kv_pages`` logical axis and shards P/n over ``kv_axis`` — each chip
    pins P/n pages and owns the global page-id range
    ``[chip*P/n, (chip+1)*P/n)`` (``repro.parallel.pagedkv``); the pool is
    padded up to a multiple of the mesh size.  The free list becomes
    **locality-aware**: it prefers handing one request pages from one chip
    (fewer chips touched per slot), spilling across chips only when no
    single chip can cover the request — and admission (admit vs defer)
    depends only on the *total* free count, never on placement, so locality
    is a performance hint with zero behavioural surface.
    """

    backend = "paged"

    def __init__(self, lm, batch: int, max_seq: int, dtype=jnp.bfloat16,
                 page_size: int = 16, num_pages: Optional[int] = None,
                 prefix_sharing: bool = True, decode_impl: str = "gather",
                 mesh=None, kv_axis: str = "model", dp_axis=None,
                 locality_chips: Optional[int] = None,
                 kv_dtype: str = "native", host_pages: int = 0,
                 prefix_store=None):
        cfg = lm.cfg
        assert cfg.family in ("dense", "vlm", "moe"), (
            "paged KV is attention-cache families only "
            f"(family={cfg.family})")
        assert decode_impl in ("gather", "pallas"), decode_impl
        assert kv_dtype in ("native", "int8"), kv_dtype
        self.decode_impl = decode_impl
        self.kv_dtype = kv_dtype
        self.quantized = kv_dtype == "int8"
        self.cfg, self.B, self.S = cfg, batch, max_seq
        self.page = page_size
        self.max_pages = -(-max_seq // page_size)              # M, per slot
        if num_pages is None:
            # default pool: full dense-equivalent capacity (+ scratch), so
            # swapping backends never changes admission behaviour
            num_pages = batch * self.max_pages + 1
        assert num_pages >= 2, "need at least scratch + one usable page"
        self.mesh, self.kv_axis = mesh, kv_axis
        self.dp_axis = dp_axis
        if mesh is not None:
            from repro.parallel.mesh import mesh_axis_size
            assert locality_chips is None, (
                "locality_chips is the mesh-free testing knob; with a mesh "
                "the chip count is the kv_axis extent")
            # 2-D batch × pages meshes: the pool shards over kv_axis only
            # (replicated across dp_axis); dp shards the dispatch batch dims
            self.chips = mesh_axis_size(mesh, kv_axis)
            if dp_axis is not None:
                assert dp_axis != kv_axis, (
                    "dp_axis and kv_axis must be distinct mesh axes")
                assert mesh_axis_size(mesh, dp_axis) >= 1
        else:
            assert dp_axis is None, (
                "dp_axis shards dispatch batch dims over a mesh; pass mesh=")
            # locality_chips simulates the per-chip free-list partitioning
            # without device sharding (host-side allocator tests)
            self.chips = locality_chips or 1
        # pad the pool so every chip holds the same P/n page count
        num_pages = -(-num_pages // self.chips) * self.chips
        self.P = num_pages
        self.pages_per_chip = num_pages // self.chips
        self.dtype = dtype
        self.prefix_sharing = prefix_sharing
        L, kvh, hd = cfg.num_layers, cfg.num_kv_heads, cfg.resolved_head_dim
        pool_shape = (L, num_pages, page_size, kvh, hd)
        scale_shape = pool_shape[:4]        # one fp32 scale per (pos, head)
        self._pool_sharding = self._scale_sharding = None
        if mesh is not None:
            from repro.parallel.pagedkv import (kv_pool_sharding,
                                                kv_scale_sharding)
            self._pool_sharding = kv_pool_sharding(mesh, pool_shape,
                                                   axis=kv_axis)
            if self.quantized:
                self._scale_sharding = kv_scale_sharding(mesh, scale_shape,
                                                         axis=kv_axis)

        def alloc_z(shape, dt, sharding):
            z = jnp.zeros(shape, dt)
            return jax.device_put(z, sharding) if sharding is not None else z

        def pool():
            return alloc_z(pool_shape, jnp.int8 if self.quantized else dtype,
                           self._pool_sharding)

        self.state = {"layers": {"k": pool(), "v": pool()}}
        if self.quantized:
            # per-page-row-per-KV-head fp32 absmax scales, stored alongside
            # the int8 pools so decode_view hands them to the dispatch as
            # part of the same donated layers subtree
            self.state["layers"]["k_scale"] = alloc_z(
                scale_shape, jnp.float32, self._scale_sharding)
            self.state["layers"]["v_scale"] = alloc_z(
                scale_shape, jnp.float32, self._scale_sharding)
        self.page_table = np.zeros((batch, self.max_pages), np.int32)
        self._page_table_dev = None      # device copy, invalidated on mutation
        # per-chip free stacks, pop() handing out the lowest id of the chip;
        # the scratch page (global id 0, chip 0) is never listed
        self._free_chip: List[List[int]] = [
            [pid for pid in range(
                min((c + 1) * self.pages_per_chip, num_pages) - 1,
                max(c * self.pages_per_chip, 1) - 1, -1)]
            for c in range(self.chips)]
        self._ref = np.zeros(num_pages, np.int32)
        self._hash_to_page: Dict[bytes, int] = {}
        self._page_to_hash: Dict[int, bytes] = {}
        self._slot_pages: List[List[int]] = [[] for _ in range(batch)]
        self._slot_shared: List[int] = [0] * batch   # leading shared pages
        # chunked-prefill bookkeeping: pages a slot has been promised but has
        # not yet claimed (drawn down by ``extend``), and slots whose table
        # rows are hidden from the fused decode dispatch while they prefill
        self._slot_need: List[int] = [0] * batch
        self._shielded: set = set()
        # multi-tenant accounting: a tenant's *full footprint* (every page
        # the slot will ever hold, shared pages included, chunked tails
        # included) is charged against its quota at admission time — so an
        # in-flight chunked slot's ``extend`` can never hit a quota wall,
        # and the banker's no-deadlock guarantee is untouched by quotas
        self._quota: Dict[str, int] = {}
        self._slot_tenant: List[Optional[str]] = [None] * batch
        self._slot_charge: List[int] = [0] * batch
        self._tenant_pages: Dict[str, int] = {}
        #: why the last ``alloc``/``alloc_chunked`` returned ``None``:
        #: "pool" (banker/exhaustion — engine defers, in-order) or "quota"
        #: (tenant cap — engine skips this request and admits others)
        self.last_deny: Optional[str] = None
        #: chips drained by ``fail_chip`` — their page-id ranges are dead:
        #: never listed free again, capacity permanently reduced
        self._failed_chips: set = set()
        # ---- host-RAM page tier (repro.serve.offload) -------------------
        # ``prefix_store`` is an externally-owned PrefixStore (persistent
        # across engines: a warmup engine's evicted prefixes prefetch into
        # a later engine's admissions); ``host_pages`` alone builds a
        # store private to this cache.  The tier rides on prefix sharing —
        # the store key IS the sharing key — so it requires it.
        self.store = None
        if prefix_store is not None or host_pages:
            from repro.serve.offload import PrefixStore
            assert prefix_sharing, (
                "the host page tier stores pages under the prefix-sharing "
                "key; construct the cache with prefix_sharing=True")
            self.store = prefix_store if prefix_store is not None \
                else PrefixStore(host_pages)
            spec = {"k": (pool_shape[2:],
                          jnp.int8 if self.quantized else dtype)}
            spec["v"] = spec["k"]
            if self.quantized:
                spec["k_scale"] = (scale_shape[2:], jnp.float32)
                spec["v_scale"] = spec["k_scale"]
            # per-page payload: pool_shape is (L, P, page, KV, D) — a page
            # slice drops the P dim, keeping the leading L
            spec = {n: ((pool_shape[0],) + shape, dt)
                    for n, (shape, dt) in spec.items()}
            self.store.bind(spec)
        #: device->host copies started by ``free`` but not yet landed in
        #: the store — drained at the next admission/stats/verify point,
        #: never on the decode hot path
        self._pending_offload: List[tuple] = []
        self._pending_keys: set = set()

    # ------------------------------------------------------------ sizing ----
    def pages_needed(self, length: int) -> int:
        return -(-length // self.page)

    def usable_pages(self) -> int:
        """Pages the allocator can ever hand out: the pool minus scratch
        minus every failed chip's range (the scratch page lives on chip 0,
        so a failed chip 0 loses one page fewer than the others)."""
        lost = sum(self.pages_per_chip - (1 if c == 0 else 0)
                   for c in self._failed_chips)
        return self.P - 1 - lost

    def can_ever_fit(self, length: int) -> bool:
        return (length <= self.S
                and self.pages_needed(length) <= self.usable_pages())

    def _chip_of(self, pid: int) -> int:
        from repro.parallel.pagedkv import chip_of_page
        return chip_of_page(pid, self.pages_per_chip)

    # ------------------------------------------------------------- alloc ----
    def _free_count(self) -> int:
        return sum(len(f) for f in self._free_chip)

    def _take_fresh(self, need: int) -> List[int]:
        """Pop ``need`` pages from the per-chip free stacks, locality-first.

        Preference order: the chip that fits the request with the fewest
        free pages to spare (best fit — keeps large same-chip runs intact
        for later requests), else spill across chips from the fullest down.
        The caller has already checked ``need <= _free_count()`` — placement
        never changes whether a request is admitted."""
        fits = [c for c in range(self.chips)
                if len(self._free_chip[c]) >= need]
        order = ([min(fits, key=lambda c: (len(self._free_chip[c]), c))]
                 if fits else
                 sorted(range(self.chips),
                        key=lambda c: (-len(self._free_chip[c]), c)))
        out: List[int] = []
        for c in order:
            while self._free_chip[c] and len(out) < need:
                out.append(self._free_chip[c].pop())
        assert len(out) == need, (len(out), need)
        return out

    def _banker_items(self, skip: Optional[int] = None):
        """(remaining_need, freeable_on_completion) per live slot — the state
        the single-resource banker's check runs over.  ``freeable`` counts
        only exclusively-owned pages (refcount 1): shared pages may outlive
        the slot, so counting them would overestimate what completion frees
        (conservative — may defer a grant that was in fact safe, never the
        reverse)."""
        items = []
        for s in range(self.B):
            if s == skip or (not self._slot_pages[s]
                             and not self._slot_need[s]):
                continue
            freeable = sum(int(self._ref[p] == 1) for p in self._slot_pages[s])
            items.append((self._slot_need[s], freeable))
        return items

    @staticmethod
    def _safe(free: int, items) -> bool:
        """Single-resource banker's safety: the live slots are completable in
        *some* order iff, walking them by ascending remaining need, each
        one's need fits in the free pool grown by its predecessors' frees."""
        for need, freeable in sorted(items):
            if need > free:
                return False
            free += freeable
        return True

    def _grant_safe(self, take: int, remaining: int, skip: Optional[int] = None,
                    extra_freeable: int = 0) -> bool:
        """Would handing out ``take`` fresh pages to a slot that will still
        need ``remaining`` more leave the pool in a banker-safe state?
        ``skip``/``extra_freeable`` describe the grantee: its current entry is
        excluded and re-added post-grant with ``take`` more freeable pages."""
        free = self._free_count()
        if take > free:
            return False
        items = self._banker_items(skip=skip)
        items.append((remaining, take + extra_freeable))
        return self._safe(free - take, items)

    # ------------------------------------------------------------ tenancy ----
    def set_quota(self, tenant: str, pages: Optional[int]) -> None:
        """Cap ``tenant``'s concurrently-charged footprint pages (``None``
        lifts the cap).  Lowering a quota below current usage only blocks
        *new* admissions — live slots run to completion."""
        if pages is None:
            self._quota.pop(tenant, None)
        else:
            assert pages >= 1, pages
            self._quota[tenant] = pages

    def tenant_pages(self, tenant: str) -> int:
        return self._tenant_pages.get(tenant, 0)

    def _quota_ok(self, tenant: Optional[str], n_total: int) -> bool:
        if tenant is None or tenant not in self._quota:
            return True
        return self.tenant_pages(tenant) + n_total <= self._quota[tenant]

    def _charge(self, slot: int, tenant: Optional[str], n_total: int) -> None:
        if tenant is None:
            return
        self._slot_tenant[slot] = tenant
        self._slot_charge[slot] = n_total
        self._tenant_pages[tenant] = self.tenant_pages(tenant) + n_total

    def slot_freeable(self, slot: int) -> int:
        """Pages ``free(slot)``/``evict(slot)`` would return to the pool
        right now (exclusively-owned only — shared prefix pages stay pinned
        by their other references)."""
        return sum(int(self._ref[p] == 1) for p in self._slot_pages[slot])

    def evict(self, slot: int) -> int:
        """Preempt ``slot``: release every page it holds (and its quota
        charge) and return the number of pages that actually re-entered the
        free pool.  The engine re-queues the request for recompute-on-resume
        prefill — if its prompt pages are still registered (another sharer
        or a not-yet-recycled page), the resume re-shares them."""
        freed = self.slot_freeable(slot)
        self.free(slot)
        return freed

    def _match_shared(self, prefix: Optional[np.ndarray], n_pages: int):
        """Leading full prompt pages this request need not recompute.

        Returns ``(shared, full, host_hits)``: ``shared`` are device pages
        already registered (content landed) that the slot maps directly;
        ``host_hits`` continue the run past the device-registered prefix
        with pages resident in the host tier — ``(logical_idx, key,
        payload)`` triples the caller prefetches into fresh device pages
        after its admission check passes.  ``full`` is the shareable
        full-page count.  Host payloads are finite-checked here: a
        poisoned host page is quarantined (counted as a poisoned miss)
        and the match run stops before it, so corrupt bytes can never
        reach ``register_landed``."""
        shared: List[int] = []
        host_hits: List[tuple] = []
        full = 0
        if self.prefix_sharing and prefix is not None:
            # only pages wholly covered by the prompt are shareable: the
            # page containing the first decode write must be private
            full = min(len(prefix) // self.page, n_pages)
            i = 0
            while i < full:
                pid = self._hash_to_page.get(self._key(prefix, i))
                if pid is None:
                    break
                shared.append(pid)
                i += 1
            if self.store is not None and i < full:
                self.drain_offloads()
                while i < full:
                    key = self._key(prefix, i)
                    payload = self.store.lookup(key)
                    if payload is None:
                        break
                    if not self._payload_finite(payload):
                        self.store.quarantine(key)
                        break
                    host_hits.append((i, key, payload))
                    i += 1
        return shared, full, host_hits

    @staticmethod
    def _payload_finite(payload: Dict[str, np.ndarray]) -> bool:
        """Prefetch-side corruption guard: every float array of the page's
        wire payload must be finite (int8 payloads are unrepresentable as
        NaN, so their fp32 scales carry the poison — same as on device)."""
        return all(np.isfinite(np.asarray(a, np.float32)).all()
                   for a in payload.values()
                   if np.issubdtype(a.dtype, np.floating))

    def _prefetch(self, host_hits: List[tuple], pids: List[int]) -> None:
        """Land ``host_hits``'s payloads in the freshly-claimed device
        pages ``pids`` (one batched ``.at[:, pids].set`` per payload
        array) and register the keys — the content IS landed, so later
        admissions in the same batch can device-share it immediately."""
        assert len(host_hits) == len(pids)
        if not host_hits:
            return
        idx = jnp.asarray(pids, jnp.int32)
        layers = dict(self.state["layers"])
        for name in layers:
            block = np.stack([payload[name]
                              for _, _, payload in host_hits], axis=1)
            arr = layers[name].at[:, idx].set(
                jnp.asarray(block, layers[name].dtype))
            if self.mesh is not None:
                sharding = (self._pool_sharding if arr.ndim == 5
                            else self._scale_sharding)
                arr = jax.device_put(arr, sharding)
            layers[name] = arr
        self.state = {**self.state, "layers": layers}
        for (_, key, _), pid in zip(host_hits, pids):
            self._hash_to_page[key] = pid
            self._page_to_hash[pid] = key
        self.store.note_prefetch(len(host_hits))

    def _offload(self, key: bytes, pid: int) -> None:
        """Start an async device->host copy of page ``pid`` under ``key``
        (called by ``free`` as the last reference drops).  The page slice
        is taken immediately — the pool buffer may be donated into the
        next fused dispatch — but materialization to host numpy waits for
        ``drain_offloads``, keeping the copy off the free/decode hot
        path.  Pages the store already holds are only LRU-refreshed."""
        if self.store.has(key) or key in self._pending_keys:
            self.store.touch(key)
            return
        slices = {}
        for name in self.state["layers"]:
            a = self.state["layers"][name][:, pid]
            try:
                a.copy_to_host_async()
            except (AttributeError, RuntimeError):
                pass               # backend without async D2H: drain copies
            slices[name] = a
        self._pending_offload.append((key, slices))
        self._pending_keys.add(key)

    def drain_offloads(self) -> None:
        """Materialize every pending device->host copy into the store.
        Called before store lookups (so a just-freed prefix is hittable),
        from ``memory_stats``/``verify`` (accounting covers in-flight
        pages), and harmlessly when nothing is pending."""
        if not self._pending_offload:
            return
        for key, slices in self._pending_offload:
            self.store.put(key, {n: np.asarray(a)
                                 for n, a in slices.items()})
        self._pending_offload.clear()
        self._pending_keys.clear()

    def alloc(self, slot: int, length: int,
              prefix: Optional[np.ndarray] = None,
              tenant: Optional[str] = None) -> Optional[int]:
        """Reserve pages covering ``length`` positions for ``slot``.

        ``prefix``: the slot's prompt tokens starting at position 0 — the
        key for prefix sharing (pass ``None`` to disable for this request,
        e.g. VLM prompts whose leading positions are image embeddings).
        ``tenant``: charge the footprint against this tenant's page quota
        (``set_quota``); a quota deny sets ``last_deny = "quota"`` without
        touching refcounts, distinguishable from a "pool" deny.
        Returns the number of leading positions backed by shared pages, or
        ``None`` when the free pool cannot cover the unshared remainder (or
        covering it would strand an in-flight chunked prefill — the banker's
        check below degrades to the plain ``need <= free`` test whenever no
        chunked slot is live).
        """
        assert not self._slot_pages[slot], f"slot {slot} already allocated"
        assert 0 < length <= self.S, (length, self.S)
        n_pages = self.pages_needed(length)
        self.last_deny = None
        if not self._quota_ok(tenant, n_pages):
            self.last_deny = "quota"
            return None                      # tenant cap, not pool pressure
        shared, full, host_hits = self._match_shared(prefix, n_pages)
        # bump shared refs before the safety check: a page going ref 1 -> 2
        # stops being freeable by its first owner's completion, and the
        # banker must see that (rolled back on deferral)
        for pid in shared:
            self._ref[pid] += 1
        # prefetch-then-admit: host-tier hits still consume fresh DEVICE
        # pages, so the banker sees the same demand as a cold request —
        # only the recompute (prefill forward) is saved, never safety
        if not self._grant_safe(n_pages - len(shared), 0):
            for pid in shared:
                self._ref[pid] -= 1
            self.last_deny = "pool"
            return None                      # admission control, not OOM
        fresh = self._take_fresh(n_pages - len(shared))
        for pid in fresh:
            self._ref[pid] = 1
        pages = shared + fresh
        # admission granted: land the host tier's pages in the first
        # host_hits fresh pages (their logical indices continue the
        # device-shared run) and register them as landed content
        self._prefetch(host_hits, fresh[:len(host_hits)])
        covered = len(shared) + len(host_hits)
        # register this request's *new* full prompt pages so later identical
        # prefixes can share them (content lands in the same _admit step)
        if self.prefix_sharing and prefix is not None:
            for i in range(covered, full):
                key = self._key(prefix, i)
                if key not in self._hash_to_page:
                    self._hash_to_page[key] = pages[i]
                    self._page_to_hash[pages[i]] = key
        self.page_table[slot, :] = 0
        self.page_table[slot, :n_pages] = pages
        self._page_table_dev = None
        self._slot_pages[slot] = pages
        self._slot_shared[slot] = covered
        self._charge(slot, tenant, n_pages)
        return covered * self.page

    # ------------------------------------------------- chunked allocation ----
    def alloc_chunked(self, slot: int, length: int, first: int,
                      prefix: Optional[np.ndarray] = None,
                      tenant: Optional[str] = None) -> Optional[int]:
        """Admit ``slot`` for chunked prefill: claim only the pages covering
        the first ``first`` positions now; the rest of the ``length``-position
        footprint (later prompt chunks + the decode tail) is recorded as this
        slot's *remaining need* and claimed chunk-by-chunk via ``extend``.

        Admission requires the post-grant pool to be banker-safe, which is a
        strictly weaker demand than the whole-footprint ``alloc`` check: a
        long prompt can be admitted into a pool whose free pages cover only
        its first chunk, as long as the live slots' completions will free
        what its later chunks need.  Prefix sharing matches only pages whose
        content has already *landed* (``register_landed`` keys pages after
        their chunk's scatter, not at alloc time), so a sharer can never
        attend a page another request has not written yet.

        Returns the shared leading positions, or ``None`` to defer."""
        assert not self._slot_pages[slot], f"slot {slot} already allocated"
        assert 0 < first <= length <= self.S, (first, length, self.S)
        n_total = self.pages_needed(length)
        self.last_deny = None
        # quota charges the FULL footprint here, at admission — later
        # ``extend`` calls draw down an already-charged reservation, so a
        # mid-prefill slot can banker-stall but never quota-stall
        if not self._quota_ok(tenant, n_total):
            self.last_deny = "quota"
            return None
        shared, _, host_hits = self._match_shared(prefix, n_total)
        # prefetch-then-admit: host-tier hits are claimed (and landed) UP
        # FRONT alongside the first chunk's pages — the chunks they cover
        # will skip their forward entirely, so deferring the claim would
        # only re-expose the recompute the tier exists to remove.  The
        # banker check still guards the whole grant: an unsafe prefetch
        # defers the admission exactly like an unsafe cold claim.
        n_first = max(self.pages_needed(first) - len(shared), 0)
        n_first = max(n_first, len(host_hits))
        remaining = n_total - len(shared) - n_first
        for pid in shared:          # pre-check bump, as in ``alloc``
            self._ref[pid] += 1
        if not self._grant_safe(n_first, remaining):
            for pid in shared:
                self._ref[pid] -= 1
            self.last_deny = "pool"
            return None
        fresh = self._take_fresh(n_first)
        for pid in fresh:
            self._ref[pid] = 1
        pages = shared + fresh
        self._prefetch(host_hits, fresh[:len(host_hits)])
        covered = len(shared) + len(host_hits)
        self.page_table[slot, :] = 0
        self.page_table[slot, :len(pages)] = pages
        self._page_table_dev = None
        self._slot_pages[slot] = pages
        self._slot_shared[slot] = covered
        self._slot_need[slot] = remaining
        self._charge(slot, tenant, n_total)
        return covered * self.page

    def extend(self, slot: int, cover: int) -> bool:
        """Grow ``slot``'s claimed pages to cover ``cover`` positions (the
        next chunk's end — or the full footprint on the final chunk, which
        claims the decode tail).  Returns ``False`` when the grant is not
        banker-safe right now: the chunk defers and resumes once completions
        free pages — the safety invariant guarantees some live slot can
        always run to completion, so a stalled prefill never deadlocks."""
        have = len(self._slot_pages[slot])
        assert have > 0, f"slot {slot} has no chunked allocation"
        need = self.pages_needed(cover) - have
        if need <= 0:
            return True
        assert need <= self._slot_need[slot], (need, self._slot_need[slot])
        freeable = sum(int(self._ref[p] == 1) for p in self._slot_pages[slot])
        if not self._grant_safe(need, self._slot_need[slot] - need,
                                skip=slot, extra_freeable=freeable):
            return False
        fresh = self._take_fresh(need)
        for pid in fresh:
            self._ref[pid] = 1
        self.page_table[slot, have:have + need] = fresh
        self._page_table_dev = None
        self._slot_pages[slot].extend(fresh)
        self._slot_need[slot] -= need
        return True

    def register_landed(self, slot: int, prefix: np.ndarray,
                        upto: int) -> None:
        """Key ``slot``'s full prompt pages whose content has landed
        (positions ``[0, upto)`` scattered) into the prefix-sharing registry.
        Chunked prefill registers here — after the chunk's scatter — instead
        of at alloc time, so no other request can ever map a page whose
        content is still pending.  Idempotent per page."""
        if not self.prefix_sharing or prefix is None:
            return
        full = min(upto, len(prefix)) // self.page
        pages = self._slot_pages[slot]
        for i in range(self._slot_shared[slot], min(full, len(pages))):
            key = self._key(prefix, i)
            pid = pages[i]
            if key not in self._hash_to_page and pid not in self._page_to_hash:
                self._hash_to_page[key] = pid
                self._page_to_hash[pid] = key

    def _key(self, prefix: np.ndarray, page_idx: int) -> bytes:
        # K/V in page i depend on tokens[: (i+1)*page] (causality), nothing
        # else — so the prefix bytes are the complete sharing key
        return np.ascontiguousarray(
            prefix[: (page_idx + 1) * self.page], np.int32).tobytes()

    # ----------------------------------------------------------- prefill ----
    def prefill_dest(self, slot: int, block_len: int, valid_len: int,
                     shared_len: int = 0) -> np.ndarray:
        """Flat pool indices for a prefill block's positions [0, block_len).

        Positions already backed by shared pages, and padding positions
        beyond ``valid_len``, route to flat index 0 (scratch page row 0) —
        the block is computed for the padded bucket but only privately-owned
        real positions land in the pool.  (The position-0 special case of
        ``chunk_dest`` — one implementation of the resolve+mask pipeline.)
        """
        return self.chunk_dest(slot, 0, valid_len, block_len, shared_len)

    def chunk_dest(self, slot: int, start: int, end: int, chunk_len: int,
                   shared_len: int = 0) -> np.ndarray:
        """Flat pool indices for one prefill chunk: global positions
        ``[start, start + chunk_len)`` of ``slot``, of which only
        ``[max(start, shared_len), end)`` actually land (padding past the
        chunk's valid tokens and positions backed by shared pages route to
        flat index 0, the scratch sink).  The caller must have ``extend``-ed
        the slot to cover ``end`` positions first."""
        pos = start + np.arange(chunk_len)
        logical = np.minimum(pos // self.page, self.max_pages - 1)
        idx = self.page_table[slot, logical] * self.page + pos % self.page
        write = (pos >= shared_len) & (pos < end)
        return np.where(write, idx, 0).astype(np.int32)

    def table_row(self, slot: int) -> np.ndarray:
        """The slot's REAL (M,) page-table row — what a chunked-prefill
        dispatch gathers through (``decode_view`` may be shielding it)."""
        return self.page_table[slot].copy()

    def staged_write_prefill(self, layers, kv_block, dest):
        """Jit-stageable multi-request prefill scatter over the per-layer
        K/V pools (``state["layers"]``).

        kv_block: per-layer (L, n, Sblk, ...) K/V; dest: (n, Sblk) flat pool
        indices (page * page_size + row, scratch-routed where masked).  On a
        sharded pool the write routes through the unified shard_map
        primitive (``repro.parallel.pagedkv.sharded_write_prefill``): each
        chip commits only its own rows with a ``mode="drop"`` local
        scatter, so the dispatch's per-chip transient is the O(group·block)
        staged K/V — never an O(P) replicated pool (the pre-unification
        GSPMD path is kept measurable as ``gspmd_write_prefill``).

        Quantized pools (``kv_dtype="int8"``): the float K/V block is
        quantized here — inside the staged (jit-traced) write, so prefill
        stays one dispatch — and the per-row scales scatter into the scale
        arrays through the *same* flat indices (a scale array is just a
        pool with no D axis)."""
        kv_block = self._quantize_block(kv_block)
        if self.mesh is not None:
            from repro.parallel.pagedkv import sharded_write_prefill
            return sharded_write_prefill(self.mesh, self.kv_axis, layers,
                                         kv_block, dest)

        def write(pool, small):
            p, pg = pool.shape[1], pool.shape[2]
            flat = pool.reshape(pool.shape[0], p * pg, *pool.shape[3:])
            flat = flat.at[:, dest].set(small.astype(pool.dtype))
            return flat.reshape(pool.shape)

        return jax.tree.map(write, layers, kv_block)

    def gspmd_write_prefill(self, layers, kv_block, dest):
        """The pre-unification sharded prefill write: a flat global
        ``.at[:, dest].set`` left to GSPMD to partition, constrained back
        to the pool sharding.  Kept ONLY as the measured baseline for the
        replicated-pool-transient comparison (bench/tests compile both
        writes and diff ``temp_size_in_bytes``); the engine always routes
        through the shard_map primitive above."""
        assert self.mesh is not None, "the GSPMD baseline is mesh-only"
        kv_block = self._quantize_block(kv_block)

        def write(pool, small):
            p, pg = pool.shape[1], pool.shape[2]
            flat = pool.reshape(pool.shape[0], p * pg, *pool.shape[3:])
            flat = flat.at[:, dest].set(small.astype(pool.dtype))
            out = flat.reshape(pool.shape)
            sharding = (self._pool_sharding if pool.ndim == 5
                        else self._scale_sharding)
            return jax.lax.with_sharding_constraint(out, sharding)

        return jax.tree.map(write, layers, kv_block)

    def _quantize_block(self, kv_block):
        """int8 pools: quantize a staged float K/V block (inside the jit
        trace) into the {k, v, k_scale, v_scale} tree the pool expects."""
        if not self.quantized:
            return kv_block
        from repro.kernels.quant import quantize_kv
        block = {}
        for name in ("k", "v"):
            q, s = quantize_kv(kv_block[name])
            block[name], block[name + "_scale"] = q, s
        return block

    def write_prefill(self, slot: int, kv_block) -> None:
        block_len = jax.tree.leaves(kv_block)[0].shape[2]
        dest = self.prefill_dest(slot, block_len, block_len,
                                 self._slot_shared[slot] * self.page)
        self.state = {"layers": self.staged_write_prefill(
            self.state["layers"], kv_block, jnp.asarray(dest[None],
                                                        jnp.int32))}

    # ------------------------------------------------------------ decode ----
    def set_decode_shield(self, slot: int, shielded: bool) -> None:
        """Hide/expose ``slot``'s table row in ``decode_view``.

        A mid-prefill slot owns live pages but must not take decode traffic:
        the fused dispatch scatter-writes *every* slot (masked ones at
        position 0), and position 0 of a prefilling slot maps to its real
        first page — the write would corrupt prefilled content.  Shielded
        rows read as all-scratch in the decode view, so both the masked
        write and the (already inactive-masked) read land in garbage space.
        Chunk dispatches bypass the shield via ``table_row``."""
        if shielded:
            self._shielded.add(slot)
        else:
            self._shielded.discard(slot)
        self._page_table_dev = None

    def decode_view(self):
        """Device pytree for ``lm.decode_step``: pools + the page table.

        The table is a plain (B, M) int32 input to the fused dispatch — its
        shape never changes, so admits/frees never retrace the decode; and
        its device copy is cached between mutations, so steady-state decode
        (no admits, no completions) pays no host->device transfer for it.
        Rows of shielded (mid-chunked-prefill) slots are zeroed to the
        scratch page (see ``set_decode_shield``)."""
        if self._page_table_dev is None:
            tbl = self.page_table
            if self._shielded:
                tbl = tbl.copy()
                tbl[sorted(self._shielded)] = 0
            self._page_table_dev = jnp.asarray(tbl)
        return {**self.state, "page_table": self._page_table_dev}

    def update(self, new_state) -> None:
        self.state = {"layers": new_state["layers"]}

    # -------------------------------------------------------------- free ----
    def free(self, slot: int) -> None:
        for pid in self._slot_pages[slot]:
            self._ref[pid] -= 1
            if self._ref[pid] == 0:
                key = self._page_to_hash.pop(pid, None)
                if key is not None:
                    del self._hash_to_page[key]
                    # the page is about to be recycled but its content is
                    # a registered (landed, uncorrupted-as-far-as-we-know)
                    # shared prefix: spill it to the host tier so a later
                    # hash-hitting admission prefetches instead of
                    # recomputing prefill.  Poison-recovered pages never
                    # get here — recovery unregisters them first.
                    if self.store is not None:
                        self._offload(key, pid)
                chip = self._chip_of(pid)
                # a failed chip's pages are gone, not recyclable: the last
                # reference dropping is when the page leaves the pool
                if chip not in self._failed_chips:
                    self._free_chip[chip].append(pid)
        self._slot_pages[slot] = []
        self._slot_shared[slot] = 0
        self._slot_need[slot] = 0
        self._shielded.discard(slot)
        tenant = self._slot_tenant[slot]
        if tenant is not None:
            left = self._tenant_pages[tenant] - self._slot_charge[slot]
            if left:
                self._tenant_pages[tenant] = left
            else:
                del self._tenant_pages[tenant]
            self._slot_tenant[slot] = None
            self._slot_charge[slot] = 0
        self.page_table[slot, :] = 0    # point the freed slot at scratch
        self._page_table_dev = None

    # ---------------------------------------------------- fault tolerance ----
    def poison_page(self, pid: int) -> None:
        """Overwrite physical page ``pid``'s content with non-finite values
        (simulated in-HBM corruption — the ``poison_page`` fault seam).  On
        a quantized pool the int8 payload has no NaN encoding, so the fp32
        scales are poisoned instead; dequantization drags the NaN into the
        attended values either way.  The page keeps its table/refcount
        bookkeeping untouched: detecting the corruption is the *reader's*
        job (the fused dispatch's non-finite logit guard), exactly as with
        real bit rot."""
        assert 0 < pid < self.P, (pid, self.P)
        layers = dict(self.state["layers"])
        names = ("k_scale", "v_scale") if self.quantized else ("k", "v")
        for name in names:
            layers[name] = layers[name].at[:, pid].set(jnp.nan)
        self.state = {**self.state, "layers": layers}

    def unregister_pages(self, pages: List[int]) -> None:
        """Drop the prefix-sharing keys of ``pages`` (content lost or
        suspect).  Existing sharers keep their references — they are
        detected and recovered through the same guard — but no *new*
        request can map the pages again, so a poisoned prompt page cannot
        re-share into a recompute-on-resume prefill and re-poison the
        stream forever."""
        for pid in pages:
            key = self._page_to_hash.pop(pid, None)
            if key is not None:
                del self._hash_to_page[key]

    def fail_chip(self, chip: int) -> List[int]:
        """Drain chip ``chip`` from the pool (a lost accelerator): its free
        pages leave the free lists for good — capacity degrades from P to
        P·(n-1)/n — and every slot holding a page in the chip's id range is
        returned as a victim for the engine to recover (evict + recompute-
        on-resume; streams with no pages there are untouched).  The chip's
        prefix-hash keys are dropped so no later admission can share
        content that no longer exists.  Idempotent per chip.

        Note the pool can be left banker-*unsafe* for in-flight chunked
        prefills whose remaining need exceeded the surviving capacity:
        such slots stall until the engine's watchdog recovers (or
        dead-letters) them — the one case where a stall is no longer
        guaranteed to resolve by completions alone."""
        from repro.parallel.pagedkv import chip_page_range
        assert 0 <= chip < self.chips, (chip, self.chips)
        if chip in self._failed_chips:
            return []
        self._failed_chips.add(chip)
        self._free_chip[chip] = []
        span = chip_page_range(chip, self.pages_per_chip)
        self.unregister_pages([p for p in span if p in self._page_to_hash])
        return [s for s in range(self.B)
                if any(span.start <= p < span.stop
                       for p in self._slot_pages[s])]

    def verify(self) -> None:
        """Invariant sanitizer over the allocator's host-side bookkeeping
        (the debug-mode health check behind ``ServeEngine(verify_cache=)``
        and the property-test fuzzers).  O(P + B·M) numpy, no device sync.
        Raises :class:`CacheInvariantError` naming the first violated
        invariant: refcounts == live references, scratch page never handed
        out, free/owned pages partition the (surviving) pool, per-chip
        free-list membership, page-table rows mirroring ``_slot_pages``,
        prefix-registry bijection, per-tenant quota accounting, and the
        ``memory_stats`` byte math."""
        from repro.parallel.pagedkv import chip_page_range

        def check(cond, what):
            if not cond:
                raise CacheInvariantError(f"PagedCache.verify: {what}")

        owned = [pid for pages in self._slot_pages for pid in pages]
        free = [pid for chip in self._free_chip for pid in chip]
        check(0 not in owned and 0 not in free and self._ref[0] == 0,
              "scratch page 0 handed out, listed free, or refcounted")
        counts = (np.bincount(owned, minlength=self.P) if owned
                  else np.zeros(self.P, np.int64))
        check((self._ref == counts).all(),
              f"refcounts drifted from live references "
              f"(ref={self._ref.tolist()} vs owned={sorted(owned)})")
        check(len(free) == len(set(free)), "duplicate page in free lists")
        check(set(free).isdisjoint(owned),
              f"pages both free and owned: {set(free) & set(owned)}")
        lost = {p for c in self._failed_chips
                for p in chip_page_range(c, self.pages_per_chip)}
        check(not lost & set(free), "failed-chip page still listed free")
        check(not lost & set(owned), "failed-chip page still owned")
        check(set(free) | set(owned) <= set(range(1, self.P)) - lost,
              "page id outside the surviving pool")
        for c, chip in enumerate(self._free_chip):
            check(all(self._chip_of(pid) == c for pid in chip),
                  f"page filed under wrong chip's free list ({c})")
        for s in range(self.B):
            pages = self._slot_pages[s]
            row = self.page_table[s]
            check(list(row[:len(pages)]) == pages,
                  f"slot {s} page-table row != owned pages")
            check((row[len(pages):] == 0).all(),
                  f"slot {s} page-table tail not parked on scratch")
            check(0 <= self._slot_shared[s] <= len(pages),
                  f"slot {s} shared-page count out of range")
            check(self._slot_need[s] >= 0,
                  f"slot {s} negative chunked-prefill need")
            check((self._slot_tenant[s] is None) ==
                  (self._slot_charge[s] == 0),
                  f"slot {s} tenant/charge mismatch")
        check(len(self._hash_to_page) == len(self._page_to_hash),
              "prefix registry maps differ in size")
        for key, pid in self._hash_to_page.items():
            check(self._page_to_hash.get(pid) == key,
                  f"prefix registry maps disagree on page {pid}")
            check(self._ref[pid] > 0,
                  f"registered prefix page {pid} has no owner")
        charges: Dict[str, int] = {}
        for s in range(self.B):
            t = self._slot_tenant[s]
            if t is not None:
                charges[t] = charges.get(t, 0) + self._slot_charge[s]
        check(charges == self._tenant_pages,
              f"tenant accounting drifted: {charges} "
              f"vs {self._tenant_pages}")
        st = self.memory_stats()
        pb = page_kv_bytes(self.cfg, self.page, self.dtype, self.kv_dtype)
        check(st.pages_total == self.usable_pages(),
              "memory_stats pages_total != usable pool")
        check(st.pages_in_use == st.pages_total - len(free),
              "memory_stats pages_in_use != usable - free")
        check(st.bytes_reserved == st.pages_in_use * pb
              and st.bytes_total == self.P * pb,
              "memory_stats byte math inconsistent")
        if not self._failed_chips:
            # grants maintain banker safety — but a chip failure may
            # legitimately strand an in-flight chunked need (the watchdog's
            # recovery case), so the check only applies to intact pools
            check(self._safe(len(free), self._banker_items()),
                  "pool not banker-safe (a live slot can never complete)")
        if self.store is not None:
            # host-resident pages: drain in-flight offloads so the store's
            # own sanitizer sees the settled state, then cross-check the
            # stats plumbing (store bytes must be wire-format page bytes)
            self.drain_offloads()
            self.store.verify()
            check(st.host_pages_total == self.store.capacity
                  and st.host_pages_in_use == self.store.pages_in_use()
                  and st.host_bytes == self.store.bytes_in_use(),
                  "memory_stats host-tier accounting drifted from store")
            check(self.store.tier.page_bytes == pb,
                  "host tier page bytes != device wire page bytes")

    # ------------------------------------------------------------- stats ----
    def memory_stats(self) -> MemoryStats:
        # self.chips covers BOTH partition modes — a device mesh and the
        # mesh-free locality_chips harness — so the --mesh and fault-drain
        # memory lines report the real per-chip split either way (the old
        # `chips if mesh else 1` reported a locality-partitioned pool as
        # one unsharded chip).  page_kv_bytes includes the int8 scale
        # bytes, so bytes_per_chip counts each chip's sharded scale arrays
        # too; bytes_scales_per_chip breaks that portion out.
        self.drain_offloads()    # settle in-flight D2H so host stats are real
        pb = page_kv_bytes(self.cfg, self.page, self.dtype, self.kv_dtype)
        usable = self.usable_pages()
        in_use = usable - self._free_count()
        sharded = self.chips
        scale_b = (self.P * self.page * 2 * self.cfg.num_layers
                   * self.cfg.num_kv_heads * SCALE_BYTES
                   if self.quantized else 0)
        return MemoryStats(
            backend=self.backend, bytes_total=self.P * pb,
            bytes_reserved=in_use * pb, slots_total=self.B,
            slots_in_use=sum(bool(p) for p in self._slot_pages),
            page_size=self.page, pages_total=usable, pages_in_use=in_use,
            pages_shared=int((self._ref > 1).sum()),
            mesh_chips=sharded, bytes_per_chip=self.P * pb // sharded,
            kv_dtype=self.kv_dtype, bytes_scales=scale_b,
            bytes_scales_per_chip=scale_b // sharded,
            chips_failed=len(self._failed_chips),
            tenant_pages=dict(self._tenant_pages),
            host_pages_total=self.store.capacity if self.store else 0,
            host_pages_in_use=(self.store.pages_in_use()
                               if self.store else 0),
            host_bytes=self.store.bytes_in_use() if self.store else 0)


# ------------------------------------------------------------- factory ----

def make_cache(lm, batch: int, max_seq: int, dtype=jnp.bfloat16,
               backend: str = "contiguous", page_size: int = 16,
               num_pages: Optional[int] = None, prefix_sharing: bool = True,
               decode_impl: str = "gather", mesh=None,
               kv_axis: str = "model", dp_axis=None,
               kv_dtype: str = "native",
               locality_chips: Optional[int] = None,
               host_pages: int = 0, prefix_store=None):
    """Build a KV-cache backend for ``lm`` (the ``lm.init_cache(backend=...)``
    entry point).  ``decode_impl`` ("gather" / "pallas") rides on the paged
    backend and tells decode consumers how to resolve the page table; the
    contiguous backend has no table and always reports "gather".  ``mesh``
    shards the paged pool P/n over ``kv_axis`` (``kv_pages`` logical axis)
    with a locality-aware free list.  ``kv_dtype="int8"`` (paged only)
    stores pages quantized with per-row fp32 scales — quantize-on-write,
    dequantize-on-read in both decode impls.  ``locality_chips`` (paged,
    mesh-free) partitions the free list as an N-chip pool without device
    sharding — the host-side harness for per-chip locality and
    chip-failure drain tests.  ``host_pages`` (paged, needs prefix
    sharing) adds an N-page host-RAM tier: cold shared prefixes spill to
    pinned host buffers on their last free and prefetch back on a later
    hash-hit instead of recomputing prefill; ``prefix_store`` passes an
    externally-owned ``repro.serve.offload.PrefixStore`` so the warm
    prefix corpus persists across engine instances."""
    if backend == "contiguous":
        if locality_chips is not None:
            raise ValueError(
                "locality_chips partitions the paged backend's free list; "
                "the contiguous layout has no pages (use backend='paged')")
        if decode_impl != "gather":
            raise ValueError(
                "decode_impl applies to the paged backend's page-table "
                f"resolution; the contiguous layout has no table to walk "
                f"(got decode_impl={decode_impl!r})")
        if mesh is not None:
            raise ValueError(
                "kv_pages sharding partitions the paged pool's page dim; "
                "the contiguous layout has no page dim (use backend='paged' "
                "to serve over a mesh)")
        if kv_dtype != "native":
            raise ValueError(
                "the int8 page format quantizes fixed-size pages with "
                "per-row scales; the contiguous layout has no pages (use "
                f"backend='paged' for kv_dtype={kv_dtype!r})")
        if host_pages or prefix_store is not None:
            raise ValueError(
                "the host page tier offloads and prefetches fixed-size "
                "pages under the prefix-sharing key; the contiguous "
                "layout has neither (use backend='paged' for host_pages/"
                "prefix_store)")
        return ContiguousCache(lm, batch, max_seq, dtype=dtype)
    if backend == "paged":
        if lm.is_encdec:
            raise NotImplementedError(
                "paged KV covers decoder self-attention caches; encdec "
                "cross-attention K/V is per-request dense state")
        return PagedCache(lm, batch, max_seq, dtype=dtype,
                          page_size=page_size, num_pages=num_pages,
                          prefix_sharing=prefix_sharing,
                          decode_impl=decode_impl, mesh=mesh,
                          kv_axis=kv_axis, dp_axis=dp_axis,
                          kv_dtype=kv_dtype,
                          locality_chips=locality_chips,
                          host_pages=host_pages, prefix_store=prefix_store)
    raise ValueError(f"unknown KV-cache backend {backend!r}")
