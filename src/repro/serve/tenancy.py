"""Multi-tenant SLO scheduling for the serve engine.

The cluster layer (``repro.core.tenancy``) shares *nodes* between
namespaces; this module shares the two resources the serving engine
actually runs out of — **decode slots** and **KV pages** — between
tenants, in SLO terms:

- every :class:`~repro.serve.engine.Request` carries a ``tenant`` id;
- each tenant belongs to a :class:`~repro.core.tenancy.PriorityClass`
  (``interactive`` / ``batch`` built in, arbitrary classes accepted) and
  may carry a hard **page quota** enforced inside ``PagedCache``'s
  banker-style safety check (a quota deny is *not* a pool-exhaustion
  deny: the engine skips the request and keeps admitting others);
- admission is priority-ordered (stable FIFO within a class), and under
  slot/page pressure the engine **preempts** the lowest-priority running
  decode: its pages are evicted and the request re-queued for
  recompute-on-resume prefill (prefix sharing makes the re-prefill cheap
  when its prompt pages are still registered);
- chunked prefill schedules TTFT-sensitive classes first and can cap a
  class's prefill tokens per iteration (``PriorityClass.prefill_budget``).

Victim selection (:func:`next_victim`) is a pure function so the
preemption policy is directly property-testable.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence

from repro.core.tenancy import (BATCH, DEFAULT_CLASSES, INTERACTIVE,
                                PriorityClass)

__all__ = ["PriorityClass", "INTERACTIVE", "BATCH", "DEFAULT_CLASSES",
           "TenantSpec", "TenancyConfig", "Victim", "next_victim"]


@dataclass(frozen=True)
class TenantSpec:
    """One tenant: a name, its priority class, and an optional hard cap on
    concurrently-held KV pages (``None`` = bounded only by the pool)."""
    name: str
    cls: str = BATCH.name
    page_quota: Optional[int] = None


class TenancyConfig:
    """Validated tenant/class table handed to ``ServeEngine(tenancy=...)``.

    ``preemption=False`` keeps quotas and priority ordering but never
    evicts a running decode (admission then waits like the untenanted
    engine does under pool pressure).
    """

    def __init__(self, tenants: Iterable[TenantSpec],
                 classes: Optional[Dict[str, PriorityClass]] = None,
                 preemption: bool = True):
        self.classes: Dict[str, PriorityClass] = dict(DEFAULT_CLASSES)
        if classes:
            for name, cls in classes.items():
                if name != cls.name:
                    raise ValueError(f"class key {name!r} != name {cls.name!r}")
                self.classes[name] = cls
        self.tenants: Dict[str, TenantSpec] = {}
        for spec in tenants:
            if spec.name in self.tenants:
                raise ValueError(f"duplicate tenant {spec.name!r}")
            if spec.cls not in self.classes:
                raise ValueError(f"tenant {spec.name!r}: unknown class "
                                 f"{spec.cls!r} (have {sorted(self.classes)})")
            if spec.page_quota is not None and spec.page_quota < 1:
                raise ValueError(f"tenant {spec.name!r}: page_quota must be "
                                 f">= 1, got {spec.page_quota}")
            self.tenants[spec.name] = spec
        if not self.tenants:
            raise ValueError("TenancyConfig needs at least one tenant")
        self.preemption = bool(preemption)

    def spec(self, tenant: str) -> TenantSpec:
        try:
            return self.tenants[tenant]
        except KeyError:
            raise ValueError(f"unknown tenant {tenant!r} "
                             f"(have {sorted(self.tenants)})") from None

    def class_of(self, tenant: str) -> PriorityClass:
        return self.classes[self.spec(tenant).cls]

    def priority_of(self, tenant: str) -> int:
        return self.class_of(tenant).priority

    def has_quotas(self) -> bool:
        return any(t.page_quota is not None for t in self.tenants.values())

    @classmethod
    def parse(cls, tenants: str, quotas: str = "",
              preemption: bool = True) -> "TenancyConfig":
        """Build a config from CLI strings.

        ``tenants`` is ``name=class,name=class,...`` (class defaults to
        ``batch`` when omitted); ``quotas`` is ``name=pages,...``.
        """
        specs: Dict[str, TenantSpec] = {}
        for part in filter(None, (p.strip() for p in tenants.split(","))):
            name, _, klass = part.partition("=")
            specs[name] = TenantSpec(name, klass or BATCH.name)
        quota_of: Dict[str, int] = {}
        for part in filter(None, (p.strip() for p in quotas.split(","))):
            name, _, pages = part.partition("=")
            if name not in specs:
                raise ValueError(f"--quota names unknown tenant {name!r}")
            quota_of[name] = int(pages)
        return cls((TenantSpec(s.name, s.cls, quota_of.get(s.name))
                    for s in specs.values()), preemption=preemption)


@dataclass(frozen=True)
class Victim:
    """A running decode slot considered for preemption: its engine slot,
    its tenant's priority, whether its class allows preemption, and how
    many pages eviction would actually return to the pool (exclusively
    owned — shared prefix pages stay pinned by their other references)."""
    slot: int
    priority: int
    preemptible: bool
    freeable: int


def next_victim(candidates: Sequence[Victim],
                preemptor_priority: int) -> Optional[Victim]:
    """Pick the slot to preempt so ``preemptor_priority`` can admit.

    Only strictly-lower-priority, preemptible slots are eligible (equal
    priority never preempts — that would livelock two batch tenants).
    Among eligible victims: lowest priority first, then most freeable
    pages (fewest evictions to satisfy the preemptor), then lowest slot
    for determinism. Returns ``None`` when nothing is eligible.
    """
    eligible: List[Victim] = [v for v in candidates
                              if v.preemptible
                              and v.priority < preemptor_priority]
    if not eligible:
        return None
    return min(eligible, key=lambda v: (v.priority, -v.freeable, v.slot))
