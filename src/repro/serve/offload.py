"""Host-RAM page tier + persistent prefix store behind ``PagedCache``.

The paper's clusters tier storage so the expensive layer holds only the
working set; the serving mirror is this module.  HBM holds the *hot* KV
pages (live requests); warm shared prefixes — system prompts, few-shot
templates, multi-turn histories — spill to preallocated ("pinned") host
buffers when their last on-device reference drops, and are copied back
instead of recomputed when a later admission hash-hits the same prefix.
The host tier turns fixed HBM into the cache of a much larger prefix
corpus: the bench sustains a working set ~10x the device pool.

Two classes, strictly layered:

* :class:`HostPageTier` — a dumb slab allocator over preallocated host
  numpy arrays, one slab per pool payload array ("k", "v", and the int8
  scale arrays when quantized).  Pages are stored in **wire format**:
  an int8 pool's host pages stay int8 + fp32 scales, so a page costs the
  same bytes in host RAM as in HBM and a prefetch is a byte-exact copy.
* :class:`PrefixStore` — the persistent map from the allocator's prefix
  key (the token bytes a page causally depends on) to a tier slot.  Keys
  are indexed by a short digest but every entry stores the **full key
  bytes**, verified on lookup: a digest collision is a recorded miss,
  never silent cross-request KV reuse.  LRU eviction; the store outlives
  any single cache/engine (pass one store to successive engines and the
  second engine's admissions prefetch what the first one computed).

The device side of the tier lives in ``PagedCache``: ``free()`` enqueues
an async device->host copy when a *registered* page's refcount drops to
zero (off the decode hot path — materialization happens at the next
admission/stats point), and ``alloc``/``alloc_chunked`` probe the store
for pages past the device-registered run, claiming fresh device pages
and landing the host bytes before returning (prefetch-then-admit).
Prefetched content is finite-checked here first: a poisoned host page
(NaN payload or scales) is quarantined and reported as a miss, so
corruption in the warm tier surfaces as recompute, never as a poisoned
stream.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np


class HostTierError(AssertionError):
    """Raised by ``PrefixStore.verify`` / ``HostPageTier.verify`` when the
    host tier's bookkeeping violates an invariant (the host-side sibling
    of ``CacheInvariantError``)."""


class HostPageTier:
    """Slab allocator over preallocated host page buffers.

    ``capacity`` pages; ``bind(spec)`` fixes the per-page payload layout
    (array name -> (shape, dtype)) on first use and asserts compatibility
    on every later bind — a persistent store can only be reused by caches
    with the identical page format.  Slabs are allocated eagerly at bind
    time (the "pinned host buffers": one contiguous array per payload, no
    per-page malloc on the offload path).
    """

    def __init__(self, capacity: int):
        assert capacity >= 1, capacity
        self.capacity = int(capacity)
        self._spec: Optional[Dict[str, Tuple[tuple, np.dtype]]] = None
        self._slabs: Dict[str, np.ndarray] = {}
        self._free: List[int] = list(range(capacity - 1, -1, -1))
        self._used: set = set()
        self.page_bytes = 0            # wire bytes of one page's payload

    def bind(self, spec: Dict[str, Tuple[tuple, object]]) -> None:
        norm = {name: (tuple(shape), np.dtype(dt))
                for name, (shape, dt) in spec.items()}
        if self._spec is not None:
            if norm != self._spec:
                raise HostTierError(
                    f"host tier bound to a different page format: "
                    f"{self._spec} vs {norm} (a persistent prefix store is "
                    f"reusable only across caches with identical page "
                    f"shape/dtype)")
            return
        self._spec = norm
        for name, (shape, dt) in norm.items():
            self._slabs[name] = np.zeros((self.capacity, *shape), dt)
        self.page_bytes = sum(
            int(np.prod(shape)) * dt.itemsize for shape, dt in norm.values())

    @property
    def bound(self) -> bool:
        return self._spec is not None

    def in_use(self) -> int:
        return len(self._used)

    def alloc_slot(self) -> Optional[int]:
        if not self._free:
            return None
        slot = self._free.pop()
        self._used.add(slot)
        return slot

    def free_slot(self, slot: int) -> None:
        assert slot in self._used, slot
        self._used.discard(slot)
        self._free.append(slot)

    def write(self, slot: int, arrays: Dict[str, np.ndarray]) -> None:
        assert slot in self._used, slot
        assert set(arrays) == set(self._slabs), (
            set(arrays), set(self._slabs))
        for name, a in arrays.items():
            self._slabs[name][slot] = a

    def read(self, slot: int) -> Dict[str, np.ndarray]:
        assert slot in self._used, slot
        return {name: slab[slot] for name, slab in self._slabs.items()}

    def verify(self) -> None:
        if sorted(self._free + list(self._used)) != list(range(self.capacity)):
            raise HostTierError(
                "host tier free/used slots do not partition the slab")


@dataclass
class _Entry:
    key: bytes          # FULL prefix key bytes — verified on every lookup
    slot: int           # tier slab slot holding the page payload


class PrefixStore:
    """Digest-indexed, collision-verified, LRU host store of prefix pages.

    The key is ``PagedCache._key``'s token-prefix bytes — the complete
    causal input of the page's K/V content — so a verified key match means
    the stored bytes ARE the page a recomputed prefill would produce.
    ``lookup`` verifies the full key against the entry before returning
    (digest collisions count in ``stats()["collisions"]`` and miss); a
    consumer that finds the payload non-finite calls ``quarantine`` which
    drops the entry and reclassifies the hit as a poisoned miss.
    """

    #: digest width (bytes) of the index key.  Kept short deliberately —
    #: collision handling must be *correct*, not statistically unreachable
    #: (tests shrink it to 1 to force collisions).
    digest_size = 16

    def __init__(self, host_pages: int):
        self.tier = HostPageTier(host_pages)
        self._entries: "OrderedDict[bytes, _Entry]" = OrderedDict()
        self._stats = {"hits": 0, "misses": 0, "collisions": 0,
                       "evictions": 0, "poisoned": 0, "offloads": 0,
                       "offload_bytes": 0, "prefetch_bytes": 0}

    # ------------------------------------------------------------- keys ----
    def _digest(self, key: bytes) -> bytes:
        return hashlib.blake2b(key, digest_size=self.digest_size).digest()

    # ------------------------------------------------------------ sizing ----
    @property
    def capacity(self) -> int:
        return self.tier.capacity

    def pages_in_use(self) -> int:
        return len(self._entries)

    def bytes_in_use(self) -> int:
        return len(self._entries) * self.tier.page_bytes

    def bytes_total(self) -> int:
        return self.tier.capacity * self.tier.page_bytes

    def bind(self, spec) -> None:
        self.tier.bind(spec)

    # -------------------------------------------------------------- ops ----
    def has(self, key: bytes) -> bool:
        """Key present (full-key verified)?  No stats side effects — this
        is the offload path's dedup probe, not a serving lookup."""
        e = self._entries.get(self._digest(key))
        return e is not None and e.key == key

    def touch(self, key: bytes) -> None:
        d = self._digest(key)
        e = self._entries.get(d)
        if e is not None and e.key == key:
            self._entries.move_to_end(d)

    def put(self, key: bytes, arrays: Dict[str, np.ndarray]) -> None:
        """Store (or refresh) ``key``'s page payload, LRU-evicting to make
        room.  A digest collision on put replaces the resident entry — the
        store is a cache, and the full-key check on lookup keeps either
        choice correct; replacing favours recency."""
        d = self._digest(key)
        e = self._entries.get(d)
        if e is not None:
            if e.key == key:
                self._entries.move_to_end(d)     # already stored: refresh
                return
            self._stats["collisions"] += 1
            self._evict_digest(d)
        slot = self.tier.alloc_slot()
        if slot is None:
            self._evict_digest(next(iter(self._entries)))   # LRU victim
            self._stats["evictions"] += 1
            slot = self.tier.alloc_slot()
            assert slot is not None
        self.tier.write(slot, {n: np.asarray(a) for n, a in arrays.items()})
        self._entries[d] = _Entry(key=key, slot=slot)
        self._stats["offloads"] += 1
        self._stats["offload_bytes"] += self.tier.page_bytes

    def lookup(self, key: bytes) -> Optional[Dict[str, np.ndarray]]:
        """Page payload for ``key``, or ``None`` (counted miss).  A digest
        hit with a different full key is a collision AND a miss — never
        another prefix's bytes."""
        d = self._digest(key)
        e = self._entries.get(d)
        if e is None:
            self._stats["misses"] += 1
            return None
        if e.key != key:
            self._stats["collisions"] += 1
            self._stats["misses"] += 1
            return None
        self._stats["hits"] += 1
        self._entries.move_to_end(d)
        return self.tier.read(e.slot)

    def note_prefetch(self, n_pages: int) -> None:
        """Count ``n_pages`` host->device page copies that actually landed
        (called by the cache after the device write, so a lookup whose
        admission was then denied never counts prefetch bytes)."""
        self._stats["prefetch_bytes"] += n_pages * self.tier.page_bytes

    def quarantine(self, key: bytes) -> None:
        """Drop ``key`` after its payload failed the consumer's finite
        check: the lookup hit stands (monotonic counters) but a miss and
        a poisoned-drop are recorded too — telemetry shows corruption as
        recompute pressure — and the bytes can never be served again."""
        d = self._digest(key)
        e = self._entries.get(d)
        if e is not None and e.key == key:
            self._evict_digest(d)
        self._stats["misses"] += 1
        self._stats["poisoned"] += 1

    def drop(self, key: bytes) -> None:
        d = self._digest(key)
        e = self._entries.get(d)
        if e is not None and e.key == key:
            self._evict_digest(d)

    def _evict_digest(self, d: bytes) -> None:
        e = self._entries.pop(d)
        self.tier.free_slot(e.slot)

    # ------------------------------------------------------------ faults ----
    def poison(self, key: bytes) -> bool:
        """Overwrite ``key``'s stored payload with non-finite values (the
        host-resident arm of the ``poison_page`` fault seam).  Float
        payloads get NaN directly; int8 payloads have no NaN encoding so
        the fp32 scale rows are poisoned, exactly as on device.  Returns
        whether the key was resident."""
        e = self._entries.get(self._digest(key))
        if e is None or e.key != key:
            return False
        arrays = self.tier.read(e.slot)
        floats = {n: a for n, a in arrays.items()
                  if np.issubdtype(a.dtype, np.floating)}
        assert floats, "page payload has no float arrays to poison"
        for a in floats.values():
            a[...] = np.nan        # slab views: writes land in the tier
        return True

    # ------------------------------------------------------------- state ----
    def stats(self) -> Dict[str, int]:
        return dict(self._stats)

    def keys(self) -> List[bytes]:
        return [e.key for e in self._entries.values()]

    def verify(self) -> None:
        """Host-tier invariant sanitizer (called from
        ``PagedCache.verify``): entries fit capacity, tier slots are owned
        exactly once and by the entry that claims them, and every index
        digest matches its entry's full key."""
        def check(cond, what):
            if not cond:
                raise HostTierError(f"PrefixStore.verify: {what}")

        check(len(self._entries) <= self.tier.capacity,
              "more store entries than tier capacity")
        slots = [e.slot for e in self._entries.values()]
        check(len(slots) == len(set(slots)),
              "two store entries share a tier slot")
        check(set(slots) == self.tier._used,
              "store entries and tier used-slots disagree")
        for d, e in self._entries.items():
            check(self._digest(e.key) == d,
                  "store index digest does not match entry key")
        self.tier.verify()
