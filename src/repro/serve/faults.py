"""Deterministic fault injection for the serve engine.

The paper's operational core (§2.2.1 Autopilot, §2.3 automated failure
handling) is that faults are the steady state of large AI infrastructure:
the interesting property of a serving stack is not that it is fast when
everything works but that a NaN out of a fused kernel, a corrupted KV
page, or a lost accelerator degrades it *predictably*.  This module is the
injection half of that story — a seedable, fully deterministic
:class:`FaultPlan` that fires :class:`FaultEvent`\\ s at named seams of
``ServeEngine``:

========================  ====================================================
kind                      seam
========================  ====================================================
``nan_logits``            the fused decode+sample dispatch emits non-finite
                          logit rows for a victim slot (injected *inside*
                          the dispatch via a traced per-slot poison mask, so
                          detection exercises the real on-device guard)
``poison_page``           a live physical KV page's content is overwritten
                          with non-finite values (``PagedCache.poison_page``)
                          — the attention read path drags the corruption
                          into the victim's logits
``chip_failure``          one chip of the ``kv_pages``-sharded pool drops
                          out (``PagedCache.fail_chip``): its free pages are
                          drained, capacity degrades P -> P·(n-1)/n, and
                          every stream holding a page there must recover
``stall_chunk``           a mid-prefill slot's next chunk is refused pages
                          for ``duration`` iterations (a stuck allocator /
                          straggling grant) — the watchdog's prey
``dispatch_error``        the fused dispatch raises a transient
                          :class:`TransientDispatchError` ``duration`` times
                          before the (idempotent) retry goes through
========================  ====================================================

Determinism contract: a plan is a pure function of its event list and
``seed`` — replaying the same plan against the same workload reproduces the
same faults at the same engine iterations, which is what lets the recovery
benches assert *bitwise* stream parity against a fault-free run.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

#: the injectable fault kinds, in the order the docs table lists them
KINDS = ("nan_logits", "poison_page", "chip_failure", "stall_chunk",
         "dispatch_error")


class TransientDispatchError(RuntimeError):
    """A simulated transient device-dispatch failure (XID-style hiccup).

    Raised *before* the real dispatch runs, so its inputs — including the
    donated cache buffers — are untouched and the retry is idempotent."""


@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``iteration`` counts engine ``step()`` calls (0-based).  ``slot`` /
    ``chip`` / ``page`` pin the victim; left ``None``, the engine resolves
    a deterministic victim at fire time (lowest eligible slot / highest
    chip / the victim slot's last private page) so plans stay reproducible
    without the author knowing the admission layout in advance.
    ``duration`` extends the stateful kinds: iterations a ``stall_chunk``
    refuses pages, consecutive ``dispatch_error`` raises."""
    iteration: int
    kind: str
    slot: Optional[int] = None
    chip: Optional[int] = None
    page: Optional[int] = None
    duration: int = 1

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(have {KINDS})")
        if self.iteration < 0 or self.duration < 1:
            raise ValueError(f"bad schedule {self.iteration}@{self.duration}")


class FaultPlan:
    """A deterministic schedule of :class:`FaultEvent` s.

    The engine polls ``events_at(iteration)`` once per ``step()``; events
    whose preconditions are not met yet (e.g. a ``nan_logits`` event while
    no slot is active) are carried forward by the engine, not dropped, so
    every planned fault eventually fires on a draining workload."""

    def __init__(self, events: Sequence[FaultEvent], seed: int = 0):
        self.events: List[FaultEvent] = sorted(
            events, key=lambda e: (e.iteration, KINDS.index(e.kind)))
        self.seed = seed

    def __len__(self) -> int:
        return len(self.events)

    def __repr__(self) -> str:
        return (f"FaultPlan({len(self.events)} events, seed={self.seed}: "
                + ", ".join(f"{e.kind}@{e.iteration}" for e in self.events)
                + ")")

    def events_at(self, iteration: int) -> List[FaultEvent]:
        return [e for e in self.events if e.iteration == iteration]

    # ------------------------------------------------------- constructors ----
    @classmethod
    def parse(cls, spec: str, seed: int = 0) -> "FaultPlan":
        """Build a plan from a CLI string (``--fault-plan``).

        Comma-separated ``kind@iteration[:key=val[:key=val...]]`` entries;
        keys are ``slot`` / ``chip`` / ``page`` / ``dur``::

            nan_logits@5,poison_page@9:slot=2,chip_failure@12:chip=1
            stall_chunk@3:slot=0:dur=8,dispatch_error@7:dur=2
        """
        events: List[FaultEvent] = []
        for part in filter(None, (p.strip() for p in spec.split(","))):
            head, *opts = part.split(":")
            kind, at, iteration = head.partition("@")
            if not at:
                raise ValueError(f"fault {part!r}: expected kind@iteration")
            kw: Dict[str, int] = {}
            for opt in opts:
                key, eq, val = opt.partition("=")
                if not eq or key not in ("slot", "chip", "page", "dur"):
                    raise ValueError(f"fault {part!r}: bad option {opt!r} "
                                     "(slot=/chip=/page=/dur=)")
                kw["duration" if key == "dur" else key] = int(val)
            events.append(FaultEvent(int(iteration), kind, **kw))
        return cls(events, seed=seed)

    @classmethod
    def random(cls, n: int, max_iter: int, seed: int = 0,
               kinds: Tuple[str, ...] = ("nan_logits", "poison_page",
                                         "stall_chunk", "dispatch_error"),
               ) -> "FaultPlan":
        """A seeded random plan for soak tests: ``n`` events drawn over
        ``[1, max_iter)`` with victims left to engine-side deterministic
        resolution.  ``chip_failure`` is excluded by default — it is not
        repeatable (a chip fails once) and belongs in targeted plans."""
        rng = np.random.default_rng(seed)
        events = [FaultEvent(int(rng.integers(1, max_iter)),
                             str(rng.choice(list(kinds))),
                             duration=int(rng.integers(1, 4)))
                  for _ in range(n)]
        return cls(events, seed=seed)
