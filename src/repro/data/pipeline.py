"""Tokenized-dataset pipeline: memmap-backed binary shards, deterministic
sharded reads per DP rank, background prefetch, and the two-tier storage
integration (tokenization happens off-cluster — §3.1.3 — so training only
ever reads fixed-width token records).

Determinism contract: ``batch_at(step)`` is a pure function of (step, seed,
topology), so a job restarted from a checkpoint consumes exactly the token
stream it would have seen without the failure — required for the FT
loss-trajectory equivalence test."""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np


def write_token_shards(directory: str, tokens: np.ndarray,
                       shard_tokens: int = 1 << 20) -> list:
    """Write a flat uint32 token stream into .bin shards + index."""
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    paths = []
    for i in range(0, len(tokens), shard_tokens):
        p = d / f"tokens_{i // shard_tokens:06d}.bin"
        tokens[i:i + shard_tokens].astype(np.uint32).tofile(p)
        paths.append(p)
    (d / "index.txt").write_text(
        "\n".join(f"{p.name} {p.stat().st_size // 4}" for p in paths))
    return paths


def synthetic_corpus(n_tokens: int, vocab: int, seed: int = 0) -> np.ndarray:
    """Zipf-ish synthetic token stream (markov-free but skewed like text)."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    return rng.choice(vocab, size=n_tokens, p=probs).astype(np.uint32)


class TokenDataset:
    """Memmap view over the shard directory."""

    def __init__(self, directory: str):
        d = Path(directory)
        index = [(l.split()[0], int(l.split()[1]))
                 for l in (d / "index.txt").read_text().splitlines()]
        self.maps = [np.memmap(d / name, np.uint32, "r", shape=(n,))
                     for name, n in index]
        self.total = sum(len(m) for m in self.maps)
        self._starts = np.cumsum([0] + [len(m) for m in self.maps])

    def slice(self, start: int, length: int) -> np.ndarray:
        start = start % max(self.total - length - 1, 1)
        out = np.empty(length + 1, np.uint32)
        got = 0
        while got <= length:
            si = int(np.searchsorted(self._starts, start, "right") - 1)
            m = self.maps[si]
            off = start - self._starts[si]
            take = min(len(m) - off, length + 1 - got)
            out[got:got + take] = m[off:off + take]
            got += take
            start += take
        return out


@dataclass
class LoaderConfig:
    batch_size: int            # global batch (sequences)
    seq_len: int
    dp_rank: int = 0
    dp_size: int = 1
    seed: int = 0


class DeterministicLoader:
    """Sharded deterministic loader: rank r reads rows [r::dp_size] of the
    global batch for any step, from any restart point."""

    def __init__(self, dataset: TokenDataset, cfg: LoaderConfig):
        assert cfg.batch_size % cfg.dp_size == 0
        self.ds = dataset
        self.cfg = cfg
        self.local_bs = cfg.batch_size // cfg.dp_size

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        c = self.cfg
        rng = np.random.default_rng((c.seed, step))
        starts = rng.integers(0, max(self.ds.total - c.seq_len - 1, 1),
                              size=c.batch_size)
        mine = starts[c.dp_rank::c.dp_size]
        toks = np.stack([self.ds.slice(int(s), c.seq_len) for s in mine])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def pack_documents(docs: list, seq_len: int, eos_id: int,
                   pad_id: int = 0) -> Dict[str, np.ndarray]:
    """Sequence packing: greedy first-fit of documents into fixed-length rows
    with EOS separators and a loss mask that excludes padding and the token
    that would predict across a document boundary.

    Returns {"tokens", "labels", "loss_mask"} each (n_rows, seq_len).
    """
    rows: list = []
    row: list = []
    boundaries: list = []
    row_bounds: list = []
    for doc in docs:
        need = len(doc) + 1
        if len(row) + need > seq_len + 1 and row:
            rows.append(row)
            row_bounds.append(boundaries)
            row, boundaries = [], []
        row.extend(list(doc) + [eos_id])
        boundaries.append(len(row) - 1)
    if row:
        rows.append(row)
        row_bounds.append(boundaries)

    n = len(rows)
    tokens = np.full((n, seq_len), pad_id, np.int32)
    labels = np.full((n, seq_len), pad_id, np.int32)
    mask = np.zeros((n, seq_len), np.float32)
    for i, (r, bnds) in enumerate(zip(rows, row_bounds)):
        r = r[:seq_len + 1]
        toks = np.asarray(r[:-1] if len(r) > seq_len else r, np.int32)
        tokens[i, :len(toks)] = toks[:seq_len]
        lab = np.asarray(r[1:len(toks) + 1], np.int32)
        labels[i, :len(lab)] = lab
        mask[i, :len(lab)] = 1.0
        for b in bnds:                       # don't predict across docs
            if 0 <= b < seq_len:
                mask[i, b] = 0.0
    return {"tokens": tokens, "labels": labels, "loss_mask": mask}


class PrefetchLoader:
    """Background-thread prefetch (keeps the accelerator fed — the paper's
    'feed the GPUs to keep them busy' requirement)."""

    def __init__(self, loader: DeterministicLoader, depth: int = 2,
                 start_step: int = 0):
        self.loader = loader
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.loader.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, batch = self.q.get()
        return step, batch

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
