from repro.data.pipeline import (DeterministicLoader, LoaderConfig,
                                 PrefetchLoader, TokenDataset,
                                 pack_documents, synthetic_corpus,
                                 write_token_shards)

__all__ = ["DeterministicLoader", "LoaderConfig", "PrefetchLoader",
           "TokenDataset", "pack_documents", "synthetic_corpus",
           "write_token_shards"]
