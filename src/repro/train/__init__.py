from repro.train.optimizer import (adamw_update, init_opt_state, lr_schedule)
from repro.train.trainer import (abstract_train_state, init_train_state,
                                 make_eval_step, make_train_step,
                                 train_state_logical_axes)

__all__ = ["adamw_update", "init_opt_state", "lr_schedule",
           "abstract_train_state", "init_train_state", "make_eval_step",
           "make_train_step", "train_state_logical_axes"]
