"""Train-step factory: loss -> grads (with microbatch accumulation) -> AdamW.

The returned step is a pure function ``(state, batch) -> (state, metrics)``
suitable for ``jax.jit`` with explicit shardings (see launch/dryrun.py) or for
plain CPU execution in tests/examples.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.models import LM, ForwardOpts
from repro.train import optimizer as opt_mod


def init_train_state(lm: LM, rng, tcfg: TrainConfig) -> Dict[str, Any]:
    params = lm.init(rng)
    return {"params": params, "opt": opt_mod.init_opt_state(params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_train_state(lm: LM) -> Dict[str, Any]:
    params = lm.abstract_params()
    return {"params": params, "opt": opt_mod.abstract_opt_state(params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def train_state_logical_axes(lm: LM) -> Dict[str, Any]:
    axes = lm.param_logical_axes()
    state_axes = {"m": axes, "v": axes}
    # master weights present iff params are not f32
    if any(jnp.dtype(p.dtype) != jnp.float32
           for p in jax.tree.leaves(lm.abstract_params())):
        state_axes["master"] = axes
    return {"params": axes, "opt": state_axes, "step": ()}


def make_train_step(lm: LM, tcfg: TrainConfig,
                    opts: ForwardOpts = ForwardOpts(),
                    microbatches: int = 1, shard_grads: bool = False):
    def loss_fn(params, batch):
        return lm.loss(params, batch, opts, moe_aux_weight=tcfg.moe_aux_loss,
                       z_loss=tcfg.z_loss)

    grad_fn_raw = jax.value_and_grad(loss_fn, has_aux=True)
    param_axes = lm.param_logical_axes() if shard_grads else None

    def grad_fn(params, batch):
        out, grads = grad_fn_raw(params, batch)
        if shard_grads:
            # pin grads to the param sharding: the cross-DP reduction lowers
            # to reduce-scatter instead of a full all-reduce (§Perf)
            from repro.parallel.sharding import constrain
            is_axes = lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x)
            grads = jax.tree.map(lambda g, ax: constrain(g, ax), grads,
                                 param_axes,
                                 is_leaf=lambda x: is_axes(x))
        return out, grads

    def accumulate(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            return loss, metrics, grads
        # split batch leading dim into microbatches and scan-accumulate f32 grads
        def resplit(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])
        mb = jax.tree.map(resplit, batch)

        def body(carry, microbatch):
            acc, loss_acc = carry
            (loss, metrics), grads = grad_fn(params, microbatch)
            acc = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32) / microbatches,
                acc, grads)
            return (acc, loss_acc + loss / microbatches), metrics

        zero = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), metrics = jax.lax.scan(body, (zero, 0.0), mb)
        metrics = jax.tree.map(lambda m: m[-1], metrics)
        return loss, metrics, grads

    def train_step(state, batch):
        loss, metrics, grads = accumulate(state["params"], batch)
        new_params, new_opt, stats = opt_mod.adamw_update(
            grads, state["opt"], state["params"], state["step"], tcfg)
        metrics = dict(metrics)
        metrics.update(stats)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return train_step


def make_eval_step(lm: LM, opts: ForwardOpts = ForwardOpts()):
    def eval_step(params, batch):
        _, metrics = lm.loss(params, batch, opts)
        return metrics
    return eval_step
