"""AdamW with mixed-precision master weights + LR schedules (no optax dep).

Optimizer state is a pytree congruent with params, so the FSDP sharding rules
apply verbatim (ZeRO: master/m/v sharded exactly like the weights).
"""
from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig


def lr_schedule(tcfg: TrainConfig, step):
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / jnp.maximum(tcfg.warmup_steps, 1), 1.0)
    total = max(tcfg.total_steps - tcfg.warmup_steps, 1)
    frac = jnp.clip((step - tcfg.warmup_steps) / total, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    mult = tcfg.min_lr_ratio + (1 - tcfg.min_lr_ratio) * cos
    return tcfg.learning_rate * warm * mult


def init_opt_state(params) -> Dict[str, Any]:
    zeros_like_f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    state = {
        "m": jax.tree.map(zeros_like_f32, params),
        "v": jax.tree.map(zeros_like_f32, params),
    }
    if any(p.dtype != jnp.float32 for p in jax.tree.leaves(params)):
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def abstract_opt_state(abstract_parms) -> Dict[str, Any]:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    state = {"m": jax.tree.map(f32, abstract_parms),
             "v": jax.tree.map(f32, abstract_parms)}
    if any(p.dtype != jnp.float32 for p in jax.tree.leaves(abstract_parms)):
        state["master"] = jax.tree.map(f32, abstract_parms)
    return state


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(grads, opt_state, params, step, tcfg: TrainConfig):
    """Returns (new_params, new_opt_state, stats).  grads may be bf16; moments
    and master weights are f32."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, tcfg.grad_clip / (gnorm + 1e-9)) \
        if tcfg.grad_clip > 0 else 1.0
    lr = lr_schedule(tcfg, step)
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - tcfg.beta1 ** t
    bc2 = 1.0 - tcfg.beta2 ** t
    masters = opt_state.get("master", params)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = tcfg.beta1 * m + (1 - tcfg.beta1) * g
        v = tcfg.beta2 * v + (1 - tcfg.beta2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        step_ = mhat / (jnp.sqrt(vhat) + tcfg.eps)
        if master.ndim >= 2 and tcfg.weight_decay > 0:
            step_ = step_ + tcfg.weight_decay * master
        new_master = master - lr * step_
        return m, v, new_master

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    flat_ma = treedef.flatten_up_to(masters)
    out = [upd(g, m, v, ma) for g, m, v, ma in
           zip(flat_g, flat_m, flat_v, flat_ma)]
    new_m = treedef.unflatten([o[0] for o in out])
    new_v = treedef.unflatten([o[1] for o in out])
    new_master = treedef.unflatten([o[2] for o in out])

    flat_p = treedef.flatten_up_to(params)
    new_params = treedef.unflatten(
        [ma.astype(p.dtype) for ma, p in
         zip([o[2] for o in out], flat_p)])
    new_state = {"m": new_m, "v": new_v}
    if "master" in opt_state:
        new_state["master"] = new_master
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, stats
