"""Fused AdamW update Pallas kernel: one HBM pass over (g, m, v, master)
instead of the multi-pass elementwise chain (grad cast, moment updates, bias
correction, weight decay, parameter write) — the optimizer is memory-bound,
so pass count is the whole game.

Grid over flat row blocks; multi-output pallas_call returns
(new_m, new_v, new_master).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(g_ref, m_ref, v_ref, p_ref, nm_ref, nv_ref, np_ref, *,
            lr: float, beta1: float, beta2: float, eps: float,
            weight_decay: float, bias_corr1: float, bias_corr2: float,
            scale: float):
    g = g_ref[...].astype(jnp.float32) * scale
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    mhat = m / bias_corr1
    vhat = v / bias_corr2
    p = p_ref[...]
    step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p
    nm_ref[...] = m
    nv_ref[...] = v
    np_ref[...] = p - lr * step


def adamw_fused(g, m, v, master, *, lr: float, beta1: float = 0.9,
                beta2: float = 0.95, eps: float = 1e-8,
                weight_decay: float = 0.0, step: int = 1,
                grad_scale: float = 1.0, block: int = 4096,
                interpret: bool = False):
    """Flat f32 arrays (m, v, master) + grad (any float dtype).
    Returns (new_m, new_v, new_master)."""
    n = g.size
    gf = g.reshape(n)
    pad = (-n) % block
    if pad:
        gf = jnp.pad(gf, (0, pad))
        m = jnp.pad(m.reshape(n), (0, pad))
        v = jnp.pad(v.reshape(n), (0, pad))
        master = jnp.pad(master.reshape(n), (0, pad))
    else:
        m, v, master = m.reshape(n), v.reshape(n), master.reshape(n)
    nt = gf.size
    kernel = functools.partial(
        _kernel, lr=lr, beta1=beta1, beta2=beta2, eps=eps,
        weight_decay=weight_decay, bias_corr1=1.0 - beta1 ** step,
        bias_corr2=1.0 - beta2 ** step, scale=grad_scale)
    spec = pl.BlockSpec((block,), lambda i: (i,))
    f32 = jnp.float32
    nm, nv, nmaster = pl.pallas_call(
        kernel,
        grid=(nt // block,),
        in_specs=[spec, spec, spec, spec],
        out_specs=(spec, spec, spec),
        out_shape=(jax.ShapeDtypeStruct((nt,), f32),
                   jax.ShapeDtypeStruct((nt,), f32),
                   jax.ShapeDtypeStruct((nt,), f32)),
        interpret=interpret,
    )(gf, m, v, master)
    shape = g.shape
    return (nm[:n].reshape(shape), nv[:n].reshape(shape),
            nmaster[:n].reshape(shape))
