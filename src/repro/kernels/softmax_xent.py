"""Fused softmax cross-entropy Pallas kernel: per row, one VMEM-resident pass
computes max, logsumexp, and the label logit — the unfused XLA chain reads
the (N, V) logits three times (max, exp-sum, gather).

Returns per-row nll; the vocab-padded tail is masked inside the kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _kernel(logits_ref, labels_ref, out_ref, *, vocab: int, bn: int):
    x = logits_ref[...].astype(jnp.float32)           # (bn, Vp)
    vp = x.shape[-1]
    if vp != vocab:                                    # mask padded vocab tail
        col = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
        x = jnp.where(col < vocab, x, NEG)
    m = jnp.max(x, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(x - m), axis=-1)) + m[:, 0]
    labels = labels_ref[...]
    cols = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    picked = jnp.sum(jnp.where(cols == labels[:, None], x, 0.0), axis=-1)
    out_ref[...] = lse - picked


def softmax_xent(logits, labels, *, vocab: int = 0, block_rows: int = 8,
                 interpret: bool = False):
    """logits: (..., Vp); labels: (...,) int32 < vocab.  Returns nll (...)."""
    vp = logits.shape[-1]
    vocab = vocab or vp
    lead = logits.shape[:-1]
    n = 1
    for d in lead:
        n *= d
    x = logits.reshape(n, vp)
    y = labels.reshape(n)
    bn = block_rows
    while n % bn:
        bn //= 2
    bn = max(bn, 1)
    out = pl.pallas_call(
        functools.partial(_kernel, vocab=vocab, bn=bn),
        grid=(n // bn,),
        in_specs=[pl.BlockSpec((bn, vp), lambda i: (i, 0)),
                  pl.BlockSpec((bn,), lambda i: (i,))],
        out_specs=pl.BlockSpec((bn,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(x, y)
    return out.reshape(lead)
