"""Flash attention Pallas TPU kernel (GQA-aware, causal).

Grid: (B·KV·G, n_q_blocks, n_kv_blocks) with the KV dimension innermost —
TPU grids iterate the trailing dim sequentially, so the online-softmax
running state (m, l, acc) lives in VMEM scratch across KV steps.  Block
shapes are MXU-aligned (128 lanes); K/V blocks are shared across the G query
groups of a KV head via the index map (b // G) so GQA never materializes
repeated KV.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, bq: int, bk: int, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    # causal: skip KV blocks strictly above this q block's last row
    live = (ki * bk <= qi * bq + bq - 1) if causal else (ki >= 0)

    @pl.when(live)
    def _body():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        v = v_ref[0].astype(jnp.float32)
        acc_scr[...] = acc_scr[...] * corr[:, None] + p @ v
        m_scr[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True, block_q: int = 128,
                         block_k: int = 128, interpret: bool = False):
    """q: (BHG, S, D); k, v: (BKV, S, D) with BHG = BKV * G.  Returns (BHG, S, D)."""
    bhg, sq, d = q.shape
    bkv, skv, _ = k.shape
    assert bhg % bkv == 0, (bhg, bkv)
    g = bhg // bkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, bq, skv, bk)
    n_q, n_kv = sq // bq, skv // bk
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               bq=bq, bk=bk, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=(bhg, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qi, ki: (b // g, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda b, qi, ki: (b // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bhg, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
