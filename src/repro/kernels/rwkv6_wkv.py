"""RWKV6 WKV chunked-recurrence Pallas kernel.

Grid: (B·H, n_chunks), chunks sequential, (K, V) state in VMEM scratch.
Intra-chunk pairs use the rank-1 exponent split around the chunk midpoint
(exact given the model's per-step log-decay floor; see models/rwkv6.py) so
the pairwise decay matrix is two MXU matmuls instead of an O(Q²K) gather.
The u-bonus diagonal is added separately.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, state_scr, *, q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros(state_scr.shape, jnp.float32)

    r = r_ref[0].astype(jnp.float32)          # (Q, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # (Q, V)
    lw = lw_ref[0].astype(jnp.float32)        # (Q, K), <= 0
    u = u_ref[0].astype(jnp.float32)          # (K,)

    cs = jnp.cumsum(lw, axis=0)               # inclusive
    ce = cs - lw                              # exclusive
    mid = cs[-1] * 0.5
    qf = r * jnp.exp(jnp.clip(ce - mid, -40.0, 40.0))
    kf = k * jnp.exp(jnp.clip(mid - cs, -40.0, 40.0))
    a = jax.lax.dot_general(qf, kf, (((1,), (1,)), ((), ())))   # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    a = jnp.where(ii > jj, a, 0.0)            # strictly lower
    diag = jnp.sum(r * u[None, :] * k, axis=-1)                 # (Q,)
    y = a @ v + diag[:, None] * v
    y = y + (r * jnp.exp(ce)) @ state_scr[...]                  # (Q, V)
    y_ref[0] = y.astype(y_ref.dtype)
    # state: S <- diag(exp(cs_Q)) S + (k * exp(cs_Q - cs))^T v
    state_scr[...] = (state_scr[...] * jnp.exp(cs[-1])[:, None]
                      + (k * jnp.exp(cs[-1] - cs)).T @ v)       # (K, V)


def wkv6_scan_bhsd(r, k, v, lw, u, *, chunk: int = 32,
                   interpret: bool = False):
    """r, k, lw: (BH, S, K); v: (BH, S, V); u: (H, K) indexed by bh % H.
    Returns y: (BH, S, V)."""
    bh, s, kd = r.shape
    vd = v.shape[-1]
    h = u.shape[0]
    qc = min(chunk, s)
    assert s % qc == 0, (s, qc)
    nc = s // qc
    return pl.pallas_call(
        functools.partial(_kernel, q=qc),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, qc, kd), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, qc, kd), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, qc, vd), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, qc, kd), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, kd), lambda i, ci: (i % h, 0)),
        ],
        out_specs=pl.BlockSpec((1, qc, vd), lambda i, ci: (i, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, vd), r.dtype),
        scratch_shapes=[pltpu.VMEM((kd, vd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u)
