"""Paged flash-decode Pallas TPU kernel: page-table-walking attention.

The XLA paged decode (``repro.models.attention.gather_pages``) resolves the
page table by materializing a dense-equivalent ``(B, M*page, KV, D)`` K and V
view every step — a transient that scales with the paged-enlarged concurrent
batch even though *pinned* pool bytes do not, which is exactly the
memory-movement waste the serving story is trying to kill.  This kernel walks
the indirection instead of materializing it:

* Grid ``(B, KV, M)`` with the logical-page dimension innermost — TPU grids
  iterate the trailing dim sequentially, so each (slot, kv-head) program
  streams its slot's pages one block at a time while the online-softmax
  running state (m, l, acc) lives in VMEM scratch across page steps.
* The **page table walk happens in the BlockSpec index maps** via scalar
  prefetch (``PrefetchScalarGridSpec``): the (B, M) table, (B,) position
  vector, and (1,) local-page offset are SMEM-resident before the body runs,
  and the K/V index map for grid point (b, h, j) resolves physical page
  ``table[b, j] - offset`` directly — the pipeline DMAs exactly one
  (page, D) tile of each pool per step, so per-step transient memory is
  O(block) = O(page·D), not O(B·M·page·D).
* **Early exit**: pages wholly past the slot's position carry no live rows,
  and — under sharded serving — pages outside this chip's local window
  ``[offset, offset + P_local)`` belong to another chip's pool shard.  Both
  kinds redirect their index map to local page 0 (consecutive grid steps
  with an unchanged block index elide the DMA) and ``pl.when`` skips their
  compute entirely, so a slot at position p pays for the live pages *this
  chip owns*, regardless of its table width M.
* The masked-softmax math matches ``decode_attention``'s reference: scores
  are fp32, rows past the slot's position are masked to NEG_INF *before* the
  running max, and the final normalization divides once at the last page.

**Sharded serving** (``repro.parallel.pagedkv``) runs one kernel instance
per chip over its (P/n, page, KV, D) pool shard with ``page_offset =
chip * P/n`` and ``partials=True``: instead of the normalized output each
chip emits its raw online-softmax triple — unnormalized ``acc`` (B, KV, G,
D), row sum ``l`` and running max ``m`` (B, KV, G) — and the caller combines
chips with one psum-style merge::

    m*  = pmax(m);  w = exp(m - m*)
    out = psum(acc * w) / psum(l * w)

A chip that owns no live page of a slot contributes (acc=0, l=0,
m=NEG_INF) — exactly the online-softmax identity element, so its merge
weight is zero.

Layouts (model code adapts via ``repro.kernels.ops``):
  q:          (B, KV, G, D)   one query token per slot, grouped GQA
  k/v pools:  (P, page, KV, D) physical pages; page 0 is the scratch page
  page_table: (B, M) int32    logical -> physical page ids (GLOBAL ids even
                              when the pool argument is a local shard)
  positions:  (B,) int32      per-slot decode position (the row just written)
  out:        (B, KV, G, D)   — or (acc, l, m) when ``partials=True``

Occupancy/shape assumptions (documented in ROADMAP): one program per
(slot, kv-head) — B·KV programs — and the KV block equals one physical page,
so TPU-efficient operation wants page·D tiles aligned to the (8, 128) fp32 /
(16, 128) bf16 tiling (i.e. serve with page_size >= 8; tiny pages still run,
they just underfill the MXU).  The page table, positions, and offset ride in
SMEM: B·(M+1)+1 int32 scalars per dispatch.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(pt_ref, pos_ref, off_ref, *refs,
            scale: float, page: int, n_pages: int, p_local: int,
            partials: bool, quantized: bool):
    if quantized:
        ks_ref, vs_ref, *refs = refs
    else:
        ks_ref = vs_ref = None
    q_ref, k_ref, v_ref, *refs = refs
    if partials:
        o_ref, l_ref, mx_ref, m_scr, l_scr, acc_scr = refs
    else:
        o_ref, m_scr, l_scr, acc_scr = refs
    b = pl.program_id(0)
    h = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full(m_scr.shape, NEG_INF, jnp.float32)
        l_scr[...] = jnp.zeros(l_scr.shape, jnp.float32)
        acc_scr[...] = jnp.zeros(acc_scr.shape, jnp.float32)

    pos = pos_ref[b]
    local = pt_ref[b, j] - off_ref[0]

    # early exit: a page whose first row is past the slot's position has no
    # live rows, and a page outside this chip's [offset, offset+P_local)
    # window lives in another chip's pool shard (its DMA was already
    # redirected to local page 0 by the index map); skip compute entirely
    @pl.when((j * page <= pos) & (local >= 0) & (local < p_local))
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)              # (page, D)
        if quantized:
            # in-register dequant: the (page,) fp32 scale rows for this
            # (page, head) ride in SMEM next to the page table and resolve
            # through the same ``local`` id the DMA used
            k = k * ks_ref[local, :, h][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        rows = j * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(rows <= pos, s, NEG_INF)                 # (G, page)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if quantized:
            v = v * vs_ref[local, :, h][:, None]
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == n_pages - 1)
    def _finalize():
        if partials:
            # raw online-softmax triple: the caller's cross-chip merge
            # normalizes once, after combining every chip's contribution
            o_ref[0, 0] = acc_scr[...].astype(o_ref.dtype)
            l_ref[0, 0] = l_scr[...]
            mx_ref[0, 0] = m_scr[...]
        else:
            denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
            o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def paged_flash_decode(q, k_pool, v_pool, page_table, positions,
                       page_offset=None, k_scale=None, v_scale=None,
                       partials: bool = False, interpret: bool = False):
    """q: (B, KV, G, D); k/v pools: (P, page, KV, D); page_table: (B, M)
    int32; positions: (B,) int32.  Returns (B, KV, G, D).

    ``page_offset`` (scalar int32, default 0): global page id of the pool
    argument's first page — table entries outside ``[offset, offset + P)``
    are treated exactly like dead pages (index-map redirect + compute skip).
    ``partials=True`` returns the raw fp32 online-softmax triple
    ``(acc (B,KV,G,D), l (B,KV,G), m (B,KV,G))`` instead of the normalized
    output, for the cross-chip partial-softmax merge of sharded serving.

    ``k_scale``/``v_scale`` (int8 pools): (P, page, KV) fp32 absmax scales
    for the quantized page format.  They ride as scalar-prefetch operands —
    SMEM-resident next to the page table — and the body dequantizes each
    K/V tile in-register (``int8 -> fp32 * scale_row``) right after the
    block load, so the dense-precision transient never exists: HBM traffic
    stays at the int8 tile plus (page,) scale rows per grid step."""
    b, kv, g, d = q.shape
    p_local, page = k_pool.shape[:2]
    assert k_pool.shape == v_pool.shape and k_pool.shape[2:] == (kv, d), (
        q.shape, k_pool.shape, v_pool.shape)
    m = page_table.shape[1]
    assert page_table.shape == (b, m) and positions.shape == (b,), (
        page_table.shape, positions.shape, b)
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), "k/v scales travel together"
    if quantized:
        assert k_scale.shape == v_scale.shape == (p_local, page, kv), (
            k_scale.shape, k_pool.shape)
    scale = 1.0 / math.sqrt(d)
    if page_offset is None:
        page_offset = 0
    off = jnp.asarray(page_offset, jnp.int32).reshape(1)

    # index maps see every scalar-prefetch operand; scales (when present)
    # trail the table/positions/offset and are unused for indexing
    def q_map(b_, h, j, pt, pos, off, *_):
        return (b_, h, 0, 0)

    def lm_map(b_, h, j, pt, pos, off, *_):
        return (b_, h, 0)

    def kv_map(b_, h, j, pt, pos, off, *_):
        # the page-table walk: dead pages (past the slot's position) and
        # non-local pages (outside this chip's pool shard) resolve to local
        # page 0 so repeated skipped steps elide their DMA
        local = pt[b_, j] - off[0]
        ok = (j * page <= pos[b_]) & (local >= 0) & (local < p_local)
        return (jnp.where(ok, local, 0), 0, h, 0)

    kernel = functools.partial(_kernel, scale=scale, page=page, n_pages=m,
                               p_local=p_local, partials=partials,
                               quantized=quantized)
    out_specs = [pl.BlockSpec((1, 1, g, d), q_map)]
    out_shape = [jax.ShapeDtypeStruct(
        (b, kv, g, d), jnp.float32 if partials else q.dtype)]
    if partials:
        out_specs += [pl.BlockSpec((1, 1, g), lm_map),
                      pl.BlockSpec((1, 1, g), lm_map)]
        out_shape += [jax.ShapeDtypeStruct((b, kv, g), jnp.float32),
                      jax.ShapeDtypeStruct((b, kv, g), jnp.float32)]
    scalar_args = [page_table.astype(jnp.int32),
                   positions.astype(jnp.int32), off]
    if quantized:
        scalar_args += [k_scale.astype(jnp.float32),
                        v_scale.astype(jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(scalar_args),
        grid=(b, kv, m),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), q_map),
            pl.BlockSpec((1, page, 1, d), kv_map),
            pl.BlockSpec((1, page, 1, d), kv_map),
        ],
        out_specs=out_specs if partials else out_specs[0],
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),       # running max
            pltpu.VMEM((g,), jnp.float32),       # running sum
            pltpu.VMEM((g, d), jnp.float32),     # output accumulator
        ],
    )
    return pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=out_shape if partials else out_shape[0],
        interpret=interpret,
    )(*scalar_args, q, k_pool, v_pool)
