"""Mamba2 SSD chunked-scan Pallas kernel.

Grid: (B·H, n_chunks) with chunks innermost (sequential), carrying the
(P, N) SSM state in VMEM scratch across chunks.  Within a chunk everything is
MXU matmuls over (Q, ·) blocks: the attention-like intra-chunk term, the
chunk-state contraction, and the state-output term.  B/C projections are
shared across the H heads of a batch entry via the index map (b // H).

Inputs are the *pre-scaled* SSD operands (X·dt, dt·A) exactly as in
``repro.models.mamba2.ssd_chunked`` — the jnp reference oracle for this
kernel is ``repro.kernels.ref.ssd_ref`` (naive sequential recurrence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, da_ref, b_ref, c_ref, y_ref, state_scr, *, q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros(state_scr.shape, jnp.float32)

    x = x_ref[0].astype(jnp.float32)          # (Q, P)
    da = da_ref[0].astype(jnp.float32)        # (Q,)
    b = b_ref[0].astype(jnp.float32)          # (Q, N)
    c = c_ref[0].astype(jnp.float32)          # (Q, N)

    cs = jnp.cumsum(da)                       # inclusive, <= 0 increments
    # intra-chunk: decay(i,j) = exp(cs_i - cs_j) for j <= i
    diff = cs[:, None] - cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(ii >= jj, jnp.exp(diff), 0.0)
    att = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ()))) * L   # (Q, Q)
    y = att @ x                                                      # (Q, P)
    # inter-chunk: y_i += (C_i * exp(cs_i)) @ state^T
    y = y + (c * jnp.exp(cs)[:, None]) @ state_scr[...].T            # (Q, P)
    y_ref[0] = y.astype(y_ref.dtype)
    # state update: S <- S * exp(cs_Q) + X^T (B * decay_to_end)
    d2e = jnp.exp(cs[-1] - cs)
    state_scr[...] = (state_scr[...] * jnp.exp(cs[-1])
                      + x.T @ (b * d2e[:, None]))                    # (P, N)


def ssd_scan_bhsd(x, da, b, c, *, chunk: int = 128,
                  interpret: bool = False):
    """x: (BH, S, P) pre-scaled by dt; da: (BH, S) = dt·A; b, c: (B, S, N)
    (broadcast across heads via index map).  Returns y: (BH, S, P)."""
    bh, s, p = x.shape
    bb, _, n = b.shape
    assert bh % bb == 0
    h = bh // bb
    qc = min(chunk, s)
    assert s % qc == 0, (s, qc)
    nc = s // qc
    return pl.pallas_call(
        functools.partial(_kernel, q=qc),
        grid=(bh, nc),
        in_specs=[
            pl.BlockSpec((1, qc, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, qc), lambda i, ci: (i, ci)),
            pl.BlockSpec((1, qc, n), lambda i, ci: (i // h, ci, 0)),
            pl.BlockSpec((1, qc, n), lambda i, ci: (i // h, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, qc, p), lambda i, ci: (i, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, da, b, c)
