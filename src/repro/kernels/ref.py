"""Pure-jnp oracles for every Pallas kernel (the ground truth the allclose
sweeps compare against).  The recurrent oracles are the *naive sequential*
recurrences — so the tests validate both the kernels and the chunked-parallel
formulations in repro.models against first principles."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (BHG, S, D); k, v: (BKV, S, D).  Dense softmax attention."""
    bhg, sq, d = q.shape
    bkv = k.shape[0]
    g = bhg // bkv
    kr = jnp.repeat(k, g, axis=0)
    vr = jnp.repeat(v, g, axis=0)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / (d ** 0.5)
    if causal:
        mask = jnp.tril(jnp.ones((sq, k.shape[1]), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, vr.astype(jnp.float32)).astype(q.dtype)


def paged_decode_ref(q, k_pool, v_pool, page_table, positions,
                     k_scale=None, v_scale=None):
    """Paged single-token decode attention by dense gather — the masked
    softmax the flash kernel must reproduce.  q: (B, KV, G, D); pools:
    (P, page, KV, D); page_table: (B, M); positions: (B,).  The gathered
    (B, M*page, KV, D) view is exactly the transient the kernel exists to
    avoid; here it *is* the spec.

    ``k_scale``/``v_scale`` ((P, page, KV) fp32): the int8 page format's
    per-row scales — the gathered views dequantize through the same table,
    the spec the kernel's in-register dequant must match."""
    b, kv, g, d = q.shape
    page = k_pool.shape[1]
    m = page_table.shape[1]
    kg = jnp.take(k_pool, page_table, axis=0).reshape(b, m * page, kv, d)
    vg = jnp.take(v_pool, page_table, axis=0).reshape(b, m * page, kv, d)
    if k_scale is not None:
        ksg = jnp.take(k_scale, page_table, axis=0).reshape(b, m * page, kv)
        vsg = jnp.take(v_scale, page_table, axis=0).reshape(b, m * page, kv)
        kg = kg.astype(jnp.float32) * ksg[..., None]
        vg = vg.astype(jnp.float32) * vsg[..., None]
    s = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32),
                   kg.astype(jnp.float32)) / (d ** 0.5)
    valid = jnp.arange(m * page)[None, :] <= positions[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgs,bskd->bkgd", p,
                      vg.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x, scale, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def ssd_ref(x, da, b, c):
    """Naive SSD recurrence.  x: (BH, S, P) pre-scaled by dt; da: (BH, S);
    b, c: (B, S, N) broadcast across heads.
        h_t = exp(da_t) h_{t-1} + b_t ⊗ x_t;  y_t = c_t · h_t
    """
    bh, s, p = x.shape
    bb, _, n = b.shape
    h = bh // bb
    br = jnp.repeat(b, h, axis=0).astype(jnp.float32)
    cr = jnp.repeat(c, h, axis=0).astype(jnp.float32)
    xf, daf = x.astype(jnp.float32), da.astype(jnp.float32)

    def step(state, t):
        xt, dat, bt, ct = t
        state = state * jnp.exp(dat)[:, None, None] + \
            xt[:, :, None] * bt[:, None, :]                    # (BH, P, N)
        y = jnp.einsum("bn,bpn->bp", ct, state)
        return state, y

    init = jnp.zeros((bh, p, n), jnp.float32)
    xs = (xf.swapaxes(0, 1), daf.swapaxes(0, 1), br.swapaxes(0, 1),
          cr.swapaxes(0, 1))
    _, ys = jax.lax.scan(step, init, xs)
    return ys.swapaxes(0, 1).astype(x.dtype)


def adamw_ref(g, m, v, master, *, lr, beta1=0.9, beta2=0.95, eps=1e-8,
              weight_decay=0.0, step=1):
    gf = g.astype(jnp.float32)
    m2 = beta1 * m + (1 - beta1) * gf
    v2 = beta2 * v + (1 - beta2) * gf * gf
    mhat = m2 / (1 - beta1 ** step)
    vhat = v2 / (1 - beta2 ** step)
    upd = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * master
    return m2, v2, master - lr * upd


def softmax_xent_ref(logits, labels, vocab: int = 0):
    vp = logits.shape[-1]
    vocab = vocab or vp
    lf = logits.astype(jnp.float32)
    if vocab != vp:
        lf = lf + jnp.where(jnp.arange(vp) < vocab, 0.0, -1e30)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return lse - ll


def wkv6_ref(r, k, v, lw, u):
    """Naive WKV6 recurrence.  r, k, lw: (BH, S, K); v: (BH, S, V); u: (H, K).
        o_t = r_t (S_{t-1} + diag(u) k_t v_t^T);  S_t = diag(w_t) S_{t-1} + k_t v_t^T
    """
    bh, s, kd = r.shape
    vd = v.shape[-1]
    h = u.shape[0]
    uf = jnp.tile(u.astype(jnp.float32), (bh // h, 1))          # (BH, K)
    rf, kf, vf, lwf = (t.astype(jnp.float32) for t in (r, k, v, lw))

    def step(state, t):
        rt, kt, vt, lwt = t
        a = kt[:, :, None] * vt[:, None, :]                     # (BH, K, V)
        o = jnp.einsum("bk,bkv->bv", rt, state + uf[:, :, None] * a)
        state = state * jnp.exp(lwt)[:, :, None] + a
        return state, o

    init = jnp.zeros((bh, kd, vd), jnp.float32)
    xs = tuple(t.swapaxes(0, 1) for t in (rf, kf, vf, lwf))
    _, ys = jax.lax.scan(step, init, xs)
    return ys.swapaxes(0, 1).astype(r.dtype)
