"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels run with interpret=True — the kernel body
executes in python for correctness validation; on TPU they compile to Mosaic.
Model code calls these through ``ForwardOpts(attn_impl="pallas")`` etc.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import mamba2_ssd as _ssd
from repro.kernels import rmsnorm as _rn
from repro.kernels import rwkv6_wkv as _wkv


def _interpret() -> bool:
    return jax.default_backend() == "cpu"


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    """Model-layout wrapper.  q: (B, S, KV, G, D); k, v: (B, S, KV, D)."""
    b, s, kv, g, d = q.shape
    q2 = q.transpose(0, 2, 3, 1, 4).reshape(b * kv * g, s, d)
    k2 = k.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    v2 = v.transpose(0, 2, 1, 3).reshape(b * kv, s, d)
    o = _fa.flash_attention_bhsd(q2, k2, v2, causal=causal,
                                 block_q=min(block_q, s),
                                 block_k=min(block_k, s),
                                 interpret=_interpret())
    return o.reshape(b, kv, g, s, d).transpose(0, 3, 1, 2, 4)


@jax.jit
def paged_decode_attention(q, k_pool, v_pool, page_table, positions,
                           k_scale=None, v_scale=None):
    """Model-layout wrapper for the page-table-walking flash-decode kernel.

    q: (B, 1, KV, G, D) — one query token per slot; k/v pools:
    (P, page, KV, D); page_table: (B, M) int32; positions: (B,) int32.
    Returns (B, 1, KV, G, D).  No gathered dense KV view is materialized:
    each (slot, kv-head) program streams one physical page at a time
    (``repro.kernels.paged_decode``).  ``k_scale``/``v_scale``
    ((P, page, KV) fp32, int8 pools): dequantized in-register inside the
    kernel, scales SMEM-prefetched next to the page table."""
    from repro.kernels import paged_decode as _pd
    b, s, kv, g, d = q.shape
    assert s == 1, q.shape
    o = _pd.paged_flash_decode(q[:, 0], k_pool, v_pool, page_table,
                               positions, k_scale=k_scale, v_scale=v_scale,
                               interpret=_interpret())
    return o[:, None]


def paged_decode_partials(q, k_pool, v_pool, page_table, positions,
                          page_offset, k_scale=None, v_scale=None):
    """Per-chip partial paged decode for sharded serving
    (``repro.parallel.pagedkv``): the pool argument is one chip's
    (P/n, page, KV, D) shard, ``page_offset`` its first global page id, and
    the page table keeps GLOBAL ids — non-local pages are skipped exactly
    like dead pages.  q: (B, 1, KV, G, D).  Returns the raw fp32
    online-softmax triple ``(acc (B,1,KV,G,D), l (B,KV,G), m (B,KV,G))``
    whose cross-chip psum-style merge reconstructs the full softmax.
    ``k_scale``/``v_scale``: the chip's local (P/n, page, KV) scale shards
    (int8 pools).  Not jitted here: it only runs inside a shard_map body
    that is already staged by the engine's fused dispatch."""
    from repro.kernels import paged_decode as _pd
    b, s, kv, g, d = q.shape
    assert s == 1, q.shape
    acc, l, m = _pd.paged_flash_decode(q[:, 0], k_pool, v_pool, page_table,
                                       positions, page_offset=page_offset,
                                       k_scale=k_scale, v_scale=v_scale,
                                       partials=True, interpret=_interpret())
    return acc[:, None], l, m


@partial(jax.jit, static_argnames=("eps", "block_rows"))
def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 128):
    """x: (..., d)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    n = x2.shape[0]
    br = block_rows
    while n % br:
        br //= 2
    o = _rn.rmsnorm_rows(x2, scale, eps=eps, block_rows=max(br, 1),
                         interpret=_interpret())
    return o.reshape(shape)


@partial(jax.jit, static_argnames=("chunk",))
def mamba2_ssd(x, da, b, c, *, chunk: int = 128):
    """Model layout: x: (B, S, H, P) pre-scaled; da: (B, S, H);
    b, c: (B, S, N)."""
    bb, s, h, p = x.shape
    x2 = x.transpose(0, 2, 1, 3).reshape(bb * h, s, p)
    da2 = da.transpose(0, 2, 1).reshape(bb * h, s)
    o = _ssd.ssd_scan_bhsd(x2, da2, b, c, chunk=chunk,
                           interpret=_interpret())
    return o.reshape(bb, h, s, p).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("lr", "beta1", "beta2", "eps",
                                   "weight_decay", "step", "block"))
def adamw_fused(g, m, v, master, *, lr, beta1=0.9, beta2=0.95, eps=1e-8,
                weight_decay=0.0, step=1, block=4096):
    from repro.kernels import adamw_update as _aw
    return _aw.adamw_fused(g, m, v, master, lr=lr, beta1=beta1, beta2=beta2,
                           eps=eps, weight_decay=weight_decay, step=step,
                           block=block, interpret=_interpret())


@partial(jax.jit, static_argnames=("vocab", "block_rows"))
def softmax_xent(logits, labels, *, vocab: int = 0, block_rows: int = 8):
    from repro.kernels import softmax_xent as _sx
    return _sx.softmax_xent(logits, labels, vocab=vocab,
                            block_rows=block_rows, interpret=_interpret())


@partial(jax.jit, static_argnames=("chunk",))
def rwkv6_wkv(r, k, v, lw, u, *, chunk: int = 32):
    """Model layout: r, k, lw: (B, S, H, K); v: (B, S, H, V); u: (H, K)."""
    b, s, h, kd = r.shape
    vd = v.shape[-1]
    def fold(t, last):
        return t.transpose(0, 2, 1, 3).reshape(b * h, s, last)
    o = _wkv.wkv6_scan_bhsd(fold(r, kd), fold(k, kd), fold(v, vd),
                            fold(lw, kd), u, chunk=chunk,
                            interpret=_interpret())
    return o.reshape(b, h, s, vd).transpose(0, 2, 1, 3)
