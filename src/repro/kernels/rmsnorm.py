"""Fused RMSNorm Pallas kernel: one pass over rows resident in VMEM (the
unfused XLA form reads x twice — once for the variance, once to scale)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, scale_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * scale_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_rows(x, scale, *, eps: float = 1e-5, block_rows: int = 128,
                 interpret: bool = False):
    """x: (N, d) -> rmsnorm over the last dim, scaled."""
    n, d = x.shape
    br = min(block_rows, n)
    assert n % br == 0, (n, br)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(n // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, scale)
