"""Shared int8 symmetric-absmax quantization — one implementation for both
the compressed-gradient-sync path (`repro.parallel.compression`) and the
quantized KV page format (`repro.serve.kvcache`).

Two granularities live here:

* `quantize_int8` / `dequantize` — per-*tensor* scale with optional error
  feedback, exactly the gradient-compression contract: the residual of one
  step seeds the next so quantization noise cancels over time.
* `quantize_kv` / `dequantize_kv` — per-*row* scale over the last axis
  (head_dim), the KV-page contract: every (position, kv_head) row gets its
  own fp32 scale so a single decode token can be quantized on write without
  rescaling — and thus re-rounding — the rest of its page.

Error bound (both forms): symmetric absmax rounds to the nearest of 255
levels spanning [-absmax, absmax], so per element

    |x - dequant(quant(x))| <= scale / 2 = absmax / 254

over the scale's granule (the tensor, or the row).  Zero and denormal
rows are exact: the scale floor (1e-12 / 127) maps them to q == 0 and
dequantizes back to exactly 0.0 within fp32.  The bound is property-tested
in tests/test_quant.py including denormal/zero pages.
"""
from __future__ import annotations

import jax.numpy as jnp

#: Quantized levels span [-QMAX, QMAX]; absmax maps to +/-QMAX.
QMAX = 127.0

#: Floor on the pre-division absmax so all-zero (or denormal) granules get a
#: tiny positive scale instead of dividing by zero; q rounds to 0 and the
#: round trip is exact.
ABSMAX_FLOOR = 1e-12


def quantize_int8(x, seed_err=None):
    """Symmetric per-tensor int8 quantization with error feedback input.

    Returns (q int8, scale f32 scalar, err f32) where ``err`` is the
    residual ``x + seed_err - dequant(q)`` to be carried to the next call.
    """
    xf = x.astype(jnp.float32)
    if seed_err is not None:
        xf = xf + seed_err
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), ABSMAX_FLOOR) / QMAX
    q = jnp.clip(jnp.round(xf / scale), -QMAX, QMAX).astype(jnp.int8)
    err = xf - q.astype(jnp.float32) * scale
    return q, scale, err


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def quantize_kv(x):
    """Per-row symmetric int8 quantization over the last axis.

    ``x``: (..., D) float.  Returns ``(q, scale)`` with ``q`` int8 of the
    same shape and ``scale`` f32 of shape ``x.shape[:-1]`` — one scale per
    row, so rows (KV positions) quantize independently: decode can write a
    single token's row into an int8 page without touching its neighbours.
    """
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1), ABSMAX_FLOOR) / QMAX
    q = jnp.clip(jnp.round(xf / scale[..., None]), -QMAX, QMAX)
    return q.astype(jnp.int8), scale


def dequantize_kv(q, scale, dtype=jnp.float32):
    """Inverse of `quantize_kv`: (..., D) int8 + (...,) f32 -> (..., D)."""
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)
