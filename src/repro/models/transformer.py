"""Decoder-only transformer stack covering dense / moe / ssm / hybrid / vlm.

Layers are stacked along a leading axis and applied with ``lax.scan`` (HLO
stays O(1) in depth — required to compile 126-layer configs) with a
configurable remat policy.  Three entry points share the weights:

* ``forward``      — full-sequence (train; prefill when ``collect_cache``)
* ``decode_step``  — one token, updating the per-layer cache pytree
* ``init_cache``   — (abstract) cache construction
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba2, mlp as mlp_mod, moe as moe_mod, rwkv6
from repro.models.common import (P, apply_norm, norm_spec, set_dtypes,
                                 stack_spec)
from repro.parallel.sharding import constrain


@dataclass(frozen=True)
class ForwardOpts:
    attn_impl: str = "blockwise"     # dense | blockwise | pallas
    mixer_impl: str = "xla"          # xla | pallas  (mamba2 SSD / rwkv6 WKV)
    q_chunk: int = 1024
    kv_chunk: int = 1024
    scan_layers: bool = True
    remat: str = "selective"         # none | selective | full
    flat_heads: bool = False         # repeat-KV flat head sharding (§Perf)
    tp_shardmap: bool = False        # explicit bf16-psum TP contractions (§Perf)
    moe_ep: bool = False             # shard_map all_to_all expert parallel (§Perf)
    # the residual stream is constrained with the "seq_sp" logical axis;
    # mapping it to "model" in the rules enables Megatron-style sequence
    # parallelism (reduce-scatter/all-gather instead of all-reduce)


def _remat(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "full":
        return jax.checkpoint(fn, prevent_cse=False)
    if policy == "selective":
        from repro.parallel.tpmm import TP_SAVE_NAME
        pol = jax.checkpoint_policies.save_from_both_policies(
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            jax.checkpoint_policies.save_only_these_names(TP_SAVE_NAME))
        return jax.checkpoint(fn, policy=pol, prevent_cse=False)
    raise ValueError(policy)


# ------------------------------------------------------------------- specs ----

def layer_spec(cfg):
    fam = cfg.family
    if fam in ("dense", "vlm"):
        return {"ln1": norm_spec(cfg), "attn": attn.attention_spec(cfg),
                "ln2": norm_spec(cfg), "mlp": mlp_mod.mlp_spec(cfg)}
    if fam == "moe":
        return {"ln1": norm_spec(cfg), "attn": attn.attention_spec(cfg),
                "ln2": norm_spec(cfg), "moe": moe_mod.moe_spec(cfg)}
    if fam == "ssm":
        return {"ln1": norm_spec(cfg), "tmix": rwkv6.tmix_spec(cfg),
                "ln2": norm_spec(cfg), "cmix": rwkv6.cmix_spec(cfg)}
    if fam == "hybrid":
        return {"ln1": norm_spec(cfg), "mamba": mamba2.mamba_spec(cfg)}
    raise ValueError(fam)


def shared_block_spec(cfg):
    """Zamba2-style shared attention+FFN block (weights tied across uses)."""
    d = cfg.d_model
    return {
        "in_proj": {"kernel": P((2 * d, d), (None, "embed"))},
        "ln1": norm_spec(cfg), "attn": attn.attention_spec(cfg),
        "ln2": norm_spec(cfg), "mlp": mlp_mod.mlp_spec(cfg),
    }


def build_spec(cfg):
    d, v = cfg.d_model, cfg.padded_vocab
    spec: Dict[str, Any] = {
        "embed": {"table": P((v, d), ("vocab", "embed"), scale=0.02)},
        "layers": stack_spec(layer_spec(cfg), cfg.num_layers, "layers"),
        "final_norm": norm_spec(cfg),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = {"kernel": P((d, v), ("embed", "vocab"))}
    if cfg.family == "hybrid" and cfg.hybrid_attn_every:
        spec["shared"] = shared_block_spec(cfg)
    if cfg.family == "vlm" and cfg.num_image_tokens:
        spec["img_pos"] = P((cfg.num_image_tokens, d), ("img", "embed"),
                            init="zeros", pin_dtype=True)
    if cfg.family == "encdec":
        raise ValueError("use repro.models.encdec for encoder-decoder configs")
    return set_dtypes(spec, cfg.param_dtype)


# ------------------------------------------------------------- layer bodies ---

def _attn_layer(lp, cfg, h, opts: ForwardOpts, collect):
    a_in = apply_norm(lp["ln1"], h, cfg)
    # flat_heads repeats KV, so it is disabled when collecting the (grouped)
    # decode cache during prefill
    a, kv = attn.attention_block(lp["attn"], cfg, a_in, impl=opts.attn_impl,
                                 q_chunk=opts.q_chunk, kv_chunk=opts.kv_chunk,
                                 flat_heads=opts.flat_heads and not collect,
                                 tp_shardmap=opts.tp_shardmap)
    h = h + a
    h = constrain(h, ("batch", "seq_sp", "embed"))
    f_in = apply_norm(lp["ln2"], h, cfg)
    aux = jnp.zeros((), jnp.float32)
    if "moe" in lp:
        if opts.moe_ep:
            from repro.parallel.epmoe import moe_ffn_ep
            f, aux = moe_ffn_ep(lp["moe"], cfg, f_in)
        else:
            f, aux = moe_mod.moe_ffn(lp["moe"], cfg, f_in)
    else:
        f = mlp_mod.mlp(lp["mlp"], cfg, f_in, tp_shardmap=opts.tp_shardmap)
    h = h + f
    h = constrain(h, ("batch", "seq_sp", "embed"))
    cache = {"k": kv[0], "v": kv[1]} if collect else None
    return h, aux, cache


def _ssm_layer(lp, cfg, h, opts: ForwardOpts, collect):
    x = apply_norm(lp["ln1"], h, cfg)
    # pallas path has no final-state output; use it when no cache is collected
    impl = opts.mixer_impl if not collect else "xla"
    y, (shift1, wkv) = rwkv6.tmix_block(lp["tmix"], cfg, x, impl=impl)
    h = h + y
    x2 = apply_norm(lp["ln2"], h, cfg)
    y2, shift2 = rwkv6.cmix_block(lp["cmix"], cfg, x2)
    h = h + y2
    h = constrain(h, ("batch", "seq", "embed"))
    cache = ({"shift1": shift1, "wkv": wkv, "shift2": shift2}
             if collect else None)
    return h, jnp.zeros((), jnp.float32), cache


def _hybrid_layer(lp, cfg, h, opts: ForwardOpts, collect):
    x = apply_norm(lp["ln1"], h, cfg)
    impl = opts.mixer_impl if not collect else "xla"
    y, (conv_st, ssm_st) = mamba2.mamba_block(lp["mamba"], cfg, x, impl=impl,
                                              tp_shardmap=opts.tp_shardmap)
    h = h + y
    h = constrain(h, ("batch", "seq", "embed"))
    cache = {"conv": conv_st, "ssm": ssm_st} if collect else None
    return h, jnp.zeros((), jnp.float32), cache


def _shared_block(sp, cfg, h, emb0, opts: ForwardOpts, collect):
    dtype = h.dtype
    u = jnp.concatenate([h, emb0], axis=-1)
    u = jnp.einsum("bsd,de->bse", u, sp["in_proj"]["kernel"].astype(dtype))
    a, kv = attn.attention_block(sp["attn"], cfg, apply_norm(sp["ln1"], u, cfg),
                                 impl=opts.attn_impl, q_chunk=opts.q_chunk,
                                 kv_chunk=opts.kv_chunk,
                                 flat_heads=opts.flat_heads and not collect,
                                 tp_shardmap=opts.tp_shardmap)
    u = u + a
    u = u + mlp_mod.mlp(sp["mlp"], cfg, apply_norm(sp["ln2"], u, cfg),
                        tp_shardmap=opts.tp_shardmap)
    cache = {"k": kv[0], "v": kv[1]} if collect else None
    return h + u, cache


_LAYER_FNS = {"dense": _attn_layer, "vlm": _attn_layer, "moe": _attn_layer,
              "ssm": _ssm_layer, "hybrid": _hybrid_layer}


def _n_shared(cfg) -> int:
    if cfg.family != "hybrid" or not cfg.hybrid_attn_every:
        return 0
    return cfg.num_layers // cfg.hybrid_attn_every


# ----------------------------------------------------------------- forward ----

def embed_inputs(params, cfg, batch):
    """Token (+ image-stub) embedding.  Returns h (B, S_total, d)."""
    table = params["embed"]["table"]
    dtype = jnp.dtype(cfg.dtype)
    h = jnp.take(table, batch["tokens"], axis=0).astype(dtype)
    if cfg.family == "vlm" and cfg.num_image_tokens:
        img = batch["img_embeds"].astype(dtype)
        img = img + params["img_pos"].astype(dtype)[None]
        h = jnp.concatenate([img, h], axis=1)
    return constrain(h, ("batch", "seq", "embed"))


def unembed(params, cfg, h):
    dtype = h.dtype
    h = apply_norm(params["final_norm"], h, cfg)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", h,
                            params["embed"]["table"].astype(dtype))
    else:
        logits = jnp.einsum("bsd,dv->bsv", h,
                            params["lm_head"]["kernel"].astype(dtype))
    return constrain(logits, ("batch", "seq", "vocab"))


def forward(params, cfg, batch, opts: ForwardOpts = ForwardOpts(),
            collect_cache: bool = False):
    """Full-sequence forward.  Returns (logits, aux, cache|None)."""
    h = embed_inputs(params, cfg, batch)
    emb0 = h if cfg.family == "hybrid" and cfg.hybrid_attn_every else None
    layer_fn = _LAYER_FNS[cfg.family]
    every = cfg.hybrid_attn_every
    n_shared = _n_shared(cfg)

    def body(carry, xs):
        lp, idx = xs
        if cfg.family == "hybrid" and every:
            h, shared_cache = carry
            h, aux, cache = layer_fn(lp, cfg, h, opts, collect_cache)

            def fire(args):
                h, sc = args
                h2, blk_cache = _shared_block(params["shared"], cfg, h, emb0,
                                              opts, collect_cache)
                if collect_cache:
                    inv = idx // every
                    sc = {
                        "k": jax.lax.dynamic_update_index_in_dim(
                            sc["k"], blk_cache["k"], inv, 0),
                        "v": jax.lax.dynamic_update_index_in_dim(
                            sc["v"], blk_cache["v"], inv, 0),
                    }
                return h2, sc

            if isinstance(idx, int):
                # unrolled layers: static branch — no lax.cond, which would
                # copy the whole shared cache through both branches every
                # layer (observed 1 TB/step bytes on zamba decode; §Perf)
                if (idx % every) == every - 1:
                    h, shared_cache = fire((h, shared_cache))
            else:
                h, shared_cache = jax.lax.cond(
                    (idx % every) == every - 1, fire, lambda a: a,
                    (h, shared_cache))
            return (h, shared_cache), (aux, cache)
        h = carry
        h, aux, cache = layer_fn(lp, cfg, h, opts, collect_cache)
        return h, (aux, cache)

    body = _remat(body, opts.remat)
    idxs = jnp.arange(cfg.num_layers)

    if cfg.family == "hybrid" and every:
        b, s = h.shape[0], h.shape[1]
        sc0 = None
        if collect_cache:
            kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim
            sc0 = {"k": jnp.zeros((n_shared, b, s, kvh, hd), h.dtype),
                   "v": jnp.zeros((n_shared, b, s, kvh, hd), h.dtype)}
        init = (h, sc0)
    else:
        init = h

    if opts.scan_layers:
        carry, (auxs, caches) = jax.lax.scan(body, init,
                                             (params["layers"], idxs))
    else:
        auxs, caches = [], []
        carry = init
        for i in range(cfg.num_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            carry, (aux, cache) = body(carry, (lp, i))
            auxs.append(aux)
            caches.append(cache)
        auxs = jnp.stack(auxs)
        caches = (jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
                  if collect_cache else None)

    if cfg.family == "hybrid" and every:
        h, shared_cache = carry
    else:
        h, shared_cache = carry, None

    logits = unembed(params, cfg, h)
    aux = {"moe_aux": jnp.sum(auxs)}
    cache = None
    if collect_cache:
        cache = {"layers": caches}
        if shared_cache is not None:
            cache["shared"] = shared_cache
    return logits, aux, cache


# ------------------------------------------------------------------ decode ----

def init_cache(cfg, batch_size: int, max_seq: int, dtype=jnp.bfloat16,
               abstract: bool = False):
    """Cache pytree for decode.  abstract=True -> ShapeDtypeStructs (dry-run)."""
    L, b, s = cfg.num_layers, batch_size, max_seq
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def mk(shape, dt=dtype):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)

    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        layers = {"k": mk((L, b, s, kvh, hd)), "v": mk((L, b, s, kvh, hd))}
    elif fam == "ssm":
        d, h_, kd = cfg.d_model, cfg.rwkv_heads, cfg.rwkv_head_dim
        layers = {"shift1": mk((L, b, 1, d)),
                  "wkv": mk((L, b, h_, kd, kd), jnp.float32),
                  "shift2": mk((L, b, 1, d))}
    elif fam == "hybrid":
        din, n, hn, pd = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                          cfg.ssm_head_dim)
        w = cfg.ssm_conv_dim
        layers = {"conv": mk((L, b, w - 1, din + 2 * n)),
                  "ssm": mk((L, b, hn, pd, n), jnp.float32)}
    else:
        raise ValueError(fam)
    cache = {"layers": layers}
    if fam == "hybrid" and cfg.hybrid_attn_every:
        ns = _n_shared(cfg)
        cache["shared"] = {"k": mk((ns, b, s, kvh, hd)),
                           "v": mk((ns, b, s, kvh, hd))}
    return cache


def cache_logical_axes(cfg, cache):
    """Logical axes for the cache pytree (for dry-run shardings)."""
    ax = {
        "k": ("layers", "batch", "kv_seq", "kv_heads", None),
        "v": ("layers", "batch", "kv_seq", "kv_heads", None),
        "shift1": ("layers", "batch", None, "embed"),
        "shift2": ("layers", "batch", None, "embed"),
        "wkv": ("layers", "batch", "rwkv_heads", None, None),
        "conv": ("layers", "batch", "conv", "mamba_inner"),
        "ssm": ("layers", "batch", "mamba_heads", None, "state"),
    }
    return jax.tree.map_with_path(
        lambda path, leaf: ax[path[-1].key if hasattr(path[-1], "key") else
                              path[-1]], cache)


def _scan_or_unroll(body, init, xs, n: int, scan: bool):
    """lax.scan or a python-unrolled equivalent (the dry-run cost calibration
    needs unrolled bodies: XLA cost analysis counts while bodies once).

    In the unrolled path, leaves that are the layer-index iota (detected as
    1-D int arrays equal to arange(n)) are replaced by the *python* index so
    bodies can resolve layer-pattern branches statically."""
    if scan:
        return jax.lax.scan(body, init, xs)
    import numpy as _np
    iota = _np.arange(n)

    def slice_leaf(a, i):
        # numpy layer-index iota -> python int (static branch resolution)
        if isinstance(a, _np.ndarray) and a.ndim == 1 and \
                a.dtype.kind == "i" and a.shape[0] == n and \
                bool((a == iota).all()):
            return i
        return a[i]

    carry = init
    ys = []
    for i in range(n):
        carry, y = body(carry, jax.tree.map(lambda a: slice_leaf(a, i), xs))
        ys.append(y)
    stacked = (jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
               if ys and ys[0] is not None else None)
    return carry, stacked


def decode_step(params, cfg, tokens, cache, cache_index, img_embeds=None,
                scan_layers: bool = True, decode_impl: str = "gather",
                mesh=None, kv_axis: str = "model", dp_axis=None):
    """One-token decode.  tokens: (B, 1).  Returns (logits, new_cache).

    ``cache_index`` is a scalar (all sequences at the same depth) or a (B,)
    per-slot position vector — the ragged continuous-batching path, where
    every slot scatter-writes and masks at its own position in one call.
    Recurrent families (ssm / hybrid mixer state) are position-free; only
    their attention sub-blocks consume the index.

    ``cache`` is either the dense pytree from ``init_cache`` (per-layer
    (B,Smax,KV,D) rows) or a paged state — per-layer (P,page,KV,D) physical
    pools plus a ``page_table`` (B, M) int32 entry (built by
    ``repro.serve.kvcache.PagedCache``); attention then scatter-writes and
    resolves reads through the page-table indirection — by XLA gather
    (``decode_impl="gather"``, the default) or by the page-table-walking
    Pallas flash kernel (``decode_impl="pallas"``,
    ``repro.kernels.paged_decode``).  The returned pytree keeps the same
    structure (the page table passes through unchanged — it is
    host-managed).

    ``mesh`` (paged caches only): the pools are ``kv_pages``-sharded P/n
    along ``kv_axis`` and each layer's scatter+attention runs under
    shard_map with a cross-chip partial-softmax merge
    (``repro.parallel.pagedkv``)."""
    del img_embeds  # image tokens only participate via the prefill cache
    page_table = cache.get("page_table") if isinstance(cache, dict) else None
    assert decode_impl in ("gather", "pallas"), decode_impl
    if page_table is not None:
        assert cfg.family in ("dense", "vlm", "moe"), (
            "paged KV decode is attention-cache families only; recurrent "
            f"state has no page structure (family={cfg.family})")
    assert mesh is None or page_table is not None, (
        "a decode mesh shards the paged pool's kv_pages dim; the dense "
        "cache layout has no page dim to shard (use the paged backend)")
    dtype = jnp.dtype(cfg.dtype)
    h = jnp.take(params["embed"]["table"], tokens, axis=0).astype(dtype)
    h = constrain(h, ("batch", None, "embed"))
    emb0 = h if cfg.family == "hybrid" and cfg.hybrid_attn_every else None
    every = cfg.hybrid_attn_every
    fam = cfg.family

    def body(carry, xs):
        lp, layer_cache, idx = xs
        if fam == "hybrid" and every:
            h, shared_kv = carry
        else:
            h = carry

        if fam in ("dense", "vlm", "moe"):
            a_in = apply_norm(lp["ln1"], h, cfg)
            if "k_scale" in layer_cache:
                # int8 paged pools: quantize-on-write + dequant-on-read
                # inside the attention block; scales ride the cache pytree
                a, nk, nv, nks, nvs = attn.attention_decode_block(
                    lp["attn"], cfg, a_in, layer_cache["k"],
                    layer_cache["v"], cache_index, page_table=page_table,
                    decode_impl=decode_impl, mesh=mesh, kv_axis=kv_axis,
                    dp_axis=dp_axis,
                    k_scale=layer_cache["k_scale"],
                    v_scale=layer_cache["v_scale"])
                new_cache = {"k": nk, "v": nv,
                             "k_scale": nks, "v_scale": nvs}
            else:
                a, nk, nv = attn.attention_decode_block(
                    lp["attn"], cfg, a_in, layer_cache["k"],
                    layer_cache["v"], cache_index, page_table=page_table,
                    decode_impl=decode_impl, mesh=mesh, kv_axis=kv_axis,
                    dp_axis=dp_axis)
                new_cache = {"k": nk, "v": nv}
            h = h + a
            f_in = apply_norm(lp["ln2"], h, cfg)
            if "moe" in lp:
                f, _ = moe_mod.moe_ffn_decode(lp["moe"], cfg, f_in)
            else:
                f = mlp_mod.mlp(lp["mlp"], cfg, f_in)
            h = h + f
        elif fam == "ssm":
            x = apply_norm(lp["ln1"], h, cfg)
            y, (s1, wkv) = rwkv6.tmix_block(lp["tmix"], cfg, x,
                                            shift_state=layer_cache["shift1"],
                                            wkv_state=layer_cache["wkv"],
                                            decode=True)
            h = h + y
            x2 = apply_norm(lp["ln2"], h, cfg)
            y2, s2 = rwkv6.cmix_block(lp["cmix"], cfg, x2,
                                      shift_state=layer_cache["shift2"])
            h = h + y2
            new_cache = {"shift1": s1, "wkv": wkv, "shift2": s2}
        elif fam == "hybrid":
            x = apply_norm(lp["ln1"], h, cfg)
            y, (cst, sst) = mamba2.mamba_block(lp["mamba"], cfg, x,
                                               conv_state=layer_cache["conv"],
                                               ssm_state=layer_cache["ssm"],
                                               decode=True)
            h = h + y

            def fire(args):
                h, skv = args
                inv = idx // every
                dtype = h.dtype
                u = jnp.concatenate([h, emb0], axis=-1)
                sp = params["shared"]
                u = jnp.einsum("bsd,de->bse", u,
                               sp["in_proj"]["kernel"].astype(dtype))
                a_in = apply_norm(sp["ln1"], u, cfg)
                a, nk, nv = attn.attention_decode_block(
                    sp["attn"], cfg, a_in, skv["k"][inv], skv["v"][inv],
                    cache_index)
                u = u + a
                u = u + mlp_mod.mlp(sp["mlp"], cfg,
                                    apply_norm(sp["ln2"], u, cfg))
                skv = {"k": jax.lax.dynamic_update_index_in_dim(
                           skv["k"], nk, inv, 0),
                       "v": jax.lax.dynamic_update_index_in_dim(
                           skv["v"], nv, inv, 0)}
                return h + u, skv

            if isinstance(idx, int):
                # unrolled: static branch avoids lax.cond's both-branch copy
                # of the whole shared cache per layer (§Perf zamba decode)
                if (idx % every) == every - 1:
                    h, shared_kv = fire((h, shared_kv))
            else:
                h, shared_kv = jax.lax.cond((idx % every) == every - 1,
                                            fire, lambda a: a, (h, shared_kv))
            new_cache = {"conv": cst, "ssm": sst}
        else:
            raise ValueError(fam)

        if fam == "hybrid" and every:
            return (h, shared_kv), new_cache
        return h, new_cache

    import numpy as _np
    idxs = _np.arange(cfg.num_layers)   # numpy: stays concrete under jit
    if fam == "hybrid" and every:
        init = (h, cache["shared"])
        (h, shared_kv), new_layers = _scan_or_unroll(
            body, init, (params["layers"], cache["layers"], idxs),
            cfg.num_layers, scan_layers)
        new_cache = {"layers": new_layers, "shared": shared_kv}
    else:
        h, new_layers = _scan_or_unroll(
            body, h, (params["layers"], cache["layers"], idxs),
            cfg.num_layers, scan_layers)
        new_cache = {"layers": new_layers}
    if page_table is not None:
        new_cache["page_table"] = page_table   # host-managed, pass-through

    logits = unembed(params, cfg, h)
    return logits, new_cache


def prefill_chunk(params, cfg, tokens, cache, start_pos, dest, last_pos,
                  scan_layers: bool = True, mesh=None,
                  kv_axis: str = "model", dp_axis=None):
    """Chunked prefill with prior cache: forward a (B, C) chunk of prompt
    tokens at global position offset ``start_pos`` through the stack; each
    layer scatter-writes the chunk's K/V into the paged pools at ``dest``
    and attends causally over the cache written by chunks ``0..k-1`` plus
    the chunk itself (``attention.attention_prefill_chunk_block``).

    ``cache`` is a paged decode-view pytree: per-layer (P,page,KV,D) pools
    under ``"layers"`` plus a ``"page_table"`` (B, M) entry holding the
    slots' REAL table rows.  ``last_pos`` (B,) is the last valid global
    position in the chunk (padding past it is masked and scratch-routed).

    Returns (last_logits (B, 1, V), new_cache): only the hidden row at
    ``last_pos`` is unembedded — the single row chunked prefill consumes
    (first-token sampling on the final chunk) — so a chunk pays one vocab
    projection, not C.  Dense-FFN attention-cache families only: recurrent
    state has no position-indexed cache to chunk into, and MoE capacity
    routing (``moe_ffn``'s per-sequence token dropping) depends on the
    forwarded group shape, so chunk-at-a-time routing would diverge from
    the whole prompt's.

    ``mesh``/``kv_axis``/``dp_axis``: with a device mesh, each layer's
    chunk scatter + attention runs under the same shard_map primitive as
    decode (``repro.parallel.pagedkv.sharded_prefill_chunk_attention``) —
    pools stay ``kv_pages``-sharded P/n, writes are per-chip
    ``mode="drop"`` local scatters, and the partial-softmax merge psums
    over ``kv_axis`` only (per-DP-replica on 2-D meshes)."""
    assert cfg.family in ("dense", "vlm"), (
        "chunked prefill is dense-FFN attention-cache families only "
        f"(family={cfg.family})")
    page_table = cache["page_table"]
    dtype = jnp.dtype(cfg.dtype)
    h = jnp.take(params["embed"]["table"], tokens, axis=0).astype(dtype)
    h = constrain(h, ("batch", None, "embed"))

    def body(h, xs):
        lp, layer_cache = xs
        a_in = apply_norm(lp["ln1"], h, cfg)
        if "k_scale" in layer_cache:
            a, nk, nv, nks, nvs = attn.attention_prefill_chunk_block(
                lp["attn"], cfg, a_in, layer_cache["k"], layer_cache["v"],
                start_pos, dest, page_table, last_pos,
                k_scale=layer_cache["k_scale"],
                v_scale=layer_cache["v_scale"],
                mesh=mesh, kv_axis=kv_axis, dp_axis=dp_axis)
            new_cache = {"k": nk, "v": nv, "k_scale": nks, "v_scale": nvs}
        else:
            a, nk, nv = attn.attention_prefill_chunk_block(
                lp["attn"], cfg, a_in, layer_cache["k"], layer_cache["v"],
                start_pos, dest, page_table, last_pos,
                mesh=mesh, kv_axis=kv_axis, dp_axis=dp_axis)
            new_cache = {"k": nk, "v": nv}
        h = h + a
        f_in = apply_norm(lp["ln2"], h, cfg)
        h = h + mlp_mod.mlp(lp["mlp"], cfg, f_in)
        return h, new_cache

    h, new_layers = _scan_or_unroll(
        body, h, (params["layers"], cache["layers"]), cfg.num_layers,
        scan_layers)
    # slice the one consumed row before unembedding
    take = (last_pos - start_pos).astype(jnp.int32)               # (B,)
    h_last = jnp.take_along_axis(h, take[:, None, None], axis=1)  # (B,1,d)
    logits = unembed(params, cfg, h_last)
    return logits, {"layers": new_layers, "page_table": page_table}
