"""RWKV6 ("Finch") block: token-shift with data-dependent mixing, WKV6
recurrence with per-channel data-dependent decay, and channel-mix FFN.

Train/prefill uses a chunked-parallel linear-attention form.  Stability: with
the per-step log-decay floored at ``LOG_DECAY_FLOOR`` (documented deviation,
applied identically in every path incl. the ref oracle and decode so all paths
agree), the intra-chunk rank-1 exponent split around the chunk midpoint is
exact in f32 for chunk length 32 (|exponent| <= 40 by construction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import P, layer_norm
from repro.parallel.sharding import constrain

LORA_MIX = 32
LORA_DECAY = 64
LOG_DECAY_FLOOR = -2.5     # per-step log-decay floor (w >= e^-2.5 ≈ 0.082)
CHUNK = 32


def tmix_spec(cfg):
    d = cfg.d_model
    h, k = cfg.rwkv_heads, cfg.rwkv_head_dim
    return {
        "mu_x": P((d,), ("embed",), init="zeros", pin_dtype=True),
        "mu": P((5, d), (None, "embed"), init="zeros", pin_dtype=True),
        "W1": {"kernel": P((d, 5 * LORA_MIX), ("embed", "lora"))},
        "W2": {"kernel": P((5, LORA_MIX, d), (None, "lora", "embed"))},
        "wr": {"kernel": P((d, d), ("embed", "rwkv_heads"))},
        "wk": {"kernel": P((d, d), ("embed", "rwkv_heads"))},
        "wv": {"kernel": P((d, d), ("embed", "rwkv_heads"))},
        "wg": {"kernel": P((d, d), ("embed", "rwkv_heads"))},
        "w0": P((d,), ("embed",), init="rwkv_decay", pin_dtype=True),
        "wA": {"kernel": P((d, LORA_DECAY), ("embed", "lora"))},
        "wB": {"kernel": P((LORA_DECAY, d), ("lora", "embed"))},
        "u": P((h, k), ("rwkv_heads", None), init="normal", scale=0.3,
               pin_dtype=True),
        "ln_x": {"scale": P((d,), ("embed",), init="ones", pin_dtype=True),
                 "bias": P((d,), ("embed",), init="zeros", pin_dtype=True)},
        "wo": {"kernel": P((d, d), ("rwkv_heads", "embed"))},
    }


def cmix_spec(cfg):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "mu_k": P((d,), ("embed",), init="zeros", pin_dtype=True),
        "mu_r": P((d,), ("embed",), init="zeros", pin_dtype=True),
        "wk": {"kernel": P((d, f), ("embed", "mlp"))},
        "wv": {"kernel": P((f, d), ("mlp", "embed"))},
        "wr": {"kernel": P((d, d), ("embed", "rwkv_heads"))},
    }


def _shift(x, shift_state=None):
    """Previous-token embedding; shift_state (B,1,d) seeds t=0 (decode)."""
    pad = shift_state if shift_state is not None else jnp.zeros(
        (x.shape[0], 1, x.shape[2]), x.dtype)
    return jnp.concatenate([pad.astype(x.dtype), x[:, :-1, :]], axis=1)


# ------------------------------------------------------------ wkv recurrence --

def wkv6_chunked(r, k, v, lw, u, chunk: int = CHUNK, initial_state=None,
                 return_final: bool = False):
    """r,k,lw: (B,S,H,K); v: (B,S,H,V); u: (H,K); lw = log decay (<= 0,
    floored).  Recurrence: S_t = diag(w_t) S_{t-1} + k_t v_t^T;
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)."""
    b, s, h, kd = r.shape
    vd = v.shape[-1]
    chunk = min(chunk, s)
    s_orig = s
    if s % chunk:
        # pad with no-op steps (lw=0 -> decay 1, k=0 -> no state update)
        pad = chunk - s % chunk
        r, k, v = (jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
                   for t in (r, k, v))
        lw = jnp.pad(lw, ((0, 0), (0, pad), (0, 0), (0, 0)))
        s = s + pad
    nc = s // chunk
    rc = r.reshape(b, nc, chunk, h, kd)
    kc = k.reshape(b, nc, chunk, h, kd)
    vc = v.reshape(b, nc, chunk, h, vd)
    lwc = lw.astype(jnp.float32).reshape(b, nc, chunk, h, kd)

    cs = jnp.cumsum(lwc, axis=2)                  # inclusive: sum_{l<=i} lw_l
    ce = cs - lwc                                 # exclusive: sum_{l<i} lw_l
    mid = cs[:, :, -1:, :, :] * 0.5               # per-chunk midpoint M
    # rank-1 split: exp(ce_i - cs_j) = exp(ce_i - M) * exp(M - cs_j)
    qf = rc.astype(jnp.float32) * jnp.exp(jnp.clip(ce - mid, -40.0, 40.0))
    kf = kc.astype(jnp.float32) * jnp.exp(jnp.clip(mid - cs, -40.0, 40.0))
    A = jnp.einsum("bcihk,bcjhk->bchij", qf, kf)  # strictly-lower part valid
    tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
    A = jnp.where(tri[None, None, None], A, 0.0)
    # diagonal bonus: o_i += r_i (u ⊙ k_i) v_i
    diag = jnp.einsum("bcihk,hk,bcihk->bcih", rc.astype(jnp.float32),
                      u.astype(jnp.float32), kc.astype(jnp.float32))
    Yintra = jnp.einsum("bchij,bcjhv->bcihv", A, vc.astype(jnp.float32))
    Yintra = Yintra + diag[..., None] * vc.astype(jnp.float32)

    # chunk summary: S_end = diag(exp(cs_end)) S_start + sum_j diag(exp(cs_end - cs_j)) k_j v_j
    dte = jnp.exp(cs[:, :, -1:, :, :] - cs)       # decay j -> chunk end, <= 1
    chunk_kv = jnp.einsum("bcjhk,bcjhv->bchkv", (kc.astype(jnp.float32) * dte),
                          vc.astype(jnp.float32))
    chunk_decay = jnp.exp(cs[:, :, -1, :, :])     # (b,nc,h,k)

    init = (initial_state if initial_state is not None
            else jnp.zeros((b, h, kd, vd), jnp.float32))

    def step(sprev, inp):
        ckv, dec = inp
        snew = sprev * dec[..., None] + ckv
        return snew, sprev

    xs = (chunk_kv.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    s_final, s_starts = jax.lax.scan(step, init, xs)
    s_starts = s_starts.swapaxes(0, 1)            # (b,nc,h,k,v)

    # inter-chunk: o_i += (r_i ⊙ exp(ce_i)) · S_start
    Yinter = jnp.einsum("bcihk,bchkv->bcihv",
                        rc.astype(jnp.float32) * jnp.exp(ce), s_starts)
    Y = (Yintra + Yinter).reshape(b, s, h, vd)[:, :s_orig]
    if return_final:
        return Y, s_final
    return Y


def wkv6_decode(r, k, v, lw, u, state):
    """Single step.  r,k,lw: (B,H,K); v: (B,H,V); state: (B,H,K,V)."""
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    a = kf[..., :, None] * vf[..., None, :]            # (B,H,K,V)
    out = jnp.einsum("bhk,bhkv->bhv", rf, state + u.astype(jnp.float32)[None, :, :, None] * a)
    new_state = state * jnp.exp(lw.astype(jnp.float32))[..., None] + a
    return out, new_state


# ------------------------------------------------------------------ blocks ----

def _mix_inputs(p, x, sx):
    """Data-dependent token-shift mixing -> per-target inputs [r,w,k,v,g]."""
    dtype = x.dtype
    xxx = x + sx * p["mu_x"].astype(dtype)
    m = jnp.tanh(jnp.einsum("bsd,dl->bsl", xxx, p["W1"]["kernel"].astype(dtype)))
    m = m.reshape(*m.shape[:-1], 5, LORA_MIX)
    m = jnp.einsum("bstl,tld->bstd", m, p["W2"]["kernel"].astype(dtype))
    mix = p["mu"].astype(dtype)[None, None] + m        # (B,S,5,d)
    return [x + sx * mix[:, :, t] for t in range(5)]


def tmix_block(p, cfg, x, *, shift_state=None, wkv_state=None,
               decode: bool = False, impl: str = "xla"):
    """Returns (y, (new_shift_state, new_wkv_state))."""
    b, s, d = x.shape
    h, kd = cfg.rwkv_heads, cfg.rwkv_head_dim
    dtype = x.dtype
    sx = _shift(x, shift_state) - x
    xr, xw, xk, xv, xg = _mix_inputs(p, x, sx)

    r = jnp.einsum("bsd,de->bse", xr, p["wr"]["kernel"].astype(dtype))
    kk = jnp.einsum("bsd,de->bse", xk, p["wk"]["kernel"].astype(dtype))
    vv = jnp.einsum("bsd,de->bse", xv, p["wv"]["kernel"].astype(dtype))
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"]["kernel"].astype(dtype)))

    dec_in = (p["w0"].astype(jnp.float32)
              + jnp.einsum("bsd,dl->bsl", xw, p["wA"]["kernel"].astype(dtype))
                   .astype(jnp.float32)
              @ p["wB"]["kernel"].astype(jnp.float32))
    # log decay, floored (see module docstring)
    lw = -jnp.exp(jnp.clip(dec_in, -12.0, 0.0))
    lw = jnp.maximum(lw, LOG_DECAY_FLOOR)

    rh = r.reshape(b, s, h, kd)
    kh = kk.reshape(b, s, h, kd)
    vh = vv.reshape(b, s, h, kd)
    lwh = lw.reshape(b, s, h, kd)
    rh = constrain(rh, ("batch", "seq", "rwkv_heads", None))

    new_shift = x[:, -1:, :]
    if decode:
        assert s == 1
        st = wkv_state if wkv_state is not None else jnp.zeros(
            (b, h, kd, kd), jnp.float32)
        out, new_state = wkv6_decode(rh[:, 0], kh[:, 0], vh[:, 0], lwh[:, 0],
                                     p["u"], st)
        out = out[:, None]
    elif impl == "pallas" and wkv_state is None:
        from repro.kernels import ops as kops
        out = kops.rwkv6_wkv(rh, kh, vh, lwh, p["u"], chunk=CHUNK)
        new_state = jnp.zeros((b, h, kd, kd), jnp.float32)
    else:
        out, new_state = wkv6_chunked(rh, kh, vh, lwh, p["u"],
                                      initial_state=wkv_state,
                                      return_final=True)

    out = out.reshape(b, s, d).astype(dtype)
    # per-head group norm (ln_x)
    out = out.reshape(b, s, h, kd)
    mu = out.mean(-1, keepdims=True)
    var = jnp.var(out.astype(jnp.float32), axis=-1, keepdims=True)
    out = ((out - mu) * jax.lax.rsqrt(var + 64e-5).astype(dtype)).reshape(b, s, d)
    out = out * p["ln_x"]["scale"].astype(dtype) + p["ln_x"]["bias"].astype(dtype)
    out = out * g
    y = jnp.einsum("bse,ed->bsd", out, p["wo"]["kernel"].astype(dtype))
    return y, (new_shift, new_state)


def cmix_block(p, cfg, x, *, shift_state=None):
    dtype = x.dtype
    sx = _shift(x, shift_state) - x
    xk = x + sx * p["mu_k"].astype(dtype)
    xr = x + sx * p["mu_r"].astype(dtype)
    kk = jnp.einsum("bsd,df->bsf", xk, p["wk"]["kernel"].astype(dtype))
    kk = jnp.square(jax.nn.relu(kk))
    vv = jnp.einsum("bsf,fd->bsd", kk, p["wv"]["kernel"].astype(dtype))
    rr = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr,
                                   p["wr"]["kernel"].astype(dtype)))
    return rr * vv, x[:, -1:, :]
