"""Functional module system: param specs, initializers, logical axes, shared ops.

Every model builds a *spec tree* (nested dicts of ``P`` leaves).  ``init_params``
materializes arrays; ``logical_axes`` extracts the parallel tree of logical axis
name tuples consumed by ``repro.parallel.sharding``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ----------------------------------------------------------------- param spec --


@dataclass(frozen=True)
class P:
    """A parameter spec leaf: shape + logical axes + initializer."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones | uniform_conv | constant
    scale: float = 0.02
    dtype: str = "float32"
    pin_dtype: bool = False   # keep f32 under set_dtypes (norms, A_log, dt_bias…)

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def set_dtypes(spec, dtype: str):
    """Cast every non-pinned leaf spec to ``dtype`` (e.g. bf16 compute weights)."""
    return spec_tree_map(
        lambda p: p if p.pin_dtype else dataclasses.replace(p, dtype=dtype), spec)


def is_spec_leaf(x) -> bool:
    return isinstance(x, P)


def spec_tree_map(fn, spec):
    return jax.tree.map(fn, spec, is_leaf=is_spec_leaf)


def _init_leaf(key, p: P):
    dtype = jnp.dtype(p.dtype)
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "normal":
        return (p.scale * jax.random.normal(key, p.shape)).astype(dtype)
    if p.init == "uniform_conv":  # depthwise conv kernels
        fan = max(int(np.prod(p.shape[:-1])), 1)
        bound = 1.0 / np.sqrt(fan)
        return jax.random.uniform(key, p.shape, dtype, -bound, bound)
    if p.init == "a_log":        # mamba2: A ~ U[1, 16], store log A
        a = jax.random.uniform(key, p.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(a).astype(dtype)
    if p.init == "dt_bias":      # mamba2: dt ~ exp(U[log 1e-3, log 0.1]); inv-softplus
        dt = jnp.exp(jax.random.uniform(key, p.shape, jnp.float32,
                                        np.log(1e-3), np.log(0.1)))
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    if p.init == "rwkv_decay":   # rwkv6: per-channel decay speed ramp
        n = p.shape[-1]
        ramp = (np.arange(n) / max(n - 1, 1)) ** 0.9
        return jnp.broadcast_to(jnp.asarray(-6.0 + 5.0 * ramp, jnp.float32),
                                p.shape).astype(dtype)
    raise ValueError(p.init)


def init_params(key, spec):
    """Materialize a spec tree into an array pytree (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree.flatten(spec, is_leaf=is_spec_leaf)
    keys = jax.random.split(key, len(leaves))
    arrays = [_init_leaf(k, p) for k, p in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, arrays)


def abstract_params(spec):
    """ShapeDtypeStruct tree matching ``init_params`` (no allocation; dry-run)."""
    return spec_tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, jnp.dtype(p.dtype)), spec)


def logical_axes(spec):
    return spec_tree_map(lambda p: p.axes, spec)


def stack_spec(spec, n: int, axis_name: Optional[str] = None):
    """Add a leading stacked-layers dim to every leaf (for scan-over-layers)."""
    return spec_tree_map(
        lambda p: dataclasses.replace(p, shape=(n,) + p.shape,
                                      axes=(axis_name,) + p.axes), spec)


def param_count_tree(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


# ------------------------------------------------------------------- numerics --

def cast(x, dtype):
    return x.astype(dtype) if x.dtype != jnp.dtype(dtype) else x


def rms_norm(x, scale, eps: float, dtype=None):
    dtype = dtype or x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32)).astype(dtype)


def layer_norm(x, scale, bias, eps: float, dtype=None):
    dtype = dtype or x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dtype)


def norm_spec(cfg, d: Optional[int] = None):
    d = d or cfg.d_model
    if cfg.norm == "rmsnorm":
        return {"scale": P((d,), ("norm",), init="ones")}
    return {"scale": P((d,), ("norm",), init="ones"),
            "bias": P((d,), ("norm",), init="zeros")}


def apply_norm(p, x, cfg, dtype=None):
    if "bias" in p:
        return layer_norm(x, p["scale"], p["bias"], cfg.norm_eps, dtype)
    return rms_norm(x, p["scale"], cfg.norm_eps, dtype)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------- RoPE --

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d, theta), jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    angles = angles[..., None, :]                               # head dim
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ------------------------------------------------------------------- losses --

def cross_entropy(logits, labels, vocab_size: int, z_loss: float = 0.0,
                  mask=None):
    """CE over a (possibly vocab-padded) logits tensor; labels < vocab_size.

    Returns (loss, aux) with aux containing z-loss and accuracy terms.
    """
    padded = logits.shape[-1]
    lf = logits.astype(jnp.float32)
    if padded != vocab_size:
        # Mask the padded vocab tail with a broadcast add (cheap, fusable).
        pad_mask = jnp.where(jnp.arange(padded) < vocab_size, 0.0, -1e9)
        lf = lf + pad_mask
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    zl = z_loss * jnp.square(lse)
    per_tok = nll + zl
    if mask is not None:
        per_tok = per_tok * mask
        denom = jnp.maximum(mask.sum(), 1.0)
    else:
        denom = float(np.prod(labels.shape))
    loss = per_tok.sum() / denom
    aux = {"nll": nll.sum() / denom, "z_loss": zl.sum() / denom}
    return loss, aux


# ------------------------------------------------------------------ helpers --

def dense_spec(d_in: int, d_out: int, axes, use_bias: bool, scale: float = 0.02,
               shape=None, init: str = "normal"):
    shape = shape or (d_in, d_out)
    spec = {"kernel": P(shape, axes, init=init, scale=scale)}
    if use_bias:
        # bias covers every output dim (all but the contracted first dim)
        spec["bias"] = P(shape[1:], axes[1:], init="zeros")
    return spec


def dense(p, x, contracting: str = "d", dtype=None):
    """x @ kernel with arbitrary kernel rank; contraction over first kernel dim."""
    dtype = dtype or x.dtype
    k = cast(p["kernel"], dtype)
    ndim_out = k.ndim - 1
    out_str = "".join(chr(ord("m") + i) for i in range(ndim_out))
    y = jnp.einsum(f"...d,d{out_str}->...{out_str}", x, k)
    if "bias" in p:
        y = y + cast(p["bias"], dtype)
    return y
