"""Dense FFN: SwiGLU (silu) or classic 2-matmul (gelu) — matches cfg.act."""
from __future__ import annotations

import math

import jax.numpy as jnp

from repro.models.common import P, activation
from repro.parallel.sharding import constrain


def mlp_spec(cfg, d_ff: int = 0):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    depth_scale = 0.02 / math.sqrt(2 * max(cfg.num_layers, 1))
    spec = {
        "wi": {"kernel": P((d, f), ("embed", "mlp"))},
        "wo": {"kernel": P((f, d), ("mlp", "embed"), scale=depth_scale)},
    }
    if cfg.act == "silu":
        spec["wg"] = {"kernel": P((d, f), ("embed", "mlp"))}
    if cfg.use_bias:
        spec["wi"]["bias"] = P((f,), ("mlp",), init="zeros")
        spec["wo"]["bias"] = P((d,), ("embed",), init="zeros")
        if "wg" in spec:
            spec["wg"]["bias"] = P((f,), ("mlp",), init="zeros")
    return spec


def mlp(p, cfg, x, tp_shardmap: bool = False):
    dtype = x.dtype
    act = activation(cfg.act)
    if tp_shardmap:
        from repro.parallel.tpmm import col_proj_tp
        up = lambda q: col_proj_tp(x, q["kernel"], q.get("bias"))
    else:
        def up(q):
            y = jnp.einsum("bsd,df->bsf", x, q["kernel"].astype(dtype))
            return y + q["bias"].astype(dtype) if "bias" in q else y
    h = up(p["wi"])
    if "wg" in p:
        h = act(up(p["wg"])) * h
    else:
        h = act(h)
    h = constrain(h, ("batch", "seq", "mlp"))
    if tp_shardmap:
        from repro.parallel.tpmm import down_proj_tp
        return down_proj_tp(h, p["wo"]["kernel"], p["wo"].get("bias"))
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"]["kernel"].astype(dtype))
    if "bias" in p["wo"]:
        y = y + p["wo"]["bias"].astype(dtype)
    return y
