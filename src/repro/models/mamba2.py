"""Mamba2 block (state-space duality / SSD) — chunked-parallel train form +
exact recurrent decode.

Train/prefill uses the SSD chunked algorithm (Dao & Gu 2024): intra-chunk
attention-like matmuls (MXU-friendly) + an inter-chunk ``lax.scan`` over the
running (H, P, N) state.  All decay terms are computed as exp of non-positive
cumulative sums — numerically stable in f32.

Decode carries (conv_state (B, w-1, din+2N), ssm_state (B, H, P, N)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import P, rms_norm
from repro.parallel.sharding import constrain


def mamba_spec(cfg):
    d, din, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.ssm_conv_dim
    return {
        "wz": {"kernel": P((d, din), ("embed", "mamba_inner"))},
        "wx": {"kernel": P((d, din), ("embed", "mamba_inner"))},
        "wB": {"kernel": P((d, n), ("embed", "state"))},
        "wC": {"kernel": P((d, n), ("embed", "state"))},
        "wdt": {"kernel": P((d, h), ("embed", "mamba_heads"))},
        "conv_x": P((w, din), ("conv", "mamba_inner"), init="uniform_conv"),
        "conv_B": P((w, n), ("conv", "state"), init="uniform_conv"),
        "conv_C": P((w, n), ("conv", "state"), init="uniform_conv"),
        "A_log": P((h,), ("mamba_heads",), init="a_log", pin_dtype=True),
        "D": P((h,), ("mamba_heads",), init="ones", pin_dtype=True),
        "dt_bias": P((h,), ("mamba_heads",), init="dt_bias", pin_dtype=True),
        "norm": {"scale": P((din,), ("mamba_inner",), init="ones",
                            pin_dtype=True)},
        "wo": {"kernel": P((din, d), ("mamba_inner", "embed"))},
    }


def _causal_conv(x, kernel, state=None):
    """Depthwise causal conv via shift-and-add.  x: (B,S,C); kernel: (w,C).
    state: (B, w-1, C) trailing inputs from the previous segment (decode)."""
    w = kernel.shape[0]
    pad = state if state is not None else jnp.zeros(
        (x.shape[0], w - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([pad.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * kernel[i].astype(x.dtype)
            for i in range(w))
    new_state = xp[:, -(w - 1):, :] if w > 1 else pad
    return y, new_state


def _segsum_decay(dAc):
    """dAc: (B,NC,Q,H) -> pairwise decay exp(cs_i - cs_j) masked j<=i,
    returned as (B,NC,Q_i,Q_j,H).  All exponents <= 0 (stable)."""
    cs = jnp.cumsum(dAc, axis=2)
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]
    q = dAc.shape[2]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0), cs


def ssd_chunked(X, dA, Bm, Cm, chunk: int, initial_state=None,
                return_final: bool = False):
    """X: (B,S,H,P) (already dt-scaled); dA: (B,S,H) (= dt*A, negative);
    Bm, Cm: (B,S,N).  Returns Y (B,S,H,P) [, final_state (B,H,P,N)]."""
    b, s, h, p = X.shape
    n = Bm.shape[-1]
    chunk = min(chunk, s)
    s_orig = s
    if s % chunk:
        # pad with no-op steps (dA=0 -> decay 1, B=0 -> no state update); padded
        # steps are at the end so they affect neither outputs nor the state
        pad = chunk - s % chunk
        X = jnp.pad(X, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // chunk
    Xc = X.reshape(b, nc, chunk, h, p)
    dAc = dA.astype(jnp.float32).reshape(b, nc, chunk, h)
    Bc = Bm.reshape(b, nc, chunk, n)
    Cc = Cm.reshape(b, nc, chunk, n)

    L, cs = _segsum_decay(dAc)                               # (b,nc,i,j,h)
    att = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)
    M = att[..., None] * L                                    # (b,nc,i,j,h)
    Ydiag = jnp.einsum("bcijh,bcjhp->bcihp", M.astype(X.dtype), Xc)

    decay_to_end = jnp.exp(cs[:, :, -1:, :] - cs)             # (b,nc,j,h)
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", Bc,
                        decay_to_end.astype(X.dtype), Xc)     # (b,nc,h,p,n)
    chunk_decay = jnp.exp(cs[:, :, -1, :])                    # (b,nc,h)

    init = (initial_state if initial_state is not None
            else jnp.zeros((b, h, p, n), jnp.float32))

    def step(hprev, inp):
        st, dec = inp
        hnew = hprev * dec[:, :, None, None] + st.astype(jnp.float32)
        return hnew, hprev

    xs = (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    h_final, h_starts = jax.lax.scan(step, init, xs)
    h_starts = h_starts.swapaxes(0, 1)                        # (b,nc,h,p,n)

    Yoff = jnp.einsum("bcin,bcih,bchpn->bcihp", Cc,
                      jnp.exp(cs).astype(X.dtype),
                      h_starts.astype(X.dtype))
    Y = (Ydiag + Yoff).reshape(b, s, h, p)[:, :s_orig]
    if return_final:
        return Y, h_final
    return Y


def mamba_block(p, cfg, x, *, conv_state=None, ssm_state=None,
                decode: bool = False, impl: str = "xla",
                tp_shardmap: bool = False):
    """x: (B,S,d).  Train/prefill when decode=False (returns (y, states) with
    states=(conv_state, ssm_state) if requested via decode-compatible callers);
    decode=True runs the exact single-step recurrence (S must be 1)."""
    b, s, d = x.shape
    din, n, h_cnt = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    pdim = cfg.ssm_head_dim
    dtype = x.dtype

    if tp_shardmap:
        # column-parallel in-projections: backward dx psums run in bf16
        # through the shard_map instead of GSPMD's f32 (§Perf zamba it3)
        from repro.parallel.tpmm import col_proj_tp
        z = col_proj_tp(x, p["wz"]["kernel"])
        xs = col_proj_tp(x, p["wx"]["kernel"])
    else:
        z = jnp.einsum("bsd,de->bse", x, p["wz"]["kernel"].astype(dtype))
        xs = jnp.einsum("bsd,de->bse", x, p["wx"]["kernel"].astype(dtype))
    Bm = jnp.einsum("bsd,dn->bsn", x, p["wB"]["kernel"].astype(dtype))
    Cm = jnp.einsum("bsd,dn->bsn", x, p["wC"]["kernel"].astype(dtype))
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"]["kernel"].astype(dtype))

    # three separate depthwise convs: xs is model-sharded (mamba_inner) while
    # B/C are replicated — a fused concat would force GSPMD to reshard the
    # (B,S,din) activation every layer (§Perf zamba2 iteration 1)
    cs_x = conv_state[..., :din] if conv_state is not None else None
    cs_b = conv_state[..., din:din + n] if conv_state is not None else None
    cs_c = conv_state[..., din + n:] if conv_state is not None else None
    xs, ncs_x = _causal_conv(xs, p["conv_x"], cs_x)
    Bm, ncs_b = _causal_conv(Bm, p["conv_B"], cs_b)
    Cm, ncs_c = _causal_conv(Cm, p["conv_C"], cs_c)
    new_conv_state = jnp.concatenate([ncs_x, ncs_b, ncs_c], axis=-1)
    xs = jax.nn.silu(xs)
    Bm = jax.nn.silu(Bm)
    Cm = jax.nn.silu(Cm)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (H,)
    dA = dt * A                                                # <= 0
    X = xs.reshape(b, s, h_cnt, pdim)
    X = constrain(X, ("batch", "seq", "mamba_heads", None))
    Xdt = X * dt[..., None].astype(dtype)

    if not decode:
        if impl == "pallas" and ssm_state is None:
            from repro.kernels import ops as kops
            Y = kops.mamba2_ssd(Xdt, dA, Bm, Cm, chunk=cfg.ssm_chunk)
            final_state = jnp.zeros((b, h_cnt, pdim, n), jnp.float32)
        else:
            Y, final_state = ssd_chunked(Xdt, dA, Bm, Cm, cfg.ssm_chunk,
                                         initial_state=ssm_state,
                                         return_final=True)
    else:
        assert s == 1
        st = ssm_state if ssm_state is not None else jnp.zeros(
            (b, h_cnt, pdim, n), jnp.float32)
        dec = jnp.exp(dA[:, 0, :])                             # (B,H)
        upd = jnp.einsum("bn,bhp->bhpn", Bm[:, 0].astype(jnp.float32),
                         Xdt[:, 0].astype(jnp.float32))
        final_state = st * dec[:, :, None, None] + upd
        Y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32),
                       final_state)[:, None].astype(dtype)

    Y = Y + p["D"].astype(dtype)[None, None, :, None] * X
    Y = Y.reshape(b, s, din)
    Y = rms_norm(Y * jax.nn.silu(z), p["norm"]["scale"], cfg.norm_eps)
    if tp_shardmap:
        from repro.parallel.tpmm import down_proj_tp
        out = down_proj_tp(Y, p["wo"]["kernel"])
    else:
        out = jnp.einsum("bse,ed->bsd", Y, p["wo"]["kernel"].astype(dtype))
    return out, (new_conv_state, final_state)
