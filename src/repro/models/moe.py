"""Mixture-of-Experts with TPU-native capacity-based dispatch.

Hardware adaptation (see DESIGN.md §5): GPU MoE stacks use ragged/megablocks
GEMMs; the TPU-idiomatic form is capacity-based dispatch into dense per-expert
buffers so the expert compute is one batched MXU GEMM, with expert parallelism
over the ``model`` mesh axis (all-to-all inserted by GSPMD at the
token-sharded -> expert-sharded boundary).

Memory-lean dispatch: instead of the GShard (G,S,E,C) one-hot einsum tensor
(O(S·E·C) — terabytes at our shapes) we compute per-token capacity positions
with one int32 cumsum over a flattened (S·k, E) one-hot, then scatter-add the
k routing slots in a python loop (k ≤ 6), so peak transient memory is O(S·E)
int32 + O(E·C·d) buffers.  Tokens over capacity are dropped (standard GShard
semantics, capacity_factor 1.25).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import P, activation
from repro.models.mlp import mlp, mlp_spec
from repro.parallel.sharding import constrain


def moe_spec(cfg):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    spec = {
        "router": {"kernel": P((d, e), ("embed", "expert"), scale=0.02,
                               dtype="float32")},
        "wi": {"kernel": P((e, d, f), ("expert", "embed", "mlp"))},
        "wg": {"kernel": P((e, d, f), ("expert", "embed", "mlp"))},
        "wo": {"kernel": P((e, f, d), ("expert", "mlp", "embed"))},
    }
    if cfg.dense_residual:
        spec["dense"] = mlp_spec(cfg)
    return spec


def _capacity(tokens_per_group: int, cfg) -> int:
    c = tokens_per_group * cfg.experts_per_token / cfg.num_experts
    return max(math.ceil(c * cfg.capacity_factor), cfg.experts_per_token)


def route(router_p, cfg, xg):
    """xg: (G, S, d) -> gates (G,S,k) f32, expert ids (G,S,k) i32, aux loss."""
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        router_p["kernel"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.experts_per_token)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)  # renorm
    # Switch/GShard load-balance loss
    e = cfg.num_experts
    density = jnp.mean(jax.nn.one_hot(ids[..., 0], e, dtype=jnp.float32), axis=1)
    density_proxy = jnp.mean(probs, axis=1)
    aux = jnp.mean(density * density_proxy) * (e * e)
    return gates, ids, aux


def moe_ffn(p, cfg, x, groups: int = 0):
    """x: (B, S, d).  Groups default to B (capacity computed per sequence)."""
    b, s, d = x.shape
    g = groups or b
    xg = x.reshape(g, (b * s) // g, d)
    xg = constrain(xg, ("moe_group", "seq", "embed"))
    sg = xg.shape[1]
    k, e = cfg.experts_per_token, cfg.num_experts
    cap = _capacity(sg, cfg)

    gates, ids, aux = route(p["router"], cfg, xg)

    # --- capacity positions: cumsum over flattened (k*Sg, E) one-hot ----------
    # slot-major order: every token's 1st choice outranks any 2nd choice
    # (GShard priority semantics).
    ids_sm = ids.transpose(0, 2, 1).reshape(g, k * sg)
    onehot = jax.nn.one_hot(ids_sm, e, dtype=jnp.int32)     # (G, k*Sg, E)
    pos_in_expert = jnp.cumsum(onehot, axis=1) - onehot     # exclusive count
    pos = jnp.take_along_axis(
        pos_in_expert, ids_sm[..., None], axis=-1)[..., 0]  # (G, k*Sg)
    pos = pos.reshape(g, k, sg).transpose(0, 2, 1)          # (G, Sg, k)
    keep = (pos < cap).astype(xg.dtype) * (gates > 0).astype(xg.dtype)

    # --- dispatch: k scatter-adds into (G, E*cap, d) buffers -------------------
    buf = jnp.zeros((g, e * cap, d), xg.dtype)
    flat_idx = ids * cap + jnp.minimum(pos, cap - 1)        # (G, Sg, k)
    for j in range(k):
        upd = xg * keep[..., j, None]
        buf = jax.vmap(lambda bfr, ix, u: bfr.at[ix].add(u))(
            buf, flat_idx[..., j], upd)
    buf = constrain(buf.reshape(g, e, cap, d),
                    ("moe_group", "expert", None, "embed"))

    # --- expert FFN: batched GEMMs over the expert-parallel axis ---------------
    dtype = x.dtype
    act = activation(cfg.act)
    hi = jnp.einsum("gecd,edf->gecf", buf, p["wi"]["kernel"].astype(dtype))
    hg = jnp.einsum("gecd,edf->gecf", buf, p["wg"]["kernel"].astype(dtype))
    h = act(hg) * hi
    h = constrain(h, ("moe_group", "expert", None, "mlp"))
    out = jnp.einsum("gecf,efd->gecd", h, p["wo"]["kernel"].astype(dtype))
    out = constrain(out, ("moe_group", "expert", None, "embed"))
    out_flat = out.reshape(g, e * cap, d)

    # --- combine: k weighted gathers -------------------------------------------
    y = jnp.zeros_like(xg)
    for j in range(k):
        gathered = jax.vmap(lambda o, ix: o[ix])(out_flat, flat_idx[..., j])
        y = y + gathered * (gates[..., j, None].astype(dtype) * keep[..., j, None])

    y = y.reshape(b, s, d)
    if "dense" in p:   # arctic: dense residual path in parallel
        y = y + mlp(p["dense"], cfg, x)
    return y, aux


def moe_ffn_decode(p, cfg, x):
    """Decode-time MoE: one global group over the batch of single tokens."""
    return moe_ffn(p, cfg, x, groups=1)
