"""GQA attention: train/prefill (dense or blockwise-flash) + decode with KV cache.

Three implementations share one set of weights:

* ``dense``      — materializes (Sq, Skv) scores; only for tiny smoke tests.
* ``blockwise``  — FlashAttention expressed in pure XLA: python-unrolled loop over
  query chunks, ``lax.scan`` over the causally-required KV chunks with an online
  softmax.  Causal-FLOP-optimal (no wasted upper-triangle work), O(chunk) memory,
  GSPMD-partitionable — this is the dry-run / production XLA path.
* ``pallas``     — the TPU kernel in ``repro.kernels.flash_attention`` (interpret
  mode on CPU); selected via ``impl="pallas"``.

Decode is a single-token attention over a (B, Smax, KV, D) cache; the cache
index is either a shared scalar or a (B,) per-slot position vector, so a
ragged continuous batch decodes in a single call.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import P, apply_rope, dense_spec, norm_spec, rms_norm
from repro.parallel.sharding import constrain

NEG_INF = -1e30


def attention_spec(cfg, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    depth_scale = 0.02 / math.sqrt(2 * max(cfg.num_layers, 1))
    spec = {
        "wq": dense_spec(d, 0, ("embed", "heads", "head_dim"), cfg.use_bias,
                         shape=(d, h, hd)),
        "wk": dense_spec(d, 0, ("embed", "kv_heads", "head_dim"), cfg.use_bias,
                         shape=(d, kv, hd)),
        "wv": dense_spec(d, 0, ("embed", "kv_heads", "head_dim"), cfg.use_bias,
                         shape=(d, kv, hd)),
        "wo": {"kernel": P((h, hd, d), ("heads", "head_dim", "embed"),
                           scale=depth_scale)},
    }
    if cfg.use_bias:
        spec["wo"]["bias"] = P((d,), ("embed",), init="zeros")
    if cfg.qk_norm and not cross:
        spec["q_norm"] = {"scale": P((hd,), ("head_dim",), init="ones", )}
        spec["k_norm"] = {"scale": P((hd,), ("head_dim",), init="ones")}
    return spec


def _proj(p, x, dtype, tp_shardmap: bool = False):
    k = p["kernel"]
    bias = p["bias"].reshape(k.shape[1:]) if "bias" in p else None
    if tp_shardmap:
        from repro.parallel.tpmm import col_proj_tp
        return col_proj_tp(x, k, bias)
    y = jnp.einsum("bsd,dhe->bshe", x, k.astype(dtype))
    if bias is not None:
        y = y + bias.astype(dtype)
    return y


def project_qkv(p, cfg, xq, xkv, q_positions, kv_positions, rope: bool = True,
                flat_heads: bool = False, tp_shardmap: bool = False):
    """Returns q: (B,Sq,KV,G,D) grouped for GQA; k, v: (B,Skv,KV,D).

    flat_heads (train/prefill): KV is repeated to H so q/k/v are all
    (B,S,H,D) reshaped to KV=H, G=1 — the flat head axis then shards over the
    ``model`` mesh axis whenever H divides it (e.g. llama3-405b H=128,
    qwen3 H=32), instead of falling back to fully-replicated attention when
    the *grouped* dims (KV, G) don't divide.  Per-chip repeated-KV bytes
    equal the per-chip q bytes, so nothing blows up.  Decode keeps the
    grouped layout (a repeated KV *cache* would be a real memory hit).
    """
    dtype = xq.dtype
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = _proj(p["wq"], xq, dtype, tp_shardmap)         # (B,Sq,H,D)
    k = _proj(p["wk"], xkv, dtype, tp_shardmap)        # (B,Skv,KV,D)
    v = _proj(p["wv"], xkv, dtype, tp_shardmap)
    if cfg.qk_norm and "q_norm" in p:
        q = rms_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"]["scale"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, q_positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    if flat_heads and h != kv:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
        kv = h
    q = q.reshape(q.shape[0], q.shape[1], kv, h // kv, hd)
    if flat_heads:
        q = constrain(q, ("batch", "seq", "heads", None, None))
        k = constrain(k, ("batch", "seq", "heads", None))
        v = constrain(v, ("batch", "seq", "heads", None))
    else:
        q = constrain(q, ("batch", "seq", "kv_heads", "q_group", None))
        k = constrain(k, ("batch", "seq", "kv_heads", None))
        v = constrain(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def output_proj(p, cfg, y, tp_shardmap: bool = False):
    """y: (B,S,KV,G,D) -> (B,S,d)."""
    dtype = y.dtype
    b, s = y.shape[:2]
    y = y.reshape(b, s, cfg.num_heads, cfg.resolved_head_dim)
    if tp_shardmap:
        from repro.parallel.tpmm import o_proj_tp
        return o_proj_tp(y, p["wo"]["kernel"], p["wo"].get("bias"))
    out = jnp.einsum("bshe,hed->bsd", y, p["wo"]["kernel"].astype(dtype))
    if "bias" in p["wo"]:
        out = out + p["wo"]["bias"].astype(dtype)
    return out


# ------------------------------------------------------------- dense variant --

def dense_attention(q, k, v, causal: bool, q_offset: int = 0):
    """q: (B,Sq,KV,G,D); k,v: (B,Skv,KV,D)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if causal:
        sq, skv = q.shape[1], k.shape[1]
        qpos = jnp.arange(sq) + q_offset
        mask = qpos[:, None] >= jnp.arange(skv)[None, :]
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


# --------------------------------------------------------- blockwise variant --

def _online_block(carry, kv_blk, q_blk, bias=None):
    """One online-softmax step.  q_blk: (B,Qc,KV,G,D) pre-scaled;
    kv_blk: (k, v).  bias: optional (Qc, kvc) additive mask — only the
    diagonal block pays for masking."""
    m_prev, l_prev, acc = carry
    k_blk, v_blk = kv_blk
    s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk).astype(jnp.float32)
    if bias is not None:
        s = s + bias
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v_blk.dtype), v_blk)
    acc = acc * corr[..., None] + pv.astype(jnp.float32)
    return (m_new, l_new, acc), None


def blockwise_attention(q, k, v, causal: bool, q_chunk: int = 1024,
                        kv_chunk: int = 1024, q_offset: int = 0):
    """Flash attention in pure XLA.  Causal-FLOP-optimal: query chunk i only
    visits KV chunks 0..ceil((q_offset+(i+1)*qc)/kvc)-1 (static per unrolled
    iteration).  Memory-lean: the softmax scale is folded into q before the
    matmul (d-sized instead of S²), and masking is an additive bias that is
    exactly zero on fully-visible blocks (fuses away) rather than a `where`
    pass over every score block."""
    b, sq, kvh, g, hd = q.shape
    skv = k.shape[1]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    assert sq % q_chunk == 0 and skv % kv_chunk == 0, (sq, q_chunk, skv, kv_chunk)
    q = q * (1.0 / math.sqrt(hd))            # folded scale (d-sized, not S²)
    n_q = sq // q_chunk
    outs = []
    for i in range(n_q):
        q_blk = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
        q_end = q_offset + (i + 1) * q_chunk if causal else skv
        n_kv = -(-min(q_end, skv) // kv_chunk)        # ceil
        kv_len = n_kv * kv_chunk
        k_i = jax.lax.dynamic_slice_in_dim(k, 0, kv_len, axis=1)
        v_i = jax.lax.dynamic_slice_in_dim(v, 0, kv_len, axis=1)
        # (n_kv, B, kvc, KV, D) scan layout.  NOTE (§Perf llama405 it0,
        # refuted): splitting masked diagonal blocks out of the scan to skip
        # the mask op on visible blocks INCREASED bytes-accessed by 12% —
        # the uniform scan fuses better; keep the single-scan structure.
        k_i = k_i.reshape(b, n_kv, kv_chunk, kvh, hd).swapaxes(0, 1)
        v_i = v_i.reshape(b, n_kv, kv_chunk, kvh, hd).swapaxes(0, 1)
        if causal:
            qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)
            kpos = (jnp.arange(n_kv)[:, None] * kv_chunk
                    + jnp.arange(kv_chunk)[None, :])      # (n_kv, kvc)
            bias = jnp.where(qpos[None, :, None] >= kpos[:, None, :],
                             0.0, NEG_INF).astype(jnp.float32)
            bias = bias[:, None, None, None, :, :]
        else:
            bias = jnp.zeros((n_kv, 1, 1, 1, 1, 1), jnp.float32)
        init = (jnp.full((b, kvh, g, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((b, kvh, g, q_chunk), jnp.float32),
                jnp.zeros((b, kvh, g, q_chunk, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            partial(_online_block_bias, q_blk=q_blk),
            init, (k_i, v_i, bias))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(o.transpose(0, 3, 1, 2, 4).astype(q.dtype))  # (B,Qc,KV,G,D)
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def _online_block_bias(carry, kv_blk, q_blk):
    k_blk, v_blk, bias = kv_blk
    return _online_block(carry, (k_blk, v_blk), q_blk, bias=bias)


# ----------------------------------------------------------------- decode ----

def decode_positions(cache_index, batch: int):
    """Normalize a decode cache index to a (B,) per-slot position vector.

    ``cache_index`` is either a scalar (synchronized batch: every sequence at
    the same depth — the train/dry-run calling convention) or already a (B,)
    vector of per-slot positions (ragged continuous batching)."""
    idx = jnp.asarray(cache_index, jnp.int32)
    if idx.ndim == 0:
        idx = jnp.full((batch,), idx)
    assert idx.shape == (batch,), (idx.shape, batch)
    return idx


def decode_attention(q, k_cache, v_cache, cache_index):
    """q: (B,1,KV,G,D); caches: (B,Smax,KV,D); attends to positions <= index.

    ``cache_index``: scalar or (B,) per-slot positions — each slot gets its
    own causal mask, so a ragged batch decodes in one call."""
    hd = q.shape[-1]
    pos = decode_positions(cache_index, q.shape[0])
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k_cache).astype(jnp.float32)
    s = s / math.sqrt(hd)
    valid = jnp.arange(k_cache.shape[1])[None, :] <= pos[:, None]  # (B,Smax)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache)


# ------------------------------------------------------------- full layers ----

def attention_block(p, cfg, x, *, impl: str = "blockwise", causal: bool = True,
                    q_chunk: int = 1024, kv_chunk: int = 1024,
                    flat_heads: bool = False, tp_shardmap: bool = False):
    """Self-attention over a full sequence (train / prefill).  Returns (y, (k, v))."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = project_qkv(p, cfg, x, x, positions, positions,
                          flat_heads=flat_heads, tp_shardmap=tp_shardmap)
    if impl == "dense":
        y = dense_attention(q, k, v, causal)
    elif impl == "blockwise":
        y = blockwise_attention(q, k, v, causal, q_chunk, kv_chunk)
    elif impl == "seqsp":
        # sequence-sharded shard_map path (archs with heads ∤ model axis)
        from repro.parallel.seqattn import seq_sharded_attention
        assert causal, "seqsp path is causal-only"
        y = seq_sharded_attention(q, k, v, q_chunk=q_chunk,
                                  kv_chunk=kv_chunk)
    elif impl == "pallas":
        from repro.kernels import ops as kops
        y = kops.flash_attention(q, k, v, causal=causal)
    else:
        raise ValueError(impl)
    return output_proj(p, cfg, y, tp_shardmap=tp_shardmap), (k, v)


def cross_attention_block(p, cfg, x, enc_kv):
    """Cross-attention: queries from x, keys/values precomputed (k, v) tuples."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    dtype = x.dtype
    q = _proj(p["wq"], x, dtype)
    q = q.reshape(b, s, cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads,
                  cfg.resolved_head_dim)
    k, v = enc_kv
    y = blockwise_attention(q, k, v, causal=False)
    return output_proj(p, cfg, y)


def encode_kv(p, cfg, enc_out):
    """Precompute cross-attention K/V from encoder output (no RoPE)."""
    dtype = enc_out.dtype
    k = _proj(p["wk"], enc_out, dtype)
    v = _proj(p["wv"], enc_out, dtype)
    return k, v


def _scatter_decode_kv(cache, new, positions):
    """Per-slot cache write: cache (B,Smax,KV,D) <- new (B,1,KV,D) at
    positions (B,).  vmap of a length-1 dynamic_update_slice lowers to a
    batched scatter — one write per slot at its own depth."""
    return jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
        c, n, i, axis=0))(cache, new.astype(cache.dtype), positions)


def attention_decode_block(p, cfg, x, k_cache, v_cache, cache_index,
                           rope: bool = True):
    """One-token decode.  x: (B,1,d); caches (B,Smax,KV,D).  ``cache_index``
    is a scalar (synchronized batch) or a (B,) vector of per-slot positions
    (ragged continuous batching: per-slot RoPE, scatter-write, and causal
    mask).  Returns (y, new_k_cache, new_v_cache)."""
    b = x.shape[0]
    per_slot = jnp.ndim(cache_index) > 0
    pos = decode_positions(cache_index, b)
    q, k, v = project_qkv(p, cfg, x, x, pos[:, None], pos[:, None], rope=rope)
    # Pin the cache sharding (batch over DP, sequence over the model axis —
    # flash-decoding style).  Without this GSPMD may back-propagate the
    # attention head sharding onto the cache and materialize a full-cache
    # reshard (observed: 2×38 GB all-gathers per step on qwen3 decode_32k).
    cache_axes = ("batch", "kv_seq", "kv_heads", None)
    if per_slot:
        k_cache = _scatter_decode_kv(k_cache, k, pos)
        v_cache = _scatter_decode_kv(v_cache, v, pos)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), cache_index, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), cache_index, axis=1)
    k_cache = constrain(k_cache, cache_axes)
    v_cache = constrain(v_cache, cache_axes)
    y = decode_attention(q, k_cache, v_cache, pos)
    y = constrain(y, ("batch", None, None, None, None))
    return output_proj(p, cfg, y), k_cache, v_cache
