"""GQA attention: train/prefill (dense or blockwise-flash) + decode with KV cache.

Three implementations share one set of weights:

* ``dense``      — materializes (Sq, Skv) scores; only for tiny smoke tests.
* ``blockwise``  — FlashAttention expressed in pure XLA: python-unrolled loop over
  query chunks, ``lax.scan`` over the causally-required KV chunks with an online
  softmax.  Causal-FLOP-optimal (no wasted upper-triangle work), O(chunk) memory,
  GSPMD-partitionable — this is the dry-run / production XLA path.
* ``pallas``     — the TPU kernel in ``repro.kernels.flash_attention`` (interpret
  mode on CPU); selected via ``impl="pallas"``.

Decode is a single-token attention over a KV cache; the cache index is either
a shared scalar or a (B,) per-slot position vector, so a ragged continuous
batch decodes in a single call.  Two cache layouts share the decode math:

* **contiguous** — (B, Smax, KV, D) dense rows per slot (train/dry-run).
* **paged**      — a (P, page, KV, D) physical page pool plus a (B, M) int32
  page table; slot positions resolve through the table (pos -> page
  ``table[b, pos // page]``, row ``pos % page``), so slots only pin the pages
  they actually use and identical prompt prefixes can share physical pages
  (``repro.serve.kvcache``).  Physical page 0 is a scratch sink: freed slots'
  table rows point at it, so masked/inactive decode writes land in garbage
  space instead of pages that may since belong to another request.

Paged decode resolves the table one of two ways (``decode_impl``):
``"gather"`` — XLA gather into a dense-equivalent per-step view (default,
runs anywhere, O(B·M·page) transient) — or ``"pallas"`` — the
``repro.kernels.paged_decode`` flash kernel that walks the table
block-by-block with O(page) transient (interpret mode on CPU).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import P, apply_rope, dense_spec, norm_spec, rms_norm
from repro.parallel.sharding import constrain

NEG_INF = -1e30


def attention_spec(cfg, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    depth_scale = 0.02 / math.sqrt(2 * max(cfg.num_layers, 1))
    spec = {
        "wq": dense_spec(d, 0, ("embed", "heads", "head_dim"), cfg.use_bias,
                         shape=(d, h, hd)),
        "wk": dense_spec(d, 0, ("embed", "kv_heads", "head_dim"), cfg.use_bias,
                         shape=(d, kv, hd)),
        "wv": dense_spec(d, 0, ("embed", "kv_heads", "head_dim"), cfg.use_bias,
                         shape=(d, kv, hd)),
        "wo": {"kernel": P((h, hd, d), ("heads", "head_dim", "embed"),
                           scale=depth_scale)},
    }
    if cfg.use_bias:
        spec["wo"]["bias"] = P((d,), ("embed",), init="zeros")
    if cfg.qk_norm and not cross:
        spec["q_norm"] = {"scale": P((hd,), ("head_dim",), init="ones", )}
        spec["k_norm"] = {"scale": P((hd,), ("head_dim",), init="ones")}
    return spec


def _proj(p, x, dtype, tp_shardmap: bool = False):
    k = p["kernel"]
    bias = p["bias"].reshape(k.shape[1:]) if "bias" in p else None
    if tp_shardmap:
        from repro.parallel.tpmm import col_proj_tp
        return col_proj_tp(x, k, bias)
    y = jnp.einsum("bsd,dhe->bshe", x, k.astype(dtype))
    if bias is not None:
        y = y + bias.astype(dtype)
    return y


def project_qkv(p, cfg, xq, xkv, q_positions, kv_positions, rope: bool = True,
                flat_heads: bool = False, tp_shardmap: bool = False):
    """Returns q: (B,Sq,KV,G,D) grouped for GQA; k, v: (B,Skv,KV,D).

    flat_heads (train/prefill): KV is repeated to H so q/k/v are all
    (B,S,H,D) reshaped to KV=H, G=1 — the flat head axis then shards over the
    ``model`` mesh axis whenever H divides it (e.g. llama3-405b H=128,
    qwen3 H=32), instead of falling back to fully-replicated attention when
    the *grouped* dims (KV, G) don't divide.  Per-chip repeated-KV bytes
    equal the per-chip q bytes, so nothing blows up.  Decode keeps the
    grouped layout (a repeated KV *cache* would be a real memory hit).
    """
    dtype = xq.dtype
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = _proj(p["wq"], xq, dtype, tp_shardmap)         # (B,Sq,H,D)
    k = _proj(p["wk"], xkv, dtype, tp_shardmap)        # (B,Skv,KV,D)
    v = _proj(p["wv"], xkv, dtype, tp_shardmap)
    if cfg.qk_norm and "q_norm" in p:
        q = rms_norm(q, p["q_norm"]["scale"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"]["scale"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, q_positions, cfg.rope_theta)
        k = apply_rope(k, kv_positions, cfg.rope_theta)
    if flat_heads and h != kv:
        k = jnp.repeat(k, h // kv, axis=2)
        v = jnp.repeat(v, h // kv, axis=2)
        kv = h
    q = q.reshape(q.shape[0], q.shape[1], kv, h // kv, hd)
    if flat_heads:
        q = constrain(q, ("batch", "seq", "heads", None, None))
        k = constrain(k, ("batch", "seq", "heads", None))
        v = constrain(v, ("batch", "seq", "heads", None))
    else:
        q = constrain(q, ("batch", "seq", "kv_heads", "q_group", None))
        k = constrain(k, ("batch", "seq", "kv_heads", None))
        v = constrain(v, ("batch", "seq", "kv_heads", None))
    return q, k, v


def output_proj(p, cfg, y, tp_shardmap: bool = False):
    """y: (B,S,KV,G,D) -> (B,S,d)."""
    dtype = y.dtype
    b, s = y.shape[:2]
    y = y.reshape(b, s, cfg.num_heads, cfg.resolved_head_dim)
    if tp_shardmap:
        from repro.parallel.tpmm import o_proj_tp
        return o_proj_tp(y, p["wo"]["kernel"], p["wo"].get("bias"))
    out = jnp.einsum("bshe,hed->bsd", y, p["wo"]["kernel"].astype(dtype))
    if "bias" in p["wo"]:
        out = out + p["wo"]["bias"].astype(dtype)
    return out


# ------------------------------------------------------------- dense variant --

def dense_attention(q, k, v, causal: bool, q_offset: int = 0):
    """q: (B,Sq,KV,G,D); k,v: (B,Skv,KV,D)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqkgd,bskd->bkgqs", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if causal:
        sq, skv = q.shape[1], k.shape[1]
        qpos = jnp.arange(sq) + q_offset
        mask = qpos[:, None] >= jnp.arange(skv)[None, :]
        scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", probs, v)


# --------------------------------------------------------- blockwise variant --

def _online_block(carry, kv_blk, q_blk, bias=None):
    """One online-softmax step.  q_blk: (B,Qc,KV,G,D) pre-scaled;
    kv_blk: (k, v).  bias: optional (Qc, kvc) additive mask — only the
    diagonal block pays for masking."""
    m_prev, l_prev, acc = carry
    k_blk, v_blk = kv_blk
    s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, k_blk).astype(jnp.float32)
    if bias is not None:
        s = s + bias
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + p.sum(axis=-1)
    pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(v_blk.dtype), v_blk)
    acc = acc * corr[..., None] + pv.astype(jnp.float32)
    return (m_new, l_new, acc), None


def blockwise_attention(q, k, v, causal: bool, q_chunk: int = 1024,
                        kv_chunk: int = 1024, q_offset: int = 0):
    """Flash attention in pure XLA.  Causal-FLOP-optimal: query chunk i only
    visits KV chunks 0..ceil((q_offset+(i+1)*qc)/kvc)-1 (static per unrolled
    iteration).  Memory-lean: the softmax scale is folded into q before the
    matmul (d-sized instead of S²), and masking is an additive bias that is
    exactly zero on fully-visible blocks (fuses away) rather than a `where`
    pass over every score block."""
    b, sq, kvh, g, hd = q.shape
    skv = k.shape[1]
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    assert sq % q_chunk == 0 and skv % kv_chunk == 0, (sq, q_chunk, skv, kv_chunk)
    q = q * (1.0 / math.sqrt(hd))            # folded scale (d-sized, not S²)
    n_q = sq // q_chunk
    outs = []
    for i in range(n_q):
        q_blk = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
        q_end = q_offset + (i + 1) * q_chunk if causal else skv
        n_kv = -(-min(q_end, skv) // kv_chunk)        # ceil
        kv_len = n_kv * kv_chunk
        k_i = jax.lax.dynamic_slice_in_dim(k, 0, kv_len, axis=1)
        v_i = jax.lax.dynamic_slice_in_dim(v, 0, kv_len, axis=1)
        # (n_kv, B, kvc, KV, D) scan layout.  NOTE (§Perf llama405 it0,
        # refuted): splitting masked diagonal blocks out of the scan to skip
        # the mask op on visible blocks INCREASED bytes-accessed by 12% —
        # the uniform scan fuses better; keep the single-scan structure.
        k_i = k_i.reshape(b, n_kv, kv_chunk, kvh, hd).swapaxes(0, 1)
        v_i = v_i.reshape(b, n_kv, kv_chunk, kvh, hd).swapaxes(0, 1)
        if causal:
            qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)
            kpos = (jnp.arange(n_kv)[:, None] * kv_chunk
                    + jnp.arange(kv_chunk)[None, :])      # (n_kv, kvc)
            bias = jnp.where(qpos[None, :, None] >= kpos[:, None, :],
                             0.0, NEG_INF).astype(jnp.float32)
            bias = bias[:, None, None, None, :, :]
        else:
            bias = jnp.zeros((n_kv, 1, 1, 1, 1, 1), jnp.float32)
        init = (jnp.full((b, kvh, g, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((b, kvh, g, q_chunk), jnp.float32),
                jnp.zeros((b, kvh, g, q_chunk, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            partial(_online_block_bias, q_blk=q_blk),
            init, (k_i, v_i, bias))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        outs.append(o.transpose(0, 3, 1, 2, 4).astype(q.dtype))  # (B,Qc,KV,G,D)
    return jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]


def _online_block_bias(carry, kv_blk, q_blk):
    k_blk, v_blk, bias = kv_blk
    return _online_block(carry, (k_blk, v_blk), q_blk, bias=bias)


# ----------------------------------------------------------------- decode ----

def decode_positions(cache_index, batch: int):
    """Normalize a decode cache index to a (B,) per-slot position vector.

    ``cache_index`` is either a scalar (synchronized batch: every sequence at
    the same depth — the train/dry-run calling convention) or already a (B,)
    vector of per-slot positions (ragged continuous batching)."""
    idx = jnp.asarray(cache_index, jnp.int32)
    if idx.ndim == 0:
        idx = jnp.full((batch,), idx)
    assert idx.shape == (batch,), (idx.shape, batch)
    return idx


def gather_pages(pool, page_table, positions=None):
    """Resolve a page pool into per-slot logical KV rows.

    pool: (P, page, KV, D) physical pages; page_table: (B, M) int32 page ids
    in logical order.  Returns (B, M*page, KV, D) where row ``pos`` of slot
    ``b`` is ``pool[page_table[b, pos // page], pos % page]``.

    ``positions`` (B,), when given, redirects table rows for logical pages
    past ``ceil((pos+1)/page)`` — allocated for the request's future decode
    but holding nothing attendable yet — to the scratch page (physical page
    0).  Every row those pages would contribute is masked to NEG_INF by the
    caller anyway, so logits are bitwise unchanged, but the gather's HBM
    reads for a short sequence shrink from the slot's full reservation to
    the pages it has actually written (repeated scratch-page reads hit the
    same lines).

    Only the pool persists in HBM; the gathered view is a per-step
    temporary — but it IS materialized at dense-equivalent size for the
    current batch, so transient decode memory grows with the (paged-enlarged)
    concurrent batch even though pinned memory does not.  The paged
    flash-decode kernel (``decode_attention(..., impl="pallas")``) walks the
    table block-by-block instead and never materializes this view."""
    b, m = page_table.shape
    page = pool.shape[1]
    if positions is not None:
        live = jnp.arange(m)[None, :] <= positions[:, None] // page  # (B, M)
        page_table = jnp.where(live, page_table, 0)
    k = jnp.take(pool, page_table, axis=0)          # (B, M, page, KV, D)
    return k.reshape(b, m * page, *pool.shape[2:])


def dequant_gathered(gathered, scale_pool, lt_or_table, b, rows, dtype):
    """Dequantize a gathered int8 KV view: ``gathered`` (B, S, KV, D) int8,
    ``scale_pool`` (P, page, KV) fp32, ``lt_or_table`` the (B, M) table the
    int8 view was gathered through — the scales resolve through the *same*
    indirection, so row and scale can never come from different pages."""
    sg = jnp.take(scale_pool, lt_or_table, axis=0).reshape(
        b, rows, scale_pool.shape[2])
    return (gathered.astype(jnp.float32) * sg[..., None]).astype(dtype)


def paged_gather_partials(q, k_pool, v_pool, page_table, positions,
                          page_offset, k_scale=None, v_scale=None):
    """Per-chip partial paged decode by XLA gather — the sharded-serving
    counterpart of the plain gather path, so gather/pallas parity holds on
    every backend (the Pallas twin is ``kernels.ops.paged_decode_partials``).

    q: (B, 1, KV, G, D); pools: one chip's LOCAL (P/n, page, KV, D) shard;
    page_table: (B, M) GLOBAL page ids; page_offset: global id of the local
    shard's first page.  Table entries outside ``[offset, offset + P/n)``
    are masked exactly like dead pages: their gather rows redirect to local
    page 0 and their scores to NEG_INF, so each chip materializes only its
    own dense-equivalent view and attends only to rows it owns.

    Returns the raw fp32 online-softmax triple ``(acc (B,1,KV,G,D),
    l (B,KV,G), m (B,KV,G))``; ``merge_paged_partials`` combines chips.  A
    chip owning no live page of a slot returns the identity element
    (acc=0, l=0, m=NEG_INF) — note the explicit ``where`` on p below: with
    every score at NEG_INF the naive ``exp(s - max)`` would be exp(0)=1.

    ``k_scale``/``v_scale`` (int8 pools): the local (P/n, page, KV) fp32
    scale shards — gathered rows dequantize through the same redirected
    table before the score/accumulate einsums."""
    hd = q.shape[-1]
    b, m = page_table.shape
    pn, page = k_pool.shape[:2]
    live = jnp.arange(m)[None, :] <= positions[:, None] // page    # (B, M)
    local = page_table - page_offset
    ok = live & (local >= 0) & (local < pn)
    lt = jnp.where(ok, local, 0)
    kg = jnp.take(k_pool, lt, axis=0).reshape(b, m * page, *k_pool.shape[2:])
    vg = jnp.take(v_pool, lt, axis=0).reshape(b, m * page, *v_pool.shape[2:])
    if k_scale is not None:
        kg = dequant_gathered(kg, k_scale, lt, b, m * page, jnp.float32)
        vg = dequant_gathered(vg, v_scale, lt, b, m * page, jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", q[:, 0], kg).astype(jnp.float32)
    s = s / math.sqrt(hd)
    rows = jnp.arange(m * page)[None, :]
    valid = (rows <= positions[:, None]) \
        & jnp.repeat(ok, page, axis=1)                             # (B, S)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    mx = jnp.max(s, axis=-1)                                       # (B,KV,G)
    p = jnp.where(valid[:, None, None, :], jnp.exp(s - mx[..., None]), 0.0)
    l = p.sum(axis=-1)
    acc = jnp.einsum("bkgs,bskd->bkgd", p, vg.astype(jnp.float32))
    return acc[:, None], l, mx


def merge_paged_partials(acc, l, m, axis_name: str):
    """Cross-chip online-softmax merge (inside shard_map): combine per-chip
    raw triples into the full softmax with one pmax + two psums.

    acc: (B, 1, KV, G, D) unnormalized; l, m: (B, KV, G).  Chips with no
    live pages carry m = NEG_INF, so their weight exp(m - m*) is exactly 0.
    The denominator can only vanish if *no* chip saw a live row, which the
    scratch-page convention rules out (logical page 0 is live at every
    position >= 0, and freed slots' tables point at physical page 0)."""
    gm = jax.lax.pmax(m, axis_name)
    w = jnp.exp(m - gm)                                            # (B,KV,G)
    num = jax.lax.psum(acc * w[:, None, :, :, None], axis_name)
    den = jax.lax.psum(l * w, axis_name)
    return num / jnp.maximum(den, 1e-30)[:, None, :, :, None]


def decode_attention(q, k_cache, v_cache, cache_index, page_table=None,
                     impl: str = "gather", k_scale=None, v_scale=None):
    """q: (B,1,KV,G,D); attends to positions <= index.

    ``cache_index``: scalar or (B,) per-slot positions — each slot gets its
    own causal mask, so a ragged batch decodes in one call.

    caches: (B,Smax,KV,D) contiguous rows, or — when ``page_table`` (B, M)
    is given — (P,page,KV,D) pools resolved per slot through the table.  The
    gathered view preserves logical row order, so the masked softmax below is
    identical math to the contiguous path (bit-for-bit when M*page == Smax).

    ``impl`` selects the paged resolution strategy: ``"gather"`` (the XLA
    fallback — materializes the dense-equivalent view per step) or
    ``"pallas"`` (the ``repro.kernels.paged_decode`` flash kernel — walks
    the page table block-by-block, O(page) transient, matching this masked
    softmax within fp32 online-softmax tolerance).  Contiguous caches
    ignore ``impl``.

    ``k_scale``/``v_scale`` (paged int8 pools only): (P, page, KV) fp32
    absmax scales — the gather path dequantizes the gathered int8 view,
    the pallas path dequantizes in-register inside the kernel.
    """
    hd = q.shape[-1]
    pos = decode_positions(cache_index, q.shape[0])
    assert k_scale is None or page_table is not None, (
        "KV scales ride on the paged int8 page format")
    if page_table is not None:
        if impl == "pallas":
            from repro.kernels import ops as kops
            return kops.paged_decode_attention(q, k_cache, v_cache,
                                               page_table, pos,
                                               k_scale=k_scale,
                                               v_scale=v_scale)
        assert impl == "gather", impl
        b, m = page_table.shape
        page = k_cache.shape[1]
        k_cache = gather_pages(k_cache, page_table, pos)
        v_cache = gather_pages(v_cache, page_table, pos)
        if k_scale is not None:
            # dequantize through the same live-masked table as the rows
            live = jnp.arange(m)[None, :] <= pos[:, None] // page
            lt = jnp.where(live, page_table, 0)
            k_cache = dequant_gathered(k_cache, k_scale, lt, b,
                                       m * page, q.dtype)
            v_cache = dequant_gathered(v_cache, v_scale, lt, b,
                                       m * page, q.dtype)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, k_cache).astype(jnp.float32)
    s = s / math.sqrt(hd)
    valid = jnp.arange(k_cache.shape[1])[None, :] <= pos[:, None]  # (B,Smax)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    return jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache)


# ------------------------------------------------------------- full layers ----

def attention_block(p, cfg, x, *, impl: str = "blockwise", causal: bool = True,
                    q_chunk: int = 1024, kv_chunk: int = 1024,
                    flat_heads: bool = False, tp_shardmap: bool = False):
    """Self-attention over a full sequence (train / prefill).  Returns (y, (k, v))."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    q, k, v = project_qkv(p, cfg, x, x, positions, positions,
                          flat_heads=flat_heads, tp_shardmap=tp_shardmap)
    if impl == "dense":
        y = dense_attention(q, k, v, causal)
    elif impl == "blockwise":
        y = blockwise_attention(q, k, v, causal, q_chunk, kv_chunk)
    elif impl == "seqsp":
        # sequence-sharded shard_map path (archs with heads ∤ model axis)
        from repro.parallel.seqattn import seq_sharded_attention
        assert causal, "seqsp path is causal-only"
        y = seq_sharded_attention(q, k, v, q_chunk=q_chunk,
                                  kv_chunk=kv_chunk)
    elif impl == "pallas":
        from repro.kernels import ops as kops
        y = kops.flash_attention(q, k, v, causal=causal)
    else:
        raise ValueError(impl)
    return output_proj(p, cfg, y, tp_shardmap=tp_shardmap), (k, v)


def cross_attention_block(p, cfg, x, enc_kv):
    """Cross-attention: queries from x, keys/values precomputed (k, v) tuples."""
    b, s, _ = x.shape
    positions = jnp.arange(s)[None, :]
    dtype = x.dtype
    q = _proj(p["wq"], x, dtype)
    q = q.reshape(b, s, cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads,
                  cfg.resolved_head_dim)
    k, v = enc_kv
    y = blockwise_attention(q, k, v, causal=False)
    return output_proj(p, cfg, y)


def encode_kv(p, cfg, enc_out):
    """Precompute cross-attention K/V from encoder output (no RoPE)."""
    dtype = enc_out.dtype
    k = _proj(p["wk"], enc_out, dtype)
    v = _proj(p["wv"], enc_out, dtype)
    return k, v


def _scatter_decode_kv(cache, new, positions):
    """Per-slot cache write: cache (B,Smax,KV,D) <- new (B,1,KV,D) at
    positions (B,).  vmap of a length-1 dynamic_update_slice lowers to a
    batched scatter — one write per slot at its own depth."""
    return jax.vmap(lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
        c, n, i, axis=0))(cache, new.astype(cache.dtype), positions)


def _scatter_paged_kv(pool, new, page_table, positions):
    """Paged cache write: pool (P,page,KV,D) <- new (B,1,KV,D), slot b's
    token landing at ``pool[page_table[b, pos//page], pos % page]``.  One flat
    scatter for the whole ragged batch.  Freed slots' table rows point at the
    scratch page (physical page 0), so their masked writes never touch pages
    owned by live requests."""
    p_pages, page = pool.shape[:2]
    flat = pool.reshape(p_pages * page, *pool.shape[2:])
    page_ids = jnp.take_along_axis(
        page_table, (positions // page)[:, None], axis=1)[:, 0]
    idx = page_ids * page + positions % page
    flat = flat.at[idx].set(new[:, 0].astype(pool.dtype))
    return flat.reshape(pool.shape)


def scatter_paged_kv_local(pool, new, page_table, positions, page_offset):
    """Sharded paged cache write (inside shard_map): each chip applies only
    the writes that land in its own (P/n, page, KV, D) pool shard.

    Slot b's write page is ``table[b, pos // page]`` — a GLOBAL id; the chip
    owning it (``offset <= id < offset + P/n``) scatters the row at the
    local flat index, every other chip routes that slot's write one past the
    end of its shard and ``mode="drop"`` discards it.  Exactly one chip
    (or zero, for freed slots whose scratch page 0 lives on chip 0) commits
    each token, so the union of shards equals the single-device pool."""
    pn, page = pool.shape[:2]
    flat = pool.reshape(pn * page, *pool.shape[2:])
    page_ids = jnp.take_along_axis(
        page_table, (positions // page)[:, None], axis=1)[:, 0]
    local = page_ids - page_offset
    idx = jnp.where((local >= 0) & (local < pn),
                    local * page + positions % page, pn * page)
    flat = flat.at[idx].set(new[:, 0].astype(pool.dtype), mode="drop")
    return flat.reshape(pool.shape)


def _scatter_chunk_paged(pool, new, dest):
    """Chunked-prefill pool write: pool (P,page,KV,D) <- new (B,C,KV,D), the
    chunk's C tokens landing at ``dest`` (B,C) flat pool rows (page *
    page_size + row, resolved host-side by ``PagedCache.chunk_dest``).
    Padding and shared-prefix positions arrive routed to flat index 0 — the
    scratch sink — whose content is never attended un-masked."""
    p_pages, page = pool.shape[:2]
    flat = pool.reshape(p_pages * page, *pool.shape[2:])
    flat = flat.at[dest.reshape(-1)].set(
        new.reshape(-1, *new.shape[2:]).astype(pool.dtype))
    return flat.reshape(pool.shape)


def scatter_chunk_paged_local(pool, new, dest, row_offset):
    """Sharded chunk/prefill pool write (inside shard_map): the local-window
    twin of ``_scatter_chunk_paged``, same routing as
    ``scatter_paged_kv_local`` but over precomputed flat rows.

    ``dest`` holds GLOBAL flat pool rows (page · page_size + row); the chip
    owning rows ``[row_offset, row_offset + P/n·page)`` commits them at the
    local flat index, every other chip routes them one past its shard end
    and ``mode="drop"`` discards the update.  Scratch-routed positions
    (flat row 0) land on chip 0's scratch page, exactly as on one chip."""
    pn, page = pool.shape[:2]
    rows = pn * page
    flat = pool.reshape(rows, *pool.shape[2:])
    local = dest.reshape(-1) - row_offset
    idx = jnp.where((local >= 0) & (local < rows), local, rows)
    flat = flat.at[idx].set(
        new.reshape(-1, *new.shape[2:]).astype(pool.dtype), mode="drop")
    return flat.reshape(pool.shape)


def paged_gather_chunk_partials(q, k_pool, v_pool, page_table, qpos,
                                last_pos, page_offset,
                                k_scale=None, v_scale=None):
    """Per-chip partial chunked-prefill attention by XLA gather — the C-row
    generalization of ``paged_gather_partials`` (decode is the C=1 case
    with a plain ``col <= pos`` mask).

    q: (B, C, KV, G, D) chunk queries; pools: one chip's LOCAL
    (P/n, page, KV, D) shard; page_table: (B, M) GLOBAL ids; qpos: (B, C)
    each row's global position; last_pos: (B,) the chunk's last valid
    position (limits the gather to claimed pages and clamps padding rows);
    page_offset: global id of the shard's first page.  Non-local pages
    redirect to local page 0 with their scores at NEG_INF — dead-page
    semantics — and the causal mask is position-exact per row
    (``col <= min(qpos, last_pos)``), matching the single-chip chunk block.

    Returns the raw fp32 triple ``(acc (B,C,KV,G,D), l (B,KV,G,C),
    m (B,KV,G,C))`` for ``merge_paged_chunk_partials``.

    ``k_scale``/``v_scale`` (int8 pools): the local (P/n, page, KV) fp32
    scale shards — gathered rows dequantize through the same redirected
    table before the score/accumulate einsums."""
    hd = q.shape[-1]
    b, m = page_table.shape
    pn, page = k_pool.shape[:2]
    live = jnp.arange(m)[None, :] <= last_pos[:, None] // page    # (B, M)
    local = page_table - page_offset
    ok = live & (local >= 0) & (local < pn)
    lt = jnp.where(ok, local, 0)
    kg = jnp.take(k_pool, lt, axis=0).reshape(b, m * page, *k_pool.shape[2:])
    vg = jnp.take(v_pool, lt, axis=0).reshape(b, m * page, *v_pool.shape[2:])
    if k_scale is not None:
        kg = dequant_gathered(kg, k_scale, lt, b, m * page, jnp.float32)
        vg = dequant_gathered(vg, v_scale, lt, b, m * page, jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, kg).astype(jnp.float32)
    s = s / math.sqrt(hd)
    cols = jnp.arange(m * page)
    valid = (cols[None, None, :]
             <= jnp.minimum(qpos, last_pos[:, None])[:, :, None]) \
        & jnp.repeat(ok, page, axis=1)[:, None, :]                # (B, C, S)
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    mx = jnp.max(s, axis=-1)                                   # (B,KV,G,C)
    pr = jnp.where(valid[:, None, None, :, :],
                   jnp.exp(s - mx[..., None]), 0.0)
    l = pr.sum(axis=-1)                                        # (B,KV,G,C)
    acc = jnp.einsum("bkgqs,bskd->bqkgd", pr, vg.astype(jnp.float32))
    return acc, l, mx


def merge_paged_chunk_partials(acc, l, m, axis_name: str):
    """Cross-chip online-softmax merge for C-row chunk partials — the chunk
    generalization of ``merge_paged_partials`` (same pmax + two psums, the
    row dim riding along).

    acc: (B, C, KV, G, D) unnormalized; l, m: (B, KV, G, C).  The
    denominator can only vanish on padding rows past ``last_pos`` — whose
    outputs the caller discards — so the 1e-30 floor never perturbs a
    consumed row."""
    gm = jax.lax.pmax(m, axis_name)
    w = jnp.exp(m - gm)                                        # (B,KV,G,C)
    num = jax.lax.psum(acc * w.transpose(0, 3, 1, 2)[..., None], axis_name)
    den = jax.lax.psum(l * w, axis_name)
    return num / jnp.maximum(den, 1e-30).transpose(0, 3, 1, 2)[..., None]


def attention_prefill_chunk_block(p, cfg, x, k_pool, v_pool, start_pos, dest,
                                  page_table, last_pos,
                                  k_scale=None, v_scale=None,
                                  mesh=None, kv_axis: str = "model",
                                  dp_axis=None):
    """Chunked-prefill attention with prior cache: a (B, C) token chunk at a
    per-request position offset writes its K/V into the paged pools and
    attends causally over everything written so far — the pages landed by
    chunks ``0..k-1`` plus the chunk itself — through the same
    ``gather_pages`` machinery the paged decode fallback uses.

    x: (B, C, d) chunk activations; start_pos: (B,) global position of each
    request's chunk start; dest: (B, C) flat pool write indices
    (``PagedCache.chunk_dest`` — padding/shared positions scratch-routed);
    page_table: the slots' REAL (B, M) table rows (``PagedCache.table_row``,
    not the shielded decode view); last_pos: (B,) last valid global position
    of the chunk — masks padding rows and limits the gather to pages the
    slot has actually claimed.  Row ``i``'s causal mask is position-exact
    (``col <= start_pos + i``), so within-chunk causality needs no separate
    path.  Returns (y, new_k_pool, new_v_pool).

    The math matches whole-prompt dense prefill op-for-op (same einsum
    contractions, fp32 masked softmax, NEG_INF mask exp-underflowing to
    exactly 0.0), which is what makes chunked and whole-prompt prefill
    bitwise-identical token streams rather than merely close ones.

    ``k_scale``/``v_scale`` (int8 pools): the chunk's K/V quantize before
    the scatter — scales land through the same ``dest`` indices — and the
    gathered views dequantize before attention, so a chunk attends its own
    rows exactly as a later decode step will read them (round-tripped
    through int8).  Returns a 5-tuple including the new scale arrays.

    ``mesh`` (kv_pages-sharded pools): the scatter + attend run through the
    unified shard_map primitive instead —
    ``repro.parallel.pagedkv.sharded_prefill_chunk_attention`` (per-chip
    ``mode="drop"`` local writes, C-row local partials, partial-softmax
    merge over ``kv_axis``; ``dp_axis`` shards the chunk batch on 2-D
    meshes)."""
    quantized = k_scale is not None
    b, c = x.shape[:2]
    qpos = start_pos[:, None] + jnp.arange(c)[None, :]            # (B, C)
    q, k, v = project_qkv(p, cfg, x, x, qpos, qpos)
    if quantized:
        from repro.kernels.quant import quantize_kv
        k, sk = quantize_kv(k)
        v, sv = quantize_kv(v)
    if mesh is not None:
        from repro.parallel.pagedkv import sharded_prefill_chunk_attention
        out = sharded_prefill_chunk_attention(
            mesh, kv_axis, q, k, v, dest, k_pool, v_pool, page_table,
            start_pos, last_pos,
            k_scale=k_scale, v_scale=v_scale,
            k_scale_new=sk if quantized else None,
            v_scale_new=sv if quantized else None, dp_axis=dp_axis)
        if quantized:
            y, k_pool, v_pool, k_scale, v_scale = out
            return (output_proj(p, cfg, y), k_pool, v_pool,
                    k_scale, v_scale)
        y, k_pool, v_pool = out
        return output_proj(p, cfg, y), k_pool, v_pool
    if quantized:
        k_scale = _scatter_chunk_paged(k_scale, sk, dest)
        v_scale = _scatter_chunk_paged(v_scale, sv, dest)
    k_pool = _scatter_chunk_paged(k_pool, k, dest)
    v_pool = _scatter_chunk_paged(v_pool, v, dest)
    kg = gather_pages(k_pool, page_table, last_pos)               # (B,S,KV,D)
    vg = gather_pages(v_pool, page_table, last_pos)
    if quantized:
        m, page = page_table.shape[1], k_pool.shape[1]
        live = jnp.arange(m)[None, :] <= last_pos[:, None] // page
        lt = jnp.where(live, page_table, 0)
        kg = dequant_gathered(kg, k_scale, lt, b, m * page, x.dtype)
        vg = dequant_gathered(vg, v_scale, lt, b, m * page, x.dtype)
    hd = q.shape[-1]
    s = jnp.einsum("bqkgd,bskd->bkgqs", q, kg).astype(jnp.float32)
    s = s / math.sqrt(hd)
    cols = jnp.arange(kg.shape[1])
    # padding rows (qpos > last_pos) are clamped to last_pos so they never
    # attend rows beyond claimed pages; their outputs are discarded and
    # their writes were scratch-routed by dest
    valid = cols[None, None, :] \
        <= jnp.minimum(qpos, last_pos[:, None])[:, :, None]       # (B, C, S)
    s = jnp.where(valid[:, None, None, :, :], s, NEG_INF)
    probs = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    y = jnp.einsum("bkgqs,bskd->bqkgd", probs, vg)
    if quantized:
        return output_proj(p, cfg, y), k_pool, v_pool, k_scale, v_scale
    return output_proj(p, cfg, y), k_pool, v_pool


def attention_decode_block(p, cfg, x, k_cache, v_cache, cache_index,
                           rope: bool = True, page_table=None,
                           decode_impl: str = "gather", mesh=None,
                           kv_axis: str = "model", dp_axis=None,
                           k_scale=None, v_scale=None):
    """One-token decode.  x: (B,1,d).  ``cache_index`` is a scalar
    (synchronized batch) or a (B,) vector of per-slot positions (ragged
    continuous batching: per-slot RoPE, scatter-write, and causal mask).

    caches are (B,Smax,KV,D) contiguous rows, or — with ``page_table``
    (B, M) — (P,page,KV,D) physical pools indexed through the table (the
    paged backend of ``repro.serve.kvcache``), resolved per ``decode_impl``
    ("gather": XLA dense-equivalent view; "pallas": page-table-walking
    flash kernel).  With ``mesh`` (paged only), the pools are sharded P/n
    along ``kv_axis`` and the scatter-write + table resolution run under
    shard_map with a cross-chip partial-softmax merge
    (``repro.parallel.pagedkv``).  Returns (y, new_k_cache, new_v_cache).

    ``k_scale``/``v_scale`` (paged int8 pools, ``kv_dtype="int8"``): the
    (P, page, KV) fp32 scale arrays — the new token's K/V row quantizes on
    write (row + scale land through the same table-resolved index) and the
    read path dequantizes per ``decode_impl``.  Returns the 5-tuple
    (y, k_cache, v_cache, k_scale, v_scale)."""
    b = x.shape[0]
    per_slot = jnp.ndim(cache_index) > 0
    pos = decode_positions(cache_index, b)
    q, k, v = project_qkv(p, cfg, x, x, pos[:, None], pos[:, None], rope=rope)
    quantized = k_scale is not None
    assert not quantized or page_table is not None, (
        "KV scales ride on the paged int8 page format")
    if page_table is not None:
        if mesh is not None:
            from repro.parallel.pagedkv import sharded_paged_decode_attention
            out = sharded_paged_decode_attention(
                mesh, kv_axis, q, k, v, k_cache, v_cache, page_table, pos,
                decode_impl, k_scale=k_scale, v_scale=v_scale,
                dp_axis=dp_axis)
            if quantized:
                y, k_cache, v_cache, k_scale, v_scale = out
            else:
                y, k_cache, v_cache = out
        else:
            if quantized:
                from repro.kernels.quant import quantize_kv
                k, sk = quantize_kv(k)
                v, sv = quantize_kv(v)
                k_scale = _scatter_paged_kv(k_scale, sk, page_table, pos)
                v_scale = _scatter_paged_kv(v_scale, sv, page_table, pos)
            k_cache = _scatter_paged_kv(k_cache, k, page_table, pos)
            v_cache = _scatter_paged_kv(v_cache, v, page_table, pos)
            y = decode_attention(q, k_cache, v_cache, pos,
                                 page_table=page_table, impl=decode_impl,
                                 k_scale=k_scale, v_scale=v_scale)
        y = constrain(y, ("batch", None, None, None, None))
        if quantized:
            return output_proj(p, cfg, y), k_cache, v_cache, k_scale, v_scale
        return output_proj(p, cfg, y), k_cache, v_cache
    # Pin the cache sharding (batch over DP, sequence over the model axis —
    # flash-decoding style).  Without this GSPMD may back-propagate the
    # attention head sharding onto the cache and materialize a full-cache
    # reshard (observed: 2×38 GB all-gathers per step on qwen3 decode_32k).
    cache_axes = ("batch", "kv_seq", "kv_heads", None)
    if per_slot:
        k_cache = _scatter_decode_kv(k_cache, k, pos)
        v_cache = _scatter_decode_kv(v_cache, v, pos)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), cache_index, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), cache_index, axis=1)
    k_cache = constrain(k_cache, cache_axes)
    v_cache = constrain(v_cache, cache_axes)
    y = decode_attention(q, k_cache, v_cache, pos)
    y = constrain(y, ("batch", None, None, None, None))
    return output_proj(p, cfg, y), k_cache, v_cache
