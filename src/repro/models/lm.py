"""Top-level model facade: one API over all families, plus ``input_specs()``
(ShapeDtypeStruct stand-ins for every model input — the dry-run contract)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec, transformer
from repro.models.common import (abstract_params, cross_entropy, init_params,
                                 logical_axes)
from repro.models.transformer import ForwardOpts


@dataclass
class LM:
    cfg: ModelConfig

    # ------------------------------------------------------------- params ----
    @property
    def is_encdec(self) -> bool:
        return self.cfg.family == "encdec"

    def spec(self):
        return (encdec.build_spec(self.cfg) if self.is_encdec
                else transformer.build_spec(self.cfg))

    def init(self, rng):
        return init_params(rng, self.spec())

    def abstract_params(self):
        return abstract_params(self.spec())

    def param_logical_axes(self):
        return logical_axes(self.spec())

    # ------------------------------------------------------------ forward ----
    def forward(self, params, batch, opts: ForwardOpts = ForwardOpts(),
                collect_cache: bool = False):
        if self.is_encdec:
            return encdec.forward(params, self.cfg, batch, opts, collect_cache)
        return transformer.forward(params, self.cfg, batch, opts, collect_cache)

    def loss(self, params, batch, opts: ForwardOpts = ForwardOpts(),
             moe_aux_weight: float = 1e-2, z_loss: float = 1e-4):
        logits, aux, _ = self.forward(params, batch, opts)
        cfg = self.cfg
        if cfg.family == "vlm" and cfg.num_image_tokens:
            logits = logits[:, cfg.num_image_tokens:, :]
        loss, ce_aux = cross_entropy(logits, batch["labels"], cfg.vocab_size,
                                     z_loss=z_loss)
        loss = loss + moe_aux_weight * aux["moe_aux"]
        metrics = {"loss": loss, "nll": ce_aux["nll"],
                   "z_loss": ce_aux["z_loss"], "moe_aux": aux["moe_aux"]}
        return loss, metrics

    # -------------------------------------------------------------- serve ----
    def prefill(self, params, batch, opts: ForwardOpts = ForwardOpts()):
        """Returns (last_logits, cache)."""
        logits, _, cache = self.forward(params, batch, opts, collect_cache=True)
        return logits[:, -1:, :], cache

    def decode_step(self, params, tokens, cache, cache_index,
                    scan_layers: bool = True, decode_impl: str = "gather",
                    mesh=None, kv_axis: str = "model", dp_axis=None):
        """One-token decode.  ``cache_index`` is a scalar shared position or
        a (B,) per-slot position vector (ragged continuous batching).
        ``decode_impl`` selects how a paged cache's page table is resolved
        ("gather": XLA fallback; "pallas": page-table-walking flash-decode
        kernel); contiguous caches ignore it.  ``mesh`` (paged only) runs
        each layer's scatter+attention under shard_map over pools sharded
        P/n along ``kv_axis``, merging per-chip softmax partials
        (``repro.parallel.pagedkv``)."""
        if self.is_encdec:
            assert mesh is None, "sharded paged decode is decoder-only"
            return encdec.decode_step(params, self.cfg, tokens, cache,
                                      cache_index, scan_layers=scan_layers)
        return transformer.decode_step(params, self.cfg, tokens, cache,
                                       cache_index, scan_layers=scan_layers,
                                       decode_impl=decode_impl, mesh=mesh,
                                       kv_axis=kv_axis, dp_axis=dp_axis)

    def prefill_chunk(self, params, tokens, cache, start_pos, dest, last_pos,
                      scan_layers: bool = True, mesh=None,
                      kv_axis: str = "model", dp_axis=None):
        """One chunk of chunked prefill: forward (B, C) prompt tokens at
        position offset ``start_pos`` against a paged cache view, scattering
        K/V into the pools at ``dest`` and attending over prior chunks'
        pages plus the chunk itself.  Returns (last_logits (B,1,V),
        new_cache).  See ``transformer.prefill_chunk``."""
        assert not self.is_encdec, (
            "chunked prefill is decoder-only (encdec prefill is per-request "
            "dense state)")
        return transformer.prefill_chunk(params, self.cfg, tokens, cache,
                                         start_pos, dest, last_pos,
                                         scan_layers=scan_layers, mesh=mesh,
                                         kv_axis=kv_axis, dp_axis=dp_axis)

    def init_cache(self, batch_size: int, max_seq: int, enc_len: int = 0,
                   dtype=jnp.bfloat16, abstract: bool = False,
                   backend: Optional[str] = None, page_size: int = 16,
                   num_pages: Optional[int] = None,
                   prefix_sharing: bool = True,
                   decode_impl: str = "gather",
                   mesh=None, kv_axis: str = "model", dp_axis=None,
                   kv_dtype: str = "native",
                   locality_chips: Optional[int] = None,
                   host_pages: int = 0, prefix_store=None):
        """Decode cache construction.

        ``backend=None`` (train / dry-run) returns the raw dense pytree —
        the contiguous layout, consumed directly by ``decode_step`` and the
        dry-run input specs.  ``backend="contiguous"`` / ``"paged"`` returns
        a managed ``repro.serve.kvcache`` backend (alloc / free / page-table
        indirection / prefix sharing) for the serve engine; ``decode_impl``
        rides on the backend and tells decode consumers how to resolve the
        page table ("gather" / "pallas").  ``mesh`` (paged only) shards the
        page pools P/n along the ``kv_pages`` logical axis -> ``kv_axis``
        mesh axis, padding the pool up to a multiple of the mesh size.
        ``kv_dtype="int8"`` (paged only) stores pages int8-quantized with
        per-row fp32 scales (``repro.serve.kvcache``).  ``host_pages`` /
        ``prefix_store`` (paged only) put a host-RAM offload tier behind
        the pool (``repro.serve.offload``)."""
        if backend is not None:
            assert not abstract, "managed cache backends are concrete-only"
            from repro.serve.kvcache import make_cache
            return make_cache(self, batch_size, max_seq, dtype=dtype,
                              backend=backend, page_size=page_size,
                              num_pages=num_pages,
                              prefix_sharing=prefix_sharing,
                              decode_impl=decode_impl, mesh=mesh,
                              kv_axis=kv_axis, dp_axis=dp_axis,
                              kv_dtype=kv_dtype,
                              locality_chips=locality_chips,
                              host_pages=host_pages,
                              prefix_store=prefix_store)
        assert kv_dtype == "native", (
            "int8 KV pages are a managed paged-backend format "
            "(init_cache(backend='paged', kv_dtype='int8'))")
        if self.is_encdec:
            return encdec.init_cache(self.cfg, batch_size, max_seq,
                                     enc_len or max_seq // self.cfg.enc_ratio,
                                     dtype, abstract)
        return transformer.init_cache(self.cfg, batch_size, max_seq, dtype,
                                      abstract)


# ------------------------------------------------------------- input specs ----

def text_len(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.family == "vlm" and cfg.num_image_tokens:
        return seq_len - cfg.num_image_tokens
    return seq_len


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the batch of a given shape cell.

    - train/prefill: tokens (+labels for train) and any stub-frontend
      embeddings (precomputed frames / patches — [audio]/[vlm] convention).
    - decode: one new token per sequence + the KV/recurrent-state cache at
      seq_len (built by ``LM.init_cache(abstract=True)``).
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        st = text_len(cfg, s)
        batch: Dict[str, Any] = {"tokens": sds((b, st), i32)}
        if shape.kind == "train":
            batch["labels"] = sds((b, st), i32)
        if cfg.family == "vlm" and cfg.num_image_tokens:
            batch["img_embeds"] = sds((b, cfg.num_image_tokens, cfg.d_model),
                                      dtype)
        if cfg.family == "encdec":
            batch["enc_embeds"] = sds((b, s // cfg.enc_ratio, cfg.d_model),
                                      dtype)
        return batch
    if shape.kind == "decode":
        lm = LM(cfg)
        return {
            "tokens": sds((b, 1), i32),
            "cache": lm.init_cache(b, s, dtype=dtype, abstract=True),
            "cache_index": sds((), i32),
        }
    raise ValueError(shape.kind)


def input_logical_axes(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Logical axes tree matching ``input_specs`` (for dry-run in_shardings)."""
    if shape.kind in ("train", "prefill"):
        axes: Dict[str, Any] = {"tokens": ("batch", "seq")}
        if shape.kind == "train":
            axes["labels"] = ("batch", "seq")
        if cfg.family == "vlm" and cfg.num_image_tokens:
            axes["img_embeds"] = ("batch", "seq", "embed")
        if cfg.family == "encdec":
            axes["enc_embeds"] = ("batch", "enc_seq", "embed")
        return axes
    lm = LM(cfg)
    cache = lm.init_cache(shape.global_batch, shape.seq_len, abstract=True)
    cache_axes = transformer.cache_logical_axes(cfg, cache) \
        if cfg.family != "encdec" else jax.tree.map_with_path(
            lambda p, l: ("layers", "batch", "kv_seq", "kv_heads", None), cache)
    return {"tokens": ("batch", None), "cache": cache_axes,
            "cache_index": ()}


def make_batch(cfg: ModelConfig, shape_or_bs, seq_len: int = 0, rng=None,
               dtype=jnp.bfloat16):
    """Concrete random batch (smoke tests / examples)."""
    import numpy as np
    if isinstance(shape_or_bs, ShapeConfig):
        b, s = shape_or_bs.global_batch, shape_or_bs.seq_len
    else:
        b, s = shape_or_bs, seq_len
    rng = np.random.default_rng(0 if rng is None else rng)
    st = text_len(cfg, s)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, st)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, st)), jnp.int32),
    }
    if cfg.family == "vlm" and cfg.num_image_tokens:
        batch["img_embeds"] = jnp.asarray(
            rng.normal(0, 1, (b, cfg.num_image_tokens, cfg.d_model)), dtype)
    if cfg.family == "encdec":
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(0, 1, (b, s // cfg.enc_ratio, cfg.d_model)), dtype)
    return batch
