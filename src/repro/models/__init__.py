from repro.models.lm import LM, input_logical_axes, input_specs, make_batch
from repro.models.transformer import ForwardOpts

__all__ = ["LM", "ForwardOpts", "input_specs", "input_logical_axes",
           "make_batch"]
