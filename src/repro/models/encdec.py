"""Encoder-decoder stack (seamless-m4t backbone).  The audio frontend is a
stub: the encoder consumes precomputed frame embeddings (B, E, d) supplied by
``input_specs()`` (paper shape-table convention for [audio] archs)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mlp as mlp_mod
from repro.models.common import P, apply_norm, norm_spec, set_dtypes, stack_spec
from repro.models.transformer import ForwardOpts, _remat
from repro.parallel.sharding import constrain


def enc_layer_spec(cfg):
    return {"ln1": norm_spec(cfg), "attn": attn.attention_spec(cfg),
            "ln2": norm_spec(cfg), "mlp": mlp_mod.mlp_spec(cfg)}


def dec_layer_spec(cfg):
    return {"ln1": norm_spec(cfg), "self_attn": attn.attention_spec(cfg),
            "ln2": norm_spec(cfg), "cross_attn": attn.attention_spec(cfg, cross=True),
            "ln3": norm_spec(cfg), "mlp": mlp_mod.mlp_spec(cfg)}


def build_spec(cfg):
    d, v = cfg.d_model, cfg.padded_vocab
    spec: Dict[str, Any] = {
        "embed": {"table": P((v, d), ("vocab", "embed"))},
        "enc_layers": stack_spec(enc_layer_spec(cfg), cfg.encoder_layers,
                                 "layers"),
        "enc_norm": norm_spec(cfg),
        "dec_layers": stack_spec(dec_layer_spec(cfg), cfg.num_layers, "layers"),
        "final_norm": norm_spec(cfg),
        "lm_head": {"kernel": P((d, v), ("embed", "vocab"))},
    }
    return set_dtypes(spec, cfg.param_dtype)


def encode(params, cfg, enc_embeds, opts: ForwardOpts = ForwardOpts()):
    """enc_embeds: (B, E, d) stub frontend output -> encoder hidden states."""
    h = enc_embeds.astype(jnp.dtype(cfg.dtype))
    h = constrain(h, ("batch", "enc_seq", "embed"))

    def body(h, lp):
        a, _ = attn.attention_block(lp["attn"], cfg,
                                    apply_norm(lp["ln1"], h, cfg),
                                    impl=opts.attn_impl, causal=False,
                                    q_chunk=opts.q_chunk,
                                    kv_chunk=opts.kv_chunk)
        h = h + a
        h = h + mlp_mod.mlp(lp["mlp"], cfg, apply_norm(lp["ln2"], h, cfg))
        return constrain(h, ("batch", "enc_seq", "embed")), None

    body = _remat(body, opts.remat)
    from repro.models.transformer import _scan_or_unroll
    h, _ = _scan_or_unroll(body, h, params["enc_layers"],
                           cfg.encoder_layers, opts.scan_layers)
    return apply_norm(params["enc_norm"], h, cfg)


def decoder_forward(params, cfg, tokens, enc_out,
                    opts: ForwardOpts = ForwardOpts(),
                    collect_cache: bool = False):
    dtype = jnp.dtype(cfg.dtype)
    h = jnp.take(params["embed"]["table"], tokens, axis=0).astype(dtype)
    h = constrain(h, ("batch", "seq", "embed"))

    def body(h, lp):
        a, kv = attn.attention_block(lp["self_attn"], cfg,
                                     apply_norm(lp["ln1"], h, cfg),
                                     impl=opts.attn_impl,
                                     q_chunk=opts.q_chunk,
                                     kv_chunk=opts.kv_chunk)
        h = h + a
        xkv = attn.encode_kv(lp["cross_attn"], cfg, enc_out)
        c = attn.cross_attention_block(lp["cross_attn"], cfg,
                                       apply_norm(lp["ln2"], h, cfg), xkv)
        h = h + c
        h = h + mlp_mod.mlp(lp["mlp"], cfg, apply_norm(lp["ln3"], h, cfg))
        h = constrain(h, ("batch", "seq", "embed"))
        cache = ({"k": kv[0], "v": kv[1], "xk": xkv[0], "xv": xkv[1]}
                 if collect_cache else None)
        return h, cache

    body = _remat(body, opts.remat)
    from repro.models.transformer import _scan_or_unroll
    h, caches = _scan_or_unroll(body, h, params["dec_layers"],
                                cfg.num_layers, opts.scan_layers)
    h = apply_norm(params["final_norm"], h, cfg)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"]["kernel"].astype(dtype))
    return constrain(logits, ("batch", "seq", "vocab")), caches


def forward(params, cfg, batch, opts: ForwardOpts = ForwardOpts(),
            collect_cache: bool = False):
    enc_out = encode(params, cfg, batch["enc_embeds"], opts)
    logits, caches = decoder_forward(params, cfg, batch["tokens"], enc_out,
                                     opts, collect_cache)
    cache = {"layers": caches} if collect_cache else None
    return logits, {"moe_aux": jnp.zeros((), jnp.float32)}, cache


def init_cache(cfg, batch_size: int, max_seq: int, enc_len: int,
               dtype=jnp.bfloat16, abstract: bool = False):
    L, b = cfg.num_layers, batch_size
    kvh, hd = cfg.num_kv_heads, cfg.resolved_head_dim

    def mk(shape):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dtype)
        return jnp.zeros(shape, dtype)

    return {"layers": {
        "k": mk((L, b, max_seq, kvh, hd)), "v": mk((L, b, max_seq, kvh, hd)),
        "xk": mk((L, b, enc_len, kvh, hd)), "xv": mk((L, b, enc_len, kvh, hd)),
    }}


def decode_step(params, cfg, tokens, cache, cache_index,
                scan_layers: bool = True):
    """One-token decoder step.  ``cache_index``: scalar or (B,) per-slot
    positions (ragged batching) — cross-attention KV is position-free, the
    self-attention cache is scatter-written per slot.

    Only the contiguous cache layout applies here: the cross-attention K/V
    block is dense per-request state with no page structure, so the paged
    backend of ``repro.serve.kvcache`` rejects encdec configs up front."""
    assert "page_table" not in cache, \
        "paged KV decode is decoder-only transformer families"
    dtype = jnp.dtype(cfg.dtype)
    h = jnp.take(params["embed"]["table"], tokens, axis=0).astype(dtype)

    def body(h, xs):
        lp, lc = xs
        a_in = apply_norm(lp["ln1"], h, cfg)
        a, nk, nv = attn.attention_decode_block(lp["self_attn"], cfg, a_in,
                                                lc["k"], lc["v"], cache_index)
        h = h + a
        c = attn.cross_attention_block(lp["cross_attn"], cfg,
                                       apply_norm(lp["ln2"], h, cfg),
                                       (lc["xk"], lc["xv"]))
        h = h + c
        h = h + mlp_mod.mlp(lp["mlp"], cfg, apply_norm(lp["ln3"], h, cfg))
        return h, {"k": nk, "v": nv, "xk": lc["xk"], "xv": lc["xv"]}

    from repro.models.transformer import _scan_or_unroll
    h, new_layers = _scan_or_unroll(body, h, (params["dec_layers"],
                                              cache["layers"]),
                                    cfg.num_layers, scan_layers)
    h = apply_norm(params["final_norm"], h, cfg)
    logits = jnp.einsum("bsd,dv->bsv", h, params["lm_head"]["kernel"].astype(dtype))
    return logits, {"layers": new_layers}
