"""Post-optimization HLO parsing: per-device collective bytes.

The SPMD-partitioned module's shapes are per-device, so summing operand/result
sizes of collective ops gives *per-device* bytes-on-the-wire, which divided by
per-chip link bandwidth is the collective roofline term (equivalent to the
global-bytes / (chips × link_bw) formulation).

Byte accounting per op (ring algorithms):
  all-reduce      2 × size   (reduce-scatter + all-gather phases)
  all-gather      result size (each device receives ~the full result)
  reduce-scatter  operand size (each device sends ~its full operand)
  all-to-all      size       (each device sends all but its own slice)
  collective-permute  size
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
# v2 iota format: replica_groups=[num_groups,group_size]<=[total]
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict]:
    """Scan optimized HLO; returns per-op-kind {count, bytes} + total.

    ``-done`` ops (async pairs) are skipped so each collective counts once.
    """
    stats = defaultdict(lambda: {"count": 0, "bytes": 0})
    ops: List[Tuple[str, int, int]] = []
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done.1" in line:
            # async completion op: shape already counted at -start
            if any(c in line for c in _COLLECTIVES):
                continue
        m = _OP_RE.search(line)
        if not m:
            continue
        result_txt, kind = m.group(1), m.group(2)
        result_bytes = _shape_bytes(result_txt)
        operand_bytes = _shape_bytes(line[m.end():])
        if kind == "all-reduce":
            b = 2 * result_bytes
        elif kind == "all-gather":
            b = result_bytes
        elif kind == "reduce-scatter":
            b = operand_bytes
        else:  # all-to-all, collective-permute
            b = max(result_bytes, operand_bytes if kind == "all-to-all" else 0)
        g = _GROUPS_RE.search(line)
        if g:
            group_size = len(g.group(1).split(","))
        else:
            g2 = _GROUPS_V2_RE.search(line)
            group_size = int(g2.group(2)) if g2 else 0
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += b
        ops.append((kind, b, group_size))
    total = sum(v["bytes"] for v in stats.values())
    return {"per_kind": dict(stats), "total_bytes": int(total),
            "largest_ops": sorted(ops, key=lambda t: -t[1])[:12]}


def count_op_flavors(hlo_text: str) -> Dict[str, int]:
    """Cheap structural profile: fusion/convert/transpose/etc. op counts (used
    to spot layout thrash and remat-duplicated compute)."""
    counts: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        m = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+([a-z][\w-]*)\(", line)
        if m:
            counts[m.group(1)] += 1
    return dict(counts)
