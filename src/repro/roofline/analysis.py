"""Three-term roofline from compiled dry-run artifacts (TPU v5e targets).

  compute    = HLO_FLOPs_per_device / 197e12          [s]
  memory     = HLO_bytes_per_device / 819e9           [s]
  collective = collective_bytes_per_device / 50e9     [s]  (single ICI link,
               conservative; v5e has 4 links — reported as-is, see DESIGN.md)

plus MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens (fwd) and the
useful-compute ratio MODEL_FLOPS / HLO_FLOPs.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass
from typing import Dict, Optional

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
ICI_LINK_BW = 50e9        # bytes/s per link


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float            # per device
    hlo_bytes: float            # per device
    collective_bytes: float     # per device
    model_flops_global: float
    tokens_per_step: int

    @property
    def compute_s(self) -> float:
        return self.hlo_flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_LINK_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        per_dev_model = self.model_flops_global / max(self.chips, 1)
        return per_dev_model / max(self.hlo_flops, 1.0)

    @property
    def mfu_bound(self) -> float:
        """Model-FLOPs utilization at the roofline bound (the score metric):
        model_flops / (chips × peak × bound_time)."""
        per_dev_model = self.model_flops_global / max(self.chips, 1)
        return per_dev_model / (PEAK_FLOPS * max(self.bound_s, 1e-30))

    def to_dict(self) -> Dict:
        d = asdict(self)
        d.update(compute_s=self.compute_s, memory_s=self.memory_s,
                 collective_s=self.collective_s, dominant=self.dominant,
                 useful_ratio=self.useful_ratio, mfu_bound=self.mfu_bound)
        return d


def from_record(rec: Dict) -> Roofline:
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        chips=rec["chips"],
        hlo_flops=rec.get("cost_analysis", {}).get("flops", 0.0),
        hlo_bytes=rec.get("cost_analysis", {}).get("bytes accessed", 0.0),
        collective_bytes=rec.get("collectives", {}).get("total_bytes", 0.0),
        model_flops_global=rec.get("model_flops_global", 0.0),
        tokens_per_step=rec.get("tokens_per_step", 0),
    )


def table_row(r: Roofline) -> str:
    return (f"| {r.arch} | {r.shape} | {r.mesh} | "
            f"{r.compute_s*1e3:.2f} | {r.memory_s*1e3:.2f} | "
            f"{r.collective_s*1e3:.2f} | {r.dominant} | "
            f"{r.useful_ratio:.2f} | {r.mfu_bound*100:.1f}% |")


TABLE_HEADER = ("| arch | shape | mesh | compute ms | memory ms | "
                "collective ms | bottleneck | useful | MFU@bound |\n"
                "|---|---|---|---|---|---|---|---|---|")
