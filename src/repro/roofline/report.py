"""Builds the EXPERIMENTS.md §Dry-run / §Roofline tables from the sweep
records in experiments/dryrun/."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

from repro.roofline.analysis import (TABLE_HEADER, Roofline, from_record,
                                     table_row)

DRYRUN = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load_records(mesh: str = "pod16x16", tag: str = "") -> List[Dict]:
    out = []
    for p in sorted((DRYRUN / mesh).glob("*.json")):
        r = json.loads(p.read_text())
        if tag and r.get("tag") != tag:
            continue
        if not tag and r.get("tag", "baseline") != "baseline":
            continue
        out.append(r)
    return out


def load_cell(arch: str, shape: str, mesh: str = "pod16x16",
              tag: str = "baseline") -> Optional[Dict]:
    suffix = "" if tag == "baseline" else f"__{tag}"
    p = DRYRUN / mesh / f"{arch}__{shape}{suffix}.json"
    if not p.exists():
        return None
    return json.loads(p.read_text())


def _key(r):
    return (r["arch"], SHAPE_ORDER.index(r["shape"])
            if r["shape"] in SHAPE_ORDER else 99)


def roofline_table(mesh: str = "pod16x16") -> str:
    lines = [TABLE_HEADER]
    skips = []
    for r in sorted(load_records(mesh), key=_key):
        if r.get("skipped"):
            skips.append(f"- `{r['arch']} × {r['shape']}`: {r['skipped']}")
            continue
        if not r.get("ok"):
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | "
                         f"FAILED: {r.get('error','?')} | | | | | |")
            continue
        lines.append(table_row(from_record(r)))
    out = "\n".join(lines)
    if skips:
        out += "\n\nSkipped cells (DESIGN.md §4):\n" + "\n".join(skips)
    return out


def dryrun_table() -> str:
    """§Dry-run: per-cell compile proof + memory analysis on both meshes."""
    lines = ["| arch | shape | mesh | compile s | args GB/dev | temp GB/dev "
             "| collective kinds |",
             "|---|---|---|---|---|---|---|"]
    for mesh in ("pod16x16", "pod2x16x16"):
        for r in sorted(load_records(mesh), key=_key):
            if r.get("skipped"):
                continue
            if not r.get("ok"):
                lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | FAIL |"
                             f" | | {r.get('error','?')} |")
                continue
            ma = r.get("memory_analysis", {})
            kinds = sorted(r.get("scan_counted", r).get(
                "collectives", {}).get("per_kind", {}))
            lines.append(
                f"| {r['arch']} | {r['shape']} | {mesh} "
                f"| {r.get('compile_s','-')} "
                f"| {ma.get('argument_size_in_bytes', 0)/1e9:.1f} "
                f"| {ma.get('temp_size_in_bytes', 0)/1e9:.1f} "
                f"| {','.join(kinds)} |")
    return "\n".join(lines)


def summary_stats(mesh: str = "pod16x16") -> Dict:
    recs = [r for r in load_records(mesh) if r.get("ok")]
    rf = [from_record(r) for r in recs]
    return {
        "cells_ok": len(recs),
        "bottlenecks": {b: sum(1 for r in rf if r.dominant == b)
                        for b in ("compute", "memory", "collective")},
        "worst_mfu": min(rf, key=lambda r: r.mfu_bound).arch if rf else None,
        "most_collective": max(rf, key=lambda r: r.collective_s).arch
        if rf else None,
    }


if __name__ == "__main__":
    print("## Single-pod roofline (16x16 = 256 chips)\n")
    print(roofline_table("pod16x16"))
    print("\n\n## Dry-run compile matrix\n")
    print(dryrun_table())
    print("\n", json.dumps(summary_stats(), indent=1))
