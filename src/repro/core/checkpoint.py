"""Sharded checkpointing with async object-store upload (§2.1.3, §2.3.3).

Layout (one directory per step):
    <dir>/step_0000100/
        manifest.json            # tree structure, shapes, dtypes, hashes
        shard_<i>.npz            # leaf groups (per-host shards at scale)
    <dir>/LATEST                 # atomic pointer, written last

Writes go to the fast tier (Scale analogue = local disk) and block training
only for the serialize+fsync; the COS upload runs on a background thread
(AFM write-back analogue) and never gates the step loop.  Restores verify
content hashes and reshard onto whatever mesh the job restarts with (elastic
restart support).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

SHARD_LEAVES = 64     # leaves per npz shard file


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [("/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path), leaf) for path, leaf in flat]


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def save_checkpoint(directory: str, state, step: int,
                    uploader: Optional[Callable[[str, int], Any]] = None,
                    keep_last: int = 3) -> Dict:
    """Blocking local write; optional async upload callback(key, nbytes)."""
    d = Path(directory) / f"step_{step:08d}"
    tmp = Path(directory) / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    items = _flatten_with_paths(state)
    manifest = {"step": step, "format": 1, "leaves": [], "shards": []}
    t0 = time.perf_counter()
    total = 0
    for si in range(0, len(items), SHARD_LEAVES):
        group = items[si:si + SHARD_LEAVES]
        shard_name = f"shard_{si // SHARD_LEAVES:05d}.npz"
        arrays = {}
        for j, (path, leaf) in enumerate(group):
            arr = np.asarray(leaf)
            arrays[f"a{j}"] = arr
            manifest["leaves"].append({
                "path": path, "shard": shard_name, "key": f"a{j}",
                "shape": list(arr.shape), "dtype": str(arr.dtype)})
            total += arr.nbytes
        with open(tmp / shard_name, "wb") as f:
            np.savez(f, **arrays)
        digest = hashlib.sha256((tmp / shard_name).read_bytes()).hexdigest()
        manifest["shards"].append({"name": shard_name, "sha256": digest})
    manifest["nbytes"] = total
    manifest["write_seconds"] = time.perf_counter() - t0
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if d.exists():
        shutil.rmtree(d)
    os.replace(tmp, d)
    # LATEST pointer written last => crash-consistent
    latest = Path(directory) / "LATEST"
    latest_tmp = Path(directory) / ".LATEST.tmp"
    latest_tmp.write_text(d.name)
    os.replace(latest_tmp, latest)

    if uploader is not None:
        threading.Thread(target=uploader, args=(d.name, total),
                         daemon=True).start()
    _gc(directory, keep_last)
    return manifest


def _gc(directory: str, keep_last: int):
    steps = sorted(p for p in Path(directory).glob("step_*") if p.is_dir())
    for p in steps[:-keep_last]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    latest = Path(directory) / "LATEST"
    if not latest.exists():
        return None
    name = latest.read_text().strip()
    if not (Path(directory) / name / "manifest.json").exists():
        return None
    return int(name.split("_")[1])


def load_checkpoint(directory: str, step: Optional[int] = None,
                    template=None, shardings=None, verify: bool = True):
    """Restore a state pytree.  With ``template`` (pytree of like-structured
    arrays/ShapeDtypeStructs) the result is unflattened into that structure;
    with ``shardings`` each leaf is device_put accordingly (elastic reshard)."""
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoint under {directory}"
    d = Path(directory) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    if verify:
        for sh in manifest["shards"]:
            digest = hashlib.sha256((d / sh["name"]).read_bytes()).hexdigest()
            if digest != sh["sha256"]:
                raise IOError(f"checkpoint corruption in {sh['name']}")
    by_shard: Dict[str, Any] = {}
    leaves: Dict[str, np.ndarray] = {}
    for entry in manifest["leaves"]:
        if entry["shard"] not in by_shard:
            by_shard[entry["shard"]] = np.load(d / entry["shard"])
        leaves[entry["path"]] = by_shard[entry["shard"]][entry["key"]]

    if template is None:
        return leaves, step
    flat = _flatten_with_paths(template)
    out = []
    for path, leaf in flat:
        arr = leaves[path]
        assert list(arr.shape) == list(leaf.shape), (path, arr.shape,
                                                     leaf.shape)
        out.append(arr)
    treedef = jax.tree.structure(template)
    state = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    return state, step


class CheckpointManager:
    """Young's-interval checkpoint policy + async upload accounting."""

    def __init__(self, directory: str, delta_seconds: float,
                 mtbf_seconds: float, step_time: float,
                 uploader: Optional[Callable] = None, keep_last: int = 3):
        from repro.core.youngs import checkpoint_every_n_steps
        self.directory = directory
        self.every = checkpoint_every_n_steps(delta_seconds, mtbf_seconds,
                                              step_time)
        self.uploader = uploader
        self.keep_last = keep_last
        self.saves: List[int] = []

    def should_save(self, step: int) -> bool:
        return step > 0 and step % self.every == 0

    def save(self, state, step: int):
        m = save_checkpoint(self.directory, state, step,
                            uploader=self.uploader, keep_last=self.keep_last)
        self.saves.append(step)
        return m

    def restore(self, template=None, shardings=None):
        if latest_step(self.directory) is None:
            return None, None
        return load_checkpoint(self.directory, template=template,
                               shardings=shardings)
