"""Young's first-order optimal checkpoint interval [Young 1974], as used on
Vela (§2.3.3): t_checkpoint = sqrt(2·δ·M) with δ = time to write a checkpoint
and M = mean time between failures."""
from __future__ import annotations

import math


def young_interval(delta: float, mtbf: float) -> float:
    """Optimal seconds between checkpoints."""
    assert delta > 0 and mtbf > 0
    return math.sqrt(2.0 * delta * mtbf)


def lost_fraction(delta: float, mtbf: float, interval: float) -> float:
    """First-order expected fraction of wall time lost:
    checkpoint overhead δ/τ + expected recompute τ/(2M)."""
    assert interval > 0
    return delta / interval + interval / (2.0 * mtbf)


def optimal_lost_fraction(delta: float, mtbf: float) -> float:
    """= sqrt(2δ/M), the overhead at the Young interval."""
    return lost_fraction(delta, mtbf, young_interval(delta, mtbf))


def checkpoint_every_n_steps(delta: float, mtbf: float,
                             step_time: float) -> int:
    """The interval quantized to training steps (>= 1)."""
    return max(1, round(young_interval(delta, mtbf) / step_time))
