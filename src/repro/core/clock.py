"""Virtual/wall clock abstraction: the failure/storage simulators advance a
virtual clock so tests never sleep, while the same components run against the
wall clock in real deployments."""
from __future__ import annotations

import time


class Clock:
    def now(self) -> float:
        raise NotImplementedError

    def advance(self, dt: float) -> None:
        raise NotImplementedError


class WallClock(Clock):
    def now(self) -> float:
        return time.monotonic()

    def advance(self, dt: float) -> None:
        time.sleep(max(dt, 0.0))


class VirtualClock(Clock):
    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> None:
        assert dt >= 0, dt
        self._t += dt
