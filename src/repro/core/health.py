"""Autopilot analogue (§2.2.1): periodic node health checks exported as
Prometheus-style gauges with PASS/ERR labels.

Two check tiers, as in the paper:
  * light checks run while workloads are present (device gemm throughput,
    host<->device bandwidth, connectivity ping)
  * intrusive checks (dcgm-level-3 analogue) run only on free nodes.

On the simulated fleet, measured values are the real local microbenchmark
scaled by the node's degradation factor, so the alert thresholds exercise the
same code path a real deployment would.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.cluster import FailureKind, SimCluster
from repro.core.telemetry import MetricsRegistry


@dataclass
class CheckResult:
    name: str
    node_id: int
    value: float
    passed: bool
    unit: str = ""


def _measure_gemm_gflops(n: int = 256, iters: int = 2) -> float:
    """Local DGEMM microbenchmark (the paper's DCGM DGEMM diag analogue)."""
    import jax
    import jax.numpy as jnp
    a = jnp.ones((n, n), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    f(a).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        a = f(a)
    a.block_until_ready()
    dt = max(time.perf_counter() - t0, 1e-9)
    return 2 * n ** 3 * iters / dt / 1e9


def _measure_h2d_gbps(nbytes: int = 1 << 22) -> float:
    """Host->device transfer (PCIe bandwidth check analogue)."""
    import jax
    host = np.ones(nbytes, np.uint8)
    jax.device_put(host).block_until_ready()
    t0 = time.perf_counter()
    jax.device_put(host).block_until_ready()
    return nbytes / max(time.perf_counter() - t0, 1e-9) / 1e9


class HealthCheck:
    name = "base"
    level = "light"          # light | intrusive
    unit = ""

    def threshold(self, baseline: float) -> float:
        return 0.5 * baseline

    def measure(self) -> float:
        raise NotImplementedError


class GemmCheck(HealthCheck):
    name = "gpu_dgemm_gflops"
    unit = "GF/s"

    def measure(self) -> float:
        return _measure_gemm_gflops()


class PcieBandwidthCheck(HealthCheck):
    name = "pcie_h2d_gbps"
    unit = "GB/s"

    def threshold(self, baseline: float) -> float:
        # paper: alert when 12h average drops below the link-generation floor
        return 0.6 * baseline

    def measure(self) -> float:
        return _measure_h2d_gbps()


class PingCheck(HealthCheck):
    name = "net_ping_ok"
    unit = "bool"

    def threshold(self, baseline: float) -> float:
        return 0.5

    def measure(self) -> float:
        return 1.0


class Dcgm3Check(HealthCheck):
    """Deep diagnostics: intrusive, only on free nodes (finds HBM corruption
    that light checks miss — paper §2.3.2)."""
    name = "dcgm_level3_ok"
    level = "intrusive"
    unit = "bool"

    def threshold(self, baseline: float) -> float:
        return 0.5

    def measure(self) -> float:
        return 1.0


DEFAULT_CHECKS = (GemmCheck(), PcieBandwidthCheck(), PingCheck(),
                  Dcgm3Check())

# which failure kinds each check is sensitive to (simulation coupling)
_SENSITIVITY: Dict[str, List[FailureKind]] = {
    "gpu_dgemm_gflops": [FailureKind.POWER_BRAKE],
    "pcie_h2d_gbps": [FailureKind.PCIE_DEGRADE],
    "net_ping_ok": [FailureKind.PORT_FAILURE, FailureKind.HOST_CRASH],
    "dcgm_level3_ok": [FailureKind.ROW_REMAP, FailureKind.CUDA_ERROR],
}


class Autopilot:
    def __init__(self, cluster: SimCluster, registry: MetricsRegistry,
                 checks=DEFAULT_CHECKS, measure_real: bool = False):
        self.cluster = cluster
        self.reg = registry
        self.checks = checks
        self.measure_real = measure_real
        self._baselines: Dict[str, float] = {}

    def _baseline(self, check: HealthCheck) -> float:
        if check.name not in self._baselines:
            if self.measure_real and check.name in ("gpu_dgemm_gflops",
                                                    "pcie_h2d_gbps"):
                self._baselines[check.name] = check.measure()
            else:
                self._baselines[check.name] = {
                    "gpu_dgemm_gflops": 100.0, "pcie_h2d_gbps": 20.0,
                    "net_ping_ok": 1.0, "dcgm_level3_ok": 1.0,
                }[check.name]
        return self._baselines[check.name]

    def _simulated_value(self, check: HealthCheck, node) -> float:
        base = self._baseline(check)
        sens = _SENSITIVITY.get(check.name, [])
        hit = [k for k in node.active_failures if k in sens]
        if node.perf_factor == 0.0 and check.name == "net_ping_ok":
            return 0.0
        if not hit:
            return base
        if check.unit == "bool":
            return 0.0
        worst = min((0.375 if k == FailureKind.POWER_BRAKE else 0.3)
                    for k in hit)
        return base * worst

    def run_checks(self, node_ids: Optional[List[int]] = None,
                   busy: Optional[List[int]] = None) -> List[CheckResult]:
        """Light checks everywhere; intrusive only on free nodes."""
        busy = set(busy or [])
        results = []
        for node in self.cluster.nodes:
            if node_ids is not None and node.id not in node_ids:
                continue
            for check in self.checks:
                if check.level == "intrusive" and node.id in busy:
                    continue
                value = self._simulated_value(check, node)
                passed = value >= check.threshold(self._baseline(check))
                results.append(CheckResult(check.name, node.id, value, passed,
                                           check.unit))
                self.reg.gauge(f"autopilot_{check.name}").set(
                    value, {"node": str(node.id)})
                self.reg.gauge("autopilot_node_ok").set(
                    float(passed), {"node": str(node.id),
                                    "check": check.name})
        return results

    def err_nodes(self, results: List[CheckResult]) -> List[int]:
        return sorted({r.node_id for r in results if not r.passed})


def serve_light_checks(engine) -> Dict[str, bool]:
    """Light (non-intrusive) health checks over a live ``ServeEngine`` —
    the Autopilot idiom applied to the serving path, run in-loop every
    ``health_every`` iterations when an ``AlertManager`` is wired in.

    Exported as ``autopilot_serve_*`` gauges (1.0 = PASS, 0.0 = ERR) so the
    existing ``autopilot_err`` alert machinery and dashboards cover serving
    without new plumbing:

    * ``dispatch_invariant`` — exactly one fused decode+sample dispatch per
      decode iteration (the engine's core perf contract);
    * ``streams_progressing`` — no live slot has gone a full watchdog
      window (or 64 iterations when the watchdog is off) without emitting
      a token, landing a chunk, or being admitted;
    * ``cache_invariants`` — ``PagedCache.verify()`` holds (only measured
      in debug mode, ``verify_cache=True``, where its O(P + B·M) host walk
      is already being paid).

    Duck-typed on the engine (reg / slot_req / watchdog / kv attrs), so it
    needs no import of the serve package."""
    reg = engine.reg
    results: Dict[str, bool] = {}
    iters = reg.counter("serve_iterations_total").get()
    disp = reg.counter("serve_decode_dispatches_total").get()
    results["dispatch_invariant"] = disp == iters
    window = engine.watchdog_iters or 64
    results["streams_progressing"] = not any(
        req is not None
        and engine._iter - engine._last_progress.get(slot, engine._iter)
        >= window
        for slot, req in enumerate(engine.slot_req))
    if engine.verify_cache and hasattr(engine.kv, "verify"):
        try:
            engine.kv.verify()
            results["cache_invariants"] = True
        except AssertionError:        # CacheInvariantError subclasses it
            results["cache_invariants"] = False
    for name, passed in results.items():
        reg.gauge(f"autopilot_serve_{name}").set(float(passed))
        reg.gauge("autopilot_node_ok").set(
            float(passed), {"node": "serve", "check": f"serve_{name}"})
    return results
