"""The fault-tolerant training runtime: cluster sim + scheduler + autopilot +
alerting + Young-interval checkpointing composed into a job lifecycle
(§2.3 end-to-end).  ``simulate_job`` validates the paper's headline claim —
<10% of wall time lost to failures — under the paper's own failure rates;
``FTTrainLoop`` applies the same mechanics to a real (CPU) jax training loop
with real file checkpoints (used by tests and examples)."""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.alerts import AlertManager, SlackSink
from repro.core.cluster import (DEFAULT_RATES, FailureKind, NodeState,
                                SimCluster)
from repro.core.health import Autopilot
from repro.core.scheduler import GangScheduler, Job, JobState
from repro.core.straggler import StragglerDetector
from repro.core.telemetry import MetricsRegistry
from repro.core.youngs import young_interval

# failure kinds that stop the job outright
_CRASH_KINDS = (FailureKind.HOST_CRASH, FailureKind.CUDA_ERROR)


@dataclass
class GoodputReport:
    total_s: float = 0.0
    useful_s: float = 0.0
    checkpoint_s: float = 0.0
    recompute_s: float = 0.0
    detection_s: float = 0.0
    restart_s: float = 0.0
    degraded_s: float = 0.0       # extra time spent running slow
    queue_s: float = 0.0          # waiting for nodes
    steps_done: int = 0
    restarts: int = 0
    node_swaps: int = 0
    failures: Dict[str, int] = field(default_factory=dict)
    checkpoint_interval_steps: int = 0

    @property
    def lost_fraction(self) -> float:
        return 1.0 - self.useful_s / self.total_s if self.total_s else 0.0

    def summary(self) -> str:
        f = self
        return (f"total={f.total_s/3600:.1f}h useful={f.useful_s/3600:.1f}h "
                f"lost={f.lost_fraction*100:.1f}% "
                f"(ckpt={f.checkpoint_s/3600:.2f}h "
                f"recompute={f.recompute_s/3600:.2f}h "
                f"detect={f.detection_s/3600:.2f}h "
                f"restart={f.restart_s/3600:.2f}h "
                f"degraded={f.degraded_s/3600:.2f}h "
                f"queue={f.queue_s/3600:.2f}h) "
                f"restarts={f.restarts} swaps={f.node_swaps}")


def job_mtbf_seconds(n_nodes: int, rates=None) -> float:
    rates = rates or DEFAULT_RATES
    crash_rate = sum(r for k, r in rates.items() if k in _CRASH_KINDS)
    return 1.0 / (crash_rate * n_nodes)


def simulate_job(n_cluster_nodes: int = 110, job_nodes: int = 96,
                 total_steps: int = 200_000, base_step_time: float = 5.0,
                 ckpt_write_seconds: float = 90.0,
                 detection_latency: float = 120.0,
                 restart_overhead: float = 600.0,
                 straggler_factor: float = 1.25,
                 buffer_fraction: float = 0.10,
                 seed: int = 0,
                 registry: Optional[MetricsRegistry] = None,
                 rates=None) -> GoodputReport:
    """Virtual-time simulation of one long training job under the paper's
    failure model.  Checkpoint interval = Young's formula."""
    reg = registry or MetricsRegistry()
    cluster = SimCluster(n_cluster_nodes, seed=seed, registry=reg,
                         rates=rates)
    sched = GangScheduler(cluster, buffer_fraction, reg)
    detector = StragglerDetector(reg, factor=straggler_factor)
    alerts = AlertManager(reg, sinks=[SlackSink()])
    autopilot = Autopilot(cluster, reg)

    mtbf = job_mtbf_seconds(job_nodes, rates)
    interval_s = young_interval(ckpt_write_seconds, mtbf)
    ckpt_every = max(1, round(interval_s / base_step_time))

    job = Job("train", job_nodes, rerunnable=True)
    sched.submit(job)

    rep = GoodputReport(checkpoint_interval_steps=ckpt_every)
    rng = np.random.default_rng(seed + 7)
    step = 0
    last_ckpt_step = 0
    check_every_steps = max(1, round(600.0 / base_step_time))  # 10-min checks

    while step < total_steps:
        if job.state != JobState.RUNNING:
            # wait for repairs / scheduling
            cluster.advance(60.0)
            rep.total_s += 60.0
            rep.queue_s += 60.0
            sched.schedule()
            continue

        perf = cluster.job_perf_factor(job.nodes)
        crashed = cluster.crashed_in(job.nodes)
        if crashed or perf == 0.0:
            # --- crash path: detect -> requeue -> restore -> recompute ------
            rep.total_s += detection_latency
            rep.detection_s += detection_latency
            cluster.advance(detection_latency)
            for n in (crashed or job.nodes[:1]):
                sched.on_node_failure(n)
            alerts.evaluate()
            rep.total_s += restart_overhead
            rep.restart_s += restart_overhead
            cluster.advance(restart_overhead)
            recompute_steps = step - last_ckpt_step
            rep.recompute_s += recompute_steps * base_step_time
            rep.total_s += recompute_steps * base_step_time
            cluster.advance(recompute_steps * base_step_time)
            step = last_ckpt_step + recompute_steps  # recompute is not useful
            rep.restarts += 1
            continue

        # --- run one step at the slowest node's speed -----------------------
        dt = base_step_time / perf
        cluster.advance(dt)
        rep.total_s += dt
        rep.useful_s += base_step_time
        rep.degraded_s += dt - base_step_time
        detector.observe_step(dt)
        step += 1
        rep.steps_done = step

        # --- periodic health checks + straggler mitigation ------------------
        # proactive posture (§2.3.2): autopilot localizes the bad node even
        # when the step-time baseline is already polluted by the slowdown
        if step % check_every_steps == 0:
            autopilot.run_checks(node_ids=job.nodes, busy=job.nodes)
            detector.check(cluster, job.nodes)   # exported for alerting
            degraded = cluster.degraded_in(job.nodes)
            if degraded:
                if sched.replace_degraded(job.id, degraded):
                    rep.node_swaps += len(degraded)
                    rep.total_s += restart_overhead
                    rep.restart_s += restart_overhead
                    cluster.advance(restart_overhead)
                    recompute_steps = step - last_ckpt_step
                    rep.recompute_s += recompute_steps * base_step_time
                    rep.total_s += recompute_steps * base_step_time
                    cluster.advance(recompute_steps * base_step_time)
            alerts.evaluate()

        # --- Young-interval checkpoint --------------------------------------
        if step - last_ckpt_step >= ckpt_every:
            rep.total_s += ckpt_write_seconds
            rep.checkpoint_s += ckpt_write_seconds
            cluster.advance(ckpt_write_seconds)
            last_ckpt_step = step

    rep.failures = {k.value: sum(1 for e in cluster.events if e.kind == k)
                    for k in FailureKind}
    return rep


class FTTrainLoop:
    """Wraps a real jax train step with checkpoint/restart + failure
    injection.  ``run`` survives injected failures by restoring the latest
    checkpoint — loss trajectories with and without failures must agree
    (tested in tests/test_ft.py)."""

    def __init__(self, train_step: Callable, init_state, ckpt_dir: str,
                 ckpt_every: int, registry: Optional[MetricsRegistry] = None,
                 uploader: Optional[Callable] = None):
        from repro.core.checkpoint import (latest_step, load_checkpoint,
                                           save_checkpoint)
        self._save = save_checkpoint
        self._load = load_checkpoint
        self._latest = latest_step
        self.train_step = train_step
        self.init_state = init_state
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.reg = registry or MetricsRegistry()
        self.uploader = uploader
        self.metrics_log: List[Dict] = []
        self.restarts = 0

    def _restore_or_init(self):
        if self._latest(self.ckpt_dir) is None:
            return self.init_state, 0
        state, step = self._load(self.ckpt_dir, template=self.init_state)
        return state, step

    def run(self, batches: Callable[[int], Dict], total_steps: int,
            fail_at: Optional[Callable[[int], bool]] = None):
        """``batches(step)`` yields the batch for a step (deterministic data
        order => failure-free and failure-injected runs are comparable).
        ``fail_at(step)`` True simulates a host crash at that step: progress
        since the last checkpoint is discarded and the loop restarts."""
        import time as _time
        state, step = self._restore_or_init()
        while step < total_steps:
            if fail_at is not None and fail_at(step) and \
                    self._pending_failure(step):
                self.restarts += 1
                self.reg.counter("job_restarts").inc()
                state, step = self._restore_or_init()
                continue
            t0 = _time.perf_counter()
            state, metrics = self.train_step(state, batches(step))
            dt = _time.perf_counter() - t0
            self.reg.histogram("train_step_seconds").observe(dt)
            self.metrics_log.append(
                {"step": step, **{k: float(v) for k, v in metrics.items()}})
            step += 1
            if step % self.ckpt_every == 0:
                self._save(self.ckpt_dir, state, step, uploader=self.uploader)
                self.reg.counter("checkpoints_written").inc()
        return state

    _fired: set

    def _pending_failure(self, step: int) -> bool:
        if not hasattr(self, "_fired"):
            self._fired = set()
        if step in self._fired:
            return False
        self._fired.add(step)
        return True
