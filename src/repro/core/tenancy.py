"""Multi-tenant resource management (§2.2): the OpenShift namespace/quota
layer over the gang scheduler — platform administrators provision quotas per
project, researchers submit within them, and capacity can be moved between
tenants (the paper's "resources are moved between clusters for training and
inference services based on business needs").

The **priority-class registry** lives here because both resource layers
share it: cluster-level namespaces (this module — nodes are the resource)
and the serving-level SLO scheduler (``repro.serve.tenancy`` — KV pages are
the resource) map the same class names onto the same relative priorities,
so "interactive outranks batch" means one thing across the whole stack.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.scheduler import GangScheduler, Job, JobState
from repro.core.telemetry import MetricsRegistry


@dataclass(frozen=True)
class PriorityClass:
    """One SLO class: a name, a strict priority (higher wins admission and
    is never preempted by lower), whether members may be preempted under
    resource pressure, and an optional per-iteration chunked-prefill token
    budget (serving only; ``None`` = bounded only by the engine's global
    budget)."""
    name: str
    priority: int
    preemptible: bool = True
    prefill_budget: Optional[int] = None


#: TTFT-sensitive traffic: admitted first, never preempted.
INTERACTIVE = PriorityClass("interactive", 100, preemptible=False)
#: Throughput traffic: yields pages/slots to interactive under pressure.
BATCH = PriorityClass("batch", 0, preemptible=True)

DEFAULT_CLASSES: Dict[str, PriorityClass] = {
    INTERACTIVE.name: INTERACTIVE, BATCH.name: BATCH}


@dataclass
class Namespace:
    name: str
    quota_nodes: int
    used_nodes: int = 0
    priority: int = 0

    @property
    def available(self) -> int:
        return self.quota_nodes - self.used_nodes


class TenantScheduler:
    """Quota-enforcing facade over GangScheduler."""

    def __init__(self, sched: GangScheduler,
                 registry: Optional[MetricsRegistry] = None):
        self.sched = sched
        self.namespaces: Dict[str, Namespace] = {}
        self.job_ns: Dict[str, str] = {}
        self.reg = registry

    def create_namespace(self, name: str, quota_nodes: int,
                         priority: int = 0) -> Namespace:
        total_quota = sum(n.quota_nodes for n in self.namespaces.values())
        assert total_quota + quota_nodes <= len(self.sched.cluster.nodes), \
            "quota overcommit"
        ns = Namespace(name, quota_nodes, priority=priority)
        self.namespaces[name] = ns
        if self.reg:
            self.reg.gauge("tenant_quota_nodes").set(quota_nodes,
                                                     {"namespace": name})
        return ns

    def resize_namespace(self, name: str, quota_nodes: int):
        """Move capacity between tenants (training <-> inference shifts)."""
        ns = self.namespaces[name]
        assert quota_nodes >= ns.used_nodes, "shrink below usage"
        others = sum(n.quota_nodes for n in self.namespaces.values()
                     if n.name != name)
        assert others + quota_nodes <= len(self.sched.cluster.nodes)
        ns.quota_nodes = quota_nodes
        if self.reg:
            self.reg.gauge("tenant_quota_nodes").set(quota_nodes,
                                                     {"namespace": name})

    def submit(self, namespace: str, job: Job) -> bool:
        ns = self.namespaces[namespace]
        if job.n_nodes > ns.available:
            if self.reg:
                self.reg.counter("tenant_quota_rejections").inc(
                    1, {"namespace": namespace})
            return False
        ns.used_nodes += job.n_nodes
        self.job_ns[job.id] = namespace
        job.priority = max(job.priority, ns.priority)
        self.sched.submit(job)
        if self.reg:
            self.reg.gauge("tenant_used_nodes").set(
                ns.used_nodes, {"namespace": namespace})
        return True

    def complete(self, job_id: str):
        ns = self.namespaces[self.job_ns.pop(job_id)]
        job = self.sched.jobs[job_id]
        ns.used_nodes -= job.n_nodes
        self.sched.complete(job_id)

    def usage_report(self) -> List[str]:
        return [f"{ns.name}: {ns.used_nodes}/{ns.quota_nodes} nodes "
                f"(prio {ns.priority})"
                for ns in self.namespaces.values()]
