"""Two-tier storage: object store (COS analogue) fronted by a parallel-FS
write-back cache (Spectrum Scale + AFM analogue, §2.1.3).

Bandwidth/latency constants are the paper's published numbers:
  COS           5 GB/s write path,   high per-op latency
  NFS           1 GB/s read,         heavy contention variance (~50% step jitter)
  Scale cache   40 GB/s read / 15 GB/s write, low variance

The simulator charges transfer costs against a (virtual or wall) clock and
exports cache/traffic metrics; the AFM queue drains asynchronously so writes
(checkpoints) never gate the training job — reproducing Fig 7's behaviour in
`benchmarks/bench_storage.py`.
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.core.clock import Clock, VirtualClock
from repro.core.telemetry import MetricsRegistry

GB = 1e9


@dataclass
class TierSpec:
    name: str
    read_bw: float               # bytes/s
    write_bw: float
    latency: float               # per-op seconds
    jitter: float = 0.0          # multiplicative stddev on op duration


COS = TierSpec("cos", read_bw=2 * GB, write_bw=5 * GB, latency=0.10,
               jitter=0.20)
NFS = TierSpec("nfs", read_bw=1 * GB, write_bw=0.8 * GB, latency=0.01,
               jitter=0.50)    # limited concurrency -> ~50% variance (paper)
SCALE = TierSpec("scale", read_bw=40 * GB, write_bw=15 * GB, latency=0.001,
                 jitter=0.05)


class BlobStore:
    """One storage tier: keeps blob sizes (contents optional) and charges
    transfer time against the clock."""

    def __init__(self, spec: TierSpec, clock: Clock,
                 registry: Optional[MetricsRegistry] = None, seed: int = 0):
        self.spec = spec
        self.clock = clock
        self.blobs: Dict[str, int] = {}
        self.data: Dict[str, bytes] = {}
        self.rng = np.random.default_rng(seed)
        self.reg = registry
        self._lock = threading.Lock()

    def _charge(self, seconds: float, op: str):
        if self.spec.jitter:
            seconds *= max(0.05, 1.0 + self.rng.normal(0, self.spec.jitter))
        self.clock.advance(seconds)
        if self.reg:
            self.reg.histogram("storage_op_seconds").observe(
                seconds, {"tier": self.spec.name, "op": op})
        return seconds

    def write(self, key: str, nbytes: int, payload: Optional[bytes] = None):
        t = self._charge(self.spec.latency + nbytes / self.spec.write_bw,
                         "write")
        with self._lock:
            self.blobs[key] = nbytes
            if payload is not None:
                self.data[key] = payload
        if self.reg:
            self.reg.counter("storage_bytes_written").inc(
                nbytes, {"tier": self.spec.name})
        return t

    def read(self, key: str) -> float:
        nbytes = self.blobs[key]
        t = self._charge(self.spec.latency + nbytes / self.spec.read_bw,
                         "read")
        if self.reg:
            self.reg.counter("storage_bytes_read").inc(
                nbytes, {"tier": self.spec.name})
        return t

    def exists(self, key: str) -> bool:
        return key in self.blobs

    def size(self, key: str) -> int:
        return self.blobs[key]


class ScaleCache:
    """AFM-style read-write cache over an object store.

    * read miss: fetch from COS into cache (charged at COS read bw), then
      serve at cache speed; hit: cache speed only.
    * write: lands in the cache at Scale speed and is queued for async
      upload to COS; ``drain_async()`` models the background AFM mover and
      charges its time to a *separate* clock so the training job isn't gated.
    * LRU eviction of clean (uploaded) entries when over capacity.
    """

    def __init__(self, backing: BlobStore, clock: Clock,
                 capacity_bytes: float = 140e12,   # 140 TB (paper)
                 spec: TierSpec = SCALE,
                 registry: Optional[MetricsRegistry] = None, seed: int = 1):
        self.cache = BlobStore(spec, clock, registry, seed)
        self.backing = backing
        self.clock = clock
        self.capacity = capacity_bytes
        self.lru: "OrderedDict[str, int]" = OrderedDict()
        self.dirty: Dict[str, int] = {}
        self.reg = registry
        self.async_clock = VirtualClock()   # AFM mover's own timeline

    @property
    def used(self) -> int:
        return sum(self.lru.values())

    def _touch(self, key: str, nbytes: int):
        self.lru.pop(key, None)
        self.lru[key] = nbytes
        self._evict()

    def _evict(self):
        while self.used > self.capacity:
            for key in list(self.lru):
                if key not in self.dirty:      # only clean entries evictable
                    self.lru.pop(key)
                    if self.reg:
                        self.reg.counter("scale_evictions").inc()
                    break
            else:
                break   # everything dirty: AFM must drain first

    def read(self, key: str) -> float:
        if key in self.lru:
            if self.reg:
                self.reg.counter("scale_cache_hits").inc()
            t = self.cache._charge(
                self.cache.spec.latency
                + self.lru[key] / self.cache.spec.read_bw, "read")
            self._touch(key, self.lru[key])
            return t
        if self.reg:
            self.reg.counter("scale_cache_misses").inc()
        t = self.backing.read(key)            # on-demand AFM fetch
        nbytes = self.backing.size(key)
        self._touch(key, nbytes)
        return t

    def write(self, key: str, nbytes: int) -> float:
        t = self.cache.write(key, nbytes)
        self.dirty[key] = nbytes
        self._touch(key, nbytes)
        if self.reg:
            self.reg.gauge("scale_dirty_bytes").set(sum(self.dirty.values()))
        return t

    def drain_async(self) -> float:
        """Background AFM upload of dirty entries; returns mover seconds spent
        (NOT charged to the foreground clock)."""
        total = 0.0
        for key in list(self.dirty):
            nbytes = self.dirty.pop(key)
            saved_clock = self.backing.clock
            self.backing.clock = self.async_clock
            try:
                total += self.backing.write(key, nbytes)
            finally:
                self.backing.clock = saved_clock
        if self.reg:
            self.reg.gauge("scale_dirty_bytes").set(0.0)
        return total


@dataclass
class StorageStack:
    """What a training job sees: dataset reads + checkpoint writes through a
    selected tier ('scale' | 'nfs' | 'cos')."""
    clock: Clock
    registry: Optional[MetricsRegistry] = None
    seed: int = 0
    cos: BlobStore = field(init=False)
    nfs: BlobStore = field(init=False)
    scale: ScaleCache = field(init=False)

    def __post_init__(self):
        self.cos = BlobStore(COS, self.clock, self.registry, self.seed)
        self.nfs = BlobStore(NFS, self.clock, self.registry, self.seed + 1)
        self.scale = ScaleCache(self.cos, self.clock,
                                registry=self.registry, seed=self.seed + 2)

    def dataset_read(self, key: str, tier: str) -> float:
        if tier == "scale":
            return self.scale.read(key)
        if tier == "nfs":
            if not self.nfs.exists(key):
                self.nfs.blobs[key] = self.cos.size(key)
            return self.nfs.read(key)
        return self.cos.read(key)

    def checkpoint_write(self, key: str, nbytes: int, tier: str) -> float:
        if tier == "scale":
            return self.scale.write(key, nbytes)
        return self.cos.write(key, nbytes)
