"""AIOps anomaly detection over cluster telemetry (§3.6 "using AIOps for
anomaly detection in cluster operational data" — the paper's stated future
direction, implemented here as a robust-statistics detector).

Per metric series: a rolling median/MAD baseline; a point is anomalous when
its robust z-score exceeds the threshold for `persistence` consecutive
samples (the paper's 12-sample-average philosophy: no single-sample alarms).
"""
from __future__ import annotations

from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.telemetry import MetricsRegistry


@dataclass
class Anomaly:
    metric: str
    labels: Dict[str, str]
    value: float
    zscore: float
    message: str


class AnomalyDetector:
    def __init__(self, window: int = 64, threshold: float = 4.0,
                 persistence: int = 3, min_history: int = 12):
        self.window = window
        self.threshold = threshold
        self.persistence = persistence
        self.min_history = min_history
        self._hist: Dict[Tuple, Deque[float]] = defaultdict(
            lambda: deque(maxlen=window))
        self._streak: Dict[Tuple, int] = defaultdict(int)

    def observe(self, metric: str, labels: Dict[str, str],
                value: float) -> Optional[Anomaly]:
        key = (metric, tuple(sorted(labels.items())))
        hist = self._hist[key]
        anomaly = None
        if len(hist) >= self.min_history:
            arr = np.asarray(hist)
            med = float(np.median(arr))
            mad = float(np.median(np.abs(arr - med))) or 1e-9
            z = 0.6745 * (value - med) / mad
            if abs(z) > self.threshold:
                self._streak[key] += 1
                if self._streak[key] >= self.persistence:
                    anomaly = Anomaly(
                        metric, labels, value, z,
                        f"{metric}{labels} robust-z={z:+.1f} "
                        f"({value:.3g} vs median {med:.3g}) for "
                        f"{self._streak[key]} consecutive samples")
            else:
                self._streak[key] = 0
        hist.append(value)
        return anomaly

    def scan_registry(self, reg: MetricsRegistry) -> List[Anomaly]:
        """Feed every gauge series' current value through the detector."""
        out = []
        for name, series in reg.snapshot().items():
            for ls, v in series.items():
                a = self.observe(name, dict(ls), v)
                if a:
                    out.append(a)
        return out


def render_dashboard(reg: MetricsRegistry, title: str = "cluster") -> str:
    """Text 'Grafana' panel (§3.4): per-node health, job throughput, storage
    and scheduler gauges in one terminal-friendly table."""
    snap = reg.snapshot()
    lines = [f"== {title} dashboard ==".upper()]

    def section(header: str, metric: str, fmt=lambda v: f"{v:.3g}"):
        series = snap.get(metric)
        if not series:
            return
        lines.append(f"-- {header}")
        for ls, v in sorted(series.items()):
            lbl = ",".join(f"{k}={v2}" for k, v2 in ls) or "(all)"
            lines.append(f"   {lbl:40s} {fmt(v)}")

    section("node performance factor", "node_perf_factor")
    section("autopilot checks (1=PASS)", "autopilot_node_ok",
            lambda v: "PASS" if v else "ERR")
    section("failures", "cluster_failures_total")
    section("scheduler", "scheduler_job_starts")
    section("node swaps", "scheduler_node_swaps")
    section("tenant quotas", "tenant_quota_nodes")
    section("tenant usage", "tenant_used_nodes")
    section("storage dirty bytes", "scale_dirty_bytes")
    section("checkpoints", "checkpoints_written")
    h = reg._metrics.get("train_step_seconds")
    if h is not None:
        lines.append("-- train step seconds (p50/p95)")
        for ls, _ in h.labels_values():
            labels = dict(ls)
            lines.append(f"   {labels or '(all)'}  "
                         f"{h.quantile(0.5, labels):.3f}/"
                         f"{h.quantile(0.95, labels):.3f}")
    return "\n".join(lines)
