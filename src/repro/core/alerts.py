"""Alert rules engine (§2.3.2): the paper's Activity-Tracker/LogDNA/
Alertmanager -> Slack pipeline, reproduced as rules over the metrics registry
with pluggable sinks.  Default rules mirror the paper's alert set:
node-down, NVSwitch fatal, CUDA error, PCIe degradation (12-sample trailing
average, eliminating false positives), power-brake active, row-remap pending,
and step-time regression."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

from repro.core.telemetry import MetricsRegistry


@dataclass
class Alert:
    rule: str
    severity: str
    message: str
    labels: Dict[str, str] = field(default_factory=dict)


@dataclass
class AlertRule:
    name: str
    severity: str
    # predicate over the registry; returns list of (labels, message)
    evaluate: Callable[[MetricsRegistry], List]


class SlackSink:
    """Collects messages like the paper's Slack webhook integration."""

    def __init__(self):
        self.messages: List[str] = []

    def send(self, alert: Alert):
        self.messages.append(
            f":rotating_light: [{alert.severity.upper()}] {alert.rule}: "
            f"{alert.message}")


class LogSink:
    def __init__(self):
        self.records: List[Alert] = []

    def send(self, alert: Alert):
        self.records.append(alert)


def _gauge_series(reg: MetricsRegistry, name: str):
    snap = reg.snapshot().get(name, {})
    return [(dict(ls), v) for ls, v in snap.items()]


def node_down_rule() -> AlertRule:
    def ev(reg):
        out = []
        for labels, v in _gauge_series(reg, "node_perf_factor"):
            if v == 0.0:
                out.append((labels,
                            f"node {labels.get('node')} is down "
                            "(VM stopped / host crash)"))
        return out
    return AlertRule("node_down", "critical", ev)


def autopilot_err_rule() -> AlertRule:
    def ev(reg):
        out = []
        for labels, v in _gauge_series(reg, "autopilot_node_ok"):
            if v == 0.0:
                out.append((labels,
                            f"health check {labels.get('check')} ERR on "
                            f"node {labels.get('node')}"))
        return out
    return AlertRule("autopilot_err", "warning", ev)


def pcie_degraded_rule(threshold_gbps: float = 12.0,
                       samples: int = 12) -> AlertRule:
    """Paper: average 12 hourly samples before alerting (no false positives)."""
    def ev(reg):
        out = []
        hist = reg._metrics.get("pcie_bw_sample")
        if hist is None:
            return out
        for ls, _ in hist.labels_values():
            labels = dict(ls)
            recent = hist.recent(samples, labels)
            if len(recent) >= samples and \
                    sum(recent) / len(recent) < threshold_gbps:
                out.append((labels,
                            f"PCIe bandwidth degraded on node "
                            f"{labels.get('node')}: "
                            f"{sum(recent)/len(recent):.1f} GB/s 12-sample avg"))
        return out
    return AlertRule("pcie_degraded", "warning", ev)


def step_time_regression_rule(factor: float = 1.3,
                              window: int = 16) -> AlertRule:
    """Job-level slowdown (e.g. the 3x power-brake incident on 768 GPUs)."""
    def ev(reg):
        hist = reg._metrics.get("train_step_seconds")
        if hist is None:
            return []
        out = []
        for ls, _ in hist.labels_values():
            labels = dict(ls)
            recent = hist.recent(window, labels)
            if len(recent) < window:
                continue
            base = sorted(recent)[len(recent) // 2]
            if recent[-1] > factor * base and base > 0:
                out.append((labels,
                            f"step time regression: {recent[-1]:.2f}s vs "
                            f"median {base:.2f}s (x{recent[-1]/base:.1f})"))
        return out
    return AlertRule("step_time_regression", "warning", ev)


def cuda_error_rule() -> AlertRule:
    def ev(reg):
        c = reg._metrics.get("cuda_errors_total")
        if c is None:
            return []
        return [(dict(ls), f"CUDA error on pod {dict(ls).get('node')}")
                for ls, v in c.labels_values() if v > 0]
    return AlertRule("gpu_cuda_error", "critical", ev)


def serve_dead_letter_rule() -> AlertRule:
    """A request terminally failed recovery (retries exhausted / capacity
    lost after a chip failure) — the serving analogue of an unrecoverable
    node error, so critical like ``gpu_cuda_error``."""
    def ev(reg):
        c = reg._metrics.get("serve_dead_letter_total")
        if c is None:
            return []
        return [(dict(ls),
                 f"{v:.0f} request(s) dead-lettered "
                 f"(reason: {dict(ls).get('reason', '?')})")
                for ls, v in c.labels_values() if v > 0 and ls]
    return AlertRule("serve_dead_letter", "critical", ev)


def serve_retry_storm_rule(threshold: int = 8) -> AlertRule:
    """Recoveries are normal in ones and twos; a pile-up under one reason
    label means a persistent fault the retry loop cannot clear."""
    def ev(reg):
        c = reg._metrics.get("serve_stream_retries_total")
        if c is None:
            return []
        return [(dict(ls),
                 f"{v:.0f} stream recoveries "
                 f"(reason: {dict(ls).get('reason', '?')}) — "
                 "persistent fault suspected")
                for ls, v in c.labels_values() if v >= threshold and ls]
    return AlertRule("serve_retry_storm", "warning", ev)


DEFAULT_RULES = (node_down_rule, autopilot_err_rule, pcie_degraded_rule,
                 step_time_regression_rule, cuda_error_rule)

#: the serving-path rule set: pass ``rules=DEFAULT_RULES + SERVE_RULES``
#: (or just ``SERVE_RULES``) to an AlertManager wired into a ServeEngine
SERVE_RULES = (serve_dead_letter_rule, serve_retry_storm_rule)


class AlertManager:
    def __init__(self, registry: MetricsRegistry, sinks=None, rules=None):
        self.reg = registry
        self.sinks = list(sinks) if sinks is not None else [SlackSink()]
        self.rules = [r() for r in (rules or DEFAULT_RULES)]
        self.fired: List[Alert] = []
        self._dedup = set()

    def evaluate(self) -> List[Alert]:
        new = []
        for rule in self.rules:
            for labels, msg in rule.evaluate(self.reg):
                key = (rule.name, tuple(sorted(labels.items())), msg)
                if key in self._dedup:
                    continue
                self._dedup.add(key)
                alert = Alert(rule.name, rule.severity, msg, labels)
                new.append(alert)
                for s in self.sinks:
                    s.send(alert)
        self.fired.extend(new)
        return new
