"""Straggler detection & localization (§2.3.1): a single throttled node drags
the whole gang to its speed (the Granite-20B 768-GPU 3x incident).  Detection
is job-level (step-time regression vs trailing median); localization is
node-level (autopilot gauges: power-brake counters / per-node GEMM
throughput), mirroring the paper's nvidia-smi power-break counter approach."""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional

from repro.core.cluster import SimCluster
from repro.core.telemetry import MetricsRegistry


@dataclass
class StragglerReport:
    detected: bool
    slowdown: float
    suspect_nodes: List[int]
    reason: str = ""


class StragglerDetector:
    def __init__(self, registry: MetricsRegistry, factor: float = 1.25,
                 window: int = 16, min_samples: int = 4):
        self.reg = registry
        self.factor = factor
        self.window = window
        self.min_samples = min_samples

    def observe_step(self, seconds: float, job: str = "default"):
        self.reg.histogram("train_step_seconds").observe(
            seconds, {"job": job})

    def check(self, cluster: Optional[SimCluster] = None,
              node_ids: Optional[List[int]] = None,
              job: str = "default") -> StragglerReport:
        hist = self.reg._metrics.get("train_step_seconds")
        if hist is None:
            return StragglerReport(False, 1.0, [])
        recent = hist.recent(self.window, {"job": job})
        if len(recent) < self.min_samples:
            return StragglerReport(False, 1.0, [])
        # long-term baseline (p25 of full history): a persistent slowdown must
        # not poison its own reference (the 3x incident ran for a while before
        # being diagnosed — the baseline has to remember healthy speed)
        base = hist.quantile(0.25, {"job": job})
        # median of the last few steps: persistent slowdowns trigger fast,
        # single hiccups don't (the paper averages 12 samples for the same
        # false-positive reason)
        tail = recent[-self.min_samples:]
        cur = sorted(tail)[len(tail) // 2]
        slowdown = cur / base if base > 0 else 1.0
        if slowdown < self.factor:
            return StragglerReport(False, slowdown, [])
        suspects: List[int] = []
        reason = "step-time regression"
        if cluster is not None and node_ids:
            suspects = cluster.degraded_in(node_ids)
            if suspects:
                kinds = {k.value for i in suspects
                         for k in cluster.nodes[i].active_failures}
                reason = f"degraded nodes {suspects}: {sorted(kinds)}"
        return StragglerReport(True, slowdown, suspects, reason)
