"""LSF/MCAD-style gang scheduler with a buffer pool (§2.3.1, §3.2.2).

Semantics reproduced from the paper:
  * gang allocation: a job runs only when its full node count is available;
  * ~10% of nodes kept as a hot buffer so failed jobs restart at full size
    immediately; the buffer is replenished as repairs complete;
  * rerunnable jobs are requeued on node failure (LSF ``rerunnable``),
    non-rerunnable jobs are lost;
  * failed nodes enter a repair queue (vendor RMA vs quick reboot times);
  * priority scheduling with optional preemption of lower-priority jobs.
"""
from __future__ import annotations

import enum
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.cluster import NodeState, SimCluster
from repro.core.telemetry import MetricsRegistry


class JobState(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    DONE = "done"
    FAILED = "failed"


@dataclass
class Job:
    id: str
    n_nodes: int
    rerunnable: bool = True
    priority: int = 0
    state: JobState = JobState.PENDING
    nodes: List[int] = field(default_factory=list)
    restarts: int = 0
    preemptions: int = 0


class GangScheduler:
    def __init__(self, cluster: SimCluster, buffer_fraction: float = 0.10,
                 registry: Optional[MetricsRegistry] = None):
        self.cluster = cluster
        self.buffer_fraction = buffer_fraction
        self.jobs: Dict[str, Job] = {}
        self.queue: List[str] = []
        self.reg = registry
        self._allocated: set = set()

    # ------------------------------------------------------------- public ----
    def submit(self, job: Job):
        assert job.id not in self.jobs
        self.jobs[job.id] = job
        self.queue.append(job.id)
        self.schedule()

    @property
    def buffer_target(self) -> int:
        return max(1, int(self.buffer_fraction * len(self.cluster.nodes)))

    def free_healthy(self) -> List[int]:
        return [n.id for n in self.cluster.healthy_nodes()
                if n.id not in self._allocated]

    def schedule(self):
        """FIFO within priority; keep the buffer for restarts: new PENDING
        jobs may not dip into the last ``buffer_target`` free nodes, but a
        RESTARTING job (restarts>0) may — that is what the buffer is for."""
        for jid in sorted(self.queue,
                          key=lambda j: (-self.jobs[j].priority,)):
            job = self.jobs[jid]
            free = self.free_healthy()
            usable = (len(free) if job.restarts > 0
                      else len(free) - self.buffer_target)
            if usable >= job.n_nodes:
                job.nodes = free[:job.n_nodes]
                self._allocated.update(job.nodes)
                job.state = JobState.RUNNING
                self.queue.remove(jid)
                if self.reg:
                    self.reg.counter("scheduler_job_starts").inc(
                        1, {"job": jid})

    def on_node_failure(self, node_id: int):
        """Failure detected: repair the node, requeue affected rerunnable
        jobs at restart priority."""
        self.cluster.start_repair(node_id)
        self._allocated.discard(node_id)
        for job in self.jobs.values():
            if job.state == JobState.RUNNING and node_id in job.nodes:
                self._release(job)
                if job.rerunnable:
                    job.state = JobState.PENDING
                    job.restarts += 1
                    self.queue.insert(0, job.id)
                else:
                    job.state = JobState.FAILED
                if self.reg:
                    self.reg.counter("scheduler_job_interrupts").inc(
                        1, {"job": job.id})
        self.schedule()

    def replace_degraded(self, job_id: str, bad_nodes: List[int]) -> bool:
        """Straggler mitigation: swap degraded nodes from the buffer pool
        without changing job size.  Returns True if fully replaced."""
        job = self.jobs[job_id]
        free = self.free_healthy()
        if len(free) < len(bad_nodes):
            return False
        for bad in bad_nodes:
            new = free.pop(0)
            job.nodes[job.nodes.index(bad)] = new
            self._allocated.discard(bad)
            self._allocated.add(new)
            self.cluster.start_repair(bad)
        job.restarts += 1
        if self.reg:
            self.reg.counter("scheduler_node_swaps").inc(
                len(bad_nodes), {"job": job_id})
        return True

    def complete(self, job_id: str):
        job = self.jobs[job_id]
        job.state = JobState.DONE
        self._release(job)
        self.schedule()

    def elastic_resize(self, job_id: str, n_nodes: int):
        """Elastic scaling: restart the job at a different gang size (the
        checkpoint reshard on restore makes this transparent)."""
        job = self.jobs[job_id]
        self._release(job)
        job.n_nodes = n_nodes
        job.state = JobState.PENDING
        job.restarts += 1
        if job.id not in self.queue:
            self.queue.insert(0, job.id)
        self.schedule()

    # ------------------------------------------------------------ helpers ----
    def _release(self, job: Job):
        for n in job.nodes:
            self._allocated.discard(n)
        job.nodes = []

    def buffer_size(self) -> int:
        return len(self.free_healthy())
