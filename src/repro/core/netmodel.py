"""α–β collective cost model calibrated to the paper's published NCCL
all_reduce measurements (Figs 3–4): TCP vs RoCE vs GPU-direct RDMA, plus the
TPU ICI point used for capacity planning in this framework.

Ring all-reduce of M bytes over n endpoints:
    t(M, n) = 2 (n-1) α  +  2 (n-1)/n · M / B
The paper plots *bus bandwidth* busbw = 2 (n-1)/n · M / t, saturating at B.

Calibration targets from the paper's text:
  * 8 MB @1024 GPUs:  GDR ≈ 2 GB/s algbw vs TCP ≈ 0.2 GB/s  (10x)
  * >=500 MB:         GDR 20-30 GB/s vs TCP ~6 GB/s          (3-5x)
These emerge from (B, α) = (30 GB/s, 4 µs) vs (6 GB/s, 40 µs); RoCE without
GDR sits between (20 GB/s, 8 µs — host-bounce bandwidth cap).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class Protocol:
    name: str
    bus_bw: float      # bytes/s saturated bus bandwidth
    alpha: float       # per-hop latency, seconds


TCP = Protocol("tcp", 6.0e9, 40e-6)
ROCE = Protocol("roce", 20.0e9, 8e-6)
GDR = Protocol("gdr", 30.0e9, 4e-6)
ICI = Protocol("ici", 100.0e9, 1e-6)      # TPU v5e 2D-torus per-chip (2 links)

PROTOCOLS: Dict[str, Protocol] = {p.name: p for p in (TCP, ROCE, GDR, ICI)}


def allreduce_time(nbytes: float, n: int, proto: Protocol) -> float:
    if n <= 1:
        return 0.0
    return 2 * (n - 1) * proto.alpha + 2 * (n - 1) / n * nbytes / proto.bus_bw


def bus_bandwidth(nbytes: float, n: int, proto: Protocol) -> float:
    """What nccl-tests reports as busbw."""
    t = allreduce_time(nbytes, n, proto)
    return 2 * (n - 1) / n * nbytes / t if t > 0 else 0.0


def alg_bandwidth(nbytes: float, n: int, proto: Protocol) -> float:
    t = allreduce_time(nbytes, n, proto)
    return nbytes / t if t > 0 else 0.0


def allgather_time(nbytes_out: float, n: int, proto: Protocol) -> float:
    if n <= 1:
        return 0.0
    return (n - 1) * proto.alpha + (n - 1) / n * nbytes_out / proto.bus_bw


def scaling_curve(proto: Protocol, sizes, n: int):
    return [(m, bus_bandwidth(m, n, proto)) for m in sizes]


def gpu_count_curve(proto: Protocol, nbytes: float, counts):
    return [(n, bus_bandwidth(nbytes, n, proto)) for n in counts]


def job_step_network_seconds(grad_bytes: float, n_dp: int,
                             proto: Protocol) -> float:
    """One DP gradient synchronization per step."""
    return allreduce_time(grad_bytes, n_dp, proto)
