"""Prometheus-style metrics registry (counters / gauges / histograms) — the
observability surface of §2.3.2 / §3.2.3.  Pure python, thread-safe, with a
text exposition renderer for the dashboards in the examples."""
from __future__ import annotations

import bisect
import threading
from typing import Dict, Iterable, List, Optional, Tuple

LabelSet = Tuple[Tuple[str, str], ...]


def _labels(labels: Optional[Dict[str, str]]) -> LabelSet:
    return tuple(sorted((labels or {}).items()))


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help_: str = ""):
        self.name = name
        self.help = help_
        self._lock = threading.Lock()
        self._series: Dict[LabelSet, float] = {}

    def labels_values(self) -> List[Tuple[LabelSet, float]]:
        with self._lock:
            return list(self._series.items())


class Counter(_Metric):
    kind = "counter"

    def inc(self, value: float = 1.0, labels: Optional[Dict] = None):
        assert value >= 0
        ls = _labels(labels)
        with self._lock:
            self._series[ls] = self._series.get(ls, 0.0) + value

    def get(self, labels: Optional[Dict] = None) -> float:
        return self._series.get(_labels(labels), 0.0)


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, labels: Optional[Dict] = None):
        with self._lock:
            self._series[_labels(labels)] = float(value)

    def inc(self, value: float = 1.0, labels: Optional[Dict] = None):
        ls = _labels(labels)
        with self._lock:
            self._series[ls] = self._series.get(ls, 0.0) + value

    def get(self, labels: Optional[Dict] = None) -> float:
        return self._series.get(_labels(labels), 0.0)


class Histogram(_Metric):
    kind = "histogram"
    DEFAULT_BUCKETS = (.005, .01, .025, .05, .1, .25, .5, 1, 2.5, 5, 10,
                       25, 60, 120, 300, float("inf"))

    def __init__(self, name: str, help_: str = "", buckets: Iterable = ()):
        super().__init__(name, help_)
        self.buckets = tuple(buckets) or self.DEFAULT_BUCKETS
        self._counts: Dict[LabelSet, List[int]] = {}
        self._sums: Dict[LabelSet, float] = {}
        self._raw: Dict[LabelSet, List[float]] = {}

    def observe(self, value: float, labels: Optional[Dict] = None):
        ls = _labels(labels)
        with self._lock:
            counts = self._counts.setdefault(ls, [0] * len(self.buckets))
            idx = bisect.bisect_left(self.buckets, value)
            counts[min(idx, len(self.buckets) - 1)] += 1
            self._sums[ls] = self._sums.get(ls, 0.0) + value
            raw = self._raw.setdefault(ls, [])
            raw.append(value)
            if len(raw) > 4096:          # ring buffer for quantile queries
                del raw[:2048]

    def count(self, labels: Optional[Dict] = None) -> int:
        return sum(self._counts.get(_labels(labels), []))

    def sum(self, labels: Optional[Dict] = None) -> float:
        return self._sums.get(_labels(labels), 0.0)

    def quantile(self, q: float, labels: Optional[Dict] = None) -> float:
        raw = sorted(self._raw.get(_labels(labels), []))
        if not raw:
            return float("nan")
        return raw[min(int(q * len(raw)), len(raw) - 1)]

    def recent(self, n: int, labels: Optional[Dict] = None) -> List[float]:
        return self._raw.get(_labels(labels), [])[-n:]


class MetricsRegistry:
    def __init__(self):
        self._metrics: Dict[str, _Metric] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help_: str, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help_, **kw)
                self._metrics[name] = m
            assert isinstance(m, cls), (name, m.kind)
            return m

    def counter(self, name: str, help_: str = "") -> Counter:
        return self._get(Counter, name, help_)

    def gauge(self, name: str, help_: str = "") -> Gauge:
        return self._get(Gauge, name, help_)

    def histogram(self, name: str, help_: str = "",
                  buckets: Iterable = ()) -> Histogram:
        return self._get(Histogram, name, help_, buckets=buckets)

    def snapshot(self) -> Dict[str, Dict[LabelSet, float]]:
        return {name: dict(m.labels_values())
                for name, m in self._metrics.items()}

    def render(self) -> str:
        """Prometheus text exposition format."""
        lines = []
        for name, m in sorted(self._metrics.items()):
            lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for ls, v in m.labels_values():
                lbl = ",".join(f'{k}="{v2}"' for k, v2 in ls)
                lines.append(f"{name}{{{lbl}}} {v}" if lbl else f"{name} {v}")
        return "\n".join(lines) + "\n"


GLOBAL_REGISTRY = MetricsRegistry()
