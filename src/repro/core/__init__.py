"""The paper's primary contribution: the fault-tolerant training
infrastructure (cluster failure model, health checks, alerting, gang
scheduling with buffer pool, two-tier storage, Young-interval checkpointing,
and the FT runtime composing them)."""
from repro.core.aiops import Anomaly, AnomalyDetector, render_dashboard
from repro.core.alerts import Alert, AlertManager, SlackSink
from repro.core.tenancy import Namespace, TenantScheduler
from repro.core.checkpoint import (CheckpointManager, latest_step,
                                   load_checkpoint, save_checkpoint)
from repro.core.clock import VirtualClock, WallClock
from repro.core.cluster import FailureKind, Node, NodeState, SimCluster
from repro.core.health import Autopilot
from repro.core.runtime import (FTTrainLoop, GoodputReport, job_mtbf_seconds,
                                simulate_job)
from repro.core.scheduler import GangScheduler, Job, JobState
from repro.core.storage import (COS, NFS, SCALE, BlobStore, ScaleCache,
                                StorageStack)
from repro.core.straggler import StragglerDetector
from repro.core.telemetry import GLOBAL_REGISTRY, MetricsRegistry
from repro.core.youngs import (checkpoint_every_n_steps, lost_fraction,
                               optimal_lost_fraction, young_interval)

__all__ = [
    "Anomaly", "AnomalyDetector", "render_dashboard", "Namespace",
    "TenantScheduler",
    "Alert", "AlertManager", "SlackSink", "CheckpointManager", "latest_step",
    "load_checkpoint", "save_checkpoint", "VirtualClock", "WallClock",
    "FailureKind", "Node", "NodeState", "SimCluster", "Autopilot",
    "FTTrainLoop", "GoodputReport", "job_mtbf_seconds", "simulate_job",
    "GangScheduler", "Job", "JobState", "COS", "NFS", "SCALE", "BlobStore",
    "ScaleCache", "StorageStack", "StragglerDetector", "GLOBAL_REGISTRY",
    "MetricsRegistry", "checkpoint_every_n_steps", "lost_fraction",
    "optimal_lost_fraction", "young_interval",
]
