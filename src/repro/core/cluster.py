"""Simulated fleet with the paper's failure taxonomy (Table 1).

Hazard rates and degradation factors are taken from the published numbers:
  * clear hardware failures (host crash): ~2%/host/month average, 5% worst
    case; HGX/NVLink repairs are slow (vendor), DIMM repairs quick.
  * subtle failures: power-brake throttling 400W -> 150W (compute derate to
    0.375 => ~2.7-3x step-time hit on the whole job), PCIe link degradation
    (most frequent; ~95% fixed by VM reboot), port failure (ECMP halves a
    node's bandwidth rather than crashing the job).
  * software failures: CUDA allocation errors, HBM row-remap pending (warn;
    reset recommended; can escalate to silent corruption / job crash).

A job's effective throughput is gated by its slowest node (§2.3.1).
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.telemetry import MetricsRegistry

MONTH = 30 * 24 * 3600.0


class FailureKind(enum.Enum):
    HOST_CRASH = "host_crash"            # HGX board / NVLink / DIMM
    POWER_BRAKE = "power_brake"          # PSU failure -> 150W throttle
    PCIE_DEGRADE = "pcie_degrade"        # link downgrade, reboot fixes
    PORT_FAILURE = "port_failure"        # one NIC port down, ECMP absorbs
    ROW_REMAP = "row_remap"              # HBM row remap pending (warning)
    CUDA_ERROR = "cuda_error"            # software failure, app crash


# per-second hazard rates (exponential), derived from the paper
DEFAULT_RATES = {
    FailureKind.HOST_CRASH: 0.02 / MONTH,
    FailureKind.POWER_BRAKE: 0.01 / MONTH,
    FailureKind.PCIE_DEGRADE: 0.06 / MONTH,   # "most frequently observed"
    FailureKind.PORT_FAILURE: 0.01 / MONTH,
    FailureKind.ROW_REMAP: 0.03 / MONTH,
    FailureKind.CUDA_ERROR: 0.02 / MONTH,
}

# multiplicative per-node compute factor while degraded
DEGRADE_FACTOR = {
    FailureKind.POWER_BRAKE: 150.0 / 400.0,   # ~2.7x slower
    FailureKind.PCIE_DEGRADE: 0.5,
    FailureKind.PORT_FAILURE: 0.8,
    FailureKind.ROW_REMAP: 1.0,               # no slowdown; crash risk only
}

# seconds to repair once detected (vendor RMA vs quick fixes)
REPAIR_TIME = {
    FailureKind.HOST_CRASH: 3 * 24 * 3600.0,   # board swap via vendor
    FailureKind.POWER_BRAKE: 8 * 3600.0,
    FailureKind.PCIE_DEGRADE: 900.0,           # VM reboot (>=95% fix rate)
    FailureKind.PORT_FAILURE: 4 * 3600.0,
    FailureKind.ROW_REMAP: 900.0,              # GPU reset
    FailureKind.CUDA_ERROR: 600.0,
}


class NodeState(enum.Enum):
    HEALTHY = "healthy"
    DEGRADED = "degraded"
    CRASHED = "crashed"
    REPAIRING = "repairing"


@dataclass
class Node:
    id: int
    gpus: int = 8
    state: NodeState = NodeState.HEALTHY
    active_failures: List[FailureKind] = field(default_factory=list)
    repair_done_at: float = 0.0
    perf_factor: float = 1.0

    def apply(self, kind: FailureKind):
        if kind in (FailureKind.HOST_CRASH, FailureKind.CUDA_ERROR):
            self.state = NodeState.CRASHED
        else:
            self.state = NodeState.DEGRADED
        if kind not in self.active_failures:
            self.active_failures.append(kind)
        self._recompute()

    def _recompute(self):
        f = 1.0
        for k in self.active_failures:
            f *= DEGRADE_FACTOR.get(k, 1.0)
        self.perf_factor = 0.0 if self.state in (
            NodeState.CRASHED, NodeState.REPAIRING) else f

    def heal(self):
        self.active_failures.clear()
        self.state = NodeState.HEALTHY
        self.perf_factor = 1.0


@dataclass
class FailureEvent:
    t: float
    node_id: int
    kind: FailureKind


class SimCluster:
    """Fleet of nodes with stochastic failures on a virtual timeline."""

    def __init__(self, n_nodes: int, seed: int = 0,
                 rates: Optional[Dict[FailureKind, float]] = None,
                 registry: Optional[MetricsRegistry] = None):
        self.nodes = [Node(i) for i in range(n_nodes)]
        self.rates = dict(rates or DEFAULT_RATES)
        self.rng = np.random.default_rng(seed)
        self.events: List[FailureEvent] = []
        self.reg = registry
        self.now = 0.0

    # ----------------------------------------------------------- dynamics ----
    def advance(self, dt: float):
        """Advance time; sample failures; finish repairs."""
        self.now += dt
        total_rate = sum(self.rates.values())
        for node in self.nodes:
            if node.state == NodeState.REPAIRING:
                if self.now >= node.repair_done_at:
                    node.heal()
                continue
            # exponential failure sampling per kind
            if self.rng.random() < -math.expm1(-total_rate * dt):
                kinds, probs = zip(*[(k, r / total_rate)
                                     for k, r in self.rates.items()])
                kind = kinds[self.rng.choice(len(kinds), p=probs)]
                self.inject(node.id, kind)

    def inject(self, node_id: int, kind: FailureKind):
        node = self.nodes[node_id]
        node.apply(kind)
        self.events.append(FailureEvent(self.now, node_id, kind))
        if self.reg:
            self.reg.counter("cluster_failures_total").inc(
                1, {"kind": kind.value})
            self.reg.gauge("node_perf_factor").set(
                node.perf_factor, {"node": str(node_id)})

    def start_repair(self, node_id: int):
        node = self.nodes[node_id]
        worst = max((REPAIR_TIME[k] for k in node.active_failures),
                    default=600.0)
        node.state = NodeState.REPAIRING
        node.repair_done_at = self.now + worst
        node._recompute()

    # ------------------------------------------------------------ queries ----
    def healthy_nodes(self) -> List[Node]:
        return [n for n in self.nodes if n.state == NodeState.HEALTHY]

    def job_perf_factor(self, node_ids: List[int]) -> float:
        """Job speed == slowest participating node (paper §2.3.1)."""
        factors = [self.nodes[i].perf_factor for i in node_ids]
        return min(factors) if factors else 0.0

    def crashed_in(self, node_ids: List[int]) -> List[int]:
        return [i for i in node_ids
                if self.nodes[i].state in (NodeState.CRASHED,
                                           NodeState.REPAIRING)]

    def degraded_in(self, node_ids: List[int], threshold: float = 0.95
                    ) -> List[int]:
        return [i for i in node_ids
                if 0 < self.nodes[i].perf_factor < threshold]
