"""internvl2-2b [vlm] — InternViT + InternLM2 backbone.

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
The ViT frontend is a STUB: input_specs() provides precomputed patch
embeddings (num_image_tokens per image) prepended to the text sequence.
[arXiv:2404.16821; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    source="arXiv:2404.16821",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    num_image_tokens=256,
    rope_theta=1000000.0,
)
