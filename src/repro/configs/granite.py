"""The paper's own Granite model family (Table 2) [arXiv:2405.04324].

granite-20b-code was trained on Vela with 4-way TP, 4-way PP, 48-way DP (768 GPUs).
These configs drive the paper-claims benchmarks (Tables 2 & 4, Fig 7).
"""
from repro.configs.base import ModelConfig

GRANITE_8B = ModelConfig(
    name="granite-8b",
    family="dense",
    source="arXiv:2405.04324",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=49152,
)

GRANITE_13B = ModelConfig(
    name="granite-13b",
    family="dense",
    source="arXiv:2405.04324",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    head_dim=128,
    d_ff=20480,
    vocab_size=49152,
    act="gelu",
    norm="layernorm",
    use_bias=True,
)

GRANITE_20B = ModelConfig(
    name="granite-20b-code",
    family="dense",
    source="arXiv:2405.04324",
    num_layers=52,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,       # MQA (GPT-BigCode style)
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    act="gelu",
    norm="layernorm",
    use_bias=True,
)
