"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal backbone.

24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206.
The modality frontend is a STUB: input_specs() provides precomputed frame
embeddings of length seq_len // enc_ratio.
[arXiv:2308.11596; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    source="arXiv:2308.11596",
    num_layers=24,          # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    enc_ratio=4,
    norm="layernorm",
    act="gelu",
    use_bias=True,
)
