"""rwkv6-1.6b [ssm] — Finch: attention-free, data-dependent decay.

24L d_model=2048 (attn-free) d_ff=7168 vocab=65536.
[arXiv:2404.05892; unverified]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    family="ssm",
    source="arXiv:2404.05892",
    num_layers=24,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=7168,
    vocab_size=65536,
    rwkv_head_dim=64,
    norm="layernorm",
)
