"""Typed configuration objects for the repro framework.

One ``ModelConfig`` dataclass covers every assigned architecture family
(dense / moe / hybrid / ssm / encdec / vlm).  Configs are frozen; derived
quantities are properties.  ``reduced()`` produces a small same-family config
for CPU smoke tests (full configs are only ever lowered via the dry-run).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    # --- identity -----------------------------------------------------------
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    source: str = ""  # provenance tag from the assignment table

    # --- transformer backbone ------------------------------------------------
    num_layers: int = 12
    d_model: int = 768
    num_heads: int = 12          # 0 => attention-free family
    num_kv_heads: int = 12
    head_dim: int = 0            # 0 => d_model // num_heads
    d_ff: int = 3072
    vocab_size: int = 32000
    act: str = "silu"            # silu (SwiGLU) | gelu
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    qk_norm: bool = False        # qwen3
    tie_embeddings: bool = False
    use_bias: bool = False

    # --- MoE ------------------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0            # expert hidden dim (d_ff used for the dense path)
    dense_residual: bool = False  # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    router_jitter: float = 0.0
    moe_every: int = 1           # MoE in every k-th layer (1 = all layers)

    # --- SSM / hybrid ----------------------------------------------------------
    ssm_state: int = 0           # mamba2 d_state
    ssm_head_dim: int = 64       # mamba2 P (channels per head)
    ssm_expand: int = 2          # d_inner = expand * d_model
    ssm_conv_dim: int = 4        # depthwise conv width
    ssm_chunk: int = 256         # SSD chunk length
    hybrid_attn_every: int = 0   # zamba2: shared attention block cadence (0 = none)

    # --- RWKV -------------------------------------------------------------------
    rwkv_head_dim: int = 64

    # --- encoder-decoder ---------------------------------------------------------
    encoder_layers: int = 0
    enc_ratio: int = 4           # enc_len = seq_len // enc_ratio (stub frontend frames)

    # --- VLM -----------------------------------------------------------------------
    num_image_tokens: int = 0    # stub ViT patch embeddings prepended to the text

    # --- numerics --------------------------------------------------------------------
    dtype: str = "bfloat16"      # activation/compute dtype
    param_dtype: str = "float32"  # master weights

    # ------------------------------------------------------------------ derived ---
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        if self.num_heads == 0:
            return 0
        return self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 (Megatron convention, MXU friendly)."""
        return pad_to_multiple(self.vocab_size, 128)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for the long_500k shape (SSM / hybrid / linear attention)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        """Encoder-only archs have no decode step; all assigned archs decode."""
        return True

    # --------------------------------------------------------------- counting ----
    def param_count(self) -> int:
        """Analytic parameter count (matches the initializer tree; tested)."""
        d, v = self.d_model, self.padded_vocab
        hd = self.resolved_head_dim
        n = v * d                      # token embedding
        if not self.tie_embeddings:
            n += v * d                 # lm head
        n += d                         # final norm

        def attn_params() -> int:
            p = d * self.num_heads * hd          # q
            p += 2 * d * self.num_kv_heads * hd  # k, v
            p += self.num_heads * hd * d         # o
            if self.qk_norm:
                p += 2 * hd
            return p

        def dense_ffn(dff: int) -> int:
            if self.act == "silu":
                return 3 * d * dff   # gate, up, down
            return 2 * d * dff

        def moe_ffn() -> int:
            p = d * self.num_experts                      # router
            p += self.num_experts * 3 * d * self.moe_d_ff  # experts (SwiGLU)
            if self.dense_residual:
                p += dense_ffn(self.d_ff)
            return p

        def mamba_params() -> int:
            din, s, hn = self.d_inner, self.ssm_state, self.ssm_heads
            p = d * (2 * din + 2 * s + hn)  # in_proj -> [x, z, B, C, dt]
            p += self.ssm_conv_dim * (din + 2 * s)  # depthwise conv over x,B,C
            p += hn + hn                    # A_log, D
            p += hn                         # dt_bias
            p += din                        # gated norm scale
            p += din * d                    # out_proj
            return p

        def rwkv_params() -> int:
            p = 0
            p += 6 * d          # token-shift mix coefficients (r,k,v,w,g + lerp x)
            p += d * 64 + 64 * d * 5   # low-rank data-dependent mix (lora dim 64)
            p += d * d * 4      # r,k,v,g projections
            p += d * 64 + 64 * d  # decay lora
            p += self.rwkv_heads * self.rwkv_head_dim  # u (bonus)
            p += d              # ln_x scale
            p += d * d          # output proj
            p += dense_ffn_rwkv()
            return p

        def dense_ffn_rwkv() -> int:
            return 2 * d + d * self.d_ff + self.d_ff * d  # rwkv channel-mix

        per_layer_norms = 2 * d

        total_layers = 0
        if self.family in ("dense", "vlm"):
            n += self.num_layers * (attn_params() + dense_ffn(self.d_ff) + per_layer_norms)
        elif self.family == "moe":
            n += self.num_layers * (attn_params() + moe_ffn() + per_layer_norms)
        elif self.family == "ssm":
            n += self.num_layers * (rwkv_params() + per_layer_norms)
        elif self.family == "hybrid":
            n += self.num_layers * (mamba_params() + d)  # one pre-norm per mamba layer
            if self.hybrid_attn_every:
                # one shared attention+ffn block (weights tied across invocations)
                n += attn_params() + dense_ffn(self.d_ff) + per_layer_norms
                n += 2 * d * d  # concat(current, embed) down-projection (zamba style)
        elif self.family == "encdec":
            enc_attn = attn_params()
            n += self.encoder_layers * (enc_attn + dense_ffn(self.d_ff) + per_layer_norms)
            # decoder: self attn + cross attn + ffn
            n += self.num_layers * (2 * attn_params() + dense_ffn(self.d_ff) + 3 * d)
            n += d  # encoder final norm
        else:
            raise ValueError(self.family)
        del total_layers
        if self.family == "vlm" and self.num_image_tokens:
            n += self.num_image_tokens * d  # learned image-token position table (stub)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        inactive_experts = self.num_experts - self.experts_per_token
        per_layer_inactive = inactive_experts * 3 * self.d_model * self.moe_d_ff
        n_moe_layers = len([i for i in range(self.num_layers) if i % self.moe_every == 0])
        return full - n_moe_layers * per_layer_inactive

    def flops_per_token(self, seq_len: int, kind: str = "train") -> float:
        """Model FLOPs per token: 6·N_active (train) / 2·N_active (fwd/decode)
        plus attention score·value FLOPs.

        Causal full-sequence attention averages S/2 keys per query:
        fwd = 2 matmuls × 2 flops × H·hd·S/2 = 2·H·hd·S per layer per token
        (×3 with backward).  Decode attends to the whole cache: 4·H·hd·S.
        """
        n_active = self.active_param_count()
        mult = 6.0 if kind == "train" else 2.0
        flops = mult * n_active
        if self.num_heads and self.family != "ssm":
            hd = self.resolved_head_dim
            n_attn_layers = self.num_layers
            if self.family == "hybrid" and self.hybrid_attn_every:
                n_attn_layers = self.num_layers // self.hybrid_attn_every
            if self.family == "encdec":
                n_attn_layers = self.num_layers + self.encoder_layers
            per_layer = (4.0 if kind == "decode" else 2.0) * \
                self.num_heads * hd * seq_len
            flops += (mult / 2.0 if kind != "decode" else 1.0) * \
                n_attn_layers * per_layer
        return flops

    # --------------------------------------------------------------- reduction ----
    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        changes = dict(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 2 if self.family != "hybrid" else 4),
            d_model=128,
            num_heads=min(self.num_heads, 4) if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=32 if self.num_heads else 0,
            d_ff=256,
            vocab_size=512,
        )
        if self.is_moe:
            changes.update(num_experts=8,
                           experts_per_token=min(self.experts_per_token, 2),
                           moe_d_ff=64)
        if self.family in ("ssm", "hybrid"):
            changes.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
        if self.hybrid_attn_every:
            changes.update(hybrid_attn_every=2)
        if self.family == "encdec":
            changes.update(encoder_layers=2)
        if self.family == "vlm":
            changes.update(num_image_tokens=8)
        return dataclasses.replace(self, **changes)


@dataclass(frozen=True)
class ShapeConfig:
    """An input-shape cell. kind selects which step gets lowered."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


@dataclass(frozen=True)
class ParallelConfig:
    """How a job maps onto the mesh."""
    mesh_shape: Tuple[int, ...] = (16, 16)
    mesh_axes: Tuple[str, ...] = ("data", "model")
    fsdp: bool = True               # shard params/opt over "data" (ZeRO-3)
    zero_stage: int = 3             # 0: replicated grads+state; 2: sharded state; 3: sharded params
    pipeline_stages: int = 1        # >1 => pipeline over leading axis
    remat: str = "selective"        # none | selective | full
    scan_layers: bool = True
    microbatches: int = 1
    grad_compression: str = "none"  # none | int8_ef
    collective_matmul: bool = False

    @property
    def num_devices(self) -> int:
        return math.prod(self.mesh_shape)


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    z_loss: float = 1e-4            # logit z-loss (stability at scale)
    moe_aux_loss: float = 1e-2      # load-balance loss weight


@dataclass(frozen=True)
class RunConfig:
    """A full job description (what the scheduler queues)."""
    model: ModelConfig = None
    shape: ShapeConfig = None
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    train: TrainConfig = field(default_factory=TrainConfig)
    checkpoint_dir: str = "/tmp/repro_ckpt"
    checkpoint_every: Optional[int] = None  # None => Young's formula
