"""starcoder2-3b [dense] — GQA kv=2, RoPE.

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152.
[arXiv:2402.19173; hf]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    source="arXiv:2402.19173",
    num_layers=30,
    d_model=3072,
    num_heads=24,
    num_kv_heads=2,
    head_dim=128,
    d_ff=12288,
    vocab_size=49152,
    act="gelu",
    norm="layernorm",
    use_bias=True,
    rope_theta=100000.0,
)
