"""The assigned input-shape set (identical across the LM-family archs)."""
from repro.configs.base import ShapeConfig

TRAIN_4K = ShapeConfig(name="train_4k", seq_len=4096, global_batch=256, kind="train")
PREFILL_32K = ShapeConfig(name="prefill_32k", seq_len=32768, global_batch=32, kind="prefill")
DECODE_32K = ShapeConfig(name="decode_32k", seq_len=32768, global_batch=128, kind="decode")
LONG_500K = ShapeConfig(name="long_500k", seq_len=524288, global_batch=1, kind="decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def applicable(model_cfg, shape: ShapeConfig) -> bool:
    """Shape-cell applicability rules (see DESIGN.md §4).

    long_500k needs sub-quadratic attention state: run for ssm/hybrid only.
    """
    if shape.name == "long_500k":
        return model_cfg.sub_quadratic
    if shape.kind == "decode" and not model_cfg.has_decoder:
        return False
    return True
