"""Config registry: ``get_config(name)`` / ``--arch <id>`` resolution."""
from repro.configs.base import (ModelConfig, ParallelConfig, RunConfig,
                                ShapeConfig, TrainConfig)
from repro.configs.shapes import SHAPES, applicable, get_shape

from repro.configs.arctic_480b import CONFIG as _arctic
from repro.configs.moonshot_v1_16b_a3b import CONFIG as _moonshot
from repro.configs.zamba2_1_2b import CONFIG as _zamba2
from repro.configs.llama3_2_3b import CONFIG as _llama32
from repro.configs.starcoder2_3b import CONFIG as _starcoder2
from repro.configs.llama3_405b import CONFIG as _llama405
from repro.configs.qwen3_4b import CONFIG as _qwen3
from repro.configs.rwkv6_1_6b import CONFIG as _rwkv6
from repro.configs.seamless_m4t_large_v2 import CONFIG as _seamless
from repro.configs.internvl2_2b import CONFIG as _internvl
from repro.configs.granite import GRANITE_8B, GRANITE_13B, GRANITE_20B

ASSIGNED_ARCHS = {
    c.name: c
    for c in (_arctic, _moonshot, _zamba2, _llama32, _starcoder2,
              _llama405, _qwen3, _rwkv6, _seamless, _internvl)
}

PAPER_ARCHS = {c.name: c for c in (GRANITE_8B, GRANITE_13B, GRANITE_20B)}

CONFIGS = {**ASSIGNED_ARCHS, **PAPER_ARCHS}


def get_config(name: str) -> ModelConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(CONFIGS)}")
    return CONFIGS[name]


def list_configs(assigned_only: bool = False):
    return sorted(ASSIGNED_ARCHS if assigned_only else CONFIGS)


__all__ = [
    "ModelConfig", "ParallelConfig", "RunConfig", "ShapeConfig", "TrainConfig",
    "SHAPES", "applicable", "get_shape", "get_config", "list_configs",
    "ASSIGNED_ARCHS", "PAPER_ARCHS", "CONFIGS",
]
