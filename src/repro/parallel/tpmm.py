"""Explicit tensor-parallel contractions via shard_map (§Perf).

Under pure GSPMD the TP psum after the attention output-projection and the
MLP down-projection reduces the *f32* dot output before converting to bf16
(observed: 8.6 GB all-reduces per layer on llama3-405b — 2× the necessary
wire bytes).  These wrappers make the collective explicit: local matmul →
cast partials to bf16 → psum in bf16, which is exactly what NCCL/ICI
reductions do in production (tensor-dtype reduction).

FSDP composition: the weight's embed dim stays data-sharded at rest and is
all-gathered over ``data`` inside (the same gather GSPMD inserted, now
explicit).  Falls back to a plain einsum outside a sharding context or when
the contraction dim doesn't divide the model axis.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from jax.ad_checkpoint import checkpoint_name

from repro.parallel.sharding import current_context, shard_map

TP_SAVE_NAME = "tp_psum_out"   # remat policy saves these (§Perf llama it6):
# jax.checkpoint can't see inside shard_map, so without the name the psum'd
# projection outputs get recomputed (collectives replayed!) in the backward.


def _ctx_ok(k_dim: int, axis: str):
    ctx = current_context()
    if ctx is None:
        return None
    mesh, rules = ctx
    if axis not in mesh.shape or k_dim % mesh.shape[axis] != 0:
        return None
    return mesh, rules


def o_proj_tp(y, kernel, bias=None, axis: str = "model"):
    """y: (B,S,H,D) head-sharded over ``axis``; kernel: (H,D,dm) with H over
    ``axis`` and dm FSDP-sharded over ``data``.  Returns (B,S,dm) psum'd in
    bf16."""
    dtype = y.dtype
    got = _ctx_ok(y.shape[2], axis)
    if got is None:
        out = jnp.einsum("bshe,hed->bsd", y, kernel.astype(dtype))
        return out if bias is None else out + bias.astype(dtype)
    mesh, rules = got
    dp = rules.get("batch")
    dm = kernel.shape[-1]
    data_ok = "data" in mesh.shape and dm % mesh.shape["data"] == 0

    def body(y_loc, w_loc):
        if data_ok:
            w_loc = jax.lax.all_gather(w_loc, "data", axis=2, tiled=True)
        part = jnp.einsum("bshe,hed->bsd", y_loc, w_loc.astype(dtype))
        return jax.lax.psum(part.astype(dtype), axis)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, None, axis, None),
                  P(axis, None, "data" if data_ok else None)),
        out_specs=P(dp, None, None), check_vma=False)
    out = checkpoint_name(fn(y, kernel), TP_SAVE_NAME)
    return out if bias is None else out + bias.astype(dtype)


def col_proj_tp(x, kernel, bias=None, axis: str = "model"):
    """Column-parallel projection: x (B,S,d) -> (B,S,*out) with the first
    output dim of kernel sharded over ``axis`` (no fwd collective; the
    *backward* dx psum runs in bf16 through the shard_map instead of GSPMD's
    f32).  kernel: (d, F) or (d, H, D) with F/H sharded; d FSDP over data."""
    dtype = x.dtype
    got = _ctx_ok(kernel.shape[1], axis)
    if got is None:
        return _plain_col(x, kernel, bias, dtype)
    mesh, rules = got
    dp = rules.get("batch")
    d = kernel.shape[0]
    data_ok = "data" in mesh.shape and d % mesh.shape["data"] == 0
    rank3 = kernel.ndim == 3
    eq = "bsd,dhe->bshe" if rank3 else "bsd,df->bsf"

    def body(x_loc, w_loc):
        if data_ok:
            w_loc = jax.lax.all_gather(w_loc, "data", axis=0, tiled=True)
        return jnp.einsum(eq, x_loc, w_loc.astype(dtype))

    w_spec = P("data" if data_ok else None, axis, None) if rank3 else \
        P("data" if data_ok else None, axis)
    out_spec = P(dp, None, axis, None) if rank3 else P(dp, None, axis)
    fn = shard_map(body, mesh=mesh,
                       in_specs=(P(dp, None, None), w_spec),
                       out_specs=out_spec, check_vma=False)
    out = checkpoint_name(fn(x, kernel), TP_SAVE_NAME)
    if bias is not None:
        out = out + bias.astype(dtype)
    return out


def _plain_col(x, kernel, bias, dtype):
    eq = "bsd,dhe->bshe" if kernel.ndim == 3 else "bsd,df->bsf"
    out = jnp.einsum(eq, x, kernel.astype(dtype))
    if bias is not None:
        out = out + bias.astype(dtype)
    return out


def down_proj_tp(h, kernel, bias=None, axis: str = "model"):
    """h: (B,S,F) F-sharded over ``axis``; kernel: (F,dm), F over ``axis``,
    dm FSDP-sharded.  Returns (B,S,dm) psum'd in bf16."""
    dtype = h.dtype
    got = _ctx_ok(h.shape[-1], axis)
    if got is None:
        out = jnp.einsum("bsf,fd->bsd", h, kernel.astype(dtype))
        return out if bias is None else out + bias.astype(dtype)
    mesh, rules = got
    dp = rules.get("batch")
    dm = kernel.shape[-1]
    data_ok = "data" in mesh.shape and dm % mesh.shape["data"] == 0

    def body(h_loc, w_loc):
        if data_ok:
            w_loc = jax.lax.all_gather(w_loc, "data", axis=1, tiled=True)
        part = jnp.einsum("bsf,fd->bsd", h_loc, w_loc.astype(dtype))
        return jax.lax.psum(part.astype(dtype), axis)

    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp, None, axis),
                  P(axis, "data" if data_ok else None)),
        out_specs=P(dp, None, None), check_vma=False)
    out = checkpoint_name(fn(h, kernel), TP_SAVE_NAME)
    return out if bias is None else out + bias.astype(dtype)
