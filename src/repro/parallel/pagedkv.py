"""Sharded paged serving: ``kv_pages``-partitioned pools under shard_map.

The paged KV pool's leading (P) dim carries the ``kv_pages`` logical axis
(``repro.parallel.sharding.default_rules`` maps it to the ``model`` mesh
axis), so an inference mesh of n chips pins P/n pages each — pool HBM
scales *down* with the mesh instead of being replicated.  Chip c owns the
global page-id range ``[c*P/n, (c+1)*P/n)``; the (B, M) page table and the
per-step q/K/V stay replicated (B·M int32 + a token or a chunk per slot —
noise next to the pool).

**One primitive, three paths.**  Every pool access in the serving stack —
decode, whole-prompt prefill, and chunked prefill — is built from the same
three shard_map verbs:

1. **Local scatter-write** — the chip owning the write row commits it at
   its local flat index; every other chip's write is ``mode="drop"``-
   discarded (``attention.scatter_paged_kv_local`` for table-resolved
   decode writes, ``attention.scatter_chunk_paged_local`` for the flat-row
   prefill/chunk destinations).  No path leaves a pool write to GSPMD, so
   no dispatch can materialize a replicated O(P) pool transient.
2. **Local partial attention** — each chip attends only to pages inside
   its window, treating non-local pages exactly like dead pages: the
   Pallas kernel's index map redirects them to local page 0 and
   ``pl.when`` skips their compute (``kernels.ops.paged_decode_partials``),
   and the XLA gather twins mask them to NEG_INF
   (``attention.paged_gather_partials`` for one-token decode,
   ``attention.paged_gather_chunk_partials`` for C-row chunks).  Either
   way the chip emits the raw online-softmax triple (acc, l, m).
3. **Partial-softmax merge** — one pmax + two psums over the *pool* axis
   reconstruct the exact softmax over the union of chips
   (``attention.merge_paged_partials`` / ``merge_paged_chunk_partials``):
   ``out = psum(acc · exp(m - pmax(m))) / psum(l · exp(m - pmax(m)))``.

**2-D batch × pages meshes** (``dp_axis``): the pool shards P/n over the
pool axis only and is *replicated* across the DP axis; the batch dims of
q / page-table / positions shard over DP.  Writes must keep the DP
replicas of each pool shard bitwise identical, so the (tiny) per-step
write operands are made full-batch on every replica — decode
``all_gather``s them over DP inside the body, prefill/chunk declare them
replicated in their in_specs — and every replica applies the *full*
batch's writes to its shard.  Attention then runs only on the replica's
own batch shard, and the softmax merge psums over the pool axis alone:
the merge runs per DP replica, so merge traffic does not grow with the
DP width.

The merge moves O(B·KV·G·(D+2)) fp32 per layer over ICI — independent of
both the pool width and the sequence length, the flash-decoding property
that makes the page dimension the right thing to shard.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.parallel.mesh import mesh_axis_size
from repro.parallel.sharding import default_rules, shard_map, spec_for

# logical axes of a per-layer-stacked page pool (L, P, page, KV, D); only
# kv_pages resolves to a mesh axis — the page/head/dim axes stay local so
# each chip holds whole pages (the kernel's block unit)
POOL_LOGICAL_AXES = ("layers", "kv_pages", None, None, None)

# the int8 page format's scale arrays (L, P, page, KV) drop the D axis but
# keep the page-partitioned leading dims: each chip holds exactly the scales
# of the pages it owns, so local dequant never reads a remote scale
SCALE_LOGICAL_AXES = POOL_LOGICAL_AXES[:4]


def chip_of_page(pid: int, pages_per_chip: int) -> int:
    """The chip owning global page id ``pid`` under the contiguous-range
    P/n split (chip c owns ``[c*P/n, (c+1)*P/n)``).  Shared by the
    allocator's per-chip free lists and the chip-failure drain path, so
    page->chip routing can never disagree between alloc and recovery."""
    return pid // pages_per_chip


def chip_page_range(chip: int, pages_per_chip: int) -> range:
    """The global page-id range chip ``chip`` owns (scratch page 0 included
    when chip 0 — callers that mean *usable* pages must skip id 0)."""
    return range(chip * pages_per_chip, (chip + 1) * pages_per_chip)


def kv_pool_spec(mesh, pool_shape, rules=None,
                 axis: str = None) -> PartitionSpec:
    """PartitionSpec for a (L, P, page, KV, D) pool: ``kv_pages`` -> mesh.

    ``axis`` overrides the rule's target mesh axis (PagedCache passes its
    ``kv_axis`` so a non-default axis name still shards the pool).  On a
    2-D (DP × pool) mesh the spec touches only the pool axis — the pool is
    replicated across DP by construction."""
    rules = dict(rules if rules is not None
                 else default_rules(mesh.axis_names))
    if axis is not None:
        rules["kv_pages"] = axis
    return spec_for(POOL_LOGICAL_AXES, pool_shape, rules, mesh)


def kv_pool_sharding(mesh, pool_shape, rules=None,
                     axis: str = None) -> NamedSharding:
    return NamedSharding(mesh, kv_pool_spec(mesh, pool_shape, rules, axis))


def kv_scale_spec(mesh, scale_shape, rules=None,
                  axis: str = None) -> PartitionSpec:
    """PartitionSpec for a (L, P, page, KV) scale array: same ``kv_pages``
    partitioning as its pool, minus the D axis."""
    rules = dict(rules if rules is not None
                 else default_rules(mesh.axis_names))
    if axis is not None:
        rules["kv_pages"] = axis
    return spec_for(SCALE_LOGICAL_AXES, scale_shape, rules, mesh)


def kv_scale_sharding(mesh, scale_shape, rules=None,
                      axis: str = None) -> NamedSharding:
    return NamedSharding(mesh, kv_scale_spec(mesh, scale_shape, rules, axis))


def _dp_or_none(mesh, dp_axis, batch: int):
    """Resolve the effective DP axis for a dispatch: present in the mesh,
    wider than 1, and dividing the dispatch's batch dim.  Group sizes are
    dynamic (an engine round stacks however many slots progressed), so a
    non-dividing group simply runs replicated across DP — a per-trace
    static decision, never a runtime branch."""
    if dp_axis is None:
        return None
    ndp = mesh_axis_size(mesh, dp_axis)
    return dp_axis if ndp > 1 and batch % ndp == 0 else None


def sharded_paged_decode_attention(mesh, axis: str, q, k_new, v_new,
                                   k_pool, v_pool, page_table, positions,
                                   decode_impl: str = "gather",
                                   k_scale=None, v_scale=None,
                                   dp_axis: str = None):
    """One layer's sharded paged decode: scatter the new token into the
    owning chip's pool shard, compute per-chip softmax partials, merge.

    q: (B, 1, KV, G, D); k_new/v_new: (B, 1, KV, D) this step's projected
    K/V; pools: (P, page, KV, D) GLOBAL views sharded P/n over ``axis``;
    page_table: (B, M) global ids; positions: (B,).  Returns
    (y (B,1,KV,G,D), new_k_pool, new_v_pool) with the pools still sharded.

    ``decode_impl`` picks the per-chip partial producer: ``"pallas"`` (the
    page-table-walking kernel with its local window) or ``"gather"`` (XLA
    local-masked gather) — both feed the identical merge, so the two impls
    stay in parity sharded exactly as they do on one chip.

    ``k_scale``/``v_scale`` (quantized int8 pools): (P, page, KV) fp32
    scale arrays sharded exactly like the pools.  The new token's float K/V
    is quantized *inside* the shard_map body (replicated, deterministic —
    every chip computes the identical (q, scale) pair) and the owning chip
    commits both the int8 row and its scale with the same ``mode="drop"``
    routing; the partial producers then dequantize locally.  Returns a
    5-tuple ``(y, k_pool, v_pool, k_scale, v_scale)``.

    ``dp_axis`` (2-D batch × pages mesh): q/table/positions shard their
    batch dim over DP while the pool stays sharded over ``axis`` only.
    Each replica ``all_gather``s the write operands over DP and applies the
    full batch's writes to its pool shard (keeping DP replicas bitwise
    identical), then attends its own batch shard with the merge psumming
    over ``axis`` alone — the per-DP-replica merge."""
    from repro.kernels import ops as kops
    from repro.models import attention as attn

    n = mesh_axis_size(mesh, axis)
    p_total = k_pool.shape[0]
    assert p_total % n == 0, (
        f"page pool P={p_total} must divide the {axis!r} axis ({n}); "
        "PagedCache pads the pool up to a multiple of the mesh size")
    pn = p_total // n
    quantized = k_scale is not None
    assert quantized == (v_scale is not None), "k/v scales travel together"
    dp = _dp_or_none(mesh, dp_axis, q.shape[0])

    def partials(q, kp, vp, pt, pos, off, ks, vs):
        if decode_impl == "pallas":
            return kops.paged_decode_partials(q, kp, vp, pt, pos, off,
                                              k_scale=ks, v_scale=vs)
        assert decode_impl == "gather", decode_impl
        return attn.paged_gather_partials(q, kp, vp, pt, pos, off,
                                          k_scale=ks, v_scale=vs)

    def full_batch(*xs):
        # 2-D meshes: the write must apply identically on every DP replica
        # of a pool shard, so the (tiny) write operands go full-batch
        if dp is None:
            return xs
        return tuple(jax.lax.all_gather(x, dp, axis=0, tiled=True)
                     for x in xs)

    def body(q, kn, vn, pt, pos, kp, vp):
        off = (jax.lax.axis_index(axis) * pn).astype(jnp.int32)
        wkn, wvn, wpt, wpos = full_batch(kn, vn, pt, pos)
        kp = attn.scatter_paged_kv_local(kp, wkn, wpt, wpos, off)
        vp = attn.scatter_paged_kv_local(vp, wvn, wpt, wpos, off)
        acc, l, m = partials(q, kp, vp, pt, pos, off, None, None)
        y = attn.merge_paged_partials(acc, l, m, axis).astype(q.dtype)
        return y, kp, vp

    def body_quant(q, kn, vn, pt, pos, kp, vp, ks, vs):
        from repro.kernels.quant import quantize_kv
        off = (jax.lax.axis_index(axis) * pn).astype(jnp.int32)
        wkn, wvn, wpt, wpos = full_batch(kn, vn, pt, pos)
        qk, sk = quantize_kv(wkn)
        qv, sv = quantize_kv(wvn)
        kp = attn.scatter_paged_kv_local(kp, qk, wpt, wpos, off)
        vp = attn.scatter_paged_kv_local(vp, qv, wpt, wpos, off)
        ks = attn.scatter_paged_kv_local(ks, sk, wpt, wpos, off)
        vs = attn.scatter_paged_kv_local(vs, sv, wpt, wpos, off)
        acc, l, m = partials(q, kp, vp, pt, pos, off, ks, vs)
        y = attn.merge_paged_partials(acc, l, m, axis).astype(q.dtype)
        return y, kp, vp, ks, vs

    bsp = PartitionSpec(dp) if dp is not None else PartitionSpec()
    sh = PartitionSpec(axis)
    if quantized:
        fn = shard_map(body_quant, mesh=mesh,
                       in_specs=(bsp, bsp, bsp, bsp, bsp, sh, sh, sh, sh),
                       out_specs=(bsp, sh, sh, sh, sh), check_vma=False)
        return fn(q, k_new, v_new, page_table, positions, k_pool, v_pool,
                  k_scale, v_scale)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(bsp, bsp, bsp, bsp, bsp, sh, sh),
                   out_specs=(bsp, sh, sh), check_vma=False)
    return fn(q, k_new, v_new, page_table, positions, k_pool, v_pool)


def sharded_write_prefill(mesh, axis: str, layers, kv_block, dest):
    """Whole-prompt prefill writes as the primitive's local scatter — the
    sharded twin of ``PagedCache.staged_write_prefill``'s flat write.

    layers: the per-layer pool pytree — (L, P, page, KV, D) pools and, for
    int8, (L, P, page, KV) scale arrays — sharded P/n over ``axis``.
    kv_block: a matching pytree of (L, n, Sblk, ...) staged values (already
    quantized for int8 pools, so scales scatter through the same indices).
    dest: (n, Sblk) GLOBAL flat pool rows (page·page_size + row, masked
    positions scratch-routed to 0 by ``PagedCache.prefill_dest``).

    Each chip translates the global rows into its own window
    ``[chip·P/n·page, (chip+1)·P/n·page)`` and commits in-window rows at
    their local flat index; out-of-window rows route one past the shard end
    and ``mode="drop"`` discards them.  The per-chip transient is the
    replicated (n, Sblk) block — O(group·block) — never the O(P) replicated
    pool that GSPMD's partitioned flat scatter may stage
    (``PagedCache.gspmd_write_prefill`` keeps that path measurable).

    On a 2-D mesh the block is replicated across DP (in_specs), so every DP
    replica of a pool shard applies the identical full-group write and the
    replicas stay bitwise equal."""
    sample = jax.tree.leaves(layers)[0]
    p_total, page = sample.shape[1], sample.shape[2]
    n = mesh_axis_size(mesh, axis)
    assert p_total % n == 0, (p_total, n)
    rows = (p_total // n) * page

    def body(layers, kv_block, dest):
        start = (jax.lax.axis_index(axis) * rows).astype(jnp.int32)
        local = dest - start
        idx = jnp.where((local >= 0) & (local < rows), local, rows)

        def write(pool, small):
            flat = pool.reshape(pool.shape[0], rows, *pool.shape[3:])
            flat = flat.at[:, idx].set(small.astype(pool.dtype),
                                       mode="drop")
            return flat.reshape(pool.shape)

        return jax.tree.map(write, layers, kv_block)

    sh = jax.tree.map(lambda _: PartitionSpec(None, axis), layers)
    rep = jax.tree.map(lambda _: PartitionSpec(), kv_block)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(sh, rep, PartitionSpec()),
                   out_specs=sh, check_vma=False)
    return fn(layers, kv_block, dest)


def sharded_prefill_chunk_attention(mesh, axis: str, q, k_new, v_new, dest,
                                    k_pool, v_pool, page_table, start_pos,
                                    last_pos, k_scale=None, v_scale=None,
                                    k_scale_new=None, v_scale_new=None,
                                    dp_axis: str = None):
    """One layer's chunked-prefill scatter + attention under the primitive:
    the sharded twin of the ``_scatter_chunk_paged`` + ``gather_pages``
    body of ``attention.attention_prefill_chunk_block``.

    q: (B, C, KV, G, D) the chunk's queries; k_new/v_new: (B, C, KV, D)
    its projected K/V (already int8-quantized for quantized pools, with
    ``k_scale_new``/``v_scale_new`` the (B, C, KV) fp32 scales); dest:
    (B, C) GLOBAL flat pool rows; pools (P, page, KV, D) sharded P/n over
    ``axis``; page_table: (B, M) REAL global rows; start_pos/last_pos: (B,).

    Writes are the same local flat scatter as prefill
    (``attention.scatter_chunk_paged_local``); attention generalizes the
    decode partials to C query rows with the chunk's position-exact causal
    mask (``attention.paged_gather_chunk_partials``) and merges with the
    C-row merge.  Returns (y (B,C,KV,G,D), pools[, scales]).

    ``dp_axis`` (2-D mesh): the attend operands (q, table, positions)
    shard their batch dim over DP, the write operands (k/v/dest/scales)
    stay replicated so every DP replica applies the full group's writes —
    identical replicas, per-DP-replica merge, exactly the decode scheme."""
    from repro.models import attention as attn

    n = mesh_axis_size(mesh, axis)
    p_total, page = k_pool.shape[:2]
    assert p_total % n == 0, (p_total, n)
    pn = p_total // n
    quantized = k_scale is not None
    c = q.shape[1]
    dp = _dp_or_none(mesh, dp_axis, q.shape[0])

    def attend(q, kp, vp, pt, sp, lp, off, ks, vs):
        qpos = sp[:, None] + jnp.arange(c)[None, :]
        acc, l, m = attn.paged_gather_chunk_partials(
            q, kp, vp, pt, qpos, lp, off, k_scale=ks, v_scale=vs)
        return attn.merge_paged_chunk_partials(acc, l, m, axis).astype(
            q.dtype)

    def body(q, kn, vn, dest, pt, sp, lp, kp, vp):
        off = (jax.lax.axis_index(axis) * pn).astype(jnp.int32)
        roff = off * page  # scatter wants flat rows, partials want pages
        kp = attn.scatter_chunk_paged_local(kp, kn, dest, roff)
        vp = attn.scatter_chunk_paged_local(vp, vn, dest, roff)
        y = attend(q, kp, vp, pt, sp, lp, off, None, None)
        return y, kp, vp

    def body_quant(q, kn, vn, skn, svn, dest, pt, sp, lp, kp, vp, ks, vs):
        off = (jax.lax.axis_index(axis) * pn).astype(jnp.int32)
        roff = off * page
        kp = attn.scatter_chunk_paged_local(kp, kn, dest, roff)
        vp = attn.scatter_chunk_paged_local(vp, vn, dest, roff)
        ks = attn.scatter_chunk_paged_local(ks, skn, dest, roff)
        vs = attn.scatter_chunk_paged_local(vs, svn, dest, roff)
        y = attend(q, kp, vp, pt, sp, lp, off, ks, vs)
        return y, kp, vp, ks, vs

    bsp = PartitionSpec(dp) if dp is not None else PartitionSpec()
    rep = PartitionSpec()
    sh = PartitionSpec(axis)
    if quantized:
        fn = shard_map(
            body_quant, mesh=mesh,
            in_specs=(bsp, rep, rep, rep, rep, rep, bsp, bsp, bsp,
                      sh, sh, sh, sh),
            out_specs=(bsp, sh, sh, sh, sh), check_vma=False)
        return fn(q, k_new, v_new, k_scale_new, v_scale_new, dest,
                  page_table, start_pos, last_pos, k_pool, v_pool,
                  k_scale, v_scale)
    fn = shard_map(body, mesh=mesh,
                   in_specs=(bsp, rep, rep, rep, bsp, bsp, bsp, sh, sh),
                   out_specs=(bsp, sh, sh), check_vma=False)
    return fn(q, k_new, v_new, dest, page_table, start_pos, last_pos,
              k_pool, v_pool)
